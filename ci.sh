#!/usr/bin/env bash
# CI gate for the rust_pallas LSQ repo. Everything here runs with NO
# XLA/PJRT libraries and no Python: the default feature set covers the
# native packed-weight backend, the native training subsystem (hand-written
# LSQ backward), the quant substrate, serving, and the docs spine. (On a
# machine with the vendored `xla` crate + PJRT, append `--features xla`
# runs for the artifact-driven paths.)
set -euo pipefail
cd "$(dirname "$0")"

echo "== rustfmt (cargo fmt --check: formatting is part of the gate) =="
cargo fmt --check

echo "== build (release, default features: native backend only) =="
cargo build --release

echo "== grad-check (fast fail: finite-difference checks of the native"
echo "   LSQ backward — Eq. 3 / Eq. 5 — before the full suite) =="
cargo test --release -q --test grad_check

echo "== tests (unit + native backend + native training + proptests + doctests) =="
cargo test -q

echo "== kernel determinism (re-run the thread-parity/workspace suite with"
echo "   every kernel forced serial: threaded and serial must agree) =="
LSQNET_THREADS=1 cargo test --release -q --test kernels

echo "== multi-model gateway (two-variant native registry — q2+q4 synthetic"
echo "   fixture — 64 requests round-robined across named sessions;"
echo "   per-variant stats must sum to the request count, hot unload must"
echo "   answer every accepted request, QueueFull must surface at depth) =="
cargo test --release -q --test registry

echo "== artifact (.lsqa zero-copy model artifacts — DESIGN.md §Artifact-"
echo "   format: bitwise pack→load→bind parity vs the manifest path with the"
echo "   panel-build counter pinned at zero, the corruption battery (every"
echo "   byte-level failure is a typed ArtifactError, never a panic), and"
echo "   registry-level refusals), then a CLI smoke: pack a fixture family,"
echo "   inspect it, serve from it with NO manifest in the serving dir, and"
echo "   confirm a truncated file is refused =="
timeout 300 cargo test --release -q --test artifact
ART_DIR="$(mktemp -d)"
timeout 300 cargo run --release -q --bin lsqnet -- pack \
  --artifacts "$ART_DIR/fixture" --family cnn_small_q2 --out "$ART_DIR/cnn_small_q2.lsqa"
timeout 300 cargo run --release -q --bin lsqnet -- artifact inspect "$ART_DIR/cnn_small_q2.lsqa"
timeout 300 cargo run --release -q --bin lsqnet -- serve \
  --artifacts "$ART_DIR/empty" --artifact "$ART_DIR/cnn_small_q2.lsqa" --requests 16
head -c 100 "$ART_DIR/cnn_small_q2.lsqa" > "$ART_DIR/corrupt.lsqa"
if cargo run --release -q --bin lsqnet -- artifact inspect "$ART_DIR/corrupt.lsqa" \
     >/dev/null 2>&1; then
  echo "ci.sh: truncated artifact was accepted — the loader must refuse it"; exit 1
fi
rm -rf "$ART_DIR"

echo "== net serve (the TCP wire protocol over loopback, ephemeral ports:"
echo "   bitwise logits parity across a real socket, structured queue_full/"
echo "   unknown_model wire errors, drain_and_unload under in-flight network"
echo "   load, malformed-frame/garbage robustness. Wrapped in 'timeout' so a"
echo "   wedged listener or reader fails CI fast instead of hanging it) =="
timeout 300 cargo test --release -q --test net

echo "== tier controller (SLO-driven adaptive precision tiering: exact"
echo "   transition sequence under a deterministic burst/ramp/sine schedule,"
echo "   zero dropped accepted requests, explicit shed at ladder saturation,"
echo "   drain failover, BENCH decision trace. Timeout-bounded like the net"
echo "   stage so a wedged driver thread fails CI fast) =="
timeout 300 cargo test --release -q --test tier

echo "== chaos (seeded fault injection — DESIGN.md §Fault-model: replica"
echo "   kills + connection sabotage mid-flood must resolve every offered"
echo "   request, reconverge to full replica count, and replay bit-for-bit."
echo "   Run TWICE: each test replays its scenario in-process, and the"
echo "   double run proves the schedule replays across processes too) =="
timeout 300 cargo test --release -q --test chaos
timeout 300 cargo test --release -q --test chaos

echo "== kernel dispatch parity (re-run the same suite with the portable"
echo "   scalar SIMD path pinned: qgemm must stay bitwise, sgemm-family"
echo "   within 1e-5 — so CI on any host exercises both dispatch sides) =="
LSQNET_FORCE_SCALAR=1 cargo test --release -q --test kernels

echo "== forced-level matrix (re-run the kernel suite with LSQNET_SIMD"
echo "   pinned to every level this host can run — each rung of the ladder"
echo "   must pass the full parity suite, not just the auto-detected best."
echo "   'scalar' is skipped here: the LSQNET_FORCE_SCALAR stage above"
echo "   already pins it via the alias) =="
for lvl in $(cargo run --release -q --bin lsqnet -- simd-levels); do
  if [ "$lvl" = "scalar" ]; then continue; fi
  echo "--   LSQNET_SIMD=$lvl"
  LSQNET_SIMD="$lvl" cargo test --release -q --test kernels
done

echo "== FMA tier (re-run the kernel suite with the fp32 FMA contraction"
echo "   mode as the default: the sgemm family must hold its cross-level"
echo "   agreement inside the FMA tier too; qgemm is integer-exact and"
echo "   unaffected) =="
LSQNET_FMA=1 cargo test --release -q --test kernels

echo "== aarch64 cross-check (type-check the NEON dispatch arm; soft-skip"
echo "   when the cross target is not installed on this host) =="
if command -v rustup >/dev/null 2>&1 \
   && rustup target list --installed 2>/dev/null | grep -q '^aarch64-unknown-linux-gnu$'; then
  cargo check --release --target aarch64-unknown-linux-gnu
else
  echo "   (skipped: aarch64-unknown-linux-gnu target not installed)"
fi

echo "== clippy (warnings are errors; missing_docs stays advisory while"
echo "   the long-tail rustdoc pass is in flight — see ROADMAP) =="
cargo clippy --all-targets -- -D warnings -A missing_docs

echo "== rustdoc (docs must build; broken intra-doc links are errors) =="
RUSTDOCFLAGS="-D rustdoc::broken-intra-doc-links" cargo doc --no-deps --quiet

echo "== gemm bench smoke, dispatched + scalar-forced (EXPERIMENTS.md §Perf"
echo "   L1; fast/scalar modes write target/BENCH_native_gemm_*.json — the"
echo "   repo-root trajectory file BENCH_native_gemm.json comes from a"
echo "   plain 'cargo bench --bench gemm') =="
LSQNET_BENCH_FAST=1 cargo bench --bench gemm
LSQNET_BENCH_FAST=1 LSQNET_FORCE_SCALAR=1 cargo bench --bench gemm

echo "== serve bench smoke (EXPERIMENTS.md §Perf L3, native, 2 replicas) =="
LSQNET_BENCH_FAST=1 cargo bench --bench serve

echo "ci.sh: all green"
