//! Analysis scenario: everything the paper says about the quantizer itself,
//! on one screen —
//!
//!  * Figure 2: the LSQ / QIL / PACT gradient curves (from the AOT artifact,
//!    cross-validated against the pure-Rust quantizer);
//!  * Section 2.2 / Appendix A: the R ≈ sqrt(N·Qp) imbalance prediction vs
//!    the measured R on an actual model (Figure 4 machinery, g = 1);
//!  * Section 3.6: quantization error of a trained checkpoint under
//!    MAE/MSE/KL vs the learned step size.
//!
//! Run: `cargo run --release --example analyze_quantizer [-- --iters 40]`

use std::path::Path;

use lsqnet::analyze::{curves, qerror, rratio};
use lsqnet::config::ExperimentConfig;
use lsqnet::quant::error::Metric;
use lsqnet::runtime::Engine;
use lsqnet::train::Trainer;
use lsqnet::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let engine = Engine::new(Path::new(&args.str("artifacts", "artifacts")))?;

    // ---- Figure 2 ---------------------------------------------------------
    let c = curves::from_artifact(&engine, -1.0, 4.0)?;
    let r = curves::from_rust(-1.0, 4.0, c.v.len());
    let dev = c
        .ds_lsq
        .iter()
        .zip(&r.ds_lsq)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("Figure 2: artifact vs rust quantizer max deviation = {dev:.2e}");
    println!("  v=1.45: LSQ {:+.3}  QIL {:+.3}  PACT {:+.3}", sample(&c, 1.45).0, sample(&c, 1.45).1, sample(&c, 1.45).2);
    println!("  v=1.55: LSQ {:+.3}  QIL {:+.3}  PACT {:+.3}", sample(&c, 1.55).0, sample(&c, 1.55).1, sample(&c, 1.55).2);
    println!("  (LSQ flips sign across the 1.5 transition; QIL doesn't — the paper's key figure)");

    // ---- Section 2.2: predicted vs measured R at g = 1 ---------------------
    let mut cfg = ExperimentConfig::default();
    cfg.model = args.str("model", "cnn_small");
    cfg.bits = 2;
    cfg.data.train_size = 640;
    let iters = args.usize("iters", 40);
    let rep = rratio::measure(&engine, &cfg, "one", iters)?;
    let fam = engine.manifest().family(&cfg.family())?.clone();
    println!("\nSection 2.2 (g=1, {} iters): per-layer R vs sqrt(N*Qp) prediction", iters);
    for (l, meta) in rep.layers.iter().zip(fam.layer_meta.iter()) {
        let qp = (1i64 << (meta.bits - 1)) - 1;
        let predicted = ((meta.n_weights as f64) * qp as f64).sqrt();
        println!(
            "  {:<10} measured R = {:>10.1}   sqrt(N*Qp) = {:>8.1}   ratio {:.2}",
            l.layer,
            l.mean_r,
            predicted,
            l.mean_r / predicted
        );
    }

    // ---- Section 3.6 on a freshly trained tiny checkpoint ------------------
    let mut qcfg = ExperimentConfig::default();
    qcfg.name = "analyze_q2".into();
    qcfg.model = cfg.model.clone();
    qcfg.bits = 2;
    qcfg.out_dir = "runs_quick".into();
    qcfg.data.train_size = 1280;
    qcfg.data.test_size = 256;
    qcfg.train.epochs = 2;
    let mut tr = Trainer::new(&engine, qcfg)?;
    tr.verbose = false;
    tr.fit()?;
    let ck = tr.state.to_checkpoint(&fam);
    let qrep = qerror::analyze_weights(&fam, &ck)?;
    println!("\nSection 3.6: learned s_hat vs error-minimizing s (weight layers)");
    println!("  mean |diff|: MAE {:.0}%  MSE {:.0}%  KL {:.0}%   (paper R18: 47/28/46%)",
        qrep.avg_pct_diff(Metric::MeanAbs),
        qrep.avg_pct_diff(Metric::MeanSq),
        qrep.avg_pct_diff(Metric::Kl));
    println!("  -> LSQ is NOT a quantization-error minimizer; it optimizes task loss.");
    Ok(())
}

fn sample(c: &curves::Curves, v: f32) -> (f32, f32, f32) {
    let i = c.v.iter().position(|&x| x >= v).unwrap_or(0);
    (c.ds_lsq[i], c.ds_qil[i], c.ds_pact[i])
}
