//! End-to-end validation driver (EXPERIMENTS.md §E2E): proves all three
//! layers compose on a real workload by running the paper's full protocol —
//!
//!   fp32 pretrain → LSQ 2-bit fine-tune (step-size init from the fp32
//!   weights + first batch) → eval → comparison against (a) the fp32
//!   baseline and (b) a 2-bit run *without* the fp32 init —
//!
//! and logging the train-loss curve + eval trajectory for all runs.
//!
//! Run: `cargo run --release --example e2e_train [-- --epochs 12 --train-size 3840]`

use std::path::Path;

use lsqnet::config::ExperimentConfig;
use lsqnet::runtime::Engine;
use lsqnet::train::Trainer;
use lsqnet::util::cli::Args;

fn base_cfg(args: &Args) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.out_dir = args.str("out-dir", "runs_e2e");
    cfg.data.train_size = args.usize("train-size", 3840);
    cfg.data.test_size = args.usize("test-size", 960);
    cfg.train.epochs = args.usize("epochs", 12);
    cfg
}

fn sparkline(vals: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (lo, hi) = vals
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
    vals.iter()
        .map(|&v| {
            let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.5 };
            BARS[((t * 7.0).round() as usize).min(7)]
        })
        .collect()
}

fn run(engine: &Engine, cfg: ExperimentConfig) -> anyhow::Result<(f64, f64, Vec<f64>)> {
    println!("\n=== {} (bits={}, init_from={:?}) ===", cfg.name, cfg.bits, cfg.init_from);
    let mut tr = Trainer::new(engine, cfg)?;
    let rep = tr.fit()?;
    // per-epoch mean train loss for the curve
    let mut curve = Vec::new();
    let mut cur_epoch = 0usize;
    let mut acc = (0.0, 0usize);
    for s in &rep.history.steps {
        if s.epoch != cur_epoch {
            curve.push(acc.0 / acc.1.max(1) as f64);
            acc = (0.0, 0);
            cur_epoch = s.epoch;
        }
        acc.0 += s.loss;
        acc.1 += 1;
    }
    if acc.1 > 0 {
        curve.push(acc.0 / acc.1 as f64);
    }
    println!(
        "loss/epoch: {}  ({:.3} -> {:.3})",
        sparkline(&curve),
        curve.first().unwrap_or(&f64::NAN),
        curve.last().unwrap_or(&f64::NAN)
    );
    println!(
        "evals: {}",
        rep.history
            .evals
            .iter()
            .map(|e| format!("{:.1}", e.top1))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!("driver overhead: {:.2}%", 100.0 * tr.driver_overhead());
    Ok((rep.final_top1, rep.final_top5, curve))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let engine = Engine::new(Path::new(&args.str("artifacts", "artifacts")))?;

    // Stage 1: fp32 pretrain.
    let mut fp = base_cfg(&args);
    fp.name = "e2e_fp32".into();
    fp.bits = 32;
    fp.train.lr = 0.05;
    let fp_ckpt = format!("{}/e2e_fp32/final.ckpt", fp.out_dir);
    let (fp_top1, _, fp_curve) = run(&engine, fp)?;

    // Stage 2: LSQ 2-bit fine-tune from the fp32 model (paper protocol).
    let mut q2 = base_cfg(&args);
    q2.name = "e2e_q2_finetune".into();
    q2.bits = 2;
    q2.train.lr = 0.01;
    q2.train.weight_decay = ExperimentConfig::paper_wd(2, 1e-4);
    q2.init_from = fp_ckpt.clone();
    let (q2_top1, q2_top5, q2_curve) = run(&engine, q2)?;

    // Stage 3 (control): 2-bit from scratch — the paper notes fp32 init
    // "is known to improve performance"; verify the gap has the right sign.
    let mut scratch = base_cfg(&args);
    scratch.name = "e2e_q2_scratch".into();
    scratch.bits = 2;
    scratch.train.lr = 0.01;
    scratch.train.weight_decay = ExperimentConfig::paper_wd(2, 1e-4);
    let (sc_top1, _, _) = run(&engine, scratch)?;

    println!("\n==================== E2E SUMMARY ====================");
    println!("fp32 baseline        : top-1 {fp_top1:.2}%");
    println!("2-bit LSQ (finetune) : top-1 {q2_top1:.2}%  top-5 {q2_top5:.2}%");
    println!("2-bit LSQ (scratch)  : top-1 {sc_top1:.2}%");
    println!(
        "fp32->2bit drop      : {:.2} pts (paper R18: 2.9 on ImageNet)",
        fp_top1 - q2_top1
    );
    anyhow::ensure!(
        fp_curve.last().unwrap() < fp_curve.first().unwrap(),
        "fp32 loss did not decrease"
    );
    anyhow::ensure!(
        q2_curve.last().unwrap() < q2_curve.first().unwrap(),
        "2-bit loss did not decrease"
    );
    anyhow::ensure!(q2_top1 > 2.0 * 10.0, "2-bit model failed to clear 2x chance");
    println!("all e2e assertions passed ✔");
    Ok(())
}
