//! Quickstart: the smallest end-to-end LSQ workflow.
//!
//! 1. load the AOT artifacts (`make artifacts` must have run),
//! 2. fine-tune a 2-bit cnn_small for a couple of epochs on synthshapes,
//! 3. evaluate, inspect the learned step sizes, and pack the weights to
//!    2-bit storage.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;

use lsqnet::config::ExperimentConfig;
use lsqnet::quant::pack::quantize_and_pack;
use lsqnet::runtime::Engine;
use lsqnet::train::Trainer;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(Path::new("artifacts"))?;
    println!("PJRT platform: {}", engine.platform());

    // -- configure a small 2-bit run ---------------------------------------
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart_q2".into();
    cfg.model = "cnn_small".into();
    cfg.bits = 2;
    cfg.out_dir = "runs_quick".into();
    cfg.data.train_size = 1280;
    cfg.data.test_size = 320;
    cfg.train.epochs = 3;
    cfg.train.lr = 0.01;
    cfg.train.weight_decay = ExperimentConfig::paper_wd(2, 1e-4);

    // -- train ---------------------------------------------------------------
    let mut trainer = Trainer::new(&engine, cfg)?;
    let report = trainer.fit()?;
    println!(
        "\nfinal: top-1 {:.2}%  top-5 {:.2}%  ({} steps, {:.1}s)",
        report.final_top1,
        report.final_top5,
        trainer.state.step,
        report.history.wall_seconds
    );

    // -- inspect learned step sizes (the paper's core learnable) -------------
    let fam = engine.manifest().family("cnn_small_q2")?.clone();
    println!("\nlearned step sizes:");
    for name in fam.step_names("step_w").iter().chain(fam.step_names("step_a").iter()) {
        let v = trainer.state.param(&fam, name)?.item_f32()?;
        println!("  {name:<14} = {v:.5}");
    }

    // -- pack one layer to true 2-bit storage (Figure 1 deployment view) ----
    let w = trainer.state.param(&fam, "conv2.w")?.f32s()?.to_vec();
    let s = trainer.state.param(&fam, "conv2.sw")?.item_f32()?;
    let packed = quantize_and_pack(&w, s, 2, true)?;
    println!(
        "\nconv2.w: {} fp32 bytes -> {} packed bytes ({:.1}x)",
        w.len() * 4,
        packed.storage_bytes(),
        (w.len() * 4) as f64 / packed.storage_bytes() as f64
    );
    Ok(())
}
