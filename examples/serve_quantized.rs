//! Serving scenario (Figure 1 deployed): stand up the dynamic-batching
//! inference server over a 2-bit family on the backend of your choice,
//! drive it with traffic from several client threads, and report latency
//! percentiles, throughput and batch occupancy — then demonstrate the raw
//! int-domain matmul (fused unpack-and-dot over packed weights) that the
//! low-precision datapath of Figure 1 performs.
//!
//! Runs out of the box with no artifacts: on the native backend, a missing
//! `manifest.json` is replaced by a synthetic fixture family. Point
//! `--artifacts` at a real AOT set (and optionally `--backend xla`,
//! requires `--features xla`) to serve trained models.
//!
//! Run: `cargo run --release --example serve_quantized -- \
//!       [--backend native|xla] [--replicas 2] [--requests 512]`

use std::path::PathBuf;
use std::time::Duration;

use lsqnet::data::SynthSpec;
use lsqnet::quant::pack::quantize_and_pack;
use lsqnet::runtime::kernels::{qgemm, Workspace};
use lsqnet::runtime::native::fixture::ensure_family_by_name;
use lsqnet::runtime::{BackendKind, BackendSpec};
use lsqnet::serve::{Server, ServerConfig};
use lsqnet::util::cli::Args;
use lsqnet::util::stats::percentile;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    let n = args.usize("requests", 512);
    let threads = args.usize("threads", 4);
    let kind = BackendKind::parse(&args.str("backend", "native"))?;
    let replicas = args.usize("replicas", if kind == BackendKind::Native { 2 } else { 1 });
    let mut family = args.str("family", "cnn_small_q2");

    // Zero-setup path: fabricate the requested family when no artifacts
    // exist (family names look like `model_qBITS`, e.g. `resnet8_q4`).
    let mut fixture_dir = None;
    if kind == BackendKind::Native && !artifacts.join("manifest.json").exists() {
        let dir = std::env::temp_dir().join(format!("lsq_example_{}", std::process::id()));
        family = ensure_family_by_name(&dir, &family)?;
        artifacts = dir.clone();
        fixture_dir = Some(dir);
    }

    // -- dynamic-batching server over the quantized model --------------------
    let server = Server::start(ServerConfig {
        backend: BackendSpec { kind, artifacts_dir: artifacts.clone() },
        family: family.clone(),
        checkpoint: args.str("checkpoint", ""),
        max_wait: Duration::from_millis(args.u64("max-wait-ms", 2)),
        queue_depth: 512,
        replicas,
        intra_threads: args.usize("intra-threads", 0),
        fused_unpack: args.flag("fused-unpack"),
    })?;

    let spec = SynthSpec::new(10, 0.35, 7);
    let t0 = std::time::Instant::now();
    let mut lats = Vec::new();
    let mut agree = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let client = server.client().expect("server intake open");
                let spec = &spec;
                s.spawn(move || {
                    let mut l = Vec::new();
                    let mut hits = 0usize;
                    for i in 0..n / threads {
                        let idx = t * 100_000 + i;
                        let img = spec.generate_alloc(idx);
                        let rep = client.infer(img).expect("infer");
                        if rep.argmax == spec.label(idx) as usize {
                            hits += 1;
                        }
                        l.push(rep.total_ms);
                    }
                    (l, hits)
                })
            })
            .collect();
        for h in handles {
            let (l, hits) = h.join().unwrap();
            lats.extend(l);
            agree += hits;
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    server.stop();

    println!("== serve_quantized ({} backend, {replicas} replica(s)) ==", kind.name());
    println!("requests      : {}", lats.len());
    println!("throughput    : {:.1} req/s", lats.len() as f64 / wall);
    println!("latency p50   : {:.2} ms", percentile(&lats, 50.0));
    println!("latency p95   : {:.2} ms", percentile(&lats, 95.0));
    println!("latency p99   : {:.2} ms", percentile(&lats, 99.0));
    println!("batches       : {} (mean occupancy {:.2})", stats.batches, stats.mean_occupancy());
    println!("mean exec     : {:.2} ms/batch", stats.mean_exec_ms());
    println!(
        "label agreement (untrained net, chance ~10%): {:.1}%",
        100.0 * agree as f64 / lats.len().max(1) as f64
    );

    // -- two-precision registry: the multi-model deployment shape ------------
    // One process, two precision tiers of the same architecture, each with
    // its own named session, replica set and stats — LSQ's
    // accuracy/size/latency trade-off (Figure 3) served side by side,
    // with a live hot-unload. Native only; skipped (instead of mutating a
    // user-supplied manifest) when the second tier doesn't exist and the
    // artifacts aren't the synthetic fixture.
    if kind == BackendKind::Native {
        two_tier_registry_demo(&artifacts, &family, replicas, fixture_dir.is_some(), &spec)?;
    }

    // -- raw Figure-1 int matmul over packed weights -------------------------
    // The same kernel the native conv/dense layers call: activations on the
    // Eq. 1 integer grid, weights unpacked tile-by-tile from 2-bit storage,
    // i32 accumulation, one fp32 rescale (Eq. 2).
    let (m, k, nn) = (128usize, 512usize, 256usize);
    let mut rng = lsqnet::util::rng::Pcg32::seeded(5);
    let w: Vec<f32> = (0..k * nn).map(|_| rng.normal() * 0.4).collect();
    let (sw, sa) = (0.02f32, 0.05f32);
    let packed = quantize_and_pack(&w, sw, 2, true)?;
    let xbar: Vec<i32> = (0..m * k).map(|_| rng.below(4) as i32).collect();
    let mut out = vec![0.0f32; m * nn];
    let mut ws = Workspace::new();
    let t1 = std::time::Instant::now();
    let iters = 50;
    for _ in 0..iters {
        qgemm(&mut ws, m, k, nn, &xbar, &packed, sa * sw, None, &mut out);
    }
    let ms = t1.elapsed().as_secs_f64() * 1e3 / iters as f64;
    // cross-check one entry against integer math on the host
    let wbar = lsqnet::quant::pack::unpack(&packed);
    let host: i64 = (0..k).map(|i| xbar[i] as i64 * wbar[i * nn] as i64).sum();
    let got = out[0];
    anyhow::ensure!(
        (got - host as f32 * sa * sw).abs() < 1e-3,
        "qgemm mismatch: {got} vs {}",
        host as f32 * sa * sw
    );
    println!("\n== Figure-1 int matmul ({m}x{k} @ {k}x{nn}, 2-bit packed, i32 accumulate) ==");
    println!("exec          : {ms:.3} ms  ({:.2} GMAC/s)", (m * k * nn) as f64 / ms / 1e6);
    println!("host cross-check passed ✔");

    if let Some(dir) = fixture_dir {
        std::fs::remove_dir_all(dir).ok();
    }
    Ok(())
}

/// The multi-model deployment shape: load `family` plus a second
/// precision tier of the same model into one [`ModelRegistry`],
/// round-robin traffic across both named sessions, then hot-unload the
/// first tier under the registry while the second keeps serving.
fn two_tier_registry_demo(
    artifacts: &std::path::Path,
    family: &str,
    replicas: usize,
    is_fixture: bool,
    spec: &SynthSpec,
) -> anyhow::Result<()> {
    use lsqnet::serve::{ModelRegistry, ServeError, VariantOptions};
    let (model, bits) = family
        .rsplit_once("_q")
        .and_then(|(m, b)| b.parse::<u32>().ok().map(|b| (m.to_string(), b)))
        .unwrap_or(("cnn_small".to_string(), 2));
    let other_bits = if bits >= 4 { 2 } else { 4 };
    let other = format!("{model}_q{other_bits}");
    let manifest = lsqnet::runtime::Manifest::load(artifacts)?;
    if !is_fixture && !manifest.families.contains_key(&other) {
        // A user-supplied artifact set without the second tier: don't
        // mutate their manifest for a demo.
        println!(
            "\n(skipping the two-precision registry demo: {} has no {other})",
            artifacts.display()
        );
        return Ok(());
    }
    drop(manifest);
    // The second tier merges into the manifest with its geometry reused
    // (a no-op when it already exists).
    let other = ensure_family_by_name(artifacts, &other)?;

    let registry = ModelRegistry::open(BackendSpec::native(artifacts));
    let opts = VariantOptions { replicas, ..VariantOptions::default() };
    registry.load(family, &opts)?;
    registry.load(&other, &opts)?;
    println!("\n== two-precision registry ({family} + {other}) ==");
    let s_lo = registry.session(family)?;
    let s_hi = registry.session(&other)?;
    for i in 0..64usize {
        // Round-robin the same traffic across both tiers by name.
        let sess = if i % 2 == 0 { &s_lo } else { &s_hi };
        sess.infer(spec.generate_alloc(500_000 + i))?;
    }
    for (name, st) in registry.all_stats() {
        println!(
            "  {name:<22} {:>3} reqs  exec {:.2} ms/batch  queue {:.2} ms/req",
            st.requests,
            st.mean_exec_ms(),
            st.mean_queue_ms()
        );
    }
    // Hot-swap: retire the low tier without touching the other variant,
    // then keep serving the survivor.
    let drained = registry.drain_and_unload(family)?;
    println!("  drained {family}: {} requests answered in total", drained.requests);
    assert!(matches!(registry.session(family), Err(ServeError::UnknownModel(_))));
    s_hi.infer(spec.generate_alloc(999_999))?;
    registry.shutdown();
    println!("  {other} kept serving through the unload ✔");
    Ok(())
}
