//! Serving scenario (Figure 1 deployed): stand up the dynamic-batching
//! inference server over a 2-bit artifact, drive it with open-loop traffic
//! from several client threads, and report latency percentiles, throughput
//! and batch occupancy — then demonstrate the raw int-domain matmul (the
//! `qmm` artifact) that the low-precision datapath of Figure 1 performs.
//!
//! Run: `cargo run --release --example serve_quantized [-- --requests 512]`

use std::path::Path;
use std::time::Duration;

use lsqnet::data::SynthSpec;
use lsqnet::runtime::Engine;
use lsqnet::serve::{Server, ServerConfig};
use lsqnet::tensor::Tensor;
use lsqnet::util::cli::Args;
use lsqnet::util::stats::percentile;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let n = args.usize("requests", 512);
    let threads = args.usize("threads", 4);

    // -- dynamic-batching server over the quantized model --------------------
    let server = Server::start(ServerConfig {
        artifacts_dir: artifacts.clone().into(),
        family: args.str("family", "cnn_small_q2"),
        checkpoint: args.str("checkpoint", ""),
        max_wait: Duration::from_millis(args.u64("max-wait-ms", 2)),
        queue_depth: 512,
    })?;

    let spec = SynthSpec::new(10, 0.35, 7);
    let t0 = std::time::Instant::now();
    let mut lats = Vec::new();
    let mut agree = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let client = server.client.clone();
                let spec = &spec;
                s.spawn(move || {
                    let mut l = Vec::new();
                    let mut hits = 0usize;
                    for i in 0..n / threads {
                        let idx = t * 100_000 + i;
                        let img = spec.generate_alloc(idx);
                        let rep = client.infer(img).expect("infer");
                        if rep.argmax == spec.label(idx) as usize {
                            hits += 1;
                        }
                        l.push(rep.total_ms);
                    }
                    (l, hits)
                })
            })
            .collect();
        for h in handles {
            let (l, hits) = h.join().unwrap();
            lats.extend(l);
            agree += hits;
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    server.stop();

    println!("== serve_quantized ==");
    println!("requests      : {}", lats.len());
    println!("throughput    : {:.1} req/s", lats.len() as f64 / wall);
    println!("latency p50   : {:.2} ms", percentile(&lats, 50.0));
    println!("latency p95   : {:.2} ms", percentile(&lats, 95.0));
    println!("latency p99   : {:.2} ms", percentile(&lats, 99.0));
    println!("batches       : {} (mean occupancy {:.2})", stats.batches, stats.mean_occupancy());
    println!("mean exec     : {:.2} ms/batch", stats.mean_exec_ms());
    println!("label agreement (untrained net, chance ~10%): {:.1}%",
             100.0 * agree as f64 / lats.len() as f64);

    // -- raw Figure-1 int matmul ---------------------------------------------
    let engine = Engine::new(Path::new(&artifacts))?;
    let qmm_id = engine
        .manifest()
        .artifacts
        .values()
        .find(|a| a.kind == "qmm")
        .map(|a| a.id.clone())
        .ok_or_else(|| anyhow::anyhow!("no qmm artifact"))?;
    let exe = engine.load(&qmm_id)?;
    let (m, k) = (exe.meta.inputs[0].shape[0], exe.meta.inputs[0].shape[1]);
    let nn = exe.meta.inputs[1].shape[1];
    let mut rng = lsqnet::util::rng::Pcg32::seeded(5);
    let xbar: Vec<i32> = (0..m * k).map(|_| rng.below(15) as i32 - 7).collect();
    let wbar: Vec<i32> = (0..k * nn).map(|_| rng.below(15) as i32 - 7).collect();
    let t1 = std::time::Instant::now();
    let iters = 50;
    let mut out = Vec::new();
    for _ in 0..iters {
        out = exe.run(&[
            Tensor::from_i32(&[m, k], xbar.clone()),
            Tensor::from_i32(&[k, nn], wbar.clone()),
            Tensor::scalar_f32(0.05),
            Tensor::scalar_f32(0.02),
        ])?;
    }
    let ms = t1.elapsed().as_secs_f64() * 1e3 / iters as f64;
    // cross-check one entry against integer math on the host
    let host: i64 = (0..k).map(|i| xbar[i] as i64 * wbar[i * nn] as i64).sum();
    let got = out[0].f32s()?[0];
    anyhow::ensure!(
        (got - host as f32 * 0.05 * 0.02).abs() < 1e-3,
        "qmm mismatch: {got} vs {}",
        host as f32 * 0.001
    );
    println!("\n== Figure-1 int matmul ({m}x{k} @ {k}x{nn}, int32 accumulate) ==");
    println!("exec          : {ms:.3} ms  ({:.2} GMAC/s)", (m * k * nn) as f64 / ms / 1e6);
    println!("host cross-check passed ✔");
    Ok(())
}
