"""AOT exporter: lower every Layer-2 step function once to HLO *text* and
emit ``artifacts/manifest.json`` describing the full calling convention.

HLO text — NOT ``lowered.compiler_ir('hlo')``/``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published ``xla``
crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/gen_hlo.py.

Artifact kinds (see ``compile.train`` for signatures):

  train       QAT train step (SGD+momentum+wd, runtime lr/wd scalars)
  train_kd    train step with same-architecture knowledge distillation
  train_diag  train step that also emits per-layer ||grad_w||,||w||,|grad_s|,s
  eval        loss / ncorrect / logits
  init_quant  step-size initialization from current weights + first batch
  infer       logits only (serving path)
  fig2        quantizer transfer curves & ds terms for Figure 2
  qmm         int-domain matmul demo (Figure 1 dataflow)

Run: ``python -m compile.aot --out ../artifacts [--set quick|default|full]``
Python never runs after this: the Rust coordinator drives the artifacts.
"""

from __future__ import annotations

import argparse
import collections
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import train as T
from .kernels import qmatmul as qmm_kernels
from .quantizers import QuantConfig, ds_term

DEFAULT_BATCH = 64
INFER_BATCH = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def hlo_op_histogram(text: str) -> dict[str, int]:
    """Crude per-opcode count over HLO text (L2 perf accounting)."""
    hist: collections.Counter[str] = collections.Counter()
    for line in text.splitlines():
        line = line.strip()
        if "=" not in line or line.startswith(("HloModule", "ENTRY", "}")):
            continue
        rhs = line.split("=", 1)[1].strip()
        # "f32[8,32]{...} opcode(..." -> opcode
        parts = rhs.split(" ", 1)
        if len(parts) == 2:
            op = parts[1].split("(", 1)[0].strip()
            if op:
                hist[op] += 1
    return dict(hist)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_entry(name, arr_or_sds, kind, param=None):
    e = {
        "name": name,
        "shape": list(arr_or_sds.shape),
        "dtype": str(np.dtype(arr_or_sds.dtype)),
        "kind": kind,
    }
    if param is not None:
        e["param"] = param
    return e


class Exporter:
    def __init__(self, out_dir: pathlib.Path, batch: int, stats: bool):
        self.out = out_dir
        self.batch = batch
        self.stats = stats
        self.families: dict[str, dict] = {}
        self.inits: dict[str, T.InitResult] = {}
        self.specs: dict[str, T.ModelSpec] = {}
        self.artifacts: list[dict] = []

    # -- families ------------------------------------------------------------
    def family(self, model: str, qbits: int) -> str:
        fam = f"{model}_q{qbits}"
        if fam in self.families:
            return fam
        spec = T.ModelSpec(model=model, qbits=qbits)
        init = T.init_model(spec, seed=0)
        bin_name = f"{fam}.params.bin"
        with open(self.out / bin_name, "wb") as f:
            for p in init.params:
                f.write(np.asarray(p, dtype=np.float32).tobytes())
        self.families[fam] = {
            "model": model,
            "qbits": qbits,
            "num_classes": spec.num_classes,
            "params_bin": bin_name,
            "n_matmul": init.n_matmul,
            "param_names": init.names,
            "roles": init.roles,
            "shapes": {
                n: list(p.shape) for n, p in zip(init.names, init.params)
            },
            "grad_names": init.grad_names,
            "layer_meta": init.layer_meta,
        }
        self.inits[fam] = init
        self.specs[fam] = spec
        return fam

    # -- lowering ------------------------------------------------------------
    def _emit(self, art_id: str, fn, arg_specs, inputs, outputs, meta):
        t0 = time.time()
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{art_id}.hlo.txt"
        (self.out / fname).write_text(text)
        entry = {
            "id": art_id,
            "file": fname,
            "inputs": inputs,
            "outputs": outputs,
            **meta,
        }
        if self.stats:
            hist = hlo_op_histogram(text)
            entry["hlo_ops"] = sum(hist.values())
            top = sorted(hist.items(), key=lambda kv: -kv[1])[:8]
            print(f"    ops={entry['hlo_ops']} top={top}")
        self.artifacts.append(entry)
        print(
            f"  [{time.time() - t0:6.1f}s] {art_id} "
            f"({len(text) / 1e6:.2f} MB hlo)"
        )

    def _param_io(self, fam, kind):
        init = self.inits[fam]
        return [
            _io_entry(n, init.params[i], kind, param=n)
            for i, n in enumerate(init.names)
        ]

    def _mom_io(self, fam):
        init = self.inits[fam]
        by_name = dict(zip(init.names, init.params))
        return [
            _io_entry(f"mom::{n}", by_name[n], "mom", param=n)
            for n in init.grad_names
        ]

    def _data_io(self, batch):
        spec = T.ModelSpec()
        x = _sds((batch, spec.image, spec.image, spec.channels))
        y = _sds((batch,), jnp.int32)
        return (
            [_io_entry("x", x, "data_x"), _io_entry("y", y, "data_y")],
            [x, y],
        )

    # -- artifact kinds -------------------------------------------------------
    def train(self, model, qbits, method="lsq", gscale="full", distill=False,
              diag=False):
        fam = self.family(model, qbits)
        spec = T.ModelSpec(model=model, qbits=qbits, method=method,
                           gscale_mode=gscale)
        init = self.inits[fam]
        kind = "train_kd" if distill else ("train_diag" if diag else "train")
        tfam = tspec = tinit = None
        if distill:
            tfam = self.family(model, 32)
            tspec, tinit = self.specs[tfam], self.inits[tfam]
        fn = T.build_train_step(spec, init, distill=distill,
                                teacher_init=tinit, teacher_spec=tspec,
                                diag=diag)
        by_name = dict(zip(init.names, init.params))
        arg_specs = [_sds(p.shape) for p in init.params]
        arg_specs += [_sds(by_name[n].shape) for n in init.grad_names]
        inputs = self._param_io(fam, "param") + self._mom_io(fam)
        if distill:
            arg_specs += [_sds(p.shape) for p in tinit.params]
            inputs += [
                _io_entry(f"teacher::{n}", p, "teacher", param=n)
                for n, p in zip(tinit.names, tinit.params)
            ]
        dio, dspecs = self._data_io(self.batch)
        arg_specs += dspecs
        inputs += dio
        arg_specs += [_sds(()), _sds(())]
        inputs += [_io_entry("lr", _sds(()), "lr"),
                   _io_entry("wd", _sds(()), "wd")]

        outputs = self._param_io(fam, "param") + self._mom_io(fam)
        outputs += [_io_entry("loss", _sds(()), "metric"),
                    _io_entry("ncorrect", _sds(()), "metric")]
        if diag:
            nq = len([n for n in init.names if init.roles[n] == "step_w"])
            for nm in ("gw_norm", "w_norm", "gs_abs", "s_val"):
                outputs.append(_io_entry(nm, _sds((nq,)), "diag"))

        suffix = ""
        if method != "lsq":
            suffix += f"_{method}"
        if gscale != "full":
            suffix += f"_{gscale}"
        art_id = f"{kind}_{fam}_b{self.batch}{suffix}"
        meta = {"kind": kind, "family": fam, "method": method,
                "gscale": gscale, "batch": self.batch}
        if distill:
            meta["teacher_family"] = tfam
        self._emit(art_id, fn, arg_specs, inputs, outputs, meta)

    def eval(self, model, qbits, method="lsq"):
        fam = self.family(model, qbits)
        spec = T.ModelSpec(model=model, qbits=qbits, method=method)
        init = self.inits[fam]
        fn = T.build_eval_step(spec, init)
        dio, dspecs = self._data_io(self.batch)
        arg_specs = [_sds(p.shape) for p in init.params] + dspecs
        inputs = self._param_io(fam, "param") + dio
        nc = self.families[fam]["num_classes"]
        outputs = [
            _io_entry("loss", _sds(()), "metric"),
            _io_entry("ncorrect", _sds(()), "metric"),
            _io_entry("logits", _sds((self.batch, nc)), "logits"),
        ]
        art_id = f"eval_{fam}_b{self.batch}"
        self._emit(art_id, fn, arg_specs, inputs, outputs,
                   {"kind": "eval", "family": fam, "method": method,
                    "batch": self.batch})

    def init_quant(self, model, qbits):
        fam = self.family(model, qbits)
        init = self.inits[fam]
        spec = self.specs[fam]
        fn = T.build_init_quant(spec, init)
        x = _sds((self.batch, spec.image, spec.image, spec.channels))
        arg_specs = [_sds(p.shape) for p in init.params] + [x]
        inputs = self._param_io(fam, "param") + [_io_entry("x", x, "data_x")]
        outputs = self._param_io(fam, "param")
        art_id = f"initq_{fam}_b{self.batch}"
        self._emit(art_id, fn, arg_specs, inputs, outputs,
                   {"kind": "init_quant", "family": fam, "batch": self.batch})

    def infer(self, model, qbits, batch=INFER_BATCH):
        fam = self.family(model, qbits)
        init = self.inits[fam]
        spec = self.specs[fam]
        fn = T.build_infer_step(spec, init)
        x = _sds((batch, spec.image, spec.image, spec.channels))
        arg_specs = [_sds(p.shape) for p in init.params] + [x]
        inputs = self._param_io(fam, "param") + [_io_entry("x", x, "data_x")]
        nc = self.families[fam]["num_classes"]
        outputs = [_io_entry("logits", _sds((batch, nc)), "logits")]
        art_id = f"infer_{fam}_b{batch}"
        self._emit(art_id, fn, arg_specs, inputs, outputs,
                   {"kind": "infer", "family": fam, "batch": batch})

    def fig2(self, n=512):
        """v sweep through each quantizer's forward + ds term (s=1, Qn=0,
        Qp=3 as in the paper's Figure 2)."""
        from .kernels import ref

        def fn(v, s):
            def cfg(m):
                return QuantConfig(bits=2, signed=False, method=m)

            vhat = ref.quantize(v, s, 0, 3)
            return (
                vhat,
                ds_term(v, s, cfg("lsq")),
                ds_term(v, s, cfg("qil")),
                ds_term(v, s, cfg("pact")),
            )

        v = _sds((n,))
        s = _sds(())
        inputs = [_io_entry("v", v, "data_x"), _io_entry("s", s, "scalar")]
        outputs = [
            _io_entry(nm, v, "series")
            for nm in ("vhat", "ds_lsq", "ds_qil", "ds_pact")
        ]
        self._emit("fig2_curves", fn, [v, s], inputs, outputs,
                   {"kind": "fig2", "family": None, "batch": n})

    def qmm(self, m=32, k=512, n=256):
        def fn(xbar, wbar, sx, sw):
            return (qmm_kernels.qmatmul(xbar, wbar, sx, sw),)

        xs = _sds((m, k), jnp.int32)
        ws = _sds((k, n), jnp.int32)
        sc = _sds(())
        inputs = [
            _io_entry("xbar", xs, "data_x"),
            _io_entry("wbar", ws, "data_w"),
            _io_entry("sx", sc, "scalar"),
            _io_entry("sw", sc, "scalar"),
        ]
        outputs = [_io_entry("out", _sds((m, n)), "logits")]
        self._emit(f"qmm_{m}x{k}x{n}", fn, [xs, ws, sc, sc], inputs, outputs,
                   {"kind": "qmm", "family": None, "batch": m})

    # -- manifest -------------------------------------------------------------
    def write_manifest(self):
        spec = T.ModelSpec()
        manifest = {
            "version": 1,
            "batch": self.batch,
            "image": spec.image,
            "channels": spec.channels,
            "num_classes": spec.num_classes,
            "families": self.families,
            "artifacts": self.artifacts,
        }
        (self.out / "manifest.json").write_text(json.dumps(manifest, indent=1))
        print(f"manifest: {len(self.artifacts)} artifacts, "
              f"{len(self.families)} families")


PRECISIONS = (2, 3, 4, 8)


def build_set(ex: Exporter, which: str):
    ex.fig2()
    ex.qmm()
    # Core sweep model at every precision (Tables 1, 2; Sec. 3.5).
    for q in (32,) + PRECISIONS:
        ex.train("cnn_small", q)
        ex.eval("cnn_small", q)
        if q != 32:
            ex.init_quant("cnn_small", q)
    ex.infer("cnn_small", 2)
    ex.infer("cnn_small", 8)
    ex.infer("cnn_small", 32)
    if which == "quick":
        return
    # Competing quantizer gradients at 2-bit (Table 1 baselines, Fig. 2).
    for method in ("qil", "pact", "fixed"):
        ex.train("cnn_small", 2, method=method)
    # Gradient-scale ablation (Table 3).
    for gs in ("sqrtn", "one", "x10", "d10"):
        ex.train("cnn_small", 2, gscale=gs)
    # Knowledge distillation (Table 4).
    for q in PRECISIONS:
        ex.train("cnn_small", q, distill=True)
    # R-ratio diagnostics (Fig. 4): gscale x precision.
    for q in PRECISIONS:
        for gs in ("one", "sqrtn", "full"):
            ex.train("cnn_small", q, gscale=gs, diag=True)
    # ResNet ladder (Tables 1, 4; Fig. 3).
    for q in (32,) + PRECISIONS:
        ex.train("resnet20", q)
        ex.eval("resnet20", q)
        if q != 32:
            ex.init_quant("resnet20", q)
    for q in (2, 3):
        ex.train("resnet20", q, distill=True)
    # Architecture families (Table 1 rows, Fig. 3 frontier).
    archs = ("resnet8", "vgg_small", "sqnxt_small")
    precs = (32, 2, 4) if which == "default" else (32,) + PRECISIONS
    for model in archs:
        for q in precs:
            ex.train(model, q)
            ex.eval(model, q)
            if q != 32:
                ex.init_quant(model, q)
    ex.infer("resnet8", 2)
    if which == "full":
        for model in ("resnet14", "resnet32"):
            for q in (32, 2, 4):
                ex.train(model, q)
                ex.eval(model, q)
                if q != 32:
                    ex.init_quant(model, q)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="default",
                    choices=("quick", "default", "full"))
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--stats", action="store_true",
                    help="print HLO op histograms (L2 perf accounting)")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    ex = Exporter(out, args.batch, args.stats)
    build_set(ex, args.set)
    ex.write_manifest()
    print(f"total {time.time() - t0:.1f}s -> {out}")


if __name__ == "__main__":
    main()
