"""Layer-1 Pallas kernels: the LSQ quantizer and the int-domain matmul.

``ref`` is the pure-jnp oracle; ``lsq``/``qmatmul`` are the Pallas
implementations the Layer-2 model actually lowers.
"""

from . import lsq, qmatmul, ref  # noqa: F401
