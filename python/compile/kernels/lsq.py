"""Layer-1 Pallas kernels for the LSQ quantizer (paper Eqs. 1-3, 5).

Three kernels, all tiled for TPU VMEM and validated under ``interpret=True``
(the CPU PJRT plugin cannot run Mosaic custom-calls, see DESIGN.md
§Hardware-Adaptation):

  * ``_fwd_kernel``      — vhat = round(clip(v/s, -Qn, Qp)) * s
  * ``_bwd_kernel``      — fused backward: STE data gradient (Eq. 5) AND the
                           per-block partial reduction of the step-size
                           gradient (Eq. 3). One pass over the data instead
                           of the two a naive autograd would emit.
  * ``_step_init_kernel`` — per-block partial sums of |v| for the
                           2<|v|>/sqrt(Qp) step initialization.

The public entry point is :func:`lsq_quantize`, a ``jax.custom_vjp`` function
whose forward and backward are both Pallas calls, so the Layer-2 model lowers
the whole quantizer (including its gradient) into a single HLO module.

Tiling: inputs are flattened, padded to a lane multiple (128) and processed
on a 1-D grid of (1, block) tiles. The block size is chosen per tensor by
``_plan``: the whole tensor in one block while it fits the VMEM budget
(``MAX_BLOCK`` = 2M f32 = 8 MB, i.e. in+out tiles fill a 16 MB VMEM), and a
grid of ``MAX_BLOCK`` tiles beyond that. Every tensor in the models shipped
here fits a single block; the multi-block path is exercised by unit tests
(and would be the real-TPU configuration for larger layers). This matters
doubly under ``interpret=True``: each grid step costs a dynamic-slice +
loop iteration that XLA:CPU cannot fuse, so single-block tiling is also
what makes the AOT artifacts run at pure-XLA speed (see EXPERIMENTS.md
§Perf L1).

The Eq.-3 terms are reduced block-locally into a (1, 1) accumulator tile
(the TPU analogue of a CUDA warp-reduce) and summed across blocks outside
the kernel.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width: the native f32 VREG minor dimension on TPU.
LANE = 128

# VMEM budget cap per block (f32 elements): 2M elems = 8 MB.
MAX_BLOCK = 1 << 21

# interpret=True everywhere: see module docstring.
_INTERPRET = True


def _plan(n: int) -> tuple[int, int]:
    """Choose (block, nblk) for an n-element tensor (see module docstring)."""
    padded = max(LANE, -(-n // LANE) * LANE)
    if padded <= MAX_BLOCK:
        return padded, 1
    return MAX_BLOCK, -(-padded // MAX_BLOCK)


def _pad_blocks(flat, block: int, nblk: int):
    """Pad a 1-D array to nblk*block and reshape to (nblk, block)."""
    n = flat.shape[0]
    pad = nblk * block - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(nblk, block)


def _fwd_kernel(v_ref, s_ref, o_ref, *, qn: int, qp: int):
    s = s_ref[0, 0]
    r = jnp.clip(v_ref[...] / s, -float(qn), float(qp))
    o_ref[...] = jnp.round(r) * s


def _bwd_kernel(v_ref, s_ref, g_ref, dv_ref, ds_ref, *, qn: int, qp: int):
    s = s_ref[0, 0]
    r = v_ref[...] / s
    g = g_ref[...]
    inside = (r > -float(qn)) & (r < float(qp))
    # Eq. 5: straight-through estimator for d(vhat)/d(v).
    dv_ref[...] = jnp.where(inside, g, 0.0)
    # Eq. 3: d(vhat)/d(s), block-locally reduced.
    term = jnp.where(
        r <= -float(qn),
        -float(qn),
        jnp.where(r >= float(qp), float(qp), jnp.round(r) - r),
    )
    ds_ref[0, 0] = jnp.sum(g * term)


def _step_init_kernel(v_ref, acc_ref):
    acc_ref[0, 0] = jnp.sum(jnp.abs(v_ref[...]))


def _scalar_spec():
    # The step size is a scalar broadcast to every grid step.
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def _tile_spec(block: int):
    return pl.BlockSpec((1, block), lambda i: (i, 0))


def _acc_spec():
    return pl.BlockSpec((1, 1), lambda i: (i, 0))


def _fwd_pallas(v2, s11, qn: int, qp: int, block: int, nblk: int):
    return pl.pallas_call(
        functools.partial(_fwd_kernel, qn=qn, qp=qp),
        grid=(nblk,),
        in_specs=[_tile_spec(block), _scalar_spec()],
        out_specs=_tile_spec(block),
        out_shape=jax.ShapeDtypeStruct(v2.shape, v2.dtype),
        interpret=_INTERPRET,
    )(v2, s11)


def _bwd_pallas(v2, s11, g2, qn: int, qp: int, block: int, nblk: int):
    return pl.pallas_call(
        functools.partial(_bwd_kernel, qn=qn, qp=qp),
        grid=(nblk,),
        in_specs=[_tile_spec(block), _scalar_spec(), _tile_spec(block)],
        out_specs=[_tile_spec(block), _acc_spec()],
        out_shape=[
            jax.ShapeDtypeStruct(v2.shape, v2.dtype),
            jax.ShapeDtypeStruct((nblk, 1), v2.dtype),
        ],
        interpret=_INTERPRET,
    )(v2, s11, g2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def lsq_quantize(v, s, qn: int, qp: int, gscale: float):
    """LSQ fake-quantization of ``v`` with learnable step size ``s``.

    Forward: Eqs. 1-2. Backward: Eq. 5 to ``v`` and Eq. 3 (scaled by
    ``gscale``, Section 2.2) to ``s``. ``qn``/``qp``/``gscale`` are static.
    """
    out, _ = _lsq_fwd(v, s, qn, qp, gscale)
    return out


def _lsq_fwd(v, s, qn: int, qp: int, gscale: float):
    shape = v.shape
    flat = v.reshape(-1)
    block, nblk = _plan(flat.shape[0])
    v2 = _pad_blocks(flat, block, nblk)
    s11 = s.reshape(1, 1).astype(v.dtype)
    o2 = _fwd_pallas(v2, s11, qn, qp, block, nblk)
    out = o2.reshape(-1)[: flat.shape[0]].reshape(shape)
    return out, (v, s)


def _lsq_bwd(qn: int, qp: int, gscale: float, res, cot):
    v, s = res
    shape = v.shape
    flat_v = v.reshape(-1)
    n = flat_v.shape[0]
    block, nblk = _plan(n)
    v2 = _pad_blocks(flat_v, block, nblk)
    # Padded cotangent lanes are zero, so they contribute nothing to either
    # gradient — padding the value lanes with zeros is safe.
    g2 = _pad_blocks(cot.reshape(-1), block, nblk)
    s11 = s.reshape(1, 1).astype(v.dtype)
    dv2, ds_part = _bwd_pallas(v2, s11, g2, qn, qp, block, nblk)
    dv = dv2.reshape(-1)[:n].reshape(shape)
    ds = jnp.sum(ds_part) * jnp.asarray(gscale, v.dtype)
    return dv, ds.reshape(s.shape)


lsq_quantize.defvjp(_lsq_fwd, _lsq_bwd)


def step_init(v, qp: int):
    """Pallas-reduced step-size init 2<|v|>/sqrt(Qp) (Section 2.1)."""
    flat = v.reshape(-1)
    n = flat.shape[0]
    block, nblk = _plan(n)
    v2 = _pad_blocks(flat, block, nblk)
    part = pl.pallas_call(
        _step_init_kernel,
        grid=(nblk,),
        in_specs=[_tile_spec(block)],
        out_specs=_acc_spec(),
        out_shape=jax.ShapeDtypeStruct((nblk, 1), v.dtype),
        interpret=_INTERPRET,
    )(v2)
    mean_abs = jnp.sum(part) / float(n)
    return 2.0 * mean_abs / math.sqrt(float(qp))
