"""Layer-1 Pallas kernel for the Figure-1 integer-domain matmul.

At inference the paper computes convolution / fully-connected layers as an
integer matrix multiply over the *integer-scaled* representations
(wbar, xbar) followed by one cheap scalar rescale by sw*sx (Eq. 2, Figure 1).
This kernel implements exactly that dataflow:

  * operands arrive as int32 tensors holding values in the low-precision
    range (|x| <= Qp, so 2-8 bit payloads),
  * the contraction accumulates in int32 — what an MXU-adjacent integer MAC
    array produces — tiled over (BM, BN) output blocks with the full K
    dimension resident per block,
  * the step-size product is applied once to the accumulator tile.

Validated against ``ref.qmatmul`` under ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INTERPRET = True

# MXU-friendly output tiling; K stays resident (layer K here is <= a few
# thousand, well inside VMEM at int32).
BM = 128
BN = 128


def _qmm_kernel(x_ref, w_ref, scale_ref, o_ref):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.int32)
    o_ref[...] = acc.astype(jnp.float32) * scale_ref[0, 0]


def _pad_to(a, m, axis):
    pad = (-a.shape[axis]) % m
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def qmatmul(xbar, wbar, sx, sw):
    """out[m,n] = (sum_k xbar[m,k] * wbar[k,n]) * sx * sw.

    ``xbar``: int32[M, K] integer-valued activations, ``wbar``: int32[K, N]
    integer-valued weights, ``sx``/``sw``: f32 scalars (step sizes).
    """
    m, k = xbar.shape
    k2, n = wbar.shape
    assert k == k2, (xbar.shape, wbar.shape)
    xp = _pad_to(xbar.astype(jnp.int32), BM, 0)
    wp = _pad_to(wbar.astype(jnp.int32), BN, 1)
    gm, gn = xp.shape[0] // BM, wp.shape[1] // BN
    scale = (sx * sw).astype(jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _qmm_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=_INTERPRET,
    )(xp, wp, scale)
    return out[:m, :n]
