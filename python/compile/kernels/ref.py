"""Pure-jnp oracle for the LSQ quantizer and the int-domain matmul.

This module is the CORRECTNESS GROUND TRUTH for every Pallas kernel in this
package (see ``lsq.py`` / ``qmatmul.py``). It implements, with no cleverness:

  * Eq. 1/2 of the paper:  vbar = round(clip(v/s, -Qn, Qp)), vhat = vbar * s
  * Eq. 3: the LSQ gradient of vhat w.r.t. the step size s
  * Eq. 5: the straight-through gradient of vhat w.r.t. v
  * the Figure-1 inference dataflow: int matmul of (wbar, xbar) rescaled by
    sw * sx.

pytest (``python/tests``) asserts the Pallas kernels match these functions to
float tolerance over hypothesis-generated shapes/values.
"""

from __future__ import annotations

import jax.numpy as jnp


def qrange(bits: int, signed: bool) -> tuple[int, int]:
    """Return (Qn, Qp) per Section 2 of the paper.

    Unsigned data (activations): Qn = 0, Qp = 2^b - 1.
    Signed data (weights):       Qn = 2^(b-1), Qp = 2^(b-1) - 1.
    """
    if bits < 1:
        raise ValueError(f"bits must be >= 1, got {bits}")
    if signed:
        return 2 ** (bits - 1), 2 ** (bits - 1) - 1
    return 0, 2**bits - 1


def quantize_vbar(v, s, qn: int, qp: int):
    """Integer-valued representation vbar = round(clip(v/s, -Qn, Qp)) (Eq. 1)."""
    return jnp.round(jnp.clip(v / s, -float(qn), float(qp)))


def quantize(v, s, qn: int, qp: int):
    """Fake-quantized vhat = vbar * s (Eq. 2)."""
    return quantize_vbar(v, s, qn, qp) * s


def grad_v_mask(v, s, qn: int, qp: int):
    """STE pass-through mask, Eq. 5: 1 inside (-Qn, Qp), 0 at/after clip."""
    r = v / s
    return jnp.where((r > -float(qn)) & (r < float(qp)), 1.0, 0.0).astype(v.dtype)


def grad_s_term(v, s, qn: int, qp: int):
    """Per-element d(vhat)/d(s), Eq. 3.

    -v/s + round(v/s)   inside the quantization domain
    -Qn / Qp            at or beyond the negative / positive clip point
    """
    r = v / s
    inner = -r + jnp.round(r)
    term = jnp.where(r <= -float(qn), -float(qn), inner)
    term = jnp.where(r >= float(qp), float(qp), term)
    return term.astype(v.dtype)


def lsq_vjp(v, s, qn: int, qp: int, gscale: float, cotangent):
    """Reference VJP of ``quantize``: (grad_v, grad_s).

    grad_s is reduced over all elements and multiplied by the step-size
    gradient scale g (Section 2.2): g = 1/sqrt(N * Qp).
    """
    gv = cotangent * grad_v_mask(v, s, qn, qp)
    gs = jnp.sum(cotangent * grad_s_term(v, s, qn, qp)) * jnp.asarray(gscale, v.dtype)
    return gv, gs


def step_init(v, qp: int):
    """Step-size initialization 2<|v|>/sqrt(Qp) (Section 2.1)."""
    return 2.0 * jnp.mean(jnp.abs(v)) / jnp.sqrt(float(qp))


def qmatmul(xbar, wbar, sx, sw):
    """Figure-1 inference path: integer matmul rescaled by the step sizes.

    ``xbar``/``wbar`` are integer-valued (stored as int32); accumulation is
    int32 as a low-precision MAC array would produce, and a single
    scalar-tensor multiply applies sx*sw afterwards.
    """
    acc = jnp.matmul(
        xbar.astype(jnp.int32),
        wbar.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * (sx * sw)
