"""Layer-2 functional NN layers with LSQ-quantized conv / dense.

A deliberately small module system ("qnn"): a model is a plain Python
function taking a :class:`Ctx` and an input tensor. The same function serves
three purposes depending on ``ctx.mode``:

  * ``init``    — registers parameters (with roles) and returns shapes
  * ``apply``   — the differentiable forward pass (training or eval)
  * ``collect`` — forward pass that records mean|v| at every activation
                  quantizer, used to initialize activation step sizes from
                  the first batch (Section 2.1)

Parameter roles drive the Rust-side manifest:

  weight   conv/fc kernels          -> gradient + weight decay
  bias     biases, BN gamma/beta    -> gradient, no weight decay
  step_w   weight step sizes        -> gradient (custom scale), no decay
  step_a   activation step sizes    -> gradient (custom scale), no decay
  state    BN running mean/var      -> no gradient, updated functionally

Per the paper, weights are quantized signed and input activations unsigned
(they follow ReLU), except the network input itself which is signed; first
and last matmul layers are always 8-bit.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import lsq as lsq_kernels
from .quantizers import QuantConfig, quantize

ROLES = ("weight", "bias", "step_w", "step_a", "state")

BN_MOMENTUM = 0.9
BN_EPS = 1e-5


class Ctx:
    """Threaded context for init/apply/collect passes over a model fn."""

    def __init__(self, mode, params=None, train=False, rng=None, qbits=32,
                 method="lsq", gscale_mode="full", num_classes=10):
        assert mode in ("init", "apply", "collect")
        self.mode = mode
        self.num_classes = num_classes
        self.params = {} if params is None else params
        self.roles: dict[str, str] = {}
        self.layer_meta: list[dict] = []  # model-size accounting (Fig. 3)
        self.train = train
        self.rng = rng
        self.state_out: dict[str, jnp.ndarray] = {}
        self.act_stats: dict[str, jnp.ndarray] = {}
        self.qbits = qbits
        self.method = method
        self.gscale_mode = gscale_mode
        self._scope: list[str] = []
        self._matmul_index = 0
        self.n_matmul: int | None = None  # set before apply for first/last

    # -- naming ------------------------------------------------------------
    def scope(self, name: str) -> "_Scope":
        return _Scope(self, name)

    def _name(self, leaf: str) -> str:
        return ".".join(self._scope + [leaf])

    # -- parameters ---------------------------------------------------------
    def param(self, leaf: str, role: str, shape, init_fn: Callable):
        name = self._name(leaf)
        if self.mode == "init":
            assert name not in self.params, f"duplicate param {name}"
            self.rng, key = jax.random.split(self.rng)
            self.params[name] = init_fn(key, shape).astype(jnp.float32)
            self.roles[name] = role
        return self.params[name]

    def layer_bits(self) -> int:
        """Precision for the current matmul layer: first/last pinned to 8."""
        i = self._matmul_index
        if self.qbits >= 32:
            return 32
        if i == 0 or (self.n_matmul is not None and i == self.n_matmul - 1):
            return max(self.qbits, 8)
        return self.qbits

    def quant_cfg(self, signed: bool, bits: int) -> QuantConfig:
        return QuantConfig(bits=bits, signed=signed, method=self.method,
                           gscale_mode=self.gscale_mode)


class _Scope:
    def __init__(self, ctx: Ctx, name: str):
        self.ctx, self.name = ctx, name

    def __enter__(self):
        self.ctx._scope.append(self.name)
        return self.ctx

    def __exit__(self, *exc):
        self.ctx._scope.pop()


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------


def kaiming(key, shape):
    """He-normal for conv (HWIO) / dense (IO) weights."""
    fan_in = 1
    for d in shape[:-1]:
        fan_in *= d
    return jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)


def zeros(_key, shape):
    return jnp.zeros(shape)


def ones(_key, shape):
    return jnp.ones(shape)


# --------------------------------------------------------------------------
# quantization plumbing shared by conv & dense
# --------------------------------------------------------------------------


def _quantize_pair(ctx: Ctx, x, w, signed_act: bool):
    """Quantize (input activations, weights) for the current matmul layer.

    Returns (x_hat, w_hat). Registers the two step-size parameters; in
    ``collect`` mode records mean|x| for data-driven activation-step init.
    """
    bits = ctx.layer_bits()
    ctx._matmul_index += 1
    if bits >= 32:
        return x, w

    wcfg = ctx.quant_cfg(signed=True, bits=bits)
    acfg = ctx.quant_cfg(signed=signed_act, bits=bits)
    _, qp_w = wcfg.qrange()

    def w_step_init(_key, shape):
        # 2<|w|>/sqrt(Qp) on the *initial* weights (Section 2.1). At init
        # time ``w`` is already materialized, so this is concrete.
        return jnp.asarray(lsq_kernels.step_init(w, qp_w)).reshape(shape)

    sw = ctx.param("sw", "step_w", (), w_step_init)
    sa = ctx.param("sa", "step_a", (), lambda _k, s: jnp.asarray(1.0))

    if ctx.mode == "collect":
        # Record mean|v| of the (unquantized) input for the data-driven
        # activation-step init, and pass everything through at fp32: we
        # fine-tune from a full-precision model, so "the first batch of
        # activations" is the fp batch.
        _, qp_a = acfg.qrange()
        ctx.act_stats[ctx._name("sa")] = (jnp.mean(jnp.abs(x)), qp_a)
        ctx.layer_meta.append(
            {"name": ".".join(ctx._scope), "n_weights": int(w.size),
             "bits": int(bits)}
        )
        return x, w

    n_w = w.size
    n_feat = x.shape[-1]
    x_hat = quantize(x, sa, acfg, n_feat)
    w_hat = quantize(w, sw, wcfg, n_w)
    ctx.layer_meta.append(
        {
            "name": ".".join(ctx._scope),
            "n_weights": int(n_w),
            "bits": int(bits),
        }
    )
    return x_hat, w_hat


# --------------------------------------------------------------------------
# layers
# --------------------------------------------------------------------------


def qconv(ctx: Ctx, x, name: str, out_ch: int, ksize=3,
          stride: int = 1, signed_act: bool = False, use_bias: bool = False):
    """Quantized 2-D convolution (NHWC x HWIO), SAME padding.

    ``ksize`` may be an int or an (kh, kw) tuple (for separable 1x3 / 3x1
    pairs as used by SqueezeNext).
    """
    if isinstance(ksize, int):
        ksize = (ksize, ksize)
    with ctx.scope(name):
        in_ch = x.shape[-1]
        w = ctx.param("w", "weight", (ksize[0], ksize[1], in_ch, out_ch), kaiming)
        if ctx.mode == "init" and ctx.layer_bits() >= 32:
            ctx.layer_meta.append(
                {"name": ".".join(ctx._scope), "n_weights": int(w.size),
                 "bits": 32}
            )
        x_hat, w_hat = _quantize_pair(ctx, x, w, signed_act)
        y = jax.lax.conv_general_dilated(
            x_hat, w_hat,
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        if use_bias:
            b = ctx.param("b", "bias", (out_ch,), zeros)
            y = y + b
        return y


def qdense(ctx: Ctx, x, name: str, out_dim: int, signed_act: bool = False,
           use_bias: bool = True):
    """Quantized fully connected layer."""
    with ctx.scope(name):
        in_dim = x.shape[-1]
        w = ctx.param("w", "weight", (in_dim, out_dim), kaiming)
        if ctx.mode == "init" and ctx.layer_bits() >= 32:
            ctx.layer_meta.append(
                {"name": ".".join(ctx._scope), "n_weights": int(w.size),
                 "bits": 32}
            )
        x_hat, w_hat = _quantize_pair(ctx, x, w, signed_act)
        y = x_hat @ w_hat
        if use_bias:
            b = ctx.param("b", "bias", (out_dim,), zeros)
            y = y + b
        return y


def batchnorm(ctx: Ctx, x, name: str):
    """BN over N,H,W (or N for 2-D input) with functional running stats."""
    with ctx.scope(name):
        ch = x.shape[-1]
        gamma = ctx.param("gamma", "bias", (ch,), ones)
        beta = ctx.param("beta", "bias", (ch,), zeros)
        rmean = ctx.param("rmean", "state", (ch,), zeros)
        rvar = ctx.param("rvar", "state", (ch,), ones)
        axes = tuple(range(x.ndim - 1))
        if ctx.train:
            mean = jnp.mean(x, axis=axes)
            var = jnp.var(x, axis=axes)
            ctx.state_out[ctx._name("rmean")] = (
                BN_MOMENTUM * rmean + (1.0 - BN_MOMENTUM) * mean
            )
            ctx.state_out[ctx._name("rvar")] = (
                BN_MOMENTUM * rvar + (1.0 - BN_MOMENTUM) * var
            )
        else:
            mean, var = rmean, rvar
        inv = jax.lax.rsqrt(var + BN_EPS)
        return (x - mean) * inv * gamma + beta


def relu(x):
    return jnp.maximum(x, 0.0)


def avgpool2(x):
    """2x2 average pooling, stride 2."""
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) * 0.25


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def global_avgpool(x):
    return jnp.mean(x, axis=(1, 2))
