"""Layer-2 model zoo: the architecture families evaluated in the paper.

All models are 32x32xC -> num_classes classifiers built from the quantized
layers in :mod:`layers`:

  * ``mlp``            — 2-layer MLP (fast unit-test model)
  * ``cnn_small``      — 4-conv BN CNN (fast sweep model)
  * ``resnet8/14/20/32`` — pre-activation ResNet (He et al. 2016), the
    CIFAR-scale stand-in for the paper's ResNet-18/34/50/101/152 ladder
  * ``vgg_small``      — VGG-style conv-BN stacks + FC head (VGG-16bn proxy)
  * ``sqnxt_small``    — SqueezeNext-style bottleneck blocks
    (SqueezeNext-23-2x proxy: aggressive parameter reduction, which the
    paper shows is hypersensitive to 2-bit quantization)

Each builder returns a function ``model(ctx, x) -> logits``. Use
:func:`get_model`.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import layers as L


def mlp(ctx: L.Ctx, x):
    x = x.reshape(x.shape[0], -1)
    x = L.qdense(ctx, x, "fc1", 256, signed_act=True)
    x = L.relu(x)
    x = L.qdense(ctx, x, "fc2", ctx.num_classes)
    return x


def cnn_small(ctx: L.Ctx, x):
    x = L.qconv(ctx, x, "conv1", 16, signed_act=True)
    x = L.batchnorm(ctx, x, "bn1")
    x = L.relu(x)
    x = L.qconv(ctx, x, "conv2", 32, stride=2)
    x = L.batchnorm(ctx, x, "bn2")
    x = L.relu(x)
    x = L.qconv(ctx, x, "conv3", 32)
    x = L.batchnorm(ctx, x, "bn3")
    x = L.relu(x)
    x = L.qconv(ctx, x, "conv4", 64, stride=2)
    x = L.batchnorm(ctx, x, "bn4")
    x = L.relu(x)
    x = L.global_avgpool(x)
    x = L.qdense(ctx, x, "fc", ctx.num_classes)
    return x


def _preact_block(ctx: L.Ctx, x, name: str, out_ch: int, stride: int):
    """Pre-activation basic block: BN-ReLU-conv, BN-ReLU-conv (+ shortcut)."""
    with ctx.scope(name):
        h = L.batchnorm(ctx, x, "bn1")
        h = L.relu(h)
        # Projection shortcut taken from the pre-activated tensor, as in the
        # original pre-act ResNet.
        if stride != 1 or x.shape[-1] != out_ch:
            sc = L.qconv(ctx, h, "proj", out_ch, ksize=1, stride=stride)
        else:
            sc = x
        h = L.qconv(ctx, h, "conv1", out_ch, stride=stride)
        h = L.batchnorm(ctx, h, "bn2")
        h = L.relu(h)
        h = L.qconv(ctx, h, "conv2", out_ch)
        return h + sc


def make_resnet(blocks_per_stage: int, width: int = 16):
    widths = (width, 2 * width, 4 * width)

    def resnet(ctx: L.Ctx, x):
        x = L.qconv(ctx, x, "stem", widths[0], signed_act=True)
        for stage, ch in enumerate(widths):
            for b in range(blocks_per_stage):
                stride = 2 if (stage > 0 and b == 0) else 1
                x = _preact_block(ctx, x, f"s{stage}b{b}", ch, stride)
        x = L.batchnorm(ctx, x, "bn_final")
        x = L.relu(x)
        x = L.global_avgpool(x)
        x = L.qdense(ctx, x, "fc", ctx.num_classes)
        return x

    return resnet


def vgg_small(ctx: L.Ctx, x):
    cfg = [(32, 2), (64, 2), (128, 2)]
    first = True
    for stage, (ch, reps) in enumerate(cfg):
        for r in range(reps):
            x = L.qconv(ctx, x, f"conv{stage}_{r}", ch, signed_act=first)
            first = False
            x = L.batchnorm(ctx, x, f"bn{stage}_{r}")
            x = L.relu(x)
        x = L.maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    x = L.qdense(ctx, x, "fc1", 128)
    x = L.relu(x)
    x = L.qdense(ctx, x, "fc2", ctx.num_classes)
    return x


def _sqnxt_block(ctx: L.Ctx, x, name: str, out_ch: int, stride: int):
    """SqueezeNext bottleneck: 1x1/2 -> 1x1/2 -> 1x3 -> 3x1 -> 1x1 expand."""
    with ctx.scope(name):
        in_ch = x.shape[-1]
        if stride != 1 or in_ch != out_ch:
            sc = L.qconv(ctx, x, "proj", out_ch, ksize=1, stride=stride)
        else:
            sc = x
        h = L.qconv(ctx, x, "r1", max(in_ch // 2, 8), ksize=1, stride=stride)
        h = L.batchnorm(ctx, h, "bnr1")
        h = L.relu(h)
        h = L.qconv(ctx, h, "r2", max(in_ch // 4, 8), ksize=1)
        h = L.batchnorm(ctx, h, "bnr2")
        h = L.relu(h)
        # Separable 1x3 then 3x1 pair (the SqueezeNext signature move).
        h = L.qconv(ctx, h, "s13", max(in_ch // 2, 8), ksize=(1, 3))
        h = L.batchnorm(ctx, h, "bns1")
        h = L.relu(h)
        h = L.qconv(ctx, h, "s31", max(in_ch // 2, 8), ksize=(3, 1))
        h = L.batchnorm(ctx, h, "bns2")
        h = L.relu(h)
        h = L.qconv(ctx, h, "expand", out_ch, ksize=1)
        h = L.batchnorm(ctx, h, "bne")
        return L.relu(h + sc)


def sqnxt_small(ctx: L.Ctx, x):
    x = L.qconv(ctx, x, "stem", 16, signed_act=True)
    x = L.batchnorm(ctx, x, "bn_stem")
    x = L.relu(x)
    plan = [(16, 1, 1), (32, 2, 2), (64, 2, 2)]
    for i, (ch, n, stride) in enumerate(plan):
        for b in range(n):
            x = _sqnxt_block(ctx, x, f"b{i}_{b}", ch, stride if b == 0 else 1)
    x = L.global_avgpool(x)
    x = L.qdense(ctx, x, "fc", ctx.num_classes)
    return x


_MODELS = {
    "mlp": mlp,
    "cnn_small": cnn_small,
    "resnet8": make_resnet(1),
    "resnet14": make_resnet(2),
    "resnet20": make_resnet(3),
    "resnet32": make_resnet(5),
    "vgg_small": vgg_small,
    "sqnxt_small": sqnxt_small,
}


def get_model(name: str):
    try:
        return _MODELS[name]
    except KeyError:
        raise ValueError(f"unknown model {name!r}; have {sorted(_MODELS)}") from None


def model_names():
    return sorted(_MODELS)
