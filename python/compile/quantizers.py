"""Layer-2 quantizer library: LSQ plus the competing gradient estimators.

The paper's central claim is about the *shape of the gradient* flowing to the
quantizer step size. To reproduce its comparisons (Table 1, Figure 2) with
everything else held fixed, every quantizer here shares the identical forward
(Eqs. 1-2) and STE data gradient (Eq. 5) and differs only in d(vhat)/d(s):

  lsq     -v/s + round(v/s) inside, -Qn / Qp saturated       (Eq. 3, Pallas)
  lsq_jnp same, pure-jnp (sanity/ablation path)
  qil     clip(v/s, -Qn, Qp): sensitive only to the distance
          from the clip points, flat w.r.t. transitions       (Jung et al.)
  pact    Qp beyond the positive clip point, zero elsewhere   (Choi et al.)
  fixed   no gradient to s at all (FAQ-style static fit)
  none    identity (full-precision layers)

All learnable variants apply the same Section-2.2 gradient scale so the
comparison isolates gradient shape, not update magnitude (the scale itself is
ablated separately via ``gscale_mode`` for Table 3 / Figure 4).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from .kernels import lsq as lsq_kernels
from .kernels import ref

METHODS = ("lsq", "lsq_jnp", "qil", "pact", "fixed", "none")
GSCALE_MODES = ("full", "sqrtn", "one", "x10", "d10")


@dataclass(frozen=True)
class QuantConfig:
    """Static per-tensor quantizer configuration, fixed at AOT time."""

    bits: int = 32  # 32 => no quantization
    signed: bool = True
    method: str = "lsq"
    gscale_mode: str = "full"  # Table-3 ablation knob

    @property
    def enabled(self) -> bool:
        return self.bits < 32 and self.method != "none"

    def qrange(self) -> tuple[int, int]:
        return ref.qrange(self.bits, self.signed)

    def with_bits(self, bits: int) -> "QuantConfig":
        return replace(self, bits=bits)


def gradscale_value(n_items: int, qp: int, mode: str) -> float:
    """The Section-2.2 gradient scale g for a layer with ``n_items`` elements.

    ``full``  g = 1/sqrt(N*Qp)   (the paper's heuristic)
    ``sqrtn`` g = 1/sqrt(N)      (Figure 4 middle / Table 3 row 2)
    ``one``   g = 1              (no scaling)
    ``x10``/``d10``: full scaled by 10 / by 1/10 (Table 3 rows 5-6)
    """
    if mode == "one":
        return 1.0
    if mode == "sqrtn":
        return 1.0 / math.sqrt(n_items)
    g = 1.0 / math.sqrt(n_items * qp)
    if mode == "x10":
        return 10.0 * g
    if mode == "d10":
        return 0.1 * g
    if mode == "full":
        return g
    raise ValueError(f"unknown gscale mode {mode!r}")


# --------------------------------------------------------------------------
# Appendix-B helper functions (Functions 1 and 2 of the paper), jnp versions.
# --------------------------------------------------------------------------


def gradscale(x, scale):
    """Function 1: identity forward, gradient multiplied by ``scale``."""
    y_grad = x * scale
    return jax.lax.stop_gradient(x - y_grad) + y_grad


def roundpass(x):
    """Function 2: round forward, straight-through gradient."""
    y = jnp.round(x)
    return jax.lax.stop_gradient(y - x) + x


# --------------------------------------------------------------------------
# Baseline step-size gradients (shared forward, custom ds term).
# --------------------------------------------------------------------------


def _make_variant(ds_term_fn):
    @functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
    def quant(v, s, qn, qp, gscale_):
        return ref.quantize(v, s, qn, qp)

    def fwd(v, s, qn, qp, gscale_):
        return ref.quantize(v, s, qn, qp), (v, s)

    def bwd(qn, qp, gscale_, res, cot):
        v, s = res
        gv = cot * ref.grad_v_mask(v, s, qn, qp)
        gs = jnp.sum(cot * ds_term_fn(v, s, qn, qp)) * jnp.asarray(
            gscale_, v.dtype
        )
        return gv, gs.reshape(s.shape)

    quant.defvjp(fwd, bwd)
    return quant


def _qil_ds(v, s, qn, qp):
    # Linear in v inside the domain, saturating at the clip points: the
    # gradient a pre-discretization interval transform produces — blind to
    # the quantization transitions themselves (Figure 2, middle).
    return jnp.clip(v / s, -float(qn), float(qp))


def _pact_ds(v, s, qn, qp):
    # Non-zero only past the clip points (Figure 2, right).
    r = v / s
    return jnp.where(
        r >= float(qp), float(qp), jnp.where(r <= -float(qn), -float(qn), 0.0)
    ).astype(v.dtype)


def _fixed_ds(v, s, qn, qp):
    return jnp.zeros_like(v)


_quant_jnp_lsq = _make_variant(ref.grad_s_term)
_quant_qil = _make_variant(_qil_ds)
_quant_pact = _make_variant(_pact_ds)
_quant_fixed = _make_variant(_fixed_ds)

_VARIANTS = {
    "lsq_jnp": _quant_jnp_lsq,
    "qil": _quant_qil,
    "pact": _quant_pact,
    "fixed": _quant_fixed,
}


def quantize(v, s, cfg: QuantConfig, n_items: int):
    """Quantize ``v`` with step ``s`` under ``cfg``; differentiable in both."""
    if not cfg.enabled:
        return v
    qn, qp = cfg.qrange()
    g = gradscale_value(n_items, qp, cfg.gscale_mode)
    if cfg.method == "lsq":
        return lsq_kernels.lsq_quantize(v, s, qn, qp, g)
    try:
        fn = _VARIANTS[cfg.method]
    except KeyError:
        raise ValueError(f"unknown quantizer method {cfg.method!r}") from None
    return fn(v, s, qn, qp, g)


def ds_term(v, s, cfg: QuantConfig):
    """The raw d(vhat)/d(s) curve for Figure 2 (no reduction, no gscale)."""
    qn, qp = cfg.qrange()
    fns = {
        "lsq": ref.grad_s_term,
        "lsq_jnp": ref.grad_s_term,
        "qil": _qil_ds,
        "pact": _pact_ds,
        "fixed": _fixed_ds,
    }
    return fns[cfg.method](v, s, qn, qp)
