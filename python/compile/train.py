"""Layer-2 training / evaluation steps for quantized models (Section 2.3).

Implements the paper's training recipe as pure functions suitable for AOT
lowering:

  * full-precision master weights, quantized forward/backward (Courbariaux
    et al. 2015 scheme) — quantization happens inside the loss via the
    custom-VJP quantizers, so SGD updates the fp32 copies;
  * SGD with momentum 0.9, weight decay on conv/fc weights only, softmax
    cross-entropy;
  * learning rate and weight decay enter as *runtime scalars* so the Rust
    coordinator owns the schedule (cosine / step decay, Section 3.5);
  * optional same-architecture knowledge distillation (Section 3.7):
    CE + equal-weighted T=1 distillation loss against a frozen fp32 teacher;
  * a diagnostic step that additionally emits per-quantized-layer
    ||grad_w||, ||w||, |grad_s|, s for the Figure-4 R-ratio analysis;
  * step-size initialization (Section 2.1): weights at model init,
    activations from the first batch via a collect pass.

Calling convention (mirrored by the Rust runtime, see manifest.json):
every step takes/returns parameters as a *flat list sorted by name*;
momentum buffers exist for gradient-bearing roles only.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import layers as L
from . import models
from .kernels import ref

MOMENTUM = 0.9

GRAD_ROLES = ("weight", "bias", "step_w", "step_a")


@dataclass(frozen=True)
class ModelSpec:
    """Everything that is baked into an artifact at AOT time."""

    model: str = "cnn_small"
    num_classes: int = 10
    image: int = 32
    channels: int = 3
    qbits: int = 32
    method: str = "lsq"
    gscale_mode: str = "full"

    def ctx_kwargs(self) -> dict:
        return dict(
            qbits=self.qbits,
            method=self.method,
            gscale_mode=self.gscale_mode,
            num_classes=self.num_classes,
        )


@dataclass
class InitResult:
    names: list[str]
    params: list[jnp.ndarray]
    roles: dict[str, str]
    layer_meta: list[dict]
    n_matmul: int
    grad_names: list[str] = field(init=False)

    def __post_init__(self):
        self.grad_names = [n for n in self.names if self.roles[n] in GRAD_ROLES]


def _dummy_input(spec: ModelSpec, batch: int = 1):
    return jnp.zeros((batch, spec.image, spec.image, spec.channels), jnp.float32)


def count_matmuls(spec: ModelSpec) -> int:
    model = models.get_model(spec.model)
    ctx = L.Ctx("init", rng=jax.random.PRNGKey(0), **spec.ctx_kwargs())
    ctx.n_matmul = None
    model(ctx, _dummy_input(spec))
    return ctx._matmul_index


def init_model(spec: ModelSpec, seed: int = 0) -> InitResult:
    """Two-pass init: count matmul layers (for the first/last-8-bit rule),
    then materialize parameters with weight step sizes set per Section 2.1."""
    n_matmul = count_matmuls(spec)
    model = models.get_model(spec.model)
    ctx = L.Ctx("init", rng=jax.random.PRNGKey(seed), **spec.ctx_kwargs())
    ctx.n_matmul = n_matmul
    model(ctx, _dummy_input(spec))
    names = sorted(ctx.params)
    return InitResult(
        names=names,
        params=[ctx.params[n] for n in names],
        roles=dict(ctx.roles),
        layer_meta=list(ctx.layer_meta),
        n_matmul=n_matmul,
    )


def apply_model(spec: ModelSpec, init: InitResult, params: dict, x,
                train: bool, mode: str = "apply"):
    """Run the model; returns (logits, ctx) — ctx carries state/collect data."""
    model = models.get_model(spec.model)
    ctx = L.Ctx(mode, params=params, train=train, **spec.ctx_kwargs())
    ctx.n_matmul = init.n_matmul
    logits = model(ctx, x)
    return logits, ctx


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def distill_loss(student_logits, teacher_logits):
    """Hinton et al. 2015 with temperature 1: KL(teacher || student)."""
    t = jax.nn.softmax(teacher_logits)
    logp = jax.nn.log_softmax(student_logits)
    logt = jax.nn.log_softmax(teacher_logits)
    return jnp.mean(jnp.sum(t * (logt - logp), axis=1))


def _split(init: InitResult, params_list):
    params = dict(zip(init.names, params_list))
    grads = {n: params[n] for n in init.grad_names}
    state = {n: params[n] for n in init.names if init.roles[n] == "state"}
    return params, grads, state


def _n_correct(logits, labels):
    return jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


def _sgd(init: InitResult, params, grads, moms, lr, wd):
    """SGD + momentum with decoupled-by-role weight decay (weights only)."""
    new_params, new_moms = {}, []
    for n, m in zip(init.grad_names, moms):
        g = grads[n]
        if init.roles[n] == "weight":
            g = g + wd * params[n]
        m_new = MOMENTUM * m + g
        new_moms.append(m_new)
        new_params[n] = params[n] - lr * m_new
    return new_params, new_moms


def _loss_and_ctx(spec, init, grad_params, state_params, x, y,
                  teacher_logits=None):
    params = dict(state_params)
    params.update(grad_params)
    logits, ctx = apply_model(spec, init, params, x, train=True)
    loss = cross_entropy(logits, y)
    if teacher_logits is not None:
        loss = loss + distill_loss(logits, teacher_logits)
    return loss, (ctx.state_out, logits)


def build_train_step(spec: ModelSpec, init: InitResult, distill: bool = False,
                     teacher_init: InitResult | None = None,
                     teacher_spec: ModelSpec | None = None,
                     diag: bool = False):
    """Build the train-step function to be AOT-lowered.

    Positional signature (all jnp arrays):
      params...[P], moms...[G], (teacher_params...[T] if distill,)
      x, y, lr, wd
    Returns:
      (new_params...[P], new_moms...[G], loss, ncorrect
       (, gw_norm[Lq], w_norm[Lq], gs_abs[Lq], s_val[Lq] if diag))
    """
    P = len(init.names)
    G = len(init.grad_names)
    T = len(teacher_init.names) if distill else 0

    # Quantized-weight layers (those owning step sizes), for diagnostics.
    sw_names = [n for n in init.names if init.roles[n] == "step_w"]
    w_of_sw = [n[: -len(".sw")] + ".w" for n in sw_names]

    def step(*args):
        params_list = list(args[:P])
        moms = list(args[P : P + G])
        ofs = P + G
        teacher_logits = None
        if distill:
            t_list = list(args[ofs : ofs + T])
            ofs += T
        x, y, lr, wd = args[ofs : ofs + 4]
        params, grad_params, state_params = _split(init, params_list)
        if distill:
            t_params = dict(zip(teacher_init.names, t_list))
            teacher_logits, _ = apply_model(
                teacher_spec, teacher_init, t_params, x, train=False
            )
            teacher_logits = jax.lax.stop_gradient(teacher_logits)

        def loss_fn(gp):
            return _loss_and_ctx(
                spec, init, gp, state_params, x, y, teacher_logits
            )

        (loss, (state_out, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(grad_params)

        new_params, new_moms = _sgd(init, params, grads, moms, lr, wd)
        # Fold in functional BN state updates.
        merged = dict(params)
        merged.update(new_params)
        merged.update(state_out)
        out_params = [merged[n] for n in init.names]
        ncorrect = _n_correct(logits, y)
        outs = out_params + new_moms + [loss, ncorrect]
        if diag:
            gw = jnp.stack(
                [jnp.linalg.norm(grads[n].reshape(-1)) for n in w_of_sw]
            )
            wn = jnp.stack(
                [jnp.linalg.norm(params[n].reshape(-1)) for n in w_of_sw]
            )
            gs = jnp.stack([jnp.abs(grads[n]).reshape(()) for n in sw_names])
            sv = jnp.stack([params[n].reshape(()) for n in sw_names])
            outs += [gw, wn, gs, sv]
        return tuple(outs)

    return step


def build_eval_step(spec: ModelSpec, init: InitResult):
    """Eval step: (params..., x, y) -> (loss, ncorrect, logits)."""
    P = len(init.names)

    def step(*args):
        params = dict(zip(init.names, args[:P]))
        x, y = args[P], args[P + 1]
        logits, _ = apply_model(spec, init, params, x, train=False)
        return cross_entropy(logits, y), _n_correct(logits, y), logits

    return step


def build_init_quant(spec: ModelSpec, init: InitResult):
    """Step-size initialization (Section 2.1): (params..., x) -> params...

    Sets every weight step size to 2<|w|>/sqrt(Qp) over the *current*
    weights (so fine-tuning from an fp32 checkpoint re-derives them from
    the loaded weights) and every activation step size to 2<|v|>/sqrt(Qp)
    over the first batch of activations. The collect pass runs the
    unquantized network — we fine-tune from a full-precision model, so the
    first batch of activations is the fp one.
    """
    P = len(init.names)
    bits_of = {m["name"]: m["bits"] for m in init.layer_meta}

    def step(*args):
        params = dict(zip(init.names, args[:P]))
        x = args[P]
        _, ctx = apply_model(spec, init, params, x, train=True, mode="collect")
        out = dict(params)
        for name, (mean_abs, qp) in ctx.act_stats.items():
            out[name] = (2.0 * mean_abs / jnp.sqrt(float(qp))).reshape(
                params[name].shape
            )
        for name in init.names:
            if init.roles[name] == "step_w":
                scope = name[: -len(".sw")]
                _, qp_w = ref.qrange(bits_of[scope], signed=True)
                w = params[scope + ".w"]
                out[name] = jnp.asarray(
                    2.0 * jnp.mean(jnp.abs(w)) / jnp.sqrt(float(qp_w))
                ).reshape(params[name].shape)
        return tuple(out[n] for n in init.names)

    return step


def build_infer_step(spec: ModelSpec, init: InitResult):
    """Serving forward: (params..., x) -> logits (eval-mode BN)."""
    P = len(init.names)

    def step(*args):
        params = dict(zip(init.names, args[:P]))
        x = args[P]
        logits, _ = apply_model(spec, init, params, x, train=False)
        return (logits,)

    return step
