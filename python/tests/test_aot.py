"""AOT exporter tests: HLO text round-trips through the XLA parser, the
manifest calling convention is self-consistent, params.bin matches shapes."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, train as T


@pytest.fixture(scope="module")
def mlp_export(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    ex = aot.Exporter(out, batch=8, stats=False)
    ex.train("mlp", 2)
    ex.eval("mlp", 2)
    ex.init_quant("mlp", 2)
    ex.infer("mlp", 2, batch=4)
    ex.fig2(n=64)
    ex.qmm(m=8, k=32, n=16)
    ex.write_manifest()
    return out


def _manifest(out):
    return json.loads((out / "manifest.json").read_text())


class TestManifest:
    def test_artifacts_and_files_exist(self, mlp_export):
        m = _manifest(mlp_export)
        assert len(m["artifacts"]) == 6
        for a in m["artifacts"]:
            assert (mlp_export / a["file"]).exists()

    def test_params_bin_size_matches_shapes(self, mlp_export):
        m = _manifest(mlp_export)
        fam = m["families"]["mlp_q2"]
        n_elems = sum(
            int(np.prod(fam["shapes"][n] or [1])) for n in fam["param_names"]
        )
        size = (mlp_export / fam["params_bin"]).stat().st_size
        assert size == 4 * n_elems

    def test_train_io_convention(self, mlp_export):
        m = _manifest(mlp_export)
        art = next(a for a in m["artifacts"] if a["kind"] == "train")
        fam = m["families"][art["family"]]
        kinds = [i["kind"] for i in art["inputs"]]
        P, G = len(fam["param_names"]), len(fam["grad_names"])
        assert kinds[:P] == ["param"] * P
        assert kinds[P:P + G] == ["mom"] * G
        assert kinds[P + G:] == ["data_x", "data_y", "lr", "wd"]
        okinds = [o["kind"] for o in art["outputs"]]
        assert okinds == ["param"] * P + ["mom"] * G + ["metric"] * 2
        # params echo in identical order so outputs can be fed back verbatim
        assert [i["name"] for i in art["inputs"][:P]] == fam["param_names"]
        assert [o["name"] for o in art["outputs"][:P]] == fam["param_names"]

    def test_eval_outputs(self, mlp_export):
        m = _manifest(mlp_export)
        art = next(a for a in m["artifacts"] if a["kind"] == "eval")
        assert [o["name"] for o in art["outputs"]] == [
            "loss", "ncorrect", "logits"
        ]

    def test_roles_flag_step_params(self, mlp_export):
        m = _manifest(mlp_export)
        fam = m["families"]["mlp_q2"]
        sw = [n for n, r in fam["roles"].items() if r == "step_w"]
        assert sw and all(n.endswith(".sw") for n in sw)


class TestHloText:
    def _parse(self, path):
        text = pathlib.Path(path).read_text()
        # Round-trip through the same parser the Rust xla crate uses.
        return xc._xla.hlo_module_from_text(text)

    def test_all_artifacts_parse(self, mlp_export):
        m = _manifest(mlp_export)
        for a in m["artifacts"]:
            mod = self._parse(mlp_export / a["file"])
            assert mod is not None

    def test_executable_runs_and_matches_jit(self, mlp_export):
        """Compile the exported eval HLO with the in-process XLA client and
        check numerics against direct jit execution — the same round trip
        the Rust runtime performs."""
        m = _manifest(mlp_export)
        art = next(a for a in m["artifacts"] if a["kind"] == "eval")
        fam = m["families"][art["family"]]
        spec = T.ModelSpec(model=fam["model"], qbits=fam["qbits"])
        init = T.init_model(spec, seed=0)

        x = np.random.default_rng(0).normal(
            size=(art["batch"], 32, 32, 3)
        ).astype(np.float32)
        y = (np.arange(art["batch"]) % 10).astype(np.int32)

        ev = jax.jit(T.build_eval_step(spec, init))
        loss, nc, logits = ev(*(init.params + [jnp.asarray(x), jnp.asarray(y)]))

        text = (mlp_export / art["file"]).read_text()
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None
        # mlir->xla already validated by the parse; execution equivalence is
        # covered end-to-end by the Rust integration tests. Here check the
        # entry signature arity matches the manifest.
        entry = text[text.index("ENTRY"):]
        n_params = entry.count(" parameter(")
        assert n_params == len(art["inputs"])
        assert float(loss) > 0 and logits.shape == (art["batch"], 10)


class TestOpHistogram:
    def test_histogram_counts_ops(self):
        text = """HloModule m
ENTRY main {
  p0 = f32[2]{0} parameter(0)
  c = f32[2]{0} constant({1,2})
  ROOT a = f32[2]{0} add(p0, c)
}
"""
        h = aot.hlo_op_histogram(text)
        assert h["add"] == 1 and h["parameter"] == 1

    def test_no_redundant_quantize_subgraphs(self, mlp_export):
        """L2 perf invariant: no wholesale recompute duplication of the
        quantizer subgraphs. Each quantizer contributes at most 4
        round-nearest-even sites in the lowered train step (fwd vhat,
        bwd STE-mask recompute, bwd Eq.-3 term, VJP residual plumbing);
        anything beyond 4x the quantizer count means XLA is re-deriving
        whole quantize subgraphs."""
        m = _manifest(mlp_export)
        art = next(a for a in m["artifacts"] if a["kind"] == "train")
        fam = m["families"][art["family"]]
        n_quant = sum(
            1 for r in fam["roles"].values() if r in ("step_w", "step_a")
        )
        text = (mlp_export / art["file"]).read_text()
        rounds = text.count("round-nearest-even")
        assert n_quant == 4  # mlp: 2 matmul layers x (weights + acts)
        assert rounds <= 4 * n_quant, (
            f"{rounds} round ops for {n_quant} quantizers — duplicated?"
        )
