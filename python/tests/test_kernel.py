"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, values, bit widths and signedness; every property
asserts allclose against ref. This is the core correctness signal for the
kernels that every artifact embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lsq, qmatmul, ref

jax.config.update("jax_platform_name", "cpu")


def _data(seed, shape, scale=1.0):
    return np.asarray(
        np.random.default_rng(seed).normal(0.0, scale, size=shape),
        dtype=np.float32,
    )


bits_st = st.sampled_from([2, 3, 4, 8])
signed_st = st.booleans()
shape_st = st.sampled_from(
    [(7,), (64,), (1023,), (1024,), (1025,), (3, 5), (8, 128), (2, 3, 4, 5)]
)


class TestQRange:
    def test_unsigned(self):
        assert ref.qrange(2, signed=False) == (0, 3)
        assert ref.qrange(8, signed=False) == (0, 255)

    def test_signed(self):
        assert ref.qrange(2, signed=True) == (2, 1)
        assert ref.qrange(3, signed=True) == (4, 3)
        assert ref.qrange(8, signed=True) == (128, 127)

    def test_invalid(self):
        with pytest.raises(ValueError):
            ref.qrange(0, signed=True)


class TestForward:
    @settings(max_examples=40, deadline=None)
    @given(bits=bits_st, signed=signed_st, shape=shape_st,
           seed=st.integers(0, 2**16), s=st.floats(0.01, 2.0))
    def test_matches_ref(self, bits, signed, shape, seed, s):
        v = _data(seed, shape)
        qn, qp = ref.qrange(bits, signed)
        got = lsq.lsq_quantize(jnp.asarray(v), jnp.float32(s), qn, qp, 1.0)
        want = ref.quantize(jnp.asarray(v), jnp.float32(s), qn, qp)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_output_is_on_grid(self):
        v = jnp.asarray(_data(0, (512,)))
        s = jnp.float32(0.3)
        qn, qp = ref.qrange(3, signed=True)
        vhat = lsq.lsq_quantize(v, s, qn, qp, 1.0)
        levels = np.round(np.asarray(vhat) / 0.3)
        assert levels.min() >= -qn and levels.max() <= qp
        np.testing.assert_allclose(np.asarray(vhat), levels * 0.3, atol=1e-6)

    def test_idempotent(self):
        v = jnp.asarray(_data(1, (300,)))
        s = jnp.float32(0.25)
        qn, qp = ref.qrange(4, signed=True)
        once = lsq.lsq_quantize(v, s, qn, qp, 1.0)
        twice = lsq.lsq_quantize(once, s, qn, qp, 1.0)
        np.testing.assert_allclose(once, twice, atol=1e-6)


class TestBackward:
    @settings(max_examples=30, deadline=None)
    @given(bits=bits_st, signed=signed_st, shape=shape_st,
           seed=st.integers(0, 2**16))
    def test_vjp_matches_ref(self, bits, signed, shape, seed):
        v = jnp.asarray(_data(seed, shape))
        s = jnp.float32(0.2)
        qn, qp = ref.qrange(bits, signed)
        n = int(np.prod(shape))
        g = 1.0 / np.sqrt(n * qp)
        cot = jnp.asarray(_data(seed + 1, shape))
        _, vjp = jax.vjp(
            lambda v_, s_: lsq.lsq_quantize(v_, s_, qn, qp, g), v, s
        )
        gv, gs = vjp(cot)
        egv, egs = ref.lsq_vjp(v, s, qn, qp, g, cot)
        np.testing.assert_allclose(gv, egv, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gs, egs, rtol=1e-4, atol=1e-5)

    def test_grad_v_zero_outside_domain(self):
        qn, qp = ref.qrange(2, signed=False)  # (0, 3)
        v = jnp.asarray([-0.5, 0.5, 2.9, 3.5], jnp.float32)
        gv = jax.grad(
            lambda v_: jnp.sum(
                lsq.lsq_quantize(v_, jnp.float32(1.0), qn, qp, 1.0)
            )
        )(v)
        np.testing.assert_allclose(gv, [0.0, 1.0, 1.0, 0.0], atol=1e-6)

    def test_grad_s_saturation_values(self):
        """Eq. 3: ds = -Qn / +Qp at the clip points."""
        qn, qp = ref.qrange(2, signed=True)  # (2, 1)
        v = jnp.asarray([-10.0], jnp.float32)
        gs = jax.grad(
            lambda s_: jnp.sum(lsq.lsq_quantize(v, s_, qn, qp, 1.0)),
        )(jnp.float32(1.0))
        assert float(gs) == pytest.approx(-2.0)
        v = jnp.asarray([10.0], jnp.float32)
        gs = jax.grad(
            lambda s_: jnp.sum(lsq.lsq_quantize(v, s_, qn, qp, 1.0)),
        )(jnp.float32(1.0))
        assert float(gs) == pytest.approx(1.0)

    def test_grad_s_transition_sensitivity(self):
        """The LSQ gradient grows as v approaches a transition point —
        the paper's key qualitative claim (Section 2.1)."""
        qn, qp = ref.qrange(3, signed=False)
        s = jnp.float32(1.0)
        near = jnp.asarray([1.49], jnp.float32)  # just below round-up point
        far = jnp.asarray([1.01], jnp.float32)  # just after a transition
        g_near = jax.grad(
            lambda s_: jnp.sum(lsq.lsq_quantize(near, s_, qn, qp, 1.0))
        )(s)
        g_far = jax.grad(
            lambda s_: jnp.sum(lsq.lsq_quantize(far, s_, qn, qp, 1.0))
        )(s)
        assert abs(float(g_near)) > abs(float(g_far))

    def test_gscale_is_linear(self):
        v = jnp.asarray(_data(3, (128,)))
        qn, qp = ref.qrange(2, signed=True)

        def f(g):
            return jax.grad(
                lambda s_: jnp.sum(lsq.lsq_quantize(v, s_, qn, qp, g))
            )(jnp.float32(0.2))

        np.testing.assert_allclose(f(0.5), 0.5 * f(1.0), rtol=1e-5)


class TestStepInit:
    @settings(max_examples=20, deadline=None)
    @given(shape=shape_st, seed=st.integers(0, 2**16), bits=bits_st)
    def test_matches_ref(self, shape, seed, bits):
        v = jnp.asarray(_data(seed, shape))
        _, qp = ref.qrange(bits, signed=True)
        got = lsq.step_init(v, qp)
        want = ref.step_init(v, qp)
        np.testing.assert_allclose(got, want, rtol=1e-5)


class TestQMatmul:
    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(1, 130), k=st.integers(1, 70), n=st.integers(1, 130),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x = rng.integers(-8, 8, size=(m, k)).astype(np.int32)
        w = rng.integers(-8, 8, size=(k, n)).astype(np.int32)
        got = qmatmul.qmatmul(jnp.asarray(x), jnp.asarray(w),
                              jnp.float32(0.13), jnp.float32(0.07))
        want = ref.qmatmul(x, w, 0.13, 0.07)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_int_accumulation_exact(self):
        """Accumulation must be exact integer arithmetic before the rescale."""
        x = np.full((4, 100), 7, np.int32)
        w = np.full((100, 3), -3, np.int32)
        got = np.asarray(
            qmatmul.qmatmul(jnp.asarray(x), jnp.asarray(w),
                            jnp.float32(1.0), jnp.float32(1.0))
        )
        assert (got == -2100.0).all()


class TestTilingPlan:
    """The block planner: single block up to the VMEM cap, grid beyond."""

    def test_small_single_block(self):
        block, nblk = lsq._plan(1000)
        assert nblk == 1 and block == 1024  # padded to lane multiple

    def test_exact_lane(self):
        block, nblk = lsq._plan(128)
        assert (block, nblk) == (128, 1)

    def test_large_tensor_gets_grid(self):
        n = lsq.MAX_BLOCK * 3 + 5
        block, nblk = lsq._plan(n)
        assert block == lsq.MAX_BLOCK
        assert nblk == 4
        assert nblk * block >= n

    def test_multi_block_path_matches_ref(self, monkeypatch):
        """Force the grid path with a tiny MAX_BLOCK and re-verify fwd+vjp —
        the configuration a real-TPU deployment of large layers would use."""
        monkeypatch.setattr(lsq, "MAX_BLOCK", 256)
        v = jnp.asarray(_data(5, (1500,)))
        s = jnp.float32(0.15)
        qn, qp = ref.qrange(3, signed=True)
        out = lsq.lsq_quantize(v, s, qn, qp, 1.0)
        np.testing.assert_allclose(out, ref.quantize(v, s, qn, qp), rtol=1e-6)
        cot = jnp.asarray(_data(6, (1500,)))
        _, vjp = jax.vjp(lambda v_, s_: lsq.lsq_quantize(v_, s_, qn, qp, 0.5), v, s)
        gv, gs = vjp(cot)
        egv, egs = ref.lsq_vjp(v, s, qn, qp, 0.5, cot)
        np.testing.assert_allclose(gv, egv, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gs, egs, rtol=1e-4, atol=1e-5)

    def test_multi_block_step_init(self, monkeypatch):
        monkeypatch.setattr(lsq, "MAX_BLOCK", 256)
        v = jnp.asarray(_data(7, (777,)))
        np.testing.assert_allclose(lsq.step_init(v, 7), ref.step_init(v, 7),
                                   rtol=1e-5)
