"""L2 model/train tests: init invariants, shapes, BN state, SGD semantics,
the first/last-8-bit convention, KD and diag steps, activation step init."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, train as T


def _batch(b=4, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (b, 32, 32, 3))
    y = jnp.arange(b) % 10
    return x, y


def _moms(init):
    by = dict(zip(init.names, init.params))
    return [jnp.zeros_like(by[n]) for n in init.grad_names]


@pytest.fixture(scope="module")
def cnn2():
    spec = T.ModelSpec(model="cnn_small", qbits=2)
    return spec, T.init_model(spec, 0)


class TestInit:
    def test_param_names_sorted_and_unique(self, cnn2):
        _, init = cnn2
        assert init.names == sorted(init.names)
        assert len(set(init.names)) == len(init.names)

    def test_roles_cover_all_params(self, cnn2):
        _, init = cnn2
        assert set(init.roles) == set(init.names)
        assert set(init.roles.values()) <= {
            "weight", "bias", "step_w", "step_a", "state"
        }

    def test_grad_names_exclude_state(self, cnn2):
        _, init = cnn2
        for n in init.grad_names:
            assert init.roles[n] != "state"

    def test_first_last_layers_are_8bit(self, cnn2):
        _, init = cnn2
        bits = {m["name"]: m["bits"] for m in init.layer_meta}
        assert bits["conv1"] == 8
        assert bits["fc"] == 8
        assert bits["conv2"] == 2

    def test_step_size_init_formula(self, cnn2):
        """sw = 2<|w|>/sqrt(Qp) over the initial weights (Section 2.1)."""
        _, init = cnn2
        by = dict(zip(init.names, init.params))
        w = by["conv2.w"]
        qp = 2 ** (2 - 1) - 1  # signed 2-bit
        want = 2.0 * jnp.mean(jnp.abs(w)) / jnp.sqrt(float(qp))
        np.testing.assert_allclose(by["conv2.sw"], want, rtol=1e-4)

    def test_fp32_family_has_no_step_params(self):
        init = T.init_model(T.ModelSpec(model="cnn_small", qbits=32), 0)
        assert not any(r in ("step_w", "step_a") for r in init.roles.values())

    def test_quantized_families_share_weight_names(self):
        i2 = T.init_model(T.ModelSpec(model="cnn_small", qbits=2), 0)
        i4 = T.init_model(T.ModelSpec(model="cnn_small", qbits=4), 0)
        assert i2.names == i4.names

    def test_deterministic(self):
        a = T.init_model(T.ModelSpec(model="mlp", qbits=2), 7)
        b = T.init_model(T.ModelSpec(model="mlp", qbits=2), 7)
        for pa, pb in zip(a.params, b.params):
            np.testing.assert_array_equal(pa, pb)

    def test_all_models_init(self):
        for m in models.model_names():
            init = T.init_model(T.ModelSpec(model=m, qbits=4), 0)
            assert init.n_matmul >= 2
            assert sum(l["n_weights"] for l in init.layer_meta) > 0


class TestTrainStep:
    def test_loss_decreases_over_steps(self, cnn2):
        spec, init = cnn2
        step = jax.jit(T.build_train_step(spec, init))
        x, y = _batch(16)
        params, moms = list(init.params), _moms(init)
        losses = []
        for _ in range(8):
            out = step(*(params + moms + [x, y, jnp.float32(0.05),
                                          jnp.float32(0.0)]))
            P, G = len(init.names), len(init.grad_names)
            params = list(out[:P])
            moms = list(out[P:P + G])
            losses.append(float(out[P + G]))
        assert losses[-1] < losses[0]

    def test_state_params_have_no_momentum(self, cnn2):
        _, init = cnn2
        state = [n for n in init.names if init.roles[n] == "state"]
        assert state and not set(state) & set(init.grad_names)

    def test_bn_running_stats_update(self, cnn2):
        spec, init = cnn2
        step = jax.jit(T.build_train_step(spec, init))
        x, y = _batch(8)
        out = step(*(init.params + _moms(init) +
                     [x, y, jnp.float32(0.0), jnp.float32(0.0)]))
        by_out = dict(zip(init.names, out[:len(init.names)]))
        by_in = dict(zip(init.names, init.params))
        # lr=0 freezes params, but BN state must still move.
        assert not np.allclose(by_out["bn1.rmean"], by_in["bn1.rmean"])
        np.testing.assert_allclose(by_out["conv2.w"], by_in["conv2.w"])

    def test_weight_decay_applies_to_weights_only(self, cnn2):
        spec, init = cnn2
        step = jax.jit(T.build_train_step(spec, init))
        x, y = _batch(8)
        o_nowd = step(*(init.params + _moms(init) +
                        [x, y, jnp.float32(0.01), jnp.float32(0.0)]))
        o_wd = step(*(init.params + _moms(init) +
                      [x, y, jnp.float32(0.01), jnp.float32(0.1)]))
        P = len(init.names)
        d_now = dict(zip(init.names, o_nowd[:P]))
        d_wd = dict(zip(init.names, o_wd[:P]))
        assert not np.allclose(d_now["conv2.w"], d_wd["conv2.w"])
        # step sizes and BN params are not decayed
        np.testing.assert_allclose(d_now["conv2.sw"], d_wd["conv2.sw"])
        np.testing.assert_allclose(d_now["bn2.gamma"], d_wd["bn2.gamma"])

    def test_step_sizes_receive_gradient(self, cnn2):
        spec, init = cnn2
        step = jax.jit(T.build_train_step(spec, init))
        x, y = _batch(8)
        out = step(*(init.params + _moms(init) +
                     [x, y, jnp.float32(0.1), jnp.float32(0.0)]))
        by_out = dict(zip(init.names, out[:len(init.names)]))
        by_in = dict(zip(init.names, init.params))
        moved = [
            n for n in init.names
            if init.roles[n] in ("step_w", "step_a")
            and not np.allclose(by_out[n], by_in[n])
        ]
        assert moved, "no step size moved after one training step"


class TestEvalAndInfer:
    def test_eval_consistent_with_infer(self, cnn2):
        spec, init = cnn2
        x, y = _batch(8)
        ev = jax.jit(T.build_eval_step(spec, init))
        inf = jax.jit(T.build_infer_step(spec, init))
        loss, nc, logits = ev(*(init.params + [x, y]))
        (logits2,) = inf(*(init.params + [x]))
        np.testing.assert_allclose(logits, logits2, rtol=1e-5, atol=1e-5)
        assert 0 <= float(nc) <= 8

    def test_eval_deterministic(self, cnn2):
        spec, init = cnn2
        x, y = _batch(8)
        ev = jax.jit(T.build_eval_step(spec, init))
        a = ev(*(init.params + [x, y]))
        b = ev(*(init.params + [x, y]))
        np.testing.assert_array_equal(a[2], b[2])


class TestInitQuant:
    def test_sets_act_and_weight_steps(self, cnn2):
        spec, init = cnn2
        iq = jax.jit(T.build_init_quant(spec, init))
        x, _ = _batch(8)
        # Perturb weights to verify sw is recomputed from *current* weights.
        by = dict(zip(init.names, init.params))
        by["conv2.w"] = by["conv2.w"] * 3.0
        plist = [by[n] for n in init.names]
        out = dict(zip(init.names, iq(*(plist + [x]))))
        qp = 1  # signed 2-bit
        want = 2.0 * jnp.mean(jnp.abs(by["conv2.w"])) / jnp.sqrt(float(qp))
        np.testing.assert_allclose(out["conv2.sw"], want, rtol=1e-4)
        assert float(out["conv1.sa"]) > 0
        # Non-step params pass through untouched.
        np.testing.assert_array_equal(out["conv2.w"], by["conv2.w"])


class TestDistillAndDiag:
    def test_kd_runs_and_differs_from_plain(self, cnn2):
        spec, init = cnn2
        tspec = T.ModelSpec(model="cnn_small", qbits=32)
        tinit = T.init_model(tspec, 1)
        kd = jax.jit(T.build_train_step(spec, init, distill=True,
                                        teacher_init=tinit,
                                        teacher_spec=tspec))
        plain = jax.jit(T.build_train_step(spec, init))
        x, y = _batch(8)
        okd = kd(*(init.params + _moms(init) + tinit.params +
                   [x, y, jnp.float32(0.01), jnp.float32(0.0)]))
        opl = plain(*(init.params + _moms(init) +
                      [x, y, jnp.float32(0.01), jnp.float32(0.0)]))
        P, G = len(init.names), len(init.grad_names)
        assert float(okd[P + G]) > float(opl[P + G])  # CE + KD > CE at init

    def test_diag_outputs_match_param_values(self, cnn2):
        spec, init = cnn2
        dg = jax.jit(T.build_train_step(spec, init, diag=True))
        x, y = _batch(8)
        out = dg(*(init.params + _moms(init) +
                   [x, y, jnp.float32(0.01), jnp.float32(0.0)]))
        gw, wn, gs, sv = out[-4:]
        sw_names = [n for n in init.names if init.roles[n] == "step_w"]
        assert gw.shape == (len(sw_names),)
        by = dict(zip(init.names, init.params))
        np.testing.assert_allclose(
            sv, jnp.stack([by[n] for n in sw_names]), rtol=1e-6
        )
        assert (np.asarray(wn) > 0).all()
        assert (np.asarray(gs) >= 0).all()
