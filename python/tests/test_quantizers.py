"""L2 quantizer-library tests: baseline gradient variants, gradscale,
Appendix-B helper functions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantizers as Q
from compile.kernels import ref


class TestAppendixB:
    def test_gradscale_forward_identity(self):
        x = jnp.asarray([1.0, -2.0, 3.0])
        np.testing.assert_allclose(Q.gradscale(x, 0.25), x)

    def test_gradscale_backward_scales(self):
        g = jax.grad(lambda x: jnp.sum(Q.gradscale(x, 0.25)))(
            jnp.asarray([1.0, 2.0])
        )
        np.testing.assert_allclose(g, [0.25, 0.25])

    def test_roundpass_forward_rounds(self):
        x = jnp.asarray([0.4, 0.6, -1.5])
        np.testing.assert_allclose(Q.roundpass(x), jnp.round(x))

    def test_roundpass_backward_is_identity(self):
        g = jax.grad(lambda x: jnp.sum(Q.roundpass(x)))(jnp.asarray([0.4, 2.7]))
        np.testing.assert_allclose(g, [1.0, 1.0])


class TestGradScaleValue:
    def test_full(self):
        assert Q.gradscale_value(100, 4, "full") == pytest.approx(0.05)

    def test_sqrtn(self):
        assert Q.gradscale_value(100, 4, "sqrtn") == pytest.approx(0.1)

    def test_one(self):
        assert Q.gradscale_value(100, 4, "one") == 1.0

    def test_x10_d10(self):
        g = Q.gradscale_value(100, 4, "full")
        assert Q.gradscale_value(100, 4, "x10") == pytest.approx(10 * g)
        assert Q.gradscale_value(100, 4, "d10") == pytest.approx(g / 10)

    def test_unknown_raises(self):
        with pytest.raises(ValueError):
            Q.gradscale_value(10, 3, "bogus")


class TestVariantForwardsAgree:
    """Every method shares the identical forward (Eqs. 1-2)."""

    @settings(max_examples=20, deadline=None)
    @given(
        method=st.sampled_from(["lsq", "lsq_jnp", "qil", "pact", "fixed"]),
        bits=st.sampled_from([2, 3, 4]),
        seed=st.integers(0, 1000),
    )
    def test_forward(self, method, bits, seed):
        v = jnp.asarray(
            np.random.default_rng(seed).normal(size=(200,)).astype(np.float32)
        )
        s = jnp.float32(0.2)
        cfg = Q.QuantConfig(bits=bits, signed=True, method=method)
        qn, qp = cfg.qrange()
        got = Q.quantize(v, s, cfg, v.size)
        np.testing.assert_allclose(
            got, ref.quantize(v, s, qn, qp), rtol=1e-5, atol=1e-6
        )


class TestVariantGradients:
    def _gs(self, method, v):
        cfg = Q.QuantConfig(bits=2, signed=False, method=method,
                            gscale_mode="one")
        return jax.grad(
            lambda s: jnp.sum(Q.quantize(v, s, cfg, v.size))
        )(jnp.float32(1.0))

    def test_pact_zero_inside_domain(self):
        v = jnp.asarray([0.4, 1.2, 2.6], jnp.float32)  # all < Qp=3
        assert float(self._gs("pact", v)) == pytest.approx(0.0)

    def test_pact_qp_beyond_clip(self):
        v = jnp.asarray([5.0], jnp.float32)
        assert float(self._gs("pact", v)) == pytest.approx(3.0)

    def test_qil_linear_inside(self):
        ga = self._gs("qil", jnp.asarray([1.0], jnp.float32))
        gb = self._gs("qil", jnp.asarray([2.0], jnp.float32))
        assert float(gb) == pytest.approx(2 * float(ga))

    def test_fixed_no_gradient(self):
        v = jnp.asarray([0.3, 1.7, 9.0], jnp.float32)
        assert float(self._gs("fixed", v)) == 0.0

    def test_lsq_transition_sawtooth(self):
        """LSQ's ds flips sign across a transition point; QIL's does not."""
        lo = self._gs("lsq_jnp", jnp.asarray([1.45], jnp.float32))
        hi = self._gs("lsq_jnp", jnp.asarray([1.55], jnp.float32))
        assert float(lo) < 0 < float(hi)
        qlo = self._gs("qil", jnp.asarray([1.45], jnp.float32))
        qhi = self._gs("qil", jnp.asarray([1.55], jnp.float32))
        assert float(qlo) > 0 and float(qhi) > 0

    def test_all_methods_share_ste_data_grad(self):
        v = jnp.asarray([0.4, 3.8], jnp.float32)
        for m in ("lsq", "lsq_jnp", "qil", "pact", "fixed"):
            cfg = Q.QuantConfig(bits=2, signed=False, method=m)
            gv = jax.grad(
                lambda v_: jnp.sum(Q.quantize(v_, jnp.float32(1.0), cfg, 2))
            )(v)
            np.testing.assert_allclose(gv, [1.0, 0.0], atol=1e-6)


class TestConfig:
    def test_disabled_is_identity(self):
        v = jnp.asarray([0.123, -4.5])
        cfg = Q.QuantConfig(bits=32)
        assert Q.quantize(v, jnp.float32(1.0), cfg, 2) is v

    def test_none_method_identity(self):
        v = jnp.asarray([0.123])
        cfg = Q.QuantConfig(bits=2, method="none")
        assert Q.quantize(v, jnp.float32(1.0), cfg, 1) is v

    def test_unknown_method_raises(self):
        cfg = Q.QuantConfig(bits=2, method="wat")
        with pytest.raises(ValueError):
            Q.quantize(jnp.asarray([1.0]), jnp.float32(1.0), cfg, 1)

    def test_with_bits(self):
        assert Q.QuantConfig(bits=2).with_bits(8).bits == 8
