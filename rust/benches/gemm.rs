//! Kernel-layer GEMM benchmarks (EXPERIMENTS.md §Perf L1): GFLOP/s for the
//! quantized GEMM at every packed width — fused-unpack vs panelized
//! weights, SIMD-dispatched vs scalar-forced, single-thread vs the
//! hardware thread count — plus the fp32 `sgemm`. This is the
//! before/after receipt for the SIMD + panelization work: every row
//! carries self-describing ratio columns (`speedup_vs_serial`,
//! `panel_vs_fused`, `simd_vs_scalar`) so the trajectory JSON needs no
//! hand-diffing, and the suite records the dispatch level it ran at
//! (`simd` field; `LSQNET_FORCE_SCALAR=1` pins the portable path — the CI
//! smoke runs both sides). Because several rows pin a level *in-process*
//! (scalar references, the VNNI-vs-AVX2 ladder comparison), every row
//! also carries its own `simd` string column — the *effective* level it
//! ran at — so no row can masquerade as the suite default.
//!
//! Ladder/autotuner receipt columns (each present only where the feature
//! is, degrading gracefully on hosts without it): `vnni_vs_avx2` (the
//! dpwssd rung vs a forced-AVX2 run of the same panel GEMM),
//! `tuned_vs_default` (autotuned [`PanelGeom`] vs the legacy constants —
//! row emitted only when tuning picked a non-default geometry), and
//! `fma_vs_pinned` (the sgemm FMA tier vs the deterministic pinned
//! reference).
//!
//! Writes the machine-readable perf-trajectory file
//! `BENCH_native_gemm.json` at the repository root (regenerate with
//! `cargo bench --bench gemm`). Under `LSQNET_BENCH_FAST=1` (the CI
//! smoke) shapes shrink, so output goes to
//! `rust/target/BENCH_native_gemm_fast.json` — it neither clobbers the
//! full-run trajectory nor dirties the working tree. Units are FLOPs
//! (2·m·k·n per call), so `units_per_sec` is FLOP/s.
//!
//! The threaded rows are labeled `t{effective width}` — `LSQNET_THREADS`
//! caps them too (and the label reflects it), so run without that env to
//! measure real hardware scaling.

use std::path::Path;

use lsqnet::quant::pack::quantize_and_pack;
use lsqnet::runtime::kernels::{
    hardware_threads, qgemm, qgemm_panel, sgemm, FpMode, PanelGeom, PanelizedWeights, SimdLevel,
    Workspace, QGEMM_MIN_ROWS_PER_THREAD,
};
use lsqnet::util::bench::{black_box, Bench};
use lsqnet::util::rng::Pcg32;

/// Bench widths for one kernel: `[1]` when the effective width collapses
/// to serial (single core, `LSQNET_THREADS=1`, or a kernel-side floor),
/// else `[1, width]` — never two identical rows in the trajectory JSON.
fn widths(effective: usize) -> Vec<usize> {
    if effective > 1 {
        vec![1, effective]
    } else {
        vec![1]
    }
}

fn main() {
    let fast = lsqnet::util::env_truthy("LSQNET_BENCH_FAST");
    let forced_scalar = lsqnet::util::env_truthy("LSQNET_FORCE_SCALAR");
    let (m, k, n) = if fast {
        (128usize, 256usize, 128usize)
    } else {
        (256, 512, 256)
    };
    let flops = (2 * m * k * n) as f64;
    // Effective parallel width: hardware, capped by LSQNET_THREADS. The
    // "tN" rows are labeled with this number so the JSON is
    // self-describing — an env-capped run can never masquerade as
    // full-hardware scaling.
    let hw = hardware_threads();
    let nt = Workspace::new().threads();
    if nt < hw {
        println!("note: LSQNET_THREADS caps intra-op width at {nt} (hardware {hw})");
    }
    let simd = SimdLevel::detect();
    println!("simd dispatch: {} (LSQNET_FORCE_SCALAR pins scalar)", simd.name());
    let mut b = Bench::new("native_gemm");
    b.set_meta("simd", simd.name());

    // Activations on the unsigned Eq. 1 grid, mostly nonzero. (The SIMD
    // panel kernels compute every lane unconditionally — only the fp32
    // sgemm/sgemm_tn paths still skip zero activations — so sparsity
    // would only flatter the sgemm rows.)
    let mut rng = Pcg32::seeded(4);

    let mut summary: Vec<(String, &'static str, f64)> = Vec::new();
    for bits in [2u32, 3, 4, 8] {
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, bits, true).unwrap();
        let panels = PanelizedWeights::build(&packed, k, n);
        let (_, qp) = lsqnet::quant::lsq::qrange(bits, false);
        let x: Vec<i32> = (0..m * k).map(|_| 1 + rng.below(qp as u32) as i32).collect();
        let mut out = vec![0.0f32; m * n];

        // Fused mode additionally floors rows-per-thread, so label with
        // the width the kernel will actually run, not the workspace cap.
        let qt = nt.min((m / QGEMM_MIN_ROWS_PER_THREAD).max(1));
        let mut fused = Vec::new();
        for threads in widths(qt) {
            let mut ws = Workspace::with_threads(threads);
            let name = format!("qgemm_{bits}bit_{m}x{k}x{n}_fused_t{threads}");
            let r = b.bench_units(&name, flops, || {
                let p = black_box(&packed);
                qgemm(&mut ws, m, k, n, black_box(&x), p, 0.01, None, &mut out);
                black_box(&out);
            });
            b.annotate_str(&name, "simd", ws.simd().name());
            fused.push((name, r.throughput()));
        }
        if fused.len() == 2 {
            let s = fused[1].1 / fused[0].1;
            b.annotate(&fused[1].0, "speedup_vs_serial", s);
            summary.push((format!("qgemm_{bits}bit fused"), "threaded/serial", s));
        }

        // Panelized weights have no per-thread unpack, hence no rows
        // floor: the full workspace width applies.
        let mut panel = Vec::new();
        for threads in widths(nt) {
            let mut ws = Workspace::with_threads(threads);
            let name = format!("qgemm_{bits}bit_{m}x{k}x{n}_panel_t{threads}");
            let r = b.bench_units(&name, flops, || {
                let pw = black_box(&panels);
                qgemm_panel(&mut ws, m, k, n, black_box(&x), pw, 0.01, None, &mut out);
                black_box(&out);
            });
            b.annotate_str(&name, "simd", ws.simd().name());
            panel.push((name, r.throughput()));
        }
        if panel.len() == 2 {
            let s = panel[1].1 / panel[0].1;
            b.annotate(&panel[1].0, "speedup_vs_serial", s);
            summary.push((format!("qgemm_{bits}bit panel"), "threaded/serial", s));
        }
        // panelized-vs-fused at matched widths (serial row always exists;
        // the threaded rows may have different effective widths, so only
        // compare when they match).
        b.annotate(&panel[0].0, "panel_vs_fused", panel[0].1 / fused[0].1);
        summary.push((
            format!("qgemm_{bits}bit"),
            "panel/fused (t1)",
            panel[0].1 / fused[0].1,
        ));
        if panel.len() == 2 && fused.len() == 2 && qt == nt {
            b.annotate(&panel[1].0, "panel_vs_fused", panel[1].1 / fused[1].1);
        }

        // Scalar-forced reference row (fused, serial — comparable to the
        // pre-SIMD scalar baseline), feeding the simd_vs_scalar column on
        // the dispatched rows. Skipped when the whole run is already
        // scalar-pinned.
        if simd != SimdLevel::Scalar {
            let mut ws = Workspace::with_threads(1);
            ws.force_scalar();
            let name = format!("qgemm_{bits}bit_{m}x{k}x{n}_fused_t1_scalar");
            let r = b.bench_units(&name, flops, || {
                let p = black_box(&packed);
                qgemm(&mut ws, m, k, n, black_box(&x), p, 0.01, None, &mut out);
                black_box(&out);
            });
            b.annotate_str(&name, "simd", ws.simd().name());
            let s = fused[0].1 / r.throughput();
            b.annotate(&fused[0].0, "simd_vs_scalar", s);
            summary.push((format!("qgemm_{bits}bit fused t1"), "simd/scalar", s));
        }

        // Ladder-step comparison: when the host dispatches the VNNI rung,
        // re-run the serial panel GEMM pinned one rung down (AVX2) so the
        // trajectory carries the dpwssd-vs-pmaddwd delta. Absent on hosts
        // without VNNI — the column simply does not appear.
        if simd == SimdLevel::Avx512Vnni {
            let mut ws = Workspace::with_threads(1);
            if ws.force_level(SimdLevel::Avx2) {
                let name = format!("qgemm_{bits}bit_{m}x{k}x{n}_panel_t1_avx2");
                let r = b.bench_units(&name, flops, || {
                    let pw = black_box(&panels);
                    qgemm_panel(&mut ws, m, k, n, black_box(&x), pw, 0.01, None, &mut out);
                    black_box(&out);
                });
                b.annotate_str(&name, "simd", ws.simd().name());
                let s = panel[0].1 / r.throughput();
                b.annotate(&panel[0].0, "vnni_vs_avx2", s);
                summary.push((format!("qgemm_{bits}bit panel t1"), "vnni/avx2", s));
            }
        }

        // Autotuner receipt: rebuild the panels through the bind-time
        // tuner (the activation bound is the row max, same as bind) and
        // time the winner against the default-geometry row. Emitted only
        // when tuning picked a non-default blocking; `LSQNET_NO_TUNE=1`
        // (or a default-geometry win) degrades to no extra row.
        let tuned = PanelizedWeights::build_for_acts(&packed, k, n, qp);
        if tuned.geom() != PanelGeom::DEFAULT {
            let mut ws = Workspace::with_threads(1);
            let name = format!("qgemm_{bits}bit_{m}x{k}x{n}_panel_t1_tuned");
            let r = b.bench_units(&name, flops, || {
                let pw = black_box(&tuned);
                qgemm_panel(&mut ws, m, k, n, black_box(&x), pw, 0.01, None, &mut out);
                black_box(&out);
            });
            b.annotate_str(&name, "simd", ws.simd().name());
            let g = tuned.geom();
            b.annotate_str(&name, "geom", &format!("kc{}_nc{}_nr{}_ki{}", g.kc, g.nc, g.nr, g.ki));
            let s = r.throughput() / panel[0].1;
            b.annotate(&name, "tuned_vs_default", s);
            summary.push((format!("qgemm_{bits}bit panel t1"), "tuned/default", s));
        }
    }

    // fp32 reference: the fake-quant training matmul / bits>=32 layers.
    let xf: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let wf: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let mut out = vec![0.0f32; m * n];
    let mut srows = Vec::new();
    for threads in widths(nt) {
        let mut ws = Workspace::with_threads(threads);
        let name = format!("sgemm_{m}x{k}x{n}_t{threads}");
        let r = b.bench_units(&name, flops, || {
            sgemm(&mut ws, m, k, n, black_box(&xf), black_box(&wf), None, &mut out);
            black_box(&out);
        });
        b.annotate_str(&name, "simd", ws.simd().name());
        srows.push((name, r.throughput()));
    }
    if srows.len() == 2 {
        let s = srows[1].1 / srows[0].1;
        b.annotate(&srows[1].0, "speedup_vs_serial", s);
        summary.push(("sgemm".to_string(), "threaded/serial", s));
    }
    if simd != SimdLevel::Scalar {
        let mut ws = Workspace::with_threads(1);
        ws.force_scalar();
        let name = format!("sgemm_{m}x{k}x{n}_t1_scalar");
        let r = b.bench_units(&name, flops, || {
            sgemm(&mut ws, m, k, n, black_box(&xf), black_box(&wf), None, &mut out);
            black_box(&out);
        });
        b.annotate_str(&name, "simd", ws.simd().name());
        let s = srows[0].1 / r.throughput();
        b.annotate(&srows[0].0, "simd_vs_scalar", s);
        summary.push(("sgemm t1".to_string(), "simd/scalar", s));
    }

    // FMA-tier receipt: the serial sgemm re-run in [`FpMode::Fma`]
    // against the pinned-reassociation reference above. Skipped (column
    // absent) on hosts without FMA units — `set_fp_mode` rejects the
    // request there.
    {
        let mut ws = Workspace::with_threads(1);
        // Only meaningful when the suite rows above ran Pinned (i.e. not
        // an LSQNET_FMA=1 run, where they already are the FMA numbers).
        let was_pinned = ws.fp_mode() == FpMode::Pinned;
        ws.set_fp_mode(FpMode::Fma);
        if was_pinned && ws.fp_mode() == FpMode::Fma {
            let name = format!("sgemm_{m}x{k}x{n}_t1_fma");
            let r = b.bench_units(&name, flops, || {
                sgemm(&mut ws, m, k, n, black_box(&xf), black_box(&wf), None, &mut out);
                black_box(&out);
            });
            b.annotate_str(&name, "simd", ws.simd().name());
            let s = r.throughput() / srows[0].1;
            b.annotate(&name, "fma_vs_pinned", s);
            summary.push(("sgemm t1".to_string(), "fma/pinned", s));
        }
    }

    for (name, what, s) in &summary {
        println!("{name:<24} {what:<18} {s:.2}x");
    }

    b.finish();
    // Perf-trajectory file at the repository root (rust/ is the package
    // dir, so the repo root is its parent). Fast-mode (CI smoke) numbers
    // use smaller shapes and must not clobber the full-run trajectory or
    // dirty the working tree, so they land under target/ instead; the
    // per-entry names carry the shapes either way. LSQNET_FORCE_SCALAR-
    // pinned runs are diverted too — the trajectory tracks each host's
    // *dispatched* path, which on a non-x86 host legitimately IS the
    // portable level (the suite-level `simd` field says which).
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = if fast || forced_scalar {
        let tag = match (fast, forced_scalar) {
            (true, true) => "fast_scalar",
            (true, false) => "fast",
            _ => "scalar",
        };
        dir.join("target").join(format!("BENCH_native_gemm_{tag}.json"))
    } else {
        dir.join("..").join("BENCH_native_gemm.json")
    };
    match b.write_json(&path) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
