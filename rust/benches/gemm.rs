//! Kernel-layer GEMM benchmarks (EXPERIMENTS.md §Perf L1): GFLOP/s for the
//! fused unpack-and-dot `qgemm` at every packed width and for the fp32
//! `sgemm`, each measured single-thread and at the hardware thread count —
//! the intra-op scaling the unified kernel layer exists to deliver.
//!
//! Writes the machine-readable perf-trajectory file
//! `BENCH_native_gemm.json` at the repository root (regenerate with
//! `cargo bench --bench gemm`). Under `LSQNET_BENCH_FAST=1` (the CI
//! smoke) shapes shrink, so output goes to
//! `rust/target/BENCH_native_gemm_fast.json` — it neither clobbers the
//! full-run trajectory nor dirties the working tree. Units are FLOPs
//! (2·m·k·n per call), so `units_per_sec` is FLOP/s.
//!
//! The threaded rows are labeled `t{effective width}` — `LSQNET_THREADS`
//! caps them too (and the label reflects it), so run without that env to
//! measure real hardware scaling.

use std::path::Path;

use lsqnet::quant::pack::quantize_and_pack;
use lsqnet::runtime::kernels::{
    hardware_threads, qgemm, sgemm, Workspace, QGEMM_MIN_ROWS_PER_THREAD,
};
use lsqnet::util::bench::{black_box, Bench};
use lsqnet::util::rng::Pcg32;

/// Bench widths for one kernel: `[1]` when the effective width collapses
/// to serial (single core, `LSQNET_THREADS=1`, or a kernel-side floor),
/// else `[1, width]` — never two identical rows in the trajectory JSON.
fn widths(effective: usize) -> Vec<usize> {
    if effective > 1 {
        vec![1, effective]
    } else {
        vec![1]
    }
}

fn main() {
    let fast = std::env::var("LSQNET_BENCH_FAST").is_ok();
    let (m, k, n) = if fast {
        (128usize, 256usize, 128usize)
    } else {
        (256, 512, 256)
    };
    let flops = (2 * m * k * n) as f64;
    // Effective parallel width: hardware, capped by LSQNET_THREADS. The
    // "tN" rows are labeled with this number so the JSON is
    // self-describing — an env-capped run can never masquerade as
    // full-hardware scaling.
    let hw = hardware_threads();
    let nt = Workspace::new().threads();
    if nt < hw {
        println!("note: LSQNET_THREADS caps intra-op width at {nt} (hardware {hw})");
    }
    let mut b = Bench::new("native_gemm");

    // Activations on the unsigned Eq. 1 grid, mostly nonzero (the
    // zero-skip fast path is a workload property, not one to bench here).
    let mut rng = Pcg32::seeded(4);

    let mut speedups: Vec<(String, f64)> = Vec::new();
    for bits in [2u32, 3, 4, 8] {
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, bits, true).unwrap();
        let (_, qp) = lsqnet::quant::lsq::qrange(bits, false);
        let x: Vec<i32> = (0..m * k).map(|_| 1 + rng.below(qp as u32) as i32).collect();
        let mut out = vec![0.0f32; m * n];

        // qgemm additionally floors rows-per-thread, so label with the
        // width the kernel will actually run, not the workspace cap.
        let qt = nt.min((m / QGEMM_MIN_ROWS_PER_THREAD).max(1));
        let mut per_threads = Vec::new();
        for threads in widths(qt) {
            let mut ws = Workspace::with_threads(threads);
            let name = format!("qgemm_{bits}bit_{m}x{k}x{n}_t{threads}");
            let r = b.bench_units(&name, flops, || {
                let p = black_box(&packed);
                qgemm(&mut ws, m, k, n, black_box(&x), p, 0.01, None, &mut out);
                black_box(&out);
            });
            per_threads.push(r.throughput());
        }
        if per_threads.len() == 2 {
            speedups.push((format!("qgemm_{bits}bit"), per_threads[1] / per_threads[0]));
        }
    }

    // fp32 reference: the fake-quant training matmul / bits>=32 layers.
    let xf: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let wf: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let mut out = vec![0.0f32; m * n];
    let mut per_threads = Vec::new();
    for threads in widths(nt) {
        let mut ws = Workspace::with_threads(threads);
        let r = b.bench_units(&format!("sgemm_{m}x{k}x{n}_t{threads}"), flops, || {
            sgemm(&mut ws, m, k, n, black_box(&xf), black_box(&wf), None, &mut out);
            black_box(&out);
        });
        per_threads.push(r.throughput());
    }
    if per_threads.len() == 2 {
        speedups.push(("sgemm".to_string(), per_threads[1] / per_threads[0]));
    }

    for (name, s) in &speedups {
        println!("{name:<16} threaded speedup over 1-thread: {s:.2}x");
    }

    b.finish();
    // Perf-trajectory file at the repository root (rust/ is the package
    // dir, so the repo root is its parent). Fast-mode (CI smoke) numbers
    // use smaller shapes and must not clobber the full-run trajectory or
    // dirty the working tree, so they land under target/ instead; the
    // per-entry names carry the shapes either way.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = if fast {
        dir.join("target").join("BENCH_native_gemm_fast.json")
    } else {
        dir.join("..").join("BENCH_native_gemm.json")
    };
    match b.write_json(&path) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
