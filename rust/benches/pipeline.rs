//! Data-pipeline benchmarks: image generation, augmentation and the
//! end-to-end prefetching loader. Perf target (DESIGN.md §Perf): the loader
//! must sustain ≥2x the trainer's batch consumption rate (~5 batches/s).
//! Run: `cargo bench --bench pipeline`

use lsqnet::config::DataConfig;
use lsqnet::data::augment::augment;
use lsqnet::data::{Dataset, Loader, SynthSpec};
use lsqnet::util::bench::{black_box, Bench};
use lsqnet::util::rng::Pcg32;

fn main() {
    let mut b = Bench::new("pipeline");
    let spec = SynthSpec::new(10, 1.2, 1);
    let mut buf = vec![0.0f32; 32 * 32 * 3];
    let mut idx = 0usize;
    b.bench_units("synth_generate_1img", 1.0, || {
        idx += 1;
        spec.generate(black_box(idx), &mut buf);
        black_box(&buf);
    });

    let mut rng = Pcg32::seeded(2);
    let mut scratch = Vec::new();
    b.bench_units("augment_1img", 1.0, || {
        augment(black_box(&mut buf), &mut scratch, &mut rng);
    });

    let cfg = DataConfig { train_size: 4096, test_size: 256, ..Default::default() };
    let ds = Dataset::train(&cfg);
    let indices: Vec<usize> = (0..64).collect();
    b.bench_units("batch_64_materialize", 64.0, || {
        black_box(ds.batch_from_indices(black_box(&indices), 64));
    });

    // End-to-end loader throughput (producer thread + channel).
    let r = b.bench_units("loader_batch64_e2e", 64.0, {
        let cfg = cfg.clone();
        let loader = std::cell::RefCell::new(Loader::spawn(&cfg, 64, usize::MAX / 2, 1, 4));
        move || {
            let b = loader.borrow().next().unwrap();
            black_box(b);
        }
    });
    let batches_per_s = 1e9 / r.mean_ns;
    println!(
        "loader sustains {batches_per_s:.1} batches/s \
         (target: >= 2x trainer consumption ~ 10/s)"
    );

    b.finish();
}
