//! Quant-substrate microbenchmarks: the pure-Rust quantizer (host-side
//! analysis path), bit packing, and the Section-3.6 error sweeps.
//! Run: `cargo bench --bench quant` (LSQNET_BENCH_FAST=1 for CI).

use lsqnet::quant::error::{sweep_min, Metric};
use lsqnet::quant::lsq::*;
use lsqnet::quant::pack;
use lsqnet::util::bench::{black_box, Bench};
use lsqnet::util::rng::Pcg32;

fn main() {
    let mut b = Bench::new("quant");
    let mut rng = Pcg32::seeded(1);
    let n = 262_144;
    let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let cot: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let (qn, qp) = qrange(2, true);

    let mut out = vec![0.0f32; n];
    b.bench_units("quantize_slice_256k", n as f64, || {
        quantize_slice(black_box(&v), 0.1, qn, qp, &mut out);
        black_box(&out);
    });

    b.bench_units("lsq_vjp_256k", n as f64, || {
        let (gv, gs) = lsq_vjp(black_box(&v), 0.1, qn, qp, 1e-3, &cot);
        black_box((gv, gs));
    });

    b.bench_units("step_init_256k", n as f64, || {
        black_box(step_init(black_box(&v), qp));
    });

    for bits in [2u32, 3, 4, 8] {
        let p = pack::quantize_and_pack(&v, 0.1, bits, true).unwrap();
        b.bench_units(&format!("pack_{bits}bit_256k"), n as f64, || {
            black_box(pack::quantize_and_pack(black_box(&v), 0.1, bits, true).unwrap());
        });
        b.bench_units(&format!("unpack_{bits}bit_256k"), n as f64, || {
            black_box(pack::unpack(black_box(&p)));
        });
    }

    let small: Vec<f32> = v[..16_384].to_vec();
    for (m, name) in [(Metric::MeanAbs, "mae"), (Metric::MeanSq, "mse"), (Metric::Kl, "kl")] {
        b.bench(&format!("qerror_sweep_{name}_16k"), || {
            black_box(sweep_min(m, black_box(&small), 0.1, 2, true));
        });
    }

    b.finish();
}
