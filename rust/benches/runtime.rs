//! Runtime benchmarks: artifact compile time, per-step train/eval latency
//! per (model, precision), and the host↔device conversion overhead (the
//! driver cost the trainer pays around each XLA call).
//!
//! These are the numbers behind EXPERIMENTS.md §Perf L3 and the per-table
//! runtime budgets. Run: `cargo bench --bench runtime`

use std::path::PathBuf;

use lsqnet::data::Dataset;
use lsqnet::runtime::Engine;
use lsqnet::tensor::Tensor;
use lsqnet::util::bench::{black_box, Bench};

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    let engine = Engine::new(&artifacts()).expect("run `make artifacts` first");
    let mut b = Bench::new("runtime");
    let cfg = lsqnet::config::ExperimentConfig::default();
    let ds = Dataset::train(&cfg.data);

    // compile cost (fresh engine each iter, one artifact)
    b.bench("compile_eval_cnn_q2", || {
        let e = Engine::new(&artifacts()).unwrap();
        black_box(e.load_kind("eval", "cnn_small_q2", None, None).unwrap());
    });

    for family in ["cnn_small_q32", "cnn_small_q2", "cnn_small_q8", "resnet20_q2"] {
        let manifest = engine.manifest();
        if !manifest.families.contains_key(family) {
            continue;
        }
        let train = engine.load_kind("train", family, None, None).unwrap();
        let eval = engine.load_kind("eval", family, None, None).unwrap();
        let params = manifest.load_initial_params(family).unwrap();
        let fam = manifest.family(family).unwrap();
        let moms: Vec<Tensor> = fam
            .grad_names
            .iter()
            .map(|n| Tensor::zeros(fam.shapes.get(n).unwrap()))
            .collect();
        let batch = train.meta.batch;
        let bt = ds.batch_from_indices(&(0..batch).collect::<Vec<_>>(), batch);

        let mut train_inputs: Vec<Tensor> = params.clone();
        train_inputs.extend(moms.iter().cloned());
        train_inputs.push(bt.x.clone());
        train_inputs.push(bt.y.clone());
        train_inputs.push(Tensor::scalar_f32(0.01));
        train_inputs.push(Tensor::scalar_f32(1e-4));
        // warmup happens inside bench(); batch=64 => units=64 images
        b.bench_units(&format!("train_step_{family}_b{batch}"), batch as f64, || {
            black_box(train.run(black_box(&train_inputs)).unwrap());
        });

        let mut eval_inputs: Vec<Tensor> = params.clone();
        eval_inputs.push(bt.x.clone());
        eval_inputs.push(bt.y.clone());
        b.bench_units(&format!("eval_step_{family}_b{batch}"), batch as f64, || {
            black_box(eval.run(black_box(&eval_inputs)).unwrap());
        });
    }

    // driver-side conversion overhead: tensor -> literal -> tensor for the
    // largest input (the image batch).
    let big = ds.batch_from_indices(&(0..64).collect::<Vec<_>>(), 64);
    b.bench_units("host_tensor_clone_batch", (64 * 32 * 32 * 3) as f64, || {
        black_box(big.x.clone());
    });

    b.finish();
}
