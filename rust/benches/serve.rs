//! Serving-path benchmarks: dynamic-batcher latency/throughput under
//! closed-loop load, batching overhead vs direct artifact execution, and
//! the Figure-1 int-matmul kernel. Run: `cargo bench --bench serve`

use std::path::PathBuf;
use std::time::Duration;

use lsqnet::data::SynthSpec;
use lsqnet::runtime::Engine;
use lsqnet::serve::{Server, ServerConfig};
use lsqnet::tensor::Tensor;
use lsqnet::util::bench::{black_box, Bench};
use lsqnet::util::stats::percentile;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    let mut b = Bench::new("serve");
    let engine = Engine::new(&artifacts()).expect("run `make artifacts` first");
    let spec = SynthSpec::new(10, 1.2, 9);

    // direct (unbatched-path) infer artifact execution as the baseline
    let infer = engine.load_kind("infer", "cnn_small_q2", None, None).unwrap();
    let params = engine.manifest().load_initial_params("cnn_small_q2").unwrap();
    let batch = infer.meta.batch;
    let mut x = Vec::new();
    for i in 0..batch {
        x.extend(spec.generate_alloc(i));
    }
    let mut inputs = params.clone();
    inputs.push(Tensor::from_f32(&[batch, 32, 32, 3], x));
    let direct = b.bench_units(&format!("infer_direct_b{batch}"), batch as f64, || {
        black_box(infer.run(black_box(&inputs)).unwrap());
    });

    // server under closed-loop load from 4 threads
    let server = Server::start(ServerConfig {
        artifacts_dir: artifacts(),
        family: "cnn_small_q2".into(),
        checkpoint: String::new(),
        max_wait: Duration::from_millis(2),
        queue_depth: 256,
    })
    .unwrap();
    let n = if std::env::var("LSQNET_BENCH_FAST").is_ok() { 128 } else { 512 };
    // Warm the serve thread (engine + artifact compile) before timing.
    server.client.infer(spec.generate_alloc(0)).unwrap();
    let t0 = std::time::Instant::now();
    let mut lats: Vec<f64> = Vec::new();
    std::thread::scope(|s| {
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let c = server.client.clone();
                let spec = &spec;
                s.spawn(move || {
                    (0..n / 4)
                        .map(|i| c.infer(spec.generate_alloc(t * 999 + i)).unwrap().total_ms)
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in hs {
            lats.extend(h.join().unwrap());
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    server.stop();
    let p50 = percentile(&lats, 50.0);
    let p95 = percentile(&lats, 95.0);
    println!(
        "serve/dynamic_batcher            {n} reqs  {:.1} req/s  p50 {p50:.2} ms  p95 {p95:.2} ms  occupancy {:.2}",
        n as f64 / wall,
        stats.mean_occupancy()
    );
    // batching overhead = p50 latency - per-batch exec time
    let direct_ms = direct.mean_ns / 1e6;
    println!(
        "serve/batching_overhead_p50      {:.2} ms (target < 1 ms + exec {:.2} ms)",
        (p50 - stats.mean_exec_ms()).max(0.0),
        direct_ms
    );

    // Figure-1 int matmul artifact
    if let Some(qmm) = engine
        .manifest()
        .artifacts
        .values()
        .find(|a| a.kind == "qmm")
        .map(|a| a.id.clone())
    {
        let exe = engine.load(&qmm).unwrap();
        let (m, k) = (exe.meta.inputs[0].shape[0], exe.meta.inputs[0].shape[1]);
        let nn = exe.meta.inputs[1].shape[1];
        let mut rng = lsqnet::util::rng::Pcg32::seeded(4);
        let xb: Vec<i32> = (0..m * k).map(|_| rng.below(15) as i32 - 7).collect();
        let wb: Vec<i32> = (0..k * nn).map(|_| rng.below(15) as i32 - 7).collect();
        let args = [
            Tensor::from_i32(&[m, k], xb),
            Tensor::from_i32(&[k, nn], wb),
            Tensor::scalar_f32(0.1),
            Tensor::scalar_f32(0.1),
        ];
        b.bench_units(&format!("qmm_{m}x{k}x{nn}"), (m * k * nn) as f64, || {
            black_box(exe.run(black_box(&args)).unwrap());
        });
    }

    b.finish();
}
