//! Serving-path benchmarks on the native packed-weight backend:
//! dynamic-batcher latency/throughput under closed-loop load with multiple
//! engine replicas, batching overhead vs direct engine execution, and the
//! Figure-1 fused unpack-and-dot integer GEMM. Runs with zero Python/XLA
//! setup (the synthetic fixture provides manifest + params); the XLA
//! numbers live in `benches/runtime.rs` (`--features xla`).
//!
//! Run: `cargo bench --bench serve` (LSQNET_BENCH_FAST=1 for CI).
//! These are the EXPERIMENTS.md §Perf L3 serving rows.

use std::time::Duration;

use lsqnet::data::SynthSpec;
use lsqnet::quant::pack::quantize_and_pack;
use lsqnet::runtime::kernels::{qgemm, Workspace};
use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::{Backend, BackendSpec};
use lsqnet::serve::{Server, ServerConfig};
use lsqnet::util::bench::{black_box, Bench};
use lsqnet::util::rng::Pcg32;
use lsqnet::util::stats::percentile;

const REPLICAS: usize = 2;

fn main() {
    let mut b = Bench::new("serve");
    let fast = lsqnet::util::env_truthy("LSQNET_BENCH_FAST");

    // Synthetic 2-bit cnn_small family, real 32x32x3 geometry.
    let dir = std::env::temp_dir().join(format!("lsq_serve_bench_{}", std::process::id()));
    let fixture = FixtureSpec { image: 32, channels: 3, num_classes: 10, batch: 8, seed: 42 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, fixture)
        .expect("write synthetic family");
    let spec = SynthSpec::new(10, 1.2, 9);

    // -- direct engine execution as the no-batcher baseline ------------------
    let mut backend = BackendSpec::native(&dir).open().unwrap();
    let params = backend.manifest().load_initial_params(&family).unwrap();
    backend.prepare_infer(&family, &params).unwrap();
    let batch = backend.batch();
    let image_len = 32 * 32 * 3;
    let mut x = Vec::with_capacity(batch * image_len);
    for i in 0..batch {
        x.extend(spec.generate_alloc(i));
    }
    let direct = b.bench_units(&format!("native_infer_direct_b{batch}"), batch as f64, || {
        black_box(backend.infer(black_box(&x)).unwrap());
    });
    drop(backend);

    // -- server under closed-loop load, REPLICAS native engine replicas ------
    let server = Server::start(ServerConfig {
        backend: BackendSpec::native(&dir),
        family: family.clone(),
        checkpoint: String::new(),
        max_wait: Duration::from_millis(2),
        queue_depth: 256,
        replicas: REPLICAS,
        intra_threads: 0,
        fused_unpack: false,
    })
    .unwrap();
    let n = if fast { 128 } else { 512 };
    // Warm every replica path before timing.
    server.client().infer(spec.generate_alloc(0)).unwrap();
    let t0 = std::time::Instant::now();
    let mut lats: Vec<f64> = Vec::new();
    std::thread::scope(|s| {
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let c = server.client();
                let spec = &spec;
                s.spawn(move || {
                    (0..n / 4)
                        .map(|i| c.infer(spec.generate_alloc(t * 999 + i)).unwrap().total_ms)
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in hs {
            lats.extend(h.join().unwrap());
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    server.stop();
    let p50 = percentile(&lats, 50.0);
    let p95 = percentile(&lats, 95.0);
    println!(
        "serve/dynamic_batcher_x{REPLICAS}        {n} reqs  {:.1} req/s  p50 {p50:.2} ms  \
         p95 {p95:.2} ms  occupancy {:.2}  ({} batches)",
        n as f64 / wall,
        stats.mean_occupancy(),
        stats.batches,
    );
    // batching overhead = p50 latency - per-batch exec time
    let direct_ms = direct.mean_ns / 1e6;
    println!(
        "serve/batching_overhead_p50      {:.2} ms (target < 1 ms + exec {:.2} ms)",
        (p50 - stats.mean_exec_ms()).max(0.0),
        direct_ms
    );

    // -- Figure-1 int matmul: the fused unpack-and-dot kernel ----------------
    // Single-thread rows (the historical L1 baseline); the threaded scaling
    // story lives in `benches/gemm.rs` / BENCH_native_gemm.json.
    let (m, k, nn) = if fast { (64, 256, 128) } else { (128, 512, 256) };
    let mut rng = Pcg32::seeded(4);
    let mut ws = Workspace::with_threads(1);
    for bits in [2u32, 4, 8] {
        let w: Vec<f32> = (0..k * nn).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, bits, true).unwrap();
        let (_, qp) = lsqnet::quant::lsq::qrange(bits, false);
        let xb: Vec<i32> = (0..m * k).map(|_| (rng.below(qp as u32 + 1)) as i32).collect();
        let mut out = vec![0.0f32; m * nn];
        b.bench_units(&format!("qgemm_{bits}bit_{m}x{k}x{nn}"), (m * k * nn) as f64, || {
            qgemm(&mut ws, m, k, nn, black_box(&xb), black_box(&packed), 0.01, None, &mut out);
            black_box(&out);
        });
    }

    b.finish();
    std::fs::remove_dir_all(&dir).ok();
}
