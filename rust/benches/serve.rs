//! Serving-path benchmarks on the native packed-weight backend:
//! dynamic-batcher latency/throughput under closed-loop load with multiple
//! engine replicas, per-variant latency through a two-precision
//! [`ModelRegistry`], batching overhead vs direct engine execution, the
//! TCP wire protocol over loopback (closed-loop `net_infer` rows plus an
//! open-loop network load generator reporting p50/p99/p999 per variant),
//! the SLO tier controller driven by a deterministic burst/ramp/sine
//! traffic schedule (per-epoch rows + the `tier_shift_*` decision trace),
//! the fleet cold-start ladder (manifest bind vs instant `.lsqa` artifact
//! bind, with panel-build counters), and the Figure-1 fused
//! unpack-and-dot integer GEMM. Runs with zero
//! Python/XLA setup (the synthetic fixture provides manifest + params);
//! the XLA numbers live in `benches/runtime.rs` (`--features xla`).
//!
//! Run: `cargo bench --bench serve` (LSQNET_BENCH_FAST=1 for CI). Writes
//! the machine-readable perf-trajectory file `BENCH_serve.json` at the
//! repository root (fast mode diverts to `rust/target/BENCH_serve_fast.json`
//! so CI smoke numbers never clobber the trajectory or dirty the tree).
//! These are the EXPERIMENTS.md §Perf L3 serving rows.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lsqnet::data::SynthSpec;
use lsqnet::quant::pack::quantize_and_pack;
use lsqnet::runtime::artifact::writer::default_levels;
use lsqnet::runtime::kernels::{panel_build_count, qgemm, Workspace};
use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::native::NativeEngine;
use lsqnet::runtime::{pack_family, Backend, BackendSpec, LoadedArtifact, Manifest, PrepareOptions};
use lsqnet::serve::net::{NetClient, NetServer};
use lsqnet::serve::tier::trace_to_bench;
use lsqnet::serve::{ModelRegistry, ServeStats, TierConfig, TierController, TierDecision, VariantOptions};
use lsqnet::util::bench::{black_box, Bench};
use lsqnet::util::rng::Pcg32;
use lsqnet::util::stats::percentile;

const REPLICAS: usize = 2;

/// Attach a variant's serve-stats columns to the bench row `name`.
fn annotate_stats(b: &mut Bench, name: &str, stats: &ServeStats) {
    b.annotate(name, "occupancy", stats.mean_occupancy());
    b.annotate(name, "mean_exec_ms", stats.mean_exec_ms());
    b.annotate(name, "mean_queue_ms", stats.mean_queue_ms());
    b.annotate(name, "padding_rows", stats.padding_rows as f64);
    b.annotate(name, "requests", stats.requests as f64);
    b.annotate(name, "batches", stats.batches as f64);
}

fn main() {
    let mut b = Bench::new("serve");
    let fast = lsqnet::util::env_truthy("LSQNET_BENCH_FAST");

    // Synthetic cnn_small family at two precisions, real 32x32x3 geometry,
    // merged into one manifest (the multi-variant deployment shape).
    let dir = std::env::temp_dir().join(format!("lsq_serve_bench_{}", std::process::id()));
    let fixture = FixtureSpec { image: 32, channels: 3, num_classes: 10, batch: 8, seed: 42 };
    let fam_q2 = write_synthetic_family(&dir, "cnn_small", 2, fixture)
        .expect("write synthetic q2 family");
    let fam_q4 = write_synthetic_family(&dir, "cnn_small", 4, fixture)
        .expect("write synthetic q4 family");
    let spec = SynthSpec::new(10, 1.2, 9);

    // -- direct engine execution as the no-batcher baseline ------------------
    let mut backend = BackendSpec::native(&dir).open().unwrap();
    let params = backend.manifest().load_initial_params(&fam_q2).unwrap();
    backend.prepare_infer(&fam_q2, &params, &PrepareOptions::new()).unwrap();
    let batch = backend.batch();
    let image_len = 32 * 32 * 3;
    let mut x = Vec::with_capacity(batch * image_len);
    for i in 0..batch {
        x.extend(spec.generate_alloc(i));
    }
    let direct = b.bench_units(&format!("native_infer_direct_b{batch}"), batch as f64, || {
        black_box(backend.infer(black_box(&x)).unwrap());
    });
    drop(backend);

    // -- two-precision registry: per-variant closed-loop latency rows --------
    let registry = ModelRegistry::open(BackendSpec::native(&dir));
    let opts = VariantOptions {
        replicas: REPLICAS,
        max_wait: Duration::from_millis(2),
        queue_depth: 256,
        ..VariantOptions::default()
    };
    registry.load(&fam_q2, &opts).unwrap();
    registry.load(&fam_q4, &opts).unwrap();
    for family in [&fam_q2, &fam_q4] {
        let session = registry.session(family).unwrap();
        // Warm the replicas, then measure single-stream request latency
        // through the whole submit→batch→execute→reply path.
        session.infer(spec.generate_alloc(0)).unwrap();
        let before = session.stats();
        let mut i = 0usize;
        let row = format!("registry_infer_{family}_x{REPLICAS}");
        b.bench(&row, || {
            i += 1;
            black_box(session.infer(spec.generate_alloc(i)).unwrap());
        });
        let window = session.stats().delta_since(&before);
        annotate_stats(&mut b, &row, &window);
    }

    // -- open-loop burst across both variants (round-robin sessions) ---------
    let n = if fast { 128 } else { 512 };
    let t0 = std::time::Instant::now();
    let mut lats: Vec<f64> = Vec::new();
    std::thread::scope(|s| {
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let sessions =
                    [registry.session(&fam_q2).unwrap(), registry.session(&fam_q4).unwrap()];
                let spec = &spec;
                s.spawn(move || {
                    (0..n / 4)
                        .map(|i| {
                            let sess = &sessions[i % 2];
                            sess.infer(spec.generate_alloc(t * 999 + i)).unwrap().total_ms
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in hs {
            lats.extend(h.join().unwrap());
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let all_stats = registry.shutdown();
    let p50 = percentile(&lats, 50.0);
    let p95 = percentile(&lats, 95.0);
    println!(
        "serve/registry_round_robin_x{REPLICAS}   {n} reqs over 2 variants  {:.1} req/s  \
         p50 {p50:.2} ms  p95 {p95:.2} ms",
        n as f64 / wall,
    );
    for (name, stats) in &all_stats {
        println!(
            "  {name:<22} {:>5} reqs  occupancy {:.2}  exec {:.2} ms/batch  queue {:.2} ms/req",
            stats.requests,
            stats.mean_occupancy(),
            stats.mean_exec_ms(),
            stats.mean_queue_ms(),
        );
    }
    // batching overhead = p50 latency - per-batch exec time
    let direct_ms = direct.mean_ns / 1e6;
    let mean_exec =
        all_stats.values().map(|s| s.mean_exec_ms()).sum::<f64>() / all_stats.len().max(1) as f64;
    println!(
        "serve/batching_overhead_p50      {:.2} ms (target < 1 ms + exec {:.2} ms)",
        (p50 - mean_exec).max(0.0),
        direct_ms
    );

    // -- the TCP wire protocol over loopback ---------------------------------
    // Closed-loop single-stream latency per variant (framing + JSON + TCP
    // on top of the registry path), then an open-loop generator: a paced
    // sender decoupled from a receiver, so arrival cadence never couples
    // to response latency — the tail percentiles (p99/p999) are the whole
    // point of measuring open-loop.
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    registry.load(&fam_q2, &opts).unwrap();
    registry.load(&fam_q4, &opts).unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    for family in [&fam_q2, &fam_q4] {
        let mut client = NetClient::connect(addr).unwrap();
        client.infer(family, &spec.generate_alloc(0)).unwrap(); // warm
        let mut i = 0usize;
        let row = format!("net_infer_{family}_x{REPLICAS}");
        let closed = b.bench(&row, || {
            i += 1;
            black_box(client.infer(family, &spec.generate_alloc(i)).unwrap());
        });

        // Offer load at ~80% of the measured single-stream capacity; the
        // replicas have headroom, so the queue stays shallow and the tail
        // reflects jitter, not saturation.
        let interval = Duration::from_nanos((closed.mean_ns * 1.25) as u64);
        let n_open = if fast { 96 } else { 384 };
        let (mut tx, mut rx) = NetClient::connect(addr).unwrap().split().unwrap();
        let (stamp_tx, stamp_rx) = std::sync::mpsc::channel::<Instant>();
        let fam = (*family).clone();
        let img = spec.generate_alloc(7);
        let sender = std::thread::spawn(move || {
            let start = Instant::now();
            for j in 0..n_open {
                let due = start + interval * j as u32;
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                stamp_tx.send(Instant::now()).unwrap();
                if tx.send_infer(&fam, &img).is_err() {
                    break;
                }
            }
            tx.finish();
        });
        // FIFO pairing: response j belongs to send stamp j (one model per
        // connection, responses in request order). Error responses still
        // consume their stamp so the pairing never skews.
        let mut lat_ns: Vec<f64> = Vec::with_capacity(n_open);
        for _ in 0..n_open {
            let resp = match rx.recv() {
                Ok(r) => r,
                Err(_) => break,
            };
            let sent = match stamp_rx.recv() {
                Ok(s) => s,
                Err(_) => break,
            };
            if resp.body.is_ok() {
                lat_ns.push(sent.elapsed().as_nanos() as f64);
            }
        }
        sender.join().unwrap();
        let open_row = format!("net_open_loop_{family}_x{REPLICAS}");
        b.record_ns(&open_row, &lat_ns, 1.0);
        b.annotate(&open_row, "p99_ms", percentile(&lat_ns, 99.0) / 1e6);
        b.annotate(&open_row, "p999_ms", percentile(&lat_ns, 99.9) / 1e6);
        b.annotate(&open_row, "offered_rps", 1e9 / interval.as_nanos().max(1) as f64);
        b.annotate(&open_row, "answered", lat_ns.len() as f64);
    }
    server.stop();
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }

    // -- SLO tier controller under a burst/ramp/sine schedule ----------------
    // Real traffic through a real controller: every epoch offers a
    // deterministic number of requests open-loop through
    // `TierController::route` (so queueing actually builds on the single
    // replica), drains the replies, then runs one control step. The
    // decision trace lands in BENCH_serve.json as `tier_shift_*` rows and
    // each epoch row carries offered load, active tier, controller
    // signals and the step's decision — the trajectory file tells the
    // whole sense→decide→act story.
    let fam_q8 = write_synthetic_family(&dir, "cnn_small", 8, fixture)
        .expect("write synthetic q8 family");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    let tier_opts = VariantOptions {
        replicas: 1,
        max_wait: Duration::from_millis(1),
        queue_depth: 256,
        ..VariantOptions::default()
    };
    for family in [&fam_q8, &fam_q4, &fam_q2] {
        registry.load(family, &tier_opts).unwrap();
    }
    let mut cfg = TierConfig::new(vec![fam_q8.clone(), fam_q4.clone(), fam_q2.clone()], 2.0);
    cfg.window = 2;
    let ctl = TierController::new(Arc::clone(&registry), cfg).unwrap();
    // Offered requests per epoch: burst, then ramp, then a sine-ish sweep.
    let schedule: Vec<usize> = if fast {
        vec![2, 16, 16, 2, 2, 4, 8, 12, 8, 4, 2]
    } else {
        vec![
            4, 4, 48, 48, 48, 4, 4, // burst
            8, 16, 24, 32, 40, 48, 56, // ramp
            40, 24, 8, 4, 8, 24, 40, 24, 8, 4, // sine-ish
        ]
    };
    for (k, &offered) in schedule.iter().enumerate() {
        let mut pending = Vec::with_capacity(offered);
        let mut shed = 0usize;
        for i in 0..offered {
            let img = spec.generate_alloc(1000 * (k + 1) + i);
            match ctl.route(img) {
                Ok(rx) => pending.push((Instant::now(), rx)),
                Err(_) => shed += 1,
            }
        }
        let mut lat_ns: Vec<f64> = Vec::with_capacity(pending.len());
        for (t, rx) in pending {
            if matches!(rx.recv(), Ok(Ok(_))) {
                lat_ns.push(t.elapsed().as_nanos() as f64);
            }
        }
        let tier_before = ctl.active_tier();
        let decision = ctl.step();
        let sig = ctl.last_signals();
        let row = format!("tier_epoch_{k:02}");
        b.record_ns(&row, &lat_ns, 1.0);
        b.annotate(&row, "offered", offered as f64);
        b.annotate(&row, "shed", shed as f64);
        b.annotate(&row, "tier", tier_before as f64);
        b.annotate(&row, "queue_ms", sig.get(tier_before).map_or(0.0, |s| s.queue_ms));
        let code = match decision {
            TierDecision::Hold => 0.0,
            TierDecision::Down { .. } => -1.0,
            TierDecision::Up { .. } => 1.0,
        };
        b.annotate(&row, "decision", code);
    }
    trace_to_bench(&mut b, ctl.tiers(), &ctl.trace());
    println!(
        "serve/tier_controller            {} epochs  {} shift(s)  {} shed  final tier {}",
        ctl.epochs(),
        ctl.trace().len(),
        ctl.shed_count(),
        ctl.active_tier_name(),
    );
    drop(ctl);
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }

    // -- bind_cold_vs_artifact: fleet cold-start, manifest vs .lsqa ----------
    // The two ways a serving replica can come up: open the manifest and
    // prepare (load params bin, quantize, bit-pack, panelize — per
    // replica), vs `NativeEngine::from_artifact` over one fully-verified
    // shared arena (borrow prebuilt panel tiles, zero build work). The
    // `panel_builds` annotations prove the difference is in kind: the
    // cold row builds panels every iteration, the artifact row never.
    {
        let manifest = Manifest::load(&dir).unwrap();
        let params = manifest.load_initial_params(&fam_q2).unwrap();
        let art_path = dir.join(format!("{fam_q2}.lsqa"));
        pack_family(&manifest, &fam_q2, &params, &art_path, &default_levels()).unwrap();

        let row = format!("bind_cold_manifest_{fam_q2}");
        let before = panel_build_count();
        b.bench(&row, || {
            let mut eng = BackendSpec::native(&dir).open().unwrap();
            eng.prepare_infer(&fam_q2, &params, &PrepareOptions::new()).unwrap();
            black_box(&eng);
        });
        b.annotate(&row, "panel_builds", (panel_build_count() - before) as f64);

        // Load + verify once (the per-variant cost), then per-replica bind.
        b.bench("artifact_load_verify", || {
            black_box(LoadedArtifact::load(&art_path).unwrap());
        });
        let art = Arc::new(LoadedArtifact::load(&art_path).unwrap());
        let row = format!("bind_instant_artifact_{fam_q2}");
        let before = panel_build_count();
        b.bench(&row, || {
            let mut eng = NativeEngine::from_artifact(Arc::clone(&art));
            eng.prepare_infer(&fam_q2, &[], &PrepareOptions::new()).unwrap();
            black_box(&eng);
        });
        b.annotate(&row, "panel_builds", (panel_build_count() - before) as f64);
    }

    // -- Figure-1 int matmul: the fused unpack-and-dot kernel ----------------
    // Single-thread rows (the historical L1 baseline); the threaded scaling
    // story lives in `benches/gemm.rs` / BENCH_native_gemm.json.
    let (m, k, nn) = if fast { (64, 256, 128) } else { (128, 512, 256) };
    let mut rng = Pcg32::seeded(4);
    let mut ws = Workspace::with_threads(1);
    for bits in [2u32, 4, 8] {
        let w: Vec<f32> = (0..k * nn).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, bits, true).unwrap();
        let (_, qp) = lsqnet::quant::lsq::qrange(bits, false);
        let xb: Vec<i32> = (0..m * k).map(|_| (rng.below(qp as u32 + 1)) as i32).collect();
        let mut out = vec![0.0f32; m * nn];
        b.bench_units(&format!("qgemm_{bits}bit_{m}x{k}x{nn}"), (m * k * nn) as f64, || {
            qgemm(&mut ws, m, k, nn, black_box(&xb), black_box(&packed), 0.01, None, &mut out);
            black_box(&out);
        });
    }

    b.finish();
    // Perf-trajectory file at the repository root (rust/ is the package
    // dir); fast-mode CI smoke numbers land under target/ instead so they
    // never clobber the full-run trajectory or dirty the working tree.
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = if fast {
        manifest_dir.join("target").join("BENCH_serve_fast.json")
    } else {
        manifest_dir.join("..").join("BENCH_serve.json")
    };
    match b.write_json(&path) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    std::fs::remove_dir_all(&dir).ok();
}
