//! Figure 2: quantizer output v̂ and step-size gradients ∂v̂/∂s for LSQ vs
//! QIL vs PACT over a v sweep (s = 1, Qn = 0, Qp = 3).
//!
//! Two sources that must agree (and are asserted to in the integration
//! tests): the `fig2` AOT artifact (the same jnp/Pallas code the training
//! artifacts embed) and the pure-Rust quantizer in `quant::lsq`.

#[cfg(feature = "xla")]
use anyhow::Result;

#[cfg(feature = "xla")]
use crate::runtime::Engine;
#[cfg(feature = "xla")]
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct Curves {
    pub v: Vec<f32>,
    pub vhat: Vec<f32>,
    pub ds_lsq: Vec<f32>,
    pub ds_qil: Vec<f32>,
    pub ds_pact: Vec<f32>,
}

/// Evaluate the curves through the AOT artifact.
#[cfg(feature = "xla")]
pub fn from_artifact(engine: &Engine, lo: f32, hi: f32) -> Result<Curves> {
    let exe = engine.load_kind("fig2", "", None, None).or_else(|_| {
        // fig2 has family=None; find by kind directly
        let id = engine
            .manifest()
            .artifacts
            .values()
            .find(|a| a.kind == "fig2")
            .map(|a| a.id.clone())
            .ok_or_else(|| anyhow::anyhow!("no fig2 artifact"))?;
        engine.load(&id)
    })?;
    let n = exe.meta.inputs[0].shape[0];
    let v: Vec<f32> = (0..n)
        .map(|i| lo + (hi - lo) * i as f32 / (n - 1) as f32)
        .collect();
    let out = exe.run(&[
        Tensor::from_f32(&[n], v.clone()),
        Tensor::scalar_f32(1.0),
    ])?;
    Ok(Curves {
        v,
        vhat: out[0].f32s()?.to_vec(),
        ds_lsq: out[1].f32s()?.to_vec(),
        ds_qil: out[2].f32s()?.to_vec(),
        ds_pact: out[3].f32s()?.to_vec(),
    })
}

/// Same curves from the pure-Rust quantizer (cross-validation path).
pub fn from_rust(lo: f32, hi: f32, n: usize) -> Curves {
    use crate::quant::lsq::{grad_s_term, quantize};
    let (qn, qp) = (0i64, 3i64);
    let v: Vec<f32> = (0..n)
        .map(|i| lo + (hi - lo) * i as f32 / (n - 1) as f32)
        .collect();
    let vhat = v.iter().map(|&x| quantize(x, 1.0, qn, qp)).collect();
    let ds_lsq = v.iter().map(|&x| grad_s_term(x, 1.0, qn, qp)).collect();
    let ds_qil = v.iter().map(|&x| (x / 1.0).clamp(-(qn as f32), qp as f32)).collect();
    let ds_pact = v
        .iter()
        .map(|&x| {
            if x >= qp as f32 {
                qp as f32
            } else if x <= -(qn as f32) && qn > 0 {
                -(qn as f32)
            } else {
                0.0
            }
        })
        .collect();
    Curves { v, vhat, ds_lsq, ds_qil, ds_pact }
}

/// CSV for plotting (columns: v, vhat, ds_lsq, ds_qil, ds_pact).
pub fn to_csv(c: &Curves) -> String {
    let mut s = String::from("v,vhat,ds_lsq,ds_qil,ds_pact\n");
    for i in 0..c.v.len() {
        s.push_str(&format!(
            "{},{},{},{},{}\n",
            c.v[i], c.vhat[i], c.ds_lsq[i], c.ds_qil[i], c.ds_pact[i]
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_curves_shape() {
        let c = from_rust(-1.0, 4.0, 101);
        assert_eq!(c.v.len(), 101);
        // vhat saturates at Qp*s = 3
        assert_eq!(*c.vhat.last().unwrap(), 3.0);
        // PACT gradient zero inside the domain, Qp at/after clip
        let mid = c.v.iter().position(|&v| v > 0.5 && v < 2.4).unwrap();
        assert_eq!(c.ds_pact[mid], 0.0);
        assert_eq!(*c.ds_pact.last().unwrap(), 3.0);
        // LSQ gradient is a sawtooth: changes sign inside the domain
        let has_neg = c.ds_lsq.iter().any(|&g| g < -0.1);
        let has_pos = c.ds_lsq.iter().any(|&g| g > 0.1);
        assert!(has_neg && has_pos);
    }
}
