//! Analysis modules for the paper's diagnostic experiments:
//!
//! * [`rratio`] — the Equation-4 update/parameter-magnitude ratio R measured
//!   via the `train_diag` artifacts (Figure 4, Section 3.4).
//! * [`qerror`] — does the learned ŝ minimize quantization error? (Sec. 3.6)
//! * [`curves`] — quantizer transfer/gradient curves (Figure 2), via the
//!   `fig2` artifact (same kernels the training path uses).

pub mod curves;
pub mod qerror;
pub mod rratio;
