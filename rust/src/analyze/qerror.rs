//! Section 3.6: compare the learned step size ŝ against the quantization-
//! error-minimizing step size under MAE / MSE / KL, per layer, on test data.
//!
//! Weight layers: scan directly over the checkpoint's weight tensors.
//! Activation layers: capture per-layer quantizer inputs by replaying the
//! fp32 forward — we approximate the activation distribution with the
//! pre-activation batch statistics captured via the init_quant relation
//! sa = 2<|v|>/sqrt(Qp) ⇒ <|v|> = sa·√Qp/2, and scan the *weights* exactly;
//! the weight-layer numbers are the directly comparable ones and are what
//! the repro table reports per metric.

use anyhow::Result;

use crate::quant::error::{pct_abs_diff, sweep_min, Metric};
use crate::quant::lsq::qrange;
use crate::runtime::Family;
use crate::tensor::Checkpoint;
use crate::util::stats::mean;

#[derive(Clone, Debug)]
pub struct LayerQError {
    pub layer: String,
    pub s_hat: f32,
    pub bits: u32,
    pub s_min_mae: f32,
    pub s_min_mse: f32,
    pub s_min_kl: f32,
}

#[derive(Clone, Debug)]
pub struct QErrorReport {
    pub layers: Vec<LayerQError>,
    /// Mean/std of ŝ across weight layers (paper: 0.025 ± 0.019 for w).
    pub s_hat_mean: f64,
    pub s_hat_std: f64,
}

impl QErrorReport {
    /// Average percent |ŝ - s_min| across layers for a metric — the
    /// headline Section-3.6 numbers (47% MAE / 28% MSE / 46% KL for
    /// weights on 2-bit ResNet-18).
    pub fn avg_pct_diff(&self, metric: Metric) -> f64 {
        mean(
            &self
                .layers
                .iter()
                .map(|l| {
                    let smin = match metric {
                        Metric::MeanAbs => l.s_min_mae,
                        Metric::MeanSq => l.s_min_mse,
                        Metric::Kl => l.s_min_kl,
                    };
                    pct_abs_diff(l.s_hat, smin)
                })
                .collect::<Vec<_>>(),
        )
    }
}

/// Analyze every quantized *weight* layer of a trained checkpoint.
pub fn analyze_weights(fam: &Family, ckpt: &Checkpoint) -> Result<QErrorReport> {
    let mut layers = Vec::new();
    let mut s_hats = Vec::new();
    let bits_of: std::collections::BTreeMap<&str, u32> =
        fam.layer_meta.iter().map(|l| (l.name.as_str(), l.bits)).collect();

    for sw_name in fam.step_names("step_w") {
        let scope = sw_name.trim_end_matches(".sw").to_string();
        let bits = *bits_of
            .get(scope.as_str())
            .ok_or_else(|| anyhow::anyhow!("no layer_meta for {scope}"))?;
        let s_hat = ckpt.get(&sw_name)?.item_f32()?;
        let w = ckpt.get(&format!("{scope}.w"))?.f32s()?.to_vec();
        let layer = LayerQError {
            layer: scope,
            s_hat,
            bits,
            s_min_mae: sweep_min(Metric::MeanAbs, &w, s_hat, bits, true),
            s_min_mse: sweep_min(Metric::MeanSq, &w, s_hat, bits, true),
            s_min_kl: sweep_min(Metric::Kl, &w, s_hat, bits, true),
        };
        s_hats.push(s_hat as f64);
        layers.push(layer);
    }
    let m = mean(&s_hats);
    let std = if s_hats.len() > 1 {
        (s_hats.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (s_hats.len() - 1) as f64).sqrt()
    } else {
        0.0
    };
    let _ = qrange(2, true); // keep the import honest for doc purposes
    Ok(QErrorReport { layers, s_hat_mean: m, s_hat_std: std })
}

/// Learned activation step sizes (mean ± std), for the Section-3.6 report
/// header (paper: 0.949 ± 0.206 for activations on 2-bit ResNet-18).
pub fn act_step_stats(fam: &Family, ckpt: &Checkpoint) -> Result<(f64, f64)> {
    let mut vals = Vec::new();
    for sa in fam.step_names("step_a") {
        vals.push(ckpt.get(&sa)?.item_f32()? as f64);
    }
    let m = mean(&vals);
    let std = if vals.len() > 1 {
        (vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (vals.len() - 1) as f64).sqrt()
    } else {
        0.0
    };
    Ok((m, std))
}
