//! Figure 4 / Section 3.4: the ratio R (Eq. 4) between relative step-size
//! updates and relative weight updates, per layer, averaged over training
//! iterations — measured with the `train_diag` artifacts, which emit
//! per-quantized-layer ‖∇w L‖, ‖w‖, |∇s L| and s each step.
//!
//! The paper measures R over 500 iterations in the middle of epoch 1 while
//! *training* with the full gradient scale; each diag artifact instead bakes
//! one gscale mode into its own gradient, so we run a short training segment
//! per mode and report per-layer mean R.

#[cfg(feature = "xla")]
use anyhow::{bail, Result};

#[cfg(feature = "xla")]
use crate::config::ExperimentConfig;
#[cfg(feature = "xla")]
use crate::data::Loader;
#[cfg(feature = "xla")]
use crate::runtime::Engine;
#[cfg(feature = "xla")]
use crate::tensor::Tensor;
#[cfg(feature = "xla")]
use crate::train::TrainState;
#[cfg(feature = "xla")]
use crate::util::stats::Welford;

#[derive(Clone, Debug)]
pub struct LayerR {
    pub layer: String,
    pub mean_r: f64,
    pub std_r: f64,
}

#[derive(Clone, Debug)]
pub struct RRatioReport {
    pub gscale: String,
    pub bits: u32,
    pub iterations: usize,
    pub layers: Vec<LayerR>,
}

impl RRatioReport {
    /// Geometric mean of per-layer mean R (the Figure-4 summary height).
    pub fn geomean_r(&self) -> f64 {
        crate::util::stats::geomean(
            &self.layers.iter().map(|l| l.mean_r.max(1e-30)).collect::<Vec<_>>(),
        )
    }
}

/// Run `iters` diag steps for (model, bits, gscale) and fold R per layer.
#[cfg(feature = "xla")]
pub fn measure(
    engine: &Engine,
    cfg: &ExperimentConfig,
    gscale: &str,
    iters: usize,
) -> Result<RRatioReport> {
    let family = cfg.family();
    let manifest = engine.manifest();
    let fam = manifest.family(&family)?.clone();
    let exe = engine.load_kind("train_diag", &family, None, Some(gscale))?;

    // Layer names, in the order the diag outputs stack them (sorted sw names).
    let sw_names = fam.step_names("step_w");
    let layers: Vec<String> = sw_names
        .iter()
        .map(|n| n.trim_end_matches(".sw").to_string())
        .collect();

    let mut state = TrainState::fresh(manifest, &family)?;
    let p = state.params.len();
    let g = state.moms.len();

    let batch = exe.meta.batch;
    let loader = Loader::spawn(&cfg.data, batch, usize::MAX / 2, cfg.train.seed, 2);

    let mut acc: Vec<Welford> = layers.iter().map(|_| Welford::new()).collect();
    for _ in 0..iters {
        let b = loader.next().ok_or_else(|| anyhow::anyhow!("loader drained"))?;
        let mut inputs: Vec<Tensor> = Vec::with_capacity(p + g + 4);
        inputs.extend(state.params.iter().cloned());
        inputs.extend(state.moms.iter().cloned());
        inputs.push(b.x);
        inputs.push(b.y);
        inputs.push(Tensor::scalar_f32(cfg.train.lr as f32));
        inputs.push(Tensor::scalar_f32(cfg.train.weight_decay as f32));
        let out = exe.run(&inputs)?;
        if out.len() != p + g + 2 + 4 {
            bail!("diag artifact returned {} outputs", out.len());
        }
        let gw = out[p + g + 2].f32s()?;
        let wn = out[p + g + 3].f32s()?;
        let gs = out[p + g + 4].f32s()?;
        let sv = out[p + g + 5].f32s()?;
        for (i, w) in acc.iter_mut().enumerate() {
            // R = (|∇s L| / s) / (‖∇w L‖ / ‖w‖), Eq. 4.
            let num = gs[i] as f64 / (sv[i].abs().max(1e-12) as f64);
            let den = gw[i] as f64 / (wn[i].abs().max(1e-12) as f64);
            if den > 0.0 {
                w.push(num / den);
            }
        }
        // keep training so R is measured on a *moving* model as in the paper
        let mut new = out;
        new.truncate(p + g);
        let moms = new.split_off(p);
        state.params = new;
        state.moms = moms;
    }

    Ok(RRatioReport {
        gscale: gscale.to_string(),
        bits: cfg.bits,
        iterations: iters,
        layers: layers
            .into_iter()
            .zip(acc)
            .map(|(layer, w)| LayerR { layer, mean_r: w.mean(), std_r: w.std() })
            .collect(),
    })
}
