//! Experiment configuration system.
//!
//! Configs are JSON files (parsed with the in-repo parser) with full
//! defaulting and validation; every CLI flag can override a field. A config
//! fully determines an experiment: model family, precision, quantizer
//! method/gscale, data generation, optimization schedule, seeds, and
//! (optionally) the fp32 checkpoint to fine-tune from — the paper's
//! protocol (Section 2.3).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct DataConfig {
    /// Number of training images (procedurally generated; index-addressable).
    pub train_size: usize,
    pub test_size: usize,
    pub classes: usize,
    /// Background/noise level in [0, 1] — the dataset difficulty knob.
    pub noise: f32,
    pub seed: u64,
    /// Random-crop padding (pixels) + horizontal mirror, as in the paper.
    pub augment: bool,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            train_size: 12_800,
            test_size: 2_560,
            classes: 10,
            noise: 1.2,
            seed: 1,
            augment: true,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// Cosine decay without restarts (Loshchilov & Hutter 2016) — the
    /// paper's default (Section 2.3).
    Cosine,
    /// Step decay ×0.1 every `step_every` epochs (Section 3.5 ablation).
    Step,
    Const,
}

impl Schedule {
    pub fn parse(s: &str) -> Result<Schedule> {
        match s {
            "cosine" => Ok(Schedule::Cosine),
            "step" => Ok(Schedule::Step),
            "const" => Ok(Schedule::Const),
            _ => bail!("unknown schedule {s:?} (cosine|step|const)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Schedule::Cosine => "cosine",
            Schedule::Step => "step",
            Schedule::Const => "const",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub schedule: Schedule,
    /// For Schedule::Step: multiply lr by 0.1 every N epochs (paper: 20).
    pub step_every: usize,
    pub eval_every: usize,
    pub seed: u64,
    /// Stop early after this many optimizer steps (0 = run all epochs);
    /// used by smoke tests and the --quick repro mode.
    pub max_steps: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 30,
            lr: 0.01,
            weight_decay: 1e-4,
            schedule: Schedule::Cosine,
            step_every: 20,
            eval_every: 1,
            seed: 0,
            max_steps: 0,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub artifacts_dir: String,
    pub out_dir: String,
    pub model: String,
    pub bits: u32,
    pub method: String,
    pub gscale: String,
    /// Training backend: `"native"` (pure-Rust backward, always available)
    /// or `"xla"` (AOT artifacts, needs `--features xla`).
    pub backend: String,
    pub distill: bool,
    /// Checkpoint of an fp32 model to fine-tune from (paper protocol).
    /// Empty = train from the AOT initial parameters.
    pub init_from: String,
    pub data: DataConfig,
    pub train: TrainConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "exp".to_string(),
            artifacts_dir: "artifacts".to_string(),
            out_dir: "runs".to_string(),
            model: "cnn_small".to_string(),
            bits: 32,
            method: "lsq".to_string(),
            gscale: "full".to_string(),
            backend: "native".to_string(),
            distill: false,
            init_from: String::new(),
            data: DataConfig::default(),
            train: TrainConfig::default(),
        }
    }
}

impl ExperimentConfig {
    pub fn family(&self) -> String {
        format!("{}_q{}", self.model, self.bits)
    }

    /// The paper's per-precision learning-rate defaults (Section 2.3):
    /// 0.1 fp32, 0.01 for 2-4 bit, 0.001 for 8-bit — scaled down one decade
    /// for our small-batch CPU runs by the configs that use them.
    pub fn paper_lr(bits: u32) -> f64 {
        match bits {
            32 => 0.1,
            8 => 0.001,
            _ => 0.01,
        }
    }

    /// Paper Table-2 result: halve weight decay at 3-bit, quarter at 2-bit.
    pub fn paper_wd(bits: u32, base: f64) -> f64 {
        match bits {
            2 => base * 0.25,
            3 => base * 0.5,
            _ => base,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !matches!(self.bits, 2 | 3 | 4 | 8 | 32) {
            bail!("bits must be one of 2,3,4,8,32 (got {})", self.bits);
        }
        if !["lsq", "lsq_jnp", "qil", "pact", "fixed"].contains(&self.method.as_str()) {
            bail!("unknown quantizer method {:?}", self.method);
        }
        if !["full", "sqrtn", "one", "x10", "d10"].contains(&self.gscale.as_str()) {
            bail!("unknown gscale mode {:?}", self.gscale);
        }
        if !["native", "xla"].contains(&self.backend.as_str()) {
            bail!("unknown train backend {:?} (native|xla)", self.backend);
        }
        if self.backend == "native" && self.distill {
            bail!("knowledge distillation is only implemented on the xla backend");
        }
        if self.train.epochs == 0 && self.train.max_steps == 0 {
            bail!("epochs and max_steps are both 0 — nothing to train");
        }
        if self.data.train_size == 0 || self.data.test_size == 0 {
            bail!("data sizes must be positive");
        }
        if self.distill && self.bits == 32 {
            bail!("distillation requires a quantized student (bits < 32)");
        }
        Ok(())
    }

    // -- JSON (de)serialization ------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            ("out_dir", Json::str(self.out_dir.clone())),
            ("model", Json::str(self.model.clone())),
            ("bits", Json::num(self.bits as f64)),
            ("method", Json::str(self.method.clone())),
            ("gscale", Json::str(self.gscale.clone())),
            ("backend", Json::str(self.backend.clone())),
            ("distill", Json::Bool(self.distill)),
            ("init_from", Json::str(self.init_from.clone())),
            (
                "data",
                Json::obj(vec![
                    ("train_size", Json::num(self.data.train_size as f64)),
                    ("test_size", Json::num(self.data.test_size as f64)),
                    ("classes", Json::num(self.data.classes as f64)),
                    ("noise", Json::num(self.data.noise as f64)),
                    ("seed", Json::num(self.data.seed as f64)),
                    ("augment", Json::Bool(self.data.augment)),
                ]),
            ),
            (
                "train",
                Json::obj(vec![
                    ("epochs", Json::num(self.train.epochs as f64)),
                    ("lr", Json::num(self.train.lr)),
                    ("weight_decay", Json::num(self.train.weight_decay)),
                    ("schedule", Json::str(self.train.schedule.name())),
                    ("step_every", Json::num(self.train.step_every as f64)),
                    ("eval_every", Json::num(self.train.eval_every as f64)),
                    ("seed", Json::num(self.train.seed as f64)),
                    ("max_steps", Json::num(self.train.max_steps as f64)),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        let gs = |j: &Json, k: &str, d: &str| -> String {
            j.get(k).and_then(Json::as_str).map(str::to_string).unwrap_or_else(|| d.into())
        };
        c.name = gs(j, "name", &c.name);
        c.artifacts_dir = gs(j, "artifacts_dir", &c.artifacts_dir);
        c.out_dir = gs(j, "out_dir", &c.out_dir);
        c.model = gs(j, "model", &c.model);
        c.bits = j.get("bits").and_then(Json::as_usize).unwrap_or(c.bits as usize) as u32;
        c.method = gs(j, "method", &c.method);
        c.gscale = gs(j, "gscale", &c.gscale);
        c.backend = gs(j, "backend", &c.backend);
        c.distill = j.get("distill").and_then(Json::as_bool).unwrap_or(c.distill);
        c.init_from = gs(j, "init_from", &c.init_from);
        if let Some(d) = j.get("data") {
            c.data.train_size =
                d.get("train_size").and_then(Json::as_usize).unwrap_or(c.data.train_size);
            c.data.test_size =
                d.get("test_size").and_then(Json::as_usize).unwrap_or(c.data.test_size);
            c.data.classes = d.get("classes").and_then(Json::as_usize).unwrap_or(c.data.classes);
            c.data.noise =
                d.get("noise").and_then(Json::as_f64).unwrap_or(c.data.noise as f64) as f32;
            c.data.seed = d.get("seed").and_then(Json::as_i64).unwrap_or(c.data.seed as i64) as u64;
            c.data.augment = d.get("augment").and_then(Json::as_bool).unwrap_or(c.data.augment);
        }
        if let Some(t) = j.get("train") {
            c.train.epochs = t.get("epochs").and_then(Json::as_usize).unwrap_or(c.train.epochs);
            c.train.lr = t.get("lr").and_then(Json::as_f64).unwrap_or(c.train.lr);
            c.train.weight_decay =
                t.get("weight_decay").and_then(Json::as_f64).unwrap_or(c.train.weight_decay);
            if let Some(s) = t.get("schedule").and_then(Json::as_str) {
                c.train.schedule = Schedule::parse(s)?;
            }
            c.train.step_every =
                t.get("step_every").and_then(Json::as_usize).unwrap_or(c.train.step_every);
            c.train.eval_every =
                t.get("eval_every").and_then(Json::as_usize).unwrap_or(c.train.eval_every);
            c.train.seed =
                t.get("seed").and_then(Json::as_i64).unwrap_or(c.train.seed as i64) as u64;
            c.train.max_steps =
                t.get("max_steps").and_then(Json::as_usize).unwrap_or(c.train.max_steps);
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Self::from_json(&j)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut c = ExperimentConfig::default();
        c.model = "resnet20".into();
        c.bits = 2;
        c.train.schedule = Schedule::Step;
        c.train.lr = 0.003;
        let j = c.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn defaults_fill_missing() {
        let j = Json::parse(r#"{"model": "mlp", "bits": 4}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "mlp");
        assert_eq!(c.bits, 4);
        assert_eq!(c.train.epochs, TrainConfig::default().epochs);
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = ExperimentConfig::default();
        c.bits = 5;
        assert!(c.validate().is_err());
        c.bits = 2;
        c.method = "nope".into();
        assert!(c.validate().is_err());
        c.method = "lsq".into();
        c.distill = true;
        c.bits = 32;
        assert!(c.validate().is_err());
    }

    #[test]
    fn paper_scalings() {
        assert_eq!(ExperimentConfig::paper_lr(32), 0.1);
        assert_eq!(ExperimentConfig::paper_lr(8), 0.001);
        assert_eq!(ExperimentConfig::paper_lr(2), 0.01);
        assert_eq!(ExperimentConfig::paper_wd(2, 1e-4), 0.25e-4);
        assert_eq!(ExperimentConfig::paper_wd(3, 1e-4), 0.5e-4);
        assert_eq!(ExperimentConfig::paper_wd(4, 1e-4), 1e-4);
    }

    #[test]
    fn family_string() {
        let mut c = ExperimentConfig::default();
        c.model = "resnet20".into();
        c.bits = 3;
        assert_eq!(c.family(), "resnet20_q3");
    }
}
