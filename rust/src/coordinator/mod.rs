//! Sweep coordinator: leader/worker scheduling of experiment jobs.
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so parallelism is process-shaped
//! the way a multi-host launcher would be: the leader owns a job queue;
//! each worker thread builds its *own* engine via a shared factory (its own
//! PJRT client and compiled executables — the same replica model the serve
//! layer uses, see DESIGN.md §Backend-trait) and pulls jobs until the queue
//! drains. Results flow back over a channel and are folded into a
//! `SweepReport` keyed by job name.
//!
//! XLA:CPU itself parallelizes single steps across cores, so the default
//! worker count is deliberately small (oversubscription hurts); sweeps of
//! many small jobs benefit from 2-4 workers.
//!
//! Training requires the AOT artifacts, so `run_job` / `run_sweep` are
//! only compiled with `--features xla`; the job/report types are always
//! available.

#[cfg(feature = "xla")]
pub mod sweep;

use std::collections::BTreeMap;
use std::path::PathBuf;
#[cfg(feature = "xla")]
use std::sync::mpsc;
#[cfg(feature = "xla")]
use std::sync::Mutex;
#[cfg(feature = "xla")]
use std::time::Instant;

use anyhow::Result;

use crate::config::ExperimentConfig;
#[cfg(feature = "xla")]
use crate::runtime::Engine;
#[cfg(feature = "xla")]
use crate::train::Trainer;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Job {
    pub cfg: ExperimentConfig,
    /// Tags propagated into the report (e.g. table row/column ids).
    pub tags: BTreeMap<String, String>,
}

impl Job {
    pub fn new(cfg: ExperimentConfig) -> Job {
        Job { cfg, tags: BTreeMap::new() }
    }

    pub fn tag(mut self, k: &str, v: impl ToString) -> Job {
        self.tags.insert(k.to_string(), v.to_string());
        self
    }
}

#[derive(Clone, Debug)]
pub struct JobResult {
    pub name: String,
    pub tags: BTreeMap<String, String>,
    pub top1: f64,
    pub top5: f64,
    pub final_train_loss: f64,
    pub wall_seconds: f64,
    pub checkpoint: PathBuf,
    pub error: Option<String>,
    /// Did training diverge / fail to beat chance? (paper Table 3 reports
    /// "Did not converge" rows.)
    pub converged: bool,
}

#[derive(Default, Debug)]
pub struct SweepReport {
    pub results: Vec<JobResult>,
}

impl SweepReport {
    pub fn by_name(&self, name: &str) -> Option<&JobResult> {
        self.results.iter().find(|r| r.name == name)
    }

    pub fn by_tags(&self, want: &[(&str, &str)]) -> Option<&JobResult> {
        self.results.iter().find(|r| {
            want.iter().all(|(k, v)| r.tags.get(*k).map(String::as_str) == Some(*v))
        })
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("name", Json::str(r.name.clone())),
                        ("top1", Json::num(r.top1)),
                        ("top5", Json::num(r.top5)),
                        ("final_train_loss", Json::num(r.final_train_loss)),
                        ("wall_seconds", Json::num(r.wall_seconds)),
                        ("converged", Json::Bool(r.converged)),
                        (
                            "checkpoint",
                            Json::str(r.checkpoint.to_string_lossy().to_string()),
                        ),
                    ];
                    if let Some(e) = &r.error {
                        fields.push(("error", Json::str(e.clone())));
                    }
                    let tags = r
                        .tags
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                        .collect();
                    fields.push(("tags", Json::Obj(tags)));
                    Json::obj(fields)
                })
                .collect(),
        )
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(d) = path.parent() {
            std::fs::create_dir_all(d)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// Execute one job on an existing engine (used by workers and directly by
/// the CLI `train` command).
#[cfg(feature = "xla")]
pub fn run_job(engine: &Engine, job: &Job) -> JobResult {
    let t0 = Instant::now();
    let name = job.cfg.name.clone();
    let chance = 100.0 / job.cfg.data.classes as f64;
    match Trainer::new(engine, job.cfg.clone()).and_then(|mut t| {
        t.verbose = false;
        t.fit()
    }) {
        Ok(rep) => JobResult {
            name,
            tags: job.tags.clone(),
            top1: rep.final_top1,
            top5: rep.final_top5,
            final_train_loss: rep.history.recent_loss(20),
            wall_seconds: t0.elapsed().as_secs_f64(),
            checkpoint: rep.checkpoint,
            error: None,
            // "converged": clearly above chance at the end.
            converged: rep.final_top1 > 1.5 * chance,
        },
        Err(e) => JobResult {
            name,
            tags: job.tags.clone(),
            top1: f64::NAN,
            top5: f64::NAN,
            final_train_loss: f64::NAN,
            wall_seconds: t0.elapsed().as_secs_f64(),
            checkpoint: PathBuf::new(),
            error: Some(format!("{e:#}")),
            converged: false,
        },
    }
}

/// Leader: run `jobs` across `workers` threads, each building its own
/// engine through `make_engine` (the factory is shared by reference; the
/// engines it returns never cross threads). Jobs run in queue order;
/// results are returned in completion order and then sorted back to
/// submission order.
#[cfg(feature = "xla")]
pub fn run_sweep_with<F>(make_engine: F, jobs: Vec<Job>, workers: usize) -> Result<SweepReport>
where
    F: Fn() -> Result<Engine> + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Ok(SweepReport::default());
    }
    let workers = workers.clamp(1, n);
    println!("sweep: {n} jobs on {workers} worker(s)");

    let queue: Mutex<Vec<(usize, Job)>> =
        Mutex::new(jobs.into_iter().enumerate().rev().collect());
    let (tx, rx) = mpsc::channel::<(usize, JobResult)>();
    let queue = &queue;
    let make_engine = &make_engine;

    std::thread::scope(|s| {
        for wid in 0..workers {
            let tx = tx.clone();
            s.spawn(move || {
                // Each worker owns its engine (non-Send client).
                let engine = match make_engine() {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("worker {wid}: engine init failed: {e:#}");
                        return;
                    }
                };
                loop {
                    let item = queue.lock().unwrap().pop();
                    let (idx, job) = match item {
                        Some(x) => x,
                        None => break,
                    };
                    let started = Instant::now();
                    let res = run_job(&engine, &job);
                    println!(
                        "  [worker {wid}] {} -> top1 {:.2}%{} ({:.1}s)",
                        res.name,
                        res.top1,
                        res.error.as_deref().map(|e| format!(" ERROR: {e}")).unwrap_or_default(),
                        started.elapsed().as_secs_f64()
                    );
                    if tx.send((idx, res)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
    });

    let mut indexed: Vec<(usize, JobResult)> = rx.iter().collect();
    indexed.sort_by_key(|(i, _)| *i);
    Ok(SweepReport { results: indexed.into_iter().map(|(_, r)| r).collect() })
}

/// [`run_sweep_with`] over the default XLA engine factory for
/// `artifacts_dir`.
#[cfg(feature = "xla")]
pub fn run_sweep(
    artifacts_dir: &std::path::Path,
    jobs: Vec<Job>,
    workers: usize,
) -> Result<SweepReport> {
    run_sweep_with(|| Engine::new(artifacts_dir), jobs, workers)
}
