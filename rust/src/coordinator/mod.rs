//! Sweep coordinator: leader/worker scheduling of experiment jobs.
//!
//! Parallelism is process-shaped the way a multi-host launcher would be:
//! the leader owns a job queue; each worker thread builds its *own*
//! training backend through a shared factory and pulls jobs until the
//! queue drains ([`run_sweep_pooled`]). Results flow back over a channel
//! and are folded into a [`SweepReport`] keyed by job name.
//!
//! Two backends plug into the same pool:
//!
//! * **native** ([`run_sweep_native`], always available) — each worker
//!   runs [`crate::train::NativeTrainer`] jobs straight off the manifest,
//!   no XLA/PJRT;
//! * **xla** (`run_sweep` / `run_sweep_with`, behind `--features xla`) —
//!   `PjRtClient` is `Rc`-backed (not `Send`), so each worker builds its
//!   own `Engine` (its own PJRT client and compiled executables — the same
//!   replica model the serve layer uses, see DESIGN.md §Backend-trait).
//!
//! XLA:CPU parallelizes single steps across cores, so the default worker
//! count is deliberately small (oversubscription hurts). The native
//! trainer's kernels are multi-threaded too (DESIGN.md §Kernel-layer), so
//! [`run_sweep_native`] caps each worker's intra-op threads at
//! `cores / workers` — inter-job and intra-op parallelism share the host
//! instead of multiplying.

pub mod sweep;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{mpsc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::ExperimentConfig;
#[cfg(feature = "xla")]
use crate::runtime::Engine;
use crate::train::{FitReport, NativeTrainer};
#[cfg(feature = "xla")]
use crate::train::Trainer;
use crate::util::json::Json;

/// One experiment to run: a config plus report tags.
#[derive(Clone, Debug)]
pub struct Job {
    /// The experiment configuration.
    pub cfg: ExperimentConfig,
    /// Tags propagated into the report (e.g. table row/column ids).
    pub tags: BTreeMap<String, String>,
}

impl Job {
    /// Wrap a config with no tags.
    pub fn new(cfg: ExperimentConfig) -> Job {
        Job { cfg, tags: BTreeMap::new() }
    }

    /// Attach a report tag.
    pub fn tag(mut self, k: &str, v: impl ToString) -> Job {
        self.tags.insert(k.to_string(), v.to_string());
        self
    }
}

/// Outcome of one job (error runs report `error` + NaN metrics).
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Job name (from the config).
    pub name: String,
    /// Tags copied from the job.
    pub tags: BTreeMap<String, String>,
    /// Final test top-1 (%).
    pub top1: f64,
    /// Final test top-5 (%).
    pub top5: f64,
    /// Mean train loss over the last 20 steps.
    pub final_train_loss: f64,
    /// Wall time of the whole job.
    pub wall_seconds: f64,
    /// Path of the final checkpoint (empty on error).
    pub checkpoint: PathBuf,
    /// Error message when the job failed.
    pub error: Option<String>,
    /// Did training diverge / fail to beat chance? (paper Table 3 reports
    /// "Did not converge" rows.)
    pub converged: bool,
}

/// Results of a sweep, in job-submission order.
#[derive(Default, Debug)]
pub struct SweepReport {
    /// One entry per job.
    pub results: Vec<JobResult>,
}

impl SweepReport {
    /// Find a result by job name.
    pub fn by_name(&self, name: &str) -> Option<&JobResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Find the first result carrying all of `want`'s tag pairs.
    pub fn by_tags(&self, want: &[(&str, &str)]) -> Option<&JobResult> {
        self.results.iter().find(|r| {
            want.iter().all(|(k, v)| r.tags.get(*k).map(String::as_str) == Some(*v))
        })
    }

    /// JSON array form (one object per result).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("name", Json::str(r.name.clone())),
                        ("top1", Json::num(r.top1)),
                        ("top5", Json::num(r.top5)),
                        ("final_train_loss", Json::num(r.final_train_loss)),
                        ("wall_seconds", Json::num(r.wall_seconds)),
                        ("converged", Json::Bool(r.converged)),
                        (
                            "checkpoint",
                            Json::str(r.checkpoint.to_string_lossy().to_string()),
                        ),
                    ];
                    if let Some(e) = &r.error {
                        fields.push(("error", Json::str(e.clone())));
                    }
                    let tags = r
                        .tags
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                        .collect();
                    fields.push(("tags", Json::Obj(tags)));
                    Json::obj(fields)
                })
                .collect(),
        )
    }

    /// Write the JSON report (creating parent directories).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(d) = path.parent() {
            std::fs::create_dir_all(d)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// Fold a finished (or failed) fit into a [`JobResult`].
fn finish_job(job: &Job, t0: Instant, res: Result<FitReport>) -> JobResult {
    let name = job.cfg.name.clone();
    let chance = 100.0 / job.cfg.data.classes as f64;
    match res {
        Ok(rep) => JobResult {
            name,
            tags: job.tags.clone(),
            top1: rep.final_top1,
            top5: rep.final_top5,
            final_train_loss: rep.history.recent_loss(20),
            wall_seconds: t0.elapsed().as_secs_f64(),
            checkpoint: rep.checkpoint,
            error: None,
            // "converged": clearly above chance at the end.
            converged: rep.final_top1 > 1.5 * chance,
        },
        Err(e) => JobResult {
            name,
            tags: job.tags.clone(),
            top1: f64::NAN,
            top5: f64::NAN,
            final_train_loss: f64::NAN,
            wall_seconds: t0.elapsed().as_secs_f64(),
            checkpoint: PathBuf::new(),
            error: Some(format!("{e:#}")),
            converged: false,
        },
    }
}

/// Execute one job on an existing XLA engine (used by workers and directly
/// by the CLI `train` command).
#[cfg(feature = "xla")]
pub fn run_job(engine: &Engine, job: &Job) -> JobResult {
    let t0 = Instant::now();
    finish_job(
        job,
        t0,
        Trainer::new(engine, job.cfg.clone()).and_then(|mut t| {
            t.verbose = false;
            t.fit()
        }),
    )
}

/// Execute one job on the native training backend (no XLA/PJRT). The
/// trainer reads `manifest.json` from the job's own `artifacts_dir` and
/// uses the full hardware thread count for its kernels.
pub fn run_job_native(job: &Job) -> JobResult {
    run_job_native_with_threads(job, 0)
}

/// [`run_job_native`] with a per-worker intra-op kernel-thread cap
/// (0 = hardware count): a sweep pool of W workers on C cores runs
/// `W × C/W` compute threads instead of `W × C`
/// (DESIGN.md §Kernel-layer).
pub fn run_job_native_with_threads(job: &Job, intra_threads: usize) -> JobResult {
    let t0 = Instant::now();
    finish_job(
        job,
        t0,
        NativeTrainer::new(job.cfg.clone()).and_then(|mut t| {
            t.verbose = false;
            t.set_threads(intra_threads);
            t.fit()
        }),
    )
}

/// Leader/worker pool shared by every training backend: run `jobs` across
/// `workers` threads, each building its own job runner through
/// `make_worker` (called once per worker thread — the place to open
/// engines or other per-thread state). Jobs run in queue order; results
/// are returned in submission order.
pub fn run_sweep_pooled<W, R>(make_worker: W, jobs: Vec<Job>, workers: usize) -> Result<SweepReport>
where
    W: Fn() -> Result<R> + Sync,
    R: FnMut(&Job) -> JobResult,
{
    let n = jobs.len();
    if n == 0 {
        return Ok(SweepReport::default());
    }
    let workers = workers.clamp(1, n);
    println!("sweep: {n} jobs on {workers} worker(s)");

    let queue: Mutex<Vec<(usize, Job)>> =
        Mutex::new(jobs.into_iter().enumerate().rev().collect());
    let (tx, rx) = mpsc::channel::<(usize, JobResult)>();
    let queue = &queue;
    let make_worker = &make_worker;

    std::thread::scope(|s| {
        for wid in 0..workers {
            let tx = tx.clone();
            s.spawn(move || {
                // Each worker owns its runner (XLA clients are not Send).
                let mut run = match make_worker() {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("worker {wid}: backend init failed: {e:#}");
                        return;
                    }
                };
                loop {
                    let item = queue.lock().unwrap().pop();
                    let (idx, job) = match item {
                        Some(x) => x,
                        None => break,
                    };
                    let started = Instant::now();
                    let res = run(&job);
                    println!(
                        "  [worker {wid}] {} -> top1 {:.2}%{} ({:.1}s)",
                        res.name,
                        res.top1,
                        res.error.as_deref().map(|e| format!(" ERROR: {e}")).unwrap_or_default(),
                        started.elapsed().as_secs_f64()
                    );
                    if tx.send((idx, res)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
    });

    let mut indexed: Vec<(usize, JobResult)> = rx.iter().collect();
    indexed.sort_by_key(|(i, _)| *i);
    Ok(SweepReport { results: indexed.into_iter().map(|(_, r)| r).collect() })
}

/// [`run_sweep_pooled`] over per-worker XLA engines built by `make_engine`
/// (the factory is shared by reference; the engines it returns never cross
/// threads).
#[cfg(feature = "xla")]
pub fn run_sweep_with<F>(make_engine: F, jobs: Vec<Job>, workers: usize) -> Result<SweepReport>
where
    F: Fn() -> Result<Engine> + Sync,
{
    run_sweep_pooled(
        || {
            let engine = make_engine()?;
            Ok(move |job: &Job| run_job(&engine, job))
        },
        jobs,
        workers,
    )
}

/// [`run_sweep_with`] over the default XLA engine factory for
/// `artifacts_dir`.
#[cfg(feature = "xla")]
pub fn run_sweep(
    artifacts_dir: &std::path::Path,
    jobs: Vec<Job>,
    workers: usize,
) -> Result<SweepReport> {
    run_sweep_with(|| Engine::new(artifacts_dir), jobs, workers)
}

/// [`run_sweep_pooled`] over the native training backend: every worker
/// runs [`run_job_native_with_threads`] jobs with intra-op kernel threads
/// capped at `cores / workers`, so inter-job and intra-op parallelism
/// never oversubscribe the host together. No XLA/PJRT required.
pub fn run_sweep_native(jobs: Vec<Job>, workers: usize) -> Result<SweepReport> {
    // Mirror run_sweep_pooled's worker clamp so the cap matches the pool
    // that actually runs.
    let eff_workers = workers.clamp(1, jobs.len().max(1));
    let intra = (crate::runtime::kernels::hardware_threads() / eff_workers).max(1);
    run_sweep_pooled(
        || Ok(move |job: &Job| run_job_native_with_threads(job, intra)),
        jobs,
        workers,
    )
}
