//! Sweep builders: translate the paper's experiment grids (Tables 1-4,
//! Sections 3.4-3.5) into job lists, including the fp32-pretrain →
//! fine-tune dependency (the paper's protocol, Section 2.3).
//!
//! The pretrain stage runs first (one fp32 job per architecture); every
//! quantized job then points its `init_from` at the produced checkpoint.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{run_sweep_native, Job, SweepReport};

/// Scale knobs shared by all repro sweeps.
#[derive(Clone, Debug)]
pub struct SweepScale {
    pub train_size: usize,
    pub test_size: usize,
    pub epochs_fp32: usize,
    pub epochs_q: usize,
    pub epochs_q8: usize,
    pub workers: usize,
    pub out_dir: String,
    pub artifacts_dir: String,
    /// Training backend the sweep jobs run on (`"native"` or `"xla"`);
    /// defaults to `"xla"` when the feature is compiled in (the repro
    /// harness drives the AOT artifacts) and `"native"` otherwise.
    pub backend: String,
}

fn default_backend() -> String {
    if cfg!(feature = "xla") {
        "xla".into()
    } else {
        "native".into()
    }
}

impl SweepScale {
    /// Full-fidelity defaults (hours on CPU).
    pub fn standard() -> SweepScale {
        SweepScale {
            train_size: 12_800,
            test_size: 2_560,
            epochs_fp32: 40,
            epochs_q: 30,
            // Paper: 8-bit starts near the fp32 optimum and needs 1 epoch.
            epochs_q8: 3,
            workers: 1,
            out_dir: "runs".into(),
            artifacts_dir: "artifacts".into(),
            backend: default_backend(),
        }
    }

    /// Minutes-scale mode for smoke/CI (`--quick`).
    pub fn quick() -> SweepScale {
        SweepScale {
            train_size: 1_920,
            test_size: 640,
            epochs_fp32: 8,
            epochs_q: 6,
            epochs_q8: 2,
            workers: 1,
            out_dir: "runs_quick".into(),
            artifacts_dir: "artifacts".into(),
            backend: default_backend(),
        }
    }

    /// Run `jobs` on this scale's training backend via the shared worker
    /// pool.
    pub fn run_jobs(&self, jobs: Vec<Job>) -> Result<SweepReport> {
        match self.backend.as_str() {
            "native" => run_sweep_native(jobs, self.workers),
            #[cfg(feature = "xla")]
            "xla" => crate::coordinator::run_sweep(
                std::path::Path::new(&self.artifacts_dir),
                jobs,
                self.workers,
            ),
            other => anyhow::bail!(
                "train backend {other:?} is not available in this build \
                 (native always; xla needs `--features xla`)"
            ),
        }
    }

    pub fn base_cfg(&self, model: &str, bits: u32) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.model = model.to_string();
        c.bits = bits;
        c.backend = self.backend.clone();
        c.artifacts_dir = self.artifacts_dir.clone();
        c.out_dir = self.out_dir.clone();
        c.data.train_size = self.train_size;
        c.data.test_size = self.test_size;
        // LR: paper ratios (0.1 / 0.01 / 0.001) scaled 1/10 for small-batch
        // CPU runs; weight decay per Table 2.
        c.train.lr = ExperimentConfig::paper_lr(bits) * 0.5;
        c.train.weight_decay = ExperimentConfig::paper_wd(bits, 1e-4);
        c.train.epochs = if bits == 32 {
            self.epochs_fp32
        } else if bits == 8 {
            self.epochs_q8
        } else {
            self.epochs_q
        };
        c.name = format!("{model}_q{bits}");
        c
    }

    pub fn fp32_ckpt(&self, model: &str) -> PathBuf {
        PathBuf::from(&self.out_dir).join(format!("{model}_q32")).join("final.ckpt")
    }
}

/// Ensure the fp32 baselines for `models` exist (training them if missing);
/// returns their top1/top5 keyed by model.
pub fn ensure_fp32(
    scale: &SweepScale,
    models: &[&str],
) -> Result<BTreeMap<String, (f64, f64)>> {
    let mut jobs = Vec::new();
    let mut have = BTreeMap::new();
    for model in models {
        let ckpt = scale.fp32_ckpt(model);
        let hist = ckpt.parent().unwrap().join("history.json");
        if ckpt.exists() && hist.exists() {
            let h = crate::train::History::load(&hist)?;
            if let Some(e) = h.final_eval() {
                have.insert(model.to_string(), (e.top1, e.top5));
                continue;
            }
        }
        let cfg = scale.base_cfg(model, 32);
        jobs.push(Job::new(cfg).tag("model", model).tag("bits", 32));
    }
    if !jobs.is_empty() {
        let rep = scale.run_jobs(jobs)?;
        for r in rep.results {
            if let Some(e) = &r.error {
                anyhow::bail!("fp32 pretrain {} failed: {e}", r.name);
            }
            have.insert(r.tags["model"].clone(), (r.top1, r.top5));
        }
    }
    Ok(have)
}

/// Build one fine-tune job from an fp32 checkpoint.
pub fn finetune_job(scale: &SweepScale, model: &str, bits: u32) -> Job {
    let mut cfg = scale.base_cfg(model, bits);
    cfg.init_from = scale.fp32_ckpt(model).to_string_lossy().to_string();
    Job::new(cfg).tag("model", model).tag("bits", bits)
}

/// Table 1 grid: models x precisions (quantized entries; fp32 comes from
/// `ensure_fp32`).
pub fn table1_jobs(scale: &SweepScale, models: &[&str], precisions: &[u32]) -> Vec<Job> {
    let mut jobs = Vec::new();
    for model in models {
        for &bits in precisions {
            jobs.push(finetune_job(scale, model, bits));
        }
    }
    jobs
}

/// Table 2 grid: weight-decay sweep at each precision (paper: ResNet-18;
/// here the configured model).
pub fn table2_jobs(scale: &SweepScale, model: &str, precisions: &[u32]) -> Vec<Job> {
    let factors = [1.0, 0.5, 0.25, 0.125];
    let mut jobs = Vec::new();
    for &f in &factors {
        for &bits in precisions {
            let mut job = finetune_job(scale, model, bits);
            job.cfg.train.weight_decay = 1e-4 * f;
            job.cfg.name = format!("{model}_q{bits}_wd{f}");
            jobs.push(job.tag("wd", format!("{f}")));
        }
    }
    jobs
}

/// Table 3 grid: gradient-scale ablation on the 2-bit model, including the
/// no-scale + lowered-LR rows.
pub fn table3_jobs(scale: &SweepScale, model: &str) -> Vec<Job> {
    let mut jobs = Vec::new();
    let rows: [(&str, f64, &str); 6] = [
        ("full", 1.0, "1/sqrt(N*Qp)"),
        ("sqrtn", 1.0, "1/sqrt(N)"),
        ("one", 1.0, "1"),
        ("one", 0.01, "1 @ lr/100"),
        ("x10", 1.0, "10/sqrt(N*Qp)"),
        ("d10", 1.0, "1/(10 sqrt(N*Qp))"),
    ];
    for (i, (gscale, lr_factor, label)) in rows.iter().enumerate() {
        let mut job = finetune_job(scale, model, 2);
        job.cfg.gscale = gscale.to_string();
        job.cfg.train.lr *= lr_factor;
        job.cfg.name = format!("{model}_q2_gs{i}_{gscale}");
        jobs.push(job.tag("gscale", *label).tag("row", i));
    }
    jobs
}

/// Table 4: LSQ + knowledge distillation across precisions.
pub fn table4_jobs(scale: &SweepScale, models: &[&str], precisions: &[u32]) -> Vec<Job> {
    let mut jobs = Vec::new();
    for model in models {
        for &bits in precisions {
            let mut job = finetune_job(scale, model, bits);
            job.cfg.distill = true;
            job.cfg.name = format!("{model}_q{bits}_kd");
            jobs.push(job.tag("kd", "1"));
        }
    }
    jobs
}

/// Section 3.5: cosine vs step LR decay on the 2-bit model.
pub fn lr_ablation_jobs(scale: &SweepScale, model: &str) -> Vec<Job> {
    let mut cos = finetune_job(scale, model, 2);
    cos.cfg.name = format!("{model}_q2_cosine");
    let mut step = finetune_job(scale, model, 2);
    step.cfg.train.schedule = crate::config::Schedule::Step;
    step.cfg.train.step_every = (scale.epochs_q / 4).max(1);
    step.cfg.name = format!("{model}_q2_step");
    vec![cos.tag("sched", "cosine"), step.tag("sched", "step")]
}

/// Baseline quantizer-gradient comparison (Table 1 columns QIL/PACT/fixed).
pub fn method_jobs(scale: &SweepScale, model: &str, methods: &[&str]) -> Vec<Job> {
    let mut jobs = Vec::new();
    for m in methods {
        let mut job = finetune_job(scale, model, 2);
        job.cfg.method = m.to_string();
        job.cfg.name = format!("{model}_q2_{m}");
        jobs.push(job.tag("method", *m));
    }
    jobs
}

/// Merge reports.
pub fn merge(reports: Vec<SweepReport>) -> SweepReport {
    let mut out = SweepReport::default();
    for mut r in reports {
        out.results.append(&mut r.results);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_grid_size() {
        let s = SweepScale::quick();
        let jobs = table1_jobs(&s, &["cnn_small", "resnet20"], &[2, 3, 4, 8]);
        assert_eq!(jobs.len(), 8);
        assert!(jobs.iter().all(|j| !j.cfg.init_from.is_empty()));
    }

    #[test]
    fn epochs_follow_precision() {
        let s = SweepScale::quick();
        assert_eq!(s.base_cfg("m", 32).train.epochs, s.epochs_fp32);
        assert_eq!(s.base_cfg("m", 8).train.epochs, s.epochs_q8);
        assert_eq!(s.base_cfg("m", 2).train.epochs, s.epochs_q);
    }

    #[test]
    fn wd_follows_table2_rule() {
        let s = SweepScale::quick();
        assert!((s.base_cfg("m", 2).train.weight_decay - 0.25e-4).abs() < 1e-12);
        assert!((s.base_cfg("m", 3).train.weight_decay - 0.5e-4).abs() < 1e-12);
        assert!((s.base_cfg("m", 4).train.weight_decay - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn table3_has_lowered_lr_row() {
        let s = SweepScale::quick();
        let jobs = table3_jobs(&s, "cnn_small");
        assert_eq!(jobs.len(), 6);
        let lrs: Vec<f64> = jobs.iter().map(|j| j.cfg.train.lr).collect();
        assert!(lrs[3] < lrs[2]);
    }

    #[test]
    fn unique_job_names() {
        let s = SweepScale::quick();
        let mut names: Vec<String> = table2_jobs(&s, "cnn_small", &[2, 3, 4, 8])
            .iter()
            .map(|j| j.cfg.name.clone())
            .collect();
        let n = names.len();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), n);
    }
}
