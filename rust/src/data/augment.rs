//! Training-time augmentation, following the paper's recipe scaled to 32px:
//! pad-and-random-crop (4 px, the CIFAR analogue of the paper's 256→224
//! random crop) and horizontal mirroring half the time.

use super::synth::{CHANNELS, IMG};
use crate::util::rng::Pcg32;

pub const PAD: usize = 4;

/// Random 4-px-pad crop + 50% horizontal mirror, in place via a scratch
/// buffer. `img` is HWC 32x32x3.
pub fn augment(img: &mut [f32], scratch: &mut Vec<f32>, rng: &mut Pcg32) {
    debug_assert_eq!(img.len(), IMG * IMG * CHANNELS);
    let padded = IMG + 2 * PAD;
    scratch.clear();
    scratch.resize(padded * padded * CHANNELS, 0.0);
    // zero-pad
    for y in 0..IMG {
        for x in 0..IMG {
            for c in 0..CHANNELS {
                scratch[((y + PAD) * padded + (x + PAD)) * CHANNELS + c] =
                    img[(y * IMG + x) * CHANNELS + c];
            }
        }
    }
    let oy = rng.below((2 * PAD + 1) as u32) as usize;
    let ox = rng.below((2 * PAD + 1) as u32) as usize;
    let mirror = rng.bool(0.5);
    for y in 0..IMG {
        for x in 0..IMG {
            let sx = if mirror { IMG - 1 - x } else { x };
            for c in 0..CHANNELS {
                img[(y * IMG + x) * CHANNELS + c] =
                    scratch[((y + oy) * padded + (sx + ox)) * CHANNELS + c];
            }
        }
    }
}

/// Pure horizontal mirror (for tests).
pub fn mirror(img: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0; img.len()];
    for y in 0..IMG {
        for x in 0..IMG {
            for c in 0..CHANNELS {
                out[(y * IMG + x) * CHANNELS + c] =
                    img[(y * IMG + (IMG - 1 - x)) * CHANNELS + c];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn mirror_involution() {
        let img = SynthSpec::new(10, 0.3, 0).generate_alloc(3);
        assert_eq!(mirror(&mirror(&img)), img);
    }

    #[test]
    fn augment_preserves_len_and_changes_content() {
        let spec = SynthSpec::new(10, 0.3, 0);
        let orig = spec.generate_alloc(5);
        let mut img = orig.clone();
        let mut scratch = Vec::new();
        let mut rng = Pcg32::seeded(9);
        augment(&mut img, &mut scratch, &mut rng);
        assert_eq!(img.len(), orig.len());
        assert_ne!(img, orig); // offset (4,4) with no mirror has p≈1/162
    }

    #[test]
    fn augment_center_crop_no_mirror_is_identity() {
        // Find a seed whose first draw is (oy=4, ox=4, mirror=false).
        let spec = SynthSpec::new(10, 0.3, 0);
        for seed in 0..5000u64 {
            let mut rng = Pcg32::seeded(seed);
            let oy = rng.below(9);
            let ox = rng.below(9);
            let m = rng.bool(0.5);
            if oy == 4 && ox == 4 && !m {
                let orig = spec.generate_alloc(1);
                let mut img = orig.clone();
                let mut scratch = Vec::new();
                let mut rng = Pcg32::seeded(seed);
                augment(&mut img, &mut scratch, &mut rng);
                assert_eq!(img, orig);
                return;
            }
        }
        panic!("no identity seed found");
    }

    #[test]
    fn augment_deterministic_under_seed() {
        let spec = SynthSpec::new(10, 0.3, 0);
        let mut a = spec.generate_alloc(2);
        let mut b = a.clone();
        let mut s1 = Vec::new();
        let mut s2 = Vec::new();
        augment(&mut a, &mut s1, &mut Pcg32::seeded(4));
        augment(&mut b, &mut s2, &mut Pcg32::seeded(4));
        assert_eq!(a, b);
    }
}
