//! Batched data pipeline with background prefetch and backpressure.
//!
//! Producer threads generate+augment images into a bounded channel
//! (`sync_channel`), so generation overlaps XLA execution and never runs
//! unboundedly ahead — the paper-training analogue of an input pipeline.
//! Epoch order is a seeded shuffle; iteration is deterministic given
//! (data seed, train seed, epoch).

use std::sync::mpsc::{Receiver, SyncSender};
use std::thread::JoinHandle;

use crate::config::DataConfig;
use crate::data::augment::augment;
use crate::data::synth::{SynthSpec, PIXELS};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
    /// Number of real (non-padded) examples — the tail batch of an eval
    /// pass may be padded up to the artifact's fixed batch size.
    pub real: usize,
}

/// Synchronous batch source (used directly by eval and by tests).
pub struct Dataset {
    pub spec: SynthSpec,
    pub size: usize,
    /// Index offset: the test split lives after the train split in the
    /// infinite procedural index space.
    pub base: usize,
}

impl Dataset {
    pub fn train(cfg: &DataConfig) -> Dataset {
        Dataset {
            spec: SynthSpec::new(cfg.classes, cfg.noise, cfg.seed),
            size: cfg.train_size,
            base: 0,
        }
    }

    pub fn test(cfg: &DataConfig) -> Dataset {
        Dataset {
            spec: SynthSpec::new(cfg.classes, cfg.noise, cfg.seed),
            size: cfg.test_size,
            base: cfg.train_size,
        }
    }

    /// Materialize a batch from explicit dataset indices, padding (by
    /// repeating index 0) to `batch` rows if fewer are given.
    pub fn batch_from_indices(&self, indices: &[usize], batch: usize) -> Batch {
        assert!(indices.len() <= batch && !indices.is_empty());
        let mut x = vec![0.0f32; batch * PIXELS];
        let mut y = vec![0i32; batch];
        for row in 0..batch {
            let idx = self.base + *indices.get(row).unwrap_or(&indices[0]);
            self.spec.generate(idx, &mut x[row * PIXELS..(row + 1) * PIXELS]);
            y[row] = self.spec.label(idx);
        }
        Batch {
            x: Tensor::from_f32(&[batch, 32, 32, 3], x),
            y: Tensor::from_i32(&[batch], y),
            real: indices.len(),
        }
    }

    /// Sequential full pass as fixed-size batches (for evaluation).
    pub fn eval_batches(&self, batch: usize) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.size {
            let n = batch.min(self.size - i);
            let idx: Vec<usize> = (i..i + n).collect();
            out.push(self.batch_from_indices(&idx, batch));
            i += n;
        }
        out
    }
}

/// Background prefetching loader for training.
pub struct Loader {
    rx: Receiver<Batch>,
    handle: Option<JoinHandle<()>>,
    pub batches_per_epoch: usize,
}

impl Loader {
    /// Spawn a producer for `epochs` epochs of shuffled, augmented batches.
    /// `depth` bounds the prefetch queue (backpressure).
    pub fn spawn(
        data_cfg: &DataConfig,
        batch: usize,
        epochs: usize,
        train_seed: u64,
        depth: usize,
    ) -> Loader {
        let (tx, rx): (SyncSender<Batch>, Receiver<Batch>) =
            std::sync::mpsc::sync_channel(depth.max(1));
        let cfg = data_cfg.clone();
        let augment_on = cfg.augment;
        let ds = Dataset::train(&cfg);
        let batches_per_epoch = ds.size / batch;
        let handle = std::thread::Builder::new()
            .name("lsq-data".into())
            .spawn(move || {
                let mut scratch = Vec::new();
                let mut order: Vec<usize> = (0..ds.size).collect();
                'outer: for epoch in 0..epochs {
                    let mut rng = Pcg32::seeded(
                        train_seed ^ 0xdead_beef ^ (epoch as u64).wrapping_mul(0x100_0001b3),
                    );
                    rng.shuffle(&mut order);
                    for chunk in order.chunks_exact(batch) {
                        let mut b = ds.batch_from_indices(chunk, batch);
                        if augment_on {
                            let xs = b.x.f32s_mut().expect("train batch is f32");
                            for row in 0..batch {
                                augment(
                                    &mut xs[row * PIXELS..(row + 1) * PIXELS],
                                    &mut scratch,
                                    &mut rng,
                                );
                            }
                        }
                        if tx.send(b).is_err() {
                            break 'outer; // consumer dropped
                        }
                    }
                }
            })
            .expect("spawn data thread");
        Loader { rx, handle: Some(handle), batches_per_epoch }
    }

    pub fn next(&self) -> Option<Batch> {
        self.rx.recv().ok()
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        // Unblock the producer by draining, then join.
        while self.rx.try_recv().is_ok() {}
        drop(std::mem::replace(&mut self.rx, std::sync::mpsc::sync_channel(1).1));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataConfig {
        DataConfig { train_size: 64, test_size: 32, classes: 4, noise: 0.2, seed: 3, augment: true }
    }

    #[test]
    fn eval_batches_cover_all_with_padding() {
        let ds = Dataset::test(&cfg());
        let batches = ds.eval_batches(10);
        assert_eq!(batches.len(), 4); // 32/10 -> 10,10,10,2
        assert_eq!(batches[3].real, 2);
        let total: usize = batches.iter().map(|b| b.real).sum();
        assert_eq!(total, 32);
        for b in &batches {
            assert_eq!(b.x.shape, vec![10, 32, 32, 3]);
        }
    }

    #[test]
    fn train_and_test_splits_disjoint() {
        let c = cfg();
        let tr = Dataset::train(&c);
        let te = Dataset::test(&c);
        let a = tr.batch_from_indices(&[0], 1);
        let b = te.batch_from_indices(&[0], 1);
        assert_ne!(a.x, b.x); // test index 0 = raw index train_size
    }

    #[test]
    fn loader_yields_expected_count_and_is_deterministic() {
        let c = cfg();
        let collect = || -> Vec<Vec<i32>> {
            let l = Loader::spawn(&c, 16, 2, 42, 2);
            let mut ys = Vec::new();
            while let Some(b) = l.next() {
                ys.push(b.y.i32s().unwrap().to_vec());
            }
            ys
        };
        let a = collect();
        assert_eq!(a.len(), 2 * (64 / 16));
        let b = collect();
        assert_eq!(a, b);
    }

    #[test]
    fn loader_epochs_are_reshuffled() {
        let c = cfg();
        let l = Loader::spawn(&c, 16, 2, 1, 2);
        let mut epochs: Vec<Vec<i32>> = vec![Vec::new(), Vec::new()];
        for i in 0..8 {
            let b = l.next().unwrap();
            epochs[i / 4].extend_from_slice(b.y.i32s().unwrap());
        }
        assert_ne!(epochs[0], epochs[1]);
    }

    #[test]
    fn drop_mid_epoch_does_not_hang() {
        let c = cfg();
        let l = Loader::spawn(&c, 16, 100, 1, 1);
        let _ = l.next();
        drop(l); // must join cleanly
    }
}
