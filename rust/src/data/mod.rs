//! Data substrate: the procedural `synthshapes` dataset (ImageNet stand-in,
//! see DESIGN.md §Substitutions), paper-style augmentation, and a prefetching
//! batched loader with backpressure.

pub mod augment;
pub mod loader;
pub mod synth;

pub use loader::{Batch, Dataset, Loader};
pub use synth::SynthSpec;
