//! `synthshapes`: a procedurally generated 32x32x3 classification dataset —
//! the in-repo stand-in for ImageNet (see DESIGN.md §Substitutions).
//!
//! Every image is generated deterministically from (dataset_seed, index):
//! class identity fixes an oriented grating frequency/angle, a color tint
//! and a geometric mask family; the instance seed jitters phase, position,
//! scale and adds background noise. The task is non-trivial (fp32 models
//! plateau well below 100% at high noise) yet learnable in minutes on CPU,
//! which is what the quantization-dynamics experiments need.
//!
//! Images are emitted already standardized to roughly zero mean / unit std.

use crate::util::rng::Pcg32;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const PIXELS: usize = IMG * IMG * CHANNELS;

#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub classes: usize,
    pub noise: f32,
    pub seed: u64,
}

impl SynthSpec {
    pub fn new(classes: usize, noise: f32, seed: u64) -> Self {
        assert!(classes >= 2 && classes <= 32, "classes in 2..=32");
        SynthSpec { classes, noise, seed }
    }

    /// Class label for dataset index `i` (balanced round-robin).
    pub fn label(&self, index: usize) -> i32 {
        (index % self.classes) as i32
    }

    /// Generate image `index` into `out` (length PIXELS, HWC layout).
    pub fn generate(&self, index: usize, out: &mut [f32]) {
        assert_eq!(out.len(), PIXELS);
        let class = self.label(index) as usize;
        let mut rng = Pcg32::new(
            self.seed ^ (index as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
            0x5851_f42d_4c95_7f2d ^ index as u64,
        );

        // -- class-determined structure ------------------------------------
        let angle = std::f32::consts::PI * (class as f32) / (self.classes as f32);
        let (sin_a, cos_a) = angle.sin_cos();
        let freq = 0.25 + 0.05 * ((class % 3) as f32); // cycles per pixel
        // tint: three phase-shifted cosines over the class index
        let tint = [
            0.6 + 0.4 * (class as f32 * 2.4).cos(),
            0.6 + 0.4 * (class as f32 * 2.4 + 2.1).cos(),
            0.6 + 0.4 * (class as f32 * 2.4 + 4.2).cos(),
        ];
        let mask_kind = class % 3; // 0 disc, 1 square, 2 diagonal band

        // -- instance jitter --------------------------------------------------
        let phase = rng.range_f32(0.0, std::f32::consts::TAU);
        let cx = 16.0 + rng.range_f32(-4.0, 4.0);
        let cy = 16.0 + rng.range_f32(-4.0, 4.0);
        let radius = rng.range_f32(8.0, 13.0);
        let freq = freq * rng.range_f32(0.9, 1.1);
        let contrast = rng.range_f32(0.8, 1.2);

        for y in 0..IMG {
            for x in 0..IMG {
                let fx = x as f32 - cx;
                let fy = y as f32 - cy;
                // oriented grating
                let t = (fx * cos_a + fy * sin_a) * freq * std::f32::consts::TAU;
                let grating = (t + phase).sin();
                // geometric mask
                let inside = match mask_kind {
                    0 => fx * fx + fy * fy <= radius * radius,
                    1 => fx.abs().max(fy.abs()) <= radius,
                    _ => (fx + fy).abs() <= radius * 0.9,
                };
                let shape = if inside { 1.0 } else { 0.15 };
                for c in 0..CHANNELS {
                    let signal = grating * shape * tint[c] * contrast;
                    let noise = self.noise * rng.normal();
                    out[(y * IMG + x) * CHANNELS + c] = signal + noise;
                }
            }
        }

        // standardize per image
        let n = out.len() as f32;
        let mean: f32 = out.iter().sum::<f32>() / n;
        let var: f32 = out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var.sqrt() + 1e-5);
        for v in out.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }

    pub fn generate_alloc(&self, index: usize) -> Vec<f32> {
        let mut v = vec![0.0; PIXELS];
        self.generate(index, &mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = SynthSpec::new(10, 0.3, 7);
        assert_eq!(spec.generate_alloc(42), spec.generate_alloc(42));
    }

    #[test]
    fn instances_differ() {
        let spec = SynthSpec::new(10, 0.3, 7);
        assert_ne!(spec.generate_alloc(0), spec.generate_alloc(10)); // same class
        assert_ne!(spec.generate_alloc(0), spec.generate_alloc(1)); // diff class
    }

    #[test]
    fn seeds_change_data() {
        let a = SynthSpec::new(10, 0.3, 1).generate_alloc(5);
        let b = SynthSpec::new(10, 0.3, 2).generate_alloc(5);
        assert_ne!(a, b);
    }

    #[test]
    fn standardized() {
        let spec = SynthSpec::new(10, 0.5, 3);
        let img = spec.generate_alloc(13);
        let n = img.len() as f32;
        let mean: f32 = img.iter().sum::<f32>() / n;
        let var: f32 = img.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        assert!(mean.abs() < 1e-3, "mean={mean}");
        assert!((var - 1.0).abs() < 1e-2, "var={var}");
    }

    #[test]
    fn labels_balanced() {
        let spec = SynthSpec::new(10, 0.3, 0);
        let mut counts = [0usize; 10];
        for i in 0..1000 {
            counts[spec.label(i) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn classes_are_separable_by_template_correlation() {
        // Nearest-class-template classification must beat chance by a wide
        // margin — i.e. the dataset actually carries class signal.
        let spec = SynthSpec::new(4, 0.2, 11);
        // class templates: average of 24 instances
        let mut templates = vec![vec![0.0f32; PIXELS]; 4];
        for c in 0..4 {
            for k in 0..24 {
                let img = spec.generate_alloc(c + 4 * k);
                for (t, v) in templates[c].iter_mut().zip(&img) {
                    *t += v / 24.0;
                }
            }
        }
        let mut correct = 0;
        let total = 80;
        for i in 1000..1000 + total {
            let img = spec.generate_alloc(i);
            let truth = spec.label(i) as usize;
            let best = (0..4)
                .max_by(|&a, &b| {
                    let sa: f32 = templates[a].iter().zip(&img).map(|(t, v)| t * v).sum();
                    let sb: f32 = templates[b].iter().zip(&img).map(|(t, v)| t * v).sum();
                    sa.partial_cmp(&sb).unwrap()
                })
                .unwrap();
            if best == truth {
                correct += 1;
            }
        }
        assert!(
            correct * 4 > total, // > 25% chance level... require > 50%
            "template classifier got {correct}/{total}"
        );
        assert!(correct * 2 > total, "template classifier got {correct}/{total}");
    }
}
