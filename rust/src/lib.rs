//! # lsqnet
//!
//! A three-layer reproduction of *Learned Step Size Quantization*
//! (Esser et al., ICLR 2020):
//!
//! * **Layer 1** — Pallas kernels (LSQ quantizer fwd/bwd, int-domain matmul),
//!   compiled AOT from Python, never executed by Python at run time.
//! * **Layer 2** — JAX model zoo + QAT train/eval steps, lowered to HLO text.
//! * **Layer 3** — this crate: the coordinator that owns configs, data,
//!   training loops, sweeps, analysis, serving and the repro harness.
//!
//! ## Execution backends
//!
//! Inference dispatches over the [`runtime::Backend`] trait and training
//! over [`train::TrainBackend`] (see DESIGN.md §Backend-trait /
//! §Native-training):
//!
//! * [`runtime::NativeEngine`] — pure-Rust packed-weight integer inference
//!   (Eq. 1/2 executed from 2/3/4/8-bit weights, `i32` accumulation).
//!   Always available; needs no XLA, PJRT or Python.
//! * [`train::NativeTrainer`] — pure-Rust LSQ *training*: hand-written
//!   backward pass with the Eq. 3 step-size gradient and the Section-2.2
//!   `1/√(N·Qp)` scale. Always available; `cargo run -- train` uses it by
//!   default.
//! * `runtime::Engine` + `train::Trainer` — the XLA/PJRT executor for the
//!   AOT HLO artifacts; the repro harness and the `xla` train backend live
//!   here, behind `--features xla`.
//!
//! Entry points: the `lsqnet` binary (see `main.rs`),
//! [`serve::ModelRegistry`] for the multi-model dynamic-batching gateway
//! (named per-precision [`serve::Session`]s, hot load/unload;
//! [`serve::Server`] remains as the one-variant shim),
//! [`serve::net::NetServer`]/[`serve::net::NetClient`] for the TCP wire
//! protocol over that gateway (`lsqnet serve --listen`),
//! [`train::NativeTrainer`], and (with `xla`) `runtime::Engine` +
//! `train::Trainer`. See README.md for the command-line quickstart and
//! EXPERIMENTS.md for the perf ladder the benches report against.

#![warn(missing_docs)]

pub mod analyze;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod quant;
#[cfg(feature = "xla")]
pub mod repro;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;
