//! # lsqnet
//!
//! A three-layer reproduction of *Learned Step Size Quantization*
//! (Esser et al., ICLR 2020):
//!
//! * **Layer 1** — Pallas kernels (LSQ quantizer fwd/bwd, int-domain matmul),
//!   compiled AOT from Python, never executed by Python at run time.
//! * **Layer 2** — JAX model zoo + QAT train/eval steps, lowered to HLO text.
//! * **Layer 3** — this crate: the coordinator that owns configs, data,
//!   training loops, sweeps, analysis, serving and the repro harness.
//!
//! Entry points: the `lsqnet` binary (see `main.rs`) and the public modules
//! below. Start with [`runtime::Engine`] + [`train::Trainer`].

pub mod analyze;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;
