//! `lsqnet` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   info                         inspect artifacts/manifest
//!   train                        run one experiment (flags or --config);
//!                                native backward by default, `--backend xla`
//!                                for the AOT artifacts
//!   eval                         evaluate a checkpoint on the test split
//!   sweep --config <json>        run a list of experiment configs
//!   repro <table1|...|all>       regenerate a paper table/figure         [xla]
//!   serve                        start the multi-model quantized-inference
//!                                registry (native backend by default; one
//!                                process serves N precision variants)
//!   pack                         quantize+pack a checkpoint, report size;
//!                                with --out, write a zero-copy `.lsqa`
//!                                artifact (weights + prebuilt SIMD panels)
//!   artifact inspect <m.lsqa>    verify + describe a packed artifact
//!   simd-levels                  list the host's runnable SIMD dispatch
//!                                levels (feeds the CI forced-level matrix)
//!
//! Commands tagged [xla] (and the xla train/eval/sweep backend) drive the
//! AOT artifacts and require building with `--features xla`; everything
//! else runs on the native backends.
//!
//! Common flags: --artifacts <dir> --out-dir <dir> --quick --workers N

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use lsqnet::runtime::Manifest;
use lsqnet::tensor::Checkpoint;
use lsqnet::util::cli::Args;

const USAGE: &str = "\
lsqnet — Learned Step Size Quantization (ICLR 2020) coordinator

USAGE: lsqnet <command> [flags]

COMMANDS
  info                     list artifacts, families and parameter counts
  train                    train one model (native backend by default; a
                           synthetic fixture family is written when the
                           artifacts dir has no manifest)
                           --model mlp --bits 3 [--method lsq]
                           [--gscale full] [--epochs N] [--lr X] [--wd X]
                           [--train-size N] [--noise X] [--max-steps N]
                           [--init-from ck.ckpt] [--config c.json]
                           [--backend native|xla] [--distill (xla)]
  eval                     --checkpoint runs/x/final.ckpt [--test-size N]
                           [--backend native|xla]
  sweep                    --config sweep.json (array of configs)
                           [--backend native|xla] [--workers N]
  repro <target>           table1|table2|table3|table4|lr-ablation|
                           fig2|fig3|fig4|qerror|all   [--quick] [--workers N]
                                                               [needs --features xla]
  serve                    --family cnn_small_q2[,cnn_small_q4,…] (one
                           registry process serves every named precision
                           variant through its own session + replica set)
                           [--backend native|xla] [--replicas N (per variant)]
                           [--checkpoint ck (single variant only)]
                           [--requests N (round-robin across variants)]
                           [--threads N (intra-op per replica; 0 = share
                            the core budget across all replicas)]
                           [--fused-unpack (low-memory weights: unpack per
                            call instead of panelizing once at bind)]
                           [--listen ADDR (e.g. 127.0.0.1:7878; expose the
                            registry over the TCP wire protocol — DESIGN.md
                            §Wire-protocol. Smoke traffic then runs over
                            real sockets; --requests 0 serves until killed)]
                           [--tiers a_q8,a_q4,a_q2 (expensive→cheap
                            precision ladder; loads exactly those families
                            and starts the SLO tier controller — smoke
                            traffic and the wire `tiered` op then route to
                            whichever tier the control loop favors)]
                           [--slo-ms X (default 5.0; per-request queue-
                            latency objective driving the tier controller)]
                           [--retry N (wire smoke clients retry transient
                            errors up to N attempts with jittered backoff;
                            0 = fail fast, the default)]
                           [--deadline-ms MS (wire smoke requests carry a
                            queue budget; the server sheds them with
                            deadline_exceeded once it expires; 0 = none)]
                           [--artifact m.lsqa[,m2.lsqa,…] (bind each variant
                            from a packed `.lsqa` artifact instead of the
                            manifest: family names come from the artifacts
                            and every replica borrows panels from one
                            verified arena — the fleet cold-start path.
                            Native only; excludes --tiers/--checkpoint)]
                           (the end-of-run report includes a health line:
                            replica failures/restarts, deadline sheds, and
                            tier sheds)
  pack                     size report: --checkpoint runs/x/final.ckpt
                           artifact:    --family cnn_small_q2 --out m.lsqa
                           [--checkpoint ck] [--levels scalar,avx2,…]
                           (quantizes + packs the family and freezes
                            prebuilt SIMD panel sections into one
                            zero-copy file — DESIGN.md §Artifact-format)
  artifact                 inspect <m.lsqa> — verify every checksum, then
                           print the header, section table and per-level
                           panel geometries
  simd-levels              list the SIMD dispatch levels this host can run
                           (one name per line, worst->best; each is a valid
                           LSQNET_SIMD value — CI's forced-level matrix
                           iterates this list)
  help                     this message

COMMON FLAGS
  --artifacts DIR   (default: artifacts)   --out-dir DIR (default: runs)
  --quick           minutes-scale repro    --workers N   sweep parallelism

The xla train/eval/sweep backend and the repro harness drive the AOT
artifacts and require building with `--features xla`; everything else runs
natively.";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let code = match dispatch(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str("artifacts", "artifacts"))
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "info" => info(args),
        "train" => train(args),
        "eval" => eval(args),
        "sweep" => sweep(args),
        "repro" => repro(args),
        "serve" => serve(args),
        "pack" => pack(args),
        "artifact" => artifact_cmd(args),
        "simd-levels" => {
            // Machine-consumable by design: ci.sh iterates this list to
            // drive the forced-level kernel parity matrix.
            for level in lsqnet::runtime::kernels::SimdLevel::available_levels() {
                println!("{}", level.name());
            }
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `lsqnet help`"),
    }
}

fn info(args: &Args) -> Result<()> {
    let m = Manifest::load(&artifacts_dir(args))?;
    println!("backends        : native{}", if cfg!(feature = "xla") { ", xla" } else { "" });
    println!("artifact batch  : {}", m.batch);
    println!("families        : {}", m.families.len());
    for (name, f) in &m.families {
        println!(
            "  {name:<22} model={:<12} bits={:<2} params={:<4} weights={}",
            f.model,
            f.qbits,
            f.param_names.len(),
            f.total_weights()
        );
    }
    println!("artifacts       : {}", m.artifacts.len());
    let mut by_kind: std::collections::BTreeMap<&str, usize> = Default::default();
    for a in m.artifacts.values() {
        *by_kind.entry(a.kind.as_str()).or_default() += 1;
    }
    for (k, n) in by_kind {
        println!("  {k:<12} x{n}");
    }
    Ok(())
}

fn cfg_from_args(args: &Args) -> Result<lsqnet::config::ExperimentConfig> {
    use lsqnet::config::ExperimentConfig;
    let mut cfg = if let Some(path) = args.opt_str("config") {
        ExperimentConfig::load(Path::new(&path))?
    } else {
        ExperimentConfig::default()
    };
    if let Some(m) = args.opt_str("model") {
        cfg.model = m;
    }
    if args.has("bits") {
        cfg.bits = args.usize("bits", cfg.bits as usize) as u32;
    }
    if let Some(m) = args.opt_str("method") {
        cfg.method = m;
    }
    if let Some(g) = args.opt_str("gscale") {
        cfg.gscale = g;
    }
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = b;
    }
    if args.has("noise") {
        cfg.data.noise = args.f64("noise", cfg.data.noise as f64) as f32;
    }
    if args.has("epochs") {
        cfg.train.epochs = args.usize("epochs", cfg.train.epochs);
    }
    if args.has("max-steps") {
        cfg.train.max_steps = args.usize("max-steps", 0);
    }
    if args.has("lr") {
        cfg.train.lr = args.f64("lr", cfg.train.lr);
    }
    if args.has("wd") {
        cfg.train.weight_decay = args.f64("wd", cfg.train.weight_decay);
    }
    if args.has("schedule") {
        cfg.train.schedule = lsqnet::config::Schedule::parse(&args.str("schedule", "cosine"))?;
    }
    if args.has("train-size") {
        cfg.data.train_size = args.usize("train-size", cfg.data.train_size);
    }
    if args.has("test-size") {
        cfg.data.test_size = args.usize("test-size", cfg.data.test_size);
    }
    if args.has("seed") {
        cfg.train.seed = args.u64("seed", cfg.train.seed);
        cfg.data.seed = cfg.train.seed.wrapping_add(1);
    }
    if let Some(p) = args.opt_str("init-from") {
        cfg.init_from = p;
    }
    if args.flag("distill") {
        cfg.distill = true;
    }
    cfg.artifacts_dir = args.str("artifacts", &cfg.artifacts_dir);
    cfg.out_dir = args.str("out-dir", &cfg.out_dir);
    if let Some(n) = args.opt_str("name") {
        cfg.name = n;
    } else if !args.has("config") {
        cfg.name = format!("{}_q{}_{}", cfg.model, cfg.bits, cfg.method);
    }
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(not(feature = "xla"))]
fn needs_xla(cmd: &str) -> Result<()> {
    bail!(
        "`lsqnet {cmd}` with the xla backend drives the AOT artifacts; rebuild with \
         `cargo build --release --features xla` or use `--backend native` \
         (see README.md feature matrix)"
    )
}

fn train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    match cfg.backend.as_str() {
        "native" => train_native(cfg),
        _ => train_xla(args, cfg),
    }
}

/// Synthesize a fixture family for `cfg`'s (model, bits) when its
/// artifacts dir lacks one, reusing the existing manifest's geometry if
/// there is one — the zero-artifacts path shared by the native `train`
/// and `sweep` commands.
fn ensure_native_family(cfg: &lsqnet::config::ExperimentConfig) -> Result<()> {
    use lsqnet::runtime::native::fixture::{ensure_family, FixtureSpec};
    let dir = PathBuf::from(&cfg.artifacts_dir);
    let spec = match Manifest::load(&dir) {
        Ok(m) => FixtureSpec {
            image: m.image,
            channels: m.channels,
            num_classes: cfg.data.classes,
            batch: m.batch,
            ..FixtureSpec::default()
        },
        Err(_) => {
            println!(
                "no manifest in {} — writing a synthetic fixture family",
                dir.display()
            );
            FixtureSpec { num_classes: cfg.data.classes, ..FixtureSpec::default() }
        }
    };
    ensure_family(&dir, &cfg.model, cfg.bits, spec)?;
    Ok(())
}

/// Native training: no XLA, no Python. When the artifacts dir has no
/// manifest (or lacks the requested family), a synthetic fixture family is
/// synthesized in place, so `cargo run -- train` works from a clean clone.
fn train_native(cfg: lsqnet::config::ExperimentConfig) -> Result<()> {
    use lsqnet::train::NativeTrainer;
    ensure_native_family(&cfg)?;
    println!(
        "training {} (family {}, method {}, gscale {}, backend native)",
        cfg.name,
        cfg.family(),
        cfg.method,
        cfg.gscale
    );
    let mut tr = NativeTrainer::new(cfg)?;
    let rep = tr.fit()?;
    println!(
        "done: top1 {:.2}%  top5 {:.2}%  wall {:.1}s  -> {}",
        rep.final_top1,
        rep.final_top5,
        rep.history.wall_seconds,
        rep.checkpoint.display()
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn train_xla(_args: &Args, cfg: lsqnet::config::ExperimentConfig) -> Result<()> {
    use lsqnet::runtime::Engine;
    use lsqnet::train::Trainer;
    let engine = Engine::new(Path::new(&cfg.artifacts_dir))?;
    println!(
        "training {} (family {}, method {}, gscale {}, backend xla)",
        cfg.name,
        cfg.family(),
        cfg.method,
        cfg.gscale
    );
    let mut tr = Trainer::new(&engine, cfg)?;
    let rep = tr.fit()?;
    println!(
        "done: top1 {:.2}%  top5 {:.2}%  wall {:.1}s  driver-overhead {:.2}%  -> {}",
        rep.final_top1,
        rep.final_top5,
        rep.history.wall_seconds,
        100.0 * tr.driver_overhead(),
        rep.checkpoint.display()
    );
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn train_xla(_args: &Args, _cfg: lsqnet::config::ExperimentConfig) -> Result<()> {
    needs_xla("train")
}

fn eval(args: &Args) -> Result<()> {
    let backend = args.str("backend", "native");
    let ckpt_path = args.opt_str("checkpoint").context("--checkpoint required")?;
    match backend.as_str() {
        "native" => {
            use lsqnet::train::NativeTrainer;
            let manifest = Manifest::load(&artifacts_dir(args))?;
            let ck = Checkpoint::load(Path::new(&ckpt_path))?;
            let family = ck
                .meta_str("family")
                .context("checkpoint missing family meta")?
                .to_string();
            let fam = manifest.family(&family)?;
            let mut cfg = lsqnet::config::ExperimentConfig::default();
            cfg.model = fam.model.clone();
            cfg.bits = fam.qbits;
            // Labels must stay inside the family's logit range.
            cfg.data.classes = fam.num_classes;
            cfg.init_from = ckpt_path.clone();
            cfg.artifacts_dir = args.str("artifacts", "artifacts");
            if args.has("test-size") {
                cfg.data.test_size = args.usize("test-size", cfg.data.test_size);
            }
            let mut tr = NativeTrainer::new(cfg)?;
            let (loss, t1, t5) = tr.evaluate()?;
            println!("{family}: loss {loss:.4}  top1 {t1:.2}%  top5 {t5:.2}%");
            Ok(())
        }
        "xla" => eval_xla(args, &ckpt_path),
        other => bail!("unknown eval backend {other:?} (native|xla)"),
    }
}

#[cfg(feature = "xla")]
fn eval_xla(args: &Args, ckpt_path: &str) -> Result<()> {
    use lsqnet::runtime::Engine;
    use lsqnet::train::Trainer;
    let engine = Engine::new(&artifacts_dir(args))?;
    let ck = Checkpoint::load(Path::new(ckpt_path))?;
    let family = ck
        .meta_str("family")
        .context("checkpoint missing family meta")?
        .to_string();
    let fam = engine.manifest().family(&family)?.clone();
    let mut cfg = lsqnet::config::ExperimentConfig::default();
    cfg.model = fam.model.clone();
    cfg.bits = fam.qbits;
    cfg.backend = "xla".to_string();
    cfg.init_from = ckpt_path.to_string();
    cfg.artifacts_dir = args.str("artifacts", "artifacts");
    if args.has("test-size") {
        cfg.data.test_size = args.usize("test-size", cfg.data.test_size);
    }
    let mut tr = Trainer::new(&engine, cfg)?;
    let (loss, t1, t5) = tr.evaluate()?;
    println!("{family}: loss {loss:.4}  top1 {t1:.2}%  top5 {t5:.2}%");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn eval_xla(_args: &Args, _ckpt: &str) -> Result<()> {
    needs_xla("eval")
}

fn sweep(args: &Args) -> Result<()> {
    use lsqnet::coordinator::{Job, SweepReport};
    use lsqnet::util::json::Json;
    let path = args
        .opt_str("config")
        .context("--config required (JSON array of configs)")?;
    let text = std::fs::read_to_string(&path)?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let arr = j.as_arr().context("sweep config must be a JSON array")?;
    // Each config picks its own train backend; --backend overrides all,
    // and --artifacts overrides every job's artifacts_dir (matching the
    // xla engine, which always opens the flag directory).
    let mut native_jobs: Vec<(usize, Job)> = Vec::new();
    let mut xla_jobs: Vec<(usize, Job)> = Vec::new();
    for (i, item) in arr.iter().enumerate() {
        let mut cfg = lsqnet::config::ExperimentConfig::from_json(item)?;
        if let Some(b) = args.opt_str("backend") {
            cfg.backend = b;
            cfg.validate()?;
        }
        if args.has("artifacts") {
            cfg.artifacts_dir = args.str("artifacts", &cfg.artifacts_dir);
        }
        match cfg.backend.as_str() {
            "xla" => xla_jobs.push((i, Job::new(cfg))),
            _ => native_jobs.push((i, Job::new(cfg))),
        }
    }
    let workers = args.usize("workers", 2);
    // Run each backend's partition, then restore submission order. A
    // failing partition must not discard the other's finished results:
    // the (possibly partial) report is saved before the error propagates.
    let mut indexed: Vec<(usize, lsqnet::coordinator::JobResult)> = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    if !native_jobs.is_empty() {
        // Same zero-artifacts affordance as `train`: synthesize missing
        // fixture families before the workers start.
        for (_, job) in &native_jobs {
            ensure_native_family(&job.cfg)?;
        }
        let (idxs, jobs): (Vec<usize>, Vec<Job>) = native_jobs.into_iter().unzip();
        match lsqnet::coordinator::run_sweep_native(jobs, workers) {
            Ok(rep) => indexed.extend(idxs.into_iter().zip(rep.results)),
            Err(e) => first_err = Some(e),
        }
    }
    if first_err.is_none() && !xla_jobs.is_empty() {
        let (idxs, jobs): (Vec<usize>, Vec<Job>) = xla_jobs.into_iter().unzip();
        match sweep_xla(args, jobs, workers) {
            Ok(rep) => indexed.extend(idxs.into_iter().zip(rep.results)),
            Err(e) => first_err = Some(e),
        }
    }
    indexed.sort_by_key(|(i, _)| *i);
    let report = SweepReport { results: indexed.into_iter().map(|(_, r)| r).collect() };
    let out = Path::new(&args.str("out-dir", "runs")).join("sweep_report.json");
    report.save(&out)?;
    println!("report -> {}", out.display());
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(feature = "xla")]
fn sweep_xla(
    args: &Args,
    jobs: Vec<lsqnet::coordinator::Job>,
    workers: usize,
) -> Result<lsqnet::coordinator::SweepReport> {
    lsqnet::coordinator::run_sweep(&artifacts_dir(args), jobs, workers)
}

#[cfg(not(feature = "xla"))]
fn sweep_xla(
    _args: &Args,
    _jobs: Vec<lsqnet::coordinator::Job>,
    _workers: usize,
) -> Result<lsqnet::coordinator::SweepReport> {
    needs_xla("sweep")?;
    unreachable!()
}

#[cfg(feature = "xla")]
fn repro(args: &Args) -> Result<()> {
    let target = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    lsqnet::repro::run(&target, args)
}

#[cfg(not(feature = "xla"))]
fn repro(_args: &Args) -> Result<()> {
    needs_xla("repro")
}

/// `lsqnet serve`: stand up a [`lsqnet::serve::ModelRegistry`] hosting one
/// or more model variants (`--family a,b,c` — comma-separated), fire a
/// round-robin request load across named sessions, and report per-variant
/// stats. On the native backend, missing `model_qBITS` families are
/// synthesized into the artifacts dir, so a multi-precision deployment
/// runs from a clean clone.
fn serve(args: &Args) -> Result<()> {
    use lsqnet::runtime::{BackendKind, BackendSpec};
    use lsqnet::serve::{ModelRegistry, TierConfig, TierController, VariantOptions};
    use std::sync::Arc;
    // --tiers names an expensive→cheap precision ladder; when present it
    // *is* the set of loaded families, and an SLO controller routes
    // between them.
    let tier_ladder: Option<Vec<String>> = args.opt_str("tiers").map(|s| {
        s.split(',').map(|t| t.trim().to_string()).filter(|t| !t.is_empty()).collect()
    });
    // --artifact binds each variant from a packed `.lsqa` file; the
    // artifacts name their own families (DESIGN.md §Artifact-format).
    let artifact_paths: Vec<PathBuf> = args
        .opt_str("artifact")
        .map(|s| {
            s.split(',')
                .map(|t| t.trim())
                .filter(|t| !t.is_empty())
                .map(PathBuf::from)
                .collect()
        })
        .unwrap_or_default();
    let kind = BackendKind::parse(&args.str("backend", "native"))?;
    let checkpoint = args.str("checkpoint", "");
    let families: Vec<String> = if !artifact_paths.is_empty() {
        anyhow::ensure!(tier_ladder.is_none(), "--artifact and --tiers are mutually exclusive");
        anyhow::ensure!(
            checkpoint.is_empty(),
            "--artifact and --checkpoint are mutually exclusive (the artifact froze its \
             checkpoint at pack time)"
        );
        anyhow::ensure!(
            kind == BackendKind::Native,
            "--artifact requires the native backend"
        );
        // Each artifact names its own family. A corrupted or mismatched
        // file is refused here — before the registry spins anything up —
        // with the loader's typed error.
        artifact_paths
            .iter()
            .map(|p| Ok(lsqnet::runtime::LoadedArtifact::load(p)?.family().to_string()))
            .collect::<Result<_>>()?
    } else {
        match &tier_ladder {
            Some(ladder) => ladder.clone(),
            None => args
                .str("family", "cnn_small_q2")
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect(),
        }
    };
    anyhow::ensure!(!families.is_empty(), "--family must name at least one variant");
    let n = args.usize("requests", 256);
    let replicas = args.usize(
        "replicas",
        if kind == BackendKind::Native { 2 } else { 1 },
    );
    anyhow::ensure!(
        checkpoint.is_empty() || families.len() == 1,
        "--checkpoint applies to a single --family, got {}",
        families.len()
    );
    let dir = artifacts_dir(args);
    if kind == BackendKind::Native && artifact_paths.is_empty() {
        // Zero-artifacts affordance (same as `train`): synthesize any
        // missing `model_qBITS` family into the artifacts dir. Artifact
        // deployments skip this — a `.lsqa` file is self-contained and
        // needs no manifest on disk at all.
        for family in &families {
            lsqnet::runtime::native::fixture::ensure_family_by_name(&dir, family)?;
        }
    }

    let registry = ModelRegistry::open(BackendSpec { kind, artifacts_dir: dir });
    let opts = VariantOptions {
        checkpoint,
        replicas,
        max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms", 2)),
        queue_depth: args.usize("queue-depth", 256),
        intra_threads: args.usize("threads", 0),
        low_memory: if args.flag("fused-unpack") { Some(true) } else { None },
        ..VariantOptions::default()
    };
    for (i, family) in families.iter().enumerate() {
        let opts = VariantOptions { artifact: artifact_paths.get(i).cloned(), ..opts.clone() };
        registry.load(family, &opts)?;
    }
    let registry = Arc::new(registry);
    let controller = match &tier_ladder {
        Some(ladder) => {
            let cfg = TierConfig::new(ladder.clone(), args.f64("slo-ms", 5.0));
            Some(Arc::new(TierController::new(Arc::clone(&registry), cfg)?))
        }
        None => None,
    };
    if let Some(listen) = args.opt_str("listen") {
        let retry = args.u64("retry", 0) as u32;
        let deadline_ms = args.u64("deadline-ms", 0);
        return serve_net(registry, controller, &families, &listen, n, retry, deadline_ms);
    }
    println!(
        "serving {} variant(s) [{}] on {} x{replicas} each (core budget {}); \
         firing {n} requests round-robin from 4 client threads…",
        families.len(),
        families.join(", "),
        kind.name(),
        registry.core_budget()
    );
    let driver = match &controller {
        Some(c) => Some(c.start_driver()?),
        None => None,
    };
    let ctl = controller.as_deref();
    let spec = lsqnet::data::SynthSpec::new(10, 0.35, 1);
    let t0 = std::time::Instant::now();
    let mut lat = Vec::new();
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let sessions: Vec<_> = families
                .iter()
                .map(|f| registry.session(f))
                .collect::<Result<_, _>>()?;
            let spec = &spec;
            handles.push(s.spawn(move || {
                let mut l = Vec::new();
                for i in 0..n / 4 {
                    let img = spec.generate_alloc(t * 10_000 + i);
                    // Tiered when a controller is routing, otherwise
                    // round-robin across the named sessions.
                    let res = match ctl {
                        Some(c) => c.infer(img),
                        None => sessions[i % sessions.len()].infer(img),
                    };
                    if let Ok(rep) = res {
                        l.push(rep.total_ms);
                    }
                }
                l
            }));
        }
        for h in handles {
            lat.extend(h.join().unwrap());
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    if let Some(d) = driver {
        d.stop();
    }
    if let Some(c) = &controller {
        print_tier_report(c);
    }
    let shed = controller.as_ref().map_or(0, |c| c.shed_count());
    drop(controller);
    let all_stats = match Arc::try_unwrap(registry) {
        Ok(r) => r.shutdown(),
        Err(_) => Default::default(), // a straggler still holds the Arc
    };
    let p50 = lsqnet::util::stats::percentile(&lat, 50.0);
    let p95 = lsqnet::util::stats::percentile(&lat, 95.0);
    println!(
        "served {} reqs in {wall:.2}s ({:.1} req/s) | p50 {p50:.1} ms  p95 {p95:.1} ms",
        lat.len(),
        lat.len() as f64 / wall,
    );
    for (name, stats) in &all_stats {
        println!(
            "  {name:<22} {:>6} reqs  {:>5} batches  occupancy {:.2}  \
             exec {:.2} ms/batch  queue {:.2} ms/req  padding {} rows",
            stats.requests,
            stats.batches,
            stats.mean_occupancy(),
            stats.mean_exec_ms(),
            stats.mean_queue_ms(),
            stats.padding_rows,
        );
    }
    print_health_report(&all_stats, shed);
    Ok(())
}

/// `lsqnet serve --listen`: put the registry behind a [`NetServer`] and
/// either serve until killed (`--requests 0`) or fire the smoke load over
/// real loopback sockets — same round-robin shape as the in-process path,
/// but every request crosses the wire protocol, so the printed latencies
/// include framing + JSON + TCP. With a tier controller the smoke load
/// uses the `tiered` op instead of naming variants.
fn serve_net(
    registry: std::sync::Arc<lsqnet::serve::ModelRegistry>,
    controller: Option<std::sync::Arc<lsqnet::serve::TierController>>,
    families: &[String],
    listen: &str,
    n: usize,
    retry: u32,
    deadline_ms: u64,
) -> Result<()> {
    use lsqnet::serve::net::{NetClient, NetServer, RetryPolicy};
    use std::sync::Arc;
    let driver = match &controller {
        Some(c) => Some(c.start_driver()?),
        None => None,
    };
    let server = NetServer::start_with(Arc::clone(&registry), controller.clone(), listen)?;
    let addr = server.local_addr();
    println!(
        "listening on {addr} — {} variant(s) [{}] over the wire protocol{}",
        families.len(),
        families.join(", "),
        if controller.is_some() { " (tiered routing on)" } else { "" },
    );
    if n == 0 {
        println!("serving until killed (ctrl-c)…");
        loop {
            std::thread::park();
        }
    }
    let tiered = controller.is_some();
    let spec = lsqnet::data::SynthSpec::new(10, 0.35, 1);
    let t0 = std::time::Instant::now();
    let mut lat: Vec<f64> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let spec = &spec;
            handles.push(s.spawn(move || -> Result<Vec<f64>> {
                let mut client = NetClient::connect(addr)?;
                if retry > 0 {
                    client.set_retry(Some(RetryPolicy {
                        max_attempts: retry,
                        seed: t as u64,
                        ..RetryPolicy::default()
                    }));
                }
                if deadline_ms > 0 {
                    client.set_deadline_ms(Some(deadline_ms));
                }
                let mut l = Vec::new();
                for i in 0..n / 4 {
                    let img = spec.generate_alloc(t * 10_000 + i);
                    let s = std::time::Instant::now();
                    // Tiered routing when the controller is up, otherwise
                    // round-robin across the named variants.
                    let ok = if tiered {
                        client.infer_tiered(&img).is_ok()
                    } else {
                        client.infer(&families[i % families.len()], &img).is_ok()
                    };
                    if ok {
                        l.push(s.elapsed().as_secs_f64() * 1e3);
                    }
                }
                Ok(l)
            }));
        }
        for h in handles {
            if let Ok(l) = h.join().unwrap() {
                lat.extend(l);
            }
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    server.stop();
    if let Some(d) = driver {
        d.stop();
    }
    if let Some(c) = &controller {
        print_tier_report(c);
    }
    let shed = controller.as_ref().map_or(0, |c| c.shed_count());
    drop(controller);
    let all_stats = match Arc::try_unwrap(registry) {
        Ok(r) => r.shutdown(),
        Err(_) => Default::default(), // a straggler still holds the Arc
    };
    let p50 = lsqnet::util::stats::percentile(&lat, 50.0);
    let p95 = lsqnet::util::stats::percentile(&lat, 95.0);
    println!(
        "served {} reqs over TCP in {wall:.2}s ({:.1} req/s) | client p50 {p50:.2} ms  \
         p95 {p95:.2} ms (incl. network + framing)",
        lat.len(),
        lat.len() as f64 / wall,
    );
    for (name, stats) in &all_stats {
        println!(
            "  {name:<22} {:>6} reqs  {:>5} batches  occupancy {:.2}  \
             exec {:.2} ms/batch  queue {:.2} ms/req  padding {} rows",
            stats.requests,
            stats.batches,
            stats.mean_occupancy(),
            stats.mean_exec_ms(),
            stats.mean_queue_ms(),
            stats.padding_rows,
        );
    }
    print_health_report(&all_stats, shed);
    Ok(())
}

/// One self-healing summary line: replica supervision activity and shed
/// work across every variant, plus the tier controller's shed count.
/// All-zero on a healthy run — nonzero numbers are the thing to grep for
/// after a chaos or failover exercise.
fn print_health_report(
    all_stats: &std::collections::BTreeMap<String, lsqnet::serve::ServeStats>,
    tier_shed: u64,
) {
    let (fails, restarts, expired, failed) =
        all_stats.values().fold((0u64, 0u64, 0u64, 0u64), |a, s| {
            (
                a.0 + s.replica_failures,
                a.1 + s.replica_restarts,
                a.2 + s.deadline_expired,
                a.3 + s.failed_requests,
            )
        });
    println!(
        "health: {fails} replica failure(s), {restarts} restart(s), \
         {expired} deadline-expired, {failed} failed request(s), {tier_shed} shed by tiering"
    );
}

/// Print the tier controller's closed-loop summary: final tier, shed
/// count, and the full decision trace (one line per tier shift).
fn print_tier_report(c: &lsqnet::serve::TierController) {
    let trace = c.trace();
    println!(
        "tier controller: {} epoch(s), active tier {}, {} request(s) shed, {} shift(s)",
        c.epochs(),
        c.active_tier_name(),
        c.shed_count(),
        trace.len(),
    );
    let tiers = c.tiers();
    for ev in &trace {
        println!(
            "  epoch {:>4}  {} -> {}  ({}; mean queue {:.2} ms)",
            ev.epoch, tiers[ev.from], tiers[ev.to], ev.reason, ev.queue_ms,
        );
    }
}

/// `lsqnet pack`: two modes. With `--out`, quantize + pack `--family` into
/// a zero-copy `.lsqa` artifact — weights, learned step sizes, and prebuilt
/// SIMD panel sections frozen at pack time (DESIGN.md §Artifact-format) —
/// then reload it and print the inspect summary as a self-check. Without
/// `--out`, the original per-layer size report over a checkpoint.
fn pack(args: &Args) -> Result<()> {
    if args.has("out") {
        return pack_artifact(args);
    }
    let ckpt_path = args.opt_str("checkpoint").context("--checkpoint required")?;
    let manifest = Manifest::load(&artifacts_dir(args))?;
    let ck = Checkpoint::load(Path::new(&ckpt_path))?;
    let family = ck.meta_str("family").context("no family meta")?.to_string();
    let fam = manifest.family(&family)?;
    let mut total_packed = 0usize;
    let mut total_fp32 = 0usize;
    println!("packing {family} weights to integer storage (Eq. 1 + bit packing):");
    for l in &fam.layer_meta {
        let w = ck.get(&format!("{}.w", l.name))?;
        let n = w.numel();
        total_fp32 += n * 4;
        if l.bits < 32 {
            let s = ck.get(&format!("{}.sw", l.name))?.item_f32()?;
            let p = lsqnet::quant::pack::quantize_and_pack(w.f32s()?, s, l.bits, true)?;
            // verify round trip: dequantized == Eq. 2 applied directly
            let dq = lsqnet::quant::pack::dequantize(&p);
            let (qn, qp) = lsqnet::quant::lsq::qrange(l.bits, true);
            let maxerr = w
                .f32s()?
                .iter()
                .zip(&dq)
                .map(|(a, b)| (lsqnet::quant::lsq::quantize(*a, s, qn, qp) - b).abs())
                .fold(0.0f32, f32::max);
            anyhow::ensure!(maxerr < 1e-5, "pack roundtrip mismatch on {}", l.name);
            total_packed += p.storage_bytes();
            println!(
                "  {:<16} {:>8} w @ {}-bit -> {:>8} B (s={:.5})",
                l.name,
                n,
                l.bits,
                p.storage_bytes(),
                s
            );
        } else {
            total_packed += n * 4;
            println!("  {:<16} {:>8} w @ fp32  -> {:>8} B", l.name, n, n * 4);
        }
    }
    println!(
        "total: {} B packed vs {} B fp32 ({:.2}x compression)",
        total_packed,
        total_fp32,
        total_fp32 as f64 / total_packed as f64
    );
    Ok(())
}

/// `lsqnet pack --family F --out m.lsqa`: write the artifact, then reload
/// it (full checksum + geometry verification) and print its summary.
fn pack_artifact(args: &Args) -> Result<()> {
    use lsqnet::runtime::kernels::SimdLevel;
    let out = PathBuf::from(args.str("out", "model.lsqa"));
    let family = args
        .opt_str("family")
        .context("--family required when packing an artifact (--out)")?;
    let dir = artifacts_dir(args);
    // Zero-artifacts affordance (same as `serve`): synthesize a missing
    // `model_qBITS` fixture family so packing works from a clean clone.
    lsqnet::runtime::native::fixture::ensure_family_by_name(&dir, &family)?;
    let manifest = Manifest::load(&dir)?;
    let params = match args.opt_str("checkpoint") {
        Some(ck) => lsqnet::train::TrainState::load(&manifest, Path::new(&ck))?.params,
        None => manifest.load_initial_params(&family)?,
    };
    let levels: Vec<SimdLevel> = match args.opt_str("levels") {
        Some(s) => s
            .split(',')
            .map(|t| t.trim())
            .filter(|t| !t.is_empty())
            .map(|t| {
                SimdLevel::parse(t).with_context(|| format!("unknown SIMD level {t:?} in --levels"))
            })
            .collect::<Result<_>>()?,
        None => lsqnet::runtime::artifact::writer::default_levels(),
    };
    lsqnet::runtime::pack_family(&manifest, &family, &params, &out, &levels)?;
    // Reload through the verifying loader: if this prints, every checksum
    // and panel geometry in the file checks out.
    let art = lsqnet::runtime::LoadedArtifact::load(&out)?;
    print!("{}", art.inspect());
    Ok(())
}

/// `lsqnet artifact inspect <m.lsqa>`: run the file through the verifying
/// loader (header, checksums, section parses, panel geometries) and print
/// what it holds. A corrupted file fails here with the same typed error
/// `serve --artifact` would refuse it with.
fn artifact_cmd(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("inspect") => {
            let path = args
                .positional
                .get(1)
                .cloned()
                .or_else(|| args.opt_str("path"))
                .context("usage: lsqnet artifact inspect <model.lsqa>")?;
            let art = lsqnet::runtime::LoadedArtifact::load(Path::new(&path))?;
            print!("{}", art.inspect());
            Ok(())
        }
        Some(other) => bail!("unknown artifact subcommand {other:?} (expected `inspect`)"),
        None => bail!("usage: lsqnet artifact inspect <model.lsqa>"),
    }
}
