//! Quantization-error metrics for the Section-3.6 study: does LSQ's learned
//! step size ŝ minimize MAE / MSE / KL, or something else?
//!
//! The paper scans s ∈ {0.01ŝ … 20ŝ} and reports the percent |difference|
//! between ŝ and the error-minimizing s per metric. `sweep_min` reproduces
//! that scan over a data slice.

use super::lsq::{qrange, quantize};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Metric {
    MeanAbs,
    MeanSq,
    Kl,
}

impl Metric {
    pub fn name(self) -> &'static str {
        match self {
            Metric::MeanAbs => "mae",
            Metric::MeanSq => "mse",
            Metric::Kl => "kl",
        }
    }
}

/// Mean absolute error <|vhat - v|>.
pub fn mean_abs_err(v: &[f32], s: f32, qn: i64, qp: i64) -> f64 {
    v.iter()
        .map(|&x| (quantize(x, s, qn, qp) - x).abs() as f64)
        .sum::<f64>()
        / v.len().max(1) as f64
}

/// Mean squared error <(vhat - v)^2>.
pub fn mean_sq_err(v: &[f32], s: f32, qn: i64, qp: i64) -> f64 {
    v.iter()
        .map(|&x| {
            let d = (quantize(x, s, qn, qp) - x) as f64;
            d * d
        })
        .sum::<f64>()
        / v.len().max(1) as f64
}

/// KL-divergence surrogate per Section 3.6: -E[log q(vhat)] where q is the
/// empirical distribution of quantized values (the v-entropy term is dropped
/// as it does not depend on s).
pub fn kl_surrogate(v: &[f32], s: f32, qn: i64, qp: i64) -> f64 {
    let n = v.len().max(1) as f64;
    // histogram over the (Qn+Qp+1) levels
    let levels = (qn + qp + 1) as usize;
    let mut counts = vec![0u64; levels];
    for &x in v {
        let vbar = super::lsq::quantize_vbar(x, s, qn, qp) as i64;
        counts[(vbar + qn) as usize] += 1;
    }
    // -E[log q] with add-one smoothing to keep empty bins finite
    let total = n + levels as f64;
    let mut acc = 0.0;
    for &c in &counts {
        if c > 0 {
            let q = (c as f64 + 1.0) / total;
            acc -= c as f64 * q.ln();
        }
    }
    acc / n
}

pub fn error(metric: Metric, v: &[f32], s: f32, qn: i64, qp: i64) -> f64 {
    match metric {
        Metric::MeanAbs => mean_abs_err(v, s, qn, qp),
        Metric::MeanSq => mean_sq_err(v, s, qn, qp),
        Metric::Kl => kl_surrogate(v, s, qn, qp),
    }
}

/// Scan s ∈ {s_hat/100, 2 s_hat/100, …, 20 s_hat} (the paper's grid) and
/// return the s minimizing the metric.
pub fn sweep_min(metric: Metric, v: &[f32], s_hat: f32, bits: u32, signed: bool) -> f32 {
    let (qn, qp) = qrange(bits, signed);
    let mut best_s = s_hat;
    let mut best_e = f64::INFINITY;
    for i in 1..=2000 {
        let s = s_hat * (i as f32) * 0.01;
        let e = error(metric, v, s, qn, qp);
        if e < best_e {
            best_e = e;
            best_s = s;
        }
    }
    best_s
}

/// Percent absolute difference between the learned ŝ and the
/// metric-minimizing s (the number Table-less Section 3.6 reports).
pub fn pct_abs_diff(s_hat: f32, s_min: f32) -> f64 {
    ((s_hat - s_min).abs() / s_hat.abs().max(1e-12)) as f64 * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn gauss(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn zero_error_when_on_grid() {
        let (qn, qp) = qrange(2, true);
        let v = [0.5f32, -1.0, 0.0];
        assert_eq!(mean_abs_err(&v, 0.5, qn, qp), 0.0);
        assert_eq!(mean_sq_err(&v, 0.5, qn, qp), 0.0);
    }

    #[test]
    fn mse_has_interior_minimum() {
        // For gaussian data the MSE-minimizing s is finite and positive:
        // the sweep must not return the grid edges.
        let v = gauss(4096, 1);
        let s_min = sweep_min(Metric::MeanSq, &v, 1.0, 2, true);
        assert!(s_min > 0.02 && s_min < 19.0, "s_min={s_min}");
        let (qn, qp) = qrange(2, true);
        let e_min = mean_sq_err(&v, s_min, qn, qp);
        assert!(e_min < mean_sq_err(&v, s_min * 3.0, qn, qp));
        assert!(e_min < mean_sq_err(&v, s_min / 3.0, qn, qp));
    }

    #[test]
    fn mae_vs_mse_minima_differ() {
        let v = gauss(4096, 2);
        let a = sweep_min(Metric::MeanAbs, &v, 1.0, 2, true);
        let b = sweep_min(Metric::MeanSq, &v, 1.0, 2, true);
        assert!((a - b).abs() > 1e-6);
    }

    #[test]
    fn pct_diff() {
        assert!((pct_abs_diff(1.0, 1.5) - 50.0).abs() < 1e-9);
        assert!((pct_abs_diff(2.0, 1.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn kl_finite_and_sensitive() {
        let v = gauss(2048, 3);
        let (qn, qp) = qrange(2, true);
        let a = kl_surrogate(&v, 0.5, qn, qp);
        let b = kl_surrogate(&v, 5.0, qn, qp);
        assert!(a.is_finite() && b.is_finite());
        assert_ne!(a, b);
    }
}
