//! Pure-Rust reimplementation of the LSQ quantizer (paper Eqs. 1-3, 5).
//!
//! This mirrors `python/compile/kernels/ref.py` exactly and serves three
//! purposes on the coordinator side:
//!   1. analysis (Section 3.6 quantization-error study, Figure 2 curves)
//!      without any XLA dependency;
//!   2. property-based cross-validation against the AOT artifacts in the
//!      integration tests;
//!   3. integer packing of trained weights for the model-size accounting
//!      and the serving path.

/// (Qn, Qp) per Section 2: unsigned (activations) vs signed (weights).
///
/// ```
/// use lsqnet::quant::lsq::qrange;
///
/// assert_eq!(qrange(2, true), (2, 1));    // signed 2-bit: v̄ ∈ [-2, 1]
/// assert_eq!(qrange(3, false), (0, 7));   // unsigned 3-bit: v̄ ∈ [0, 7]
/// assert_eq!(qrange(4, true), (8, 7));
/// assert_eq!(qrange(8, false), (0, 255));
/// ```
pub fn qrange(bits: u32, signed: bool) -> (i64, i64) {
    assert!(bits >= 1 && bits <= 31, "bits out of range: {bits}");
    if signed {
        (1i64 << (bits - 1), (1i64 << (bits - 1)) - 1)
    } else {
        (0, (1i64 << bits) - 1)
    }
}

/// Round half to even, matching XLA's `round-nearest-even` and numpy.
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

/// Eq. 1: vbar = round(clip(v/s, -Qn, Qp)).
#[inline]
pub fn quantize_vbar(v: f32, s: f32, qn: i64, qp: i64) -> f32 {
    let r = (v / s).clamp(-(qn as f32), qp as f32);
    round_ties_even(r)
}

/// Eq. 2: vhat = vbar * s.
///
/// The full Eq. 1 → Eq. 2 round trip at 2, 3 and 4 bits — every value
/// lands on the step grid `v̄ * s` and saturates at `-Qn*s` / `Qp*s`:
///
/// ```
/// use lsqnet::quant::lsq::{qrange, quantize};
///
/// for bits in [2u32, 3, 4] {
///     let (qn, qp) = qrange(bits, true);
///     let s = 0.25;
///     // on-grid values are fixed points
///     assert_eq!(quantize(s * qp as f32, s, qn, qp), s * qp as f32);
///     // everything clips to the representable range
///     assert_eq!(quantize(1e9, s, qn, qp), s * qp as f32);
///     assert_eq!(quantize(-1e9, s, qn, qp), -s * qn as f32);
/// }
/// // 2-bit signed, s = 0.25: 0.26 -> 0.25, -0.6 -> -0.5 (grid), 10 -> 0.25 (clip)
/// let (qn, qp) = qrange(2, true);
/// assert_eq!(quantize(0.26, 0.25, qn, qp), 0.25);
/// assert_eq!(quantize(-0.6, 0.25, qn, qp), -0.5);
/// assert_eq!(quantize(10.0, 0.25, qn, qp), 0.25);
/// ```
#[inline]
pub fn quantize(v: f32, s: f32, qn: i64, qp: i64) -> f32 {
    quantize_vbar(v, s, qn, qp) * s
}

pub fn quantize_slice(v: &[f32], s: f32, qn: i64, qp: i64, out: &mut [f32]) {
    assert_eq!(v.len(), out.len());
    for (o, &x) in out.iter_mut().zip(v) {
        *o = quantize(x, s, qn, qp);
    }
}

/// Eq. 5: straight-through data gradient mask.
#[inline]
pub fn grad_v_mask(v: f32, s: f32, qn: i64, qp: i64) -> f32 {
    let r = v / s;
    if r > -(qn as f32) && r < qp as f32 {
        1.0
    } else {
        0.0
    }
}

/// Eq. 3: per-element d(vhat)/d(s).
#[inline]
pub fn grad_s_term(v: f32, s: f32, qn: i64, qp: i64) -> f32 {
    let r = v / s;
    if r <= -(qn as f32) {
        -(qn as f32)
    } else if r >= qp as f32 {
        qp as f32
    } else {
        round_ties_even(r) - r
    }
}

/// Section 2.2 gradient scale g = 1/sqrt(N * Qp).
pub fn grad_scale(n_items: usize, qp: i64) -> f64 {
    1.0 / ((n_items as f64) * qp as f64).sqrt()
}

/// Section 2.1 step initialization 2<|v|>/sqrt(Qp).
pub fn step_init(v: &[f32], qp: i64) -> f32 {
    if v.is_empty() {
        return 1.0;
    }
    let mean_abs: f64 = v.iter().map(|x| x.abs() as f64).sum::<f64>() / v.len() as f64;
    (2.0 * mean_abs / (qp as f64).sqrt()) as f32
}

/// Full reference VJP over a slice: returns (grad_v, grad_s).
pub fn lsq_vjp(
    v: &[f32],
    s: f32,
    qn: i64,
    qp: i64,
    gscale: f64,
    cotangent: &[f32],
) -> (Vec<f32>, f32) {
    assert_eq!(v.len(), cotangent.len());
    let mut gv = vec![0.0f32; v.len()];
    let mut gs = 0.0f64;
    for i in 0..v.len() {
        gv[i] = cotangent[i] * grad_v_mask(v[i], s, qn, qp);
        gs += (cotangent[i] * grad_s_term(v[i], s, qn, qp)) as f64;
    }
    (gv, (gs * gscale) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qranges() {
        assert_eq!(qrange(2, false), (0, 3));
        assert_eq!(qrange(2, true), (2, 1));
        assert_eq!(qrange(8, true), (128, 127));
        assert_eq!(qrange(8, false), (0, 255));
    }

    #[test]
    fn ties_to_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(0.4999), 0.0);
        assert_eq!(round_ties_even(1.2), 1.0);
    }

    #[test]
    fn quantize_grid() {
        let (qn, qp) = qrange(2, true);
        assert_eq!(quantize(0.26, 0.25, qn, qp), 0.25);
        assert_eq!(quantize(10.0, 0.25, qn, qp), 0.25); // clipped at Qp=1
        assert_eq!(quantize(-10.0, 0.25, qn, qp), -0.5); // clipped at -Qn=-2
    }

    #[test]
    fn grad_saturation() {
        let (qn, qp) = qrange(2, true);
        assert_eq!(grad_s_term(-100.0, 1.0, qn, qp), -2.0);
        assert_eq!(grad_s_term(100.0, 1.0, qn, qp), 1.0);
        assert_eq!(grad_v_mask(-100.0, 1.0, qn, qp), 0.0);
        assert_eq!(grad_v_mask(0.3, 1.0, qn, qp), 1.0);
    }

    #[test]
    fn transition_sensitivity() {
        // |ds| grows towards a transition point (Section 2.1 argument).
        let (qn, qp) = qrange(3, false);
        let near = grad_s_term(1.49, 1.0, qn, qp).abs();
        let far = grad_s_term(1.05, 1.0, qn, qp).abs();
        assert!(near > far);
    }

    #[test]
    fn step_init_formula() {
        let v = [1.0f32, -1.0, 1.0, -1.0];
        assert!((step_init(&v, 4) - 2.0 / 2.0).abs() < 1e-6);
    }

    #[test]
    fn vjp_zero_cotangent() {
        let v = [0.1f32, 5.0, -3.0];
        let cot = [0.0f32; 3];
        let (gv, gs) = lsq_vjp(&v, 0.5, 2, 1, 1.0, &cot);
        assert_eq!(gv, vec![0.0; 3]);
        assert_eq!(gs, 0.0);
    }
}
