//! Quantization substrate (pure Rust, no XLA dependency):
//! the LSQ quantizer math (Eqs. 1-3, 5), integer bit-packing,
//! quantization-error metrics (Section 3.6) and model-size accounting
//! (Figure 3). Cross-validated against the Pallas kernels by the
//! integration/property tests.

pub mod error;
pub mod lsq;
pub mod model_size;
pub mod pack;
