//! Model-size accounting for the Figure-3 accuracy-vs-size frontier.
//!
//! Size of a quantized model = Σ_layers n_weights × bits/8 (+ one fp32 step
//! size per quantized layer). Per the paper's convention the first and last
//! layers are stored at 8-bit; the manifest's `layer_meta` already records
//! the effective per-layer bit width, so this module just folds it up.

/// One matmul layer as recorded in `manifest.json: families.*.layer_meta`.
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub name: String,
    pub n_weights: usize,
    pub bits: u32,
}

/// Total parameter storage in bytes for the quantized model.
pub fn model_bytes(layers: &[LayerMeta]) -> usize {
    layers
        .iter()
        .map(|l| {
            let payload = (l.n_weights * l.bits as usize + 7) / 8;
            let step = if l.bits < 32 { 4 } else { 0 };
            payload + step
        })
        .sum()
}

/// Storage for the fp32 reference model.
pub fn fp32_bytes(layers: &[LayerMeta]) -> usize {
    layers.iter().map(|l| l.n_weights * 4).sum()
}

pub fn megabytes(bytes: usize) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// A point on the Figure-3 frontier.
#[derive(Clone, Debug)]
pub struct SizePoint {
    pub model: String,
    pub bits: u32,
    pub bytes: usize,
    pub top1: f64,
}

/// The subset of `points` on the accuracy-vs-size Pareto frontier
/// (no other point is both smaller and more accurate), sorted by size.
pub fn pareto_frontier(points: &[SizePoint]) -> Vec<SizePoint> {
    let mut sorted: Vec<SizePoint> = points.to_vec();
    sorted.sort_by(|a, b| a.bytes.cmp(&b.bytes));
    let mut out: Vec<SizePoint> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        if p.top1 > best {
            best = p.top1;
            out.push(p);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(n: usize, bits: u32) -> LayerMeta {
        LayerMeta { name: format!("l{n}_{bits}"), n_weights: n, bits }
    }

    #[test]
    fn bytes_at_two_bit() {
        // 1000 weights at 2-bit = 250 bytes + 4 step bytes.
        assert_eq!(model_bytes(&[l(1000, 2)]), 254);
    }

    #[test]
    fn first_last_8bit_dominate_small_models() {
        let layers = [l(432, 8), l(4608, 2), l(640, 8)];
        let b = model_bytes(&layers);
        assert_eq!(b, 432 + 4608 / 4 + 640 + 12);
    }

    #[test]
    fn fp32_is_4x_8bit() {
        let layers = [l(100, 8)];
        assert_eq!(fp32_bytes(&layers), 400);
        assert_eq!(model_bytes(&layers), 104);
    }

    #[test]
    fn frontier_filters_dominated() {
        let pts = vec![
            SizePoint { model: "a".into(), bits: 2, bytes: 100, top1: 60.0 },
            SizePoint { model: "b".into(), bits: 4, bytes: 200, top1: 55.0 }, // dominated
            SizePoint { model: "c".into(), bits: 8, bytes: 300, top1: 70.0 },
        ];
        let f = pareto_frontier(&pts);
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].model, "a");
        assert_eq!(f[1].model, "c");
    }
}
