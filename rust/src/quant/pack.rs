//! Bit-packing of integer-quantized tensors (2/3/4/8 bits per value).
//!
//! This is the storage/serving substrate behind Figure 3's model-size axis
//! and the quantized-serving path: trained weights are quantized to vbar
//! (Eq. 1), offset to unsigned, and packed little-endian into a byte stream
//! at exactly `bits` bits per value plus one fp32 step size per layer.

use anyhow::{bail, Result};

/// Packed low-precision tensor: `bits` bits per value, values stored as
/// unsigned offsets from -Qn (i.e. stored = vbar + Qn).
#[derive(Clone, Debug, PartialEq)]
pub struct Packed {
    pub bits: u32,
    pub signed: bool,
    pub len: usize,
    pub step: f32,
    pub bytes: Vec<u8>,
}

/// Pack `vbar` integer values (already in [-Qn, Qp]) at `bits` per value.
///
/// Round-trips exactly with [`unpack`] at every width — the Eq. 1 integer
/// grid survives storage bit-for-bit:
///
/// ```
/// use lsqnet::quant::pack::{pack, unpack};
///
/// for bits in [2u32, 3, 4] {
///     let (qn, qp) = lsqnet::quant::lsq::qrange(bits, true);
///     let vbar: Vec<i32> = (-qn..=qp).map(|v| v as i32).collect();
///     let p = pack(&vbar, bits, true, 0.25).unwrap();
///     // storage really is `bits` bits per value (plus the fp32 step)
///     assert_eq!(p.bytes.len(), (vbar.len() * bits as usize + 7) / 8);
///     assert_eq!(unpack(&p), vbar);
/// }
/// ```
pub fn pack(vbar: &[i32], bits: u32, signed: bool, step: f32) -> Result<Packed> {
    if !(1..=8).contains(&bits) {
        bail!("pack supports 1..=8 bits, got {bits}");
    }
    let (qn, qp) = super::lsq::qrange(bits, signed);
    let mut bytes = vec![0u8; (vbar.len() * bits as usize + 7) / 8];
    for (i, &v) in vbar.iter().enumerate() {
        let v64 = v as i64;
        if v64 < -qn || v64 > qp {
            bail!("value {v} out of range [-{qn}, {qp}] for {bits}-bit");
        }
        let u = (v64 + qn) as u64; // 0..(Qn+Qp)
        let bitpos = i * bits as usize;
        let byte = bitpos / 8;
        let shift = bitpos % 8;
        bytes[byte] |= ((u << shift) & 0xff) as u8;
        if shift + bits as usize > 8 {
            bytes[byte + 1] |= (u >> (8 - shift)) as u8;
        }
    }
    Ok(Packed { bits, signed, len: vbar.len(), step, bytes })
}

/// Unpack back to integer values in [-Qn, Qp].
///
/// ```
/// use lsqnet::quant::pack::{quantize_and_pack, unpack};
///
/// // Eq. 1 at 2-bit signed: v̄ = round(clip(v/s, -2, 1)), s = 0.25.
/// let p = quantize_and_pack(&[0.26, -0.6, 0.0, 10.0], 0.25, 2, true).unwrap();
/// assert_eq!(unpack(&p), vec![1, -2, 0, 1]);
/// ```
pub fn unpack(p: &Packed) -> Vec<i32> {
    let mut out = vec![0i32; p.len];
    unpack_range(p, 0, p.len, &mut out);
    out
}

/// Unpack the `len` values starting at element `start` into `out[..len]`.
/// This is the tile-granular primitive behind the kernel layer's fused
/// unpack-and-dot GEMM ([`crate::runtime::kernels::qgemm`]).
///
/// The loop body branches on the runtime `bits`; hot paths at the standard
/// widths should go through [`unpack_range_spec`], which dispatches to a
/// monomorphized [`unpack_range_const`] instance instead.
pub fn unpack_range(p: &Packed, start: usize, len: usize, out: &mut [i32]) {
    assert!(start + len <= p.len, "unpack_range out of bounds");
    assert!(out.len() >= len, "unpack_range output too small");
    let (qn, _) = super::lsq::qrange(p.bits, p.signed);
    let bits = p.bits as usize;
    let mask = (1u64 << bits) - 1;
    for (j, o) in out.iter_mut().enumerate().take(len) {
        let bitpos = (start + j) * bits;
        let byte = bitpos / 8;
        let shift = bitpos % 8;
        let mut u = (p.bytes[byte] as u64) >> shift;
        if shift + bits > 8 {
            u |= (p.bytes[byte + 1] as u64) << (8 - shift);
        }
        *o = ((u & mask) as i64 - qn) as i32;
    }
}

/// [`unpack_range`] with the bit width as a const generic: the extraction
/// mask/shift math constant-folds, and for widths dividing 8 (2/4/8) the
/// byte-straddle branch disappears at compile time, leaving a branch-free
/// inner loop. This is the per-tile unpack the specialized qgemm paths use
/// ([`crate::runtime::kernels::qgemm`] fused mode and the one-time
/// panelized build) — the runtime-`bits` [`unpack_range`] stays as the
/// fallback for nonstandard widths.
pub fn unpack_range_const<const BITS: u32>(p: &Packed, start: usize, len: usize, out: &mut [i32]) {
    assert_eq!(p.bits, BITS, "unpack_range_const width mismatch");
    assert!(start + len <= p.len, "unpack_range out of bounds");
    assert!(out.len() >= len, "unpack_range output too small");
    let (qn, _) = super::lsq::qrange(BITS, p.signed);
    let qn = qn as i32;
    debug_assert!((1..=8).contains(&BITS));
    let mask: u32 = (1u32 << BITS) - 1;
    let bits = BITS as usize;
    for (j, o) in out.iter_mut().enumerate().take(len) {
        let bitpos = (start + j) * bits;
        let byte = bitpos >> 3;
        let shift = bitpos & 7;
        let mut u = (p.bytes[byte] as u32) >> shift;
        // For widths dividing 8 a value never straddles a byte, so this
        // whole block is removed at compile time.
        if 8 % BITS != 0 && shift + bits > 8 {
            u |= (p.bytes[byte + 1] as u32) << (8 - shift);
        }
        *o = (u & mask) as i32 - qn;
    }
}

/// Width-dispatched unpack: one `match` on `bits` selects a monomorphized
/// [`unpack_range_const`] instance for the paper's standard widths
/// (2/3/4/8), falling back to the generic [`unpack_range`] loop otherwise
/// (1/5/6/7-bit packings exist only in pack-format tests). Callers that
/// unpack many tiles per call pay the width branch once here instead of
/// per value.
pub fn unpack_range_spec(p: &Packed, start: usize, len: usize, out: &mut [i32]) {
    match p.bits {
        2 => unpack_range_const::<2>(p, start, len, out),
        3 => unpack_range_const::<3>(p, start, len, out),
        4 => unpack_range_const::<4>(p, start, len, out),
        8 => unpack_range_const::<8>(p, start, len, out),
        _ => unpack_range(p, start, len, out),
    }
}

/// Dequantize a packed tensor back to f32 (vbar * s, Eq. 2).
pub fn dequantize(p: &Packed) -> Vec<f32> {
    unpack(p).into_iter().map(|v| v as f32 * p.step).collect()
}

/// Quantize an f32 weight tensor with step `s` and pack it.
pub fn quantize_and_pack(w: &[f32], s: f32, bits: u32, signed: bool) -> Result<Packed> {
    let (qn, qp) = super::lsq::qrange(bits, signed);
    let vbar: Vec<i32> = w
        .iter()
        .map(|&x| super::lsq::quantize_vbar(x, s, qn, qp) as i32)
        .collect();
    pack(&vbar, bits, signed, s)
}

impl Packed {
    /// Storage bytes including the fp32 step size.
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len() + 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 1..=8u32 {
            for signed in [true, false] {
                let (qn, qp) = crate::quant::lsq::qrange(bits, signed);
                let vals: Vec<i32> = (-qn..=qp).map(|v| v as i32).collect();
                let p = pack(&vals, bits, signed, 0.5).unwrap();
                assert_eq!(unpack(&p), vals, "bits={bits} signed={signed}");
            }
        }
    }

    #[test]
    fn density() {
        let vals = vec![0i32; 100];
        let p = pack(&vals, 3, false, 1.0).unwrap();
        assert_eq!(p.bytes.len(), (100 * 3 + 7) / 8); // 38 bytes
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(pack(&[5], 2, true, 1.0).is_err()); // Qp = 1
        assert!(pack(&[-1], 2, false, 1.0).is_err()); // unsigned
    }

    #[test]
    fn dequantize_matches_eq2() {
        let w = [0.26f32, -0.6, 0.0, 10.0];
        let p = quantize_and_pack(&w, 0.25, 2, true).unwrap();
        let dq = dequantize(&p);
        assert_eq!(dq, vec![0.25, -0.5, 0.0, 0.25]);
    }

    #[test]
    fn unpack_range_matches_full_unpack_at_any_offset() {
        for bits in 1..=8u32 {
            let (qn, qp) = crate::quant::lsq::qrange(bits, true);
            let vals: Vec<i32> = (0..100).map(|i| (i % (qn + qp + 1)) as i32 - qn as i32).collect();
            let p = pack(&vals, bits, true, 1.0).unwrap();
            let full = unpack(&p);
            for start in [0usize, 1, 7, 13, 50, 99] {
                let len = (100 - start).min(17);
                let mut out = vec![0i32; len];
                unpack_range(&p, start, len, &mut out);
                assert_eq!(out, full[start..start + len], "bits={bits} start={start}");
            }
        }
    }

    #[test]
    fn unpack_range_spec_matches_generic_all_widths() {
        // The specialized (const-generic) instances and the runtime-`bits`
        // loop must agree value-for-value, signed and unsigned, at offsets
        // that straddle byte boundaries.
        for bits in 1..=8u32 {
            for signed in [true, false] {
                let (qn, qp) = crate::quant::lsq::qrange(bits, signed);
                let vals: Vec<i32> =
                    (0..77).map(|i| (i % (qn + qp + 1)) as i32 - qn as i32).collect();
                let p = pack(&vals, bits, signed, 1.0).unwrap();
                for start in [0usize, 1, 3, 8, 21, 76] {
                    let len = (77 - start).min(19);
                    let mut a = vec![0i32; len];
                    let mut b = vec![0i32; len];
                    unpack_range(&p, start, len, &mut a);
                    unpack_range_spec(&p, start, len, &mut b);
                    assert_eq!(a, b, "bits={bits} signed={signed} start={start}");
                    assert_eq!(a, vals[start..start + len], "reference slice");
                }
            }
        }
    }

    #[test]
    fn unpack_range_const_rejects_width_mismatch() {
        let p = pack(&[0, 1, -1], 3, true, 1.0).unwrap();
        let r = std::panic::catch_unwind(|| {
            let mut out = vec![0i32; 3];
            unpack_range_const::<4>(&p, 0, 3, &mut out);
        });
        assert!(r.is_err(), "width mismatch must panic");
    }

    #[test]
    fn unaligned_lengths() {
        for n in [1usize, 3, 7, 9, 63, 65] {
            let vals: Vec<i32> = (0..n).map(|i| (i % 4) as i32).collect();
            let p = pack(&vals, 3, false, 1.0).unwrap();
            assert_eq!(unpack(&p), vals);
        }
    }
}
