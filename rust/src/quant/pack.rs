//! Bit-packing of integer-quantized tensors (2/3/4/8 bits per value).
//!
//! This is the storage/serving substrate behind Figure 3's model-size axis
//! and the quantized-serving path: trained weights are quantized to vbar
//! (Eq. 1), offset to unsigned, and packed little-endian into a byte stream
//! at exactly `bits` bits per value plus one fp32 step size per layer.

use anyhow::{bail, Result};

/// Packed low-precision tensor: `bits` bits per value, values stored as
/// unsigned offsets from -Qn (i.e. stored = vbar + Qn).
#[derive(Clone, Debug, PartialEq)]
pub struct Packed {
    pub bits: u32,
    pub signed: bool,
    pub len: usize,
    pub step: f32,
    pub bytes: Vec<u8>,
}

/// Pack `vbar` integer values (already in [-Qn, Qp]) at `bits` per value.
pub fn pack(vbar: &[i32], bits: u32, signed: bool, step: f32) -> Result<Packed> {
    if !(1..=8).contains(&bits) {
        bail!("pack supports 1..=8 bits, got {bits}");
    }
    let (qn, qp) = super::lsq::qrange(bits, signed);
    let mut bytes = vec![0u8; (vbar.len() * bits as usize + 7) / 8];
    for (i, &v) in vbar.iter().enumerate() {
        let v64 = v as i64;
        if v64 < -qn || v64 > qp {
            bail!("value {v} out of range [-{qn}, {qp}] for {bits}-bit");
        }
        let u = (v64 + qn) as u64; // 0..(Qn+Qp)
        let bitpos = i * bits as usize;
        let byte = bitpos / 8;
        let shift = bitpos % 8;
        bytes[byte] |= ((u << shift) & 0xff) as u8;
        if shift + bits as usize > 8 {
            bytes[byte + 1] |= (u >> (8 - shift)) as u8;
        }
    }
    Ok(Packed { bits, signed, len: vbar.len(), step, bytes })
}

/// Unpack back to integer values in [-Qn, Qp].
pub fn unpack(p: &Packed) -> Vec<i32> {
    let (qn, _) = super::lsq::qrange(p.bits, p.signed);
    let mask = (1u64 << p.bits) - 1;
    let mut out = Vec::with_capacity(p.len);
    for i in 0..p.len {
        let bitpos = i * p.bits as usize;
        let byte = bitpos / 8;
        let shift = bitpos % 8;
        let mut u = (p.bytes[byte] as u64) >> shift;
        if shift + p.bits as usize > 8 {
            u |= (p.bytes[byte + 1] as u64) << (8 - shift);
        }
        out.push(((u & mask) as i64 - qn) as i32);
    }
    out
}

/// Dequantize a packed tensor back to f32 (vbar * s, Eq. 2).
pub fn dequantize(p: &Packed) -> Vec<f32> {
    unpack(p).into_iter().map(|v| v as f32 * p.step).collect()
}

/// Quantize an f32 weight tensor with step `s` and pack it.
pub fn quantize_and_pack(w: &[f32], s: f32, bits: u32, signed: bool) -> Result<Packed> {
    let (qn, qp) = super::lsq::qrange(bits, signed);
    let vbar: Vec<i32> = w
        .iter()
        .map(|&x| super::lsq::quantize_vbar(x, s, qn, qp) as i32)
        .collect();
    pack(&vbar, bits, signed, s)
}

impl Packed {
    /// Storage bytes including the fp32 step size.
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len() + 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 1..=8u32 {
            for signed in [true, false] {
                let (qn, qp) = crate::quant::lsq::qrange(bits, signed);
                let vals: Vec<i32> = (-qn..=qp).map(|v| v as i32).collect();
                let p = pack(&vals, bits, signed, 0.5).unwrap();
                assert_eq!(unpack(&p), vals, "bits={bits} signed={signed}");
            }
        }
    }

    #[test]
    fn density() {
        let vals = vec![0i32; 100];
        let p = pack(&vals, 3, false, 1.0).unwrap();
        assert_eq!(p.bytes.len(), (100 * 3 + 7) / 8); // 38 bytes
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(pack(&[5], 2, true, 1.0).is_err()); // Qp = 1
        assert!(pack(&[-1], 2, false, 1.0).is_err()); // unsigned
    }

    #[test]
    fn dequantize_matches_eq2() {
        let w = [0.26f32, -0.6, 0.0, 10.0];
        let p = quantize_and_pack(&w, 0.25, 2, true).unwrap();
        let dq = dequantize(&p);
        assert_eq!(dq, vec![0.25, -0.5, 0.0, 0.25]);
    }

    #[test]
    fn unaligned_lengths() {
        for n in [1usize, 3, 7, 9, 63, 65] {
            let vals: Vec<i32> = (0..n).map(|i| (i % 4) as i32).collect();
            let p = pack(&vals, 3, false, 1.0).unwrap();
            assert_eq!(unpack(&p), vals);
        }
    }
}
