//! Generators for the paper's Figures 2-4 and the Section-3.6 study.

use std::path::Path;

use anyhow::Result;

use super::{emit, paper};
use crate::analyze::{curves, qerror, rratio};
use crate::coordinator::sweep::{ensure_fp32, finetune_job, SweepScale};
use crate::coordinator::run_sweep;
use crate::quant::error::Metric;
use crate::quant::model_size::{megabytes, model_bytes, pareto_frontier, SizePoint};
use crate::runtime::{Engine, Manifest};
use crate::tensor::Checkpoint;
use crate::util::cli::Args;
use crate::util::table::Table;

/// Figure 2: quantizer output and ∂v̂/∂s curves for LSQ vs QIL vs PACT.
pub fn fig2(scale: &SweepScale, _args: &Args) -> Result<()> {
    let engine = Engine::new(Path::new(&scale.artifacts_dir))?;
    let c = curves::from_artifact(&engine, -1.0, 4.0)?;
    let r = curves::from_rust(-1.0, 4.0, c.v.len());
    // Cross-validate artifact vs pure-Rust quantizer.
    let max_dev = c
        .ds_lsq
        .iter()
        .zip(&r.ds_lsq)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("fig2: artifact-vs-rust max |Δ ds_lsq| = {max_dev:.2e}");

    let dir = Path::new(&scale.out_dir).join("repro");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("fig2_curves.csv"), curves::to_csv(&c))?;

    println!("\nReproduction target: LSQ's gradient is a sawtooth (sensitive to the");
    println!("distance from each transition point, sign flips inside the domain);");
    println!("QIL's is monotone in v; PACT's is zero below the clip point.\n");

    // Compact summary table: sample the gradients at probe points.
    let probe = [0.3f32, 0.7, 1.3, 1.7, 3.5];
    let mut t = Table::new(
        "Figure 2B — d(vhat)/ds at probe v (s=1, Qn=0, Qp=3)",
        &["v", "LSQ", "QIL", "PACT"],
    );
    for p in probe {
        let i = c
            .v
            .iter()
            .position(|&x| x >= p)
            .unwrap_or(c.v.len() - 1);
        t.row(vec![
            format!("{p:.1}"),
            format!("{:+.3}", c.ds_lsq[i]),
            format!("{:+.3}", c.ds_qil[i]),
            format!("{:+.3}", c.ds_pact[i]),
        ]);
    }
    emit(scale, "fig2", &t)?;
    anyhow::ensure!(max_dev < 1e-4, "artifact and rust quantizer disagree");
    Ok(())
}

/// Figure 3: accuracy vs model size frontier across (model, precision).
pub fn fig3(scale: &SweepScale, args: &Args) -> Result<()> {
    // Reuse table1 result JSON if present, otherwise run the sweep.
    let results_path = Path::new(&scale.out_dir).join("repro/table1_results.json");
    if !results_path.exists() {
        super::tables::table1(scale, args)?;
    }
    let manifest = Manifest::load(Path::new(&scale.artifacts_dir))?;
    let j = crate::util::json::Json::parse(&std::fs::read_to_string(&results_path)?)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut points = Vec::new();
    for r in j.as_arr().unwrap_or(&[]) {
        let tags = r.get("tags").cloned().unwrap_or(crate::util::json::Json::Null);
        let (model, bits) = match (
            tags.get("model").and_then(crate::util::json::Json::as_str),
            tags.get("bits").and_then(crate::util::json::Json::as_str),
        ) {
            (Some(m), Some(b)) => (m.to_string(), b.parse::<u32>().unwrap_or(0)),
            _ => continue,
        };
        if tags.get("method").is_some() {
            continue; // skip baseline-method runs
        }
        let top1 = r.get("top1").and_then(crate::util::json::Json::as_f64).unwrap_or(f64::NAN);
        if !top1.is_finite() {
            continue;
        }
        let fam = match manifest.families.get(&format!("{model}_q{bits}")) {
            Some(f) => f,
            None => continue,
        };
        points.push(SizePoint {
            model,
            bits,
            bytes: model_bytes(&fam.layer_meta),
            top1,
        });
    }
    anyhow::ensure!(!points.is_empty(), "no table1 results with finite top1");

    println!("\nReproduction target: some low-bit big models beat high-bit small models");
    println!("at equal size — the frontier is not precision-monotone (paper Fig. 3).\n");

    points.sort_by(|a, b| a.bytes.cmp(&b.bytes));
    let frontier = pareto_frontier(&points);
    let mut t = Table::new(
        "Figure 3 — accuracy vs model size (measured)",
        &["model", "bits", "size", "top-1", "on frontier"],
    );
    for p in &points {
        let on = frontier.iter().any(|f| f.model == p.model && f.bits == p.bits);
        t.row(vec![
            p.model.clone(),
            p.bits.to_string(),
            format!("{:.3} MB", megabytes(p.bytes)),
            format!("{:.1}", p.top1),
            if on { "*".into() } else { "".into() },
        ]);
    }
    emit(scale, "fig3", &t)
}

/// Figure 4: R-ratio (Eq. 4) per layer under the three gradient scales.
pub fn fig4(scale: &SweepScale, args: &Args) -> Result<()> {
    let model = args.str("model", "cnn_small");
    let iters = args.usize("iters", if scale.out_dir.contains("quick") { 60 } else { 500 });
    let engine = Engine::new(Path::new(&scale.artifacts_dir))?;

    println!("\nReproduction target: g=1 leaves step updates orders of magnitude too");
    println!("large (worse at higher precision); 1/sqrt(N) centers layers but keeps a");
    println!("precision trend; 1/sqrt(N*Qp) brings R near 1 across precisions.\n");

    let mut t = Table::new(
        &format!("Figure 4 — geomean R over {iters} iters ({model})"),
        &["precision", "g = 1", "g = 1/sqrt(N)", "g = 1/sqrt(N*Qp)"],
    );
    let mut csv = String::from("bits,gscale,layer,mean_r,std_r\n");
    for bits in [2u32, 3, 4, 8] {
        let mut cfg = scale.base_cfg(&model, bits);
        cfg.train.max_steps = iters;
        let mut cells = vec![format!("{bits}-bit")];
        for gs in ["one", "sqrtn", "full"] {
            match rratio::measure(&engine, &cfg, gs, iters) {
                Ok(rep) => {
                    for l in &rep.layers {
                        csv.push_str(&format!(
                            "{bits},{gs},{},{:.6e},{:.6e}\n",
                            l.layer, l.mean_r, l.std_r
                        ));
                    }
                    cells.push(format!("{:.3e}", rep.geomean_r()));
                }
                Err(e) => {
                    cells.push(format!("n/a ({e})"));
                }
            }
        }
        t.row(cells);
    }
    let dir = Path::new(&scale.out_dir).join("repro");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("fig4_layers.csv"), csv)?;
    emit(scale, "fig4", &t)
}

/// Section 3.6: learned ŝ vs quantization-error-minimizing s.
pub fn qerror(scale: &SweepScale, args: &Args) -> Result<()> {
    let model = args.str("model", "cnn_small");
    let bits = args.usize("bits", 2) as u32;
    let family = format!("{model}_q{bits}");

    // Need a trained 2-bit checkpoint; train one if absent.
    let ckpt_path = Path::new(&scale.out_dir).join(format!("{family}")).join("final.ckpt");
    if !ckpt_path.exists() {
        ensure_fp32(scale, &[&model])?;
        let job = finetune_job(scale, &model, bits);
        let rep = run_sweep(Path::new(&scale.artifacts_dir), vec![job], 1)?;
        anyhow::ensure!(
            rep.results[0].error.is_none(),
            "training for qerror failed: {:?}",
            rep.results[0].error
        );
    }
    let manifest = Manifest::load(Path::new(&scale.artifacts_dir))?;
    let fam = manifest.family(&family)?;
    let ckpt = Checkpoint::load(&ckpt_path)?;

    let rep = qerror::analyze_weights(fam, &ckpt)?;
    let (am, astd) = qerror::act_step_stats(fam, &ckpt)?;

    println!("\nReproduction target: the learned ŝ does NOT coincide with the");
    println!("MAE/MSE/KL-minimizing step size (paper: 47/28/46% mean |Δ| for weights).\n");
    println!(
        "learned steps: weights ŝ = {:.4} ± {:.4}   activations ŝ = {:.3} ± {:.3}",
        rep.s_hat_mean, rep.s_hat_std, am, astd
    );

    let mut t = Table::new(
        &format!("Section 3.6 — % |ŝ - s_min| across weight layers ({bits}-bit {model})"),
        &["metric", "measured avg %", "paper (R18 weights)"],
    );
    let (pm, ps, pk) = paper::QERROR_WEIGHTS_PCT;
    t.row(vec!["MAE".into(), format!("{:.0}%", rep.avg_pct_diff(Metric::MeanAbs)), format!("{pm:.0}%")]);
    t.row(vec!["MSE".into(), format!("{:.0}%", rep.avg_pct_diff(Metric::MeanSq)), format!("{ps:.0}%")]);
    t.row(vec!["KL".into(), format!("{:.0}%", rep.avg_pct_diff(Metric::Kl)), format!("{pk:.0}%")]);
    emit(scale, "qerror", &t)?;

    let mut lt = Table::new(
        "Section 3.6 — per-layer detail",
        &["layer", "bits", "s_hat", "s_min(MAE)", "s_min(MSE)", "s_min(KL)"],
    );
    for l in &rep.layers {
        lt.row(vec![
            l.layer.clone(),
            l.bits.to_string(),
            format!("{:.5}", l.s_hat),
            format!("{:.5}", l.s_min_mae),
            format!("{:.5}", l.s_min_mse),
            format!("{:.5}", l.s_min_kl),
        ]);
    }
    emit(scale, "qerror_layers", &lt)
}
