//! Repro harness: one generator per paper table/figure (see DESIGN.md's
//! experiment index). Each generator runs the necessary sweeps (reusing
//! cached fp32 pretrains and per-run results where available), prints the
//! paper-reference vs measured rows, and writes a CSV next to the run dir.
//!
//! Absolute ImageNet accuracies are not reproducible on this substrate
//! (synthetic 32x32 data, CPU); the reproduction target is the *shape* of
//! each result — orderings, gaps and crossovers — which every generator
//! states explicitly in its output header.

pub mod figures;
pub mod paper;
pub mod tables;

use std::path::Path;

use anyhow::Result;

use crate::coordinator::sweep::SweepScale;
use crate::util::cli::Args;

pub fn scale_from_args(args: &Args) -> SweepScale {
    let mut s = if args.flag("quick") { SweepScale::quick() } else { SweepScale::standard() };
    if let Some(v) = args.opt_str("train-size") {
        s.train_size = v.parse().unwrap_or(s.train_size);
    }
    if let Some(v) = args.opt_str("test-size") {
        s.test_size = v.parse().unwrap_or(s.test_size);
    }
    if args.has("epochs") {
        s.epochs_q = args.usize("epochs", s.epochs_q);
        s.epochs_fp32 = args.usize("epochs", s.epochs_fp32).max(s.epochs_q);
    }
    s.workers = args.usize("workers", s.workers);
    if let Some(v) = args.opt_str("out-dir") {
        s.out_dir = v;
    }
    if let Some(v) = args.opt_str("artifacts") {
        s.artifacts_dir = v;
    }
    s
}

/// Write a rendered table + CSV under `<out_dir>/repro/`.
pub fn emit(scale: &SweepScale, name: &str, table: &crate::util::table::Table) -> Result<()> {
    let dir = Path::new(&scale.out_dir).join("repro");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())?;
    let rendered = table.render();
    std::fs::write(dir.join(format!("{name}.txt")), &rendered)?;
    println!("{rendered}");
    Ok(())
}

pub fn run(which: &str, args: &Args) -> Result<()> {
    let scale = scale_from_args(args);
    match which {
        "table1" => tables::table1(&scale, args),
        "table2" => tables::table2(&scale, args),
        "table3" => tables::table3(&scale, args),
        "table4" => tables::table4(&scale, args),
        "lr-ablation" => tables::lr_ablation(&scale, args),
        "fig2" => figures::fig2(&scale, args),
        "fig3" => figures::fig3(&scale, args),
        "fig4" => figures::fig4(&scale, args),
        "qerror" => figures::qerror(&scale, args),
        "all" => {
            tables::table1(&scale, args)?;
            tables::table2(&scale, args)?;
            tables::table3(&scale, args)?;
            tables::table4(&scale, args)?;
            tables::lr_ablation(&scale, args)?;
            figures::fig2(&scale, args)?;
            figures::fig3(&scale, args)?;
            figures::fig4(&scale, args)?;
            figures::qerror(&scale, args)
        }
        other => anyhow::bail!(
            "unknown repro target {other:?} \
             (table1|table2|table3|table4|lr-ablation|fig2|fig3|fig4|qerror|all)"
        ),
    }
}
