//! Paper-reported reference numbers (ImageNet), embedded so every repro
//! table prints paper-vs-measured side by side. Source: Esser et al.,
//! ICLR 2020, Tables 1-4 and Sections 3.4-3.6.

/// Table 1, LSQ rows: (network, fp32 top1, [top1@2,3,4,8]).
pub const TABLE1_LSQ_TOP1: &[(&str, f64, [f64; 4])] = &[
    ("ResNet-18", 70.5, [67.6, 70.2, 71.1, 71.1]),
    ("ResNet-34", 74.1, [71.6, 73.4, 74.1, 74.1]),
    ("ResNet-50", 76.9, [73.7, 75.8, 76.7, 76.8]),
    ("ResNet-101", 78.2, [76.1, 77.5, 78.3, 78.1]),
    ("ResNet-152", 78.9, [76.9, 78.2, 78.5, 78.5]),
    ("VGG-16bn", 73.4, [71.4, 73.4, 74.0, 73.5]),
    ("SqueezeNext-23-2x", 67.3, [53.3, 63.7, 67.4, 67.0]),
];

/// Table 1, competing methods on ResNet-18 top1 (2/3/4-bit; None = absent).
pub const TABLE1_R18_METHODS: &[(&str, [Option<f64>; 3])] = &[
    ("LSQ", [Some(67.6), Some(70.2), Some(71.1)]),
    ("QIL", [Some(65.7), Some(69.2), Some(70.1)]),
    ("LQ-Nets", [Some(64.9), Some(68.2), Some(69.3)]),
    ("PACT", [Some(64.4), Some(68.1), Some(69.2)]),
    ("NICE", [None, Some(67.7), Some(69.8)]),
    ("Regularization", [Some(61.7), None, Some(67.3)]),
];

/// Table 2: ResNet-18 top1 per (weight-decay factor of 1e-4, precision).
pub const TABLE2: &[(f64, [f64; 4])] = &[
    (1.0, [66.9, 70.1, 71.0, 71.1]),
    (0.5, [67.3, 70.2, 70.9, 71.1]),
    (0.25, [67.6, 70.0, 70.9, 71.0]),
    (0.125, [67.4, 66.9, 70.8, 71.0]),
];

/// Table 3: 2-bit ResNet-18 (gradient scale label, lr, top1; NaN = did not
/// converge).
pub const TABLE3: &[(&str, f64, f64)] = &[
    ("1/sqrt(N*Qp)", 0.01, 67.6),
    ("1/sqrt(N)", 0.01, 67.3),
    ("1", 0.01, f64::NAN),
    ("1 @ lr/100", 0.0001, 64.2),
    ("10/sqrt(N*Qp)", 0.01, 67.4),
    ("1/(10 sqrt(N*Qp))", 0.01, 67.3),
];

/// Table 4 (LSQ + KD): (network, [top1@2,3,4,8], fp32 top1).
pub const TABLE4: &[(&str, [f64; 4], f64)] = &[
    ("ResNet-18", [67.9, 70.6, 71.2, 71.1], 70.5),
    ("ResNet-34", [72.4, 74.3, 74.8, 74.1], 74.1),
    ("ResNet-50", [74.6, 76.9, 77.6, 76.8], 76.9),
];

/// Section 3.5: 2-bit ResNet-18 cosine (67.6) vs step decay (67.2).
pub const LR_ABLATION: (f64, f64) = (67.6, 67.2);

/// Section 3.6 percent |ŝ - s_min| for weights: (MAE, MSE, KL).
pub const QERROR_WEIGHTS_PCT: (f64, f64, f64) = (47.0, 28.0, 46.0);

/// Section 3.4 prose: with g=1, step updates are 2-3 orders of magnitude
/// larger than weight updates (relative), growing with precision.
pub const R_IMBALANCE_G1_MIN: f64 = 100.0;

/// Map our stand-in architecture names to the paper rows they proxy.
pub fn proxy_for(model: &str) -> &'static str {
    match model {
        "resnet8" => "ResNet-18 (proxy: resnet8)",
        "resnet14" => "ResNet-34 (proxy: resnet14)",
        "resnet20" => "ResNet-18 (proxy: resnet20)",
        "resnet32" => "ResNet-50 (proxy: resnet32)",
        "vgg_small" => "VGG-16bn (proxy: vgg_small)",
        "sqnxt_small" => "SqueezeNext-23-2x (proxy: sqnxt_small)",
        "cnn_small" => "small-CNN (no paper row)",
        other => {
            let _ = other;
            "unmapped"
        }
    }
}

/// Paper Table-1 reference row for a proxy model (fp32, [2,3,4,8]).
pub fn table1_ref(model: &str) -> Option<(f64, [f64; 4])> {
    let name = match model {
        "resnet8" | "resnet20" => "ResNet-18",
        "resnet14" => "ResNet-34",
        "resnet32" => "ResNet-50",
        "vgg_small" => "VGG-16bn",
        "sqnxt_small" => "SqueezeNext-23-2x",
        _ => return None,
    };
    TABLE1_LSQ_TOP1
        .iter()
        .find(|(n, _, _)| *n == name)
        .map(|(_, fp, row)| (*fp, *row))
}
