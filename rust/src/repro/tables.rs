//! Generators for the paper's Tables 1-4 and the Section-3.5 LR ablation.

use anyhow::Result;

use super::{emit, paper};
use crate::coordinator::sweep::{
    ensure_fp32, lr_ablation_jobs, method_jobs, table1_jobs, table2_jobs, table3_jobs,
    table4_jobs, SweepScale,
};
use crate::coordinator::{run_sweep, SweepReport};
use crate::util::cli::Args;
use crate::util::table::{acc, Table};

fn models_from_args(scale: &SweepScale, args: &Args, default_quick: &[&'static str],
                    default_std: &[&'static str]) -> Vec<String> {
    if let Some(m) = args.opt_str("models") {
        m.split(',').map(str::to_string).collect()
    } else if scale.out_dir.contains("quick") {
        default_quick.iter().map(|s| s.to_string()).collect()
    } else {
        default_std.iter().map(|s| s.to_string()).collect()
    }
}

const PRECISIONS: [u32; 4] = [2, 3, 4, 8];

fn measured(rep: &SweepReport, model: &str, bits: u32, kd: bool) -> Option<f64> {
    rep.results
        .iter()
        .find(|r| {
            r.tags.get("model").map(String::as_str) == Some(model)
                && r.tags.get("bits").map(String::as_str) == Some(&bits.to_string())
                && r.tags.contains_key("kd") == kd
                && r.error.is_none()
        })
        .map(|r| r.top1)
}

fn measured5(rep: &SweepReport, model: &str, bits: u32) -> Option<f64> {
    rep.results
        .iter()
        .find(|r| {
            r.tags.get("model").map(String::as_str) == Some(model)
                && r.tags.get("bits").map(String::as_str) == Some(&bits.to_string())
                && r.error.is_none()
        })
        .map(|r| r.top5)
}

/// Table 1: accuracy vs precision across architectures + competing
/// quantizer gradients at 2-bit.
pub fn table1(scale: &SweepScale, args: &Args) -> Result<()> {
    let models = models_from_args(
        scale,
        args,
        &["cnn_small", "resnet8"],
        &["cnn_small", "resnet8", "resnet20", "vgg_small", "sqnxt_small"],
    );
    let model_refs: Vec<&str> = models.iter().map(String::as_str).collect();
    let fp32 = ensure_fp32(scale, &model_refs)?;

    // Only request precisions whose artifacts exist (default set trims the
    // secondary architectures to 2/4-bit).
    let manifest = crate::runtime::Manifest::load(std::path::Path::new(&scale.artifacts_dir))?;
    let mut jobs = Vec::new();
    for m in &model_refs {
        let precs: Vec<u32> = PRECISIONS
            .iter()
            .copied()
            .filter(|b| manifest.families.contains_key(&format!("{m}_q{b}")))
            .collect();
        jobs.extend(table1_jobs(scale, &[m], &precs));
    }
    // Competing gradient methods on the sweep model.
    let methods = ["qil", "pact", "fixed"];
    jobs.extend(method_jobs(scale, "cnn_small", &methods));

    let rep = run_sweep(std::path::Path::new(&scale.artifacts_dir), jobs, scale.workers)?;
    rep.save(&std::path::Path::new(&scale.out_dir).join("repro/table1_results.json"))?;

    println!("\nReproduction target: LSQ accuracy increases with precision; 8-bit ≈ fp32;");
    println!("2-bit drop is largest for the parameter-lean SqueezeNext-style model;");
    println!("LSQ beats QIL/PACT/fixed-gradient baselines at 2-bit.\n");

    let mut t = Table::new(
        "Table 1 — top-1 @ precision (measured on synthshapes | paper ImageNet in brackets)",
        &["network", "fp32", "2", "3", "4", "8"],
    );
    for m in &model_refs {
        let praw = paper::table1_ref(m);
        let fmt = |bits_idx: usize, v: Option<f64>| -> String {
            let p = praw.map(|(_, row)| row[bits_idx]);
            match (v, p) {
                (Some(v), Some(p)) => format!("{v:.1} [{p:.1}]"),
                (Some(v), None) => format!("{v:.1}"),
                (None, _) => "-".into(),
            }
        };
        let fp = fp32.get(*m).map(|x| x.0);
        let fp_s = match (fp, praw) {
            (Some(v), Some((p, _))) => format!("{v:.1} [{p:.1}]"),
            (Some(v), None) => format!("{v:.1}"),
            _ => "-".into(),
        };
        t.row(vec![
            paper::proxy_for(m).to_string(),
            fp_s,
            fmt(0, measured(&rep, m, 2, false)),
            fmt(1, measured(&rep, m, 3, false)),
            fmt(2, measured(&rep, m, 4, false)),
            fmt(3, measured(&rep, m, 8, false)),
        ]);
    }
    emit(scale, "table1", &t)?;

    let mut t5 = Table::new(
        "Table 1 (top-5, measured)",
        &["network", "2", "3", "4", "8"],
    );
    for m in &model_refs {
        t5.row(vec![
            m.to_string(),
            acc(measured5(&rep, m, 2)),
            acc(measured5(&rep, m, 3)),
            acc(measured5(&rep, m, 4)),
            acc(measured5(&rep, m, 8)),
        ]);
    }
    emit(scale, "table1_top5", &t5)?;

    let mut tm = Table::new(
        "Table 1 — quantizer-gradient comparison, 2-bit cnn_small (paper: R18 2-bit)",
        &["method", "top-1 (measured)", "paper R18@2"],
    );
    let paper2: std::collections::BTreeMap<&str, f64> = [
        ("lsq", 67.6),
        ("qil", 65.7),
        ("pact", 64.4),
        ("fixed", f64::NAN),
    ]
    .into_iter()
    .collect();
    let lsq_m = measured(&rep, "cnn_small", 2, false);
    tm.row(vec!["lsq".into(), acc(lsq_m), "67.6".into()]);
    for m in methods {
        let got = rep
            .results
            .iter()
            .find(|r| r.tags.get("method").map(String::as_str) == Some(m))
            .map(|r| r.top1);
        let p = paper2.get(m).copied().unwrap_or(f64::NAN);
        tm.row(vec![
            m.to_string(),
            acc(got),
            if p.is_nan() { "-".into() } else { format!("{p:.1}") },
        ]);
    }
    emit(scale, "table1_methods", &tm)
}

/// Table 2: weight-decay sweep per precision.
pub fn table2(scale: &SweepScale, args: &Args) -> Result<()> {
    let model = args.str("model", "cnn_small");
    ensure_fp32(scale, &[&model])?;
    let jobs = table2_jobs(scale, &model, &PRECISIONS);
    let rep = run_sweep(std::path::Path::new(&scale.artifacts_dir), jobs, scale.workers)?;
    rep.save(&std::path::Path::new(&scale.out_dir).join("repro/table2_results.json"))?;

    println!("\nReproduction target: lower precision prefers less weight decay —");
    println!("the per-column argmax moves to smaller factors as bits decrease.\n");

    let mut t = Table::new(
        &format!("Table 2 — top-1 vs weight decay ({model}; paper: ResNet-18 in brackets)"),
        &["weight decay", "2-bit", "3-bit", "4-bit", "8-bit"],
    );
    for (i, (f, prow)) in paper::TABLE2.iter().enumerate() {
        let mut cells = vec![format!("{f} x 1e-4")];
        for (j, bits) in PRECISIONS.iter().enumerate() {
            let got = rep
                .by_tags(&[("wd", &format!("{f}")), ("bits", &bits.to_string())])
                .map(|r| r.top1);
            cells.push(match got {
                Some(v) => format!("{v:.1} [{:.1}]", prow[j]),
                None => format!("- [{:.1}]", prow[j]),
            });
        }
        let _ = i;
        t.row(cells);
    }
    emit(scale, "table2", &t)
}

/// Table 3: step-size gradient-scale ablation at 2-bit.
pub fn table3(scale: &SweepScale, args: &Args) -> Result<()> {
    let model = args.str("model", "cnn_small");
    ensure_fp32(scale, &[&model])?;
    let jobs = table3_jobs(scale, &model);
    let rep = run_sweep(std::path::Path::new(&scale.artifacts_dir), jobs, scale.workers)?;
    rep.save(&std::path::Path::new(&scale.out_dir).join("repro/table3_results.json"))?;

    println!("\nReproduction target: full scale 1/sqrt(N*Qp) is best; g=1 at the");
    println!("standard lr diverges; recovering with lr/100 still loses accuracy.\n");

    let mut t = Table::new(
        &format!("Table 3 — gradient scale ablation, 2-bit {model} (paper: R18 in brackets)"),
        &["gradient scale", "lr factor", "top-1", "paper"],
    );
    for (i, (label, plr, ptop)) in paper::TABLE3.iter().enumerate() {
        let got = rep.by_tags(&[("row", &i.to_string())]);
        let cell = match got {
            Some(r) if !r.converged && r.error.is_none() => {
                format!("{:.1} (no convergence)", r.top1)
            }
            Some(r) if r.error.is_none() => format!("{:.1}", r.top1),
            _ => "-".into(),
        };
        t.row(vec![
            label.to_string(),
            format!("{plr}"),
            cell,
            if ptop.is_nan() { "did not converge".into() } else { format!("{ptop:.1}") },
        ]);
    }
    emit(scale, "table3", &t)
}

/// Table 4: LSQ + knowledge distillation.
pub fn table4(scale: &SweepScale, args: &Args) -> Result<()> {
    let models = models_from_args(scale, args, &["cnn_small"], &["cnn_small", "resnet20"]);
    let model_refs: Vec<&str> = models.iter().map(String::as_str).collect();
    let fp32 = ensure_fp32(scale, &model_refs)?;

    let manifest = crate::runtime::Manifest::load(std::path::Path::new(&scale.artifacts_dir))?;
    let mut jobs = Vec::new();
    for m in &model_refs {
        let precs: Vec<u32> = PRECISIONS
            .iter()
            .copied()
            .filter(|b| {
                manifest
                    .artifacts
                    .values()
                    .any(|a| a.kind == "train_kd" && a.family.as_deref() == Some(&format!("{m}_q{b}")))
            })
            .collect();
        jobs.extend(table4_jobs(scale, &[m], &precs));
        // plain-LSQ comparators at the same precisions
        jobs.extend(table1_jobs(scale, &[m], &precs));
    }
    let rep = run_sweep(std::path::Path::new(&scale.artifacts_dir), jobs, scale.workers)?;
    rep.save(&std::path::Path::new(&scale.out_dir).join("repro/table4_results.json"))?;

    println!("\nReproduction target: KD improves the quantized student (biggest gain");
    println!("at low precision), pushing 3-bit to (or past) the fp32 baseline.\n");

    let mut t = Table::new(
        "Table 4 — LSQ+KD vs LSQ top-1 (measured; paper R18 KD row in brackets)",
        &["network", "2", "3", "4", "8", "fp32"],
    );
    let paper_kd = paper::TABLE4[0].1; // ResNet-18 row as the bracket ref
    for m in &model_refs {
        let mut cells = vec![format!("{m} +KD")];
        for (j, bits) in PRECISIONS.iter().enumerate() {
            cells.push(match measured(&rep, m, *bits, true) {
                Some(v) => format!("{v:.1} [{:.1}]", paper_kd[j]),
                None => "-".into(),
            });
        }
        cells.push(fp32.get(*m).map(|x| format!("{:.1}", x.0)).unwrap_or("-".into()));
        t.row(cells);
        let mut cells = vec![format!("{m} LSQ only")];
        for bits in PRECISIONS {
            cells.push(acc(measured(&rep, m, bits, false)));
        }
        cells.push("".into());
        t.row(cells);
    }
    emit(scale, "table4", &t)
}

/// Section 3.5: cosine vs step decay.
pub fn lr_ablation(scale: &SweepScale, args: &Args) -> Result<()> {
    let model = args.str("model", "cnn_small");
    ensure_fp32(scale, &[&model])?;
    let jobs = lr_ablation_jobs(scale, &model);
    let rep = run_sweep(std::path::Path::new(&scale.artifacts_dir), jobs, scale.workers)?;

    println!("\nReproduction target: cosine ≥ step decay by a small margin (paper: +0.4).\n");
    let mut t = Table::new(
        &format!("Section 3.5 — LR schedule, 2-bit {model} (paper R18 in brackets)"),
        &["schedule", "top-1"],
    );
    let (pc, ps) = paper::LR_ABLATION;
    let get = |s: &str| rep.by_tags(&[("sched", s)]).map(|r| r.top1);
    t.row(vec!["cosine".into(), get("cosine").map(|v| format!("{v:.1} [{pc:.1}]")).unwrap_or("-".into())]);
    t.row(vec!["step x0.1".into(), get("step").map(|v| format!("{v:.1} [{ps:.1}]")).unwrap_or("-".into())]);
    emit(scale, "lr_ablation", &t)
}
