//! The `.lsqa` byte-level format: header, section table, CRC32, and the
//! typed [`ArtifactError`] every reader-side failure maps to.
//!
//! Layout (all integers little-endian on disk; the header's endian tag
//! lets a big-endian reader refuse loudly instead of misparsing):
//!
//! ```text
//! offset 0, 64 bytes — header
//!   0..4    magic  b"LSQA"
//!   4..6    format version (u16, currently 1)
//!   6..8    endian tag 0x1234 (reads as 0x3412 on a byte-swapped view)
//!   8..12   header length (u32, 64)
//!   12..16  section count (u32)
//!   16..24  section table offset (u64, 64)
//!   24..32  total file length (u64)
//!   32..60  reserved, zero
//!   60..64  CRC32 of header bytes 0..60
//! offset 64 — section table, `count` × 32-byte entries
//!   0..4    section kind (u32: 1 META, 2 TENSORS, 3 PACKED, 4 PANELS)
//!   4..8    SIMD level (u32 index into `SimdLevel::ALL`; 0 unless PANELS)
//!   8..16   section offset (u64, 64-byte aligned)
//!   16..24  section length (u64)
//!   24..28  CRC32 of the section body
//!   28..32  reserved, zero
//! then the section bodies, each starting on a 64-byte boundary
//! ```
//!
//! Section starts (and every panel blob inside a PANELS section) are
//! 64-byte aligned *file* offsets; the loader reads the whole file into a
//! page-aligned arena, so alignment in the file is alignment in memory —
//! the layout is mmap-ready by construction (DESIGN.md §Artifact-format).

use std::path::PathBuf;

/// File magic: the first four bytes of every `.lsqa`.
pub const MAGIC: [u8; 4] = *b"LSQA";
/// Current (and only) format version.
pub const VERSION: u16 = 1;
/// Endian tag as written by a little-endian writer.
pub const ENDIAN_TAG: u16 = 0x1234;
/// Header size in bytes.
pub const HEADER_LEN: usize = 64;
/// Section-table entry size in bytes.
pub const SECTION_ENTRY_LEN: usize = 32;
/// Alignment of every section start and panel blob (file offsets).
pub const ALIGN: usize = 64;

/// Section kind: the artifact metadata JSON (family, arch IR, shapes).
pub const SEC_META: u32 = 1;
/// Section kind: fp32 parameter tensors (steps, biases, BN, fp32 weights).
pub const SEC_TENSORS: u32 = 2;
/// Section kind: bit-packed quantized weights (the fallback working set).
pub const SEC_PACKED: u32 = 3;
/// Section kind: prebuilt panel blobs for one SIMD level.
pub const SEC_PANELS: u32 = 4;

/// Round `off` up to the next [`ALIGN`] boundary.
pub fn align_up(off: usize) -> usize {
    off.div_ceil(ALIGN) * ALIGN
}

/// CRC32 (IEEE 802.3, reflected, the zlib/`cksum -o3` polynomial) over
/// `bytes`. Table-driven, table built once per process.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Every way a `.lsqa` can fail to load or bind, as a typed variant — the
/// corruption battery in `tests/artifact.rs` asserts the reader never
/// panics and never silently falls back; it returns exactly one of these.
#[derive(Debug)]
pub enum ArtifactError {
    /// The file could not be opened or read.
    Io {
        /// The artifact path the I/O failed on.
        path: PathBuf,
        /// The underlying OS error.
        err: std::io::Error,
    },
    /// The file (or a section/record inside it) ends before the bytes the
    /// header or a directory said would be there.
    Truncated {
        /// What was being read when the bytes ran out.
        what: String,
    },
    /// The first four bytes are not `LSQA` — not an artifact at all.
    BadMagic,
    /// A well-formed artifact of a format version this reader predates
    /// (or postdates).
    UnsupportedVersion {
        /// Version found in the header.
        got: u16,
        /// Version this reader speaks.
        want: u16,
    },
    /// The endian tag read byte-swapped: the artifact was written on a
    /// machine of the opposite endianness.
    EndianMismatch,
    /// A CRC32 over the header or a section body did not match the
    /// recorded value — bit rot or tampering.
    ChecksumMismatch {
        /// Which checksummed range failed (`header` or `section <kind>`).
        section: String,
    },
    /// Structurally invalid content inside an intact (checksum-passing)
    /// envelope: bad counts, out-of-range fields, undecodable JSON.
    Malformed {
        /// What was structurally wrong.
        what: String,
    },
    /// A panel/packed entry exists but disagrees with what the binding
    /// model expects (shape, bits, activation class, or an invalid
    /// [`crate::runtime::kernels::PanelGeom`]) — refusing beats silently
    /// rebuilding.
    GeomMismatch {
        /// The layer whose recorded entry disagrees.
        layer: String,
        /// The specific disagreement.
        detail: String,
    },
    /// The artifact holds a different model family than the caller asked
    /// to bind.
    FamilyMismatch {
        /// Family the caller wanted.
        want: String,
        /// Family the artifact holds.
        got: String,
    },
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io { path, err } => {
                write!(f, "artifact {}: {err}", path.display())
            }
            ArtifactError::Truncated { what } => {
                write!(f, "artifact truncated while reading {what}")
            }
            ArtifactError::BadMagic => write!(f, "not an .lsqa artifact (bad magic)"),
            ArtifactError::UnsupportedVersion { got, want } => {
                write!(f, "unsupported artifact version {got} (this reader speaks {want})")
            }
            ArtifactError::EndianMismatch => {
                write!(f, "artifact was written on a machine of the opposite endianness")
            }
            ArtifactError::ChecksumMismatch { section } => {
                write!(f, "artifact checksum mismatch in {section}")
            }
            ArtifactError::Malformed { what } => write!(f, "malformed artifact: {what}"),
            ArtifactError::GeomMismatch { layer, detail } => {
                write!(f, "artifact layer {layer}: {detail}")
            }
            ArtifactError::FamilyMismatch { want, got } => {
                write!(f, "artifact holds family {got:?}, caller asked for {want:?}")
            }
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io { err, .. } => Some(err),
            _ => None,
        }
    }
}

/// Shorthand used throughout the reader.
pub type AResult<T> = Result<T, ArtifactError>;

fn truncated(what: &str) -> ArtifactError {
    ArtifactError::Truncated { what: what.to_string() }
}

/// Bounds-checked little-endian cursor over a section body. Every read
/// returns [`ArtifactError::Truncated`] instead of panicking, which is
/// what lets the corruption battery feed arbitrary bytes through the
/// whole parse path.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'a str,
}

impl<'a> Cursor<'a> {
    /// Cursor over `buf`; `what` names the region in truncation errors.
    pub fn new(buf: &'a [u8], what: &'a str) -> Cursor<'a> {
        Cursor { buf, pos: 0, what }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take `n` raw bytes.
    pub fn bytes(&mut self, n: usize) -> AResult<&'a [u8]> {
        if self.remaining() < n {
            return Err(truncated(self.what));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> AResult<u8> {
        Ok(self.bytes(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn u16(&mut self) -> AResult<u16> {
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().expect("2 bytes")))
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> AResult<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().expect("4 bytes")))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> AResult<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().expect("8 bytes")))
    }

    /// Read a little-endian `i64`.
    pub fn i64(&mut self) -> AResult<i64> {
        Ok(self.u64()? as i64)
    }

    /// Read a little-endian `f32` (bit pattern — exact roundtrip).
    pub fn f32(&mut self) -> AResult<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Read a `u64` that must fit `usize` on this host.
    pub fn usize(&mut self) -> AResult<usize> {
        usize::try_from(self.u64()?).map_err(|_| ArtifactError::Malformed {
            what: format!("{}: length exceeds this host's usize", self.what),
        })
    }

    /// Read a length-prefixed (u16) UTF-8 name.
    pub fn name(&mut self) -> AResult<String> {
        let n = self.u16()? as usize;
        let raw = self.bytes(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| ArtifactError::Malformed {
            what: format!("{}: non-UTF-8 name", self.what),
        })
    }
}

/// Little-endian append helpers for the writer side (infallible; the
/// writer builds the whole artifact in memory and writes it once).
pub struct Buf(pub Vec<u8>);

impl Buf {
    /// Fresh empty buffer.
    pub fn new() -> Buf {
        Buf(Vec::new())
    }

    /// Append raw bytes.
    pub fn bytes(&mut self, b: &[u8]) {
        self.0.extend_from_slice(b);
    }

    /// Append a `u8`.
    pub fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    /// Append a little-endian `u16`.
    pub fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian `u32`.
    pub fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian `u64`.
    pub fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian `i64`.
    pub fn i64(&mut self, v: i64) {
        self.u64(v as u64);
    }

    /// Append an `f32` bit pattern (exact roundtrip).
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// Append a length-prefixed (u16) UTF-8 name.
    ///
    /// # Panics
    /// If the name exceeds `u16::MAX` bytes (parameter names are short).
    pub fn name(&mut self, s: &str) {
        let n = u16::try_from(s.len()).expect("name fits u16");
        self.u16(n);
        self.bytes(s.as_bytes());
    }
}

impl Default for Buf {
    fn default() -> Buf {
        Buf::new()
    }
}

/// One parsed section-table row (also surfaced by
/// [`super::LoadedArtifact::sections`] so tests can aim bit flips at a
/// specific body and `artifact inspect` can print the table).
#[derive(Clone, Copy, Debug)]
pub struct SectionInfo {
    /// Section kind (`SEC_*`).
    pub kind: u32,
    /// Raw SIMD-level index (meaningful for [`SEC_PANELS`] only).
    pub level: u32,
    /// Absolute file offset of the body (64-byte aligned).
    pub off: usize,
    /// Body length in bytes.
    pub len: usize,
}

/// Human-readable name of a section kind for `artifact inspect`.
pub fn kind_name(kind: u32) -> &'static str {
    match kind {
        SEC_META => "meta",
        SEC_TENSORS => "tensors",
        SEC_PACKED => "packed",
        SEC_PANELS => "panels",
        _ => "unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin the CRC32 implementation to the IEEE reference vector — the
    /// on-disk checksums must never silently change meaning.
    #[test]
    fn crc32_reference_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn cursor_roundtrip_and_truncation() {
        let mut b = Buf::new();
        b.u16(7);
        b.u32(0xDEAD_BEEF);
        b.u64(1 << 40);
        b.i64(-3);
        b.f32(0.25);
        b.name("conv1.sw");
        let mut c = Cursor::new(&b.0, "test");
        assert_eq!(c.u16().unwrap(), 7);
        assert_eq!(c.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(c.u64().unwrap(), 1 << 40);
        assert_eq!(c.i64().unwrap(), -3);
        assert_eq!(c.f32().unwrap(), 0.25);
        assert_eq!(c.name().unwrap(), "conv1.sw");
        assert_eq!(c.remaining(), 0);
        assert!(matches!(c.u8(), Err(ArtifactError::Truncated { .. })));
    }

    #[test]
    fn align_up_rounds_to_64() {
        assert_eq!(align_up(0), 0);
        assert_eq!(align_up(1), 64);
        assert_eq!(align_up(64), 64);
        assert_eq!(align_up(65), 128);
    }
}
