//! Zero-copy model artifacts: the versioned, checksummed `.lsqa` on-disk
//! format plus its writer and instant-bind loader (DESIGN.md
//! §Artifact-format).
//!
//! LSQ's deployment story is low-precision models that are small *and*
//! fast to stand up — yet without an artifact, every process start and
//! every hot [`crate::serve::ModelRegistry`] load re-derives the
//! [`crate::runtime::kernels::PanelizedWeights`] blocks from packed
//! bytes. At fleet scale (many precision variants × many replicas ×
//! many processes) that rebuild is the dominant cold-start tax. A
//! `.lsqa` captures, at pack time:
//!
//! * the **arch IR seed** (model name, qbits, geometry — enough to
//!   rebuild the deterministic [`crate::runtime::native::arch`] graph)
//!   and the family metadata a manifest would carry,
//! * every **fp32 parameter** that isn't a quantized weight (per-layer
//!   Eq. 1 step sizes `s_w`/`s_a`, biases, folded-BN inputs, full-
//!   precision weights),
//! * the **bit-packed quantized weights** (the Figure-3 storage form and
//!   the universal fallback), and
//! * prebuilt **panel blobs** in their native 64-byte-aligned layout,
//!   one section per [`crate::runtime::kernels::SimdLevel`], keyed on
//!   `PanelGeom` + level + bits + activation class — the PR-8
//!   autotuner's tuned geometries are frozen at pack time.
//!
//! The loader ([`LoadedArtifact::load`]) bulk-reads the file into a
//! page-aligned arena with one aligned read (std-only; the layout is
//! mmap-ready so a feature-gated mmap can slot in later), verifies
//! magic/version/endianness and every section CRC up front, and then
//! hands [`crate::runtime::NativeEngine`] *borrowed* panel blocks: the
//! arena — not per-engine copies — is the working set shared across all
//! replicas of a variant, and binding performs **zero** unpack or
//! panelize work (`tests/artifact.rs` asserts the panel-build counter
//! stays flat). A host that supports none of the recorded SIMD sections
//! falls back to the packed-bytes section and a normal counted panel
//! build — never to silence: any *mismatched* section is a typed
//! [`ArtifactError`].

pub mod format;
pub mod reader;
pub mod writer;

pub use format::{ArtifactError, SectionInfo};
pub use reader::LoadedArtifact;
pub use writer::pack_family;
