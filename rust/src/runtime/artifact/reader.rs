//! `.lsqa` loader: one page-aligned bulk read, full structural + checksum
//! verification up front, then zero-copy panel binding.
//!
//! [`LoadedArtifact::load`] reads the whole file into a page-aligned
//! arena ([`ArtifactArena`] — a single `read_exact` into an aligned
//! window of an over-allocated buffer; the file layout keeps every panel
//! blob on a 64-byte file offset, so in-file alignment *is* in-memory
//! alignment, and the same layout serves a future feature-gated mmap).
//! Everything that can be wrong with the bytes — truncation, bad magic,
//! foreign version or endianness, checksum mismatches, malformed
//! directories, geometry disagreements — surfaces as a typed
//! [`ArtifactError`] here or in [`LoadedArtifact::panel_for`]; nothing
//! in this module panics on file content and nothing falls back
//! silently.
//!
//! The arena is the shared working set: [`LoadedArtifact::panel_for`]
//! hands out [`PanelizedWeights`] that *borrow* their tile bytes from
//! the `Arc`'d arena via [`PanelSource`], so N replicas of a variant
//! share one copy of the panels instead of building N.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::quant::model_size::LayerMeta;
use crate::quant::pack::Packed;
use crate::runtime::kernels::panel::tile_offsets;
use crate::runtime::kernels::{PanelGeom, PanelSource, PanelizedWeights, SimdLevel};
use crate::runtime::manifest::{Family, Manifest};
use crate::tensor::Tensor;
use crate::util::json::Json;

use super::format::{
    crc32, kind_name, AResult, ArtifactError, Cursor, SectionInfo, ALIGN, ENDIAN_TAG, HEADER_LEN,
    MAGIC, SECTION_ENTRY_LEN, SEC_META, SEC_PACKED, SEC_PANELS, SEC_TENSORS, VERSION,
};

/// Page alignment of the arena base (covers the 64-byte panel alignment
/// with room to spare, and matches what an mmap would provide).
const PAGE: usize = 4096;

/// The artifact bytes, resident once per process per artifact: an
/// over-allocated buffer whose `base..base+len` window is page-aligned
/// and holds the file image verbatim (so absolute file offsets are
/// arena offsets).
pub struct ArtifactArena {
    buf: Vec<u8>,
    base: usize,
    len: usize,
}

impl ArtifactArena {
    fn read_from(path: &Path) -> AResult<ArtifactArena> {
        let io = |err| ArtifactError::Io { path: path.to_path_buf(), err };
        let mut f = std::fs::File::open(path).map_err(io)?;
        let len = f.metadata().map_err(io)?.len();
        let len = usize::try_from(len).map_err(|_| ArtifactError::Malformed {
            what: "file length exceeds this host's usize".to_string(),
        })?;
        let mut buf = vec![0u8; len + PAGE];
        let base = buf.as_ptr().align_offset(PAGE);
        f.read_exact(&mut buf[base..base + len]).map_err(io)?;
        Ok(ArtifactArena { buf, base, len })
    }

    /// The file image (absolute file offsets index into this).
    pub fn data(&self) -> &[u8] {
        &self.buf[self.base..self.base + self.len]
    }
}

impl PanelSource for ArtifactArena {
    fn bytes(&self) -> &[i8] {
        let d = self.data();
        // u8 → i8 view: identical size and alignment, every bit pattern
        // valid — the panel tiles were written as raw i8 bytes.
        unsafe { std::slice::from_raw_parts(d.as_ptr() as *const i8, d.len()) }
    }
}

/// One quantized matmul layer as recorded in META's `layers` list.
#[derive(Clone, Debug)]
pub struct LayerInfo {
    /// Layer name (the arch op name, e.g. `conv1`).
    pub name: String,
    /// Weight/activation bit width.
    pub bits: u32,
    /// Whether the layer's input activations are signed (Eq. 1 range).
    pub signed_act: bool,
    /// GEMM reduction dimension.
    pub k: usize,
    /// GEMM output dimension.
    pub n: usize,
}

/// The family record + arch IR seed parsed from the META section.
struct Meta {
    family: String,
    model: String,
    qbits: u32,
    num_classes: usize,
    image: usize,
    channels: usize,
    batch: usize,
    n_matmul: usize,
    params_bin: String,
    param_names: Vec<String>,
    grad_names: Vec<String>,
    roles: BTreeMap<String, String>,
    shapes: BTreeMap<String, Vec<usize>>,
    layer_meta: Vec<LayerMeta>,
    layers: Vec<LayerInfo>,
}

struct PackedEntry {
    bits: u32,
    signed: bool,
    len: usize,
    step: f32,
    /// Absolute file offset of the packed bytes.
    off: usize,
    nbytes: usize,
}

struct PanelEntry {
    k: usize,
    n: usize,
    bits: u32,
    act_max: i64,
    geom: PanelGeom,
    /// Absolute file offset of the 64-aligned tile blob.
    off: usize,
    len: usize,
}

struct PanelSection {
    level: SimdLevel,
    entries: BTreeMap<String, PanelEntry>,
}

/// A fully verified `.lsqa` held resident in its page-aligned arena,
/// ready for instant binds: [`crate::runtime::NativeEngine`] replicas
/// borrow panel blocks straight out of the arena
/// ([`LoadedArtifact::panel_for`]) and read every non-quantized
/// parameter from the materialized [`Tensor`] map.
pub struct LoadedArtifact {
    path: PathBuf,
    arena: Arc<ArtifactArena>,
    meta: Meta,
    tensors: BTreeMap<String, Tensor>,
    packed: BTreeMap<String, PackedEntry>,
    panels: Vec<PanelSection>,
    sections: Vec<SectionInfo>,
}

impl std::fmt::Debug for LoadedArtifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LoadedArtifact")
            .field("path", &self.path)
            .field("family", &self.meta.family)
            .field("sections", &self.sections.len())
            .finish_non_exhaustive()
    }
}

fn malformed(what: impl Into<String>) -> ArtifactError {
    ArtifactError::Malformed { what: what.into() }
}

fn jstr(j: &Json, key: &str) -> AResult<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| malformed(format!("meta: missing string field {key:?}")))
}

fn jusize(j: &Json, key: &str) -> AResult<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| malformed(format!("meta: missing numeric field {key:?}")))
}

fn jstrs(j: &Json, key: &str) -> AResult<Vec<String>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| malformed(format!("meta: missing array field {key:?}")))?
        .iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| malformed(format!("meta: non-string entry in {key:?}")))
        })
        .collect()
}

fn parse_meta(body: &[u8]) -> AResult<Meta> {
    let text = std::str::from_utf8(body).map_err(|_| malformed("meta: not UTF-8"))?;
    let j = Json::parse(text).map_err(|e| malformed(format!("meta: {e}")))?;
    let roles = j
        .get("roles")
        .and_then(Json::as_obj)
        .ok_or_else(|| malformed("meta: missing object field \"roles\""))?
        .iter()
        .map(|(k, v)| {
            v.as_str()
                .map(|s| (k.clone(), s.to_string()))
                .ok_or_else(|| malformed("meta: non-string role"))
        })
        .collect::<AResult<BTreeMap<_, _>>>()?;
    let shapes = j
        .get("shapes")
        .and_then(Json::as_obj)
        .ok_or_else(|| malformed("meta: missing object field \"shapes\""))?
        .iter()
        .map(|(k, v)| {
            v.as_arr()
                .and_then(|a| a.iter().map(Json::as_usize).collect::<Option<Vec<_>>>())
                .map(|dims| (k.clone(), dims))
                .ok_or_else(|| malformed("meta: non-numeric shape"))
        })
        .collect::<AResult<BTreeMap<_, _>>>()?;
    let layer_meta = j
        .get("layer_meta")
        .and_then(Json::as_arr)
        .ok_or_else(|| malformed("meta: missing array field \"layer_meta\""))?
        .iter()
        .map(|lm| {
            Ok(LayerMeta {
                name: jstr(lm, "name")?,
                n_weights: jusize(lm, "n_weights")?,
                bits: jusize(lm, "bits")? as u32,
            })
        })
        .collect::<AResult<Vec<_>>>()?;
    let layers = j
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| malformed("meta: missing array field \"layers\""))?
        .iter()
        .map(|l| {
            Ok(LayerInfo {
                name: jstr(l, "name")?,
                bits: jusize(l, "bits")? as u32,
                signed_act: l
                    .get("signed_act")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| malformed("meta: missing bool field \"signed_act\""))?,
                k: jusize(l, "k")?,
                n: jusize(l, "n")?,
            })
        })
        .collect::<AResult<Vec<_>>>()?;
    Ok(Meta {
        family: jstr(&j, "family")?,
        model: jstr(&j, "model")?,
        qbits: jusize(&j, "qbits")? as u32,
        num_classes: jusize(&j, "num_classes")?,
        image: jusize(&j, "image")?,
        channels: jusize(&j, "channels")?,
        batch: jusize(&j, "batch")?,
        n_matmul: jusize(&j, "n_matmul")?,
        params_bin: jstr(&j, "params_bin")?,
        param_names: jstrs(&j, "param_names")?,
        grad_names: jstrs(&j, "grad_names")?,
        roles,
        shapes,
        layer_meta,
        layers,
    })
}

fn parse_tensors(body: &[u8]) -> AResult<BTreeMap<String, Tensor>> {
    let mut c = Cursor::new(body, "tensors section");
    let count = c.u32()?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name = c.name()?;
        let ndim = c.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        let mut numel = 1usize;
        for _ in 0..ndim {
            let d = c.usize()?;
            numel = numel
                .checked_mul(d)
                .ok_or_else(|| malformed(format!("tensor {name}: shape overflow")))?;
            shape.push(d);
        }
        let raw = c.bytes(numel.checked_mul(4).ok_or_else(|| {
            malformed(format!("tensor {name}: byte length overflow"))
        })?)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_bits(u32::from_le_bytes(b.try_into().expect("4 bytes"))))
            .collect();
        if out.insert(name.clone(), Tensor::from_f32(&shape, data)).is_some() {
            return Err(malformed(format!("duplicate tensor {name}")));
        }
    }
    if c.remaining() != 0 {
        return Err(malformed("tensors section: trailing bytes"));
    }
    Ok(out)
}

fn parse_packed(body: &[u8], section_off: usize) -> AResult<BTreeMap<String, PackedEntry>> {
    let mut c = Cursor::new(body, "packed section");
    let count = c.u32()?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name = c.name()?;
        let bits = c.u32()?;
        if !(1..=8).contains(&bits) {
            return Err(malformed(format!("packed {name}: bits {bits} outside 1..=8")));
        }
        let signed = match c.u8()? {
            0 => false,
            1 => true,
            v => return Err(malformed(format!("packed {name}: bad signed flag {v}"))),
        };
        let len = c.usize()?;
        let step = c.f32()?;
        if !(step.is_finite() && step > 0.0) {
            return Err(malformed(format!("packed {name}: non-positive step")));
        }
        let nbytes = c.usize()?;
        let want = (len * bits as usize).div_ceil(8);
        if nbytes != want {
            return Err(malformed(format!(
                "packed {name}: {nbytes} bytes for {len} x {bits}-bit values (want {want})"
            )));
        }
        let off = section_off + (body.len() - c.remaining());
        c.bytes(nbytes)?;
        if out.insert(name.clone(), PackedEntry { bits, signed, len, step, off, nbytes }).is_some()
        {
            return Err(malformed(format!("duplicate packed layer {name}")));
        }
    }
    if c.remaining() != 0 {
        return Err(malformed("packed section: trailing bytes"));
    }
    Ok(out)
}

fn parse_panels(body: &[u8], sec: &SectionInfo) -> AResult<PanelSection> {
    let level = SimdLevel::ALL
        .get(sec.level as usize)
        .copied()
        .ok_or_else(|| malformed(format!("panels section: unknown SIMD level {}", sec.level)))?;
    let mut c = Cursor::new(body, "panels section");
    let count = c.u32()?;
    let mut entries = BTreeMap::new();
    for _ in 0..count {
        let name = c.name()?;
        let k = c.usize()?;
        let n = c.usize()?;
        let bits = c.u32()?;
        let act_max = c.i64()?;
        let geom = PanelGeom {
            kc: c.usize()?,
            nc: c.usize()?,
            nr: c.usize()?,
            ki: c.usize()?,
        };
        let off = c.usize()?;
        let len = c.usize()?;
        if !geom.valid() {
            return Err(ArtifactError::GeomMismatch {
                layer: name,
                detail: format!("invalid panel geometry {geom:?}"),
            });
        }
        if off % ALIGN != 0 {
            return Err(malformed(format!("panel {name}: blob offset {off} not 64-aligned")));
        }
        let (sec_start, sec_end) = (sec.off, sec.off + sec.len);
        if off < sec_start || off.checked_add(len).map_or(true, |end| end > sec_end) {
            return Err(malformed(format!(
                "panel {name}: blob [{off}, +{len}) escapes its section"
            )));
        }
        let want = *tile_offsets(k, n, geom).last().expect("sentinel");
        if want != len {
            return Err(ArtifactError::GeomMismatch {
                layer: name,
                detail: format!(
                    "blob length {len} != {want} computed from k={k} n={n} {geom:?}"
                ),
            });
        }
        if entries
            .insert(name.clone(), PanelEntry { k, n, bits, act_max, geom, off, len })
            .is_some()
        {
            return Err(malformed(format!("duplicate panel layer {name}")));
        }
    }
    Ok(PanelSection { level, entries })
}

impl LoadedArtifact {
    /// Read and fully verify the artifact at `path`: magic, version,
    /// endianness, header CRC, section table bounds, every section body
    /// CRC, and every directory's structural invariants. After `load`
    /// returns, binds cannot fail on byte-level corruption — only on
    /// semantic mismatches ([`LoadedArtifact::panel_for`]).
    pub fn load(path: &Path) -> AResult<LoadedArtifact> {
        let arena = Arc::new(ArtifactArena::read_from(path)?);
        let data = arena.data();
        if data.len() < HEADER_LEN {
            return Err(ArtifactError::Truncated { what: "header".to_string() });
        }
        if data[0..4] != MAGIC {
            return Err(ArtifactError::BadMagic);
        }
        let version = u16::from_le_bytes(data[4..6].try_into().expect("2 bytes"));
        if version != VERSION {
            return Err(ArtifactError::UnsupportedVersion { got: version, want: VERSION });
        }
        let endian = u16::from_le_bytes(data[6..8].try_into().expect("2 bytes"));
        if endian == ENDIAN_TAG.swap_bytes() {
            return Err(ArtifactError::EndianMismatch);
        }
        let hcrc = u32::from_le_bytes(data[HEADER_LEN - 4..HEADER_LEN].try_into().expect("crc"));
        if crc32(&data[0..HEADER_LEN - 4]) != hcrc {
            return Err(ArtifactError::ChecksumMismatch { section: "header".to_string() });
        }
        if endian != ENDIAN_TAG {
            return Err(malformed(format!("bad endian tag {endian:#06x}")));
        }
        let mut h = Cursor::new(&data[8..HEADER_LEN - 4], "header");
        let header_len = h.u32()? as usize;
        let section_count = h.u32()? as usize;
        let table_off = h.usize()?;
        let file_len = h.usize()?;
        if header_len != HEADER_LEN {
            return Err(malformed(format!("header length {header_len} != {HEADER_LEN}")));
        }
        match file_len.cmp(&data.len()) {
            std::cmp::Ordering::Greater => {
                return Err(ArtifactError::Truncated { what: "file body".to_string() })
            }
            std::cmp::Ordering::Less => {
                return Err(malformed(format!(
                    "file is {} bytes, header says {file_len}",
                    data.len()
                )))
            }
            std::cmp::Ordering::Equal => {}
        }
        let table_len = section_count
            .checked_mul(SECTION_ENTRY_LEN)
            .ok_or_else(|| malformed("section count overflow"))?;
        if table_off.checked_add(table_len).map_or(true, |end| end > data.len()) {
            return Err(ArtifactError::Truncated { what: "section table".to_string() });
        }

        let mut sections = Vec::with_capacity(section_count);
        for i in 0..section_count {
            let mut e = Cursor::new(
                &data[table_off + i * SECTION_ENTRY_LEN..table_off + (i + 1) * SECTION_ENTRY_LEN],
                "section table entry",
            );
            let kind = e.u32()?;
            let level = e.u32()?;
            let off = e.usize()?;
            let len = e.usize()?;
            let crc = e.u32()?;
            if off % ALIGN != 0 {
                return Err(malformed(format!(
                    "section {} offset {off} not 64-aligned",
                    kind_name(kind)
                )));
            }
            if off.checked_add(len).map_or(true, |end| end > data.len()) {
                return Err(ArtifactError::Truncated {
                    what: format!("section {}", kind_name(kind)),
                });
            }
            if crc32(&data[off..off + len]) != crc {
                return Err(ArtifactError::ChecksumMismatch {
                    section: format!("section {}", kind_name(kind)),
                });
            }
            sections.push(SectionInfo { kind, level, off, len });
        }

        let one = |kind: u32| -> AResult<&SectionInfo> {
            let mut found = sections.iter().filter(|s| s.kind == kind);
            let first = found
                .next()
                .ok_or_else(|| malformed(format!("missing {} section", kind_name(kind))))?;
            if found.next().is_some() {
                return Err(malformed(format!("duplicate {} section", kind_name(kind))));
            }
            Ok(first)
        };
        let meta_sec = *one(SEC_META)?;
        let tensors_sec = *one(SEC_TENSORS)?;
        let packed_sec = *one(SEC_PACKED)?;
        let meta = parse_meta(&data[meta_sec.off..meta_sec.off + meta_sec.len])?;
        let tensors = parse_tensors(&data[tensors_sec.off..tensors_sec.off + tensors_sec.len])?;
        let packed = parse_packed(
            &data[packed_sec.off..packed_sec.off + packed_sec.len],
            packed_sec.off,
        )?;
        let mut panels = Vec::new();
        for sec in sections.iter().filter(|s| s.kind == SEC_PANELS) {
            let ps = parse_panels(&data[sec.off..sec.off + sec.len], sec)?;
            if panels.iter().any(|p: &PanelSection| p.level == ps.level) {
                return Err(malformed(format!(
                    "duplicate panels section for level {}",
                    ps.level.name()
                )));
            }
            panels.push(ps);
        }
        // Cross-checks: every quantized layer listed in META must have a
        // packed record, and every panel entry must describe a layer the
        // packed section knows — a directory that disagrees with itself
        // is corruption even when every CRC passes.
        for l in &meta.layers {
            let p = packed
                .get(&l.name)
                .ok_or_else(|| malformed(format!("layer {} has no packed record", l.name)))?;
            if p.bits != l.bits || p.len != l.k * l.n {
                return Err(ArtifactError::GeomMismatch {
                    layer: l.name.clone(),
                    detail: format!(
                        "packed record ({} bits, {} values) disagrees with meta \
                         ({} bits, {}x{})",
                        p.bits, p.len, l.bits, l.k, l.n
                    ),
                });
            }
        }
        for ps in &panels {
            for (name, e) in &ps.entries {
                if !packed.contains_key(name) {
                    return Err(malformed(format!(
                        "panel layer {name} (level {}) has no packed record",
                        ps.level.name()
                    )));
                }
                if !meta.layers.iter().any(|l| {
                    l.name == *name && l.bits == e.bits && l.k == e.k && l.n == e.n
                }) {
                    return Err(ArtifactError::GeomMismatch {
                        layer: name.clone(),
                        detail: format!(
                            "panel entry (level {}) disagrees with meta layers",
                            ps.level.name()
                        ),
                    });
                }
            }
        }
        Ok(LoadedArtifact {
            path: path.to_path_buf(),
            arena,
            meta,
            tensors,
            packed,
            panels,
            sections,
        })
    }

    /// The family this artifact holds.
    pub fn family(&self) -> &str {
        &self.meta.family
    }

    /// Model architecture name (the arch IR seed).
    pub fn model(&self) -> &str {
        &self.meta.model
    }

    /// Family quantization bit width.
    pub fn qbits(&self) -> u32 {
        self.meta.qbits
    }

    /// Input image side length.
    pub fn image(&self) -> usize {
        self.meta.image
    }

    /// Input channels.
    pub fn channels(&self) -> usize {
        self.meta.channels
    }

    /// Output classes.
    pub fn num_classes(&self) -> usize {
        self.meta.num_classes
    }

    /// Serving batch hint carried over from the source manifest.
    pub fn batch(&self) -> usize {
        self.meta.batch
    }

    /// Per-image input element count.
    pub fn image_len(&self) -> usize {
        self.meta.image * self.meta.image * self.meta.channels
    }

    /// The path this artifact was loaded from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The quantized matmul layers recorded in META, graph order.
    pub fn layers(&self) -> &[LayerInfo] {
        &self.meta.layers
    }

    /// The verified section table (kind, level, offset, length) — for
    /// `artifact inspect` and for tests that aim corruption at a
    /// specific body.
    pub fn sections(&self) -> &[SectionInfo] {
        &self.sections
    }

    /// A non-quantized parameter tensor by name (step sizes, biases, BN
    /// parameters, fp32 weights), if recorded.
    pub fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    /// All recorded parameter tensors (everything except the quantized
    /// weights, which travel packed + panelized).
    pub fn tensors(&self) -> &BTreeMap<String, Tensor> {
        &self.tensors
    }

    /// Synthesize the single-family [`Manifest`] equivalent of this
    /// artifact, so manifest-shaped code (engines, stats, the serve
    /// layer) runs unchanged without a `manifest.json` on disk.
    pub fn manifest(&self) -> Manifest {
        let m = &self.meta;
        let fam = Family {
            name: m.family.clone(),
            model: m.model.clone(),
            qbits: m.qbits,
            num_classes: m.num_classes,
            params_bin: m.params_bin.clone(),
            n_matmul: m.n_matmul,
            param_names: m.param_names.clone(),
            grad_names: m.grad_names.clone(),
            roles: m.roles.clone(),
            shapes: m.shapes.clone(),
            layer_meta: m.layer_meta.clone(),
        };
        Manifest {
            dir: self.path.parent().unwrap_or_else(|| Path::new(".")).to_path_buf(),
            batch: m.batch,
            image: m.image,
            channels: m.channels,
            num_classes: m.num_classes,
            families: BTreeMap::from([(m.family.clone(), fam)]),
            artifacts: BTreeMap::new(),
        }
    }

    /// The PANELS section the binding path uses on this host: the one
    /// matching the level this process dispatches to
    /// ([`SimdLevel::detect`], which honors the env pins), else the best
    /// rung the host can execute, else `None` (bind falls back to the
    /// packed bytes and a normal counted panel build).
    fn best_panel_section(&self) -> Option<&PanelSection> {
        let detected = SimdLevel::detect();
        if let Some(ps) = self.panels.iter().find(|p| p.level == detected) {
            return Some(ps);
        }
        self.panels
            .iter()
            .filter(|p| p.level.available())
            .max_by_key(|p| SimdLevel::ALL.iter().position(|&l| l == p.level))
    }

    /// The SIMD level of the panels section binds will borrow from, if
    /// any (for `artifact inspect` and bench annotation).
    pub fn bound_level(&self) -> Option<SimdLevel> {
        self.best_panel_section().map(|p| p.level)
    }

    /// A zero-copy [`PanelizedWeights`] for layer `name`, borrowing its
    /// tile bytes from the shared arena. `Ok(None)` means the artifact
    /// records no panels section this host can use — the caller falls
    /// back to [`LoadedArtifact::packed_for`] and a normal panel build.
    /// A *present* entry that disagrees with the expected shape, bit
    /// width, or activation class is a typed
    /// [`ArtifactError::GeomMismatch`] — never a silent rebuild.
    pub fn panel_for(
        &self,
        name: &str,
        k: usize,
        n: usize,
        bits: u32,
        act_max: i64,
    ) -> AResult<Option<PanelizedWeights>> {
        let Some(section) = self.best_panel_section() else {
            return Ok(None);
        };
        let e = section.entries.get(name).ok_or_else(|| ArtifactError::GeomMismatch {
            layer: name.to_string(),
            detail: format!("absent from the {} panels section", section.level.name()),
        })?;
        if e.k != k || e.n != n || e.bits != bits || e.act_max != act_max {
            return Err(ArtifactError::GeomMismatch {
                layer: name.to_string(),
                detail: format!(
                    "recorded (k={}, n={}, {} bits, act_max={}) != expected \
                     (k={k}, n={n}, {bits} bits, act_max={act_max})",
                    e.k, e.n, e.bits, e.act_max
                ),
            });
        }
        Ok(Some(PanelizedWeights::from_shared(
            k,
            n,
            e.geom,
            Arc::clone(&self.arena) as Arc<dyn PanelSource>,
            e.off,
            e.len,
        )))
    }

    /// The bit-packed weights for layer `name`, copied out of the arena
    /// (the fallback working set when no panels section matches, and the
    /// fused low-memory mode's resident form). Shape/bits disagreements
    /// are typed errors, as in [`LoadedArtifact::panel_for`].
    pub fn packed_for(&self, name: &str, k: usize, n: usize, bits: u32) -> AResult<Packed> {
        let e = self.packed.get(name).ok_or_else(|| {
            malformed(format!("artifact has no packed record for layer {name}"))
        })?;
        if e.bits != bits || e.len != k * n {
            return Err(ArtifactError::GeomMismatch {
                layer: name.to_string(),
                detail: format!(
                    "packed record ({} bits, {} values) != expected ({bits} bits, {})",
                    e.bits,
                    e.len,
                    k * n
                ),
            });
        }
        let bytes = self.arena.data()[e.off..e.off + e.nbytes].to_vec();
        Ok(Packed { bits: e.bits, signed: e.signed, len: e.len, step: e.step, bytes })
    }

    /// Human-readable artifact summary for `lsqnet artifact inspect`.
    pub fn inspect(&self) -> String {
        use std::fmt::Write as _;
        let m = &self.meta;
        let mut s = String::new();
        let _ = writeln!(s, "artifact   {}", self.path.display());
        let _ = writeln!(
            s,
            "family     {} (model {}, {}-bit, {} classes, {}x{}x{})",
            m.family, m.model, m.qbits, m.num_classes, m.image, m.image, m.channels
        );
        let _ = writeln!(
            s,
            "params     {} tensors, {} packed layers, batch hint {}",
            self.tensors.len(),
            self.packed.len(),
            m.batch
        );
        let _ = writeln!(s, "sections   ({} total)", self.sections.len());
        for sec in &self.sections {
            let lvl = if sec.kind == SEC_PANELS {
                SimdLevel::ALL
                    .get(sec.level as usize)
                    .map_or("?", |l| l.name())
            } else {
                "-"
            };
            let _ = writeln!(
                s,
                "  {:<8} level={:<10} off={:<10} len={}",
                kind_name(sec.kind),
                lvl,
                sec.off,
                sec.len
            );
        }
        for ps in &self.panels {
            let total: usize = ps.entries.values().map(|e| e.len).sum();
            let _ = writeln!(
                s,
                "panels[{}]  {} layers, {} tile bytes{}",
                ps.level.name(),
                ps.entries.len(),
                total,
                if Some(ps.level) == self.bound_level() { "  <- binds on this host" } else { "" }
            );
            for (name, e) in &ps.entries {
                let g = e.geom;
                let _ = writeln!(
                    s,
                    "  {:<12} k={:<5} n={:<5} {}-bit act_max={:<4} \
                     geom kc={} nc={} nr={} ki={}",
                    name, e.k, e.n, e.bits, e.act_max, g.kc, g.nc, g.nr, g.ki
                );
            }
        }
        s
    }
}
