//! `.lsqa` writer: quantize + pack a family once, panelize it at the
//! autotuner's geometries, and freeze the result — header, section
//! table, META/TENSORS/PACKED bodies and one PANELS section per
//! requested SIMD level — into a single in-memory image written with one
//! `fs::write`.
//!
//! Packing is the expensive, once-per-deploy step (`lsqnet pack`); the
//! payoff is that [`super::reader::LoadedArtifact`] binds with zero
//! quantize/unpack/panelize work on every process start and hot reload.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use crate::quant::lsq::qrange;
use crate::quant::pack::{quantize_and_pack, Packed};
use crate::runtime::kernels::{check_accumulator_bound, PanelGeom, PanelizedWeights, SimdLevel};
use crate::runtime::native::arch::{self, Arch, ArchOp};
use crate::runtime::Manifest;
use crate::tensor::Tensor;
use crate::util::json::Json;

use super::format::{
    align_up, crc32, Buf, ENDIAN_TAG, HEADER_LEN, MAGIC, SECTION_ENTRY_LEN, SEC_META, SEC_PACKED,
    SEC_PANELS, SEC_TENSORS, VERSION,
};

/// One sub-32-bit matmul layer of the arch graph, in deterministic graph
/// order (the same order [`crate::runtime::native::arch::for_each_matmul_bits`]
/// visits).
struct QLayer {
    name: String,
    bits: u32,
    signed_act: bool,
    k: usize,
    n: usize,
    shape: Vec<usize>,
}

fn push_conv(out: &mut Vec<QLayer>, c: &arch::ConvSpec) {
    if c.bits < 32 {
        out.push(QLayer {
            name: c.name.clone(),
            bits: c.bits,
            signed_act: c.signed_act,
            k: c.kh * c.kw * c.in_ch,
            n: c.out_ch,
            shape: vec![c.kh, c.kw, c.in_ch, c.out_ch],
        });
    }
}

/// The quantized (bits < 32) matmul layers of `arch`, graph order.
fn collect_qlayers(arch: &Arch) -> Vec<QLayer> {
    let mut out = Vec::new();
    for op in &arch.ops {
        match op {
            ArchOp::Conv(c) => push_conv(&mut out, c),
            ArchOp::Dense(d) => {
                if d.bits < 32 {
                    out.push(QLayer {
                        name: d.name.clone(),
                        bits: d.bits,
                        signed_act: d.signed_act,
                        k: d.in_dim,
                        n: d.out_dim,
                        shape: vec![d.in_dim, d.out_dim],
                    });
                }
            }
            ArchOp::Preact(p) => {
                if let Some(proj) = &p.proj {
                    push_conv(&mut out, proj);
                }
                push_conv(&mut out, &p.conv1);
                push_conv(&mut out, &p.conv2);
            }
            _ => {}
        }
    }
    out
}

/// The PANELS sections a plain `lsqnet pack` writes: this host's
/// dispatched level (capturing the autotuner's geometries) plus the
/// universal [`SimdLevel::Scalar`] rung every machine can bind,
/// deduplicated.
pub fn default_levels() -> Vec<SimdLevel> {
    let mut out = vec![SimdLevel::detect()];
    if !out.contains(&SimdLevel::Scalar) {
        out.push(SimdLevel::Scalar);
    }
    out
}

/// The blocking frozen into a PANELS section for `level`: the bind-time
/// autotuner's measured pick when `level` is what this process actually
/// dispatches to (the PR-8 geometries are captured at pack time), the
/// deterministic [`PanelGeom::DEFAULT`] for any other requested rung (we
/// cannot measure a level this host doesn't run; DEFAULT is every
/// level's safe shape).
fn geom_for(level: SimdLevel, p: &Packed, k: usize, n: usize, act_max: i64) -> PanelGeom {
    if level == SimdLevel::detect() {
        crate::runtime::kernels::tune::tune_geom(p, k, n, act_max)
    } else {
        PanelGeom::DEFAULT
    }
}

fn usize_num(v: usize) -> Json {
    Json::Num(v as f64)
}

/// Serialize `family` (bound to `params`, in `Family::param_names`
/// order) into a `.lsqa` artifact at `out`, with one prebuilt-panels
/// section per level in `levels` (deduplicated; pass
/// [`default_levels`]'s result for the standard pair, or an empty slice
/// to write a packed-bytes-only artifact that always binds through the
/// fallback panel build).
pub fn pack_family(
    manifest: &Manifest,
    family: &str,
    params: &[Tensor],
    out: &Path,
    levels: &[SimdLevel],
) -> Result<()> {
    let fam = manifest.family(family)?;
    ensure!(
        params.len() == fam.param_names.len(),
        "family {family}: got {} params, manifest lists {}",
        params.len(),
        fam.param_names.len()
    );
    let arch = arch::build(
        &fam.model,
        manifest.image,
        manifest.channels,
        fam.num_classes,
        fam.qbits,
    )?;
    let map: BTreeMap<&str, &Tensor> =
        fam.param_names.iter().map(String::as_str).zip(params).collect();
    let tensor = |name: &str| -> Result<&Tensor> {
        map.get(name)
            .copied()
            .ok_or_else(|| anyhow!("family {family} has no parameter {name:?}"))
    };

    // Quantize + pack every sub-32-bit matmul layer (the expensive step
    // the artifact amortizes), validating exactly what bind would.
    let qlayers = collect_qlayers(&arch);
    let mut packs: Vec<(usize, Packed, i64)> = Vec::with_capacity(qlayers.len());
    for (i, ql) in qlayers.iter().enumerate() {
        let w = tensor(&format!("{}.w", ql.name))?;
        ensure!(
            w.shape == ql.shape,
            "{}.w shape {:?} != expected {:?}",
            ql.name,
            w.shape,
            ql.shape
        );
        let sw = tensor(&format!("{}.sw", ql.name))?.item_f32()?;
        let sa = tensor(&format!("{}.sa", ql.name))?.item_f32()?;
        ensure!(sw > 0.0 && sa > 0.0, "{}: non-positive step size (sw={sw}, sa={sa})", ql.name);
        let (act_qn, act_qp) = qrange(ql.bits, ql.signed_act);
        let (wqn, wqp) = qrange(ql.bits, true);
        ensure!(
            check_accumulator_bound(ql.k, act_qp, act_qn, wqn, wqp),
            "{}: k={} at {}-bit would overflow the i32 accumulator",
            ql.name,
            ql.k,
            ql.bits
        );
        let packed = quantize_and_pack(w.f32s()?, sw, ql.bits, true)?;
        packs.push((i, packed, act_qp.max(act_qn)));
    }
    let qweight_names: BTreeSet<String> =
        qlayers.iter().map(|ql| format!("{}.w", ql.name)).collect();

    // -- META: the family record + arch IR seed, floats excluded (all
    //    f32 values travel in binary sections for exact roundtrip).
    let meta = Json::Obj(BTreeMap::from([
        ("family".to_string(), Json::Str(family.to_string())),
        ("model".to_string(), Json::Str(fam.model.clone())),
        ("qbits".to_string(), usize_num(fam.qbits as usize)),
        ("num_classes".to_string(), usize_num(fam.num_classes)),
        ("image".to_string(), usize_num(manifest.image)),
        ("channels".to_string(), usize_num(manifest.channels)),
        ("batch".to_string(), usize_num(manifest.batch)),
        ("n_matmul".to_string(), usize_num(fam.n_matmul)),
        ("params_bin".to_string(), Json::Str(fam.params_bin.clone())),
        (
            "param_names".to_string(),
            Json::Arr(fam.param_names.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        (
            "grad_names".to_string(),
            Json::Arr(fam.grad_names.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        (
            "roles".to_string(),
            Json::Obj(
                fam.roles.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
            ),
        ),
        (
            "shapes".to_string(),
            Json::Obj(
                fam.shapes
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Arr(v.iter().map(|&d| usize_num(d)).collect())))
                    .collect(),
            ),
        ),
        (
            "layer_meta".to_string(),
            Json::Arr(
                fam.layer_meta
                    .iter()
                    .map(|lm| {
                        Json::Obj(BTreeMap::from([
                            ("name".to_string(), Json::Str(lm.name.clone())),
                            ("n_weights".to_string(), usize_num(lm.n_weights)),
                            ("bits".to_string(), usize_num(lm.bits as usize)),
                        ]))
                    })
                    .collect(),
            ),
        ),
        (
            "layers".to_string(),
            Json::Arr(
                qlayers
                    .iter()
                    .map(|ql| {
                        Json::Obj(BTreeMap::from([
                            ("name".to_string(), Json::Str(ql.name.clone())),
                            ("bits".to_string(), usize_num(ql.bits as usize)),
                            ("signed_act".to_string(), Json::Bool(ql.signed_act)),
                            ("k".to_string(), usize_num(ql.k)),
                            ("n".to_string(), usize_num(ql.n)),
                        ]))
                    })
                    .collect(),
            ),
        ),
    ]));
    let meta_body = meta.to_string().into_bytes();

    // -- TENSORS: every parameter except the quantized weights (those
    //    travel bit-packed): step sizes, biases, BN params, fp32 weights.
    let mut tensors = Buf::new();
    let kept: Vec<&String> =
        fam.param_names.iter().filter(|n| !qweight_names.contains(*n)).collect();
    tensors.u32(u32::try_from(kept.len()).context("tensor count")?);
    for name in kept {
        let t = tensor(name)?;
        tensors.name(name);
        tensors.u8(u8::try_from(t.shape.len()).context("tensor rank")?);
        for &d in &t.shape {
            tensors.u64(d as u64);
        }
        for &v in t.f32s().with_context(|| format!("artifact tensor {name} must be f32"))? {
            tensors.f32(v);
        }
    }

    // -- PACKED: the bit-packed quantized weights, graph order.
    let mut packed_body = Buf::new();
    packed_body.u32(u32::try_from(packs.len()).context("packed count")?);
    for (i, p, _) in &packs {
        let ql = &qlayers[*i];
        packed_body.name(&ql.name);
        packed_body.u32(p.bits);
        packed_body.u8(p.signed as u8);
        packed_body.u64(p.len as u64);
        packed_body.f32(p.step);
        packed_body.u64(p.bytes.len() as u64);
        packed_body.bytes(&p.bytes);
    }

    // -- File assembly: header + table placeholders, then 64-aligned
    //    section bodies; PANELS directories carry absolute blob offsets,
    //    so those sections are laid out in place.
    let mut lvls: Vec<SimdLevel> = Vec::new();
    for &l in levels {
        if !lvls.contains(&l) {
            lvls.push(l);
        }
    }
    let section_count = 3 + lvls.len();
    let table_off = HEADER_LEN;
    let mut file = vec![0u8; align_up(table_off + section_count * SECTION_ENTRY_LEN)];
    let mut sections: Vec<(u32, u32, usize, usize)> = Vec::with_capacity(section_count);

    let append = |file: &mut Vec<u8>, kind: u32, level: u32, body: &[u8]| {
        file.resize(align_up(file.len()), 0);
        let off = file.len();
        file.extend_from_slice(body);
        (kind, level, off, body.len())
    };
    let s = append(&mut file, SEC_META, 0, &meta_body);
    sections.push(s);
    let s = append(&mut file, SEC_TENSORS, 0, &tensors.0);
    sections.push(s);
    let s = append(&mut file, SEC_PACKED, 0, &packed_body.0);
    sections.push(s);

    for level in lvls {
        let level_ix = SimdLevel::ALL
            .iter()
            .position(|&l| l == level)
            .expect("level in ALL") as u32;
        file.resize(align_up(file.len()), 0);
        let off = file.len();
        // Panelize every quantized layer at this level's geometry, then
        // lay out: directory || padding || 64-aligned blobs (absolute
        // offsets — in-file alignment is in-memory alignment after the
        // loader's aligned bulk read).
        let panels: Vec<(usize, PanelizedWeights)> = packs
            .iter()
            .map(|(i, p, act_max)| {
                let ql = &qlayers[*i];
                let geom = geom_for(level, p, ql.k, ql.n, *act_max);
                (*i, PanelizedWeights::build_with_geom(p, ql.k, ql.n, geom))
            })
            .collect();
        let dir_len: usize = 4
            + panels
                .iter()
                .map(|(i, _)| 2 + qlayers[*i].name.len() + 8 * 8 + 4 + 8)
                .sum::<usize>();
        let mut blob_off = align_up(off + dir_len);
        let mut dir = Buf::new();
        dir.u32(u32::try_from(panels.len()).context("panel count")?);
        let mut blob_offs = Vec::with_capacity(panels.len());
        for ((i, pw), (_, p, act_max)) in panels.iter().zip(&packs) {
            let ql = &qlayers[*i];
            let g = pw.geom();
            dir.name(&ql.name);
            dir.u64(ql.k as u64);
            dir.u64(ql.n as u64);
            dir.u32(p.bits);
            dir.i64(*act_max);
            dir.u64(g.kc as u64);
            dir.u64(g.nc as u64);
            dir.u64(g.nr as u64);
            dir.u64(g.ki as u64);
            dir.u64(blob_off as u64);
            dir.u64(pw.raw_data().len() as u64);
            blob_offs.push(blob_off);
            blob_off = align_up(blob_off + pw.raw_data().len());
        }
        debug_assert_eq!(dir.0.len(), dir_len);
        file.extend_from_slice(&dir.0);
        for ((_, pw), &boff) in panels.iter().zip(&blob_offs) {
            file.resize(boff, 0);
            // i8 → u8 reinterpretation of the tile bytes (same size and
            // alignment; the loader performs the inverse view).
            let raw = pw.raw_data();
            let bytes =
                unsafe { std::slice::from_raw_parts(raw.as_ptr() as *const u8, raw.len()) };
            file.extend_from_slice(bytes);
        }
        sections.push((SEC_PANELS, level_ix, off, file.len() - off));
    }

    // -- Section table + header, checksums last.
    for (i, &(kind, level, off, len)) in sections.iter().enumerate() {
        let e = table_off + i * SECTION_ENTRY_LEN;
        file[e..e + 4].copy_from_slice(&kind.to_le_bytes());
        file[e + 4..e + 8].copy_from_slice(&level.to_le_bytes());
        file[e + 8..e + 16].copy_from_slice(&(off as u64).to_le_bytes());
        file[e + 16..e + 24].copy_from_slice(&(len as u64).to_le_bytes());
        let crc = crc32(&file[off..off + len]);
        file[e + 24..e + 28].copy_from_slice(&crc.to_le_bytes());
        file[e + 28..e + 32].copy_from_slice(&0u32.to_le_bytes());
    }
    file[0..4].copy_from_slice(&MAGIC);
    file[4..6].copy_from_slice(&VERSION.to_le_bytes());
    file[6..8].copy_from_slice(&ENDIAN_TAG.to_le_bytes());
    file[8..12].copy_from_slice(&(HEADER_LEN as u32).to_le_bytes());
    file[12..16].copy_from_slice(&(section_count as u32).to_le_bytes());
    file[16..24].copy_from_slice(&(table_off as u64).to_le_bytes());
    file[24..32].copy_from_slice(&(file.len() as u64).to_le_bytes());
    let hcrc = crc32(&file[0..HEADER_LEN - 4]);
    file[HEADER_LEN - 4..HEADER_LEN].copy_from_slice(&hcrc.to_le_bytes());

    std::fs::write(out, &file)
        .with_context(|| format!("writing artifact {}", out.display()))?;
    Ok(())
}
