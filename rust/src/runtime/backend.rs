//! Execution-backend abstraction: one trait, two engines.
//!
//! [`Backend`] is the contract the serving and benchmark layers program
//! against — bind a family's parameters once ([`Backend::prepare_infer`]),
//! then run batched image→logits inference ([`Backend::infer`]) many times.
//! Two implementations exist:
//!
//! * [`crate::runtime::native::NativeEngine`] — pure-Rust packed-weight
//!   integer inference. Always compiled in, needs only `manifest.json` +
//!   the family's params bin (no HLO artifacts, no PJRT libraries).
//! * `crate::runtime::Engine` — the XLA/PJRT artifact executor, behind
//!   `--features xla`. Its client is `Rc`-backed and not `Send`, so one
//!   engine is opened per worker thread.
//!
//! [`BackendSpec`] is the cheap `Send + Clone` description that worker
//! threads use to open their own engine instance (see DESIGN.md
//! §Backend-trait for the replica model this enables).

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::runtime::Manifest;
use crate::tensor::Tensor;

/// Which engine implementation a [`BackendSpec`] opens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust packed-weight inference (always available, `Send`).
    Native,
    /// XLA/PJRT artifact execution (requires building with `--features xla`).
    Xla,
}

impl BackendKind {
    /// Parse a CLI name: `"native"` or `"xla"`.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => bail!("unknown backend {other:?} (expected native|xla)"),
        }
    }

    /// The CLI name of this kind.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Serializable description of an inference engine: which implementation,
/// over which artifacts directory. `Send + Clone`, unlike the engines it
/// opens — each serve replica / sweep worker calls [`BackendSpec::open`] on
/// its own thread.
#[derive(Clone, Debug)]
pub struct BackendSpec {
    /// Engine implementation to open.
    pub kind: BackendKind,
    /// Directory holding `manifest.json` (plus params bins and, for the XLA
    /// backend, the HLO-text artifacts).
    pub artifacts_dir: PathBuf,
}

impl BackendSpec {
    /// Spec for the native packed-weight backend over `dir`.
    pub fn native(dir: &Path) -> BackendSpec {
        BackendSpec { kind: BackendKind::Native, artifacts_dir: dir.to_path_buf() }
    }

    /// Spec for the XLA/PJRT backend over `dir`.
    pub fn xla(dir: &Path) -> BackendSpec {
        BackendSpec { kind: BackendKind::Xla, artifacts_dir: dir.to_path_buf() }
    }

    /// Cheap availability check: errors when the spec names an engine this
    /// build cannot open (XLA without `--features xla`). Unlike
    /// [`BackendSpec::open`], this constructs nothing.
    pub fn check_available(&self) -> Result<()> {
        if self.kind == BackendKind::Xla && !cfg!(feature = "xla") {
            bail!(
                "this build has no XLA support; rebuild with `cargo build --features xla` \
                 or use the native backend"
            );
        }
        Ok(())
    }

    /// Open one engine instance. Call once per worker thread: the XLA
    /// client must not cross threads, and the native engine keeps per-model
    /// packed state that is cheapest left thread-local.
    pub fn open(&self) -> Result<Box<dyn Backend>> {
        match self.kind {
            BackendKind::Native => Ok(Box::new(super::native::NativeEngine::new(
                &self.artifacts_dir,
            )?)),
            BackendKind::Xla => self.open_xla(),
        }
    }

    #[cfg(feature = "xla")]
    fn open_xla(&self) -> Result<Box<dyn Backend>> {
        Ok(Box::new(super::engine::Engine::new(&self.artifacts_dir)?))
    }

    #[cfg(not(feature = "xla"))]
    fn open_xla(&self) -> Result<Box<dyn Backend>> {
        bail!(
            "this build has no XLA support; rebuild with `cargo build --features xla` \
             or use the native backend"
        )
    }
}

/// Options for one [`Backend::prepare_infer`] bind, passed by value at the
/// single point where they can take effect.
///
/// This replaces the old mutate-before-prepare setter protocol (the
/// intra-op-thread and low-memory setters of PRs 3/4): setters had
/// stomp-ordering footguns — a caller pushing `false` would clobber the
/// engine's own `LSQNET_FUSED_UNPACK` env default, so every call site had
/// to know which settings were safe to write unconditionally. An options
/// struct is order-free, and "not specified" is representable
/// (`low_memory: None`).
#[derive(Clone, Debug, Default)]
pub struct PrepareOptions {
    /// Intra-op kernel threads for this engine (0 = hardware count). The
    /// serve layer passes `core budget / total replicas` so
    /// `replicas × intra-op threads` never oversubscribes the host (see
    /// DESIGN.md §Kernel-layer). Ignored by the XLA engine, which manages
    /// its own thread pool.
    pub intra_op_threads: usize,
    /// Weight-storage choice for the native engine: `Some(true)` binds in
    /// the low-memory fused-unpack mode (skip bind-time panelization,
    /// unpack weight tiles per call), `Some(false)` pins the panelized
    /// fast path, and `None` (the default) defers to the process-wide
    /// `LSQNET_FUSED_UNPACK` env default — see DESIGN.md §SIMD-dispatch
    /// for the memory/speed trade-off. Ignored by the XLA engine, which
    /// has no packed-weight storage to trade.
    pub low_memory: Option<bool>,
    /// Bind from a loaded `.lsqa` artifact instead of quantizing and
    /// panelizing `params`: the native engine borrows prebuilt panel
    /// blocks from the artifact's shared arena (zero rebuild work — the
    /// fleet cold-start path, DESIGN.md §Artifact-format). The bound
    /// family must match [`crate::runtime::artifact::LoadedArtifact::family`]
    /// and `params` must be empty (the artifact *is* the checkpoint).
    /// Ignored by the XLA engine.
    pub artifact: Option<std::sync::Arc<crate::runtime::artifact::LoadedArtifact>>,
}

impl PrepareOptions {
    /// Options with everything at its default (hardware threads, env-default
    /// weight storage).
    pub fn new() -> PrepareOptions {
        PrepareOptions::default()
    }

    /// Builder-style intra-op thread cap.
    pub fn intra_op_threads(mut self, threads: usize) -> PrepareOptions {
        self.intra_op_threads = threads;
        self
    }

    /// Builder-style explicit low-memory choice.
    pub fn low_memory(mut self, fused_unpack: bool) -> PrepareOptions {
        self.low_memory = Some(fused_unpack);
        self
    }

    /// Builder-style artifact bind: share `art`'s arena with this engine.
    pub fn artifact(
        mut self,
        art: std::sync::Arc<crate::runtime::artifact::LoadedArtifact>,
    ) -> PrepareOptions {
        self.artifact = Some(art);
        self
    }
}

/// A loaded inference engine. The call pattern is: open (via
/// [`BackendSpec::open`]) → [`prepare_infer`](Backend::prepare_infer) once →
/// [`infer`](Backend::infer) many times from the serving hot loop.
pub trait Backend {
    /// Short implementation name (`"native"` / `"xla-pjrt"`).
    fn name(&self) -> &'static str;

    /// The artifact/family contract this engine was opened over.
    fn manifest(&self) -> &Manifest;

    /// Bind `family` + `params` for inference, configured by `opts`. The
    /// native engine quantizes and bit-packs the weights here (Eq. 1); the
    /// XLA engine compiles the family's `infer` artifact. `params` follow
    /// `Family::param_names` order, as loaded by
    /// `Manifest::load_initial_params` or from a checkpoint. All
    /// per-deployment configuration flows through [`PrepareOptions`] —
    /// there are no post-`open` setters on this trait.
    fn prepare_infer(
        &mut self,
        family: &str,
        params: &[Tensor],
        opts: &PrepareOptions,
    ) -> Result<()>;

    /// Preferred batch size (rows per [`infer`](Backend::infer) call) after
    /// `prepare_infer`.
    fn batch(&self) -> usize;

    /// Whether [`infer`](Backend::infer) requires exactly `batch()` rows.
    /// XLA artifacts have a fixed input shape and need tail padding; the
    /// native backend accepts any row count, so callers can skip the
    /// padding work entirely.
    fn fixed_batch(&self) -> bool {
        true
    }

    /// Run one padded batch: `x` holds `batch() * image_len` floats in NHWC
    /// layout. Returns `batch() * num_classes` logits, row-major.
    fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>>;
}
