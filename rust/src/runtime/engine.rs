//! PJRT execution engine: load HLO-text artifacts, compile once, execute
//! many times from the coordinator hot path.
//!
//! Design notes (see DESIGN.md §Architecture-decisions):
//!  * `PjRtClient` is `Rc`-backed and not `Send`; each worker thread owns an
//!    `Engine`. The sweep coordinator never shares engines across threads.
//!  * The calling convention is positional per the manifest; `Executable`
//!    validates arity and (optionally) shapes before dispatch.
//!  * Multi-output computations return a single tuple buffer on this XLA
//!    version; `execute` decomposes the tuple literal (a move, not a copy)
//!    into per-output host tensors.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::backend::{Backend, PrepareOptions};
use super::manifest::{ArtifactMeta, Manifest};
use crate::tensor::{DType, Tensor};

/// Converts an xla error (not std-Error on this crate version) to anyhow.
macro_rules! xtry {
    ($e:expr, $what:expr) => {
        $e.map_err(|err| anyhow!("{}: {:?}", $what, err))?
    };
}

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Compiled executables, keyed by artifact id (compile once, run many).
    cache: RefCell<HashMap<String, std::rc::Rc<Executable>>>,
    pub compile_ms: RefCell<f64>,
    /// Inference state bound by `Backend::prepare_infer`.
    prepared: Option<PreparedInfer>,
}

/// The family `infer` artifact + bound parameters behind the [`Backend`]
/// implementation.
struct PreparedInfer {
    exe: std::rc::Rc<Executable>,
    params: Vec<Tensor>,
    input_shape: Vec<usize>,
}

pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xtry!(xla::PjRtClient::cpu(), "create PJRT CPU client");
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_ms: RefCell::new(0.0),
            prepared: None,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by id).
    pub fn load(&self, artifact_id: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(artifact_id) {
            return Ok(e.clone());
        }
        let meta = self.manifest.artifact(artifact_id)?.clone();
        let path: PathBuf = self.manifest.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = xtry!(
            xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?
            ),
            format!("parse HLO text {path:?}")
        );
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = xtry!(self.client.compile(&comp), format!("compile {artifact_id}"));
        *self.compile_ms.borrow_mut() += t0.elapsed().as_secs_f64() * 1e3;
        let e = std::rc::Rc::new(Executable { meta, exe });
        self.cache.borrow_mut().insert(artifact_id.to_string(), e.clone());
        Ok(e)
    }

    /// Shorthand: find by (kind, family) then load.
    pub fn load_kind(
        &self,
        kind: &str,
        family: &str,
        method: Option<&str>,
        gscale: Option<&str>,
    ) -> Result<std::rc::Rc<Executable>> {
        let id = self.manifest.find(kind, family, method, gscale)?.id.clone();
        self.load(&id)
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn prepare_infer(
        &mut self,
        family: &str,
        params: &[Tensor],
        _opts: &PrepareOptions,
    ) -> Result<()> {
        // PrepareOptions carries nothing for this engine: the XLA runtime
        // manages its own thread pool and has no packed-weight storage.
        let meta = self.manifest.find("infer", family, None, None)?.clone();
        let exe = self.load(&meta.id)?;
        let input_shape = meta
            .inputs
            .last()
            .ok_or_else(|| anyhow!("{}: infer artifact has no inputs", meta.id))?
            .shape
            .clone();
        self.prepared = Some(PreparedInfer { exe, params: params.to_vec(), input_shape });
        Ok(())
    }

    fn batch(&self) -> usize {
        self.prepared
            .as_ref()
            .and_then(|p| p.input_shape.first().copied())
            .unwrap_or(self.manifest.batch)
            .max(1)
    }

    fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let p = self
            .prepared
            .as_ref()
            .ok_or_else(|| anyhow!("call prepare_infer before infer"))?;
        let want: usize = p.input_shape.iter().product();
        if x.len() != want {
            bail!(
                "infer input has {} floats, artifact expects {want} (shape {:?})",
                x.len(),
                p.input_shape
            );
        }
        let mut inputs = p.params.clone();
        inputs.push(Tensor::from_f32(&p.input_shape, x.to_vec()));
        let out = p.exe.run(&inputs)?;
        Ok(out
            .first()
            .ok_or_else(|| anyhow!("infer artifact returned no outputs"))?
            .f32s()?
            .to_vec())
    }
}

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let ty = match t.dtype() {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, t.raw_bytes())
        .map_err(|e| anyhow!("literal from tensor: {e:?}"))
}

fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.primitive_type() {
        xla::PrimitiveType::F32 => {
            let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal->f32: {e:?}"))?;
            Ok(Tensor::from_f32(&dims, v))
        }
        xla::PrimitiveType::S32 => {
            let v: Vec<i32> = lit.to_vec().map_err(|e| anyhow!("literal->i32: {e:?}"))?;
            Ok(Tensor::from_i32(&dims, v))
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

impl Executable {
    /// Execute with host tensors; returns one host tensor per manifest
    /// output. Validates input arity, dtype and shape against the manifest.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.validate(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let outs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.meta.id))?;
        let buf = outs
            .first()
            .and_then(|replica| replica.first())
            .ok_or_else(|| anyhow!("{}: no output buffers", self.meta.id))?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch outputs of {}: {e:?}", self.meta.id))?;
        // Multi-output artifacts come back as one tuple literal.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple outputs of {}: {e:?}", self.meta.id))?;
        let parts = if parts.is_empty() { vec![lit_clone_guard()?] } else { parts };
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.id,
                self.meta.outputs.len(),
                parts.len()
            );
        }
        parts.iter().map(literal_to_tensor).collect()
    }

    fn validate(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.id,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if t.dtype() != spec.dtype {
                bail!(
                    "{} input #{i} ({}): dtype {:?} != manifest {:?}",
                    self.meta.id, spec.name, t.dtype(), spec.dtype
                );
            }
            if t.shape != spec.shape {
                bail!(
                    "{} input #{i} ({}): shape {:?} != manifest {:?}",
                    self.meta.id, spec.name, t.shape, spec.shape
                );
            }
        }
        Ok(())
    }

    /// Index of the first output with the given manifest kind.
    pub fn output_index(&self, kind: &str, name: Option<&str>) -> Result<usize> {
        self.meta
            .outputs
            .iter()
            .position(|o| o.kind == kind && name.map_or(true, |n| o.name == n))
            .ok_or_else(|| anyhow!("{}: no output kind={kind} name={name:?}", self.meta.id))
    }
}

// `return_tuple=True` in aot.py guarantees a tuple even for single outputs,
// so an empty decompose means something unexpected happened.
fn lit_clone_guard() -> Result<xla::Literal> {
    bail!("artifact returned a non-tuple literal; aot.py must lower with return_tuple=True")
}
