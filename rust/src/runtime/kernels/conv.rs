//! Convolution lowering primitives shared by inference and training:
//! SAME-padding geometry, the im2col patch gather, and its adjoint
//! scatter ([`col2im`], the `dX̂` path of the conv backward).

/// SAME-padding geometry for one spatial dim: returns `(out_size,
/// pad_before)`, matching XLA's `padding="SAME"` (pad_before = total/2,
/// rounded down).
pub fn same_padding(size: usize, kernel: usize, stride: usize) -> (usize, usize) {
    let out = (size + stride - 1) / stride;
    let pad_total = ((out - 1) * stride + kernel).saturating_sub(size);
    (out, pad_total / 2)
}

/// im2col for NHWC input: writes `b*oh*ow` rows of `kh*kw*c` patch elements
/// (ordered `(dh, dw, cin)`, matching row-major flattened HWIO weights)
/// into `out`, zero-padding out-of-bounds taps. Returns `(oh, ow)`.
///
/// `out` is cleared and resized — pass a workspace-recycled buffer
/// ([`super::Workspace::take_f32`] / `take_i32`) so the steady-state call
/// allocates nothing.
#[allow(clippy::too_many_arguments)]
pub fn im2col<T: Copy>(
    x: &[T],
    zero: T,
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    out: &mut Vec<T>,
) -> (usize, usize) {
    assert_eq!(x.len(), b * h * w * c, "input shape");
    let (oh, pad_t) = same_padding(h, kh, stride);
    let (ow, pad_l) = same_padding(w, kw, stride);
    let patch = kh * kw * c;
    out.clear();
    out.resize(b * oh * ow * patch, zero);
    for bi in 0..b {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad_t as isize;
            for ox in 0..ow {
                let ix0 = (ox * stride) as isize - pad_l as isize;
                let row = ((bi * oh + oy) * ow + ox) * patch;
                for dh in 0..kh {
                    let iy = iy0 + dh as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for dw in 0..kw {
                        let ix = ix0 + dw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (dh * kw + dw) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    (oh, ow)
}

/// Adjoint of [`im2col`]: scatter-accumulate patch-space gradients
/// `dcols[b*oh*ow × kh*kw*c]` back onto the input image grid
/// `dx[b×h×w×c]` (which must be pre-zeroed). Taps that fell in the SAME
/// zero padding are dropped, exactly mirroring the forward gather.
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    dcols: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    dx: &mut [f32],
) {
    assert_eq!(dx.len(), b * h * w * c, "dx shape");
    let (oh, pad_t) = same_padding(h, kh, stride);
    let (ow, pad_l) = same_padding(w, kw, stride);
    let patch = kh * kw * c;
    assert_eq!(dcols.len(), b * oh * ow * patch, "dcols shape");
    for bi in 0..b {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad_t as isize;
            for ox in 0..ow {
                let ix0 = (ox * stride) as isize - pad_l as isize;
                let row = ((bi * oh + oy) * ow + ox) * patch;
                for dh in 0..kh {
                    let iy = iy0 + dh as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for dw in 0..kw {
                        let ix = ix0 + dw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let dst = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let src = row + (dh * kw + dw) * c;
                        for ch in 0..c {
                            dx[dst + ch] += dcols[src + ch];
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_matches_xla() {
        assert_eq!(same_padding(32, 3, 1), (32, 1));
        assert_eq!(same_padding(32, 3, 2), (16, 0)); // total pad 1 -> (0, 1)
        assert_eq!(same_padding(16, 1, 1), (16, 0));
        assert_eq!(same_padding(16, 1, 2), (8, 0));
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1: im2col is the identity.
        let x: Vec<f32> = (0..2 * 3 * 3 * 2).map(|i| i as f32).collect();
        let mut out = Vec::new();
        let (oh, ow) = im2col(&x, 0.0, 2, 3, 3, 2, 1, 1, 1, &mut out);
        assert_eq!((oh, ow), (3, 3));
        assert_eq!(out, x);
    }

    #[test]
    fn im2col_pads_borders_with_zeros() {
        // Single 2x2 image, one channel, 3x3 kernel: the center patch sees
        // all four pixels, corners of the patch are zero padding.
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut out = Vec::new();
        let (oh, ow) = im2col(&x, 0.0, 1, 2, 2, 1, 3, 3, 1, &mut out);
        assert_eq!((oh, ow), (2, 2));
        // Row for output (0,0): taps at (dy-1, dx-1) relative offsets.
        let r0 = &out[0..9];
        assert_eq!(r0, &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y — the defining
        // property of the transposed scatter, covering padding and stride.
        let (b, h, w, c, kh, kw) = (2usize, 5usize, 4usize, 3usize, 3usize, 3usize);
        for stride in [1usize, 2] {
            let mut rng = crate::util::rng::Pcg32::seeded(23 + stride as u64);
            let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal()).collect();
            let mut cols = Vec::new();
            let (oh, ow) = im2col(&x, 0.0f32, b, h, w, c, kh, kw, stride, &mut cols);
            let y: Vec<f32> = (0..b * oh * ow * kh * kw * c).map(|_| rng.normal()).collect();
            let fwd: f64 = cols.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
            let mut dx = vec![0.0f32; b * h * w * c];
            col2im(&y, b, h, w, c, kh, kw, stride, &mut dx);
            let adj: f64 = x.iter().zip(&dx).map(|(a, b)| (a * b) as f64).sum();
            assert!((fwd - adj).abs() < 1e-3 * fwd.abs().max(1.0), "stride={stride}");
        }
    }
}
