//! The GEMM microkernel family — one implementation shared by the
//! inference forward ([`crate::runtime::native`]) and the training
//! forward/backward ([`crate::train::native::backward`]).
//!
//! * [`qgemm`] / [`qgemm_panel`] — integer GEMM over low-precision
//!   weights, the native datapath of the paper's Figure 1: activations
//!   quantized to integers per Eq. 1, multiply-accumulate in `i32`, one
//!   fp32 rescale by `s_a * s_w` (Eq. 2) at the end. Both entry points
//!   share one SIMD-dispatched inner compute over the interleaved i8
//!   panel layout ([`super::panel`], [`super::simd`]); they differ only in
//!   where the panels come from:
//!   - [`qgemm`] ("fused unpack-and-dot", the low-memory mode): the
//!     weight matrix stays in its [`Packed`] 2/3/4/8-bit form and each
//!     thread builds KC×NC panel tiles into workspace scratch on the fly
//!     (precision-specialized unpack,
//!     [`crate::quant::pack::unpack_range_spec`]);
//!   - [`qgemm_panel`] (the serve default): panels were built **once** at
//!     model bind ([`PanelizedWeights::build`]) and are shared read-only —
//!     the hot loop does no unpack work at all.
//! * [`sgemm`] / [`sgemm_nt`] / [`sgemm_tn`] — the fp32 family used by
//!   full-precision (bits ≥ 32) layers and by the training tape's
//!   `dX̂ = dY·Ŵᵀ` / `dŴ = X̂ᵀ·dY` transposes, with SIMD-dispatched
//!   axpy/dot inner loops.
//!
//! Threading model (DESIGN.md §Kernel-layer): every kernel parallelizes
//! over *row blocks of the output* with `std::thread::scope`, so each
//! output element is owned by exactly one thread. `qgemm` accumulates in
//! `i32`, where addition is exact, and is therefore **bitwise identical**
//! across thread counts *and* SIMD levels; the fp32 family is bitwise
//! across thread counts too (per-element order never depends on the
//! split), and across SIMD levels everywhere except `sgemm_nt`'s
//! reassociated dot reduction (1e-5 — DESIGN.md §SIMD-dispatch). The
//! fp32 family additionally honors the workspace's
//! [`super::simd::FpMode`]: the default `Pinned` mode keeps the two-
//! roundings mul+add reference; the `Fma` tier contracts each element to
//! one fused rounding (per-element, so the same cross-thread/cross-level
//! guarantees hold *within* the mode). The thread count comes from the
//! caller's [`Workspace`] (`LSQNET_THREADS=1` forces serial; serve caps
//! replicas at `cores / replicas`).
//!
//! Accumulation is exact in `i32` provided
//! `k * Qp_act * max(Qn_w, Qp_w) < 2^31`, which [`check_accumulator_bound`]
//! verifies at model-build time (for 8-bit weights/activations that allows
//! k up to ~65k — far above any layer in the model zoo). The panel
//! kernels additionally require each activation to fit i16 (asserted per
//! call) and each weight to fit i8 — both trivially true for every Eq. 1
//! grid at ≤ 8 bits.

use crate::quant::pack::{unpack_range_spec, Packed};

use super::panel::{fill_tile_panel, fits_i8, PanelGeom, PanelizedWeights};
use super::simd::{pack_xgroups, FpMode, SimdLevel};
use super::workspace::{QThreadScratch, Workspace};

/// Rows of the weight matrix per tile (the k blocking factor).
pub const KC: usize = 256;
/// Columns of the weight matrix per tile (the n blocking factor).
pub const NC: usize = 64;
/// Column width of one `qgemm` microkernel block: this many i32
/// accumulator lanes per j-block, and the interleave width of the panel
/// layout ([`super::panel`]).
pub const NR: usize = 8;

/// Minimum activation rows per *fused-mode* `qgemm` thread. In fused mode
/// each thread builds its own copy of every panel tile (tile build costs
/// ~one dot-product row per tile), so a thread owning fewer rows than this
/// spends more time unpacking than multiplying — small serve batches stay
/// serial instead of going 2× slower. Panelized mode has no per-thread
/// unpack and no rows floor. Thread count never changes the output
/// (bitwise invariant), only the split.
pub const QGEMM_MIN_ROWS_PER_THREAD: usize = 8;

/// Minimum multiply-accumulates one spawned thread must own before the
/// GEMM family adds it to the split: `std::thread::scope` spawns and
/// joins real OS threads (tens of µs each), so a thread needs on the
/// order of 64k MACs (~tens of µs of scalar compute) to pay for itself.
/// Small layers — the trainer's dense head, tiny serve batches — stay
/// serial. Like every width decision here, this never changes output
/// bits, only the split.
pub const MIN_MACS_PER_THREAD: usize = 1 << 16;

/// Width cap from the work floor: at most one thread per
/// [`MIN_MACS_PER_THREAD`] of total work.
fn work_capped(threads: usize, macs: usize) -> usize {
    threads.min((macs / MIN_MACS_PER_THREAD).max(1))
}

/// Split-dispatch shared by the whole GEMM family: run the first work
/// item on the calling thread and the rest on scoped threads, so a
/// width-T split spawns only T−1 OS threads and nobody idles in the
/// join. Every item must own disjoint output — the callers' `chunks_mut`
/// iterators guarantee it.
macro_rules! scoped_split {
    ($items:expr, |$item:pat_param| $body:expr) => {
        std::thread::scope(|s| {
            let mut inline = None;
            for it in $items {
                if inline.is_none() {
                    inline = Some(it);
                } else {
                    let $item = it;
                    s.spawn(move || $body);
                }
            }
            if let Some(it) = inline {
                let $item = it;
                $body;
            }
        })
    };
}

/// `true` iff an `i32` accumulator cannot overflow for a length-`k` dot
/// product of activations in `[-qn_a, qp_a]` with weights in
/// `[-qn_w, qp_w]`.
pub fn check_accumulator_bound(k: usize, qp_a: i64, qn_a: i64, qn_w: i64, qp_w: i64) -> bool {
    let amax = qp_a.max(qn_a);
    let wmax = qn_w.max(qp_w);
    (k as i64)
        .checked_mul(amax)
        .and_then(|v| v.checked_mul(wmax))
        .map(|v| v < i32::MAX as i64)
        .unwrap_or(false)
}

/// Rows per thread when splitting `rows` across at most `threads` workers.
fn row_chunk(rows: usize, threads: usize) -> usize {
    let t = threads.max(1);
    ((rows + t - 1) / t).max(1)
}

/// Where a `qgemm` call's panel tiles come from (shared read-only across
/// the row-block threads).
#[derive(Clone, Copy)]
enum PanelSrc<'a> {
    /// Build per tile, per thread, into workspace scratch (fused mode).
    Fused(&'a Packed),
    /// Pre-built once at model bind, zero unpack work per call.
    Pre(&'a PanelizedWeights),
}

/// Quantized GEMM, fused-unpack mode:
/// `out[m×n] = (x[m×k] · unpack(w)[k×n]) * scale (+ bias)`.
///
/// * `x` — integer activations (Eq. 1 `v̄` values), row-major `m×k`.
///   Values must fit i16 (asserted): the SIMD panel kernels stream
///   activations as i16 pairs. Every Eq. 1 grid at ≤ 8 bits satisfies
///   this (|v̄| ≤ 255) with huge margin;
/// * `w` — bit-packed weights, logically row-major `k×n` (`w.len == k*n`),
///   values must fit the i8 panel element (always true for signed
///   packings; see [`super::panel`]);
/// * `scale` — the per-layer `s_a * s_w` rescale (Eq. 2 applied to both
///   operands at once);
/// * `bias` — optional fp32 bias of length `n`, added after the rescale.
///
/// The i32 accumulator, per-thread panel tiles and activation-pair
/// buffers come from `ws` and are reused across calls. Output is bitwise
/// identical for every thread count and SIMD level (each element is owned
/// by one thread; integer addition is exact). Use [`qgemm_panel`] when
/// the weights were panelized at bind time.
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    ws: &mut Workspace,
    m: usize,
    k: usize,
    n: usize,
    x: &[i32],
    w: &Packed,
    scale: f32,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(w.len, k * n, "packed weight shape");
    assert!(fits_i8(w), "unsigned 8-bit weights do not fit i8 panels");
    qgemm_core(ws, m, k, n, x, PanelSrc::Fused(w), scale, bias, out);
}

/// Quantized GEMM over pre-built panels ([`PanelizedWeights`]) — the
/// serving default: identical contract and bitwise-identical output to
/// [`qgemm`], with zero per-call unpack work.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_panel(
    ws: &mut Workspace,
    m: usize,
    k: usize,
    n: usize,
    x: &[i32],
    pw: &PanelizedWeights,
    scale: f32,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!((pw.k(), pw.n()), (k, n), "panelized weight shape");
    qgemm_core(ws, m, k, n, x, PanelSrc::Pre(pw), scale, bias, out);
}

#[allow(clippy::too_many_arguments)]
fn qgemm_core(
    ws: &mut Workspace,
    m: usize,
    k: usize,
    n: usize,
    x: &[i32],
    src: PanelSrc,
    scale: f32,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), m * k, "activation buffer shape");
    assert_eq!(out.len(), m * n, "output buffer shape");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias length");
    }
    if m == 0 || n == 0 {
        return;
    }

    // Cap the split: fused mode additionally floors rows-per-thread so
    // every thread amortizes its own tile builds
    // (QGEMM_MIN_ROWS_PER_THREAD); both modes respect the spawn work
    // floor (MIN_MACS_PER_THREAD).
    let rows_floor = match src {
        PanelSrc::Fused(_) => QGEMM_MIN_ROWS_PER_THREAD,
        PanelSrc::Pre(_) => 1,
    };
    let threads = work_capped(ws.threads().min((m / rows_floor).max(1)), m * k * n);
    let simd = ws.simd();
    let (acc, scratch) = ws.gemm_scratch(threads);
    acc.clear();
    acc.resize(m * n, 0);
    if k > 0 {
        if threads <= 1 {
            qgemm_rows(simd, m, k, n, x, src, &mut scratch[0], acc);
        } else {
            let chunk = row_chunk(m, threads);
            scoped_split!(
                acc.chunks_mut(chunk * n).zip(x.chunks(chunk * k)).zip(scratch.iter_mut()),
                |((acc_c, x_c), scr)| qgemm_rows(simd, acc_c.len() / n, k, n, x_c, src, scr, acc_c)
            );
        }
    }

    match bias {
        Some(b) => {
            for i in 0..m {
                for j in 0..n {
                    out[i * n + j] = acc[i * n + j] as f32 * scale + b[j];
                }
            }
        }
        None => {
            for (o, &a) in out.iter_mut().zip(acc.iter()) {
                *o = a as f32 * scale;
            }
        }
    }
}

/// One thread's share of the quantized GEMM: `mb` activation rows against
/// the whole weight matrix, at the blocking geometry of the panel source
/// (pre-built panels carry the autotuner's per-layer [`PanelGeom`]; the
/// fused mode always uses [`PanelGeom::DEFAULT`]). Per kc block, the
/// thread packs its activation rows into k-groups once; per kc×nc tile it
/// either borrows the pre-built panel or builds one into its scratch,
/// then runs the SIMD-dispatched microkernel ([`SimdLevel::qgemm_tile`]).
///
/// Exception: at [`SimdLevel::Scalar`] the *fused* source skips panel
/// interleaving entirely and runs the direct unpack-and-dot loop
/// ([`qgemm_rows_scalar_fused`]) — paying the interleave without a SIMD
/// payoff would make non-x86 hosts (and the forced-scalar baseline rows
/// in `benches/gemm.rs`) strictly slower than the pre-SIMD datapath.
/// Pre-built panels have no per-call build cost, so the panel microkernel
/// stays in use there at every level. All paths are bitwise-identical
/// (exact i32 sums).
#[allow(clippy::too_many_arguments)]
fn qgemm_rows(
    simd: SimdLevel,
    mb: usize,
    k: usize,
    n: usize,
    x: &[i32],
    src: PanelSrc,
    scr: &mut QThreadScratch,
    acc: &mut [i32],
) {
    if simd == SimdLevel::Scalar {
        if let PanelSrc::Fused(p) = src {
            return qgemm_rows_scalar_fused(mb, k, n, x, p, scr, acc);
        }
    }
    let geom = match src {
        PanelSrc::Fused(_) => PanelGeom::DEFAULT,
        PanelSrc::Pre(pw) => pw.geom(),
    };
    for (ik, k0) in (0..k).step_by(geom.kc).enumerate() {
        let kc = geom.kc.min(k - k0);
        let groups = geom.groups(kc);
        if scr.xpairs.len() < mb * groups {
            scr.xpairs.resize(mb * groups, 0);
        }
        for i in 0..mb {
            pack_xgroups(
                &x[i * k + k0..i * k + k0 + kc],
                geom.ki,
                &mut scr.xpairs[i * groups..(i + 1) * groups],
            );
        }
        for (in_, n0) in (0..n).step_by(geom.nc).enumerate() {
            let nc = geom.nc.min(n - n0);
            let tile: &[i8] = match src {
                PanelSrc::Pre(pw) => pw.tile(ik, in_),
                PanelSrc::Fused(p) => {
                    let len = geom.tile_len(kc, nc);
                    if scr.panel.len() < len {
                        scr.panel.resize(len, 0);
                    }
                    fill_tile_panel(p, n, k0, kc, n0, nc, geom, &mut scr.row, &mut scr.panel[..len]);
                    &scr.panel[..len]
                }
            };
            simd.qgemm_tile(tile, &scr.xpairs, mb, groups, nc, n, n0, geom, acc);
        }
    }
}

/// The scalar-level fused path: direct unpack-and-dot over a plain
/// row-major i32 tile (precision-specialized unpack, NR-wide register
/// tile, zero activations skipped) — the pre-SIMD datapath, kept because
/// building interleaved panels buys nothing without vector instructions.
/// Bitwise-identical to the panel kernels (i32 addition is exact; skipped
/// zero rows contribute zero).
fn qgemm_rows_scalar_fused(
    mb: usize,
    k: usize,
    n: usize,
    x: &[i32],
    w: &Packed,
    scr: &mut QThreadScratch,
    acc: &mut [i32],
) {
    if scr.tile.len() < KC * NC {
        scr.tile.resize(KC * NC, 0);
    }
    let tile = &mut scr.tile[..];
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        for n0 in (0..n).step_by(NC) {
            let nc = NC.min(n - n0);
            // Unpack this KC×NC weight tile once; it then stays hot in
            // cache for all mb activation rows of this thread.
            for kk in 0..kc {
                unpack_range_spec(w, (k0 + kk) * n + n0, nc, &mut tile[kk * nc..kk * nc + nc]);
            }
            for i in 0..mb {
                let xrow = &x[i * k + k0..i * k + k0 + kc];
                let arow = &mut acc[i * n + n0..i * n + n0 + nc];
                let mut j0 = 0;
                while j0 < nc {
                    let nr = NR.min(nc - j0);
                    let mut r = [0i32; NR];
                    for (kk, &xv) in xrow.iter().enumerate() {
                        if xv == 0 {
                            continue;
                        }
                        let wrow = &tile[kk * nc + j0..kk * nc + j0 + nr];
                        for (rj, &wv) in r[..nr].iter_mut().zip(wrow) {
                            *rj += xv * wv;
                        }
                    }
                    for (a, &rj) in arow[j0..j0 + nr].iter_mut().zip(&r[..nr]) {
                        *a += rj;
                    }
                    j0 += nr;
                }
            }
        }
    }
}

/// fp32 GEMM with the same blocking, for the model zoo's full-precision
/// (bits ≥ 32) layers and the training-tape forward:
/// `out[m×n] = x[m×k] · w[k×n] (+ bias)`.
///
/// Parallelized over output row blocks; per-element accumulation order is
/// the serial k order regardless of thread count, so results are bitwise
/// identical across thread counts — and across SIMD levels too: the
/// dispatched inner loop is an elementwise axpy (one mul + one add per
/// element, no reassociation).
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    ws: &mut Workspace,
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), m * k, "activation buffer shape");
    assert_eq!(w.len(), k * n, "weight shape");
    assert_eq!(out.len(), m * n, "output buffer shape");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias length");
    }
    if m == 0 || n == 0 {
        return;
    }
    match bias {
        Some(b) => {
            for orow in out.chunks_exact_mut(n) {
                orow.copy_from_slice(b);
            }
        }
        None => out.fill(0.0),
    }
    if k == 0 {
        return;
    }
    let simd = ws.simd();
    let fp = ws.fp_mode();
    let threads = work_capped(ws.threads().min(m), m * k * n);
    if threads <= 1 {
        sgemm_rows(simd, fp, m, k, n, x, w, out);
    } else {
        let chunk = row_chunk(m, threads);
        scoped_split!(
            out.chunks_mut(chunk * n).zip(x.chunks(chunk * k)),
            |(out_c, x_c)| sgemm_rows(simd, fp, out_c.len() / n, k, n, x_c, w, out_c)
        );
    }
}

/// One thread's share of [`sgemm`]: streaming-axpy inner loop (vectorized
/// without reassociating the per-element sum), zero activations skipped.
#[allow(clippy::too_many_arguments)]
fn sgemm_rows(
    simd: SimdLevel,
    fp: FpMode,
    mb: usize,
    k: usize,
    n: usize,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
) {
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        for i in 0..mb {
            let xrow = &x[i * k + k0..i * k + k0 + kc];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                simd.saxpy(fp, xv, wrow, orow);
            }
        }
    }
}

/// Transposed-B fp32 GEMM: `out[m×k] = a[m×n] · w[k×n]ᵀ`.
///
/// This is the data-gradient path of the native backward pass
/// (`dX̂ = dY · Ŵᵀ`, see `crate::train::native::backward`): both `a` rows
/// and `w` rows are contiguous, so the inner dot runs stride-1 on both
/// operands with no transpose materialized. Parallel over `out` row
/// blocks. The SIMD dot reduction reassociates the fp32 sum, so across
/// *dispatch levels* results agree to 1e-5 (across thread counts they
/// stay bitwise — the split never changes which level computes an
/// element).
pub fn sgemm_nt(
    ws: &mut Workspace,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    w: &[f32],
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * n, "a shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * k, "output shape");
    if m == 0 || k == 0 {
        return;
    }
    if n == 0 {
        out.fill(0.0);
        return;
    }
    let simd = ws.simd();
    let fp = ws.fp_mode();
    let threads = work_capped(ws.threads().min(m), m * k * n);
    if threads <= 1 {
        sgemm_nt_rows(simd, fp, m, k, n, a, w, out);
    } else {
        let chunk = row_chunk(m, threads);
        scoped_split!(
            out.chunks_mut(chunk * k).zip(a.chunks(chunk * n)),
            |(out_c, a_c)| sgemm_nt_rows(simd, fp, out_c.len() / k, k, n, a_c, w, out_c)
        );
    }
}

#[allow(clippy::too_many_arguments)]
fn sgemm_nt_rows(
    simd: SimdLevel,
    fp: FpMode,
    mb: usize,
    k: usize,
    n: usize,
    a: &[f32],
    w: &[f32],
    out: &mut [f32],
) {
    for i in 0..mb {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (kk, o) in orow.iter_mut().enumerate() {
            *o = simd.sdot(fp, arow, &w[kk * n..(kk + 1) * n]);
        }
    }
}

/// Transposed-A fp32 GEMM: `out[k×n] = x[m×k]ᵀ · dy[m×n]`.
///
/// The weight-gradient path of the native backward pass
/// (`dŴ = X̂ᵀ · dY`). The inner loop streams a `dy` row into an `out`
/// row (elementwise axpy — bitwise across thread counts *and* SIMD
/// levels), skipping zero activations (common after ReLU + unsigned
/// quantization). Parallel over `out` row blocks (the k dimension): each
/// thread reduces over all m batch rows for its own output rows, so the
/// per-element m-order matches the serial loop for every thread count.
pub fn sgemm_tn(
    ws: &mut Workspace,
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    dy: &[f32],
    out: &mut [f32],
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(dy.len(), m * n, "dy shape");
    assert_eq!(out.len(), k * n, "output shape");
    if k == 0 || n == 0 {
        return;
    }
    let simd = ws.simd();
    let fp = ws.fp_mode();
    let threads = work_capped(ws.threads().min(k), m * k * n);
    if threads <= 1 {
        sgemm_tn_rows(simd, fp, m, k, n, 0, x, dy, out);
    } else {
        let chunk = row_chunk(k, threads);
        scoped_split!(
            out.chunks_mut(chunk * n).enumerate(),
            |(ci, out_c)| sgemm_tn_rows(simd, fp, m, k, n, ci * chunk, x, dy, out_c)
        );
    }
}

/// One thread's share of [`sgemm_tn`]: output rows `[k_off, k_off + kb)`
/// where `kb = out.len() / n`.
#[allow(clippy::too_many_arguments)]
fn sgemm_tn_rows(
    simd: SimdLevel,
    fp: FpMode,
    m: usize,
    k: usize,
    n: usize,
    k_off: usize,
    x: &[f32],
    dy: &[f32],
    out: &mut [f32],
) {
    out.fill(0.0);
    let kb = out.len() / n;
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        for kk in 0..kb {
            let xv = x[i * k + k_off + kk];
            if xv == 0.0 {
                continue;
            }
            simd.saxpy(fp, xv, dyrow, &mut out[kk * n..(kk + 1) * n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack;

    #[test]
    fn qgemm_matches_naive_i64() {
        let (m, k, n) = (3usize, 70usize, 9usize);
        let mut rng = crate::util::rng::Pcg32::seeded(7);
        let x: Vec<i32> = (0..m * k).map(|_| rng.below(8) as i32 - 4).collect();
        let wv: Vec<i32> = (0..k * n).map(|_| rng.below(15) as i32 - 7).collect();
        let p = pack(&wv, 4, true, 0.5).unwrap();
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.25).collect();
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; m * n];
        qgemm(&mut ws, m, k, n, &x, &p, 0.5, Some(&bias), &mut out);
        for i in 0..m {
            for j in 0..n {
                let acc: i64 =
                    (0..k).map(|kk| x[i * k + kk] as i64 * wv[kk * n + j] as i64).sum();
                let want = acc as f32 * 0.5 + bias[j];
                assert!(
                    (out[i * n + j] - want).abs() < 1e-4,
                    "({i},{j}): {} vs {want}",
                    out[i * n + j]
                );
            }
        }
    }

    #[test]
    fn qgemm_blocks_cover_large_shapes() {
        // k and n straddle the KC/NC tile boundaries.
        let (m, k, n) = (2usize, KC + 13, NC + 5);
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        let x: Vec<i32> = (0..m * k).map(|_| rng.below(4) as i32).collect();
        let wv: Vec<i32> = (0..k * n).map(|_| rng.below(3) as i32 - 1).collect();
        let p = pack(&wv, 2, true, 1.0).unwrap();
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; m * n];
        qgemm(&mut ws, m, k, n, &x, &p, 1.0, None, &mut out);
        for i in 0..m {
            for j in 0..n {
                let acc: i64 =
                    (0..k).map(|kk| x[i * k + kk] as i64 * wv[kk * n + j] as i64).sum();
                assert_eq!(out[i * n + j], acc as f32, "({i},{j})");
            }
        }
    }

    #[test]
    fn qgemm_panel_bitwise_matches_fused() {
        for &(m, k, n, bits) in
            &[(1usize, 5usize, 3usize, 2u32), (4, KC + 9, NC + 3, 3), (7, 64, 40, 4), (2, 33, 9, 8)]
        {
            let mut rng = crate::util::rng::Pcg32::seeded(40 + bits as u64);
            let (qn, qp) = crate::quant::lsq::qrange(bits, true);
            let wv: Vec<i32> = (0..k * n)
                .map(|_| rng.below((qn + qp + 1) as u32) as i32 - qn as i32)
                .collect();
            let p = pack(&wv, bits, true, 1.0).unwrap();
            let pw = PanelizedWeights::build(&p, k, n);
            let x: Vec<i32> = (0..m * k).map(|_| rng.below(8) as i32 - 3).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut ws = Workspace::new();
            let mut fused = vec![0.0f32; m * n];
            qgemm(&mut ws, m, k, n, &x, &p, 0.07, Some(&bias), &mut fused);
            let mut paneled = vec![0.0f32; m * n];
            qgemm_panel(&mut ws, m, k, n, &x, &pw, 0.07, Some(&bias), &mut paneled);
            for (i, (a, b)) in fused.iter().zip(&paneled).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} elem {i}");
            }
        }
    }

    #[test]
    fn sgemm_matches_naive() {
        let (m, k, n) = (5usize, 17usize, 6usize);
        let mut rng = crate::util::rng::Pcg32::seeded(20);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; m * n];
        sgemm(&mut ws, m, k, n, &x, &w, Some(&bias), &mut out);
        for i in 0..m {
            for j in 0..n {
                let want: f32 =
                    bias[j] + (0..k).map(|kk| x[i * k + kk] * w[kk * n + j]).sum::<f32>();
                assert!((out[i * n + j] - want).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn sgemm_nt_matches_naive_transpose() {
        let (m, k, n) = (3usize, 5usize, 7usize);
        let mut rng = crate::util::rng::Pcg32::seeded(21);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; m * k];
        sgemm_nt(&mut ws, m, k, n, &a, &w, &mut out);
        for i in 0..m {
            for kk in 0..k {
                let want: f32 = (0..n).map(|j| a[i * n + j] * w[kk * n + j]).sum();
                assert!((out[i * k + kk] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn sgemm_tn_matches_naive_transpose() {
        let (m, k, n) = (4usize, 6usize, 3usize);
        let mut rng = crate::util::rng::Pcg32::seeded(22);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; k * n];
        sgemm_tn(&mut ws, m, k, n, &x, &dy, &mut out);
        for kk in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| x[i * k + kk] * dy[i * n + j]).sum();
                assert!((out[kk * n + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn accumulator_bound() {
        assert!(check_accumulator_bound(65_000, 255, 0, 128, 127));
        assert!(!check_accumulator_bound(66_000, 255, 0, 128, 127));
    }
}
