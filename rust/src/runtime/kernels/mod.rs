//! The unified parallel kernel layer: one compute core shared by the
//! native inference backend ([`crate::runtime::native`]) and the native
//! training subsystem ([`crate::train::native`]).
//!
//! Before this layer existed, the packed-weight forward and the training
//! tape each carried their own copies of the GEMM/im2col/pool/BN ops, all
//! scalar, single-threaded, and re-allocating their scratch on every call.
//! This module collapses both paths onto one implementation with two
//! properties the deployment story (paper Figure 1; McKinstry et al. 2018)
//! needs:
//!
//! * **Workspace reuse** — [`Workspace`] owns the accumulator, the
//!   per-thread fused-unpack panels and activation-pair buffers, and a
//!   pool of recycled activation / im2col / gradient buffers. Serve
//!   replicas and `NativeTrainer` each hold one, so the steady-state hot
//!   path is allocation-free.
//! * **Deterministic multi-threading** — the GEMM family parallelizes over
//!   output row blocks with `std::thread::scope`; every output element is
//!   owned by exactly one thread and accumulated in the serial order, so
//!   `qgemm` is bitwise identical across thread counts (and the fp32
//!   family is too). The thread count is capped per-workspace (serve uses
//!   `cores / replicas`) and process-wide via `LSQNET_THREADS`.
//! * **Hardware-shaped inner compute** — the GEMM inner loops dispatch
//!   once per workspace to a runtime-detected [`SimdLevel`]
//!   (AVX-512 VNNI / AVX2 / SSE2 / NEON / portable scalar;
//!   `LSQNET_SIMD=<name>` pins any available level, `LSQNET_FORCE_SCALAR=1`
//!   stays as the scalar alias), the quantized kernel runs over an
//!   interleaved i8 panel layout whose blocking ([`PanelGeom`]) the
//!   bind-time autotuner ([`tune`]) measures per layer shape — built
//!   either once at model bind ([`panel::PanelizedWeights`], the serve
//!   default) or per tile into per-thread scratch (fused low-memory mode,
//!   always the default geometry), and the per-value unpack is
//!   precision-specialized (const-generic `BITS`,
//!   [`crate::quant::pack::unpack_range_spec`]). The fp32 family adds an
//!   opt-in FMA tier ([`FpMode`], `LSQNET_FMA=1`) behind the same
//!   determinism story. `qgemm` stays bitwise identical across SIMD
//!   levels, panel modes, *and* panel geometries (exact i32 sums) — see
//!   DESIGN.md §SIMD-dispatch.
//!
//! Submodules: [`workspace`] (scratch arena + thread resolution), [`gemm`]
//! (the `qgemm`/`qgemm_panel`/`sgemm`/`sgemm_nt`/`sgemm_tn` kernels),
//! [`panel`] (the interleaved i8 weight-panel layout + [`PanelGeom`]),
//! [`simd`] (dispatch + the per-ISA microkernels), [`tune`] (the
//! bind-time panel-geometry autotuner), [`conv`] (im2col / col2im / SAME
//! padding), [`pool`] (max pool, global average pool, ReLU), [`norm`]
//! (folded and batch-stat batch norm). See DESIGN.md §Kernel-layer for
//! the ownership rules and determinism guarantee.

pub mod conv;
pub mod gemm;
pub mod norm;
pub mod panel;
pub mod pool;
pub mod simd;
pub mod tune;
pub mod workspace;

pub use conv::{col2im, im2col, same_padding};
pub use gemm::{
    check_accumulator_bound, qgemm, qgemm_panel, sgemm, sgemm_nt, sgemm_tn, KC, NC, NR,
    QGEMM_MIN_ROWS_PER_THREAD,
};
pub use norm::{bn_apply, bn_apply_out, bn_batch_stats, bn_bwd, bn_normalize, fold_bn, BN_EPS};
pub use panel::{panel_build_count, PanelGeom, PanelSource, PanelizedWeights};
pub use pool::{
    global_avg_pool, global_avg_pool_bwd, maxpool2, maxpool2_bwd, relu, relu_bwd, relu_mask,
};
pub use simd::{FpMode, SimdLevel};
pub use workspace::{hardware_threads, Workspace};
