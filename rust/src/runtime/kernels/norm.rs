//! Batch-norm primitives shared by inference and training.
//!
//! Inference uses the *folded* eval form ([`fold_bn`] + [`bn_apply`] /
//! [`bn_apply_out`]): running stats and γ/β collapse to one per-channel
//! affine `y = x·scale + shift` at model-build time. Training uses the
//! batch-stat form ([`bn_batch_stats`] + [`bn_normalize`]) and the
//! standard three-term backward ([`bn_bwd`]); running-stat bookkeeping
//! (momentum, functional updates) stays in the training tape, which owns
//! the parameter story.

/// BN variance epsilon (matches `python/compile/layers.py` `BN_EPS`).
pub const BN_EPS: f32 = 1e-5;

/// Fold eval-mode batch norm into a per-channel affine:
/// `scale = γ/√(rvar+ε)`, `shift = β − rmean·scale`.
pub fn fold_bn(
    gamma: &[f32],
    beta: &[f32],
    rmean: &[f32],
    rvar: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut scale = Vec::with_capacity(gamma.len());
    let mut shift = Vec::with_capacity(gamma.len());
    for i in 0..gamma.len() {
        let s = gamma[i] / (rvar[i] + BN_EPS).sqrt();
        scale.push(s);
        shift.push(beta[i] - rmean[i] * s);
    }
    (scale, shift)
}

/// In-place folded BN: `x = x·scale + shift` per trailing channel.
pub fn bn_apply(x: &mut [f32], scale: &[f32], shift: &[f32]) {
    let c = scale.len();
    for chunk in x.chunks_exact_mut(c) {
        for (i, v) in chunk.iter_mut().enumerate() {
            *v = *v * scale[i] + shift[i];
        }
    }
}

/// Out-of-place folded BN: `out = x·scale + shift` per trailing channel.
/// Lets a residual block keep `x` alive as its identity shortcut without
/// cloning the activation tensor.
pub fn bn_apply_out(x: &[f32], scale: &[f32], shift: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len(), "bn_apply_out shape");
    let c = scale.len();
    for (chunk, ochunk) in x.chunks_exact(c).zip(out.chunks_exact_mut(c)) {
        for i in 0..c {
            ochunk[i] = chunk[i] * scale[i] + shift[i];
        }
    }
}

/// Per-channel batch mean and *biased* variance (like `jnp.var`) over the
/// trailing-channel layout, accumulated in f64.
pub fn bn_batch_stats(x: &[f32], ch: usize) -> (Vec<f32>, Vec<f32>) {
    let rows = x.len() / ch.max(1);
    let mut mean = vec![0.0f64; ch];
    let mut var = vec![0.0f64; ch];
    for chunk in x.chunks_exact(ch) {
        for (i, &v) in chunk.iter().enumerate() {
            mean[i] += v as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= rows.max(1) as f64;
    }
    for chunk in x.chunks_exact(ch) {
        for (i, &v) in chunk.iter().enumerate() {
            let d = v as f64 - mean[i];
            var[i] += d * d;
        }
    }
    for v in var.iter_mut() {
        *v /= rows.max(1) as f64;
    }
    (
        mean.iter().map(|&v| v as f32).collect(),
        var.iter().map(|&v| v as f32).collect(),
    )
}

/// In-place normalize + affine: `x = x̂·γ + β` with `x̂ = (x−μ)·inv`.
/// When `xhat` is given it is cleared and filled with the normalized
/// values — the saved context [`bn_bwd`] needs.
pub fn bn_normalize(
    x: &mut [f32],
    mean: &[f32],
    inv: &[f32],
    gamma: &[f32],
    beta: &[f32],
    xhat: Option<&mut Vec<f32>>,
) {
    let c = mean.len();
    if let Some(xh) = xhat {
        xh.clear();
        xh.reserve(x.len());
        for chunk in x.chunks_exact_mut(c) {
            for (i, v) in chunk.iter_mut().enumerate() {
                let nx = (*v - mean[i]) * inv[i];
                xh.push(nx);
                *v = nx * gamma[i] + beta[i];
            }
        }
    } else {
        for chunk in x.chunks_exact_mut(c) {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (*v - mean[i]) * inv[i] * gamma[i] + beta[i];
            }
        }
    }
}

/// Standard three-term batch-norm backward over the saved normalized
/// activations: `dy` is rewritten in place to
/// `dx = inv/N · (N·dx̂ − Σdx̂ − x̂·Σ(dx̂·x̂))` per channel; returns
/// `(dγ, dβ)` as f64 channel sums (`dγ = Σ dy·x̂`, `dβ = Σ dy`).
pub fn bn_bwd(xhat: &[f32], inv: &[f32], gamma: &[f32], dy: &mut [f32]) -> (Vec<f64>, Vec<f64>) {
    let ch = gamma.len();
    assert_eq!(dy.len(), xhat.len(), "bn backward shape");
    let rows = dy.len() / ch.max(1);
    let mut dgamma = vec![0.0f64; ch];
    let mut dbeta = vec![0.0f64; ch];
    let mut s1 = vec![0.0f64; ch];
    let mut s2 = vec![0.0f64; ch];
    for (r, chunk) in dy.chunks_exact_mut(ch).enumerate() {
        let xh = &xhat[r * ch..(r + 1) * ch];
        for i in 0..ch {
            let g = chunk[i] as f64;
            dgamma[i] += g * xh[i] as f64;
            dbeta[i] += g;
            let dxh = g * gamma[i] as f64;
            s1[i] += dxh;
            s2[i] += dxh * xh[i] as f64;
            chunk[i] = dxh as f32; // dy buffer now holds dx̂
        }
    }
    let n = rows as f64;
    for (r, chunk) in dy.chunks_exact_mut(ch).enumerate() {
        let xh = &xhat[r * ch..(r + 1) * ch];
        for i in 0..ch {
            let dxh = chunk[i] as f64;
            chunk[i] = (inv[i] as f64 * (dxh - s1[i] / n - xh[i] as f64 * s2[i] / n)) as f32;
        }
    }
    (dgamma, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_matches_eval_formula() {
        let (scale, shift) = fold_bn(&[2.0], &[1.0], &[0.5], &[4.0]);
        let s = 2.0 / (4.0f32 + BN_EPS).sqrt();
        assert!((scale[0] - s).abs() < 1e-6);
        assert!((shift[0] - (1.0 - 0.5 * s)).abs() < 1e-6);
    }

    #[test]
    fn apply_out_matches_apply_inplace() {
        let mut rng = crate::util::rng::Pcg32::seeded(41);
        let c = 3usize;
        let x: Vec<f32> = (0..4 * c).map(|_| rng.normal()).collect();
        let scale: Vec<f32> = (0..c).map(|_| rng.normal()).collect();
        let shift: Vec<f32> = (0..c).map(|_| rng.normal()).collect();
        let mut a = x.clone();
        bn_apply(&mut a, &scale, &shift);
        let mut b = vec![0.0f32; x.len()];
        bn_apply_out(&x, &scale, &shift, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn batch_stats_zero_mean_unit_var_after_normalize() {
        let mut rng = crate::util::rng::Pcg32::seeded(42);
        let c = 2usize;
        let mut x: Vec<f32> = (0..64 * c).map(|_| rng.normal() * 3.0 + 1.0).collect();
        let (mean, var) = bn_batch_stats(&x, c);
        let inv: Vec<f32> = var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
        let mut xhat = Vec::new();
        bn_normalize(&mut x, &mean, &inv, &[1.0, 1.0], &[0.0, 0.0], Some(&mut xhat));
        assert_eq!(xhat, x); // γ=1, β=0
        let (m2, v2) = bn_batch_stats(&x, c);
        for i in 0..c {
            assert!(m2[i].abs() < 1e-4, "mean {}", m2[i]);
            assert!((v2[i] - 1.0).abs() < 1e-3, "var {}", v2[i]);
        }
    }
}
