//! Panelized weight storage for the quantized GEMM: the packed weight
//! matrix unpacked **once** into the exact blocked, interleaved i8 layout
//! the SIMD microkernels consume ([`super::simd`]).
//!
//! Since the autotuner landed (DESIGN.md §SIMD-dispatch), the blocking is
//! a per-panel [`PanelGeom`] — `kc`×`nc` tiles, `nr`-wide column blocks,
//! `ki`-deep k-interleave — instead of compile-time constants. The legacy
//! constants survive as [`PanelGeom::DEFAULT`] (`KC`=256 × `NC`=64, NR=8,
//! pair interleave), which is also what `LSQNET_NO_TUNE=1` pins and what
//! the fused-unpack mode always uses. Layout, per kc×nc tile:
//!
//! ```text
//! tile = [ j-block 0 | j-block 1 | … ]            nblocks = ⌈nc / nr⌉
//! j-block = [ chunk t=0 | chunk t=1 | … ]         groups  = ⌈kc / ki⌉
//! chunk t = ki·nr bytes:
//!           w[ki·t][j0+0] … w[ki·t+ki-1][j0+0]  w[ki·t][j0+1] …
//!           (ki consecutive k rows × nr columns, k-interleaved)
//! ```
//!
//! One chunk is exactly one SIMD load: at `ki=2` it is widened to i16 and
//! a single `pmaddwd`/`vpdpwssd` against the broadcast activation pair
//! `(x[2t], x[2t+1])` yields the per-column partial sums; at `ki=4` (the
//! NEON sdot shape — activations must fit i8) four consecutive k rows
//! multiply against a broadcast 4×i8 activation group. Ragged edges (kc
//! not a multiple of ki, nc not a multiple of nr) are zero-padded inside
//! the chunk, so the microkernels never branch on them. Geometry never
//! affects *results*: i32 accumulation is exact, so every [`PanelGeom`]
//! produces bitwise-identical GEMM output (the autotuner only moves time).
//!
//! Two build sites share this layout (DESIGN.md §SIMD-dispatch):
//!
//! * [`PanelizedWeights::build_for_acts`] — once per layer at
//!   engine/trainer bind time, with the blocking chosen by the bind-time
//!   autotuner ([`super::tune`]); serve replicas then read the shared
//!   panels with **zero** per-call unpack work, at a memory cost of
//!   ~`k·n` bytes per layer (vs `k·n·bits/8` packed).
//! * the fused mode of [`super::qgemm`] — per-tile into per-thread
//!   workspace scratch at [`PanelGeom::DEFAULT`], preserving the old
//!   low-memory behavior for deployments where the unpacked panels don't
//!   fit (`PrepareOptions::low_memory` — `ServerConfig::fused_unpack` /
//!   `VariantOptions::low_memory` at the serve layer, or
//!   `LSQNET_FUSED_UNPACK=1`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::quant::pack::{unpack_range_spec, Packed};

use super::gemm::{KC, NC, NR};

/// Process-wide count of panel *constructions* (calls that actually ran
/// the unpack loop in [`PanelizedWeights::build_with_geom`]). Shared
/// bindings ([`PanelizedWeights::from_shared`] — the artifact zero-copy
/// path) do **not** increment it, which is exactly what the artifact
/// round-trip tests assert: binding from a `.lsqa` performs zero panel
/// builds.
static PANEL_BUILDS: AtomicU64 = AtomicU64::new(0);

/// Total panel constructions so far in this process (monotone; see
/// [`PANEL_BUILDS`]). Diff two readings around a bind to count the panel
/// work it did.
pub fn panel_build_count() -> u64 {
    PANEL_BUILDS.load(Ordering::Relaxed)
}

/// Backing storage a shared (borrowed) panel block lives in — implemented
/// by the artifact loader's page-aligned arena so every replica of a
/// variant reads the *same* bytes instead of per-engine copies.
pub trait PanelSource: Send + Sync {
    /// The full backing byte range (panel blocks index into this).
    fn bytes(&self) -> &[i8];
}

/// Where a [`PanelizedWeights`]'s tile bytes live: built-and-owned (the
/// bind-time path) or a borrowed window of a shared [`PanelSource`] arena
/// (the artifact path). Layout and indexing are identical either way.
enum PanelData {
    Owned(Vec<i8>),
    Shared { src: Arc<dyn PanelSource>, off: usize, len: usize },
}

impl PanelData {
    #[inline]
    fn as_slice(&self) -> &[i8] {
        match self {
            PanelData::Owned(v) => v,
            PanelData::Shared { src, off, len } => &src.bytes()[*off..*off + *len],
        }
    }
}

/// Widest column block any microkernel uses (the AVX-512 VNNI level's 16
/// i32 lanes) — sizes the scalar reference kernel's register tile.
pub(crate) const MAX_NR: usize = 16;

/// Per-panel blocking geometry: the microkernel shape a
/// [`PanelizedWeights`] was built for. Chosen at bind time by the
/// autotuner ([`super::tune`]) from a small per-[`super::simd::SimdLevel`]
/// candidate set; [`PanelGeom::DEFAULT`] reproduces the pre-autotuner
/// compile-time constants byte-for-byte.
///
/// Geometry is a *time* decision only: `qgemm` output is bitwise
/// identical for every valid geometry (exact i32 sums).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PanelGeom {
    /// Weight rows per tile (the k blocking factor).
    pub kc: usize,
    /// Weight columns per tile (the n blocking factor).
    pub nc: usize,
    /// Column width of one microkernel block (i32 accumulator lanes).
    pub nr: usize,
    /// k-interleave depth of one chunk: 2 (i16-pair kernels — `pmaddwd`,
    /// `vpdpwssd`, NEON `smlal`) or 4 (the NEON sdot shape; requires
    /// activations that fit i8).
    pub ki: usize,
}

impl PanelGeom {
    /// The legacy compile-time blocking (`KC`×`NC`, NR=8, pair
    /// interleave): what [`PanelizedWeights::build`] uses, what
    /// `LSQNET_NO_TUNE=1` pins, and the fused-unpack mode's only
    /// geometry. Produces byte-identical panels to the pre-autotuner
    /// layout.
    pub const DEFAULT: PanelGeom = PanelGeom { kc: KC, nc: NC, nr: NR, ki: 2 };

    /// `true` iff this geometry is one the kernel layer can execute:
    /// positive blocking, `nr ≤` [`MAX_NR`], `ki ∈ {2, 4}`.
    pub fn valid(&self) -> bool {
        self.kc > 0 && self.nc > 0 && self.nr > 0 && self.nr <= MAX_NR && matches!(self.ki, 2 | 4)
    }

    /// Activation groups (chunks) in a tile of `kc` rows.
    #[inline]
    pub(crate) fn groups(&self, kc: usize) -> usize {
        kc.div_ceil(self.ki)
    }

    /// Bytes of one panelized tile: `⌈nc/nr⌉` j-blocks of `groups`
    /// chunks, `ki·nr` bytes each.
    #[inline]
    pub(crate) fn tile_len(&self, kc: usize, nc: usize) -> usize {
        nc.div_ceil(self.nr) * self.groups(kc) * self.ki * self.nr
    }
}

/// `true` iff every stored weight value of `p` fits the i8 panel element.
/// Signed packings always fit (Eq. 1 weights are symmetric signed, values
/// in [-128, 127]); unsigned fits through 7 bits. The only excluded case —
/// unsigned 8-bit *weights* — does not occur in the engine, which packs
/// weights signed.
pub(crate) fn fits_i8(p: &Packed) -> bool {
    p.signed || p.bits < 8
}

/// Unpack one kc×nc weight tile of `p` (logical row-major `k×n`, rows
/// `k0..k0+kc`, columns `n0..n0+nc`) into the interleaved panel layout of
/// `geom`. `row` is caller scratch for one unpacked tile row; `out` must
/// be exactly [`PanelGeom::tile_len`] bytes. Ragged tiles are
/// zero-padded; full interior tiles overwrite every byte, so stale
/// scratch needs no clearing.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_tile_panel(
    p: &Packed,
    n: usize,
    k0: usize,
    kc: usize,
    n0: usize,
    nc: usize,
    geom: PanelGeom,
    row: &mut Vec<i32>,
    out: &mut [i8],
) {
    debug_assert!(fits_i8(p), "weight values exceed the i8 panel range");
    debug_assert_eq!(out.len(), geom.tile_len(kc, nc));
    let (nr, ki) = (geom.nr, geom.ki);
    let block_len = geom.groups(kc) * ki * nr;
    if kc % ki != 0 || nc % nr != 0 {
        out.fill(0);
    }
    if row.len() < nc {
        row.resize(nc, 0);
    }
    for kk in 0..kc {
        unpack_range_spec(p, (k0 + kk) * n + n0, nc, row);
        let (t, r) = (kk / ki, kk % ki);
        for (j, &v) in row.iter().enumerate().take(nc) {
            let (jb, c) = (j / nr, j % nr);
            out[jb * block_len + t * ki * nr + c * ki + r] = v as i8;
        }
    }
}

/// The whole packed weight matrix pre-unpacked into panel tiles, built
/// once at model bind and shared read-only by every forward call — the
/// serve hot loop stops paying per-call per-thread tile unpack entirely.
pub struct PanelizedWeights {
    k: usize,
    n: usize,
    geom: PanelGeom,
    /// Tile start offsets, row-major over the (⌈k/kc⌉ × ⌈n/nc⌉) tile
    /// grid, with a trailing sentinel equal to `data.len()`.
    offsets: Vec<usize>,
    data: PanelData,
}

/// Tile start offsets for a `k×n` matrix panelized at `geom`: row-major
/// over the (⌈k/kc⌉ × ⌈n/nc⌉) tile grid, with a trailing sentinel equal
/// to the total panel byte length. Offsets are a pure function of the
/// shape and geometry — the artifact format stores only `(k, n, geom)`
/// and recomputes them here, so a tampered length can never index out of
/// a section (the reader cross-checks the sentinel against the recorded
/// blob length first).
pub(crate) fn tile_offsets(k: usize, n: usize, geom: PanelGeom) -> Vec<usize> {
    let (kt, nt) = (k.div_ceil(geom.kc), n.div_ceil(geom.nc));
    let mut offsets = Vec::with_capacity(kt * nt + 1);
    let mut total = 0usize;
    for ik in 0..kt {
        let kc = geom.kc.min(k - ik * geom.kc);
        for in_ in 0..nt {
            offsets.push(total);
            total += geom.tile_len(kc, geom.nc.min(n - in_ * geom.nc));
        }
    }
    offsets.push(total);
    offsets
}

impl PanelizedWeights {
    /// Unpack `p` (logical row-major `k×n`) into panel tiles at the
    /// legacy [`PanelGeom::DEFAULT`] blocking (no autotuning — the
    /// deterministic-geometry entry point tests and benches use).
    ///
    /// # Panics
    /// If `p.len != k*n`, or if `p` stores values outside the i8 panel
    /// range (unsigned 8-bit packings — never produced for weights).
    pub fn build(p: &Packed, k: usize, n: usize) -> PanelizedWeights {
        PanelizedWeights::build_with_geom(p, k, n, PanelGeom::DEFAULT)
    }

    /// The bind-path entry point: pick the blocking with the bind-time
    /// autotuner ([`super::tune::tune_geom`] — measured on this layer's
    /// real `(k, n, bits)` shape, cached process-wide, pinned to
    /// [`PanelGeom::DEFAULT`] by `LSQNET_NO_TUNE=1`), then build.
    /// `act_max` is the largest activation magnitude the layer can feed
    /// this panel (`max(act_qn, act_qp)` from Eq. 1): geometries with
    /// `ki=4` pack activations as i8 and are only eligible when
    /// `act_max ≤ 127`.
    pub fn build_for_acts(p: &Packed, k: usize, n: usize, act_max: i64) -> PanelizedWeights {
        let geom = super::tune::tune_geom(p, k, n, act_max);
        PanelizedWeights::build_with_geom(p, k, n, geom)
    }

    /// Unpack `p` into panel tiles at an explicit `geom` (must satisfy
    /// [`PanelGeom::valid`]). Every valid geometry yields bitwise-identical
    /// GEMM results; only throughput differs.
    pub fn build_with_geom(p: &Packed, k: usize, n: usize, geom: PanelGeom) -> PanelizedWeights {
        assert_eq!(p.len, k * n, "packed weight shape");
        assert!(fits_i8(p), "unsigned 8-bit weights do not fit i8 panels");
        assert!(geom.valid(), "invalid panel geometry {geom:?}");
        PANEL_BUILDS.fetch_add(1, Ordering::Relaxed);
        let (kt, nt) = (k.div_ceil(geom.kc), n.div_ceil(geom.nc));
        let offsets = tile_offsets(k, n, geom);
        let mut data = vec![0i8; *offsets.last().expect("sentinel")];
        let mut row = Vec::with_capacity(geom.nc);
        for ik in 0..kt {
            let kc = geom.kc.min(k - ik * geom.kc);
            for in_ in 0..nt {
                let nc = geom.nc.min(n - in_ * geom.nc);
                let t = ik * nt + in_;
                let out = &mut data[offsets[t]..offsets[t + 1]];
                fill_tile_panel(p, n, ik * geom.kc, kc, in_ * geom.nc, nc, geom, &mut row, out);
            }
        }
        PanelizedWeights { k, n, geom, offsets, data: PanelData::Owned(data) }
    }

    /// Bind panels over a borrowed `len`-byte window at `off` of a shared
    /// [`PanelSource`] arena — the artifact zero-copy path. The bytes must
    /// already be in the exact layout [`PanelizedWeights::build_with_geom`]
    /// would produce for `(k, n, geom)` (the `.lsqa` writer guarantees
    /// this; the reader verifies lengths and checksums before calling).
    /// Performs no unpack work and does **not** count as a panel build.
    ///
    /// # Panics
    /// If `geom` is invalid, the window length does not match the layout's
    /// computed total, or the window falls outside the source.
    pub(crate) fn from_shared(
        k: usize,
        n: usize,
        geom: PanelGeom,
        src: Arc<dyn PanelSource>,
        off: usize,
        len: usize,
    ) -> PanelizedWeights {
        assert!(geom.valid(), "invalid panel geometry {geom:?}");
        let offsets = tile_offsets(k, n, geom);
        assert_eq!(*offsets.last().expect("sentinel"), len, "shared panel length");
        assert!(
            off.checked_add(len).is_some_and(|end| end <= src.bytes().len()),
            "shared panel window out of bounds"
        );
        PanelizedWeights { k, n, geom, offsets, data: PanelData::Shared { src, off, len } }
    }

    /// Logical weight rows (the GEMM k dimension).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Logical weight columns (the GEMM n dimension).
    pub fn n(&self) -> usize {
        self.n
    }

    /// The blocking geometry these panels were built with (drives the
    /// `qgemm_panel` loop structure and microkernel selection).
    pub fn geom(&self) -> PanelGeom {
        self.geom
    }

    /// Resident panel bytes — the memory cost of the pre-unpacked mode
    /// (compare `Packed::storage_bytes` for the fused-unpack footprint).
    /// Counts the tile bytes plus the per-panel metadata (tile offset
    /// table and [`PanelGeom`]), and reports the same number whether the
    /// panels were built at bind time or borrowed from an artifact arena —
    /// storage/working-set numbers must not drift between the two paths.
    pub fn panel_bytes(&self) -> usize {
        self.data.as_slice().len()
            + self.offsets.len() * std::mem::size_of::<usize>()
            + std::mem::size_of::<PanelGeom>()
    }

    /// The raw tile bytes, offset-table order (what the `.lsqa` writer
    /// serializes verbatim).
    pub(crate) fn raw_data(&self) -> &[i8] {
        self.data.as_slice()
    }

    /// The tile at k-block `ik`, n-block `in_`.
    pub(crate) fn tile(&self, ik: usize, in_: usize) -> &[i8] {
        let nt = self.n.div_ceil(self.geom.nc);
        let t = ik * nt + in_;
        &self.data.as_slice()[self.offsets[t]..self.offsets[t + 1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{pack, unpack};
    use crate::util::rng::Pcg32;

    /// Panel bytes must equal the unpacked weight values, at the layout's
    /// documented positions, for shapes straddling every tile edge — for
    /// the default geometry and for alternate blockings including the
    /// ki=4 (NEON sdot) interleave.
    #[test]
    fn panel_layout_matches_unpacked_weights() {
        let geoms = [
            PanelGeom::DEFAULT,
            PanelGeom { kc: 128, nc: 128, nr: 8, ki: 2 },
            PanelGeom { kc: 256, nc: 64, nr: 16, ki: 2 },
            PanelGeom { kc: 256, nc: 64, nr: 8, ki: 4 },
        ];
        for &(k, n, bits) in &[
            (5usize, 3usize, 2u32),
            (KC + 7, NC + 9, 3),
            (KC, NC, 4),
            (2 * KC + 1, 17, 8),
        ] {
            let mut rng = Pcg32::seeded(1000 + k as u64 + n as u64 + bits as u64);
            let (qn, qp) = crate::quant::lsq::qrange(bits, true);
            let w: Vec<i32> = (0..k * n)
                .map(|_| rng.below((qn + qp + 1) as u32) as i32 - qn as i32)
                .collect();
            let p = pack(&w, bits, true, 1.0).unwrap();
            let full = unpack(&p);
            for geom in geoms {
                let pw = PanelizedWeights::build_with_geom(&p, k, n, geom);
                assert_eq!(pw.geom(), geom);
                let (nr, ki) = (geom.nr, geom.ki);
                let (kt, nt) = (k.div_ceil(geom.kc), n.div_ceil(geom.nc));
                for ik in 0..kt {
                    let kc = geom.kc.min(k - ik * geom.kc);
                    let (groups, block_len) = (geom.groups(kc), geom.groups(kc) * ki * nr);
                    for in_ in 0..nt {
                        let nc = geom.nc.min(n - in_ * geom.nc);
                        let tile = pw.tile(ik, in_);
                        assert_eq!(tile.len(), geom.tile_len(kc, nc));
                        for jb in 0..nc.div_ceil(nr) {
                            for t in 0..groups {
                                for c in 0..nr {
                                    for r in 0..ki {
                                        let (kk, j) = (ki * t + r, jb * nr + c);
                                        let got =
                                            tile[jb * block_len + t * ki * nr + c * ki + r] as i32;
                                        let want = if kk < kc && j < nc {
                                            full[(ik * geom.kc + kk) * n + in_ * geom.nc + j]
                                        } else {
                                            0 // padding
                                        };
                                        assert_eq!(
                                            got, want,
                                            "k={k} n={n} bits={bits} {geom:?} \
                                             tile ({ik},{in_}) kk={kk} j={j}"
                                        );
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// The default geometry must reproduce the pre-autotuner layout
    /// byte-for-byte (the fused-unpack path and pre-built panels share
    /// layout code, so this pins both).
    #[test]
    fn default_geom_matches_legacy_constants() {
        let g = PanelGeom::DEFAULT;
        assert_eq!((g.kc, g.nc, g.nr, g.ki), (KC, NC, NR, 2));
        assert_eq!(g.tile_len(KC, NC), (NC / NR) * (KC / 2) * 2 * NR);
        // Ragged edges round up exactly like the old hand-rolled
        // `(x + d - 1) / d` ceilings did.
        assert_eq!(g.tile_len(5, 3), ((5 + 1) / 2) * 2 * NR);
        assert_eq!(g.groups(7), (7 + 1) / 2);
    }

    #[test]
    fn fused_tile_builder_matches_prebuilt_panels() {
        let (k, n) = (KC + 3, NC + 5);
        let mut rng = Pcg32::seeded(2024);
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(15) as i32 - 7).collect();
        let p = pack(&w, 4, true, 1.0).unwrap();
        let pw = PanelizedWeights::build(&p, k, n);
        let mut row = Vec::new();
        for (ik, k0) in (0..k).step_by(KC).enumerate() {
            let kc = KC.min(k - k0);
            for (in_, n0) in (0..n).step_by(NC).enumerate() {
                let nc = NC.min(n - n0);
                // Stale scratch: the builder must fully define every byte.
                let mut scratch = vec![0x55i8; PanelGeom::DEFAULT.tile_len(kc, nc)];
                fill_tile_panel(&p, n, k0, kc, n0, nc, PanelGeom::DEFAULT, &mut row, &mut scratch);
                assert_eq!(scratch, pw.tile(ik, in_), "tile ({ik},{in_})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "unsigned 8-bit")]
    fn unsigned_8bit_weights_rejected() {
        let p = pack(&[200, 3], 8, false, 1.0).unwrap();
        PanelizedWeights::build(&p, 1, 2);
    }

    #[test]
    #[should_panic(expected = "invalid panel geometry")]
    fn invalid_geometry_rejected() {
        let p = pack(&[1, -1], 2, true, 1.0).unwrap();
        PanelizedWeights::build_with_geom(&p, 1, 2, PanelGeom { kc: 64, nc: 64, nr: 8, ki: 3 });
    }
}
