//! Panelized weight storage for the quantized GEMM: the packed weight
//! matrix unpacked **once** into the exact KC×NC-blocked, NR-interleaved
//! i8 layout the SIMD microkernels consume ([`super::simd`]).
//!
//! Layout, per KC×NC tile (kc×nc at the ragged edges):
//!
//! ```text
//! tile = [ j-block 0 | j-block 1 | … ]            nblocks = ⌈nc / NR⌉
//! j-block = [ chunk t=0 | chunk t=1 | … ]         pairs   = ⌈kc / 2⌉
//! chunk t = 16 bytes:  w[2t][j0+0] w[2t+1][j0+0]  w[2t][j0+1] w[2t+1][j0+1] …
//!           (two consecutive k rows × NR=8 columns, k-pair interleaved)
//! ```
//!
//! One 16-byte chunk is exactly one SIMD load: widened to i16, a single
//! `pmaddwd` against the broadcast activation pair `(x[2t], x[2t+1])`
//! yields the eight per-column partial sums. Ragged edges (odd `kc`, `nc`
//! not a multiple of NR) are zero-padded inside the chunk, so the
//! microkernels never branch on them.
//!
//! Two build sites share this layout (DESIGN.md §SIMD-dispatch):
//!
//! * [`PanelizedWeights::build`] — once per layer at engine/trainer bind
//!   time; serve replicas then read the shared panels with **zero**
//!   per-call unpack work, at a memory cost of ~`k·n` bytes per layer
//!   (vs `k·n·bits/8` packed).
//! * the fused mode of [`super::qgemm`] — per-tile into per-thread
//!   workspace scratch, preserving the old low-memory behavior for
//!   deployments where the unpacked panels don't fit
//!   (`PrepareOptions::low_memory` — `ServerConfig::fused_unpack` /
//!   `VariantOptions::low_memory` at the serve layer, or
//!   `LSQNET_FUSED_UNPACK=1`).

use crate::quant::pack::{unpack_range_spec, Packed};

use super::gemm::{KC, NC, NR};

/// `true` iff every stored weight value of `p` fits the i8 panel element.
/// Signed packings always fit (Eq. 1 weights are symmetric signed, values
/// in [-128, 127]); unsigned fits through 7 bits. The only excluded case —
/// unsigned 8-bit *weights* — does not occur in the engine, which packs
/// weights signed.
pub(crate) fn fits_i8(p: &Packed) -> bool {
    p.signed || p.bits < 8
}

/// Number of k-row pairs in a tile of `kc` rows.
#[inline]
pub(crate) fn tile_pairs(kc: usize) -> usize {
    (kc + 1) / 2
}

/// Bytes of one panelized tile: `⌈nc/NR⌉` j-blocks of `pairs` 16-byte
/// chunks.
#[inline]
pub(crate) fn tile_len(kc: usize, nc: usize) -> usize {
    ((nc + NR - 1) / NR) * tile_pairs(kc) * 2 * NR
}

/// Unpack one kc×nc weight tile of `p` (logical row-major `k×n`, rows
/// `k0..k0+kc`, columns `n0..n0+nc`) into the interleaved panel layout.
/// `row` is caller scratch for one unpacked tile row; `out` must be
/// exactly [`tile_len`] bytes. Ragged tiles are zero-padded; full interior
/// tiles overwrite every byte, so stale scratch needs no clearing.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_tile_panel(
    p: &Packed,
    n: usize,
    k0: usize,
    kc: usize,
    n0: usize,
    nc: usize,
    row: &mut Vec<i32>,
    out: &mut [i8],
) {
    debug_assert!(fits_i8(p), "weight values exceed the i8 panel range");
    debug_assert_eq!(out.len(), tile_len(kc, nc));
    let pairs = tile_pairs(kc);
    if kc % 2 != 0 || nc % NR != 0 {
        out.fill(0);
    }
    if row.len() < nc {
        row.resize(nc, 0);
    }
    for kk in 0..kc {
        unpack_range_spec(p, (k0 + kk) * n + n0, nc, row);
        let (t, r) = (kk / 2, kk % 2);
        for (j, &v) in row.iter().enumerate().take(nc) {
            let (jb, c) = (j / NR, j % NR);
            out[jb * pairs * 2 * NR + t * 2 * NR + 2 * c + r] = v as i8;
        }
    }
}

/// The whole packed weight matrix pre-unpacked into panel tiles, built
/// once at model bind and shared read-only by every forward call — the
/// serve hot loop stops paying per-call per-thread tile unpack entirely.
pub struct PanelizedWeights {
    k: usize,
    n: usize,
    /// Tile start offsets, row-major over the (⌈k/KC⌉ × ⌈n/NC⌉) tile grid,
    /// with a trailing sentinel equal to `data.len()`.
    offsets: Vec<usize>,
    data: Vec<i8>,
}

impl PanelizedWeights {
    /// Unpack `p` (logical row-major `k×n`) into panel tiles.
    ///
    /// # Panics
    /// If `p.len != k*n`, or if `p` stores values outside the i8 panel
    /// range (unsigned 8-bit packings — never produced for weights).
    pub fn build(p: &Packed, k: usize, n: usize) -> PanelizedWeights {
        assert_eq!(p.len, k * n, "packed weight shape");
        assert!(fits_i8(p), "unsigned 8-bit weights do not fit i8 panels");
        let (kt, nt) = ((k + KC - 1) / KC, (n + NC - 1) / NC);
        let mut offsets = Vec::with_capacity(kt * nt + 1);
        let mut total = 0usize;
        for ik in 0..kt {
            let kc = KC.min(k - ik * KC);
            for in_ in 0..nt {
                offsets.push(total);
                total += tile_len(kc, NC.min(n - in_ * NC));
            }
        }
        offsets.push(total);
        let mut data = vec![0i8; total];
        let mut row = Vec::with_capacity(NC);
        for ik in 0..kt {
            let kc = KC.min(k - ik * KC);
            for in_ in 0..nt {
                let nc = NC.min(n - in_ * NC);
                let t = ik * nt + in_;
                let out = &mut data[offsets[t]..offsets[t + 1]];
                fill_tile_panel(p, n, ik * KC, kc, in_ * NC, nc, &mut row, out);
            }
        }
        PanelizedWeights { k, n, offsets, data }
    }

    /// Logical weight rows (the GEMM k dimension).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Logical weight columns (the GEMM n dimension).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Resident panel bytes — the memory cost of the pre-unpacked mode
    /// (compare `Packed::storage_bytes` for the fused-unpack footprint).
    pub fn panel_bytes(&self) -> usize {
        self.data.len()
    }

    /// The tile at k-block `ik`, n-block `in_`.
    pub(crate) fn tile(&self, ik: usize, in_: usize) -> &[i8] {
        let nt = (self.n + NC - 1) / NC;
        let t = ik * nt + in_;
        &self.data[self.offsets[t]..self.offsets[t + 1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{pack, unpack};
    use crate::util::rng::Pcg32;

    /// Panel bytes must equal the unpacked weight values, at the layout's
    /// documented positions, for shapes straddling every tile edge.
    #[test]
    fn panel_layout_matches_unpacked_weights() {
        for &(k, n, bits) in &[
            (5usize, 3usize, 2u32),
            (KC + 7, NC + 9, 3),
            (KC, NC, 4),
            (2 * KC + 1, 17, 8),
        ] {
            let mut rng = Pcg32::seeded(1000 + k as u64 + n as u64 + bits as u64);
            let (qn, qp) = crate::quant::lsq::qrange(bits, true);
            let w: Vec<i32> = (0..k * n)
                .map(|_| rng.below((qn + qp + 1) as u32) as i32 - qn as i32)
                .collect();
            let p = pack(&w, bits, true, 1.0).unwrap();
            let pw = PanelizedWeights::build(&p, k, n);
            let full = unpack(&p);
            let (kt, nt) = ((k + KC - 1) / KC, (n + NC - 1) / NC);
            for ik in 0..kt {
                let kc = KC.min(k - ik * KC);
                let pairs = tile_pairs(kc);
                for in_ in 0..nt {
                    let nc = NC.min(n - in_ * NC);
                    let tile = pw.tile(ik, in_);
                    assert_eq!(tile.len(), tile_len(kc, nc));
                    let nblocks = (nc + NR - 1) / NR;
                    for jb in 0..nblocks {
                        for t in 0..pairs {
                            for c in 0..NR {
                                for r in 0..2usize {
                                    let (kk, j) = (2 * t + r, jb * NR + c);
                                    let got =
                                        tile[jb * pairs * 2 * NR + t * 2 * NR + 2 * c + r] as i32;
                                    let want = if kk < kc && j < nc {
                                        full[(ik * KC + kk) * n + in_ * NC + j]
                                    } else {
                                        0 // padding
                                    };
                                    assert_eq!(
                                        got, want,
                                        "k={k} n={n} bits={bits} tile ({ik},{in_}) kk={kk} j={j}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fused_tile_builder_matches_prebuilt_panels() {
        let (k, n) = (KC + 3, NC + 5);
        let mut rng = Pcg32::seeded(2024);
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(15) as i32 - 7).collect();
        let p = pack(&w, 4, true, 1.0).unwrap();
        let pw = PanelizedWeights::build(&p, k, n);
        let mut row = Vec::new();
        for (ik, k0) in (0..k).step_by(KC).enumerate() {
            let kc = KC.min(k - k0);
            for (in_, n0) in (0..n).step_by(NC).enumerate() {
                let nc = NC.min(n - n0);
                // Stale scratch: the builder must fully define every byte.
                let mut scratch = vec![0x55i8; tile_len(kc, nc)];
                fill_tile_panel(&p, n, k0, kc, n0, nc, &mut row, &mut scratch);
                assert_eq!(scratch, pw.tile(ik, in_), "tile ({ik},{in_})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "unsigned 8-bit")]
    fn unsigned_8bit_weights_rejected() {
        let p = pack(&[200, 3], 8, false, 1.0).unwrap();
        PanelizedWeights::build(&p, 1, 2);
    }
}
