//! Pooling and activation primitives shared by the inference forward and
//! the training tape: 2×2 max pool (with optional argmax recording for the
//! backward scatter), global average pool, and ReLU (with optional mask
//! recording).

/// 2×2 / stride-2 max pool over an NHWC buffer. `out` must hold
/// `b*(h/2)*(w/2)*c` elements. When `argmax` is given it is resized to the
/// output length and records the flat input index of each winning element
/// — the scatter targets [`maxpool2_bwd`] replays.
pub fn maxpool2(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
    mut argmax: Option<&mut Vec<usize>>,
) {
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(x.len(), b * h * w * c, "maxpool input shape");
    assert_eq!(out.len(), b * oh * ow * c, "maxpool output shape");
    if let Some(a) = argmax.as_deref_mut() {
        a.clear();
        a.resize(b * oh * ow * c, 0);
    }
    out.fill(f32::NEG_INFINITY);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((bi * oh + oy) * ow + ox) * c;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let src = ((bi * h + oy * 2 + dy) * w + ox * 2 + dx) * c;
                        for ch in 0..c {
                            let v = x[src + ch];
                            if v > out[dst + ch] {
                                out[dst + ch] = v;
                                if let Some(a) = argmax.as_deref_mut() {
                                    a[dst + ch] = src + ch;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Backward of [`maxpool2`]: route each output gradient to the input
/// element that won the forward max. `dx` is zeroed here.
pub fn maxpool2_bwd(argmax: &[usize], dy: &[f32], dx: &mut [f32]) {
    assert_eq!(dy.len(), argmax.len(), "maxpool backward shape");
    dx.fill(0.0);
    for (&a, &d) in argmax.iter().zip(dy) {
        dx[a] += d;
    }
}

/// Global average pool over the spatial dims of an NHWC buffer:
/// `out[b×c] = mean over h*w`. `out` is overwritten.
pub fn global_avg_pool(x: &[f32], b: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    assert_eq!(x.len(), b * h * w * c, "gap input shape");
    assert_eq!(out.len(), b * c, "gap output shape");
    let inv = 1.0 / (h * w) as f32;
    out.fill(0.0);
    for bi in 0..b {
        for p in 0..h * w {
            let src = (bi * h * w + p) * c;
            for ch in 0..c {
                out[bi * c + ch] += x[src + ch];
            }
        }
        for ch in 0..c {
            out[bi * c + ch] *= inv;
        }
    }
}

/// Backward of [`global_avg_pool`]: broadcast `dy[b×c] / (h*w)` over the
/// spatial grid. `dx` is overwritten.
pub fn global_avg_pool_bwd(dy: &[f32], b: usize, h: usize, w: usize, c: usize, dx: &mut [f32]) {
    assert_eq!(dy.len(), b * c, "gap backward dy shape");
    assert_eq!(dx.len(), b * h * w * c, "gap backward dx shape");
    let inv = 1.0 / (h * w) as f32;
    for bi in 0..b {
        for p in 0..h * w {
            let dst = (bi * h * w + p) * c;
            for ch in 0..c {
                dx[dst + ch] = dy[bi * c + ch] * inv;
            }
        }
    }
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.max(0.0);
    }
}

/// In-place ReLU that also records the pass-through mask (`x > 0`) for
/// [`relu_bwd`].
pub fn relu_mask(x: &mut [f32], mask: &mut Vec<bool>) {
    mask.clear();
    mask.reserve(x.len());
    for v in x.iter_mut() {
        mask.push(*v > 0.0);
        *v = v.max(0.0);
    }
}

/// Backward of ReLU: zero the gradient where the forward input was ≤ 0.
pub fn relu_bwd(mask: &[bool], dy: &mut [f32]) {
    for (d, &m) in dy.iter_mut().zip(mask) {
        if !m {
            *d = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_picks_max_and_records_argmax() {
        // One 2x2 image, one channel.
        let x = vec![1.0f32, 4.0, 2.0, 3.0];
        let mut out = vec![0.0f32; 1];
        let mut arg = Vec::new();
        maxpool2(&x, 1, 2, 2, 1, &mut out, Some(&mut arg));
        assert_eq!(out, vec![4.0]);
        assert_eq!(arg, vec![1]);
        // Backward routes the whole gradient to the winner.
        let mut dx = vec![9.0f32; 4];
        maxpool2_bwd(&arg, &[2.5], &mut dx);
        assert_eq!(dx, vec![0.0, 2.5, 0.0, 0.0]);
    }

    #[test]
    fn gap_is_mean_and_bwd_is_adjoint() {
        let (b, h, w, c) = (2usize, 2usize, 2usize, 3usize);
        let mut rng = crate::util::rng::Pcg32::seeded(31);
        let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f32; b * c];
        global_avg_pool(&x, b, h, w, c, &mut out);
        for bi in 0..b {
            for ch in 0..c {
                let want: f32 =
                    (0..h * w).map(|p| x[(bi * h * w + p) * c + ch]).sum::<f32>() / 4.0;
                assert!((out[bi * c + ch] - want).abs() < 1e-6);
            }
        }
        // <gap(x), y> == <x, gap_bwd(y)>
        let y: Vec<f32> = (0..b * c).map(|_| rng.normal()).collect();
        let fwd: f64 = out.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
        let mut dx = vec![0.0f32; b * h * w * c];
        global_avg_pool_bwd(&y, b, h, w, c, &mut dx);
        let adj: f64 = x.iter().zip(&dx).map(|(a, b)| (a * b) as f64).sum();
        assert!((fwd - adj).abs() < 1e-5);
    }

    #[test]
    fn relu_mask_roundtrip() {
        let mut x = vec![-1.0f32, 0.0, 2.0];
        let mut mask = Vec::new();
        relu_mask(&mut x, &mut mask);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        assert_eq!(mask, vec![false, false, true]);
        let mut dy = vec![5.0f32, 5.0, 5.0];
        relu_bwd(&mask, &mut dy);
        assert_eq!(dy, vec![0.0, 0.0, 5.0]);
    }
}
