//! aarch64 NEON microkernels (`std::arch`, no external deps) — the
//! [`super::SimdLevel::Neon`] rung, so non-x86 hosts stop falling through
//! to scalar.
//!
//! # Safety
//!
//! Mirrors `x86.rs`: every function is `unsafe` for target features
//! (reached only through [`super::SimdLevel::Neon`], which
//! [`super::SimdLevel::detect`] yields only after
//! `is_aarch64_feature_detected!("neon")`) and for raw-pointer bounds
//! (the dispatcher asserts panel/xgroups/accumulator sizes first). Only
//! baseline Armv8.0 NEON intrinsics are used — no `dotprod` extension
//! required — so the module runs on every aarch64 host.
//!
//! Two quantized kernels cover the two panel interleaves:
//!
//! * **pair kernel** (`ki=2`, the portable geometry): the 16-byte chunk
//!   `[w[2t][c], w[2t+1][c]]×8` widens to i16 (`sxtl`), multiplies
//!   against the broadcast activation pair reinterpreted as alternating
//!   i16 lanes `[x0, x1, x0, x1, …]` (`smull`), and a pairwise add
//!   (`addp`) folds each in-column product pair into its i32 column
//!   lane — the NEON spelling of `pmaddwd`.
//! * **quad kernel** (`ki=4`, the sdot shape): four k rows per column per
//!   32-byte chunk multiply as i8×i8→i16 (`smull` — products ≤ 127·127
//!   fit i16 with headroom, which is why this geometry requires
//!   activations in i8 range), then two pairwise widening/folding adds
//!   (`saddlp`, `addp`) produce the i32 column sums: the same
//!   4-element dot-product dataflow as the `sdot` instruction, from
//!   baseline intrinsics.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::*;

use super::super::gemm::NR;

/// NEON quantized tile kernel, pair interleave (`nr=8`, `ki=2`).
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qgemm_tile_neon_pair(
    panel: &[i8],
    xp: &[i32],
    mb: usize,
    pairs: usize,
    nc: usize,
    n: usize,
    n0: usize,
    acc: &mut [i32],
) {
    let nblocks = nc.div_ceil(NR);
    let block_len = pairs * 2 * NR;
    for i in 0..mb {
        let xrow = xp.as_ptr().add(i * pairs);
        for jb in 0..nblocks {
            let block = panel.as_ptr().add(jb * block_len);
            let mut acc_lo = vdupq_n_s32(0); // columns 0..4
            let mut acc_hi = vdupq_n_s32(0); // columns 4..8
            for t in 0..pairs {
                let raw = vld1q_s8(block.add(t * 16));
                // [x0, x1, x0, x1, …] as 8 i16 lanes (little-endian:
                // lane 0 is the low half of the packed pair = x[2t]).
                let xv = vreinterpretq_s16_s32(vdupq_n_s32(*xrow.add(t)));
                let w_lo = vmovl_s8(vget_low_s8(raw)); // cols 0..4, pair-interleaved
                let w_hi = vmovl_s8(vget_high_s8(raw)); // cols 4..8
                // smull gives [w0c·x0, w1c·x1] adjacent per column;
                // addp folds each pair into its column's i32 lane.
                let p0 = vmull_s16(vget_low_s16(w_lo), vget_low_s16(xv));
                let p1 = vmull_s16(vget_high_s16(w_lo), vget_high_s16(xv));
                acc_lo = vaddq_s32(acc_lo, vpaddq_s32(p0, p1));
                let p2 = vmull_s16(vget_low_s16(w_hi), vget_low_s16(xv));
                let p3 = vmull_s16(vget_high_s16(w_hi), vget_high_s16(xv));
                acc_hi = vaddq_s32(acc_hi, vpaddq_s32(p2, p3));
            }
            store_cols8(acc, i * n + n0 + jb * NR, NR.min(nc - jb * NR), acc_lo, acc_hi);
        }
    }
}

/// NEON quantized tile kernel, quad interleave (`nr=8`, `ki=4` — the
/// sdot-shaped geometry the autotuner offers when activations fit i8).
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qgemm_tile_neon_quad(
    panel: &[i8],
    xq: &[i32],
    mb: usize,
    groups: usize,
    nc: usize,
    n: usize,
    n0: usize,
    acc: &mut [i32],
) {
    let nblocks = nc.div_ceil(NR);
    let block_len = groups * 4 * NR;
    for i in 0..mb {
        let xrow = xq.as_ptr().add(i * groups);
        for jb in 0..nblocks {
            let block = panel.as_ptr().add(jb * block_len);
            let mut acc_lo = vdupq_n_s32(0); // columns 0..4
            let mut acc_hi = vdupq_n_s32(0); // columns 4..8
            for t in 0..groups {
                // [x0..x3] repeated 4× as 16 i8 lanes.
                let xv = vreinterpretq_s8_u32(vdupq_n_u32(*xrow.add(t) as u32));
                let raw_lo = vld1q_s8(block.add(t * 32)); // cols 0..4 × 4 k rows
                let raw_hi = vld1q_s8(block.add(t * 32 + 16)); // cols 4..8
                // i8×i8→i16 products, then pairwise-fold twice:
                // saddlp pairs k0·x0+k1·x1 / k2·x2+k3·x3 per column,
                // addp folds those into one i32 lane per column.
                let a = vpaddlq_s16(vmull_s8(vget_low_s8(raw_lo), vget_low_s8(xv)));
                let b = vpaddlq_s16(vmull_s8(vget_high_s8(raw_lo), vget_high_s8(xv)));
                acc_lo = vaddq_s32(acc_lo, vpaddq_s32(a, b));
                let c = vpaddlq_s16(vmull_s8(vget_low_s8(raw_hi), vget_low_s8(xv)));
                let d = vpaddlq_s16(vmull_s8(vget_high_s8(raw_hi), vget_high_s8(xv)));
                acc_hi = vaddq_s32(acc_hi, vpaddq_s32(c, d));
            }
            store_cols8(acc, i * n + n0 + jb * NR, NR.min(nc - jb * NR), acc_lo, acc_hi);
        }
    }
}

/// Add two 4-lane i32 accumulators into `acc[off..off+js]` (js ≤ 8),
/// spilling through a stack tile at ragged edges like the x86 kernels.
#[target_feature(enable = "neon")]
unsafe fn store_cols8(acc: &mut [i32], off: usize, js: usize, lo: int32x4_t, hi: int32x4_t) {
    let dst = acc.as_mut_ptr().add(off);
    if js == NR {
        vst1q_s32(dst, vaddq_s32(vld1q_s32(dst), lo));
        vst1q_s32(dst.add(4), vaddq_s32(vld1q_s32(dst.add(4)), hi));
    } else {
        let mut tmp = [0i32; NR];
        vst1q_s32(tmp.as_mut_ptr(), lo);
        vst1q_s32(tmp.as_mut_ptr().add(4), hi);
        for (c, &v) in tmp.iter().enumerate().take(js) {
            *dst.add(c) += v;
        }
    }
}

/// NEON `out[j] += alpha * x[j]` — explicit mul then add (`vmlaq_f32` is
/// avoided: the compiler may contract it to a fused `fmla`, which would
/// break the [`super::FpMode::Pinned`] bitwise contract vs scalar).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn saxpy_neon(alpha: f32, x: &[f32], out: &mut [f32]) {
    let len = out.len().min(x.len());
    let va = vdupq_n_f32(alpha);
    let mut j = 0usize;
    while j + 4 <= len {
        let o = vld1q_f32(out.as_ptr().add(j));
        let v = vld1q_f32(x.as_ptr().add(j));
        vst1q_f32(out.as_mut_ptr().add(j), vaddq_f32(o, vmulq_f32(va, v)));
        j += 4;
    }
    while j < len {
        *out.get_unchecked_mut(j) += alpha * *x.get_unchecked(j);
        j += 1;
    }
}

/// FMA-tier NEON saxpy: one fused `fmla` rounding per element, matching
/// `f32::mul_add` bitwise ([`super::FpMode::Fma`]).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn saxpy_neon_fma(alpha: f32, x: &[f32], out: &mut [f32]) {
    let len = out.len().min(x.len());
    let va = vdupq_n_f32(alpha);
    let mut j = 0usize;
    while j + 4 <= len {
        let o = vld1q_f32(out.as_ptr().add(j));
        let v = vld1q_f32(x.as_ptr().add(j));
        vst1q_f32(out.as_mut_ptr().add(j), vfmaq_f32(o, va, v));
        j += 4;
    }
    while j < len {
        let o = out.get_unchecked_mut(j);
        *o = alpha.mul_add(*x.get_unchecked(j), *o);
        j += 1;
    }
}

/// NEON dot product: 4 lane accumulators (mul + add, no contraction),
/// reduced in the same fixed order as the x86 `hsum128` —
/// `(l0 + l2) + (l1 + l3)` (reassociated vs scalar: 1e-5 contract).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn sdot_neon(a: &[f32], b: &[f32]) -> f32 {
    let len = a.len().min(b.len());
    let mut acc = vdupq_n_f32(0.0);
    let mut j = 0usize;
    while j + 4 <= len {
        let va = vld1q_f32(a.as_ptr().add(j));
        let vb = vld1q_f32(b.as_ptr().add(j));
        acc = vaddq_f32(acc, vmulq_f32(va, vb));
        j += 4;
    }
    let mut sum = hsum_f32x4(acc);
    while j < len {
        sum += *a.get_unchecked(j) * *b.get_unchecked(j);
        j += 1;
    }
    sum
}

/// FMA-tier NEON dot product (fused lane accumulators, same fixed-order
/// reduce).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn sdot_neon_fma(a: &[f32], b: &[f32]) -> f32 {
    let len = a.len().min(b.len());
    let mut acc = vdupq_n_f32(0.0);
    let mut j = 0usize;
    while j + 4 <= len {
        let va = vld1q_f32(a.as_ptr().add(j));
        let vb = vld1q_f32(b.as_ptr().add(j));
        acc = vfmaq_f32(acc, va, vb);
        j += 4;
    }
    let mut sum = hsum_f32x4(acc);
    while j < len {
        sum = a.get_unchecked(j).mul_add(*b.get_unchecked(j), sum);
        j += 1;
    }
    sum
}

/// Horizontal sum of 4 fp32 lanes in the fixed `(l0 + l2) + (l1 + l3)`
/// order (matches x86 `hsum128`, keeping sdot results identical across
/// vector levels at equal lane width).
#[target_feature(enable = "neon")]
unsafe fn hsum_f32x4(v: float32x4_t) -> f32 {
    let l0 = vgetq_lane_f32(v, 0);
    let l1 = vgetq_lane_f32(v, 1);
    let l2 = vgetq_lane_f32(v, 2);
    let l3 = vgetq_lane_f32(v, 3);
    (l0 + l2) + (l1 + l3)
}
