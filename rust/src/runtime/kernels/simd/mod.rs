//! Runtime-dispatched SIMD layer for the GEMM microkernels.
//!
//! One [`SimdLevel`] is detected per process (cached) and copied into every
//! [`super::Workspace`] at construction, so the hot loops pay a single
//! `match` per tile / per row instead of re-detecting features. Three
//! levels exist:
//!
//! * [`SimdLevel::Avx2`] — 256-bit x86_64 path: the quantized microkernel
//!   widens interleaved i8 weight panels to i16 (`vpmovsxbw`) and runs
//!   pair-wise multiply-accumulate into eight i32 lanes (`vpmaddwd`); the
//!   fp32 kernels are 8-lane mul/add.
//! * [`SimdLevel::Sse2`] — 128-bit x86_64 fallback (SSE2 is part of the
//!   x86_64 baseline, so this level is always available there): the same
//!   panel layout processed in two 4-column halves (`pmaddwd`), fp32 in
//!   4 lanes.
//! * [`SimdLevel::Scalar`] — portable Rust, bit-for-bit the reference the
//!   other levels are tested against. Always available; pinned by
//!   `LSQNET_FORCE_SCALAR=1` (the CI cross-check) or
//!   [`super::Workspace::force_scalar`] (the in-process parity tests).
//!
//! Determinism across levels (DESIGN.md §SIMD-dispatch): the quantized
//! kernel accumulates in `i32`, where addition is exact and associative, so
//! `qgemm` is **bitwise identical** at every level. The fp32 `saxpy` used
//! by `sgemm`/`sgemm_tn` performs the same per-element mul+add (no FMA, no
//! reassociation) and stays bitwise too; only [`SimdLevel::sdot`]
//! (`sgemm_nt`'s inner product) reassociates the sum across lanes and is
//! held to the kernel layer's 1e-5 fp32 tolerance instead.

mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::OnceLock;

use super::gemm::NR;

/// Instruction-set level the kernel layer dispatches to, resolved once per
/// process by [`SimdLevel::detect`] and stored per-[`super::Workspace`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable Rust reference path (always available, any architecture).
    Scalar,
    /// x86_64 128-bit path (baseline on x86_64 — never absent there).
    Sse2,
    /// x86_64 256-bit path (`is_x86_feature_detected!("avx2")`).
    Avx2,
}

/// `LSQNET_FORCE_SCALAR=1` pins the portable path process-wide (read once).
fn env_force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| crate::util::env_truthy("LSQNET_FORCE_SCALAR"))
}

impl SimdLevel {
    /// The best level this host supports, honoring the
    /// `LSQNET_FORCE_SCALAR` pin. Feature detection runs once per process;
    /// the result is cached.
    pub fn detect() -> SimdLevel {
        static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
        *LEVEL.get_or_init(|| {
            if env_force_scalar() {
                return SimdLevel::Scalar;
            }
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2") {
                    SimdLevel::Avx2
                } else {
                    SimdLevel::Sse2
                }
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                SimdLevel::Scalar
            }
        })
    }

    /// Short name for logs and the bench-trajectory JSON.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
        }
    }

    /// One (KC×NC) tile of the quantized GEMM for `mb` activation rows:
    /// `acc[i*n + n0 + j] += Σ_kk x[i][kk] · w[kk][n0+j]` with the weights
    /// in the interleaved i8 panel layout ([`super::panel`]) and the
    /// activations pre-packed into i16 pairs (`xp`, `mb × pairs` entries).
    ///
    /// All levels produce bitwise-identical `acc` (exact i32 sums).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn qgemm_tile(
        self,
        panel: &[i8],
        xp: &[i32],
        mb: usize,
        pairs: usize,
        nc: usize,
        n: usize,
        n0: usize,
        acc: &mut [i32],
    ) {
        if mb == 0 || nc == 0 {
            return;
        }
        // Bounds the unsafe SIMD paths rely on (checked here once per tile
        // so the inner loops can use raw loads/stores).
        let nblocks = (nc + NR - 1) / NR;
        assert!(panel.len() >= nblocks * pairs * 2 * NR, "panel tile too small");
        assert!(xp.len() >= mb * pairs, "xpairs buffer too small");
        assert!(acc.len() >= (mb - 1) * n + n0 + nc, "accumulator too small");
        assert!(n0 + nc <= n, "tile exceeds row width");
        match self {
            SimdLevel::Scalar => scalar::qgemm_tile(panel, xp, mb, pairs, nc, n, n0, acc),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Sse2 => unsafe {
                x86::qgemm_tile_sse2(panel, xp, mb, pairs, nc, n, n0, acc)
            },
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => unsafe {
                x86::qgemm_tile_avx2(panel, xp, mb, pairs, nc, n, n0, acc)
            },
            #[cfg(not(target_arch = "x86_64"))]
            _ => scalar::qgemm_tile(panel, xp, mb, pairs, nc, n, n0, acc),
        }
    }

    /// `out[j] += alpha * x[j]` over `min(out.len(), x.len())` elements.
    /// Per-element mul+add in every level (no FMA contraction), so the
    /// result is bitwise identical to the scalar loop.
    pub(crate) fn saxpy(self, alpha: f32, x: &[f32], out: &mut [f32]) {
        match self {
            SimdLevel::Scalar => scalar::saxpy(alpha, x, out),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Sse2 => unsafe { x86::saxpy_sse2(alpha, x, out) },
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => unsafe { x86::saxpy_avx2(alpha, x, out) },
            #[cfg(not(target_arch = "x86_64"))]
            _ => scalar::saxpy(alpha, x, out),
        }
    }

    /// Dot product over `min(a.len(), b.len())` elements. The SIMD levels
    /// accumulate in lanes and reduce at the end, which *reassociates* the
    /// fp32 sum — results agree with scalar to the kernel layer's 1e-5
    /// tolerance, not bitwise (DESIGN.md §SIMD-dispatch).
    pub(crate) fn sdot(self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            SimdLevel::Scalar => scalar::sdot(a, b),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Sse2 => unsafe { x86::sdot_sse2(a, b) },
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => unsafe { x86::sdot_avx2(a, b) },
            #[cfg(not(target_arch = "x86_64"))]
            _ => scalar::sdot(a, b),
        }
    }
}

/// Pack one activation row into the i16-pair stream [`SimdLevel::qgemm_tile`]
/// consumes: entry `t` holds `(x[2t] as i16, x[2t+1] as i16)` in the low and
/// high halves of an `i32` (a trailing odd element pairs with zero).
///
/// Values must fit i16 — guaranteed for Eq. 1 activations at ≤ 8 bits
/// (|v̄| ≤ 255), and a **hard** assert here because silently truncating
/// would void `qgemm`'s exactness contract for out-of-contract callers
/// (the check is O(m·k) next to O(m·k·n) dot work).
pub(crate) fn pack_xpairs(x: &[i32], out: &mut [i32]) {
    let pairs = (x.len() + 1) / 2;
    debug_assert!(out.len() >= pairs);
    for (t, o) in out.iter_mut().enumerate().take(pairs) {
        let x0 = x[2 * t];
        let x1 = if 2 * t + 1 < x.len() { x[2 * t + 1] } else { 0 };
        assert!(
            (i16::MIN as i32..=i16::MAX as i32).contains(&x0)
                && (i16::MIN as i32..=i16::MAX as i32).contains(&x1),
            "qgemm activation {} out of the i16 range the SIMD panel kernels require",
            if (i16::MIN as i32..=i16::MAX as i32).contains(&x0) { x1 } else { x0 },
        );
        *o = ((x0 as i16 as u16 as u32) | ((x1 as i16 as u16 as u32) << 16)) as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_is_stable_and_named() {
        let a = SimdLevel::detect();
        let b = SimdLevel::detect();
        assert_eq!(a, b);
        assert!(["scalar", "sse2", "avx2"].contains(&a.name()));
    }

    #[test]
    fn pack_xpairs_round_trips_signed_halves() {
        let x = vec![-3i32, 255, 0, -128, 7];
        let mut out = vec![0i32; 3];
        pack_xpairs(&x, &mut out);
        for (t, &pair) in out.iter().enumerate() {
            let x0 = pair as i16 as i32;
            let x1 = (pair >> 16) as i32;
            assert_eq!(x0, x[2 * t]);
            assert_eq!(x1, if 2 * t + 1 < x.len() { x[2 * t + 1] } else { 0 });
        }
    }

    /// Every available level must agree bitwise with scalar on the
    /// quantized tile kernel, including ragged column blocks and odd k.
    #[test]
    fn qgemm_tile_levels_match_scalar_bitwise() {
        let mut rng = crate::util::rng::Pcg32::seeded(77);
        for &(mb, kc, nc) in &[(1usize, 1usize, 1usize), (3, 7, 11), (4, 16, 8), (2, 5, 19)] {
            let pairs = (kc + 1) / 2;
            let nblocks = (nc + NR - 1) / NR;
            // Random panel (pad rows already zeroed by construction here).
            let mut panel = vec![0i8; nblocks * pairs * 2 * NR];
            for jb in 0..nblocks {
                for t in 0..pairs {
                    for c in 0..NR {
                        let j = jb * NR + c;
                        for r in 0..2usize {
                            let kk = 2 * t + r;
                            if j < nc && kk < kc {
                                panel[jb * pairs * 2 * NR + t * 2 * NR + 2 * c + r] =
                                    (rng.below(31) as i32 - 15) as i8;
                            }
                        }
                    }
                }
            }
            let x: Vec<i32> = (0..mb * kc).map(|_| rng.below(16) as i32 - 4).collect();
            let mut xp = vec![0i32; mb * pairs];
            for i in 0..mb {
                pack_xpairs(&x[i * kc..(i + 1) * kc], &mut xp[i * pairs..(i + 1) * pairs]);
            }
            let n = nc + 3; // embed the tile at n0=2 in a wider row
            let n0 = 2usize;
            let mut base = vec![0i32; mb * n];
            SimdLevel::Scalar.qgemm_tile(&panel, &xp, mb, pairs, nc, n, n0, &mut base);
            // Scalar reference from first principles.
            for i in 0..mb {
                for j in 0..nc {
                    let mut want = 0i64;
                    for kk in 0..kc {
                        let jb = j / NR;
                        let idx = jb * pairs * 2 * NR + (kk / 2) * 2 * NR + 2 * (j % NR) + kk % 2;
                        want += x[i * kc + kk] as i64 * panel[idx] as i64;
                    }
                    assert_eq!(base[i * n + n0 + j] as i64, want, "scalar ({i},{j})");
                }
            }
            for level in [SimdLevel::Sse2, SimdLevel::Avx2] {
                if !level_available(level) {
                    continue;
                }
                let mut got = vec![0i32; mb * n];
                level.qgemm_tile(&panel, &xp, mb, pairs, nc, n, n0, &mut got);
                assert_eq!(base, got, "{} vs scalar (mb={mb} kc={kc} nc={nc})", level.name());
            }
        }
    }

    #[test]
    fn fp32_kernels_match_scalar() {
        let mut rng = crate::util::rng::Pcg32::seeded(78);
        for len in [1usize, 4, 8, 13, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let mut out_s = b.clone();
            SimdLevel::Scalar.saxpy(0.37, &a, &mut out_s);
            let dot_s = SimdLevel::Scalar.sdot(&a, &b);
            for level in [SimdLevel::Sse2, SimdLevel::Avx2] {
                if !level_available(level) {
                    continue;
                }
                let mut out = b.clone();
                level.saxpy(0.37, &a, &mut out);
                // saxpy is elementwise: bitwise equal.
                for (p, q) in out_s.iter().zip(&out) {
                    assert_eq!(p.to_bits(), q.to_bits(), "saxpy {} len={len}", level.name());
                }
                // sdot reassociates: tolerance only.
                let dot = level.sdot(&a, &b);
                assert!(
                    (dot - dot_s).abs() <= 1e-5 * dot_s.abs().max(1.0),
                    "sdot {} len={len}: {dot} vs {dot_s}",
                    level.name()
                );
            }
        }
    }

    fn level_available(level: SimdLevel) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            match level {
                SimdLevel::Scalar | SimdLevel::Sse2 => true,
                SimdLevel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            level == SimdLevel::Scalar
        }
    }
}
