//! Runtime-dispatched SIMD layer for the GEMM microkernels.
//!
//! One [`SimdLevel`] is detected per process (cached) and copied into every
//! [`super::Workspace`] at construction, so the hot loops pay a single
//! `match` per tile / per row instead of re-detecting features. Five
//! levels exist:
//!
//! * [`SimdLevel::Avx512Vnni`] — 512-bit x86_64 path: `vpdpwssd` fuses the
//!   AVX2 rung's multiply-add-accumulate triple into one instruction over
//!   sixteen i32 lanes (a 256-bit VL variant serves 8-wide panel
//!   geometries). Requires avx512f+avx512bw+avx512vl+avx512vnni.
//! * [`SimdLevel::Avx2`] — 256-bit x86_64 path: the quantized microkernel
//!   widens interleaved i8 weight panels to i16 (`vpmovsxbw`) and runs
//!   pair-wise multiply-accumulate into eight i32 lanes (`vpmaddwd`); the
//!   fp32 kernels are 8-lane mul/add.
//! * [`SimdLevel::Sse2`] — 128-bit x86_64 fallback (SSE2 is part of the
//!   x86_64 baseline, so this level is always available there): the same
//!   panel layout processed in two 4-column halves (`pmaddwd`), fp32 in
//!   4 lanes.
//! * [`SimdLevel::Neon`] — aarch64 128-bit path (`simd/aarch64.rs`):
//!   `smull`/`addp` pair kernel plus an sdot-shaped `ki=4` quad kernel,
//!   fp32 in 4 lanes. Baseline Armv8.0 NEON only.
//! * [`SimdLevel::Scalar`] — portable Rust, bit-for-bit the reference the
//!   other levels are tested against, and geometry-generic: it executes
//!   any valid [`PanelGeom`], so unsupported (level, geometry) pairs fall
//!   back here and stay correct by construction. Always available;
//!   pinned by `LSQNET_SIMD=scalar` / `LSQNET_FORCE_SCALAR=1` (the CI
//!   cross-checks) or [`super::Workspace::force_scalar`] (the in-process
//!   parity tests).
//!
//! `LSQNET_SIMD=<name>` pins any *available* level process-wide (an
//! unavailable name falls through to the best detected level — CI can run
//! the same matrix on any host); `LSQNET_FORCE_SCALAR=1` is the legacy
//! alias for `LSQNET_SIMD=scalar` and wins when both are set.
//!
//! Determinism across levels (DESIGN.md §SIMD-dispatch): the quantized
//! kernel accumulates in `i32`, where addition is exact and associative, so
//! `qgemm` is **bitwise identical** at every level *and every panel
//! geometry*. The fp32 `saxpy` used by `sgemm`/`sgemm_tn` performs the
//! same per-element mul+add and stays bitwise too; only [`SimdLevel::sdot`]
//! (`sgemm_nt`'s inner product) reassociates the sum across lanes and is
//! held to the kernel layer's 1e-5 fp32 tolerance instead. The same
//! split holds inside the [`FpMode::Fma`] tier: saxpy is one fused
//! rounding per element at every level (`f32::mul_add` scalar, `vfmadd`
//! vector), sdot reassociates. *Across* the two FpModes results differ
//! (that is the point — one rounding vs two), which is why
//! [`FpMode::Pinned`] remains the default and the test reference.

mod scalar;
#[cfg(target_arch = "aarch64")]
#[path = "aarch64.rs"]
mod arm;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::OnceLock;

use super::gemm::NR;
use super::panel::PanelGeom;

/// Instruction-set level the kernel layer dispatches to, resolved once per
/// process by [`SimdLevel::detect`] and stored per-[`super::Workspace`].
///
/// Every variant exists on every architecture (so level names, env pins,
/// and the autotuner cache key are portable); [`SimdLevel::available`]
/// says whether this host can actually execute one. Dispatching an
/// unavailable level is safe — the quantized kernel falls back to the
/// geometry-generic scalar path — but the constructors never produce one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SimdLevel {
    /// Portable Rust reference path (always available, any architecture).
    Scalar,
    /// x86_64 128-bit path (baseline on x86_64 — never absent there).
    Sse2,
    /// x86_64 256-bit path (`is_x86_feature_detected!("avx2")`).
    Avx2,
    /// x86_64 AVX-512 VNNI path (avx512f+bw+vl+vnni all detected).
    Avx512Vnni,
    /// aarch64 NEON path (baseline Armv8.0 vector unit).
    Neon,
}

/// `LSQNET_FORCE_SCALAR=1` pins the portable path process-wide (read once;
/// legacy alias of `LSQNET_SIMD=scalar`, takes precedence over it).
fn env_force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| crate::util::env_truthy("LSQNET_FORCE_SCALAR"))
}

/// Host FMA support for the fp32 [`FpMode::Fma`] tier, detected once.
/// (Distinct from the level ladder: x86 `fma` is a separate CPUID bit
/// from avx2; every aarch64 NEON host has fused `fmla`.)
pub(crate) fn fma_available() -> bool {
    static FMA: OnceLock<bool> = OnceLock::new();
    *FMA.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            std::arch::is_x86_feature_detected!("fma")
        }
        #[cfg(target_arch = "aarch64")]
        {
            true
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            false
        }
    })
}

impl SimdLevel {
    /// All levels, worst to best (the order `available_levels` and the
    /// `simd-levels` CLI listing use).
    pub const ALL: [SimdLevel; 5] = [
        SimdLevel::Scalar,
        SimdLevel::Sse2,
        SimdLevel::Avx2,
        SimdLevel::Avx512Vnni,
        SimdLevel::Neon,
    ];

    /// The level this process dispatches to: the best available, unless
    /// `LSQNET_FORCE_SCALAR=1` (legacy pin) or `LSQNET_SIMD=<name>` (any
    /// available level by name; unavailable names fall through to the
    /// best) overrides. Feature detection runs once; the result is cached.
    pub fn detect() -> SimdLevel {
        static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
        *LEVEL.get_or_init(|| {
            if env_force_scalar() {
                return SimdLevel::Scalar;
            }
            if let Ok(name) = std::env::var("LSQNET_SIMD") {
                if let Some(level) = SimdLevel::parse(name.trim()) {
                    if level.available() {
                        return level;
                    }
                }
            }
            SimdLevel::best_available()
        })
    }

    /// The widest level this host supports (ignores env pins).
    pub fn best_available() -> SimdLevel {
        SimdLevel::ALL
            .into_iter()
            .rev()
            .find(|l| l.available())
            .unwrap_or(SimdLevel::Scalar)
    }

    /// `true` iff this host can execute this level's kernels.
    pub fn available(self) -> bool {
        match self {
            SimdLevel::Scalar => true,
            SimdLevel::Sse2 => cfg!(target_arch = "x86_64"),
            SimdLevel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            SimdLevel::Avx512Vnni => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                        && std::arch::is_x86_feature_detected!("avx512bw")
                        && std::arch::is_x86_feature_detected!("avx512vl")
                        && std::arch::is_x86_feature_detected!("avx512vnni")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            SimdLevel::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }

    /// The levels this host can execute, worst to best (always contains
    /// [`SimdLevel::Scalar`]). Drives the CI forced-level matrix via the
    /// `simd-levels` CLI subcommand.
    pub fn available_levels() -> Vec<SimdLevel> {
        SimdLevel::ALL.into_iter().filter(|l| l.available()).collect()
    }

    /// Short name for logs, the bench-trajectory JSON, and the
    /// `LSQNET_SIMD` pin.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512Vnni => "avx512vnni",
            SimdLevel::Neon => "neon",
        }
    }

    /// Inverse of [`SimdLevel::name`] (case-insensitive).
    pub fn parse(name: &str) -> Option<SimdLevel> {
        let lower = name.to_ascii_lowercase();
        SimdLevel::ALL.into_iter().find(|l| l.name() == lower)
    }

    /// One (kc×nc) tile of the quantized GEMM for `mb` activation rows:
    /// `acc[i*n + n0 + j] += Σ_kk x[i][kk] · w[kk][n0+j]` with the weights
    /// in the interleaved i8 panel layout ([`super::panel`]) at geometry
    /// `geom` and the activations pre-packed into k-groups (`xg`,
    /// `mb × groups` entries — [`pack_xgroups`]).
    ///
    /// All levels and all geometries produce bitwise-identical `acc`
    /// (exact i32 sums). (level, geometry) pairs without a dedicated
    /// vector kernel run the geometry-generic scalar path.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn qgemm_tile(
        self,
        panel: &[i8],
        xg: &[i32],
        mb: usize,
        groups: usize,
        nc: usize,
        n: usize,
        n0: usize,
        geom: PanelGeom,
        acc: &mut [i32],
    ) {
        if mb == 0 || nc == 0 {
            return;
        }
        // Bounds the unsafe SIMD paths rely on (checked here once per tile
        // so the inner loops can use raw loads/stores).
        assert!(geom.valid(), "invalid panel geometry {geom:?}");
        let nblocks = nc.div_ceil(geom.nr);
        assert!(panel.len() >= nblocks * groups * geom.ki * geom.nr, "panel tile too small");
        assert!(xg.len() >= mb * groups, "xgroups buffer too small");
        assert!(acc.len() >= (mb - 1) * n + n0 + nc, "accumulator too small");
        assert!(n0 + nc <= n, "tile exceeds row width");
        match (self, geom.nr, geom.ki) {
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Sse2, NR, 2) => unsafe {
                x86::qgemm_tile_sse2(panel, xg, mb, groups, nc, n, n0, acc)
            },
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Avx2, NR, 2) => unsafe {
                x86::qgemm_tile_avx2(panel, xg, mb, groups, nc, n, n0, acc)
            },
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Avx512Vnni, 16, 2) => unsafe {
                x86::qgemm_tile_vnni512(panel, xg, mb, groups, nc, n, n0, acc)
            },
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Avx512Vnni, NR, 2) => unsafe {
                x86::qgemm_tile_vnni256(panel, xg, mb, groups, nc, n, n0, acc)
            },
            #[cfg(target_arch = "aarch64")]
            (SimdLevel::Neon, NR, 2) => unsafe {
                arm::qgemm_tile_neon_pair(panel, xg, mb, groups, nc, n, n0, acc)
            },
            #[cfg(target_arch = "aarch64")]
            (SimdLevel::Neon, NR, 4) => unsafe {
                arm::qgemm_tile_neon_quad(panel, xg, mb, groups, nc, n, n0, acc)
            },
            // Scalar level, plus any (level, geometry) pair with no
            // dedicated kernel: the geometry-generic reference path.
            _ => scalar::qgemm_tile(panel, xg, mb, groups, nc, n, n0, geom, acc),
        }
    }

    /// `out[j] += alpha * x[j]` over `min(out.len(), x.len())` elements.
    /// Elementwise at every level, so the result is bitwise identical to
    /// the same-`fp` scalar loop: [`FpMode::Pinned`] is mul then add (two
    /// roundings), [`FpMode::Fma`] one fused rounding (`f32::mul_add` /
    /// `vfmadd`/`fmla` — requires [`fma_available`], which the dispatcher
    /// re-checks and otherwise falls back to the scalar `mul_add` loop,
    /// preserving Fma semantics bitwise).
    pub(crate) fn saxpy(self, fp: FpMode, alpha: f32, x: &[f32], out: &mut [f32]) {
        match (self, fp) {
            (SimdLevel::Scalar, FpMode::Pinned) => scalar::saxpy(alpha, x, out),
            (SimdLevel::Scalar, FpMode::Fma) => scalar::saxpy_fma(alpha, x, out),
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Sse2, FpMode::Pinned) => unsafe { x86::saxpy_sse2(alpha, x, out) },
            // No sse+fma kernel: pre-AVX2 FMA hosts are a museum piece,
            // and the scalar mul_add loop is bitwise-identical anyway.
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Sse2, FpMode::Fma) => scalar::saxpy_fma(alpha, x, out),
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Avx2 | SimdLevel::Avx512Vnni, FpMode::Pinned) => unsafe {
                x86::saxpy_avx2(alpha, x, out)
            },
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Avx2 | SimdLevel::Avx512Vnni, FpMode::Fma) => {
                if fma_available() {
                    unsafe { x86::saxpy_fma256(alpha, x, out) }
                } else {
                    scalar::saxpy_fma(alpha, x, out)
                }
            }
            #[cfg(target_arch = "aarch64")]
            (SimdLevel::Neon, FpMode::Pinned) => unsafe { arm::saxpy_neon(alpha, x, out) },
            #[cfg(target_arch = "aarch64")]
            (SimdLevel::Neon, FpMode::Fma) => unsafe { arm::saxpy_neon_fma(alpha, x, out) },
            (_, FpMode::Pinned) => scalar::saxpy(alpha, x, out),
            (_, FpMode::Fma) => scalar::saxpy_fma(alpha, x, out),
        }
    }

    /// Dot product over `min(a.len(), b.len())` elements. The SIMD levels
    /// accumulate in lanes and reduce at the end, which *reassociates* the
    /// fp32 sum — results agree with scalar to the kernel layer's 1e-5
    /// tolerance, not bitwise, in both [`FpMode`]s (DESIGN.md
    /// §SIMD-dispatch).
    pub(crate) fn sdot(self, fp: FpMode, a: &[f32], b: &[f32]) -> f32 {
        match (self, fp) {
            (SimdLevel::Scalar, FpMode::Pinned) => scalar::sdot(a, b),
            (SimdLevel::Scalar, FpMode::Fma) => scalar::sdot_fma(a, b),
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Sse2, FpMode::Pinned) => unsafe { x86::sdot_sse2(a, b) },
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Sse2, FpMode::Fma) => scalar::sdot_fma(a, b),
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Avx2 | SimdLevel::Avx512Vnni, FpMode::Pinned) => unsafe {
                x86::sdot_avx2(a, b)
            },
            #[cfg(target_arch = "x86_64")]
            (SimdLevel::Avx2 | SimdLevel::Avx512Vnni, FpMode::Fma) => {
                if fma_available() {
                    unsafe { x86::sdot_fma256(a, b) }
                } else {
                    scalar::sdot_fma(a, b)
                }
            }
            #[cfg(target_arch = "aarch64")]
            (SimdLevel::Neon, FpMode::Pinned) => unsafe { arm::sdot_neon(a, b) },
            #[cfg(target_arch = "aarch64")]
            (SimdLevel::Neon, FpMode::Fma) => unsafe { arm::sdot_neon_fma(a, b) },
            (_, FpMode::Pinned) => scalar::sdot(a, b),
            (_, FpMode::Fma) => scalar::sdot_fma(a, b),
        }
    }
}

/// Floating-point contraction mode for the fp32 training GEMMs
/// (`sgemm`/`sgemm_nt`/`sgemm_tn`), stored per-[`super::Workspace`].
///
/// [`FpMode::Pinned`] (default) keeps the historical two-roundings
/// mul+add semantics — the bitwise reference every test pins.
/// [`FpMode::Fma`] contracts to one fused rounding per element, the perf
/// tier for training throughput; enabled per-workspace
/// ([`super::Workspace::set_fp_mode`]) or process-wide with
/// `LSQNET_FMA=1` (ignored when the host lacks FMA units). The two modes
/// differ in low-order bits by design; CI cross-checks them against each
/// other at the kernel layer's fp32 tolerance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum FpMode {
    /// Separate mul and add roundings — the deterministic test reference.
    #[default]
    Pinned,
    /// One fused multiply-add rounding per element.
    Fma,
}

impl FpMode {
    /// The process-default mode: `LSQNET_FMA=1` when the host has FMA
    /// units, else [`FpMode::Pinned`] (read once).
    pub fn default_mode() -> FpMode {
        static MODE: OnceLock<FpMode> = OnceLock::new();
        *MODE.get_or_init(|| {
            if crate::util::env_truthy("LSQNET_FMA") && fma_available() {
                FpMode::Fma
            } else {
                FpMode::Pinned
            }
        })
    }

    /// Short name for logs and bench metadata.
    pub fn name(self) -> &'static str {
        match self {
            FpMode::Pinned => "pinned",
            FpMode::Fma => "fma",
        }
    }
}

/// Pack one activation row into the k-group stream
/// [`SimdLevel::qgemm_tile`] consumes for interleave depth `ki` —
/// [`pack_xpairs`] for `ki=2`, [`pack_xquads`] for `ki=4`.
pub(crate) fn pack_xgroups(x: &[i32], ki: usize, out: &mut [i32]) {
    match ki {
        2 => pack_xpairs(x, out),
        4 => pack_xquads(x, out),
        _ => unreachable!("unsupported k-interleave {ki}"),
    }
}

/// Pack one activation row into the i16-pair stream the `ki=2` kernels
/// consume: entry `t` holds `(x[2t] as i16, x[2t+1] as i16)` in the low and
/// high halves of an `i32` (a trailing partial group pads with zero).
///
/// Values must fit i16 — guaranteed for Eq. 1 activations at ≤ 8 bits
/// (|v̄| ≤ 255), and a **hard** assert here because silently truncating
/// would void `qgemm`'s exactness contract for out-of-contract callers
/// (the check is O(m·k) next to O(m·k·n) dot work).
pub(crate) fn pack_xpairs(x: &[i32], out: &mut [i32]) {
    let pairs = x.len().div_ceil(2);
    debug_assert!(out.len() >= pairs);
    for (t, o) in out.iter_mut().enumerate().take(pairs) {
        let x0 = x[2 * t];
        let x1 = if 2 * t + 1 < x.len() { x[2 * t + 1] } else { 0 };
        assert!(
            (i16::MIN as i32..=i16::MAX as i32).contains(&x0)
                && (i16::MIN as i32..=i16::MAX as i32).contains(&x1),
            "qgemm activation {} out of the i16 range the SIMD panel kernels require",
            if (i16::MIN as i32..=i16::MAX as i32).contains(&x0) { x1 } else { x0 },
        );
        *o = ((x0 as i16 as u16 as u32) | ((x1 as i16 as u16 as u32) << 16)) as i32;
    }
}

/// Pack one activation row into the 4×i8 stream the `ki=4` kernels
/// consume: entry `t` holds `x[4t..4t+4]` as four little-endian i8 bytes
/// (trailing partial group pads with zero).
///
/// Values must fit **i8** — which is why `ki=4` geometries are only
/// offered by the autotuner when the layer's activation range does
/// (`act_max ≤ 127`); hard assert for the same exactness reason as
/// [`pack_xpairs`].
pub(crate) fn pack_xquads(x: &[i32], out: &mut [i32]) {
    let quads = x.len().div_ceil(4);
    debug_assert!(out.len() >= quads);
    for (t, o) in out.iter_mut().enumerate().take(quads) {
        let mut bytes = [0u8; 4];
        for (r, b) in bytes.iter_mut().enumerate() {
            let v = if 4 * t + r < x.len() { x[4 * t + r] } else { 0 };
            assert!(
                (i8::MIN as i32..=i8::MAX as i32).contains(&v),
                "qgemm activation {v} out of the i8 range the ki=4 panel kernels require",
            );
            *b = v as i8 as u8;
        }
        *o = u32::from_le_bytes(bytes) as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_is_stable_and_named() {
        let a = SimdLevel::detect();
        let b = SimdLevel::detect();
        assert_eq!(a, b);
        assert!(a.available());
        assert!(SimdLevel::ALL.map(SimdLevel::name).contains(&a.name()));
    }

    #[test]
    fn parse_round_trips_every_level() {
        for level in SimdLevel::ALL {
            assert_eq!(SimdLevel::parse(level.name()), Some(level));
            assert_eq!(SimdLevel::parse(&level.name().to_ascii_uppercase()), Some(level));
        }
        assert_eq!(SimdLevel::parse("avx9000"), None);
        // available_levels always offers the portable path and only
        // executable levels.
        let avail = SimdLevel::available_levels();
        assert!(avail.contains(&SimdLevel::Scalar));
        assert!(avail.iter().all(|l| l.available()));
        assert!(avail.contains(&SimdLevel::best_available()));
    }

    #[test]
    fn pack_xpairs_round_trips_signed_halves() {
        let x = vec![-3i32, 255, 0, -128, 7];
        let mut out = vec![0i32; 3];
        pack_xpairs(&x, &mut out);
        for (t, &pair) in out.iter().enumerate() {
            let x0 = pair as i16 as i32;
            let x1 = (pair >> 16) as i32;
            assert_eq!(x0, x[2 * t]);
            assert_eq!(x1, if 2 * t + 1 < x.len() { x[2 * t + 1] } else { 0 });
        }
    }

    #[test]
    fn pack_xquads_round_trips_signed_bytes() {
        let x = vec![-3i32, 127, 0, -128, 7];
        let mut out = vec![0i32; 2];
        pack_xquads(&x, &mut out);
        for (t, &quad) in out.iter().enumerate() {
            for (r, &b) in (quad as u32).to_le_bytes().iter().enumerate() {
                let want = if 4 * t + r < x.len() { x[4 * t + r] } else { 0 };
                assert_eq!(b as i8 as i32, want, "t={t} r={r}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "i8 range")]
    fn pack_xquads_rejects_wide_activations() {
        pack_xquads(&[200], &mut [0i32; 1]);
    }

    /// Every level (available or not — unsupported combos fall back to the
    /// geometry-generic scalar path) must agree bitwise with a
    /// first-principles dot product, at every kernel geometry.
    #[test]
    fn qgemm_tile_levels_match_scalar_bitwise() {
        let mut rng = crate::util::rng::Pcg32::seeded(77);
        let geoms = [
            PanelGeom::DEFAULT,
            PanelGeom { kc: 256, nc: 64, nr: 16, ki: 2 },
            PanelGeom { kc: 256, nc: 64, nr: 8, ki: 4 },
        ];
        for geom in geoms {
            let (nr, ki) = (geom.nr, geom.ki);
            for &(mb, kc, nc) in
                &[(1usize, 1usize, 1usize), (3, 7, 11), (4, 16, 8), (2, 5, 19), (2, 9, 33)]
            {
                let groups = kc.div_ceil(ki);
                let nblocks = nc.div_ceil(nr);
                let block_len = groups * ki * nr;
                // Random panel (pad positions stay zero by construction).
                let mut panel = vec![0i8; nblocks * block_len];
                for jb in 0..nblocks {
                    for t in 0..groups {
                        for c in 0..nr {
                            for r in 0..ki {
                                let (j, kk) = (jb * nr + c, ki * t + r);
                                if j < nc && kk < kc {
                                    panel[jb * block_len + t * ki * nr + c * ki + r] =
                                        (rng.below(31) as i32 - 15) as i8;
                                }
                            }
                        }
                    }
                }
                let xmax: u32 = if ki == 4 { 127 } else { 255 };
                let x: Vec<i32> =
                    (0..mb * kc).map(|_| rng.below(xmax + 5) as i32 - 4).collect();
                let mut xg = vec![0i32; mb * groups];
                for i in 0..mb {
                    pack_xgroups(&x[i * kc..(i + 1) * kc], ki, &mut xg[i * groups..]);
                }
                let n = nc + 3; // embed the tile at n0=2 in a wider row
                let n0 = 2usize;
                let mut base = vec![0i32; mb * n];
                SimdLevel::Scalar.qgemm_tile(&panel, &xg, mb, groups, nc, n, n0, geom, &mut base);
                // Scalar reference from first principles.
                for i in 0..mb {
                    for j in 0..nc {
                        let mut want = 0i64;
                        for kk in 0..kc {
                            let idx = (j / nr) * block_len + (kk / ki) * ki * nr + (j % nr) * ki
                                + kk % ki;
                            want += x[i * kc + kk] as i64 * panel[idx] as i64;
                        }
                        assert_eq!(
                            base[i * n + n0 + j] as i64,
                            want,
                            "scalar ({i},{j}) {geom:?}"
                        );
                    }
                }
                for level in SimdLevel::ALL {
                    if !level.available() {
                        continue;
                    }
                    let mut got = vec![0i32; mb * n];
                    level.qgemm_tile(&panel, &xg, mb, groups, nc, n, n0, geom, &mut got);
                    assert_eq!(
                        base,
                        got,
                        "{} vs scalar (mb={mb} kc={kc} nc={nc} {geom:?})",
                        level.name()
                    );
                }
            }
        }
    }

    #[test]
    fn fp32_kernels_match_scalar() {
        let mut rng = crate::util::rng::Pcg32::seeded(78);
        for len in [1usize, 4, 8, 13, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            for fp in [FpMode::Pinned, FpMode::Fma] {
                let mut out_s = b.clone();
                SimdLevel::Scalar.saxpy(fp, 0.37, &a, &mut out_s);
                let dot_s = SimdLevel::Scalar.sdot(fp, &a, &b);
                for level in SimdLevel::ALL {
                    if !level.available() {
                        continue;
                    }
                    let mut out = b.clone();
                    level.saxpy(fp, 0.37, &a, &mut out);
                    // saxpy is elementwise: bitwise equal within a mode.
                    for (p, q) in out_s.iter().zip(&out) {
                        assert_eq!(
                            p.to_bits(),
                            q.to_bits(),
                            "saxpy {} {} len={len}",
                            level.name(),
                            fp.name()
                        );
                    }
                    // sdot reassociates: tolerance only.
                    let dot = level.sdot(fp, &a, &b);
                    assert!(
                        (dot - dot_s).abs() <= 1e-5 * dot_s.abs().max(1.0),
                        "sdot {} {} len={len}: {dot} vs {dot_s}",
                        level.name(),
                        fp.name()
                    );
                }
            }
        }
    }

    /// The two FpModes agree to tolerance (they differ in low-order bits
    /// by design: one fused rounding vs two).
    #[test]
    fn fma_mode_matches_pinned_to_tolerance() {
        let mut rng = crate::util::rng::Pcg32::seeded(79);
        let a: Vec<f32> = (0..257).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..257).map(|_| rng.normal()).collect();
        let level = SimdLevel::detect();
        let mut pinned = b.clone();
        level.saxpy(FpMode::Pinned, 1.618, &a, &mut pinned);
        let mut fused = b.clone();
        level.saxpy(FpMode::Fma, 1.618, &a, &mut fused);
        for (p, f) in pinned.iter().zip(&fused) {
            assert!((p - f).abs() <= 1e-5 * p.abs().max(1.0), "{p} vs {f}");
        }
        let dp = level.sdot(FpMode::Pinned, &a, &b);
        let df = level.sdot(FpMode::Fma, &a, &b);
        assert!((dp - df).abs() <= 1e-5 * dp.abs().max(1.0), "{dp} vs {df}");
    }
}
