//! Portable reference implementations of the SIMD microkernels — the
//! always-available [`super::SimdLevel::Scalar`] path, and the semantics
//! every vector path is tested against ([`super::SimdLevel`] documents
//! which kernels must match bitwise and which to 1e-5). The quantized
//! kernel here is geometry-generic: it executes *any* valid
//! [`PanelGeom`], so it doubles as the fallback for (level, geometry)
//! pairs that have no dedicated vector kernel — correctness for every
//! autotuner candidate holds by construction.

use super::super::panel::{PanelGeom, MAX_NR};

/// Quantized tile kernel over the interleaved i8 panel layout (see
/// [`super::super::panel`]): for each activation row and `nr`-column
/// block, accumulate the k-group dot products in i32. `xg` holds one
/// packed activation group per i32 (`ki=2`: two i16 halves; `ki=4`: four
/// i8 bytes, little-endian). The caller
/// ([`super::SimdLevel::qgemm_tile`]) has already bounds-checked every
/// slice.
#[allow(clippy::too_many_arguments)]
pub(crate) fn qgemm_tile(
    panel: &[i8],
    xg: &[i32],
    mb: usize,
    groups: usize,
    nc: usize,
    n: usize,
    n0: usize,
    geom: PanelGeom,
    acc: &mut [i32],
) {
    let (nr, ki) = (geom.nr, geom.ki);
    debug_assert!(nr <= MAX_NR && matches!(ki, 2 | 4));
    let nblocks = nc.div_ceil(nr);
    let block_len = groups * ki * nr;
    for i in 0..mb {
        let xrow = &xg[i * groups..(i + 1) * groups];
        for jb in 0..nblocks {
            let block = &panel[jb * block_len..(jb + 1) * block_len];
            let mut r = [0i32; MAX_NR];
            for (t, &g) in xrow.iter().enumerate() {
                let chunk = &block[t * ki * nr..(t + 1) * ki * nr];
                if ki == 2 {
                    let x0 = g as i16 as i32;
                    let x1 = g >> 16; // arithmetic shift: high i16, sign-extended
                    for (c, rj) in r.iter_mut().enumerate().take(nr) {
                        *rj += x0 * chunk[2 * c] as i32 + x1 * chunk[2 * c + 1] as i32;
                    }
                } else {
                    let xb = (g as u32).to_le_bytes();
                    let x = [
                        xb[0] as i8 as i32,
                        xb[1] as i8 as i32,
                        xb[2] as i8 as i32,
                        xb[3] as i8 as i32,
                    ];
                    for (c, rj) in r.iter_mut().enumerate().take(nr) {
                        let w = &chunk[4 * c..4 * c + 4];
                        *rj += x[0] * w[0] as i32
                            + x[1] * w[1] as i32
                            + x[2] * w[2] as i32
                            + x[3] * w[3] as i32;
                    }
                }
            }
            let js = nr.min(nc - jb * nr);
            let off = i * n + n0 + jb * nr;
            for (a, &rj) in acc[off..off + js].iter_mut().zip(&r[..js]) {
                *a += rj;
            }
        }
    }
}

/// `out[j] += alpha * x[j]`, sequential — one mul rounding and one add
/// rounding per element, the contract every level preserves in
/// [`super::FpMode::Pinned`] mode.
pub(crate) fn saxpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += alpha * v;
    }
}

/// [`super::FpMode::Fma`] variant of [`saxpy`]: one fused
/// multiply-add rounding per element (`f32::mul_add` lowers to a scalar
/// FMA on every target the vector levels run on), matching the vector
/// FMA kernels' per-element semantics bitwise.
pub(crate) fn saxpy_fma(alpha: f32, x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = alpha.mul_add(v, *o);
    }
}

/// Sequential dot product — the serial accumulation order the kernel
/// layer's pre-SIMD `sgemm_nt` used.
pub(crate) fn sdot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// [`super::FpMode::Fma`] variant of [`sdot`]: sequential fused
/// multiply-adds. Serial order differs from the vector FMA kernels'
/// 8-lane reassociation, so `sgemm_nt` stays a tolerance (not bitwise)
/// comparison across levels — same story as the Pinned tier.
pub(crate) fn sdot_fma(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc = x.mul_add(y, acc);
    }
    acc
}
