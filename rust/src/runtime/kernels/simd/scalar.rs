//! Portable reference implementations of the SIMD microkernels — the
//! always-available [`super::SimdLevel::Scalar`] path, and the semantics
//! the x86 paths are tested against ([`super::SimdLevel`] documents which
//! kernels must match bitwise and which to 1e-5).

use super::super::gemm::NR;

/// Quantized tile kernel over the interleaved i8 panel layout (see
/// [`super::super::panel`]): for each activation row and NR-column block,
/// accumulate the i16-pair dot products in i32. The caller
/// ([`super::SimdLevel::qgemm_tile`]) has already bounds-checked every
/// slice.
#[allow(clippy::too_many_arguments)]
pub(crate) fn qgemm_tile(
    panel: &[i8],
    xp: &[i32],
    mb: usize,
    pairs: usize,
    nc: usize,
    n: usize,
    n0: usize,
    acc: &mut [i32],
) {
    let nblocks = (nc + NR - 1) / NR;
    let block_len = pairs * 2 * NR;
    for i in 0..mb {
        let xrow = &xp[i * pairs..(i + 1) * pairs];
        for jb in 0..nblocks {
            let block = &panel[jb * block_len..(jb + 1) * block_len];
            let mut r = [0i32; NR];
            for (t, &pair) in xrow.iter().enumerate() {
                let x0 = pair as i16 as i32;
                let x1 = pair >> 16; // arithmetic shift: high i16, sign-extended
                let chunk = &block[t * 2 * NR..(t + 1) * 2 * NR];
                for (c, rj) in r.iter_mut().enumerate() {
                    *rj += x0 * chunk[2 * c] as i32 + x1 * chunk[2 * c + 1] as i32;
                }
            }
            let js = NR.min(nc - jb * NR);
            let off = i * n + n0 + jb * NR;
            for (a, &rj) in acc[off..off + js].iter_mut().zip(&r[..js]) {
                *a += rj;
            }
        }
    }
}

/// `out[j] += alpha * x[j]`, sequential — one mul rounding and one add
/// rounding per element, the contract every level preserves.
pub(crate) fn saxpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += alpha * v;
    }
}

/// Sequential dot product — the serial accumulation order the kernel
/// layer's pre-SIMD `sgemm_nt` used.
pub(crate) fn sdot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}
