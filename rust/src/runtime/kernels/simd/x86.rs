//! x86_64 SSE2/AVX2/AVX-512-VNNI/FMA microkernels (`std::arch`, no
//! external deps).
//!
//! # Safety
//!
//! Every function here is `unsafe` on two counts, both discharged by the
//! dispatcher ([`super::SimdLevel`]):
//!
//! * **target features** — AVX2 functions are only reached through
//!   [`super::SimdLevel::Avx2`] (or higher), which
//!   [`super::SimdLevel::detect`] yields only after
//!   `is_x86_feature_detected!("avx2")`; the VNNI kernels only through
//!   [`super::SimdLevel::Avx512Vnni`] (avx512f + avx512bw + avx512vl +
//!   avx512vnni all detected); the FMA fp32 kernels only when
//!   [`super::fma_available`] confirmed `fma`; SSE2 is part of the x86_64
//!   baseline.
//! * **bounds** — the raw-pointer loads/stores stay inside their slices
//!   because the dispatcher asserts the panel/xgroups/accumulator sizes
//!   before calling (`panel.len() ≥ nblocks·groups·ki·nr`, etc.).
//!
//! The quantized kernels are the classic int8 GEMM shape: one chunk of
//! `ki=2` interleaved i8 weights per load — two consecutive k rows ×
//! `nr` columns — widened to i16, then a multiply-add against a broadcast
//! `(x[2t], x[2t+1])` i16 pair computes, per i32 lane `c`, exactly
//! `w[2t][j0+c]·x[2t] + w[2t+1][j0+c]·x[2t+1]`. The SSE2/AVX2 rungs
//! spend three instructions on it (widen + `pmaddwd` + `paddd`); the
//! VNNI rungs collapse the multiply-add-accumulate into one `vpdpwssd`.
//! (The ISSUE names `vpdpbusd`, but that instruction takes *unsigned*
//! 8-bit activations; our activations are signed i16 pairs, so the
//! signed-word sibling `vpdpwssd` is the correct VNNI instruction for
//! this panel layout — same port, same fusion win.) No saturation is
//! reachable: |w| ≤ 128 and |x| ≤ 255 keep every i16 product pair far
//! from the `pmaddwd` edge case (−32768·−32768) and `vpdpwssd` does not
//! saturate at all; the i32 accumulator is covered by
//! `check_accumulator_bound` at model build.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;

use super::super::gemm::NR;

/// AVX2 quantized tile kernel: 8 i32 column lanes per `vpmaddwd`, two
/// k-pair chunks in flight per iteration (i32 addition is exact, so the
/// two-accumulator split cannot change the result).
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qgemm_tile_avx2(
    panel: &[i8],
    xp: &[i32],
    mb: usize,
    pairs: usize,
    nc: usize,
    n: usize,
    n0: usize,
    acc: &mut [i32],
) {
    let nblocks = (nc + NR - 1) / NR;
    let block_len = pairs * 2 * NR;
    for i in 0..mb {
        let xrow = xp.as_ptr().add(i * pairs);
        for jb in 0..nblocks {
            let block = panel.as_ptr().add(jb * block_len);
            let mut acc0 = _mm256_setzero_si256();
            let mut acc1 = _mm256_setzero_si256();
            let mut t = 0usize;
            while t + 2 <= pairs {
                let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(block.add(t * 16) as *const __m128i));
                acc0 = _mm256_add_epi32(
                    acc0,
                    _mm256_madd_epi16(w0, _mm256_set1_epi32(*xrow.add(t))),
                );
                let w1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                    block.add((t + 1) * 16) as *const __m128i,
                ));
                acc1 = _mm256_add_epi32(
                    acc1,
                    _mm256_madd_epi16(w1, _mm256_set1_epi32(*xrow.add(t + 1))),
                );
                t += 2;
            }
            if t < pairs {
                let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(block.add(t * 16) as *const __m128i));
                acc0 = _mm256_add_epi32(
                    acc0,
                    _mm256_madd_epi16(w0, _mm256_set1_epi32(*xrow.add(t))),
                );
            }
            let sum = _mm256_add_epi32(acc0, acc1);
            let js = NR.min(nc - jb * NR);
            let dst = acc.as_mut_ptr().add(i * n + n0 + jb * NR);
            if js == NR {
                let cur = _mm256_loadu_si256(dst as *const __m256i);
                _mm256_storeu_si256(dst as *mut __m256i, _mm256_add_epi32(cur, sum));
            } else {
                let mut tmp = [0i32; NR];
                _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, sum);
                for (c, &v) in tmp.iter().enumerate().take(js) {
                    *dst.add(c) += v;
                }
            }
        }
    }
}

/// SSE2 quantized tile kernel: the same 16-byte panel chunks, widened via
/// sign-interleave (`pcmpgtb` + `punpck{l,h}bw`) and reduced with two
/// `pmaddwd` — columns 0..4 in one accumulator, 4..8 in the other.
#[target_feature(enable = "sse2")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qgemm_tile_sse2(
    panel: &[i8],
    xp: &[i32],
    mb: usize,
    pairs: usize,
    nc: usize,
    n: usize,
    n0: usize,
    acc: &mut [i32],
) {
    let nblocks = (nc + NR - 1) / NR;
    let block_len = pairs * 2 * NR;
    let zero = _mm_setzero_si128();
    for i in 0..mb {
        let xrow = xp.as_ptr().add(i * pairs);
        for jb in 0..nblocks {
            let block = panel.as_ptr().add(jb * block_len);
            let mut acc_lo = _mm_setzero_si128(); // columns 0..4
            let mut acc_hi = _mm_setzero_si128(); // columns 4..8
            for t in 0..pairs {
                let raw = _mm_loadu_si128(block.add(t * 16) as *const __m128i);
                let sign = _mm_cmpgt_epi8(zero, raw);
                let lo = _mm_unpacklo_epi8(raw, sign);
                let hi = _mm_unpackhi_epi8(raw, sign);
                let xv = _mm_set1_epi32(*xrow.add(t));
                acc_lo = _mm_add_epi32(acc_lo, _mm_madd_epi16(lo, xv));
                acc_hi = _mm_add_epi32(acc_hi, _mm_madd_epi16(hi, xv));
            }
            let js = NR.min(nc - jb * NR);
            let dst = acc.as_mut_ptr().add(i * n + n0 + jb * NR);
            if js == NR {
                let cur_lo = _mm_loadu_si128(dst as *const __m128i);
                let cur_hi = _mm_loadu_si128(dst.add(4) as *const __m128i);
                _mm_storeu_si128(dst as *mut __m128i, _mm_add_epi32(cur_lo, acc_lo));
                _mm_storeu_si128(dst.add(4) as *mut __m128i, _mm_add_epi32(cur_hi, acc_hi));
            } else {
                let mut tmp = [0i32; NR];
                _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, acc_lo);
                _mm_storeu_si128(tmp.as_mut_ptr().add(4) as *mut __m128i, acc_hi);
                for (c, &v) in tmp.iter().enumerate().take(js) {
                    *dst.add(c) += v;
                }
            }
        }
    }
}

/// AVX-512 VNNI quantized tile kernel at the wide geometry (`nr=16`,
/// `ki=2`): 16 i32 column lanes, one `vpdpwssd` per 32-byte chunk —
/// widen is still explicit (`vpmovsxbw`), but the multiply-add-accumulate
/// triple of the AVX2 rung is a single instruction. Two chunks in flight
/// (i32 addition is exact, so the split cannot change the result).
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qgemm_tile_vnni512(
    panel: &[i8],
    xp: &[i32],
    mb: usize,
    pairs: usize,
    nc: usize,
    n: usize,
    n0: usize,
    acc: &mut [i32],
) {
    const NRW: usize = 16; // wide-geometry column block
    let nblocks = nc.div_ceil(NRW);
    let block_len = pairs * 2 * NRW;
    for i in 0..mb {
        let xrow = xp.as_ptr().add(i * pairs);
        for jb in 0..nblocks {
            let block = panel.as_ptr().add(jb * block_len);
            let mut acc0 = _mm512_setzero_si512();
            let mut acc1 = _mm512_setzero_si512();
            let mut t = 0usize;
            while t + 2 <= pairs {
                let w0 = _mm512_cvtepi8_epi16(_mm256_loadu_si256(
                    block.add(t * 32) as *const __m256i
                ));
                acc0 = _mm512_dpwssd_epi32(acc0, w0, _mm512_set1_epi32(*xrow.add(t)));
                let w1 = _mm512_cvtepi8_epi16(_mm256_loadu_si256(
                    block.add((t + 1) * 32) as *const __m256i,
                ));
                acc1 = _mm512_dpwssd_epi32(acc1, w1, _mm512_set1_epi32(*xrow.add(t + 1)));
                t += 2;
            }
            if t < pairs {
                let w0 = _mm512_cvtepi8_epi16(_mm256_loadu_si256(
                    block.add(t * 32) as *const __m256i
                ));
                acc0 = _mm512_dpwssd_epi32(acc0, w0, _mm512_set1_epi32(*xrow.add(t)));
            }
            let sum = _mm512_add_epi32(acc0, acc1);
            let js = NRW.min(nc - jb * NRW);
            let dst = acc.as_mut_ptr().add(i * n + n0 + jb * NRW);
            if js == NRW {
                let cur = _mm512_loadu_epi32(dst);
                _mm512_storeu_epi32(dst, _mm512_add_epi32(cur, sum));
            } else {
                let mut tmp = [0i32; NRW];
                _mm512_storeu_epi32(tmp.as_mut_ptr(), sum);
                for (c, &v) in tmp.iter().enumerate().take(js) {
                    *dst.add(c) += v;
                }
            }
        }
    }
}

/// AVX-512 VNNI quantized tile kernel at the legacy geometry (`nr=8`,
/// `ki=2`, 256-bit): byte-compatible with the AVX2 panels, but the
/// `vpmaddwd`+`vpaddd` pair becomes one `vpdpwssd` (VL encoding). Used
/// when the autotuner keeps the 8-wide blocking on a VNNI host.
#[target_feature(enable = "avx2,avx512vl,avx512vnni")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn qgemm_tile_vnni256(
    panel: &[i8],
    xp: &[i32],
    mb: usize,
    pairs: usize,
    nc: usize,
    n: usize,
    n0: usize,
    acc: &mut [i32],
) {
    let nblocks = nc.div_ceil(NR);
    let block_len = pairs * 2 * NR;
    for i in 0..mb {
        let xrow = xp.as_ptr().add(i * pairs);
        for jb in 0..nblocks {
            let block = panel.as_ptr().add(jb * block_len);
            let mut acc0 = _mm256_setzero_si256();
            let mut acc1 = _mm256_setzero_si256();
            let mut t = 0usize;
            while t + 2 <= pairs {
                let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(block.add(t * 16) as *const __m128i));
                acc0 = _mm256_dpwssd_epi32(acc0, w0, _mm256_set1_epi32(*xrow.add(t)));
                let w1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                    block.add((t + 1) * 16) as *const __m128i,
                ));
                acc1 = _mm256_dpwssd_epi32(acc1, w1, _mm256_set1_epi32(*xrow.add(t + 1)));
                t += 2;
            }
            if t < pairs {
                let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(block.add(t * 16) as *const __m128i));
                acc0 = _mm256_dpwssd_epi32(acc0, w0, _mm256_set1_epi32(*xrow.add(t)));
            }
            let sum = _mm256_add_epi32(acc0, acc1);
            let js = NR.min(nc - jb * NR);
            let dst = acc.as_mut_ptr().add(i * n + n0 + jb * NR);
            if js == NR {
                let cur = _mm256_loadu_si256(dst as *const __m256i);
                _mm256_storeu_si256(dst as *mut __m256i, _mm256_add_epi32(cur, sum));
            } else {
                let mut tmp = [0i32; NR];
                _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, sum);
                for (c, &v) in tmp.iter().enumerate().take(js) {
                    *dst.add(c) += v;
                }
            }
        }
    }
}

/// AVX2 `out[j] += alpha * x[j]` — per-element mul then add (no FMA), so
/// the roundings match the scalar loop exactly.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn saxpy_avx2(alpha: f32, x: &[f32], out: &mut [f32]) {
    let len = out.len().min(x.len());
    let va = _mm256_set1_ps(alpha);
    let mut j = 0usize;
    while j + 8 <= len {
        let o = _mm256_loadu_ps(out.as_ptr().add(j));
        let v = _mm256_loadu_ps(x.as_ptr().add(j));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_add_ps(o, _mm256_mul_ps(va, v)));
        j += 8;
    }
    while j < len {
        *out.get_unchecked_mut(j) += alpha * *x.get_unchecked(j);
        j += 1;
    }
}

/// SSE2 `saxpy` (4 lanes), same per-element rounding contract.
#[target_feature(enable = "sse2")]
pub(crate) unsafe fn saxpy_sse2(alpha: f32, x: &[f32], out: &mut [f32]) {
    let len = out.len().min(x.len());
    let va = _mm_set1_ps(alpha);
    let mut j = 0usize;
    while j + 4 <= len {
        let o = _mm_loadu_ps(out.as_ptr().add(j));
        let v = _mm_loadu_ps(x.as_ptr().add(j));
        _mm_storeu_ps(out.as_mut_ptr().add(j), _mm_add_ps(o, _mm_mul_ps(va, v)));
        j += 4;
    }
    while j < len {
        *out.get_unchecked_mut(j) += alpha * *x.get_unchecked(j);
        j += 1;
    }
}

/// AVX2 dot product: 8 lane accumulators reduced at the end (reassociated —
/// 1e-5 contract, see [`super::SimdLevel::sdot`]).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn sdot_avx2(a: &[f32], b: &[f32]) -> f32 {
    let len = a.len().min(b.len());
    let mut acc = _mm256_setzero_ps();
    let mut j = 0usize;
    while j + 8 <= len {
        let va = _mm256_loadu_ps(a.as_ptr().add(j));
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        j += 8;
    }
    let hi = _mm256_extractf128_ps(acc, 1);
    let lo = _mm256_castps256_ps128(acc);
    let mut sum = hsum128(_mm_add_ps(lo, hi));
    while j < len {
        sum += *a.get_unchecked(j) * *b.get_unchecked(j);
        j += 1;
    }
    sum
}

/// SSE2 dot product (4 lane accumulators, reassociated).
#[target_feature(enable = "sse2")]
pub(crate) unsafe fn sdot_sse2(a: &[f32], b: &[f32]) -> f32 {
    let len = a.len().min(b.len());
    let mut acc = _mm_setzero_ps();
    let mut j = 0usize;
    while j + 4 <= len {
        let va = _mm_loadu_ps(a.as_ptr().add(j));
        let vb = _mm_loadu_ps(b.as_ptr().add(j));
        acc = _mm_add_ps(acc, _mm_mul_ps(va, vb));
        j += 4;
    }
    let mut sum = hsum128(acc);
    while j < len {
        sum += *a.get_unchecked(j) * *b.get_unchecked(j);
        j += 1;
    }
    sum
}

/// FMA-tier `out[j] += alpha * x[j]`: one `vfmadd` rounding per element,
/// bitwise-identical to the scalar `f32::mul_add` fallback
/// (`scalar::saxpy_fma`) — per-element semantics,
/// no reassociation, so [`super::FpMode::Fma`] keeps saxpy-based GEMMs
/// bitwise across levels too.
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn saxpy_fma256(alpha: f32, x: &[f32], out: &mut [f32]) {
    let len = out.len().min(x.len());
    let va = _mm256_set1_ps(alpha);
    let mut j = 0usize;
    while j + 8 <= len {
        let o = _mm256_loadu_ps(out.as_ptr().add(j));
        let v = _mm256_loadu_ps(x.as_ptr().add(j));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_fmadd_ps(va, v, o));
        j += 8;
    }
    while j < len {
        let o = out.get_unchecked_mut(j);
        *o = alpha.mul_add(*x.get_unchecked(j), *o);
        j += 1;
    }
}

/// FMA-tier dot product: 8 fused lane accumulators reduced at the end —
/// reassociated like [`sdot_avx2`], so `sgemm_nt` keeps its 1e-5 (not
/// bitwise) cross-level contract in Fma mode as well.
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn sdot_fma256(a: &[f32], b: &[f32]) -> f32 {
    let len = a.len().min(b.len());
    let mut acc = _mm256_setzero_ps();
    let mut j = 0usize;
    while j + 8 <= len {
        let va = _mm256_loadu_ps(a.as_ptr().add(j));
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        acc = _mm256_fmadd_ps(va, vb, acc);
        j += 8;
    }
    let hi = _mm256_extractf128_ps(acc, 1);
    let lo = _mm256_castps256_ps128(acc);
    let mut sum = hsum128(_mm_add_ps(lo, hi));
    while j < len {
        sum = a.get_unchecked(j).mul_add(*b.get_unchecked(j), sum);
        j += 1;
    }
    sum
}

/// Horizontal sum of 4 fp32 lanes in a fixed order:
/// `(l0 + l2) + (l1 + l3)`.
#[inline]
unsafe fn hsum128(v: __m128) -> f32 {
    let shuf = _mm_movehl_ps(v, v); // lanes [2, 3, 2, 3]
    let sums = _mm_add_ps(v, shuf); // [l0+l2, l1+l3, ..]
    let shuf2 = _mm_shuffle_ps(sums, sums, 0b01); // lane 1 to slot 0
    _mm_cvtss_f32(_mm_add_ss(sums, shuf2))
}
