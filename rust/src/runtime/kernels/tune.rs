//! Bind-time microkernel autotuner: pick a [`PanelGeom`] per layer shape
//! by *measuring*, not guessing (DESIGN.md §SIMD-dispatch).
//!
//! Blocking factors that win on one (k, n, bits, SIMD level) combination
//! lose on another — a wide-n layer wants deeper kc blocks, the VNNI
//! level wants 16-wide j-blocks, NEON hosts with i8-range activations
//! want the `ki=4` sdot interleave. Instead of freezing one compromise
//! into `const`s, [`tune_geom`] times the real panel GEMM over a small
//! per-level candidate set **on the layer's own shape** (clipped to a
//! sub-shape cap so bind time stays milliseconds) and bakes the winner
//! into the [`PanelizedWeights`](super::panel::PanelizedWeights) being
//! built.
//!
//! Safety of the whole idea rests on one invariant, enforced by the
//! parity proptests: **geometry never changes results** — `qgemm`
//! accumulates in exact i32, so every candidate produces bitwise-identical
//! output and the timer can only ever move *time*. That also makes the
//! cache race-free by construction: if two binds tune the same key
//! concurrently and disagree (timing noise), either answer is correct.
//!
//! The winner is cached process-wide per [`TuneKey`] — (k, n, bits,
//! activation range class, [`SimdLevel`]) — so registry replicas and hot
//! `load`s of the same architecture never re-tune. `LSQNET_NO_TUNE=1`
//! pins [`PanelGeom::DEFAULT`] (the legacy constants) for
//! determinism-sensitive workflows; it is read per call so tests can
//! toggle it.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::quant::lsq::qrange;
use crate::quant::pack::{pack, Packed};
use crate::util::rng::Pcg32;

use super::gemm::qgemm_panel;
use super::panel::{fits_i8, PanelGeom, PanelizedWeights};
use super::simd::SimdLevel;
use super::workspace::Workspace;

/// Timing sub-shape caps: layers larger than this are measured on a
/// clipped k×n prefix (blocking behavior is periodic in whole tiles, so a
/// few tiles' worth predicts the full shape; an unclipped 4096×4096 layer
/// would push bind time from milliseconds toward seconds).
const TUNE_K_CAP: usize = 1024;
const TUNE_N_CAP: usize = 256;
/// Activation rows for the timing runs — one serve-sized microbatch.
const TUNE_M: usize = 16;
/// Timing repetitions per candidate; the minimum is taken (min-of-N is
/// the standard scheduler-noise filter for microbenchmarks).
const TUNE_REPS: usize = 3;

/// Process-wide tuning cache key. `acts_i8` classifies the layer's
/// activation range (it gates `ki=4` candidates), `level` the dispatch
/// rung — the same shape tuned under a different forced level is a
/// different measurement.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct TuneKey {
    k: usize,
    n: usize,
    bits: u32,
    acts_i8: bool,
    level: SimdLevel,
}

fn cache() -> &'static Mutex<HashMap<TuneKey, PanelGeom>> {
    static CACHE: OnceLock<Mutex<HashMap<TuneKey, PanelGeom>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Number of (shape, bits, level) entries tuned so far in this process —
/// observability for tests and bind-time diagnostics: a second bind of
/// the same model must not grow this.
pub fn cache_len() -> usize {
    cache().lock().unwrap().len()
}

/// `LSQNET_NO_TUNE=1` pins [`PanelGeom::DEFAULT`]. Read per call (not
/// cached) so determinism-sensitive tests can set and unset it.
fn no_tune() -> bool {
    crate::util::env_truthy("LSQNET_NO_TUNE")
}

/// The candidate blockings for one dispatch level. Small by design
/// (2–4): the default always competes, plus the level's plausible
/// rivals — wider j-blocks where the microkernel has 16 lanes, a
/// deeper-k/narrower-n split, and on NEON the `ki=4` sdot interleave
/// when the activation range permits it.
fn candidates(level: SimdLevel, acts_i8: bool) -> Vec<PanelGeom> {
    let mut c = match level {
        SimdLevel::Avx512Vnni => vec![
            PanelGeom::DEFAULT,
            PanelGeom { kc: 256, nc: 64, nr: 16, ki: 2 },
            PanelGeom { kc: 128, nc: 128, nr: 16, ki: 2 },
        ],
        SimdLevel::Neon => vec![PanelGeom::DEFAULT, PanelGeom { kc: 128, nc: 128, nr: 8, ki: 2 }],
        _ => vec![
            PanelGeom::DEFAULT,
            PanelGeom { kc: 128, nc: 128, nr: 8, ki: 2 },
            PanelGeom { kc: 512, nc: 32, nr: 8, ki: 2 },
        ],
    };
    if level == SimdLevel::Neon && acts_i8 {
        c.push(PanelGeom { kc: 256, nc: 64, nr: 8, ki: 4 });
    }
    c
}

/// The blocking geometry to build `p`'s panels with: the cached winner
/// for this (shape, bits, activation class, level) if one exists, else a
/// fresh measurement (cached afterwards). `act_max` is the layer's
/// largest activation magnitude — `≤ 127` unlocks i8-activation (`ki=4`)
/// candidates. `LSQNET_NO_TUNE=1` short-circuits to
/// [`PanelGeom::DEFAULT`].
pub(crate) fn tune_geom(p: &Packed, k: usize, n: usize, act_max: i64) -> PanelGeom {
    if no_tune() || !fits_i8(p) {
        return PanelGeom::DEFAULT;
    }
    let level = SimdLevel::detect();
    let acts_i8 = act_max <= i8::MAX as i64;
    let key = TuneKey { k, n, bits: p.bits, acts_i8, level };
    if let Some(&g) = cache().lock().unwrap().get(&key) {
        return g;
    }
    let cands = candidates(level, acts_i8);
    let geom = measure(p.bits, p.signed, k.min(TUNE_K_CAP), n.min(TUNE_N_CAP), acts_i8, &cands);
    // Two binds may race to tune the same key; both wrote a *correct*
    // geometry (bitwise invariant), so last-writer-wins is fine.
    cache().lock().unwrap().insert(key, geom);
    geom
}

/// Time every candidate on a synthetic (kk×nn, `bits`) layer and return
/// the fastest. Weights and activations are synthetic but in-range (the
/// kernels' cost is shape-dependent, not value-dependent — the only
/// value sensitivity, the fused scalar zero-skip, is not on the panel
/// path being timed). Panel builds happen *outside* the timed region:
/// the bind path pays the build once, the serve hot loop never does, so
/// only steady-state GEMM time may vote.
fn measure(
    bits: u32,
    signed: bool,
    kk: usize,
    nn: usize,
    acts_i8: bool,
    cands: &[PanelGeom],
) -> PanelGeom {
    let (qn, qp) = qrange(bits, signed);
    let mut rng =
        Pcg32::seeded(0xB17E ^ ((kk as u64) << 24) ^ ((nn as u64) << 8) ^ bits as u64);
    let span = (qn + qp + 1) as u32;
    let w: Vec<i32> = (0..kk * nn).map(|_| rng.below(span) as i32 - qn as i32).collect();
    let packed = pack(&w, bits, signed, 1.0).expect("synthetic tuning weights pack");
    let xmax: u32 = if acts_i8 { i8::MAX as u32 } else { 255 };
    let x: Vec<i32> = (0..TUNE_M * kk).map(|_| rng.below(xmax + 1) as i32).collect();
    // Serial, and on the process dispatch level: the tuned artifact is
    // consumed by replicas whose per-call split varies, but per-tile
    // kernel cost — what geometry controls — does not depend on the
    // split.
    let mut ws = Workspace::with_threads(1);
    let mut out = vec![0.0f32; TUNE_M * nn];
    let mut best: Option<(u128, PanelGeom)> = None;
    for &g in cands {
        let pw = PanelizedWeights::build_with_geom(&packed, kk, nn, g);
        qgemm_panel(&mut ws, TUNE_M, kk, nn, &x, &pw, 1.0, None, &mut out); // warm caches
        let mut t_min = u128::MAX;
        for _ in 0..TUNE_REPS {
            let t0 = Instant::now();
            qgemm_panel(&mut ws, TUNE_M, kk, nn, &x, &pw, 1.0, None, &mut out);
            t_min = t_min.min(t0.elapsed().as_nanos());
        }
        if best.map(|(t, _)| t_min < t).unwrap_or(true) {
            best = Some((t_min, g));
        }
    }
    best.map(|(_, g)| g).unwrap_or(PanelGeom::DEFAULT)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_always_include_default_and_stay_valid() {
        for level in SimdLevel::ALL {
            for acts_i8 in [false, true] {
                let c = candidates(level, acts_i8);
                assert!(c.contains(&PanelGeom::DEFAULT), "{}", level.name());
                assert!((2..=4).contains(&c.len()), "{}", level.name());
                assert!(c.iter().all(|g| g.valid()));
                // ki=4 needs i8 activations: never offered otherwise.
                assert!(acts_i8 || c.iter().all(|g| g.ki == 2));
            }
        }
    }

    #[test]
    fn tune_caches_per_shape_and_reuses_across_binds() {
        let mut rng = Pcg32::seeded(4242);
        let (k, n, bits) = (96usize, 40usize, 4u32);
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(15) as i32 - 7).collect();
        let p = pack(&w, bits, true, 1.0).unwrap();
        let g1 = tune_geom(&p, k, n, 255);
        let len_after_first = cache_len();
        // Second bind of the same shape: cache hit, identical geometry,
        // no new entry.
        let g2 = tune_geom(&p, k, n, 255);
        assert_eq!(g1, g2);
        assert_eq!(cache_len(), len_after_first);
        assert!(g1.valid());
        // A different activation class is a different key (it changes
        // the candidate set).
        let g3 = tune_geom(&p, k, n, 127);
        assert!(g3.valid());
        assert!(cache_len() > len_after_first);
    }

    /// `LSQNET_NO_TUNE=1` must pin the legacy constants. Set → assert →
    /// remove runs sequentially inside one test; a concurrently running
    /// tuned bind would only ever pick a different-but-bitwise-identical
    /// geometry, so the env race is benign by the module invariant.
    #[test]
    fn no_tune_pins_default_geometry() {
        let mut rng = Pcg32::seeded(4343);
        let (k, n) = (64usize, 24usize);
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(3) as i32 - 1).collect();
        let p = pack(&w, 2, true, 1.0).unwrap();
        std::env::set_var("LSQNET_NO_TUNE", "1");
        let g = tune_geom(&p, k, n, 255);
        std::env::remove_var("LSQNET_NO_TUNE");
        assert_eq!(g, PanelGeom::DEFAULT);
    }
}
