//! Scratch-arena [`Workspace`] and intra-op thread-count resolution for the
//! kernel layer.
//!
//! Every kernel that needs scratch memory (the `qgemm` i32 accumulator, the
//! per-thread fused-unpack panels and activation-pair buffers) or transient
//! buffers (im2col patches, layer activations, gradient staging) draws it
//! from a `Workspace` instead of allocating. The workspace also carries the
//! [`SimdLevel`] resolved once at construction — the kernels' dispatch
//! decision (DESIGN.md §SIMD-dispatch). Serve replicas and the native trainer each own one
//! workspace, so the steady-state hot path performs no heap allocation:
//! buffers grow to the high-water mark of the model's layer shapes on the
//! first pass and are reused afterwards (see DESIGN.md §Kernel-layer for
//! the ownership rules).
//!
//! Thread-count resolution: the effective intra-op width of a kernel call
//! is `min(workspace cap (0 = hardware), LSQNET_THREADS (if set), rows)`:
//!
//! * `LSQNET_THREADS=1` forces every kernel serial — the CI determinism
//!   re-run uses this to show threaded and serial runs agree;
//! * a serve deployment partitions its core budget across every replica
//!   of every loaded variant
//!   ([`crate::runtime::PrepareOptions::intra_op_threads`], set by the
//!   registry from [`crate::serve::VariantOptions::intra_threads`]) so
//!   `total replicas × intra-op threads` never oversubscribes the host.

use std::sync::OnceLock;

use super::simd::{FpMode, SimdLevel};

/// Process-wide hard cap from the `LSQNET_THREADS` environment variable,
/// read once. 0 = unset (no cap).
fn env_thread_cap() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("LSQNET_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .map(|n| n.max(1))
            .unwrap_or(0)
    })
}

/// Number of hardware threads the host reports (always ≥ 1).
pub fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// How many recycled buffers of each element type a workspace retains;
/// beyond this, [`Workspace::recycle_f32`] drops instead of pooling. This
/// is a runaway backstop, not a working-set tuning knob: it must exceed
/// the number of buffers one training step recycles at once (a resnet8
/// tape returns ~50 — four per matmul entry plus BN saves — in one
/// `recycle_tape` burst), or the "allocation-free steady state" silently
/// degrades to malloc-per-step for whatever spills past the bound. In
/// steady state the pool holds exactly the model's high-water buffer set,
/// so memory is bounded by the working set itself; 128 only caps
/// pathological churn (e.g. one workspace cycled through many models).
const POOL_KEEP: usize = 128;

/// Per-thread `qgemm` scratch: the fused-mode panel tile, the one-row
/// unpack buffer feeding it, and the packed activation-pair stream for the
/// thread's row block. All grown on demand inside the owning thread (each
/// thread holds `&mut` to exactly one of these during a kernel call).
#[derive(Default)]
pub(crate) struct QThreadScratch {
    /// Fused-mode interleaved i8 panel for one KC×NC tile
    /// ([`super::panel::fill_tile_panel`]); unused in panelized mode.
    pub(crate) panel: Vec<i8>,
    /// One unpacked tile row (≤ NC values), scratch for the panel builder.
    pub(crate) row: Vec<i32>,
    /// i16-pair packed activations for this thread's rows × one k block
    /// ([`super::simd::pack_xpairs`]).
    pub(crate) xpairs: Vec<i32>,
    /// Plain row-major i32 KC×NC tile for the scalar-level fused path
    /// (direct unpack-and-dot — no panel interleave, zero-skip kept).
    pub(crate) tile: Vec<i32>,
}

/// Reusable scratch arena for the kernel layer.
///
/// Owns (a) the `qgemm` i32 accumulator and per-thread panel/activation
/// scratch, (b) a small pool of recycled `f32`/`i32` buffers that the
/// inference forward and training forward/backward cycle through
/// ([`Workspace::take_f32`] / [`Workspace::recycle_f32`]), and (c) the
/// [`SimdLevel`] every kernel call dispatches on — resolved once at
/// construction ([`SimdLevel::detect`]), pinnable to the portable path
/// with [`Workspace::force_scalar`]. One workspace serves one
/// engine/trainer at a time — kernels take `&mut Workspace`, so the borrow
/// checker enforces exclusivity; cross-replica parallelism comes from each
/// replica owning its own workspace.
pub struct Workspace {
    /// Requested intra-op thread cap; 0 = use [`hardware_threads`].
    threads: usize,
    /// SIMD dispatch level for every kernel call drawing on this
    /// workspace.
    simd: SimdLevel,
    /// fp32 contraction mode for the sgemm family (default
    /// [`FpMode::Pinned`]; `LSQNET_FMA=1` or
    /// [`Workspace::set_fp_mode`] opts into the FMA tier).
    fp: FpMode,
    /// `qgemm` i32 accumulator (`m×n`, resized per call).
    pub(crate) acc: Vec<i32>,
    /// Per-thread `qgemm` scratch (fused panels + activation pairs).
    pub(crate) qscratch: Vec<QThreadScratch>,
    pool_f32: Vec<Vec<f32>>,
    pool_i32: Vec<Vec<i32>>,
    pool_bool: Vec<Vec<bool>>,
    pool_usize: Vec<Vec<usize>>,
}

impl Default for Workspace {
    fn default() -> Self {
        Workspace::new()
    }
}

impl Workspace {
    /// A workspace that follows the hardware thread count (modulo the
    /// `LSQNET_THREADS` cap).
    pub fn new() -> Workspace {
        Workspace::with_threads(0)
    }

    /// A workspace capped at `threads` intra-op threads (0 = hardware).
    /// The SIMD dispatch level is resolved here, once
    /// ([`SimdLevel::detect`] — cached per process, `LSQNET_FORCE_SCALAR`
    /// honored).
    pub fn with_threads(threads: usize) -> Workspace {
        Workspace {
            threads,
            simd: SimdLevel::detect(),
            fp: FpMode::default_mode(),
            acc: Vec::new(),
            qscratch: Vec::new(),
            pool_f32: Vec::new(),
            pool_i32: Vec::new(),
            pool_bool: Vec::new(),
            pool_usize: Vec::new(),
        }
    }

    /// The SIMD level kernel calls on this workspace dispatch to.
    pub fn simd(&self) -> SimdLevel {
        self.simd
    }

    /// Pin this workspace to the portable scalar kernels (the in-process
    /// side of the dispatch-parity tests; `LSQNET_SIMD=scalar` /
    /// `LSQNET_FORCE_SCALAR=1` is the process-wide equivalent).
    /// Downgrade-only by design: forcing a *higher* level than the host
    /// supports would be unsound.
    pub fn force_scalar(&mut self) {
        self.simd = SimdLevel::Scalar;
    }

    /// Pin this workspace to an explicit dispatch `level` (the in-process
    /// side of the forced-level parity matrix; `LSQNET_SIMD=<name>` is
    /// the process-wide equivalent). Returns `false` — leaving the
    /// workspace unchanged — when this host cannot execute `level`:
    /// dispatching an unavailable vector level would be unsound, so the
    /// availability gate lives here, not in the caller.
    pub fn force_level(&mut self, level: SimdLevel) -> bool {
        if !level.available() {
            return false;
        }
        self.simd = level;
        true
    }

    /// The fp32 contraction mode the sgemm family uses on this workspace.
    pub fn fp_mode(&self) -> FpMode {
        self.fp
    }

    /// Select the fp32 contraction mode ([`FpMode::Fma`] = one fused
    /// rounding per element — the training-throughput tier; requests are
    /// ignored on hosts without FMA units, keeping the mode executable by
    /// construction). `qgemm` is integer-exact and unaffected.
    pub fn set_fp_mode(&mut self, fp: FpMode) {
        if fp == FpMode::Fma && !super::simd::fma_available() {
            return;
        }
        self.fp = fp;
    }

    /// Re-cap the intra-op thread count (0 = hardware). Existing scratch
    /// buffers are kept.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }

    /// The effective intra-op thread count for the next kernel call:
    /// the workspace cap (or hardware count), further capped by
    /// `LSQNET_THREADS` when set. Always ≥ 1.
    pub fn threads(&self) -> usize {
        let want = if self.threads == 0 {
            hardware_threads()
        } else {
            self.threads
        };
        let cap = env_thread_cap();
        let eff = if cap == 0 { want } else { want.min(cap) };
        eff.max(1)
    }

    /// The `qgemm` scratch pair: the shared i32 accumulator plus one
    /// [`QThreadScratch`] per thread. Returned as two disjoint borrows so
    /// the caller can split the accumulator across threads while each
    /// thread owns its scratch; the per-thread buffers grow on demand
    /// inside the kernel (each thread holds them `&mut`).
    pub(crate) fn gemm_scratch(
        &mut self,
        threads: usize,
    ) -> (&mut Vec<i32>, &mut [QThreadScratch]) {
        if self.qscratch.len() < threads {
            self.qscratch.resize_with(threads, QThreadScratch::default);
        }
        let Workspace { acc, qscratch, .. } = self;
        (acc, &mut qscratch[..threads])
    }

    /// A zero-filled `f32` buffer of exactly `len` elements, reusing a
    /// recycled buffer's capacity when one fits (best-fit, falling back to
    /// the largest). Pair with [`Workspace::recycle_f32`] when the buffer
    /// dies so the capacity returns to the pool.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        let mut v = take_pooled(&mut self.pool_f32, len);
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// [`Workspace::take_f32`] for `i32` buffers.
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        let mut v = take_pooled(&mut self.pool_i32, len);
        v.clear();
        v.resize(len, 0);
        v
    }

    /// An *empty* `f32` buffer with capacity ≥ `len` (no zero-fill), for
    /// callers that fully initialize the contents themselves — im2col's
    /// clear+resize, `extend_from_slice` copies, push-style fills. This
    /// skips the redundant zeroing write pass [`Workspace::take_f32`]
    /// would spend on the layer's largest buffers.
    pub fn take_f32_cap(&mut self, len: usize) -> Vec<f32> {
        let mut v = take_pooled(&mut self.pool_f32, len);
        v.clear();
        v.reserve(len);
        v
    }

    /// [`Workspace::take_f32_cap`] for `i32` buffers.
    pub fn take_i32_cap(&mut self, len: usize) -> Vec<i32> {
        let mut v = take_pooled(&mut self.pool_i32, len);
        v.clear();
        v.reserve(len);
        v
    }

    /// A length-`len` `f32` buffer with **arbitrary contents** (stale
    /// values from earlier recycles; only the grown tail is zeroed), for
    /// kernels that initialize every output element themselves — GEMM
    /// epilogues, `fill`-then-accumulate backward kernels, pooling. This
    /// skips the full memset [`Workspace::take_f32`] performs; use the
    /// zeroed variant when the kernel *accumulates* into the buffer
    /// (e.g. [`super::col2im`]).
    pub fn take_f32_any(&mut self, len: usize) -> Vec<f32> {
        let mut v = take_pooled(&mut self.pool_f32, len);
        v.truncate(len);
        v.resize(len, 0.0);
        v
    }

    /// [`Workspace::take_f32_cap`] for `bool` buffers (ReLU masks on the
    /// training tape).
    pub fn take_bool_cap(&mut self, len: usize) -> Vec<bool> {
        let mut v = take_pooled(&mut self.pool_bool, len);
        v.clear();
        v.reserve(len);
        v
    }

    /// [`Workspace::take_f32_cap`] for `usize` buffers (maxpool argmax on
    /// the training tape).
    pub fn take_usize_cap(&mut self, len: usize) -> Vec<usize> {
        let mut v = take_pooled(&mut self.pool_usize, len);
        v.clear();
        v.reserve(len);
        v
    }

    /// Return a dead buffer's capacity to the pool (dropped once the pool
    /// holds `POOL_KEEP` buffers).
    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        if self.pool_f32.len() < POOL_KEEP && v.capacity() > 0 {
            self.pool_f32.push(v);
        }
    }

    /// [`Workspace::recycle_f32`] for `i32` buffers.
    pub fn recycle_i32(&mut self, v: Vec<i32>) {
        if self.pool_i32.len() < POOL_KEEP && v.capacity() > 0 {
            self.pool_i32.push(v);
        }
    }

    /// [`Workspace::recycle_f32`] for `bool` buffers.
    pub fn recycle_bool(&mut self, v: Vec<bool>) {
        if self.pool_bool.len() < POOL_KEEP && v.capacity() > 0 {
            self.pool_bool.push(v);
        }
    }

    /// [`Workspace::recycle_f32`] for `usize` buffers.
    pub fn recycle_usize(&mut self, v: Vec<usize>) {
        if self.pool_usize.len() < POOL_KEEP && v.capacity() > 0 {
            self.pool_usize.push(v);
        }
    }
}

/// Pop the best-fitting pooled buffer for `len`: the smallest capacity
/// ≥ `len`, else the largest available (its capacity will grow once), else
/// a fresh empty `Vec`.
fn take_pooled<T>(pool: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    if pool.is_empty() {
        return Vec::with_capacity(len);
    }
    let mut best: Option<usize> = None; // smallest capacity >= len
    let mut largest = 0usize; // fallback: largest capacity overall
    for (i, v) in pool.iter().enumerate() {
        let tighter_fit = match best {
            None => true,
            Some(b) => v.capacity() < pool[b].capacity(),
        };
        if v.capacity() >= len && tighter_fit {
            best = Some(i);
        }
        if v.capacity() >= pool[largest].capacity() {
            largest = i;
        }
    }
    pool.swap_remove(best.unwrap_or(largest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_respects_explicit_cap() {
        let ws = Workspace::with_threads(3);
        assert!(ws.threads() >= 1);
        assert!(ws.threads() <= 3);
        let auto = Workspace::new();
        assert!(auto.threads() >= 1);
    }

    #[test]
    fn take_recycle_reuses_capacity() {
        let mut ws = Workspace::new();
        let mut v = ws.take_f32(100);
        v[0] = 1.0;
        let cap = v.capacity();
        let ptr = v.as_ptr();
        ws.recycle_f32(v);
        // Same capacity comes back, zeroed, even for a smaller request.
        let v2 = ws.take_f32(50);
        assert_eq!(v2.len(), 50);
        assert!(v2.iter().all(|&x| x == 0.0));
        assert_eq!(v2.capacity(), cap);
        assert_eq!(v2.as_ptr(), ptr);
    }

    #[test]
    fn take_cap_returns_empty_with_reused_capacity() {
        let mut ws = Workspace::new();
        let v = ws.take_f32(64);
        let cap = v.capacity();
        ws.recycle_f32(v);
        let c = ws.take_f32_cap(10);
        assert!(c.is_empty());
        assert_eq!(c.capacity(), cap);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let mut ws = Workspace::new();
        let big = ws.take_i32(1000);
        let small = ws.take_i32(10);
        let (big_cap, small_cap) = (big.capacity(), small.capacity());
        ws.recycle_i32(big);
        ws.recycle_i32(small);
        assert!(big_cap > small_cap);
        // A tiny request must not burn the big buffer.
        let got = ws.take_i32(8);
        assert_eq!(got.capacity(), small_cap);
    }

    #[test]
    fn gemm_scratch_grows_per_thread_slots() {
        let mut ws = Workspace::new();
        let (acc, scr) = ws.gemm_scratch(4);
        assert_eq!(scr.len(), 4);
        scr[3].panel.resize(64, 0);
        acc.resize(10, 0);
        let (acc2, scr2) = ws.gemm_scratch(2);
        assert_eq!(acc2.len(), 10);
        assert_eq!(scr2.len(), 2);
        // Slots persist: asking for fewer threads must not drop capacity.
        let (_, scr3) = ws.gemm_scratch(4);
        assert_eq!(scr3[3].panel.len(), 64);
    }

    #[test]
    fn force_scalar_pins_portable_path() {
        let mut ws = Workspace::new();
        ws.force_scalar();
        assert_eq!(ws.simd(), crate::runtime::kernels::SimdLevel::Scalar);
    }

    #[test]
    fn force_level_gates_on_availability() {
        let mut ws = Workspace::new();
        // Scalar is available everywhere.
        assert!(ws.force_level(SimdLevel::Scalar));
        assert_eq!(ws.simd(), SimdLevel::Scalar);
        // Every available level can be pinned; unavailable ones are
        // rejected without changing the workspace.
        for level in SimdLevel::ALL {
            let before = ws.simd();
            let ok = ws.force_level(level);
            assert_eq!(ok, level.available(), "{}", level.name());
            assert_eq!(ws.simd(), if ok { level } else { before });
        }
    }

    #[test]
    fn fp_mode_defaults_pinned_and_gates_fma() {
        let mut ws = Workspace::new();
        // Default is deterministic Pinned unless LSQNET_FMA opted in.
        if !crate::util::env_truthy("LSQNET_FMA") {
            assert_eq!(ws.fp_mode(), FpMode::Pinned);
        }
        ws.set_fp_mode(FpMode::Fma);
        // Accepted only where the host has FMA units.
        assert_eq!(ws.fp_mode() == FpMode::Fma, super::super::simd::fma_available());
        ws.set_fp_mode(FpMode::Pinned);
        assert_eq!(ws.fp_mode(), FpMode::Pinned);
    }
}
