//! Typed view over `artifacts/manifest.json` — the contract between the
//! Python compile path and the Rust runtime.
//!
//! The manifest records, for every AOT artifact, the exact positional
//! calling convention (input/output tensor names, shapes, dtypes and roles)
//! plus per-family parameter metadata (names, roles, shapes, initial-params
//! binary, per-layer bit widths for model-size accounting).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::quant::model_size::LayerMeta;
use crate::tensor::{f32s_from_bytes, numel, DType, Tensor};
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// param | mom | teacher | data_x | data_y | data_w | lr | wd | metric |
    /// logits | diag | series | scalar
    pub kind: String,
    /// For param/mom/teacher slots: the parameter name this slot carries.
    pub param: Option<String>,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub id: String,
    pub file: String,
    /// train | train_kd | train_diag | eval | init_quant | infer | fig2 | qmm
    pub kind: String,
    pub family: Option<String>,
    pub teacher_family: Option<String>,
    pub method: Option<String>,
    pub gscale: Option<String>,
    pub batch: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct Family {
    pub name: String,
    pub model: String,
    pub qbits: u32,
    pub num_classes: usize,
    pub params_bin: String,
    pub n_matmul: usize,
    pub param_names: Vec<String>,
    pub grad_names: Vec<String>,
    pub roles: BTreeMap<String, String>,
    pub shapes: BTreeMap<String, Vec<usize>>,
    pub layer_meta: Vec<LayerMeta>,
}

impl Family {
    /// Parameter names with role `step_w` / `step_a`.
    pub fn step_names(&self, role: &str) -> Vec<String> {
        self.param_names
            .iter()
            .filter(|n| self.roles.get(*n).map(String::as_str) == Some(role))
            .cloned()
            .collect()
    }

    pub fn total_weights(&self) -> usize {
        self.layer_meta.iter().map(|l| l.n_weights).sum()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub image: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub families: BTreeMap<String, Family>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.str_at("name")?.to_string(),
        shape: j
            .arr_at("shape")?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
            .collect::<Result<_>>()?,
        dtype: DType::from_name(j.str_at("dtype")?)?,
        kind: j.str_at("kind")?.to_string(),
        param: j.get("param").and_then(Json::as_str).map(str::to_string),
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let mut families = BTreeMap::new();
        for (name, fj) in j
            .get("families")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing families"))?
        {
            let mut roles = BTreeMap::new();
            for (k, v) in fj.get("roles").and_then(Json::as_obj).unwrap_or(&BTreeMap::new()) {
                roles.insert(k.clone(), v.as_str().unwrap_or("").to_string());
            }
            let mut shapes = BTreeMap::new();
            for (k, v) in fj.get("shapes").and_then(Json::as_obj).unwrap_or(&BTreeMap::new()) {
                let dims = v
                    .as_arr()
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                shapes.insert(k.clone(), dims);
            }
            let layer_meta = fj
                .arr_at("layer_meta")?
                .iter()
                .map(|l| {
                    Ok(LayerMeta {
                        name: l.str_at("name")?.to_string(),
                        n_weights: l.usize_at("n_weights")?,
                        bits: l.usize_at("bits")? as u32,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let strings = |key: &str| -> Result<Vec<String>> {
                Ok(fj
                    .arr_at(key)?
                    .iter()
                    .filter_map(Json::as_str)
                    .map(str::to_string)
                    .collect())
            };
            families.insert(
                name.clone(),
                Family {
                    name: name.clone(),
                    model: fj.str_at("model")?.to_string(),
                    qbits: fj.usize_at("qbits")? as u32,
                    num_classes: fj.usize_at("num_classes")?,
                    params_bin: fj.str_at("params_bin")?.to_string(),
                    n_matmul: fj.usize_at("n_matmul")?,
                    param_names: strings("param_names")?,
                    grad_names: strings("grad_names")?,
                    roles,
                    shapes,
                    layer_meta,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for aj in j.arr_at("artifacts")? {
            let meta = ArtifactMeta {
                id: aj.str_at("id")?.to_string(),
                file: aj.str_at("file")?.to_string(),
                kind: aj.str_at("kind")?.to_string(),
                family: aj.get("family").and_then(Json::as_str).map(str::to_string),
                teacher_family: aj
                    .get("teacher_family")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                method: aj.get("method").and_then(Json::as_str).map(str::to_string),
                gscale: aj.get("gscale").and_then(Json::as_str).map(str::to_string),
                batch: aj.usize_at("batch")?,
                inputs: aj.arr_at("inputs")?.iter().map(parse_io).collect::<Result<_>>()?,
                outputs: aj.arr_at("outputs")?.iter().map(parse_io).collect::<Result<_>>()?,
            };
            artifacts.insert(meta.id.clone(), meta);
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            batch: j.usize_at("batch")?,
            image: j.usize_at("image")?,
            channels: j.usize_at("channels")?,
            num_classes: j.usize_at("num_classes")?,
            families,
            artifacts,
        })
    }

    pub fn family(&self, name: &str) -> Result<&Family> {
        self.families
            .get(name)
            .ok_or_else(|| anyhow!("family {name:?} not in manifest"))
    }

    pub fn artifact(&self, id: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(id)
            .ok_or_else(|| anyhow!("artifact {id:?} not in manifest"))
    }

    /// Find an artifact by (kind, family) plus optional method/gscale.
    pub fn find(
        &self,
        kind: &str,
        family: &str,
        method: Option<&str>,
        gscale: Option<&str>,
    ) -> Result<&ArtifactMeta> {
        self.artifacts
            .values()
            .find(|a| {
                a.kind == kind
                    && a.family.as_deref() == Some(family)
                    && method.map_or(true, |m| a.method.as_deref() == Some(m))
                    && gscale.map_or(true, |g| a.gscale.as_deref() == Some(g))
            })
            .ok_or_else(|| {
                anyhow!(
                    "no artifact kind={kind} family={family} method={method:?} \
                     gscale={gscale:?} — re-run `make artifacts` with a larger --set"
                )
            })
    }

    /// Load the initial parameter tensors for a family from its params.bin.
    pub fn load_initial_params(&self, family: &str) -> Result<Vec<Tensor>> {
        let fam = self.family(family)?;
        let path = self.dir.join(&fam.params_bin);
        let bytes = std::fs::read(&path).with_context(|| format!("read {path:?}"))?;
        let mut out = Vec::with_capacity(fam.param_names.len());
        let mut off = 0usize;
        for name in &fam.param_names {
            let shape = fam
                .shapes
                .get(name)
                .ok_or_else(|| anyhow!("no shape for param {name}"))?;
            let n = numel(shape) * 4;
            if off + n > bytes.len() {
                bail!("{path:?} truncated at param {name}");
            }
            out.push(Tensor::from_f32(shape, f32s_from_bytes(&bytes[off..off + n])));
            off += n;
        }
        if off != bytes.len() {
            bail!("{path:?} has {} trailing bytes", bytes.len() - off);
        }
        Ok(out)
    }
}
