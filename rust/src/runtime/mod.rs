//! Runtime layer: loads AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//! See `manifest` for the calling-convention contract and `engine` for the
//! execution path.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Executable};
pub use manifest::{ArtifactMeta, Family, IoSpec, Manifest};
