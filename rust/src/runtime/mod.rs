//! Runtime layer: the execution backends behind the coordinator.
//!
//! * [`manifest`] — the typed calling-convention contract produced by
//!   `python/compile/aot.py` (always available).
//! * [`backend`] — the [`Backend`] trait + [`BackendSpec`] the serving and
//!   bench layers dispatch over.
//! * [`kernels`] — the unified parallel kernel layer (workspace-reused,
//!   multi-threaded GEMM/im2col/pool/BN) shared by native inference and
//!   native training.
//! * [`native`] — pure-Rust packed-weight inference (always available).
//! * [`artifact`] — the versioned `.lsqa` zero-copy model artifact
//!   (writer + instant-bind loader) for fleet cold-start.
//! * `engine` — the XLA/PJRT executor for the AOT HLO artifacts
//!   (train/eval/diag paths), behind `--features xla`.

pub mod artifact;
pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
pub mod kernels;
pub mod manifest;
pub mod native;

pub use artifact::{pack_family, ArtifactError, LoadedArtifact};
pub use backend::{Backend, BackendKind, BackendSpec, PrepareOptions};
#[cfg(feature = "xla")]
pub use engine::{Engine, Executable};
pub use manifest::{ArtifactMeta, Family, IoSpec, Manifest};
pub use native::NativeEngine;
