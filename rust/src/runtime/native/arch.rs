//! Rust-side mirror of `python/compile/models.py`: the architecture IR that
//! the native backend interprets.
//!
//! A family's `model` string plus the manifest-level input geometry fully
//! determine the layer graph, parameter names and per-matmul bit widths, so
//! the native engine can rebuild the forward pass without any HLO artifact.
//! The matmul ordering and scope naming here must match the Python `Ctx`
//! exactly — parameter names like `s0b0.conv1.sw` are the contract between
//! `params.bin` / checkpoints and this builder (asserted by the native
//! parity tests).

use anyhow::{bail, Result};

/// One (possibly quantized) 2-D convolution: NHWC input × HWIO weights,
/// SAME padding, no bias (as in the Python model zoo).
#[derive(Clone, Debug)]
pub struct ConvSpec {
    /// Scope name, e.g. `"conv1"` or `"s0b0.proj"`; parameters are
    /// `{name}.w`, `{name}.sw`, `{name}.sa`.
    pub name: String,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Stride (same in both spatial dims).
    pub stride: usize,
    /// Input channels.
    pub in_ch: usize,
    /// Output channels.
    pub out_ch: usize,
    /// Whether the input activations quantize signed (true only where the
    /// layer consumes the raw network input) or unsigned (post-ReLU).
    pub signed_act: bool,
    /// Matmul precision for both weights and input activations; 32 means
    /// full precision (no quantizer parameters exist).
    pub bits: u32,
}

/// One (possibly quantized) fully connected layer with bias.
#[derive(Clone, Debug)]
pub struct DenseSpec {
    /// Scope name; parameters are `{name}.w`, `{name}.sw`, `{name}.sa`,
    /// `{name}.b`.
    pub name: String,
    /// Input features.
    pub in_dim: usize,
    /// Output features.
    pub out_dim: usize,
    /// Signed vs unsigned input-activation quantization.
    pub signed_act: bool,
    /// Matmul precision (32 = full precision).
    pub bits: u32,
}

/// Batch normalization over the trailing channel dim (eval mode: running
/// stats).
#[derive(Clone, Debug)]
pub struct BnSpec {
    /// Scope name; parameters are `{name}.{gamma,beta,rmean,rvar}`.
    pub name: String,
    /// Channel count.
    pub ch: usize,
}

/// Pre-activation ResNet basic block (He et al. 2016), mirroring
/// `models._preact_block`: `bn1 → relu`, projection shortcut from the
/// pre-activated tensor when shape changes, `conv1 → bn2 → relu → conv2`,
/// then the residual add.
#[derive(Clone, Debug)]
pub struct PreactSpec {
    /// First batch norm (over the block input).
    pub bn1: BnSpec,
    /// 1×1 projection shortcut, present iff stride ≠ 1 or channels change.
    pub proj: Option<ConvSpec>,
    /// First 3×3 conv (carries the stride).
    pub conv1: ConvSpec,
    /// Mid-block batch norm.
    pub bn2: BnSpec,
    /// Second 3×3 conv.
    pub conv2: ConvSpec,
}

/// One node of the interpreted forward pass.
#[derive(Clone, Debug)]
pub enum ArchOp {
    /// Quantized/fp32 convolution.
    Conv(ConvSpec),
    /// Quantized/fp32 dense layer.
    Dense(DenseSpec),
    /// Batch normalization (eval mode).
    BatchNorm(BnSpec),
    /// Elementwise `max(x, 0)`.
    Relu,
    /// 2×2 max pooling, stride 2, VALID.
    MaxPool2,
    /// Mean over the spatial dims: `[b,h,w,c] → [b,c]`.
    GlobalAvgPool,
    /// Reshape `[b,h,w,c] → [b,h*w*c]`.
    Flatten,
    /// Pre-activation residual block.
    Preact(Box<PreactSpec>),
}

/// A fully specified architecture: op list plus the metadata the engine and
/// fixture writer need.
#[derive(Clone, Debug)]
pub struct Arch {
    /// Model zoo name this was built from (`"mlp"`, `"cnn_small"`, ...).
    pub model: String,
    /// Ops in execution order.
    pub ops: Vec<ArchOp>,
    /// Number of quantizable matmul layers (conv + dense), matching the
    /// manifest's `n_matmul`.
    pub n_matmul: usize,
    /// Input image side length.
    pub image: usize,
    /// Input channels.
    pub channels: usize,
    /// Logit count.
    pub num_classes: usize,
}

fn conv(
    name: impl Into<String>,
    in_ch: usize,
    out_ch: usize,
    (kh, kw): (usize, usize),
    stride: usize,
    signed_act: bool,
    bits: u32,
) -> ConvSpec {
    ConvSpec { name: name.into(), kh, kw, stride, in_ch, out_ch, signed_act, bits }
}

fn bn(name: impl Into<String>, ch: usize) -> BnSpec {
    BnSpec { name: name.into(), ch }
}

/// Build the architecture for `model` at `qbits`. Matches
/// `python/compile/models.py` layer-for-layer, including the paper's rule
/// that the first and last matmul layers are pinned to at least 8 bits
/// (Section 2.3).
pub fn build(
    model: &str,
    image: usize,
    channels: usize,
    num_classes: usize,
    qbits: u32,
) -> Result<Arch> {
    let b = if qbits >= 32 { 32 } else { qbits };
    let mut ops: Vec<ArchOp> = Vec::new();
    match model {
        "mlp" => {
            let flat = image * image * channels;
            ops.push(ArchOp::Flatten);
            ops.push(ArchOp::Dense(DenseSpec {
                name: "fc1".into(),
                in_dim: flat,
                out_dim: 256,
                signed_act: true,
                bits: b,
            }));
            ops.push(ArchOp::Relu);
            ops.push(ArchOp::Dense(DenseSpec {
                name: "fc2".into(),
                in_dim: 256,
                out_dim: num_classes,
                signed_act: false,
                bits: b,
            }));
        }
        "cnn_small" => {
            let plan = [
                ("conv1", channels, 16usize, 1usize, true),
                ("conv2", 16, 32, 2, false),
                ("conv3", 32, 32, 1, false),
                ("conv4", 32, 64, 2, false),
            ];
            for (i, (name, ic, oc, stride, signed)) in plan.into_iter().enumerate() {
                ops.push(ArchOp::Conv(conv(name, ic, oc, (3, 3), stride, signed, b)));
                ops.push(ArchOp::BatchNorm(bn(format!("bn{}", i + 1), oc)));
                ops.push(ArchOp::Relu);
            }
            ops.push(ArchOp::GlobalAvgPool);
            ops.push(ArchOp::Dense(DenseSpec {
                name: "fc".into(),
                in_dim: 64,
                out_dim: num_classes,
                signed_act: false,
                bits: b,
            }));
        }
        "resnet8" | "resnet14" | "resnet20" | "resnet32" => {
            let blocks_per_stage = match model {
                "resnet8" => 1,
                "resnet14" => 2,
                "resnet20" => 3,
                _ => 5,
            };
            let widths = [16usize, 32, 64];
            ops.push(ArchOp::Conv(conv("stem", channels, widths[0], (3, 3), 1, true, b)));
            let mut cur = widths[0];
            for (stage, &ch) in widths.iter().enumerate() {
                for blk in 0..blocks_per_stage {
                    let stride = if stage > 0 && blk == 0 { 2 } else { 1 };
                    let name = format!("s{stage}b{blk}");
                    let proj = if stride != 1 || cur != ch {
                        Some(conv(format!("{name}.proj"), cur, ch, (1, 1), stride, false, b))
                    } else {
                        None
                    };
                    ops.push(ArchOp::Preact(Box::new(PreactSpec {
                        bn1: bn(format!("{name}.bn1"), cur),
                        proj,
                        conv1: conv(format!("{name}.conv1"), cur, ch, (3, 3), stride, false, b),
                        bn2: bn(format!("{name}.bn2"), ch),
                        conv2: conv(format!("{name}.conv2"), ch, ch, (3, 3), 1, false, b),
                    })));
                    cur = ch;
                }
            }
            ops.push(ArchOp::BatchNorm(bn("bn_final", cur)));
            ops.push(ArchOp::Relu);
            ops.push(ArchOp::GlobalAvgPool);
            ops.push(ArchOp::Dense(DenseSpec {
                name: "fc".into(),
                in_dim: cur,
                out_dim: num_classes,
                signed_act: false,
                bits: b,
            }));
        }
        "vgg_small" => {
            let cfg = [(32usize, 2usize), (64, 2), (128, 2)];
            let mut cur = channels;
            let mut side = image;
            let mut first = true;
            for (stage, (ch, reps)) in cfg.into_iter().enumerate() {
                for r in 0..reps {
                    ops.push(ArchOp::Conv(conv(
                        format!("conv{stage}_{r}"),
                        cur,
                        ch,
                        (3, 3),
                        1,
                        first,
                        b,
                    )));
                    first = false;
                    ops.push(ArchOp::BatchNorm(bn(format!("bn{stage}_{r}"), ch)));
                    ops.push(ArchOp::Relu);
                    cur = ch;
                }
                ops.push(ArchOp::MaxPool2);
                side /= 2;
            }
            ops.push(ArchOp::Flatten);
            ops.push(ArchOp::Dense(DenseSpec {
                name: "fc1".into(),
                in_dim: cur * side * side,
                out_dim: 128,
                signed_act: false,
                bits: b,
            }));
            ops.push(ArchOp::Relu);
            ops.push(ArchOp::Dense(DenseSpec {
                name: "fc2".into(),
                in_dim: 128,
                out_dim: num_classes,
                signed_act: false,
                bits: b,
            }));
        }
        other => bail!(
            "model {other:?} is not supported by the native backend \
             (have: mlp, cnn_small, resnet8/14/20/32, vgg_small)"
        ),
    }

    let mut arch =
        Arch { model: model.to_string(), ops, n_matmul: 0, image, channels, num_classes };
    let mut count = 0usize;
    for_each_matmul_bits(&mut arch.ops, &mut |_| count += 1);
    arch.n_matmul = count;
    // First/last matmul pinned to >= 8 bits (paper Section 2.3), exactly as
    // Ctx.layer_bits does on the Python side.
    if qbits < 32 {
        let pinned = qbits.max(8);
        let (mut idx, last) = (0usize, count - 1);
        for_each_matmul_bits(&mut arch.ops, &mut |bits| {
            if idx == 0 || idx == last {
                *bits = pinned;
            }
            idx += 1;
        });
    }
    Ok(arch)
}

/// Visit the `bits` field of every matmul layer in execution order — the
/// same order `Ctx._matmul_index` counts on the Python side (within a
/// pre-act block: proj, conv1, conv2).
pub fn for_each_matmul_bits(ops: &mut [ArchOp], f: &mut impl FnMut(&mut u32)) {
    for op in ops {
        match op {
            ArchOp::Conv(c) => f(&mut c.bits),
            ArchOp::Dense(d) => f(&mut d.bits),
            ArchOp::Preact(p) => {
                if let Some(proj) = &mut p.proj {
                    f(&mut proj.bits);
                }
                f(&mut p.conv1.bits);
                f(&mut p.conv2.bits);
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect_bits(arch: &mut Arch) -> Vec<u32> {
        let mut v = Vec::new();
        for_each_matmul_bits(&mut arch.ops, &mut |b| v.push(*b));
        v
    }

    #[test]
    fn cnn_small_layout_and_bit_pinning() {
        let mut a = build("cnn_small", 32, 3, 10, 2).unwrap();
        assert_eq!(a.n_matmul, 5);
        assert_eq!(collect_bits(&mut a), vec![8, 2, 2, 2, 8]);
    }

    #[test]
    fn mlp_two_layers_both_pinned() {
        let mut a = build("mlp", 32, 3, 10, 2).unwrap();
        assert_eq!(a.n_matmul, 2);
        assert_eq!(collect_bits(&mut a), vec![8, 8]);
    }

    #[test]
    fn resnet20_matmul_count() {
        // stem + 9 blocks x (conv1, conv2) + 2 projections (stage 1, 2) + fc
        let a = build("resnet20", 32, 3, 10, 4).unwrap();
        assert_eq!(a.n_matmul, 1 + 9 * 2 + 2 + 1);
    }

    #[test]
    fn fp32_build_has_no_quantizers() {
        let mut a = build("cnn_small", 32, 3, 10, 32).unwrap();
        assert!(collect_bits(&mut a).iter().all(|&b| b == 32));
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(build("sqnxt_small", 32, 3, 10, 2).is_err());
    }
}
