//! Synthetic artifact fixtures: write a `manifest.json` + initial
//! `params.bin` for one model family so the native backend (and the serve
//! stack above it) can run with **zero** Python/XLA steps.
//!
//! The parameter registration order, naming and roles replicate
//! `python/compile/layers.Ctx` exactly (conv: `w, sw, sa`; dense:
//! `w, sw, sa, b`; batch norm: `gamma, beta, rmean, rvar`), so a fixture
//! family is indistinguishable from a real AOT one to everything that
//! consumes the manifest. Used by `tests/native.rs`, `benches/serve.rs`
//! and the `serve_quantized` example's no-artifacts path.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::quant::lsq::{qrange, step_init};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::arch::{self, ArchOp, BnSpec, ConvSpec, DenseSpec};

struct ParamWriter {
    rng: Pcg32,
    names: Vec<String>,
    roles: BTreeMap<String, Json>,
    shapes: BTreeMap<String, Json>,
    data: Vec<f32>,
    layer_meta: Vec<Json>,
}

impl ParamWriter {
    fn push(&mut self, name: String, role: &str, shape: &[usize], values: Vec<f32>) {
        assert_eq!(values.len(), shape.iter().product::<usize>().max(1), "{name}");
        self.roles.insert(name.clone(), Json::str(role));
        self.shapes
            .insert(name.clone(), Json::Arr(shape.iter().map(|&d| Json::num(d as f64)).collect()));
        self.names.push(name);
        self.data.extend_from_slice(&values);
    }

    fn kaiming(&mut self, shape: &[usize]) -> Vec<f32> {
        let fan_in: usize = shape[..shape.len() - 1].iter().product();
        let scale = (2.0 / fan_in.max(1) as f32).sqrt();
        (0..shape.iter().product::<usize>()).map(|_| self.rng.normal() * scale).collect()
    }

    fn matmul(&mut self, name: &str, shape: &[usize], bits: u32, signed_act: bool) -> Vec<f32> {
        let w = self.kaiming(shape);
        self.push(format!("{name}.w"), "weight", shape, w.clone());
        if bits < 32 {
            let (_, qp_w) = qrange(bits, true);
            let sw = step_init(&w, qp_w).max(1e-6);
            // Activation steps: the Section-2.1 data-driven init assuming
            // standardized inputs (mean |v| ~ 0.8).
            let (_, qp_a) = qrange(bits, signed_act);
            let sa = (2.0 * 0.8 / (qp_a.max(1) as f64).sqrt()) as f32;
            self.push(format!("{name}.sw"), "step_w", &[], vec![sw]);
            self.push(format!("{name}.sa"), "step_a", &[], vec![sa]);
        }
        self.layer_meta.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("n_weights", Json::num(shape.iter().product::<usize>() as f64)),
            ("bits", Json::num(bits.min(32) as f64)),
        ]));
        w
    }

    fn conv(&mut self, c: &ConvSpec) {
        self.matmul(&c.name, &[c.kh, c.kw, c.in_ch, c.out_ch], c.bits, c.signed_act);
    }

    fn dense(&mut self, d: &DenseSpec) {
        self.matmul(&d.name, &[d.in_dim, d.out_dim], d.bits, d.signed_act);
        self.push(format!("{}.b", d.name), "bias", &[d.out_dim], vec![0.0; d.out_dim]);
    }

    fn bn(&mut self, b: &BnSpec) {
        self.push(format!("{}.gamma", b.name), "bias", &[b.ch], vec![1.0; b.ch]);
        self.push(format!("{}.beta", b.name), "bias", &[b.ch], vec![0.0; b.ch]);
        self.push(format!("{}.rmean", b.name), "state", &[b.ch], vec![0.0; b.ch]);
        self.push(format!("{}.rvar", b.name), "state", &[b.ch], vec![1.0; b.ch]);
    }

    fn visit(&mut self, ops: &[ArchOp]) {
        for op in ops {
            match op {
                ArchOp::Conv(c) => self.conv(c),
                ArchOp::Dense(d) => self.dense(d),
                ArchOp::BatchNorm(b) => self.bn(b),
                ArchOp::Preact(p) => {
                    self.bn(&p.bn1);
                    if let Some(proj) = &p.proj {
                        self.conv(proj);
                    }
                    self.conv(&p.conv1);
                    self.bn(&p.bn2);
                    self.conv(&p.conv2);
                }
                ArchOp::Relu | ArchOp::MaxPool2 | ArchOp::GlobalAvgPool | ArchOp::Flatten => {}
            }
        }
    }
}

/// Geometry knobs for a synthetic family. `Default` matches the real
/// artifact set (32×32×3 images, 10 classes, batch 8).
#[derive(Clone, Copy, Debug)]
pub struct FixtureSpec {
    /// Input image side length.
    pub image: usize,
    /// Input channels.
    pub channels: usize,
    /// Output classes.
    pub num_classes: usize,
    /// Manifest-level preferred batch size.
    pub batch: usize,
    /// Parameter-init RNG seed.
    pub seed: u64,
}

impl Default for FixtureSpec {
    fn default() -> Self {
        FixtureSpec { image: 32, channels: 3, num_classes: 10, batch: 8, seed: 17 }
    }
}

/// Write `manifest.json` + `params_{family}.bin` for `model` at `qbits`
/// into `dir` (created if needed). Returns the family name
/// (`"{model}_q{qbits}"`).
///
/// When `dir` already holds a manifest with the same geometry, the new
/// family is **merged** into it (existing families and artifacts are
/// preserved) — this is what lets one fixture directory serve the paper's
/// fp32-pretrain → per-precision fine-tune protocol and multi-family
/// native sweeps. A geometry mismatch is an error, not a silent overwrite.
pub fn write_synthetic_family(
    dir: &Path,
    model: &str,
    qbits: u32,
    spec: FixtureSpec,
) -> Result<String> {
    let arch = arch::build(model, spec.image, spec.channels, spec.num_classes, qbits)?;
    let mut pw = ParamWriter {
        rng: Pcg32::seeded(spec.seed),
        names: Vec::new(),
        roles: BTreeMap::new(),
        shapes: BTreeMap::new(),
        data: Vec::new(),
        layer_meta: Vec::new(),
    };
    pw.visit(&arch.ops);

    let family = format!("{model}_q{qbits}");
    let params_bin = format!("params_{family}.bin");
    std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
    let bytes: Vec<u8> = pw.data.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(dir.join(&params_bin), bytes)
        .with_context(|| format!("write {params_bin}"))?;

    // Everything with a role other than `state` receives gradients.
    let grad_names: Vec<Json> = pw
        .names
        .iter()
        .filter(|n| pw.roles.get(*n).and_then(Json::as_str) != Some("state"))
        .map(|n| Json::str(n.clone()))
        .collect();
    let fam_json = Json::obj(vec![
        ("model", Json::str(model)),
        ("qbits", Json::num(qbits as f64)),
        ("num_classes", Json::num(spec.num_classes as f64)),
        ("params_bin", Json::str(params_bin)),
        ("n_matmul", Json::num(arch.n_matmul as f64)),
        (
            "param_names",
            Json::Arr(pw.names.iter().map(|n| Json::str(n.clone())).collect()),
        ),
        ("grad_names", Json::Arr(grad_names)),
        ("roles", Json::Obj(pw.roles.clone())),
        ("shapes", Json::Obj(pw.shapes.clone())),
        ("layer_meta", Json::Arr(pw.layer_meta.clone())),
    ]);
    let manifest_path = dir.join("manifest.json");
    let manifest = if manifest_path.exists() {
        // Merge into the existing manifest (see doc comment above).
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?}"))?;
        let parsed = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{manifest_path:?}: {e}"))?;
        for (key, want) in [
            ("batch", spec.batch),
            ("image", spec.image),
            ("channels", spec.channels),
        ] {
            let have = parsed.usize_at(key)?;
            anyhow::ensure!(
                have == want,
                "fixture geometry mismatch in {manifest_path:?}: {key} is {have}, \
                 new family wants {want}"
            );
        }
        match parsed {
            Json::Obj(mut top) => {
                match top.get_mut("families") {
                    Some(Json::Obj(fams)) => {
                        fams.insert(family.clone(), fam_json);
                    }
                    _ => anyhow::bail!("{manifest_path:?}: missing families object"),
                }
                Json::Obj(top)
            }
            _ => anyhow::bail!("{manifest_path:?}: manifest is not an object"),
        }
    } else {
        let mut families = BTreeMap::new();
        families.insert(family.clone(), fam_json);
        Json::obj(vec![
            ("batch", Json::num(spec.batch as f64)),
            ("image", Json::num(spec.image as f64)),
            ("channels", Json::num(spec.channels as f64)),
            ("num_classes", Json::num(spec.num_classes as f64)),
            ("families", Json::Obj(families)),
            ("artifacts", Json::Arr(Vec::new())),
        ])
    };
    std::fs::write(&manifest_path, manifest.to_string_pretty())
        .with_context(|| "write manifest.json")?;
    Ok(family)
}

/// Ensure `dir` holds the family `name` (of the `model_qBITS` form, e.g.
/// `cnn_small_q2`), synthesizing it — with the existing manifest's
/// geometry, or [`FixtureSpec::default`] when there is no manifest — when
/// absent. Errors when `name` is neither already present nor of the
/// synthesizable form. This is the single name-driven entry point the
/// serve CLI and examples share, so the `model_qBITS` parse and the
/// geometry-reuse logic live in exactly one place.
pub fn ensure_family_by_name(dir: &Path, name: &str) -> Result<String> {
    let spec = match crate::runtime::Manifest::load(dir) {
        Ok(m) => {
            if m.families.contains_key(name) {
                return Ok(name.to_string());
            }
            FixtureSpec {
                image: m.image,
                channels: m.channels,
                batch: m.batch,
                ..FixtureSpec::default()
            }
        }
        Err(_) => FixtureSpec::default(),
    };
    let (model, qbits) = name
        .rsplit_once("_q")
        .and_then(|(m, b)| b.parse::<u32>().ok().map(|b| (m.to_string(), b)))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "family {name:?} is not in {} and is not of the form model_qBITS, \
                 so a synthetic family cannot be generated",
                dir.display()
            )
        })?;
    println!("(no {name} in {} — writing a synthetic fixture family)", dir.display());
    write_synthetic_family(dir, &model, qbits, spec)
}

/// Ensure `dir` holds a loadable family `{model}_q{qbits}`, writing a
/// synthetic one (merged into any existing manifest) when absent. Returns
/// the family name. This is the zero-artifacts entry point the native
/// `train`/`sweep` CLI paths use.
pub fn ensure_family(dir: &Path, model: &str, qbits: u32, spec: FixtureSpec) -> Result<String> {
    let family = format!("{model}_q{qbits}");
    if dir.join("manifest.json").exists() {
        if let Ok(m) = crate::runtime::Manifest::load(dir) {
            if let Some(fam) = m.families.get(&family) {
                // Reusing a family with a different logit count would
                // panic later on out-of-range labels — fail cleanly here.
                anyhow::ensure!(
                    fam.num_classes == spec.num_classes,
                    "family {family} in {dir:?} has {} classes, requested {} — \
                     use a fresh artifacts dir or matching --config classes",
                    fam.num_classes,
                    spec.num_classes
                );
                return Ok(family);
            }
        }
    }
    write_synthetic_family(dir, model, qbits, spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn families_merge_into_one_manifest() {
        let dir = std::env::temp_dir().join(format!("lsq_fixmerge_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let spec = FixtureSpec { image: 8, channels: 3, num_classes: 4, batch: 2, seed: 7 };
        let fam32 = write_synthetic_family(&dir, "mlp", 32, spec).unwrap();
        let fam3 = write_synthetic_family(&dir, "mlp", 3, spec).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.families.contains_key(&fam32) && m.families.contains_key(&fam3));
        assert!(m.load_initial_params(&fam32).is_ok());
        assert!(m.load_initial_params(&fam3).is_ok());
        // ensure_family is idempotent and geometry mismatches are rejected
        assert_eq!(ensure_family(&dir, "mlp", 3, spec).unwrap(), fam3);
        let bad = FixtureSpec { image: 16, ..spec };
        assert!(write_synthetic_family(&dir, "mlp", 2, bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fixture_manifest_loads_and_params_bind() {
        let dir = std::env::temp_dir().join(format!("lsq_fixture_{}", std::process::id()));
        let family =
            write_synthetic_family(&dir, "cnn_small", 2, FixtureSpec::default()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let fam = m.family(&family).unwrap();
        assert_eq!(fam.model, "cnn_small");
        assert_eq!(fam.n_matmul, 5);
        let params = m.load_initial_params(&family).unwrap();
        assert_eq!(params.len(), fam.param_names.len());
        // The native model builds from the fixture end to end.
        let model = super::super::NativeModel::build(&m, &family, &params).unwrap();
        assert!(model.packed_bytes > 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
