//! Integer GEMM kernels over bit-packed weights — the native datapath of
//! the paper's Figure 1: activations quantized to integers per Eq. 1,
//! multiply-accumulate in `i32`, one fp32 rescale by `s_a * s_w` (Eq. 2) at
//! the end.
//!
//! The weight matrix stays in its [`Packed`] 2/3/4/8-bit form; the kernel
//! unpacks KC×NC tiles into a small integer scratch buffer inside the
//! cache-blocked loop ("fused unpack-and-dot"), so the full-precision
//! weight matrix never materializes. Accumulation is exact in `i32`
//! provided `k * Qp_act * max(Qn_w, Qp_w) < 2^31`, which
//! [`check_accumulator_bound`] verifies at model-build time (for 8-bit
//! weights/activations that allows k up to ~65k — far above any layer in
//! the model zoo).

use crate::quant::pack::{unpack_range, Packed};

/// Rows of the packed weight matrix per tile (the k blocking factor).
pub const KC: usize = 256;
/// Columns of the packed weight matrix per tile (the n blocking factor).
pub const NC: usize = 64;

/// `true` iff an `i32` accumulator cannot overflow for a length-`k` dot
/// product of activations in `[-qn_a, qp_a]` with weights in
/// `[-qn_w, qp_w]`.
pub fn check_accumulator_bound(k: usize, qp_a: i64, qn_a: i64, qn_w: i64, qp_w: i64) -> bool {
    let amax = qp_a.max(qn_a);
    let wmax = qn_w.max(qp_w);
    (k as i64)
        .checked_mul(amax)
        .and_then(|v| v.checked_mul(wmax))
        .map(|v| v < i32::MAX as i64)
        .unwrap_or(false)
}

/// Quantized GEMM: `out[m×n] = (x[m×k] · unpack(w)[k×n]) * scale (+ bias)`.
///
/// * `x` — integer activations (Eq. 1 `v̄` values), row-major `m×k`;
/// * `w` — bit-packed weights, logically row-major `k×n` (`w.len == k*n`);
/// * `scale` — the per-layer `s_a * s_w` rescale (Eq. 2 applied to both
///   operands at once);
/// * `bias` — optional fp32 bias of length `n`, added after the rescale.
///
/// Zero activations (the common case after ReLU + unsigned quantization)
/// skip their inner row entirely.
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    m: usize,
    k: usize,
    n: usize,
    x: &[i32],
    w: &Packed,
    scale: f32,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), m * k, "activation buffer shape");
    assert_eq!(w.len, k * n, "packed weight shape");
    assert_eq!(out.len(), m * n, "output buffer shape");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias length");
    }

    let mut acc = vec![0i32; m * n];
    let mut wtile = vec![0i32; KC * NC];
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        for n0 in (0..n).step_by(NC) {
            let nc = NC.min(n - n0);
            // Unpack this KC×NC weight tile once; it then stays hot in
            // cache for all m activation rows.
            for kk in 0..kc {
                unpack_range(w, (k0 + kk) * n + n0, nc, &mut wtile[kk * nc..kk * nc + nc]);
            }
            for i in 0..m {
                let xrow = &x[i * k + k0..i * k + k0 + kc];
                let arow = &mut acc[i * n + n0..i * n + n0 + nc];
                for (kk, &xv) in xrow.iter().enumerate() {
                    if xv == 0 {
                        continue;
                    }
                    let wrow = &wtile[kk * nc..kk * nc + nc];
                    for (a, &wv) in arow.iter_mut().zip(wrow) {
                        *a += xv * wv;
                    }
                }
            }
        }
    }

    match bias {
        Some(b) => {
            for i in 0..m {
                for j in 0..n {
                    out[i * n + j] = acc[i * n + j] as f32 * scale + b[j];
                }
            }
        }
        None => {
            for (o, &a) in out.iter_mut().zip(&acc) {
                *o = a as f32 * scale;
            }
        }
    }
}

/// fp32 GEMM with the same blocking, for the model zoo's full-precision
/// (bits ≥ 32) layers: `out[m×n] = x[m×k] · w[k×n] (+ bias)`.
pub fn sgemm(
    m: usize,
    k: usize,
    n: usize,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), m * k, "activation buffer shape");
    assert_eq!(w.len(), k * n, "weight shape");
    assert_eq!(out.len(), m * n, "output buffer shape");

    match bias {
        Some(b) => {
            for i in 0..m {
                for j in 0..n {
                    out[i * n + j] = b[j];
                }
            }
        }
        None => out.fill(0.0),
    }
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        for i in 0..m {
            let xrow = &x[i * k + k0..i * k + k0 + kc];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
    }
}

/// Transposed-B fp32 GEMM: `out[m×k] = a[m×n] · w[k×n]ᵀ`.
///
/// This is the data-gradient path of the native backward pass
/// (`dX̂ = dY · Ŵᵀ`, see `crate::train::native::backward`): both `a` rows
/// and `w` rows are contiguous, so the inner dot runs stride-1 on both
/// operands with no transpose materialized.
pub fn sgemm_nt(m: usize, k: usize, n: usize, a: &[f32], w: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * n, "a shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * k, "output shape");
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (kk, o) in orow.iter_mut().enumerate() {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &wv) in arow.iter().zip(wrow) {
                acc += av * wv;
            }
            *o = acc;
        }
    }
}

/// Transposed-A fp32 GEMM: `out[k×n] = x[m×k]ᵀ · dy[m×n]`.
///
/// The weight-gradient path of the native backward pass
/// (`dŴ = X̂ᵀ · dY`). Layout mirrors [`sgemm`]: the inner loop streams a
/// `dy` row into an `out` row, skipping zero activations (common after
/// ReLU + unsigned quantization).
pub fn sgemm_tn(m: usize, k: usize, n: usize, x: &[f32], dy: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(dy.len(), m * n, "dy shape");
    assert_eq!(out.len(), k * n, "output shape");
    out.fill(0.0);
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        for kk in 0..k {
            let xv = x[i * k + kk];
            if xv == 0.0 {
                continue;
            }
            let orow = &mut out[kk * n..(kk + 1) * n];
            for (o, &dv) in orow.iter_mut().zip(dyrow) {
                *o += xv * dv;
            }
        }
    }
}

/// Adjoint of [`im2col`]: scatter-accumulate patch-space gradients
/// `dcols[b*oh*ow × kh*kw*c]` back onto the input image grid
/// `dx[b×h×w×c]` (which must be pre-zeroed). Taps that fell in the SAME
/// zero padding are dropped, exactly mirroring the forward gather.
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    dcols: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    dx: &mut [f32],
) {
    assert_eq!(dx.len(), b * h * w * c, "dx shape");
    let (oh, pad_t) = same_padding(h, kh, stride);
    let (ow, pad_l) = same_padding(w, kw, stride);
    let patch = kh * kw * c;
    assert_eq!(dcols.len(), b * oh * ow * patch, "dcols shape");
    for bi in 0..b {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad_t as isize;
            for ox in 0..ow {
                let ix0 = (ox * stride) as isize - pad_l as isize;
                let row = ((bi * oh + oy) * ow + ox) * patch;
                for dh in 0..kh {
                    let iy = iy0 + dh as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for dw in 0..kw {
                        let ix = ix0 + dw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let dst = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let src = row + (dh * kw + dw) * c;
                        for ch in 0..c {
                            dx[dst + ch] += dcols[src + ch];
                        }
                    }
                }
            }
        }
    }
}

/// SAME-padding geometry for one spatial dim: returns `(out_size,
/// pad_before)`, matching XLA's `padding="SAME"` (pad_before = total/2,
/// rounded down).
pub fn same_padding(size: usize, kernel: usize, stride: usize) -> (usize, usize) {
    let out = (size + stride - 1) / stride;
    let pad_total = ((out - 1) * stride + kernel).saturating_sub(size);
    (out, pad_total / 2)
}

/// im2col for NHWC input: writes `b*oh*ow` rows of `kh*kw*c` patch elements
/// (ordered `(dh, dw, cin)`, matching row-major flattened HWIO weights)
/// into `out`, zero-padding out-of-bounds taps. Returns `(oh, ow)`.
#[allow(clippy::too_many_arguments)]
pub fn im2col<T: Copy>(
    x: &[T],
    zero: T,
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    out: &mut Vec<T>,
) -> (usize, usize) {
    assert_eq!(x.len(), b * h * w * c, "input shape");
    let (oh, pad_t) = same_padding(h, kh, stride);
    let (ow, pad_l) = same_padding(w, kw, stride);
    let patch = kh * kw * c;
    out.clear();
    out.resize(b * oh * ow * patch, zero);
    for bi in 0..b {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad_t as isize;
            for ox in 0..ow {
                let ix0 = (ox * stride) as isize - pad_l as isize;
                let row = ((bi * oh + oy) * ow + ox) * patch;
                for dh in 0..kh {
                    let iy = iy0 + dh as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for dw in 0..kw {
                        let ix = ix0 + dw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (dh * kw + dw) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    (oh, ow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack;

    #[test]
    fn same_padding_matches_xla() {
        assert_eq!(same_padding(32, 3, 1), (32, 1));
        assert_eq!(same_padding(32, 3, 2), (16, 0)); // total pad 1 -> (0, 1)
        assert_eq!(same_padding(16, 1, 1), (16, 0));
        assert_eq!(same_padding(16, 1, 2), (8, 0));
    }

    #[test]
    fn qgemm_matches_naive_i64() {
        let (m, k, n) = (3usize, 70usize, 9usize);
        let mut rng = crate::util::rng::Pcg32::seeded(7);
        let x: Vec<i32> = (0..m * k).map(|_| rng.below(8) as i32 - 4).collect();
        let wv: Vec<i32> = (0..k * n).map(|_| rng.below(15) as i32 - 7).collect();
        let p = pack(&wv, 4, true, 0.5).unwrap();
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.25).collect();
        let mut out = vec![0.0f32; m * n];
        qgemm(m, k, n, &x, &p, 0.5, Some(&bias), &mut out);
        for i in 0..m {
            for j in 0..n {
                let acc: i64 =
                    (0..k).map(|kk| x[i * k + kk] as i64 * wv[kk * n + j] as i64).sum();
                let want = acc as f32 * 0.5 + bias[j];
                assert!(
                    (out[i * n + j] - want).abs() < 1e-4,
                    "({i},{j}): {} vs {want}",
                    out[i * n + j]
                );
            }
        }
    }

    #[test]
    fn qgemm_blocks_cover_large_shapes() {
        // k and n straddle the KC/NC tile boundaries.
        let (m, k, n) = (2usize, KC + 13, NC + 5);
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        let x: Vec<i32> = (0..m * k).map(|_| rng.below(4) as i32).collect();
        let wv: Vec<i32> = (0..k * n).map(|_| rng.below(3) as i32 - 1).collect();
        let p = pack(&wv, 2, true, 1.0).unwrap();
        let mut out = vec![0.0f32; m * n];
        qgemm(m, k, n, &x, &p, 1.0, None, &mut out);
        for i in 0..m {
            for j in 0..n {
                let acc: i64 =
                    (0..k).map(|kk| x[i * k + kk] as i64 * wv[kk * n + j] as i64).sum();
                assert_eq!(out[i * n + j], acc as f32, "({i},{j})");
            }
        }
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1: im2col is the identity.
        let x: Vec<f32> = (0..2 * 3 * 3 * 2).map(|i| i as f32).collect();
        let mut out = Vec::new();
        let (oh, ow) = im2col(&x, 0.0, 2, 3, 3, 2, 1, 1, 1, &mut out);
        assert_eq!((oh, ow), (3, 3));
        assert_eq!(out, x);
    }

    #[test]
    fn im2col_pads_borders_with_zeros() {
        // Single 2x2 image, one channel, 3x3 kernel: the center patch sees
        // all four pixels, corners of the patch are zero padding.
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut out = Vec::new();
        let (oh, ow) = im2col(&x, 0.0, 1, 2, 2, 1, 3, 3, 1, &mut out);
        assert_eq!((oh, ow), (2, 2));
        // Row for output (0,0): taps at (dy-1, dx-1) relative offsets.
        let r0 = &out[0..9];
        assert_eq!(r0, &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn sgemm_nt_matches_naive_transpose() {
        let (m, k, n) = (3usize, 5usize, 7usize);
        let mut rng = crate::util::rng::Pcg32::seeded(21);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f32; m * k];
        sgemm_nt(m, k, n, &a, &w, &mut out);
        for i in 0..m {
            for kk in 0..k {
                let want: f32 = (0..n).map(|j| a[i * n + j] * w[kk * n + j]).sum();
                assert!((out[i * k + kk] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn sgemm_tn_matches_naive_transpose() {
        let (m, k, n) = (4usize, 6usize, 3usize);
        let mut rng = crate::util::rng::Pcg32::seeded(22);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f32; k * n];
        sgemm_tn(m, k, n, &x, &dy, &mut out);
        for kk in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| x[i * k + kk] * dy[i * n + j]).sum();
                assert!((out[kk * n + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y — the defining
        // property of the transposed scatter, covering padding and stride.
        let (b, h, w, c, kh, kw) = (2usize, 5usize, 4usize, 3usize, 3usize, 3usize);
        for stride in [1usize, 2] {
            let mut rng = crate::util::rng::Pcg32::seeded(23 + stride as u64);
            let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal()).collect();
            let mut cols = Vec::new();
            let (oh, ow) = im2col(&x, 0.0f32, b, h, w, c, kh, kw, stride, &mut cols);
            let y: Vec<f32> = (0..b * oh * ow * kh * kw * c).map(|_| rng.normal()).collect();
            let fwd: f64 = cols.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
            let mut dx = vec![0.0f32; b * h * w * c];
            col2im(&y, b, h, w, c, kh, kw, stride, &mut dx);
            let adj: f64 = x.iter().zip(&dx).map(|(a, b)| (a * b) as f64).sum();
            assert!((fwd - adj).abs() < 1e-3 * fwd.abs().max(1.0), "stride={stride}");
        }
    }

    #[test]
    fn accumulator_bound() {
        assert!(check_accumulator_bound(65_000, 255, 0, 128, 127));
        assert!(!check_accumulator_bound(66_000, 255, 0, 128, 127));
    }
}
