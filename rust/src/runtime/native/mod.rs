//! Native pure-Rust inference backend: runs the quantized forward pass
//! directly from bit-packed weights, with no XLA/PJRT dependency.
//!
//! This is the deployment path the paper motivates (Figure 1 / McKinstry et
//! al. 2018): weights arrive in their 2/3/4/8-bit
//! [`crate::quant::pack::Packed`] form, activations are quantized to
//! integers per Eq. 1 on entry to every conv/dense layer, the
//! multiply-accumulate runs in `i32` through the SIMD-dispatched panel
//! kernels ([`crate::runtime::kernels::qgemm_panel`] by default — weights
//! unpacked once at bind time; [`UnpackMode::Fused`] keeps the per-call
//! fused unpack for memory-constrained hosts), and a single fp32 rescale
//! by `s_a * s_w` applies Eq. 2 to the result. Layers the paper keeps in
//! full precision (`qbits >= 32` families) fall back to an fp32 GEMM.
//!
//! All compute routes through the shared kernel layer
//! ([`crate::runtime::kernels`]): the forward draws every activation,
//! im2col, and quantized-activation buffer from a caller-provided
//! [`Workspace`], so the steady-state serving hot path allocates only the
//! exact-size logits `Vec` it returns (pool buffers never escape), and
//! the GEMMs run multi-threaded under the workspace's intra-op thread cap
//! ([`PrepareOptions::intra_op_threads`]).
//!
//! Unlike the XLA engine, [`NativeEngine`] is `Send`, needs only
//! `manifest.json` + the family's `params.bin` (no HLO artifacts), and can
//! therefore be replicated across serve worker threads — see DESIGN.md
//! §Backend-trait.
//!
//! Submodules: [`arch`] (model-zoo IR mirroring `python/compile/models.py`),
//! [`fixture`] (synthetic manifest/params for artifact-free tests and
//! benches).

pub mod arch;
pub mod fixture;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Result};

use std::sync::Arc;

use crate::quant::lsq::{self, qrange};
use crate::quant::pack::{quantize_and_pack, Packed};
use crate::runtime::artifact::LoadedArtifact;
use crate::runtime::backend::{Backend, PrepareOptions};
use crate::runtime::kernels::{self, check_accumulator_bound, PanelizedWeights, Workspace};
use crate::runtime::Manifest;
use crate::tensor::Tensor;

use arch::{Arch, ArchOp, BnSpec, ConvSpec, DenseSpec};

/// How a bound [`NativeModel`] stores its sub-32-bit weights for the
/// forward pass (DESIGN.md §SIMD-dispatch):
///
/// * [`UnpackMode::Panelized`] — unpack every layer **once** at bind time
///   into the kernel layer's shared i8 panel layout
///   ([`PanelizedWeights`]); forward calls do zero unpack work. The
///   packed byte buffer is dropped after the build (the panels *are* the
///   working set), so the resident cost is ~`k·n` bytes per layer
///   (reported as [`NativeModel::panel_bytes`]) instead of the
///   `k·n·bits/8` packed form.
/// * [`UnpackMode::Fused`] — keep only the packed bits; each forward call
///   unpacks KC×NC tiles into per-thread scratch on the fly (the
///   pre-panelization behavior). The low-memory choice for constrained
///   deployments: `PrepareOptions::low_memory` (surfaced as
///   `ServerConfig::fused_unpack` / `VariantOptions::low_memory` in the
///   serve layer) or `LSQNET_FUSED_UNPACK=1`.
///
/// Both modes produce bitwise-identical logits (`tests/kernels.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnpackMode {
    /// Panels built once at bind; fastest serving (the default).
    Panelized,
    /// Per-call fused unpack; smallest resident footprint.
    Fused,
}

impl UnpackMode {
    /// The process default: [`UnpackMode::Panelized`], unless
    /// `LSQNET_FUSED_UNPACK` is set to anything but `0` (shared truthy
    /// rule: [`crate::util::env_truthy`]; read once per process, like the
    /// kernel layer's other env knobs).
    pub fn default_mode() -> UnpackMode {
        static MODE: std::sync::OnceLock<UnpackMode> = std::sync::OnceLock::new();
        *MODE.get_or_init(|| {
            if crate::util::env_truthy("LSQNET_FUSED_UNPACK") {
                UnpackMode::Fused
            } else {
                UnpackMode::Panelized
            }
        })
    }
}

/// Weight storage for one matmul layer.
enum LayerWeights {
    /// [`UnpackMode::Panelized`]: bind-time panels plus the Eq. 2 steps.
    /// The packed byte buffer is **dropped** once the panels are built —
    /// the forward path reads only `sw` — and `storage_bytes` preserves
    /// the Figure-3 accounting the bits would have reported.
    Panel {
        panel: PanelizedWeights,
        sw: f32,
        storage_bytes: usize,
        sa: f32,
        act_qn: i64,
        act_qp: i64,
    },
    /// [`UnpackMode::Fused`]: packed integer weights (step = `s_w`) kept
    /// resident; tiles unpack per call.
    Packed { w: Packed, sa: f32, act_qn: i64, act_qp: i64 },
    /// Full-precision path for `bits >= 32` layers.
    F32(Vec<f32>),
}

impl LayerWeights {
    /// The quantized-path parameters: `(s_a·s_w rescale, s_a, act range)`.
    ///
    /// # Panics
    /// On the fp32 variant — callers match that arm away first.
    fn quant_params(&self) -> (f32, f32, i64, i64) {
        match self {
            LayerWeights::Panel { sw, sa, act_qn, act_qp, .. } => {
                (sa * sw, *sa, *act_qn, *act_qp)
            }
            LayerWeights::Packed { w, sa, act_qn, act_qp } => (sa * w.step, *sa, *act_qn, *act_qp),
            LayerWeights::F32(_) => unreachable!("quant_params on an fp32 layer"),
        }
    }
}

struct RtConv {
    spec: ConvSpec,
    wq: LayerWeights,
}

struct RtDense {
    spec: DenseSpec,
    wq: LayerWeights,
    bias: Option<Vec<f32>>,
}

/// Eval-mode batch norm folded to `y = x * scale + shift` per channel
/// ([`kernels::fold_bn`]).
struct RtBn {
    scale: Vec<f32>,
    shift: Vec<f32>,
}

struct RtPreact {
    bn1: RtBn,
    proj: Option<RtConv>,
    conv1: RtConv,
    bn2: RtBn,
    conv2: RtConv,
}

enum RtOp {
    Conv(RtConv),
    Dense(RtDense),
    Bn(RtBn),
    Relu,
    MaxPool2,
    GlobalAvgPool,
    Flatten,
    Preact(Box<RtPreact>),
}

/// A model family bound to concrete parameters, with weights already
/// quantized (Eq. 1) and bit-packed, ready for the native forward pass.
pub struct NativeModel {
    family: String,
    image: usize,
    channels: usize,
    num_classes: usize,
    ops: Vec<RtOp>,
    /// Total packed weight bytes (including per-layer fp32 steps) — the
    /// Figure 3 storage axis.
    pub packed_bytes: usize,
    /// Resident bytes of the bind-time weight panels (0 in
    /// [`UnpackMode::Fused`]) — the memory the panelized fast path adds on
    /// top of `packed_bytes`.
    pub panel_bytes: usize,
}

/// Host activation tensor used inside the interpreted forward pass. The
/// backing `data` buffer cycles through the caller's [`Workspace`] pool.
struct Act {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Act {
    fn dims4(&self) -> Result<(usize, usize, usize, usize)> {
        match self.shape[..] {
            [b, h, w, c] => Ok((b, h, w, c)),
            _ => bail!("expected a 4-d NHWC activation, got shape {:?}", self.shape),
        }
    }
}

struct Binder<'a> {
    family: &'a str,
    map: BTreeMap<&'a str, &'a Tensor>,
}

impl<'a> Binder<'a> {
    fn tensor(&self, name: &str) -> Result<&'a Tensor> {
        self.map
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("family {} has no parameter {name:?}", self.family))
    }

    fn scalar(&self, name: &str) -> Result<f32> {
        self.tensor(name)?.item_f32()
    }
}

/// A weight-binding strategy: how one matmul layer's [`LayerWeights`]
/// come to exist. The manifest path ([`bind_weights`]) quantizes, packs,
/// and panelizes from the raw fp32 tensor; the artifact path
/// ([`bind_weights_art`]) borrows prebuilt panels from a
/// [`LoadedArtifact`] arena. Everything else about binding (graph walk,
/// BN folding, biases, accounting) is shared.
type WeightBinder<'a> =
    &'a dyn Fn(&Binder, &str, u32, bool, usize, &[usize], UnpackMode) -> Result<LayerWeights>;

fn bind_weights(
    binder: &Binder,
    name: &str,
    bits: u32,
    signed_act: bool,
    k: usize,
    want_shape: &[usize],
    mode: UnpackMode,
) -> Result<LayerWeights> {
    let w = binder.tensor(&format!("{name}.w"))?;
    ensure!(
        w.shape == want_shape,
        "{name}.w shape {:?} != expected {:?}",
        w.shape,
        want_shape
    );
    if bits >= 32 {
        return Ok(LayerWeights::F32(w.f32s()?.to_vec()));
    }
    let sw = binder.scalar(&format!("{name}.sw"))?;
    let sa = binder.scalar(&format!("{name}.sa"))?;
    ensure!(sw > 0.0 && sa > 0.0, "{name}: non-positive step size (sw={sw}, sa={sa})");
    let (act_qn, act_qp) = qrange(bits, signed_act);
    let (wqn, wqp) = qrange(bits, true);
    ensure!(
        check_accumulator_bound(k, act_qp, act_qn, wqn, wqp),
        "{name}: k={k} at {bits}-bit would overflow the i32 accumulator"
    );
    let packed = quantize_and_pack(w.f32s()?, sw, bits, true)?;
    // The weight matrix is logically k×n with n the trailing axis of the
    // parameter shape (kh·kw·in × out for convs, in × out for dense) —
    // exactly the row-major layout the GEMM consumes.
    let n = *want_shape.last().expect("non-empty weight shape");
    Ok(match mode {
        UnpackMode::Panelized => LayerWeights::Panel {
            storage_bytes: packed.storage_bytes() + 4, // + s_a
            sw: packed.step,
            // Bind-time autotuned blocking; the activation bound gates
            // i8-activation (ki=4) candidate geometries.
            panel: PanelizedWeights::build_for_acts(&packed, k, n, act_qp.max(act_qn)),
            sa,
            act_qn,
            act_qp,
            // `packed` drops here: panels hold the working set.
        },
        UnpackMode::Fused => LayerWeights::Packed { w: packed, sa, act_qn, act_qp },
    })
}

/// The artifact-path [`WeightBinder`]: sub-32-bit layers bind to panel
/// blocks *borrowed* from the artifact arena (zero quantize/pack/
/// panelize work — the panel-build counter stays flat), falling back to
/// the artifact's packed bytes and a normal counted build only when the
/// artifact carries no panels section this host can use. Step sizes,
/// biases, and fp32 weights come from the artifact's tensor records via
/// the same [`Binder`] the manifest path uses, so validation and the
/// Eq. 2 rescale are identical — which is what makes the logits bitwise
/// equal across the two paths.
fn bind_weights_art(
    art: &LoadedArtifact,
    binder: &Binder,
    name: &str,
    bits: u32,
    signed_act: bool,
    k: usize,
    want_shape: &[usize],
    mode: UnpackMode,
) -> Result<LayerWeights> {
    if bits >= 32 {
        let w = binder.tensor(&format!("{name}.w"))?;
        ensure!(
            w.shape == want_shape,
            "{name}.w shape {:?} != expected {:?}",
            w.shape,
            want_shape
        );
        return Ok(LayerWeights::F32(w.f32s()?.to_vec()));
    }
    let sw = binder.scalar(&format!("{name}.sw"))?;
    let sa = binder.scalar(&format!("{name}.sa"))?;
    ensure!(sw > 0.0 && sa > 0.0, "{name}: non-positive step size (sw={sw}, sa={sa})");
    let (act_qn, act_qp) = qrange(bits, signed_act);
    let (wqn, wqp) = qrange(bits, true);
    ensure!(
        check_accumulator_bound(k, act_qp, act_qn, wqn, wqp),
        "{name}: k={k} at {bits}-bit would overflow the i32 accumulator"
    );
    let n = *want_shape.last().expect("non-empty weight shape");
    let act_max = act_qp.max(act_qn);
    Ok(match mode {
        UnpackMode::Panelized => match art.panel_for(name, k, n, bits, act_max)? {
            Some(panel) => LayerWeights::Panel {
                // Same Figure-3 accounting as the manifest path: packed
                // bytes + the s_w step + the s_a step, even though the
                // packed form never materializes here.
                storage_bytes: (k * n * bits as usize).div_ceil(8) + 8,
                sw,
                panel,
                sa,
                act_qn,
                act_qp,
            },
            None => {
                let packed = art.packed_for(name, k, n, bits)?;
                LayerWeights::Panel {
                    storage_bytes: packed.storage_bytes() + 4, // + s_a
                    sw: packed.step,
                    panel: PanelizedWeights::build_for_acts(&packed, k, n, act_max),
                    sa,
                    act_qn,
                    act_qp,
                }
            }
        },
        UnpackMode::Fused => LayerWeights::Packed {
            w: art.packed_for(name, k, n, bits)?,
            sa,
            act_qn,
            act_qp,
        },
    })
}

fn bind_conv(
    binder: &Binder,
    spec: &ConvSpec,
    mode: UnpackMode,
    bw: WeightBinder,
) -> Result<RtConv> {
    let shape = [spec.kh, spec.kw, spec.in_ch, spec.out_ch];
    let k = spec.kh * spec.kw * spec.in_ch;
    let wq = bw(binder, &spec.name, spec.bits, spec.signed_act, k, &shape, mode)?;
    Ok(RtConv { spec: spec.clone(), wq })
}

fn bind_dense(
    binder: &Binder,
    spec: &DenseSpec,
    mode: UnpackMode,
    bw: WeightBinder,
) -> Result<RtDense> {
    let shape = [spec.in_dim, spec.out_dim];
    let wq = bw(binder, &spec.name, spec.bits, spec.signed_act, spec.in_dim, &shape, mode)?;
    let bias = match binder.map.get(format!("{}.b", spec.name).as_str()) {
        Some(t) => {
            ensure!(t.numel() == spec.out_dim, "{}.b wrong length", spec.name);
            Some(t.f32s()?.to_vec())
        }
        None => None,
    };
    Ok(RtDense { spec: spec.clone(), wq, bias })
}

fn bind_bn(binder: &Binder, spec: &BnSpec) -> Result<RtBn> {
    let gamma = binder.tensor(&format!("{}.gamma", spec.name))?.f32s()?;
    let beta = binder.tensor(&format!("{}.beta", spec.name))?.f32s()?;
    let rmean = binder.tensor(&format!("{}.rmean", spec.name))?.f32s()?;
    let rvar = binder.tensor(&format!("{}.rvar", spec.name))?.f32s()?;
    ensure!(
        [beta.len(), rmean.len(), rvar.len()].iter().all(|&l| l == gamma.len())
            && gamma.len() == spec.ch,
        "{}: inconsistent batch-norm parameter lengths",
        spec.name
    );
    let (scale, shift) = kernels::fold_bn(gamma, beta, rmean, rvar);
    Ok(RtBn { scale, shift })
}

fn layer_packed_bytes(wq: &LayerWeights) -> usize {
    match wq {
        LayerWeights::Panel { storage_bytes, .. } => *storage_bytes,
        LayerWeights::Packed { w, .. } => w.storage_bytes() + 4, // + s_a
        LayerWeights::F32(v) => v.len() * 4,
    }
}

fn layer_panel_bytes(wq: &LayerWeights) -> usize {
    match wq {
        LayerWeights::Panel { panel, .. } => panel.panel_bytes(),
        _ => 0,
    }
}

/// Walk the arch graph once, binding every op through the supplied
/// [`WeightBinder`]; returns `(ops, packed_bytes, panel_bytes)`. Shared
/// by the manifest and artifact build paths so the graph structure, BN
/// folding, bias handling, and storage accounting can never drift
/// between them.
fn bind_ops(
    binder: &Binder,
    arch: &Arch,
    mode: UnpackMode,
    bw: WeightBinder,
) -> Result<(Vec<RtOp>, usize, usize)> {
    let mut packed_bytes = 0usize;
    let mut panel_bytes = 0usize;
    let mut ops = Vec::with_capacity(arch.ops.len());
    for op in &arch.ops {
        ops.push(match op {
            ArchOp::Conv(c) => {
                let rt = bind_conv(binder, c, mode, bw)?;
                packed_bytes += layer_packed_bytes(&rt.wq);
                panel_bytes += layer_panel_bytes(&rt.wq);
                RtOp::Conv(rt)
            }
            ArchOp::Dense(d) => {
                let rt = bind_dense(binder, d, mode, bw)?;
                packed_bytes += layer_packed_bytes(&rt.wq);
                panel_bytes += layer_panel_bytes(&rt.wq);
                packed_bytes += rt.bias.as_ref().map_or(0, |b| b.len() * 4);
                RtOp::Dense(rt)
            }
            ArchOp::BatchNorm(b) => RtOp::Bn(bind_bn(binder, b)?),
            ArchOp::Relu => RtOp::Relu,
            ArchOp::MaxPool2 => RtOp::MaxPool2,
            ArchOp::GlobalAvgPool => RtOp::GlobalAvgPool,
            ArchOp::Flatten => RtOp::Flatten,
            ArchOp::Preact(p) => {
                let rt = RtPreact {
                    bn1: bind_bn(binder, &p.bn1)?,
                    proj: p.proj.as_ref().map(|c| bind_conv(binder, c, mode, bw)).transpose()?,
                    conv1: bind_conv(binder, &p.conv1, mode, bw)?,
                    bn2: bind_bn(binder, &p.bn2)?,
                    conv2: bind_conv(binder, &p.conv2, mode, bw)?,
                };
                packed_bytes += layer_packed_bytes(&rt.conv1.wq)
                    + layer_packed_bytes(&rt.conv2.wq)
                    + rt.proj.as_ref().map_or(0, |c| layer_packed_bytes(&c.wq));
                panel_bytes += layer_panel_bytes(&rt.conv1.wq)
                    + layer_panel_bytes(&rt.conv2.wq)
                    + rt.proj.as_ref().map_or(0, |c| layer_panel_bytes(&c.wq));
                RtOp::Preact(Box::new(rt))
            }
        });
    }
    Ok((ops, packed_bytes, panel_bytes))
}

impl NativeModel {
    /// [`NativeModel::build_with_mode`] with the process-default
    /// [`UnpackMode`] (panelized, unless `LSQNET_FUSED_UNPACK` is set).
    pub fn build(manifest: &Manifest, family: &str, params: &[Tensor]) -> Result<NativeModel> {
        NativeModel::build_with_mode(manifest, family, params, UnpackMode::default_mode())
    }

    /// Bind `family`'s architecture to `params` (in `Family::param_names`
    /// order), quantizing and packing every sub-32-bit weight tensor —
    /// and, in [`UnpackMode::Panelized`], unpacking each into the kernel
    /// layer's shared panel layout once, here, so forward calls do no
    /// unpack work.
    pub fn build_with_mode(
        manifest: &Manifest,
        family: &str,
        params: &[Tensor],
        mode: UnpackMode,
    ) -> Result<NativeModel> {
        let fam = manifest.family(family)?;
        ensure!(
            params.len() == fam.param_names.len(),
            "family {family}: got {} params, manifest lists {}",
            params.len(),
            fam.param_names.len()
        );
        let arch: Arch = arch::build(
            &fam.model,
            manifest.image,
            manifest.channels,
            fam.num_classes,
            fam.qbits,
        )?;
        let binder = Binder {
            family,
            map: fam.param_names.iter().map(String::as_str).zip(params).collect(),
        };
        let (ops, packed_bytes, panel_bytes) = bind_ops(&binder, &arch, mode, &bind_weights)?;
        Ok(NativeModel {
            family: family.to_string(),
            image: manifest.image,
            channels: manifest.channels,
            num_classes: fam.num_classes,
            ops,
            packed_bytes,
            panel_bytes,
        })
    }

    /// Bind a model straight from a loaded `.lsqa` artifact — the
    /// instant-bind path: panel blocks are *borrowed* from the artifact's
    /// shared arena (zero quantize/pack/panelize work in
    /// [`UnpackMode::Panelized`] when a recorded panels section matches
    /// this host), steps/biases/BN come from the artifact's tensor
    /// records, and the resulting logits are bitwise identical to a
    /// [`NativeModel::build_with_mode`] bind of the same checkpoint
    /// (`tests/artifact.rs`).
    pub fn build_from_artifact(art: &LoadedArtifact, mode: UnpackMode) -> Result<NativeModel> {
        let arch: Arch = arch::build(
            art.model(),
            art.image(),
            art.channels(),
            art.num_classes(),
            art.qbits(),
        )?;
        let binder = Binder {
            family: art.family(),
            map: art.tensors().iter().map(|(k, v)| (k.as_str(), v)).collect(),
        };
        let bw = |binder: &Binder,
                  name: &str,
                  bits: u32,
                  signed_act: bool,
                  k: usize,
                  shape: &[usize],
                  mode: UnpackMode| {
            bind_weights_art(art, binder, name, bits, signed_act, k, shape, mode)
        };
        let (ops, packed_bytes, panel_bytes) = bind_ops(&binder, &arch, mode, &bw)?;
        Ok(NativeModel {
            family: art.family().to_string(),
            image: art.image(),
            channels: art.channels(),
            num_classes: art.num_classes(),
            ops,
            packed_bytes,
            panel_bytes,
        })
    }

    /// Per-image input element count (`image * image * channels`).
    pub fn image_len(&self) -> usize {
        self.image * self.image * self.channels
    }

    /// Number of output classes per row.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// The family this model was built for.
    pub fn family(&self) -> &str {
        &self.family
    }

    /// Run the quantized forward pass on `rows` images packed into `x`
    /// (NHWC, `rows * image_len()` floats), drawing all scratch from `ws`.
    /// Returns `rows * num_classes` logits, row-major.
    pub fn forward(&self, ws: &mut Workspace, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        ensure!(rows > 0, "empty batch");
        ensure!(
            x.len() == rows * self.image_len(),
            "input has {} floats, expected {} ({} rows x {})",
            x.len(),
            rows * self.image_len(),
            rows,
            self.image_len()
        );
        let mut data = ws.take_f32_cap(x.len());
        data.extend_from_slice(x);
        let mut act = Act {
            shape: vec![rows, self.image, self.image, self.channels],
            data,
        };
        for op in &self.ops {
            act = apply(ws, act, op)?;
        }
        ensure!(
            act.shape == [rows, self.num_classes],
            "forward produced shape {:?}, expected [{rows}, {}]",
            act.shape,
            self.num_classes
        );
        // The caller owns the returned logits, so hand out a plain
        // exact-size Vec and keep the pooled buffer: one small
        // logits-sized allocation per call, never a pool leak (a pooled
        // buffer escaping here would cascade — each call would burn the
        // smallest fitting pool entry and re-grow another).
        let logits = act.data.clone();
        ws.recycle_f32(act.data);
        Ok(logits)
    }
}

fn apply(ws: &mut Workspace, act: Act, op: &RtOp) -> Result<Act> {
    Ok(match op {
        RtOp::Conv(c) => {
            let out = apply_conv(ws, &act, c)?;
            ws.recycle_f32(act.data);
            out
        }
        RtOp::Dense(d) => {
            let out = apply_dense(ws, &act, d)?;
            ws.recycle_f32(act.data);
            out
        }
        RtOp::Bn(b) => {
            let mut act = act;
            apply_bn(&mut act, b)?;
            act
        }
        RtOp::Relu => {
            let mut act = act;
            kernels::relu(&mut act.data);
            act
        }
        RtOp::MaxPool2 => {
            let out = apply_maxpool2(ws, &act)?;
            ws.recycle_f32(act.data);
            out
        }
        RtOp::GlobalAvgPool => {
            let out = apply_gap(ws, &act)?;
            ws.recycle_f32(act.data);
            out
        }
        RtOp::Flatten => {
            let (b, h, w, c) = act.dims4()?;
            Act { shape: vec![b, h * w * c], data: act.data }
        }
        RtOp::Preact(p) => apply_preact(ws, act, p)?,
    })
}

fn apply_preact(ws: &mut Workspace, x: Act, p: &RtPreact) -> Result<Act> {
    // Projection shortcut is taken from the pre-activated tensor (as in
    // the original pre-act ResNet), so with a projection `x` can be
    // consumed outright; the identity shortcut keeps `x` alive and runs
    // bn1 out-of-place into a workspace buffer — no activation clone.
    let ch = *x.shape.last().unwrap_or(&0);
    ensure!(ch == p.bn1.scale.len(), "bn1 over {ch} channels, expected {}", p.bn1.scale.len());
    let (pre, sc) = match &p.proj {
        Some(proj) => {
            let mut pre = x;
            kernels::bn_apply(&mut pre.data, &p.bn1.scale, &p.bn1.shift);
            kernels::relu(&mut pre.data);
            let sc = apply_conv(ws, &pre, proj)?;
            (pre, sc)
        }
        None => {
            let mut data = ws.take_f32_any(x.data.len());
            kernels::bn_apply_out(&x.data, &p.bn1.scale, &p.bn1.shift, &mut data);
            kernels::relu(&mut data);
            (Act { shape: x.shape.clone(), data }, x)
        }
    };
    let mut h = apply_conv(ws, &pre, &p.conv1)?;
    ws.recycle_f32(pre.data);
    apply_bn(&mut h, &p.bn2)?;
    kernels::relu(&mut h.data);
    let mut out = apply_conv(ws, &h, &p.conv2)?;
    ws.recycle_f32(h.data);
    ensure!(out.shape == sc.shape, "residual shape mismatch: {:?} vs {:?}", out.shape, sc.shape);
    for (a, b) in out.data.iter_mut().zip(&sc.data) {
        *a += b;
    }
    ws.recycle_f32(sc.data);
    Ok(out)
}

/// Quantize an activation buffer to the Eq. 1 integer grid, into a
/// workspace buffer.
fn quantize_acts(ws: &mut Workspace, x: &[f32], sa: f32, qn: i64, qp: i64) -> Vec<i32> {
    let mut xq = ws.take_i32_cap(x.len());
    xq.extend(x.iter().map(|&v| lsq::quantize_vbar(v, sa, qn, qp) as i32));
    xq
}

fn apply_conv(ws: &mut Workspace, act: &Act, rt: &RtConv) -> Result<Act> {
    let (b, h, w, c) = act.dims4()?;
    let spec = &rt.spec;
    ensure!(c == spec.in_ch, "{}: input has {c} channels, expected {}", spec.name, spec.in_ch);
    let k = spec.kh * spec.kw * c;
    let n = spec.out_ch;
    // Pre-size the patch buffer so the pool hands back a fitting
    // allocation (im2col re-derives the same geometry).
    let (oh, _) = kernels::same_padding(h, spec.kh, spec.stride);
    let (ow, _) = kernels::same_padding(w, spec.kw, spec.stride);
    let rows = b * oh * ow;
    match &rt.wq {
        LayerWeights::F32(wv) => {
            let mut cols = ws.take_f32_cap(rows * k);
            kernels::im2col(&act.data, 0.0, b, h, w, c, spec.kh, spec.kw, spec.stride, &mut cols);
            let mut out = ws.take_f32_any(rows * n);
            kernels::sgemm(ws, rows, k, n, &cols, wv, None, &mut out);
            ws.recycle_f32(cols);
            Ok(Act { shape: vec![b, oh, ow, n], data: out })
        }
        wq => {
            let (scale, sa, act_qn, act_qp) = wq.quant_params();
            let xq = quantize_acts(ws, &act.data, sa, act_qn, act_qp);
            let mut cols = ws.take_i32_cap(rows * k);
            kernels::im2col(&xq, 0, b, h, w, c, spec.kh, spec.kw, spec.stride, &mut cols);
            ws.recycle_i32(xq);
            let mut out = ws.take_f32_any(rows * n);
            match wq {
                LayerWeights::Panel { panel, .. } => {
                    kernels::qgemm_panel(ws, rows, k, n, &cols, panel, scale, None, &mut out)
                }
                LayerWeights::Packed { w: pw, .. } => {
                    kernels::qgemm(ws, rows, k, n, &cols, pw, scale, None, &mut out)
                }
                LayerWeights::F32(_) => unreachable!(),
            }
            ws.recycle_i32(cols);
            Ok(Act { shape: vec![b, oh, ow, n], data: out })
        }
    }
}

fn apply_dense(ws: &mut Workspace, act: &Act, rt: &RtDense) -> Result<Act> {
    let spec = &rt.spec;
    let (b, d) = match act.shape[..] {
        [b, d] => (b, d),
        _ => bail!("{}: expected a 2-d input, got {:?}", spec.name, act.shape),
    };
    ensure!(d == spec.in_dim, "{}: input dim {d} != expected {}", spec.name, spec.in_dim);
    let n = spec.out_dim;
    let mut out = ws.take_f32_any(b * n);
    match &rt.wq {
        LayerWeights::F32(wv) => {
            kernels::sgemm(ws, b, d, n, &act.data, wv, rt.bias.as_deref(), &mut out);
        }
        wq => {
            let (scale, sa, act_qn, act_qp) = wq.quant_params();
            let xq = quantize_acts(ws, &act.data, sa, act_qn, act_qp);
            match wq {
                LayerWeights::Panel { panel, .. } => kernels::qgemm_panel(
                    ws,
                    b,
                    d,
                    n,
                    &xq,
                    panel,
                    scale,
                    rt.bias.as_deref(),
                    &mut out,
                ),
                LayerWeights::Packed { w: pw, .. } => {
                    kernels::qgemm(ws, b, d, n, &xq, pw, scale, rt.bias.as_deref(), &mut out)
                }
                LayerWeights::F32(_) => unreachable!(),
            }
            ws.recycle_i32(xq);
        }
    }
    Ok(Act { shape: vec![b, n], data: out })
}

fn apply_bn(act: &mut Act, bn: &RtBn) -> Result<()> {
    let c = *act.shape.last().unwrap_or(&0);
    ensure!(c == bn.scale.len(), "batch norm over {c} channels, expected {}", bn.scale.len());
    kernels::bn_apply(&mut act.data, &bn.scale, &bn.shift);
    Ok(())
}

fn apply_maxpool2(ws: &mut Workspace, act: &Act) -> Result<Act> {
    let (b, h, w, c) = act.dims4()?;
    let (oh, ow) = (h / 2, w / 2);
    let mut out = ws.take_f32_any(b * oh * ow * c);
    kernels::maxpool2(&act.data, b, h, w, c, &mut out, None);
    Ok(Act { shape: vec![b, oh, ow, c], data: out })
}

fn apply_gap(ws: &mut Workspace, act: &Act) -> Result<Act> {
    let (b, h, w, c) = act.dims4()?;
    let mut out = ws.take_f32_any(b * c);
    kernels::global_avg_pool(&act.data, b, h, w, c, &mut out);
    Ok(Act { shape: vec![b, c], data: out })
}

/// The native inference engine: a [`Manifest`] plus (after
/// [`Backend::prepare_infer`]) one bound [`NativeModel`] and the
/// [`Workspace`] its forward passes reuse.
pub struct NativeEngine {
    manifest: Manifest,
    model: Option<NativeModel>,
    ws: Workspace,
    mode: UnpackMode,
    /// The `.lsqa` this engine was opened from, if any: binds borrow
    /// panels from its shared arena instead of rebuilding them.
    artifact: Option<Arc<LoadedArtifact>>,
}

impl NativeEngine {
    /// Open the manifest at `dir`. No HLO artifacts or PJRT libraries are
    /// required — only `manifest.json` and the family params bins.
    pub fn new(dir: &Path) -> Result<NativeEngine> {
        Ok(NativeEngine {
            manifest: Manifest::load(dir)?,
            model: None,
            ws: Workspace::new(),
            mode: UnpackMode::default_mode(),
            artifact: None,
        })
    }

    /// Open an engine over a loaded `.lsqa` artifact — no `manifest.json`
    /// or params bin on disk; the synthesized single-family manifest and
    /// every parameter come from the artifact, and `prepare_infer` binds
    /// zero-copy against the artifact's arena (which the caller typically
    /// shares across a variant's replicas via the `Arc`).
    pub fn from_artifact(art: Arc<LoadedArtifact>) -> NativeEngine {
        NativeEngine {
            manifest: art.manifest(),
            model: None,
            ws: Workspace::new(),
            mode: UnpackMode::default_mode(),
            artifact: Some(art),
        }
    }

    /// The model bound by the last `prepare_infer`, if any.
    pub fn model(&self) -> Option<&NativeModel> {
        self.model.as_ref()
    }

    /// The weight-storage mode the last `prepare_infer` bound with (the
    /// process default before any bind).
    pub fn unpack_mode(&self) -> UnpackMode {
        self.mode
    }
}

impl Backend for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn prepare_infer(
        &mut self,
        family: &str,
        params: &[Tensor],
        opts: &PrepareOptions,
    ) -> Result<()> {
        // `None` defers to the process-wide LSQNET_FUSED_UNPACK default —
        // options cannot stomp the env resolution the way the old
        // low-memory setter's unconditional `false` could.
        self.mode = match opts.low_memory {
            Some(true) => UnpackMode::Fused,
            Some(false) => UnpackMode::Panelized,
            None => UnpackMode::default_mode(),
        };
        self.ws.set_threads(opts.intra_op_threads);
        // Artifact binds (engine opened via `from_artifact`, or an
        // artifact supplied per-prepare through the options) take no
        // checkpoint params: the artifact *is* the checkpoint, frozen at
        // pack time.
        if let Some(art) = opts.artifact.clone().or_else(|| self.artifact.clone()) {
            ensure!(
                family == art.family(),
                "artifact {} holds family {:?}, caller asked for {family:?}",
                art.path().display(),
                art.family()
            );
            ensure!(
                params.is_empty(),
                "artifact bind takes no explicit params ({} supplied)",
                params.len()
            );
            self.model = Some(NativeModel::build_from_artifact(&art, self.mode)?);
            return Ok(());
        }
        self.model = Some(NativeModel::build_with_mode(
            &self.manifest,
            family,
            params,
            self.mode,
        )?);
        Ok(())
    }

    fn batch(&self) -> usize {
        self.manifest.batch.max(1)
    }

    fn fixed_batch(&self) -> bool {
        false // forward() handles any row count; no padding needed
    }

    fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let model = self
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("call prepare_infer before infer"))?;
        let il = model.image_len();
        ensure!(il > 0, "family {} has a degenerate image geometry", model.family);
        ensure!(
            !x.is_empty() && x.len() % il == 0,
            "input length {} is not a multiple of image_len {il}",
            x.len()
        );
        model.forward(&mut self.ws, x, x.len() / il)
    }
}
