//! Deterministic fault injection for the serving stack (DESIGN.md
//! §Fault-model).
//!
//! A [`FaultPlan`] is a *precomputed schedule* of faults, derived entirely
//! from a seed: for every injection **site** (replica panic, slow exec,
//! engine-open failure, stalled read, dropped connection, corrupt frame,
//! truncated write) the plan draws a fixed set of occurrence indices from
//! an independent [`Pcg32`] stream. At runtime each site keeps an atomic
//! occurrence counter; the k-th query at a site fires iff k is in that
//! site's precomputed index set. The schedule is therefore a pure function
//! of the seed — no wall clock, no thread timing — which is what makes the
//! chaos tests (`tests/chaos.rs`) exact instead of flaky:
//!
//!  * the *set* of fired occurrence indices per site is bit-for-bit
//!    identical across runs with the same seed and the same number of
//!    queries, regardless of thread interleaving (each query atomically
//!    claims one index; the verdict for an index never changes);
//!  * which *wall-clock request* lands on a firing index IS
//!    scheduling-dependent — so chaos assertions compare schedules, fired
//!    sets and conservation laws ("accepted ⇒ answered exactly once"),
//!    never the ok/error split of individual requests.
//!
//! The hooks are always compiled and default to `None`
//! ([`VariantOptions::fault`](crate::serve::VariantOptions),
//! `NetServer::start_faulted`), so production builds pay one `Option`
//! check per site and carry zero feature-flag skew.
//!
//! The ISSUE sketch said "xorshift from `util/rng`"; the repo's RNG is
//! PCG-XSH-RR 64/32 ([`Pcg32`]) — same role (tiny seeded deterministic
//! generator, zero deps), so the plan uses that (DESIGN.md §Substitutions).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::rng::Pcg32;

/// How many faults of each kind to schedule, and over what horizon.
///
/// Counts are clamped to `horizon` (a site cannot fire more often than it
/// is queried within the schedule). `Default` is an all-zero plan — handy
/// as a base for struct-update syntax in tests.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// Seed for the whole schedule: same seed ⇒ same schedule, bit for bit.
    pub seed: u64,
    /// Occurrence-index horizon per site: indices are drawn from
    /// `[0, horizon)`. Queries past the horizon never fire.
    pub horizon: u64,
    /// Replica batches that panic mid-dispatch (after answering their
    /// pending requests — the thread dies, the requests do not).
    pub replica_panics: u64,
    /// Replica (re)starts whose engine open is forced to fail.
    pub replica_open_fails: u64,
    /// Replica batches whose execution is delayed by [`FaultSpec::slow_exec`].
    pub slow_execs: u64,
    /// Injected delay for a slow-exec fault.
    pub slow_exec: Duration,
    /// Server-side reads that stall [`FaultSpec::read_stall`] after a frame
    /// arrives (exercises client timeouts, not the frame deadline).
    pub stalled_reads: u64,
    /// Injected delay for a stalled read.
    pub read_stall: Duration,
    /// Server connections hard-dropped after reading a frame (the request
    /// is never submitted, so a client retry is safe).
    pub dropped_conns: u64,
    /// Response frames whose JSON payload is garbled (framing stays valid;
    /// the client sees a protocol error and reconnects).
    pub corrupt_frames: u64,
    /// Response frames truncated mid-payload, then the connection dies.
    pub truncated_writes: u64,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            seed: 0,
            horizon: 0,
            replica_panics: 0,
            replica_open_fails: 0,
            slow_execs: 0,
            slow_exec: Duration::from_millis(50),
            stalled_reads: 0,
            read_stall: Duration::from_millis(50),
            dropped_conns: 0,
            corrupt_frames: 0,
            truncated_writes: 0,
        }
    }
}

/// Verdict for one replica exec-loop batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaFault {
    /// No fault: execute normally.
    None,
    /// Answer the pending batch, then panic the replica thread.
    Panic,
    /// Sleep this long before executing (SLO pressure without death).
    Slow(Duration),
}

/// Verdict for one net-stack read or write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetFault {
    /// No fault.
    None,
    /// (read) Sleep this long before handling the frame.
    Stall(Duration),
    /// (read) Shut the connection down without handling the frame.
    Drop,
    /// (write) Garble the payload bytes; framing stays intact.
    Corrupt,
    /// (write) Send a full-length header but only half the payload, then
    /// kill the connection.
    Truncate,
}

/// One injection site: a precomputed sorted index set plus a live
/// occurrence counter and a log of indices that actually fired.
#[derive(Debug)]
struct Site {
    name: &'static str,
    /// Sorted, distinct occurrence indices in `[0, horizon)`.
    indices: Vec<u64>,
    counter: AtomicU64,
    fired: Mutex<Vec<u64>>,
}

impl Site {
    /// Draw `count` distinct indices in `[0, horizon)` from an independent
    /// PCG stream keyed on (seed, site tag).
    fn new(name: &'static str, seed: u64, tag: u64, count: u64, horizon: u64) -> Site {
        let mut indices = Vec::new();
        if horizon > 0 && count > 0 {
            let count = count.min(horizon);
            let mut rng = Pcg32::new(seed, tag);
            // Horizons are test-sized (≤ a few thousand); rejection
            // sampling into a sorted set is plenty.
            let bound = horizon.min(u32::MAX as u64) as u32;
            while (indices.len() as u64) < count {
                let k = rng.below(bound) as u64;
                if let Err(pos) = indices.binary_search(&k) {
                    indices.insert(pos, k);
                }
            }
        }
        Site { name, indices, counter: AtomicU64::new(0), fired: Mutex::new(Vec::new()) }
    }

    /// Claim the next occurrence index and report whether it fires. The
    /// verdict for index k is fixed at plan construction, so the fired
    /// *set* is schedule-deterministic even under thread races.
    fn check(&self) -> bool {
        let k = self.counter.fetch_add(1, Ordering::SeqCst);
        let hit = self.indices.binary_search(&k).is_ok();
        if hit {
            let mut fired = self.fired.lock().unwrap_or_else(|e| e.into_inner());
            let pos = fired.binary_search(&k).unwrap_or_else(|p| p);
            fired.insert(pos, k);
        }
        hit
    }

    fn fired(&self) -> Vec<u64> {
        self.fired.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    fn done(&self) -> bool {
        self.fired.lock().unwrap_or_else(|e| e.into_inner()).len() == self.indices.len()
    }
}

// Per-site PCG stream tags: any distinct odd-ish constants work; these are
// fixed forever so a seed's schedule never changes across versions.
const TAG_REPLICA_PANIC: u64 = 0x9e37_79b9_7f4a_7c15;
const TAG_REPLICA_OPEN: u64 = 0xc2b2_ae3d_27d4_eb4f;
const TAG_SLOW_EXEC: u64 = 0x1656_67b1_9e37_79f9;
const TAG_READ_STALL: u64 = 0x27d4_eb2f_1656_67c5;
const TAG_CONN_DROP: u64 = 0x85eb_ca6b_c2b2_ae35;
const TAG_FRAME_CORRUPT: u64 = 0x94d0_49bb_1331_11eb;
const TAG_WRITE_TRUNC: u64 = 0xbf58_476d_1ce4_e5b9;

/// A seeded, thread-safe fault schedule. Share one plan (via `Arc`) across
/// the registry and the net server so the whole process replays a single
/// coherent failure scenario.
#[derive(Debug)]
pub struct FaultPlan {
    spec: FaultSpec,
    replica_panic: Site,
    replica_open: Site,
    slow_exec: Site,
    read_stall: Site,
    conn_drop: Site,
    frame_corrupt: Site,
    write_trunc: Site,
}

impl FaultPlan {
    /// Precompute the full schedule from `spec` (pure function of the spec).
    pub fn new(spec: &FaultSpec) -> FaultPlan {
        let (s, h) = (spec.seed, spec.horizon);
        FaultPlan {
            replica_panic: Site::new("replica_panic", s, TAG_REPLICA_PANIC, spec.replica_panics, h),
            replica_open: Site::new(
                "replica_open",
                s,
                TAG_REPLICA_OPEN,
                spec.replica_open_fails,
                h,
            ),
            slow_exec: Site::new("slow_exec", s, TAG_SLOW_EXEC, spec.slow_execs, h),
            read_stall: Site::new("read_stall", s, TAG_READ_STALL, spec.stalled_reads, h),
            conn_drop: Site::new("conn_drop", s, TAG_CONN_DROP, spec.dropped_conns, h),
            frame_corrupt: Site::new("frame_corrupt", s, TAG_FRAME_CORRUPT, spec.corrupt_frames, h),
            write_trunc: Site::new("write_trunc", s, TAG_WRITE_TRUNC, spec.truncated_writes, h),
            spec: spec.clone(),
        }
    }

    /// Should this replica (re)start fail its engine open?
    pub fn replica_open_fail(&self) -> bool {
        self.replica_open.check()
    }

    /// Verdict for one dispatched batch. Both sub-sites advance their
    /// counters on every call (so each site's occurrence stream is
    /// independent of the other's verdicts); a panic wins if both fire.
    pub fn replica_exec(&self) -> ReplicaFault {
        let panic = self.replica_panic.check();
        let slow = self.slow_exec.check();
        if panic {
            ReplicaFault::Panic
        } else if slow {
            ReplicaFault::Slow(self.spec.slow_exec)
        } else {
            ReplicaFault::None
        }
    }

    /// Verdict for one server-side frame read (queried after a complete
    /// frame arrives, before it is handled). Both sub-sites always advance;
    /// a drop wins if both fire.
    pub fn net_read(&self) -> NetFault {
        let stall = self.read_stall.check();
        let drop = self.conn_drop.check();
        if drop {
            NetFault::Drop
        } else if stall {
            NetFault::Stall(self.spec.read_stall)
        } else {
            NetFault::None
        }
    }

    /// Verdict for one server-side response write. Both sub-sites always
    /// advance; truncation wins if both fire.
    pub fn net_write(&self) -> NetFault {
        let corrupt = self.frame_corrupt.check();
        let trunc = self.write_trunc.check();
        if trunc {
            NetFault::Truncate
        } else if corrupt {
            NetFault::Corrupt
        } else {
            NetFault::None
        }
    }

    fn sites(&self) -> [&Site; 7] {
        [
            &self.replica_panic,
            &self.replica_open,
            &self.slow_exec,
            &self.read_stall,
            &self.conn_drop,
            &self.frame_corrupt,
            &self.write_trunc,
        ]
    }

    /// The precomputed schedule as a canonical digest string — two plans
    /// with the same seed/spec render identically (the chaos determinism
    /// assertion compares these).
    pub fn schedule(&self) -> String {
        let mut out = String::new();
        for site in self.sites() {
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(site.name);
            out.push_str(":[");
            for (i, k) in site.indices.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&k.to_string());
            }
            out.push(']');
        }
        out
    }

    /// Occurrence indices that actually fired, per site (sorted). After a
    /// run, equal `fired()` maps across same-seed runs is the replay proof.
    pub fn fired(&self) -> BTreeMap<&'static str, Vec<u64>> {
        self.sites().iter().map(|s| (s.name, s.fired())).collect()
    }

    /// True once every planned fault at every site has fired — the chaos
    /// flood loops until this (with a wall-clock cap) so the scenario
    /// always fully plays out.
    pub fn all_fired(&self) -> bool {
        self.sites().iter().all(|s| s.done())
    }

    /// Total planned faults across all sites.
    pub fn planned(&self) -> u64 {
        self.sites().iter().map(|s| s.indices.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            horizon: 64,
            replica_panics: 3,
            replica_open_fails: 2,
            slow_execs: 4,
            stalled_reads: 2,
            dropped_conns: 2,
            corrupt_frames: 2,
            truncated_writes: 1,
            ..FaultSpec::default()
        }
    }

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultPlan::new(&spec(7));
        let b = FaultPlan::new(&spec(7));
        assert_eq!(a.schedule(), b.schedule());
        assert_eq!(a.planned(), b.planned());
        let c = FaultPlan::new(&spec(8));
        assert_ne!(a.schedule(), c.schedule());
    }

    #[test]
    fn fires_exactly_at_planned_indices() {
        let plan = FaultPlan::new(&spec(42));
        let mut panics = Vec::new();
        for k in 0..64u64 {
            if plan.replica_exec() == ReplicaFault::Panic {
                panics.push(k);
            }
        }
        assert_eq!(panics.len(), 3, "all planned panics fire within the horizon");
        assert_eq!(plan.fired()["replica_panic"], panics);
        // Past the horizon nothing ever fires.
        for _ in 0..64 {
            assert_eq!(plan.replica_exec(), ReplicaFault::None);
        }
    }

    #[test]
    fn all_fired_tracks_every_site() {
        let plan = FaultPlan::new(&spec(9));
        assert!(!plan.all_fired());
        for _ in 0..64 {
            plan.replica_exec();
            plan.replica_open_fail();
            plan.net_read();
            plan.net_write();
        }
        assert!(plan.all_fired());
        let fired = plan.fired();
        assert_eq!(fired["slow_exec"].len(), 4);
        assert_eq!(fired["write_trunc"].len(), 1);
    }

    #[test]
    fn counts_clamp_to_horizon_and_zero_horizon_is_inert() {
        let tight =
            FaultPlan::new(&FaultSpec { seed: 1, horizon: 2, replica_panics: 10, ..FaultSpec::default() });
        assert_eq!(tight.planned(), 2);
        let inert =
            FaultPlan::new(&FaultSpec { seed: 1, horizon: 0, replica_panics: 10, ..FaultSpec::default() });
        assert_eq!(inert.planned(), 0);
        assert!(inert.all_fired());
        assert_eq!(inert.replica_exec(), ReplicaFault::None);
    }

    #[test]
    fn net_precedence_drop_and_truncate_win() {
        // With counts == horizon every index fires at every site, so the
        // precedence arms are exercised deterministically.
        let plan = FaultPlan::new(&FaultSpec {
            seed: 3,
            horizon: 4,
            stalled_reads: 4,
            dropped_conns: 4,
            corrupt_frames: 4,
            truncated_writes: 4,
            ..FaultSpec::default()
        });
        assert_eq!(plan.net_read(), NetFault::Drop);
        assert_eq!(plan.net_write(), NetFault::Truncate);
    }
}
