//! Quantized-inference serving path (Figure 1 deployed): a request router +
//! dynamic batcher in front of engine replicas, multi-model by design.
//!
//! Architecture (vLLM-router-shaped, scaled to this model family):
//!
//!  * [`registry::ModelRegistry`] is the serving surface: one process
//!    hosts many bound model **variants** (e.g. `cnn_small_q2/q3/q4/q8` —
//!    the same architecture at several precisions, LSQ's whole point),
//!    each with its own request queue, replica set and [`ServeStats`],
//!    sharing one core budget. Requests address a variant by name through
//!    a [`registry::Session`] handle, and variants hot load/unload under
//!    live traffic;
//!  * each replica worker opens its **own** engine from a
//!    [`crate::runtime::BackendSpec`] (the XLA client is `Rc`-backed and
//!    not `Send`; the native engine is `Send` but keeps per-model packed
//!    state thread-local anyway), configured once via
//!    [`crate::runtime::PrepareOptions`], and drains its variant's queue
//!    with *dynamic batching*: dispatch as soon as `batch` rows are
//!    waiting, or after `max_wait` with whatever is there (tail rows are
//!    zero-padded only for fixed-shape backends — see
//!    `Backend::fixed_batch`);
//!  * the queue hand-off is serialized (a mutex around the receiver) but
//!    execution is not, so replicas overlap on the expensive part — the
//!    forward pass;
//!  * every client-visible failure is a typed [`ServeError`]
//!    (`Closed` / `UnknownModel` / `QueueFull` / `ShutDown` / `BadImage`),
//!    so open-loop clients get real backpressure semantics instead of
//!    panics or silently dropped reply channels.
//!
//! [`Server`]/[`ServerConfig`] survive as a thin one-variant compatibility
//! shim over the registry. With the native backend this runs entirely from
//! packed weights and scales across cores on two axes: replicas (inter-op)
//! and the kernel layer's row-block threading (intra-op), partitioned so
//! the two never oversubscribe (DESIGN.md §Serving-API).
//!
//! [`net`] exposes all of this over TCP: length-delimited JSON frames,
//! every [`ServeError`] variant mapped to a structured wire error, and
//! connection drain composed with `drain_and_unload` (DESIGN.md
//! §Wire-protocol).

pub mod net;
pub mod registry;

use std::sync::mpsc::{Receiver, SyncSender};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::BackendSpec;

pub use registry::{ModelRegistry, Session, VariantOptions};

/// One queued inference request (internal to the serve layer).
pub struct Request {
    /// Flattened NHWC image, `image * image * channels` floats.
    pub image: Vec<f32>,
    submitted: Instant,
    reply: SyncSender<Reply>,
}

/// The answer a client receives for one image.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// Index of the winning class.
    pub argmax: usize,
    /// Time spent queued + batching before execution started.
    pub queue_ms: f64,
    /// End-to-end latency (submit → reply).
    pub total_ms: f64,
}

/// Typed client-visible serving failures. Everything an open-loop client
/// can hit is represented — no panics, no silently dropped reply channels.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The variant's intake was closed (`close_intake` / drain): new
    /// requests are not accepted; already-accepted ones are still answered.
    Closed,
    /// No variant with this name is loaded in the registry.
    UnknownModel(String),
    /// The variant's request queue is at `depth`: backpressure. Retry,
    /// shed, or route to another tier — the submit never blocks.
    QueueFull {
        /// The configured queue bound that was hit.
        depth: usize,
    },
    /// The serving side went away (replicas exited or the reply channel
    /// dropped mid-request).
    ShutDown,
    /// The image has the wrong number of floats for the variant's
    /// geometry.
    BadImage {
        /// Floats submitted.
        got: usize,
        /// Floats the variant's `image × image × channels` geometry needs.
        want: usize,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Closed => write!(f, "intake closed: variant no longer accepts requests"),
            ServeError::UnknownModel(name) => write!(f, "unknown model variant {name:?}"),
            ServeError::QueueFull { depth } => {
                write!(f, "request queue full (depth {depth}): backpressure, retry later")
            }
            ServeError::ShutDown => write!(f, "server shut down"),
            ServeError::BadImage { got, want } => {
                write!(f, "image must have {want} floats, got {got}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Aggregate serving metrics for one variant (all of its replicas).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests answered.
    pub requests: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Rows dispatched including padding.
    pub rows_dispatched: u64,
    /// Zero rows padded onto batch tails for fixed-shape backends
    /// (`rows_dispatched − requests`), kept separately so
    /// [`ServeStats::mean_exec_ms`] can be attributed: exec time is per
    /// dispatched batch, and this is how much of each batch was padding
    /// (EXPERIMENTS.md §Perf L3 reports the tail-padding overhead per
    /// backend from it).
    pub padding_rows: u64,
    /// Total forward-pass wall time.
    pub exec_ms_total: f64,
    /// Summed per-request queue+batching time (submit → execution start).
    pub queue_ms_total: f64,
    /// Sum over batches of real/batch (for mean occupancy).
    pub occupancy_sum: f64,
}

impl ServeStats {
    /// Mean fraction of each dispatched batch holding real requests.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum / self.batches as f64
        }
    }

    /// Mean forward-pass time per batch. Note this is per *dispatched*
    /// batch — on fixed-shape backends it includes the cost of
    /// [`ServeStats::padding_rows`]; real-row throughput is
    /// `requests / exec_ms_total`.
    pub fn mean_exec_ms(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.exec_ms_total / self.batches as f64
        }
    }

    /// Mean time a request spends queued + batching before its batch
    /// starts executing.
    pub fn mean_queue_ms(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.queue_ms_total / self.requests as f64
        }
    }

    /// Mean fraction of dispatched rows that were tail padding.
    pub fn padding_fraction(&self) -> f64 {
        if self.rows_dispatched == 0 {
            0.0
        } else {
            self.padding_rows as f64 / self.rows_dispatched as f64
        }
    }
}

/// Cloneable handle for submitting requests to a [`Server`] from any
/// thread — a named-variant [`Session`] under the hood.
#[derive(Clone)]
pub struct ServeClient {
    session: Session,
}

impl ServeClient {
    /// Blocking single-request inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply, ServeError> {
        self.session.infer(image)
    }

    /// Non-blocking submit; returns the reply channel. See
    /// [`Session::submit`] for the error contract ([`ServeError::QueueFull`]
    /// backpressure instead of blocking).
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Reply>, ServeError> {
        self.session.submit(image)
    }
}

/// A running one-variant inference server: the compatibility shim over
/// [`ModelRegistry`] for callers that serve a single family. New code
/// serving several precision tiers should use the registry directly.
pub struct Server {
    registry: ModelRegistry,
    variant: String,
    /// Number of engine replicas actually started.
    pub replicas: usize,
}

/// One-variant server configuration (the [`Server`] shim; multi-variant
/// deployments configure each variant via [`VariantOptions`]).
pub struct ServerConfig {
    /// Which engine to open (and over which artifacts directory); each
    /// replica opens its own instance.
    pub backend: BackendSpec,
    /// Model family to serve, e.g. `"cnn_small_q2"`.
    pub family: String,
    /// Checkpoint with trained params (empty = the family's initial params).
    pub checkpoint: String,
    /// Dynamic-batching window: maximum time a dispatching worker waits for
    /// stragglers after the first request of a batch arrives.
    pub max_wait: Duration,
    /// Bound on queued requests ([`ServeError::QueueFull`] backpressure
    /// for open-loop clients).
    pub queue_depth: usize,
    /// Engine replicas (worker threads). Clamped to at least 1.
    pub replicas: usize,
    /// Intra-op kernel threads *per replica*
    /// ([`crate::runtime::PrepareOptions::intra_op_threads`]). 0 = auto:
    /// `hardware_threads / replicas`, so the deployment never
    /// oversubscribes (`LSQNET_THREADS` still caps process-wide).
    pub intra_threads: usize,
    /// Low-memory weight mode: skip bind-time panelization and unpack
    /// weight tiles per call (`UnpackMode::Fused`, via
    /// [`crate::runtime::PrepareOptions::low_memory`]) — for
    /// memory-constrained deployments; the panelized default is faster.
    /// `false` defers to the `LSQNET_FUSED_UNPACK` environment knob
    /// rather than overriding it.
    pub fused_unpack: bool,
}

impl Server {
    /// Start `replicas` worker threads serving `family`.
    ///
    /// Manifest/params problems surface here; per-replica engine failures
    /// (e.g. a missing HLO artifact on the XLA backend) are reported on
    /// stderr by the failing worker.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let registry = ModelRegistry::open(cfg.backend);
        let replicas = cfg.replicas.max(1);
        registry.load(
            &cfg.family,
            &VariantOptions {
                checkpoint: cfg.checkpoint,
                replicas,
                max_wait: cfg.max_wait,
                queue_depth: cfg.queue_depth,
                intra_threads: cfg.intra_threads,
                // `None` (not `Some(false)`) when the flag is unset: the
                // engine's LSQNET_FUSED_UNPACK env default must not be
                // stomped — the ordering footgun PrepareOptions removes.
                low_memory: if cfg.fused_unpack { Some(true) } else { None },
            },
        )?;
        Ok(Server { registry, variant: cfg.family, replicas })
    }

    /// A submit handle (cloneable, usable from any thread), or
    /// [`ServeError::Closed`] after [`Server::close_intake`] — a closed
    /// server accepts no new requests (this used to panic).
    pub fn client(&self) -> Result<ServeClient, ServeError> {
        let session = self.registry.session(&self.variant)?;
        // A closed intake means close_intake was called: hand the typed
        // error to the caller up front instead of failing every submit.
        if !session.is_open() {
            return Err(ServeError::Closed);
        }
        Ok(ServeClient { session })
    }

    /// Stop accepting new requests: every already-accepted request is
    /// still dispatched promptly (no `max_wait` straggler window) and
    /// answered exactly once; subsequent submits on existing clients
    /// observe [`ServeError::Closed`].
    pub fn close_intake(&mut self) {
        let _ = self.registry.close_intake(&self.variant);
    }

    /// Snapshot of the aggregate metrics.
    pub fn stats(&self) -> ServeStats {
        self.registry.stats(&self.variant).unwrap_or_default()
    }

    /// Drain and stop all replicas and join them: close the intake,
    /// dispatch and answer everything already accepted, join. Joining
    /// never hangs on a long `max_wait`, even while caller clients stay
    /// alive — client handles never hold the queue open.
    pub fn stop(self) {
        self.registry.shutdown();
    }
}
