//! Quantized-inference serving path (Figure 1 deployed): a request router +
//! dynamic batcher in front of N engine replicas.
//!
//! Architecture (vLLM-router-shaped, scaled to this model family):
//!  * callers submit single images from any thread via a cloneable
//!    [`ServeClient`] and block on (or poll) a reply channel;
//!  * `replicas` worker threads each open their **own** engine from a
//!    [`BackendSpec`] (the XLA client is `Rc`-backed and not `Send`; the
//!    native engine is `Send` but keeps per-model packed state thread-local
//!    anyway) and drain one shared queue. Each worker applies *dynamic
//!    batching*: dispatch as soon as `batch` rows are waiting, or after
//!    `max_wait` with whatever is there (tail rows are zero-padded only
//!    for fixed-shape backends — see `Backend::fixed_batch`);
//!  * the queue hand-off is serialized (a mutex around the receiver) but
//!    execution is not, so replicas overlap on the expensive part — the
//!    forward pass;
//!  * per-request latency and batch-occupancy metrics are accumulated for
//!    the serve bench (EXPERIMENTS.md §Perf L3).
//!
//! With the native backend this runs entirely from packed weights and
//! scales across cores on two axes: replicas (inter-op) and the kernel
//! layer's row-block threading (intra-op). `Server::start` partitions the
//! host's cores across replicas via
//! [`crate::runtime::Backend::set_intra_op_threads`]
//! (`ServerConfig::intra_threads`, default `cores / replicas`) so the two
//! axes never oversubscribe. With the XLA backend `replicas > 1` simply
//! opens one PJRT client per worker (same memory model as the sweep
//! coordinator).

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::{Backend as _, BackendKind, BackendSpec, Manifest};
use crate::tensor::Tensor;

/// One queued inference request (internal to the server).
pub struct Request {
    /// Flattened NHWC image, `image * image * channels` floats.
    pub image: Vec<f32>,
    submitted: Instant,
    reply: SyncSender<Reply>,
}

/// The answer a client receives for one image.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// Index of the winning class.
    pub argmax: usize,
    /// Time spent queued + batching before execution started.
    pub queue_ms: f64,
    /// End-to-end latency (submit → reply).
    pub total_ms: f64,
}

/// Aggregate serving metrics across all replicas.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests answered.
    pub requests: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Rows dispatched including padding.
    pub rows_dispatched: u64,
    /// Total forward-pass wall time.
    pub exec_ms_total: f64,
    /// Sum over batches of real/batch (for mean occupancy).
    pub occupancy_sum: f64,
}

impl ServeStats {
    /// Mean fraction of each dispatched batch holding real requests.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum / self.batches as f64
        }
    }

    /// Mean forward-pass time per batch.
    pub fn mean_exec_ms(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.exec_ms_total / self.batches as f64
        }
    }
}

/// Cloneable handle for submitting requests from any thread.
#[derive(Clone)]
pub struct ServeClient {
    tx: SyncSender<Request>,
    image_len: usize,
}

impl ServeClient {
    /// Blocking single-request inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| anyhow!("server shut down"))
    }

    /// Async submit; returns the reply channel.
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Reply>> {
        if image.len() != self.image_len {
            anyhow::bail!("image must have {} floats, got {}", self.image_len, image.len());
        }
        let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(Request { image, submitted: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow!("server shut down"))?;
        Ok(reply_rx)
    }
}

/// A running inference server: client handle, shared stats, worker handles.
pub struct Server {
    /// The server-held submit handle; `None` after [`Server::close_intake`].
    client: Option<ServeClient>,
    /// Shared metrics, updated by every replica.
    pub stats: Arc<Mutex<ServeStats>>,
    /// Number of engine replicas actually started.
    pub replicas: usize,
    stop: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Server configuration.
pub struct ServerConfig {
    /// Which engine to open (and over which artifacts directory); each
    /// replica opens its own instance.
    pub backend: BackendSpec,
    /// Model family to serve, e.g. `"cnn_small_q2"`.
    pub family: String,
    /// Checkpoint with trained params (empty = the family's initial params).
    pub checkpoint: String,
    /// Dynamic-batching window: maximum time a dispatching worker waits for
    /// stragglers after the first request of a batch arrives.
    pub max_wait: Duration,
    /// Bound on queued requests (backpressure for open-loop clients).
    pub queue_depth: usize,
    /// Engine replicas (worker threads). Clamped to at least 1.
    pub replicas: usize,
    /// Intra-op kernel threads *per replica*
    /// ([`crate::runtime::Backend::set_intra_op_threads`]). 0 = auto:
    /// `hardware_threads / replicas`, so the deployment never
    /// oversubscribes (`LSQNET_THREADS` still caps process-wide).
    pub intra_threads: usize,
    /// Low-memory weight mode: skip bind-time panelization and unpack
    /// weight tiles per call (`UnpackMode::Fused`,
    /// [`crate::runtime::Backend::set_low_memory`]) — for
    /// memory-constrained deployments; the panelized default is faster.
    /// ORed with the `LSQNET_FUSED_UNPACK=1` environment knob.
    pub fused_unpack: bool,
}

impl Server {
    /// Start `replicas` worker threads serving `family`.
    ///
    /// Manifest/params problems surface here; per-replica engine failures
    /// (e.g. a missing HLO artifact on the XLA backend) are reported on
    /// stderr by the failing worker.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        // Resolve geometry and parameters on the caller thread so startup
        // errors surface synchronously.
        let manifest = Manifest::load(&cfg.backend.artifacts_dir)?;
        let image_len = manifest.image * manifest.image * manifest.channels;
        let classes = manifest.family(&cfg.family)?.num_classes;
        let params: Vec<Tensor> = if cfg.checkpoint.is_empty() {
            manifest.load_initial_params(&cfg.family)?
        } else {
            crate::train::TrainState::load(&manifest, Path::new(&cfg.checkpoint))?.params
        };
        // Fail fast on configuration errors a replica could otherwise only
        // report to stderr after start() already returned Ok.
        match cfg.backend.kind {
            BackendKind::Native => {
                // Dry-run bind: catches unsupported architectures and
                // missing/mis-shaped parameters synchronously, at the cost
                // of one extra quantize+pack at startup. Always fused here
                // — panelizing twice would double peak startup memory for
                // no extra validation.
                crate::runtime::native::NativeModel::build_with_mode(
                    &manifest,
                    &cfg.family,
                    &params,
                    crate::runtime::native::UnpackMode::Fused,
                )?;
            }
            BackendKind::Xla => {
                cfg.backend.check_available()?;
                manifest.find("infer", &cfg.family, None, None)?;
            }
        }
        drop(manifest);

        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(cfg.queue_depth.max(1));
        // The shared queue: workers take turns holding the receiver while
        // they collect a batch, then release it for the next replica.
        let shared_rx = Arc::new(Mutex::new(rx));
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(ServeStats::default()));

        let replicas = cfg.replicas.max(1);
        // Partition the host's cores across replicas unless the caller
        // pinned an explicit per-replica intra-op width.
        let intra_threads = if cfg.intra_threads == 0 {
            (crate::runtime::kernels::hardware_threads() / replicas).max(1)
        } else {
            cfg.intra_threads
        };
        let cfg_fused_unpack = cfg.fused_unpack;
        let mut handles = Vec::with_capacity(replicas);
        for rid in 0..replicas {
            let spec = cfg.backend.clone();
            let family = cfg.family.clone();
            let params = params.clone();
            let shared_rx = shared_rx.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let max_wait = cfg.max_wait;
            let handle = std::thread::Builder::new()
                .name(format!("lsq-serve-{rid}"))
                .spawn(move || {
                    if let Err(e) = replica_loop(
                        &spec,
                        &family,
                        &params,
                        &shared_rx,
                        &stop,
                        &stats,
                        max_wait,
                        classes,
                        image_len,
                        intra_threads,
                        cfg_fused_unpack,
                    ) {
                        eprintln!("serve replica {rid}: {e:#}");
                    }
                })?;
            handles.push(handle);
        }

        Ok(Server {
            client: Some(ServeClient { tx, image_len }),
            stats,
            replicas,
            stop,
            handles,
        })
    }

    /// A submit handle (cloneable, usable from any thread).
    ///
    /// # Panics
    /// After [`Server::close_intake`] — a closed server accepts no new
    /// requests.
    pub fn client(&self) -> ServeClient {
        self.client.as_ref().expect("server intake already closed").clone()
    }

    /// Stop accepting new requests by dropping the server-held sender.
    /// Once every caller-held [`ServeClient`] clone is dropped too, the
    /// queue disconnects: replicas dispatch whatever is pending
    /// immediately (no `max_wait` stragglers wait) and exit — every
    /// already-submitted request still receives exactly one reply.
    pub fn close_intake(&mut self) {
        self.client = None;
    }

    /// Snapshot of the aggregate metrics.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Stop all replicas and join them: close the intake, flag shutdown,
    /// join. Requests a replica already collected into its current batch
    /// are dispatched and answered; requests still sitting in the queue
    /// receive a disconnect on their reply channels (for a drain-then-stop
    /// shutdown, call [`Server::close_intake`], drop caller clients, and
    /// collect replies first). The stop flag bounds the batching wait, so
    /// joining never hangs on a long `max_wait` even while caller clients
    /// stay alive.
    pub fn stop(mut self) {
        self.close_intake();
        self.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One replica: open an engine, bind the family, then batch-and-execute
/// until shutdown.
#[allow(clippy::too_many_arguments)]
fn replica_loop(
    spec: &BackendSpec,
    family: &str,
    params: &[Tensor],
    shared_rx: &Mutex<Receiver<Request>>,
    stop: &AtomicBool,
    stats: &Mutex<ServeStats>,
    max_wait: Duration,
    classes: usize,
    image_len: usize,
    intra_threads: usize,
    fused_unpack: bool,
) -> Result<()> {
    let mut backend = spec.open()?;
    backend.set_intra_op_threads(intra_threads);
    // Only *opt into* low memory here: a freshly opened native engine
    // already resolved the LSQNET_FUSED_UNPACK env default itself, and
    // unconditionally pushing `false` would stomp it.
    if fused_unpack {
        backend.set_low_memory(true);
    }
    backend.prepare_infer(family, params)?;
    let batch = backend.batch();
    let mut pending: Vec<Request> = Vec::with_capacity(batch);

    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        // Collect a batch while holding the queue; execution happens after
        // the lock is released so replicas overlap on the forward pass.
        {
            let rx = match shared_rx.lock() {
                Ok(g) => g,
                Err(_) => return Ok(()), // another replica panicked
            };
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => continue, // re-check stop
                Err(RecvTimeoutError::Disconnected) => return Ok(()),
            }
            let deadline = Instant::now() + max_wait;
            while pending.len() < batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() || stop.load(Ordering::Relaxed) {
                    // Shutdown mid-collection: dispatch what we have so
                    // every collected request still gets its reply, even
                    // when max_wait is long.
                    break;
                }
                // Wait in short slices so the stop flag bounds the
                // batching window instead of max_wait.
                match rx.recv_timeout(left.min(Duration::from_millis(20))) {
                    Ok(r) => pending.push(r),
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }

        // Assemble the batch; pad the tail only for fixed-shape backends
        // (the native backend runs exactly `real` rows).
        let real = pending.len();
        let rows = if backend.fixed_batch() { batch } else { real };
        let mut x = vec![0.0f32; rows * image_len];
        for (row, req) in pending.iter().enumerate() {
            x[row * image_len..(row + 1) * image_len].copy_from_slice(&req.image);
        }

        let t_exec = Instant::now();
        let logits = backend.infer(&x)?;
        let exec_ms = t_exec.elapsed().as_secs_f64() * 1e3;

        {
            let mut s = stats.lock().unwrap();
            s.batches += 1;
            s.requests += real as u64;
            s.rows_dispatched += rows as u64;
            s.exec_ms_total += exec_ms;
            // Occupancy stays relative to the target batch size: it
            // measures how full the batcher runs, not the dispatch shape.
            s.occupancy_sum += real as f64 / batch as f64;
        }

        for (row, req) in pending.drain(..).enumerate() {
            let lg = logits[row * classes..(row + 1) * classes].to_vec();
            let argmax = lg
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            let total_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
            let _ = req.reply.send(Reply {
                logits: lg,
                argmax,
                queue_ms: (total_ms - exec_ms).max(0.0),
                total_ms,
            });
        }
    }
}
