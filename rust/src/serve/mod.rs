//! Quantized-inference serving path (Figure 1 deployed): a request router +
//! dynamic batcher in front of engine replicas, multi-model by design.
//!
//! Architecture (vLLM-router-shaped, scaled to this model family):
//!
//!  * [`registry::ModelRegistry`] is the serving surface: one process
//!    hosts many bound model **variants** (e.g. `cnn_small_q2/q3/q4/q8` —
//!    the same architecture at several precisions, LSQ's whole point),
//!    each with its own request queue, replica set and [`ServeStats`],
//!    sharing one core budget. Requests address a variant by name through
//!    a [`registry::Session`] handle, and variants hot load/unload under
//!    live traffic;
//!  * each replica worker opens its **own** engine from a
//!    [`crate::runtime::BackendSpec`] (the XLA client is `Rc`-backed and
//!    not `Send`; the native engine is `Send` but keeps per-model packed
//!    state thread-local anyway), configured once via
//!    [`crate::runtime::PrepareOptions`], and drains its variant's queue
//!    with *dynamic batching*: dispatch as soon as `batch` rows are
//!    waiting, or after `max_wait` with whatever is there (tail rows are
//!    zero-padded only for fixed-shape backends — see
//!    `Backend::fixed_batch`);
//!  * the queue hand-off is serialized (a mutex around the receiver) but
//!    execution is not, so replicas overlap on the expensive part — the
//!    forward pass;
//!  * every client-visible failure is a typed [`ServeError`]
//!    (`Closed` / `UnknownModel` / `QueueFull` / `ShutDown` / `BadImage`),
//!    so open-loop clients get real backpressure semantics instead of
//!    panics or silently dropped reply channels.
//!
//! [`Server`]/[`ServerConfig`] survive as a thin one-variant compatibility
//! shim over the registry. With the native backend this runs entirely from
//! packed weights and scales across cores on two axes: replicas (inter-op)
//! and the kernel layer's row-block threading (intra-op), partitioned so
//! the two never oversubscribe (DESIGN.md §Serving-API).
//!
//! [`net`] exposes all of this over TCP: length-delimited JSON frames,
//! every [`ServeError`] variant mapped to a structured wire error, and
//! connection drain composed with `drain_and_unload` (DESIGN.md
//! §Wire-protocol).
//!
//! [`tier`] closes the loop the registry only enables: a
//! [`TierController`] samples windowed per-variant stats against a
//! latency SLO and shifts routing across an ordered precision ladder
//! (`q8 → q4 → q2`), shedding load ([`ServeError::Shed`]) only when the
//! whole ladder is saturated (DESIGN.md §Serving-API).
//!
//! The stack is self-healing and testably so (DESIGN.md §Fault-model): a
//! per-variant supervisor respawns dead replica threads under a
//! [`RestartPolicy`] (jittered exponential backoff, rolling restart
//! budget; exhaustion flips the variant unhealthy so the tier controller
//! fails over), clients carry retry/deadline budgets
//! ([`net::RetryPolicy`], `deadline_ms`), and [`fault`] provides the
//! seeded deterministic fault injection the chaos tests drive it all with.

pub mod fault;
pub mod net;
pub mod registry;
pub mod tier;

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, SyncSender};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::BackendSpec;

pub use fault::{FaultPlan, FaultSpec, NetFault, ReplicaFault};
pub use registry::{ModelRegistry, RestartPolicy, Session, VariantOptions};
pub use tier::{TierConfig, TierController, TierDecision, TierDriver, TierEvent, TierSignal};

/// One queued inference request (internal to the serve layer).
pub struct Request {
    /// Flattened NHWC image, `image * image * channels` floats.
    pub image: Vec<f32>,
    submitted: Instant,
    /// Absolute deadline (from the client's `deadline_ms` budget). A
    /// replica sheds the request at dequeue once this has passed —
    /// answering [`ServeError::DeadlineExceeded`] instead of burning a
    /// forward pass on an answer nobody is waiting for.
    expires: Option<Instant>,
    reply: SyncSender<Result<Reply, ServeError>>,
}

/// The answer a client receives for one image.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// Index of the winning class.
    pub argmax: usize,
    /// Time spent queued + batching before execution started.
    pub queue_ms: f64,
    /// End-to-end latency (submit → reply).
    pub total_ms: f64,
}

/// Typed client-visible serving failures. Everything an open-loop client
/// can hit is represented — no panics, no silently dropped reply channels.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The variant's intake was closed (`close_intake` / drain): new
    /// requests are not accepted; already-accepted ones are still answered.
    Closed,
    /// No variant with this name is loaded in the registry.
    UnknownModel(String),
    /// The variant's request queue is at `depth`: backpressure. Retry,
    /// shed, or route to another tier — the submit never blocks.
    QueueFull {
        /// The configured queue bound that was hit.
        depth: usize,
    },
    /// The serving side went away (replicas exited or the reply channel
    /// dropped mid-request).
    ShutDown,
    /// The image has the wrong number of floats for the variant's
    /// geometry.
    BadImage {
        /// Floats submitted.
        got: usize,
        /// Floats the variant's `image × image × channels` geometry needs.
        want: usize,
    },
    /// Every tier of the routed precision ladder is saturated: the request
    /// was not accepted anywhere and has been shed. Unlike
    /// [`ServeError::QueueFull`] — one variant's backpressure, where the
    /// right response is to retry or route to another tier — shedding
    /// means the whole ladder is out of capacity: back off before
    /// retrying. Only the [`tier::TierController`] produces this; a bare
    /// [`Session`] reports per-queue `QueueFull`.
    Shed,
    /// The request's `deadline_ms` budget expired before a replica got to
    /// it: the server shed it at dequeue without executing. The client was
    /// no longer waiting (or was about to stop), so retrying with a fresh
    /// budget is the only sensible follow-up.
    DeadlineExceeded,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Closed => write!(f, "intake closed: variant no longer accepts requests"),
            ServeError::UnknownModel(name) => write!(f, "unknown model variant {name:?}"),
            ServeError::QueueFull { depth } => {
                write!(f, "request queue full (depth {depth}): backpressure, retry later")
            }
            ServeError::ShutDown => write!(f, "server shut down"),
            ServeError::BadImage { got, want } => {
                write!(f, "image must have {want} floats, got {got}")
            }
            ServeError::Shed => {
                write!(f, "all precision tiers saturated: request shed, back off before retrying")
            }
            ServeError::DeadlineExceeded => {
                write!(f, "request deadline expired before execution; shed at dequeue")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Aggregate serving metrics for one variant (all of its replicas).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests answered.
    pub requests: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Rows dispatched including padding.
    pub rows_dispatched: u64,
    /// Zero rows padded onto batch tails for fixed-shape backends
    /// (`rows_dispatched − requests`), kept separately so
    /// [`ServeStats::mean_exec_ms`] can be attributed: exec time is per
    /// dispatched batch, and this is how much of each batch was padding
    /// (EXPERIMENTS.md §Perf L3 reports the tail-padding overhead per
    /// backend from it).
    pub padding_rows: u64,
    /// Total forward-pass wall time.
    pub exec_ms_total: f64,
    /// Summed per-request queue+batching time (submit → execution start).
    pub queue_ms_total: f64,
    /// Sum over batches of real/batch (for mean occupancy).
    pub occupancy_sum: f64,
    /// Replica worker threads that exited on an engine error (open /
    /// prepare / execute failure). The variant keeps serving on its
    /// surviving replicas, so this is the liveness signal a controller
    /// reads: `replica_failures` ≥ the configured replica count means the
    /// variant is dead even though its intake still accepts requests.
    pub replica_failures: u64,
    /// Replica threads respawned by the variant's supervisor after a
    /// failure (jittered exponential backoff under a rolling restart
    /// budget — see [`RestartPolicy`]). `replica_failures` counts deaths;
    /// this counts recoveries. A widening gap means the budget is
    /// exhausted and the variant has been marked unhealthy.
    pub replica_restarts: u64,
    /// Requests shed at dequeue because their `deadline_ms` budget had
    /// already expired (answered [`ServeError::DeadlineExceeded`], never
    /// executed).
    pub deadline_expired: u64,
    /// Accepted requests answered with a terminal error (engine execution
    /// failure or a replica death mid-batch) instead of a [`Reply`]. Part
    /// of the "accepted ⇒ answered exactly once" ledger: `requests +
    /// deadline_expired + failed_requests` is everything answered.
    pub failed_requests: u64,
}

impl ServeStats {
    /// Mean fraction of each dispatched batch holding real requests.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum / self.batches as f64
        }
    }

    /// Mean forward-pass time per batch. Note this is per *dispatched*
    /// batch — on fixed-shape backends it includes the cost of
    /// [`ServeStats::padding_rows`]; real-row throughput in requests per
    /// second is `1e3 * requests / exec_ms_total` (`exec_ms_total` is in
    /// milliseconds, so the bare ratio would be requests per *milli*second).
    pub fn mean_exec_ms(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.exec_ms_total / self.batches as f64
        }
    }

    /// Mean time a request spends queued + batching before its batch
    /// starts executing.
    pub fn mean_queue_ms(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.queue_ms_total / self.requests as f64
        }
    }

    /// Every accepted request that has been answered — with a [`Reply`]
    /// (`requests`), a deadline shed (`deadline_expired`) or a terminal
    /// error (`failed_requests`). `accepted − answered()` is the true
    /// in-flight count; the registry's exactly-once ledger balances when
    /// this reaches the accepted count.
    pub fn answered(&self) -> u64 {
        self.requests + self.deadline_expired + self.failed_requests
    }

    /// Mean fraction of dispatched rows that were tail padding.
    pub fn padding_fraction(&self) -> f64 {
        if self.rows_dispatched == 0 {
            0.0
        } else {
            self.padding_rows as f64 / self.rows_dispatched as f64
        }
    }

    /// The stats accumulated *since* an earlier snapshot of the same
    /// variant: every counter field of `self − earlier`, saturating at
    /// zero so a stale/reset baseline degrades to lifetime totals instead
    /// of underflowing. The derived means (`mean_queue_ms`,
    /// `mean_occupancy`, …) then describe only that interval — this is
    /// what [`StatsWindow`] and the tier controller use so SLO decisions
    /// see recent load, not lifetime averages.
    pub fn delta_since(&self, earlier: &ServeStats) -> ServeStats {
        ServeStats {
            requests: self.requests.saturating_sub(earlier.requests),
            batches: self.batches.saturating_sub(earlier.batches),
            rows_dispatched: self.rows_dispatched.saturating_sub(earlier.rows_dispatched),
            padding_rows: self.padding_rows.saturating_sub(earlier.padding_rows),
            exec_ms_total: (self.exec_ms_total - earlier.exec_ms_total).max(0.0),
            queue_ms_total: (self.queue_ms_total - earlier.queue_ms_total).max(0.0),
            occupancy_sum: (self.occupancy_sum - earlier.occupancy_sum).max(0.0),
            replica_failures: self.replica_failures.saturating_sub(earlier.replica_failures),
            replica_restarts: self.replica_restarts.saturating_sub(earlier.replica_restarts),
            deadline_expired: self.deadline_expired.saturating_sub(earlier.deadline_expired),
            failed_requests: self.failed_requests.saturating_sub(earlier.failed_requests),
        }
    }
}

/// A rolling window over [`ServeStats`] snapshots: push the latest
/// cumulative snapshot each sampling epoch and get back the stats
/// accumulated over the most recent `cap` epochs ([`ServeStats::delta_since`]
/// the snapshot that fell off the back). Until `cap` snapshots have been
/// pushed the window covers all history so far — with a `Default`
/// (all-zero) baseline that is still a correct delta, just a wider one.
#[derive(Clone, Debug)]
pub struct StatsWindow {
    cap: usize,
    baseline: ServeStats,
    snaps: VecDeque<ServeStats>,
}

impl StatsWindow {
    /// A window spanning `cap` pushes (clamped to at least 1).
    pub fn new(cap: usize) -> StatsWindow {
        StatsWindow { cap: cap.max(1), baseline: ServeStats::default(), snaps: VecDeque::new() }
    }

    /// Number of pushes the window spans once full.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Record the newest cumulative snapshot and return the windowed
    /// delta (newest minus the baseline that slid off the back).
    pub fn push(&mut self, snapshot: ServeStats) -> ServeStats {
        self.snaps.push_back(snapshot);
        if self.snaps.len() > self.cap {
            // The oldest in-window snapshot becomes the new baseline: the
            // returned delta always spans exactly the last `cap` pushes.
            self.baseline = self.snaps.pop_front().expect("window non-empty");
        }
        self.snaps.back().expect("just pushed").delta_since(&self.baseline)
    }
}

/// Cloneable handle for submitting requests to a [`Server`] from any
/// thread — a named-variant [`Session`] under the hood.
#[derive(Clone)]
pub struct ServeClient {
    session: Session,
}

impl ServeClient {
    /// Blocking single-request inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply, ServeError> {
        self.session.infer(image)
    }

    /// Non-blocking submit; returns the reply channel (each accepted
    /// request is answered exactly once with `Ok(Reply)` or a terminal
    /// `Err`). See [`Session::submit`] for the error contract
    /// ([`ServeError::QueueFull`] backpressure instead of blocking).
    pub fn submit(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, ServeError> {
        self.session.submit(image)
    }
}

/// A running one-variant inference server: the compatibility shim over
/// [`ModelRegistry`] for callers that serve a single family. New code
/// serving several precision tiers should use the registry directly.
pub struct Server {
    registry: ModelRegistry,
    variant: String,
    /// Number of engine replicas actually started.
    pub replicas: usize,
}

/// One-variant server configuration (the [`Server`] shim; multi-variant
/// deployments configure each variant via [`VariantOptions`]).
pub struct ServerConfig {
    /// Which engine to open (and over which artifacts directory); each
    /// replica opens its own instance.
    pub backend: BackendSpec,
    /// Model family to serve, e.g. `"cnn_small_q2"`.
    pub family: String,
    /// Checkpoint with trained params (empty = the family's initial params).
    pub checkpoint: String,
    /// Dynamic-batching window: maximum time a dispatching worker waits for
    /// stragglers after the first request of a batch arrives.
    pub max_wait: Duration,
    /// Bound on queued requests ([`ServeError::QueueFull`] backpressure
    /// for open-loop clients).
    pub queue_depth: usize,
    /// Engine replicas (worker threads). Clamped to at least 1.
    pub replicas: usize,
    /// Intra-op kernel threads *per replica*
    /// ([`crate::runtime::PrepareOptions::intra_op_threads`]). 0 = auto:
    /// `hardware_threads / replicas`, so the deployment never
    /// oversubscribes (`LSQNET_THREADS` still caps process-wide).
    pub intra_threads: usize,
    /// Low-memory weight mode: skip bind-time panelization and unpack
    /// weight tiles per call (`UnpackMode::Fused`, via
    /// [`crate::runtime::PrepareOptions::low_memory`]) — for
    /// memory-constrained deployments; the panelized default is faster.
    /// `false` defers to the `LSQNET_FUSED_UNPACK` environment knob
    /// rather than overriding it.
    pub fused_unpack: bool,
}

impl Server {
    /// Start `replicas` worker threads serving `family`.
    ///
    /// Manifest/params problems surface here; per-replica engine failures
    /// (e.g. a missing HLO artifact on the XLA backend) are reported on
    /// stderr by the failing worker.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let registry = ModelRegistry::open(cfg.backend);
        let replicas = cfg.replicas.max(1);
        registry.load(
            &cfg.family,
            &VariantOptions {
                checkpoint: cfg.checkpoint,
                replicas,
                max_wait: cfg.max_wait,
                queue_depth: cfg.queue_depth,
                intra_threads: cfg.intra_threads,
                // `None` (not `Some(false)`) when the flag is unset: the
                // engine's LSQNET_FUSED_UNPACK env default must not be
                // stomped — the ordering footgun PrepareOptions removes.
                low_memory: if cfg.fused_unpack { Some(true) } else { None },
                ..VariantOptions::default()
            },
        )?;
        Ok(Server { registry, variant: cfg.family, replicas })
    }

    /// A submit handle (cloneable, usable from any thread), or
    /// [`ServeError::Closed`] after [`Server::close_intake`] — a closed
    /// server accepts no new requests (this used to panic).
    pub fn client(&self) -> Result<ServeClient, ServeError> {
        let session = self.registry.session(&self.variant)?;
        // A closed intake means close_intake was called: hand the typed
        // error to the caller up front instead of failing every submit.
        if !session.is_open() {
            return Err(ServeError::Closed);
        }
        Ok(ServeClient { session })
    }

    /// Stop accepting new requests: every already-accepted request is
    /// still dispatched promptly (no `max_wait` straggler window) and
    /// answered exactly once; subsequent submits on existing clients
    /// observe [`ServeError::Closed`].
    pub fn close_intake(&mut self) {
        let _ = self.registry.close_intake(&self.variant);
    }

    /// Snapshot of the aggregate metrics.
    pub fn stats(&self) -> ServeStats {
        self.registry.stats(&self.variant).unwrap_or_default()
    }

    /// Drain and stop all replicas and join them: close the intake,
    /// dispatch and answer everything already accepted, join. Joining
    /// never hangs on a long `max_wait`, even while caller clients stay
    /// alive — client handles never hold the queue open.
    pub fn stop(self) {
        self.registry.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(requests: u64, queue_ms_total: f64, failures: u64) -> ServeStats {
        ServeStats {
            requests,
            batches: requests,
            rows_dispatched: requests,
            padding_rows: 0,
            exec_ms_total: requests as f64 * 0.5,
            queue_ms_total,
            occupancy_sum: requests as f64,
            replica_failures: failures,
            replica_restarts: failures / 2,
            deadline_expired: 0,
            failed_requests: 0,
        }
    }

    #[test]
    fn delta_since_subtracts_every_counter_and_saturates() {
        let early = snap(10, 20.0, 1);
        let late = snap(25, 80.0, 3);
        let d = late.delta_since(&early);
        assert_eq!(d.requests, 15);
        assert_eq!(d.batches, 15);
        assert!((d.queue_ms_total - 60.0).abs() < 1e-9);
        assert!((d.exec_ms_total - 7.5).abs() < 1e-9);
        assert_eq!(d.replica_failures, 2);
        assert_eq!(d.replica_restarts, 1);
        assert!((d.mean_queue_ms() - 4.0).abs() < 1e-9);
        // A stale baseline (counters ahead of the snapshot) saturates to
        // zero instead of wrapping — the window degrades, never panics.
        let d = early.delta_since(&late);
        assert_eq!(d.requests, 0);
        assert_eq!(d.queue_ms_total, 0.0);
        assert_eq!(d.mean_queue_ms(), 0.0);
    }

    #[test]
    fn stats_window_covers_exactly_the_last_cap_pushes() {
        let mut w = StatsWindow::new(2);
        assert_eq!(w.cap(), 2);
        // Until the window fills, deltas span all history so far.
        let d = w.push(snap(4, 8.0, 0));
        assert_eq!(d.requests, 4);
        let d = w.push(snap(10, 20.0, 0));
        assert_eq!(d.requests, 10);
        // Third push: the first snapshot becomes the baseline.
        let d = w.push(snap(12, 30.0, 0));
        assert_eq!(d.requests, 8);
        assert!((d.queue_ms_total - 22.0).abs() < 1e-9);
        // An idle stretch (unchanged counters) windows down to zero load.
        let d = w.push(snap(12, 30.0, 0));
        let d2 = w.push(snap(12, 30.0, 0));
        assert_eq!(d.requests, 2);
        assert_eq!(d2.requests, 0);
        assert_eq!(d2.mean_queue_ms(), 0.0);
    }

    #[test]
    fn answered_sums_the_exactly_once_ledger() {
        let s = ServeStats {
            requests: 10,
            deadline_expired: 3,
            failed_requests: 2,
            ..ServeStats::default()
        };
        assert_eq!(s.answered(), 15);
    }

    #[test]
    fn stats_window_cap_is_clamped_to_one() {
        let mut w = StatsWindow::new(0);
        assert_eq!(w.cap(), 1);
        w.push(snap(5, 1.0, 0));
        let d = w.push(snap(9, 2.0, 0));
        assert_eq!(d.requests, 4);
    }
}
