//! Quantized-inference serving path (Figure 1 deployed): a request router +
//! dynamic batcher in front of an `infer` artifact.
//!
//! Architecture (vLLM-router-shaped, scaled to this model family):
//!  * callers submit single images from any thread via a cloneable
//!    [`ServeClient`] and block on (or poll) a reply channel;
//!  * one engine thread owns the non-`Send` PJRT client, drains the queue
//!    with a *dynamic batching* policy — dispatch as soon as `batch` rows
//!    are waiting, or after `max_wait` with whatever is there (padding the
//!    tail rows) — and fans results back out;
//!  * per-request latency and batch-occupancy metrics are accumulated for
//!    the serve bench (EXPERIMENTS.md §Perf L3).

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::Engine;
use crate::tensor::Tensor;

pub struct Request {
    pub image: Vec<f32>, // 32*32*3
    submitted: Instant,
    reply: SyncSender<Reply>,
}

#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub argmax: usize,
    pub queue_ms: f64,
    pub total_ms: f64,
}

#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub rows_dispatched: u64,
    pub exec_ms_total: f64,
    pub occupancy_sum: f64,
}

impl ServeStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum / self.batches as f64
        }
    }

    pub fn mean_exec_ms(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.exec_ms_total / self.batches as f64
        }
    }
}

#[derive(Clone)]
pub struct ServeClient {
    tx: SyncSender<Request>,
    image_len: usize,
}

impl ServeClient {
    /// Blocking single-request inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| anyhow!("server shut down"))
    }

    /// Async submit; returns the reply channel.
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Reply>> {
        if image.len() != self.image_len {
            anyhow::bail!("image must have {} floats, got {}", self.image_len, image.len());
        }
        let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(Request { image, submitted: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow!("server shut down"))?;
        Ok(reply_rx)
    }
}

pub struct Server {
    pub client: ServeClient,
    pub stats: Arc<Mutex<ServeStats>>,
    shutdown: SyncSender<()>,
    handle: Option<std::thread::JoinHandle<()>>,
}

pub struct ServerConfig {
    pub artifacts_dir: std::path::PathBuf,
    pub family: String,
    /// Checkpoint with trained params (empty = AOT initial params).
    pub checkpoint: String,
    pub max_wait: Duration,
    pub queue_depth: usize,
}

impl Server {
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(cfg.queue_depth.max(1));
        let (stop_tx, stop_rx) = std::sync::mpsc::sync_channel::<()>(1);
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats_bg = stats.clone();

        // Resolve params on the caller thread so startup errors surface here.
        let engine_probe = Engine::new(&cfg.artifacts_dir)?;
        let infer_meta = engine_probe
            .manifest()
            .find("infer", &cfg.family, None, None)?
            .clone();
        let image_len: usize = infer_meta.inputs.last().unwrap().shape[1..].iter().product();
        drop(engine_probe);

        let handle = std::thread::Builder::new().name("lsq-serve".into()).spawn(move || {
            let run = || -> Result<()> {
                let engine = Engine::new(&cfg.artifacts_dir)?;
                let exe = engine.load(&infer_meta.id)?;
                let manifest = engine.manifest();
                let params: Vec<Tensor> = if cfg.checkpoint.is_empty() {
                    manifest.load_initial_params(&cfg.family)?
                } else {
                    let st = crate::train::TrainState::load(
                        manifest,
                        std::path::Path::new(&cfg.checkpoint),
                    )?;
                    st.params
                };
                let batch = exe.meta.batch;
                let img = image_len;
                let mut pending: Vec<Request> = Vec::with_capacity(batch);

                loop {
                    // Block for the first request (or shutdown).
                    if pending.is_empty() {
                        match rx.recv_timeout(Duration::from_millis(50)) {
                            Ok(r) => pending.push(r),
                            Err(RecvTimeoutError::Timeout) => {
                                if stop_rx.try_recv().is_ok() {
                                    return Ok(());
                                }
                                continue;
                            }
                            Err(RecvTimeoutError::Disconnected) => return Ok(()),
                        }
                    }
                    // Dynamic batching: fill until `batch` or `max_wait`.
                    let deadline = Instant::now() + cfg.max_wait;
                    while pending.len() < batch {
                        let left = deadline.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        match rx.recv_timeout(left) {
                            Ok(r) => pending.push(r),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }

                    // Assemble the padded batch.
                    let real = pending.len();
                    let mut x = vec![0.0f32; batch * img];
                    for (row, req) in pending.iter().enumerate() {
                        x[row * img..(row + 1) * img].copy_from_slice(&req.image);
                    }
                    let mut inputs = params.clone();
                    let mut shape = vec![batch];
                    shape.extend_from_slice(&infer_meta.inputs.last().unwrap().shape[1..]);
                    inputs.push(Tensor::from_f32(&shape, x));

                    let t_exec = Instant::now();
                    let out = exe.run(&inputs)?;
                    let exec_ms = t_exec.elapsed().as_secs_f64() * 1e3;
                    let logits = out[0].f32s()?;
                    let classes = out[0].shape[1];

                    {
                        let mut s = stats_bg.lock().unwrap();
                        s.batches += 1;
                        s.requests += real as u64;
                        s.rows_dispatched += batch as u64;
                        s.exec_ms_total += exec_ms;
                        s.occupancy_sum += real as f64 / batch as f64;
                    }

                    for (row, req) in pending.drain(..).enumerate() {
                        let lg = logits[row * classes..(row + 1) * classes].to_vec();
                        let argmax = lg
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        let total_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
                        let _ = req.reply.send(Reply {
                            logits: lg,
                            argmax,
                            queue_ms: total_ms - exec_ms,
                            total_ms,
                        });
                    }
                    if stop_rx.try_recv().is_ok() {
                        return Ok(());
                    }
                }
            };
            if let Err(e) = run() {
                eprintln!("serve thread error: {e:#}");
            }
        })?;

        Ok(Server {
            client: ServeClient { tx, image_len },
            stats,
            shutdown: stop_tx,
            handle: Some(handle),
        })
    }

    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn stop(mut self) {
        let _ = self.shutdown.send(());
        // Drop our client sender so the recv loop can observe disconnect.
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
