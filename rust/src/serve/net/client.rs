//! A small blocking client for the wire protocol — what the e2e tests,
//! the benches' load generator, and `lsqnet serve --listen` smoke traffic
//! use. One [`NetClient`] wraps one connection; it is not `Sync` — use
//! one per thread, or [`NetClient::split`] the connection into a send
//! half and a receive half for open-loop (pipelined) traffic where the
//! sender must never block on the receiver.

use std::io::{self, Read};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};

use super::frame::{self, FrameRead, MAX_FRAME_LEN};
use super::wire::{NetRequest, NetResponse, RespBody, WireError};
use crate::serve::Reply;
use crate::util::json::Json;

/// Everything a client call can fail with.
#[derive(Debug)]
pub enum NetClientError {
    /// The socket failed (connect, reset, broken pipe).
    Io(io::Error),
    /// The server broke the protocol: unparseable frame, mismatched id,
    /// wrong body for the op, or closed mid-frame.
    Protocol(String),
    /// The server answered with a structured wire error — the remote
    /// image of [`crate::serve::ServeError`], e.g. `QueueFull`
    /// backpressure or `UnknownModel`.
    Wire(WireError),
}

impl std::fmt::Display for NetClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetClientError::Io(e) => write!(f, "i/o: {e}"),
            NetClientError::Protocol(msg) => write!(f, "protocol: {msg}"),
            NetClientError::Wire(e) => write!(f, "server: {e}"),
        }
    }
}

impl std::error::Error for NetClientError {}

impl From<io::Error> for NetClientError {
    fn from(e: io::Error) -> NetClientError {
        NetClientError::Io(e)
    }
}

/// One blocking connection to a [`super::NetServer`].
pub struct NetClient {
    stream: TcpStream,
    buf: Vec<u8>,
    next_id: u64,
}

impl NetClient {
    /// Connect to a serving endpoint.
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient { stream, buf: Vec::new(), next_id: 0 })
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send one request frame without waiting for the response; returns
    /// the id to pair the eventual response with. This is the pipelining
    /// primitive — the saturation test floods a queue with it.
    pub fn send(&mut self, req: &NetRequest) -> Result<(), NetClientError> {
        let payload = req.to_json().to_string();
        frame::write_frame(&mut self.stream, payload.as_bytes())?;
        Ok(())
    }

    /// Send an infer request (pipelined); returns its id.
    pub fn send_infer(&mut self, model: &str, image: &[f32]) -> Result<u64, NetClientError> {
        let id = self.fresh_id();
        self.send(&NetRequest::Infer { id, model: model.to_string(), image: image.to_vec() })?;
        Ok(id)
    }

    /// Send a `tiered` request (pipelined); returns its id. The server's
    /// tier controller picks the precision variant — there is no model
    /// name to give. Servers without a controller answer `bad_request`.
    pub fn send_tiered(&mut self, image: &[f32]) -> Result<u64, NetClientError> {
        let id = self.fresh_id();
        self.send(&NetRequest::Tiered { id, image: image.to_vec() })?;
        Ok(id)
    }

    /// Block for the next response frame. Responses to one connection
    /// arrive in request order.
    pub fn recv(&mut self) -> Result<NetResponse, NetClientError> {
        recv_on(&mut self.stream, &mut self.buf)
    }

    /// Blocking single-image inference: the remote analogue of
    /// [`crate::serve::registry::Session::infer`], returning the same
    /// [`Reply`] shape (its timings are the server's; network time is the
    /// caller's to measure).
    pub fn infer(&mut self, model: &str, image: &[f32]) -> Result<Reply, NetClientError> {
        let id = self.send_infer(model, image)?;
        let resp = self.recv()?;
        expect_id(&resp, id)?;
        match resp.body {
            Ok(RespBody::Infer { logits, argmax, queue_ms, total_ms }) => {
                Ok(Reply { logits, argmax, queue_ms, total_ms })
            }
            Ok(other) => Err(NetClientError::Protocol(format!(
                "expected infer body, got {other:?}"
            ))),
            Err(e) => Err(NetClientError::Wire(e)),
        }
    }

    /// Blocking tiered inference: like [`NetClient::infer`] but the
    /// server's tier controller chooses the variant. A `shed` wire error
    /// (the ladder is saturated end to end) surfaces as
    /// [`NetClientError::Wire`] — back off before retrying.
    pub fn infer_tiered(&mut self, image: &[f32]) -> Result<Reply, NetClientError> {
        let id = self.send_tiered(image)?;
        let resp = self.recv()?;
        expect_id(&resp, id)?;
        match resp.body {
            Ok(RespBody::Infer { logits, argmax, queue_ms, total_ms }) => {
                Ok(Reply { logits, argmax, queue_ms, total_ms })
            }
            Ok(other) => Err(NetClientError::Protocol(format!(
                "expected infer body, got {other:?}"
            ))),
            Err(e) => Err(NetClientError::Wire(e)),
        }
    }

    /// List the variants loaded on the server.
    pub fn models(&mut self) -> Result<Vec<String>, NetClientError> {
        let id = self.fresh_id();
        self.send(&NetRequest::Models { id })?;
        let resp = self.recv()?;
        expect_id(&resp, id)?;
        match resp.body {
            Ok(RespBody::Models { models }) => Ok(models),
            Ok(other) => Err(NetClientError::Protocol(format!(
                "expected models body, got {other:?}"
            ))),
            Err(e) => Err(NetClientError::Wire(e)),
        }
    }

    /// Liveness round-trip.
    pub fn ping(&mut self) -> Result<(), NetClientError> {
        let id = self.fresh_id();
        self.send(&NetRequest::Ping { id })?;
        let resp = self.recv()?;
        expect_id(&resp, id)?;
        match resp.body {
            Ok(RespBody::Pong) => Ok(()),
            Ok(other) => Err(NetClientError::Protocol(format!("expected pong, got {other:?}"))),
            Err(e) => Err(NetClientError::Wire(e)),
        }
    }

    /// Split into an independent send half and receive half (two handles
    /// on the same socket). The open-loop load generator sends on a paced
    /// thread while another thread receives — arrival cadence must not
    /// couple to response latency, or the measurement degenerates to
    /// closed-loop.
    pub fn split(self) -> io::Result<(NetSender, NetReceiver)> {
        let rstream = self.stream.try_clone()?;
        Ok((
            NetSender { stream: self.stream, next_id: self.next_id },
            NetReceiver { stream: rstream, buf: self.buf },
        ))
    }
}

/// The send half of a split [`NetClient`].
pub struct NetSender {
    stream: TcpStream,
    next_id: u64,
}

impl NetSender {
    /// Send an infer request; returns its id. Responses arrive on the
    /// paired [`NetReceiver`] in send order.
    pub fn send_infer(&mut self, model: &str, image: &[f32]) -> Result<u64, NetClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let req = NetRequest::Infer { id, model: model.to_string(), image: image.to_vec() };
        let payload = req.to_json().to_string();
        frame::write_frame(&mut self.stream, payload.as_bytes())?;
        Ok(id)
    }

    /// Send a `tiered` request; returns its id. The paired receiver sees
    /// the response (or a `shed` error) in send order.
    pub fn send_tiered(&mut self, image: &[f32]) -> Result<u64, NetClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let req = NetRequest::Tiered { id, image: image.to_vec() };
        let payload = req.to_json().to_string();
        frame::write_frame(&mut self.stream, payload.as_bytes())?;
        Ok(id)
    }

    /// Half-close the write side, telling the server no more requests are
    /// coming; the receiver still drains every response.
    pub fn finish(self) {
        let _ = self.stream.shutdown(Shutdown::Write);
    }
}

/// The receive half of a split [`NetClient`].
pub struct NetReceiver {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl NetReceiver {
    /// Block for the next response frame.
    pub fn recv(&mut self) -> Result<NetResponse, NetClientError> {
        recv_on(&mut self.stream, &mut self.buf)
    }
}

fn recv_on(stream: &mut impl Read, buf: &mut Vec<u8>) -> Result<NetResponse, NetClientError> {
    match frame::read_frame(stream, buf, MAX_FRAME_LEN)? {
        FrameRead::Frame => {}
        FrameRead::Eof => {
            return Err(NetClientError::Protocol("server closed the connection".to_string()))
        }
        FrameRead::Idle => {
            // Client sockets have no read timeout, so Idle means someone
            // set one; treat it like a stall.
            return Err(NetClientError::Protocol("timed out waiting for a response".to_string()));
        }
        FrameRead::TooLarge { len } => {
            return Err(NetClientError::Protocol(format!("server sent an oversized frame ({len} B)")))
        }
        FrameRead::Truncated => {
            return Err(NetClientError::Protocol("server closed mid-frame".to_string()))
        }
    }
    let text = std::str::from_utf8(buf)
        .map_err(|_| NetClientError::Protocol("response frame is not UTF-8".to_string()))?;
    let v = Json::parse(text)
        .map_err(|e| NetClientError::Protocol(format!("response is not JSON: {e}")))?;
    NetResponse::from_json(&v).map_err(NetClientError::Protocol)
}

fn expect_id(resp: &NetResponse, want: u64) -> Result<(), NetClientError> {
    if resp.id.as_u64() == Some(want) {
        Ok(())
    } else {
        Err(NetClientError::Protocol(format!(
            "response id {:?} does not match request id {want}",
            resp.id
        )))
    }
}
