//! A small blocking client for the wire protocol — what the e2e tests,
//! the benches' load generator, and `lsqnet serve --listen` smoke traffic
//! use. One [`NetClient`] wraps one connection; it is not `Sync` — use
//! one per thread, or [`NetClient::split`] the connection into a send
//! half and a receive half for open-loop (pipelined) traffic where the
//! sender must never block on the receiver.
//!
//! Resilience knobs (all off by default, so existing callers are
//! unchanged):
//!
//!  * connects are bounded by a timeout ([`NetClient::connect_with`];
//!    plain [`NetClient::connect`] uses [`DEFAULT_CONNECT_TIMEOUT`]) —
//!    a black-holed address returns an error instead of hanging in the
//!    kernel's connect for minutes;
//!  * [`NetClient::set_retry`] arms a [`RetryPolicy`]: the blocking
//!    `infer`/`infer_tiered` calls then retry *transient* failures
//!    (`queue_full`, `shed`, `closed`, `shut_down`, I/O and protocol
//!    errors — the last two after a transparent reconnect) with capped,
//!    jittered exponential backoff. Deterministic refusals (`bad_image`,
//!    `unknown_model`, `bad_request`, `deadline_exceeded`, …) surface
//!    immediately: retrying them cannot succeed. Retries are
//!    at-least-once — a lost response may mean the server already
//!    executed the request; inference is idempotent, so replaying it is
//!    safe;
//!  * [`NetClient::set_deadline_ms`] stamps every request with a
//!    `deadline_ms` queue budget and bounds the *total* retry loop
//!    (attempts + backoff) by the same budget, so a deadline client gets
//!    an answer or a timely `deadline_exceeded`, never an unbounded wait.

use std::io::{self, Read};
use std::net::{Shutdown, SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::frame::{self, FrameRead, MAX_FRAME_LEN};
use super::wire::{NetRequest, NetResponse, RespBody, WireError};
use crate::serve::Reply;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Connect timeout used by [`NetClient::connect`]. Long enough for a
/// loaded loopback accept queue, short enough that a black-holed address
/// fails the caller instead of wedging it.
pub const DEFAULT_CONNECT_TIMEOUT: Duration = Duration::from_secs(3);

/// Pcg32 stream tag for client-side backoff jitter ("client" in ASCII).
const JITTER_STREAM: u64 = 0x636c_6965_6e74;

/// Retry budget for the blocking [`NetClient::infer`] /
/// [`NetClient::infer_tiered`] calls, armed via [`NetClient::set_retry`].
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts including the first (so `1` means no retries).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per retry.
    pub backoff: Duration,
    /// Backoff ceiling after doubling, before jitter.
    pub backoff_cap: Duration,
    /// Seed for the ±25 % backoff jitter — fixed seed, reproducible
    /// pause schedule (the chaos tests rely on this).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            backoff: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(200),
            seed: 0,
        }
    }
}

/// Transient failures are worth retrying; deterministic refusals are not.
/// The second flag says whether the connection itself is suspect (retry
/// only after a reconnect).
fn classify(e: &NetClientError) -> (bool, bool) {
    match e {
        NetClientError::Io(_) | NetClientError::Protocol(_) => (true, true),
        NetClientError::Wire(w) => match w {
            WireError::QueueFull { .. }
            | WireError::Shed
            | WireError::Closed
            | WireError::ShutDown => (true, false),
            WireError::UnknownModel { .. }
            | WireError::BadImage { .. }
            | WireError::BadRequest { .. }
            | WireError::FrameTooLarge { .. }
            | WireError::DeadlineExceeded => (false, false),
        },
    }
}

/// Everything a client call can fail with.
#[derive(Debug)]
pub enum NetClientError {
    /// The socket failed (connect, reset, broken pipe).
    Io(io::Error),
    /// The server broke the protocol: unparseable frame, mismatched id,
    /// wrong body for the op, or closed mid-frame.
    Protocol(String),
    /// The server answered with a structured wire error — the remote
    /// image of [`crate::serve::ServeError`], e.g. `QueueFull`
    /// backpressure or `UnknownModel`.
    Wire(WireError),
}

impl std::fmt::Display for NetClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetClientError::Io(e) => write!(f, "i/o: {e}"),
            NetClientError::Protocol(msg) => write!(f, "protocol: {msg}"),
            NetClientError::Wire(e) => write!(f, "server: {e}"),
        }
    }
}

impl std::error::Error for NetClientError {}

impl From<io::Error> for NetClientError {
    fn from(e: io::Error) -> NetClientError {
        NetClientError::Io(e)
    }
}

/// One blocking connection to a [`super::NetServer`].
pub struct NetClient {
    stream: TcpStream,
    buf: Vec<u8>,
    next_id: u64,
    /// Peer address, kept for transparent reconnects.
    addr: Option<SocketAddr>,
    connect_timeout: Duration,
    retry: Option<RetryPolicy>,
    deadline_ms: Option<u64>,
    rng: Pcg32,
}

impl NetClient {
    /// Connect to a serving endpoint, bounded by
    /// [`DEFAULT_CONNECT_TIMEOUT`].
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<NetClient> {
        Self::connect_with(addr, DEFAULT_CONNECT_TIMEOUT)
    }

    /// Connect with an explicit per-address timeout. Each resolved
    /// address gets the full timeout; the first to accept wins, and the
    /// last error is returned when none does. This is the fix for the
    /// black-hole hang: `TcpStream::connect` against an unroutable
    /// address blocks for the kernel's SYN-retry schedule (minutes);
    /// `connect_timeout` returns `TimedOut` on schedule.
    pub fn connect_with(addr: impl ToSocketAddrs, timeout: Duration) -> io::Result<NetClient> {
        let mut last: Option<io::Error> = None;
        for a in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&a, timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    return Ok(NetClient {
                        stream,
                        buf: Vec::new(),
                        next_id: 0,
                        addr: Some(a),
                        connect_timeout: timeout,
                        retry: None,
                        deadline_ms: None,
                        rng: Pcg32::new(0, JITTER_STREAM),
                    });
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidInput, "address resolved to nothing")
        }))
    }

    /// Arm retries on the blocking [`NetClient::infer`] /
    /// [`NetClient::infer_tiered`] calls. `None` (the default) fails
    /// fast on the first error.
    pub fn set_retry(&mut self, policy: Option<RetryPolicy>) {
        if let Some(p) = &policy {
            self.rng = Pcg32::new(p.seed, JITTER_STREAM);
        }
        self.retry = policy;
    }

    /// Stamp every subsequent infer/tiered request with a `deadline_ms`
    /// queue budget (`None` = no deadline). With retries armed, the same
    /// budget also bounds the whole retry loop.
    pub fn set_deadline_ms(&mut self, deadline_ms: Option<u64>) {
        self.deadline_ms = deadline_ms;
    }

    /// Drop the current socket and dial the recorded peer address again.
    fn reconnect(&mut self) -> io::Result<()> {
        let addr = self
            .addr
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "no peer address recorded"))?;
        let stream = TcpStream::connect_timeout(&addr, self.connect_timeout)?;
        stream.set_nodelay(true)?;
        self.stream = stream;
        self.buf.clear();
        Ok(())
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send one request frame without waiting for the response; returns
    /// the id to pair the eventual response with. This is the pipelining
    /// primitive — the saturation test floods a queue with it.
    pub fn send(&mut self, req: &NetRequest) -> Result<(), NetClientError> {
        let payload = req.to_json().to_string();
        frame::write_frame(&mut self.stream, payload.as_bytes())?;
        Ok(())
    }

    /// Send an infer request (pipelined); returns its id. Carries the
    /// client's configured `deadline_ms`, if any.
    pub fn send_infer(&mut self, model: &str, image: &[f32]) -> Result<u64, NetClientError> {
        let id = self.fresh_id();
        self.send(&NetRequest::Infer {
            id,
            model: model.to_string(),
            image: image.to_vec(),
            deadline_ms: self.deadline_ms,
        })?;
        Ok(id)
    }

    /// Send a `tiered` request (pipelined); returns its id. The server's
    /// tier controller picks the precision variant — there is no model
    /// name to give. Servers without a controller answer `bad_request`.
    pub fn send_tiered(&mut self, image: &[f32]) -> Result<u64, NetClientError> {
        let id = self.fresh_id();
        self.send(&NetRequest::Tiered {
            id,
            image: image.to_vec(),
            deadline_ms: self.deadline_ms,
        })?;
        Ok(id)
    }

    /// Block for the next response frame. Responses to one connection
    /// arrive in request order.
    pub fn recv(&mut self) -> Result<NetResponse, NetClientError> {
        recv_on(&mut self.stream, &mut self.buf)
    }

    /// Blocking single-image inference: the remote analogue of
    /// [`crate::serve::registry::Session::infer`], returning the same
    /// [`Reply`] shape (its timings are the server's; network time is the
    /// caller's to measure). Honors [`NetClient::set_retry`] and
    /// [`NetClient::set_deadline_ms`].
    pub fn infer(&mut self, model: &str, image: &[f32]) -> Result<Reply, NetClientError> {
        self.infer_retry(Some(model), image)
    }

    /// Blocking tiered inference: like [`NetClient::infer`] but the
    /// server's tier controller chooses the variant. A `shed` wire error
    /// (the ladder is saturated end to end) surfaces as
    /// [`NetClientError::Wire`] — unless retries are armed, in which case
    /// it is backed off and retried like `queue_full`.
    pub fn infer_tiered(&mut self, image: &[f32]) -> Result<Reply, NetClientError> {
        self.infer_retry(None, image)
    }

    /// One request/response exchange; `model: None` means `tiered`.
    fn infer_once(
        &mut self,
        model: Option<&str>,
        image: &[f32],
        deadline_ms: Option<u64>,
    ) -> Result<Reply, NetClientError> {
        let id = self.fresh_id();
        let req = match model {
            Some(m) => NetRequest::Infer {
                id,
                model: m.to_string(),
                image: image.to_vec(),
                deadline_ms,
            },
            None => NetRequest::Tiered { id, image: image.to_vec(), deadline_ms },
        };
        self.send(&req)?;
        let resp = self.recv()?;
        expect_id(&resp, id)?;
        match resp.body {
            Ok(RespBody::Infer { logits, argmax, queue_ms, total_ms }) => {
                Ok(Reply { logits, argmax, queue_ms, total_ms })
            }
            Ok(other) => Err(NetClientError::Protocol(format!(
                "expected infer body, got {other:?}"
            ))),
            Err(e) => Err(NetClientError::Wire(e)),
        }
    }

    /// The retry loop around [`NetClient::infer_once`]: transient errors
    /// back off (capped, jittered exponential) and retry; connection
    /// errors reconnect first; the optional overall `deadline_ms` budget
    /// bounds attempts *and* pauses, with each attempt's wire deadline
    /// set to the remaining budget.
    fn infer_retry(&mut self, model: Option<&str>, image: &[f32]) -> Result<Reply, NetClientError> {
        let policy = match self.retry.clone() {
            None => return self.infer_once(model, image, self.deadline_ms),
            Some(p) => p,
        };
        let start = Instant::now();
        let overall = self.deadline_ms.map(Duration::from_millis);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            let wire_deadline = match overall {
                None => None,
                Some(total) => {
                    let left = total.saturating_sub(start.elapsed());
                    if left.is_zero() {
                        return Err(NetClientError::Wire(WireError::DeadlineExceeded));
                    }
                    Some(left.as_millis() as u64)
                }
            };
            let err = match self.infer_once(model, image, wire_deadline) {
                Ok(r) => return Ok(r),
                Err(e) => e,
            };
            let (retryable, reconnect) = classify(&err);
            if !retryable || attempt >= policy.max_attempts {
                return Err(err);
            }
            let n = attempt.min(16);
            let base = policy
                .backoff
                .saturating_mul(1u32 << (n - 1))
                .min(policy.backoff_cap);
            let mut pause = base.mul_f64(1.0 + 0.25 * self.rng.uniform() as f64);
            if let Some(total) = overall {
                let left = total.saturating_sub(start.elapsed());
                if left.is_zero() {
                    return Err(NetClientError::Wire(WireError::DeadlineExceeded));
                }
                pause = pause.min(left);
            }
            std::thread::sleep(pause);
            if reconnect {
                // A failed reconnect leaves the dead socket in place; the
                // next attempt fails fast on it and consumes its slot —
                // the loop stays bounded by max_attempts either way.
                let _ = self.reconnect();
            }
        }
    }

    /// List the variants loaded on the server.
    pub fn models(&mut self) -> Result<Vec<String>, NetClientError> {
        let id = self.fresh_id();
        self.send(&NetRequest::Models { id })?;
        let resp = self.recv()?;
        expect_id(&resp, id)?;
        match resp.body {
            Ok(RespBody::Models { models }) => Ok(models),
            Ok(other) => Err(NetClientError::Protocol(format!(
                "expected models body, got {other:?}"
            ))),
            Err(e) => Err(NetClientError::Wire(e)),
        }
    }

    /// Liveness round-trip.
    pub fn ping(&mut self) -> Result<(), NetClientError> {
        let id = self.fresh_id();
        self.send(&NetRequest::Ping { id })?;
        let resp = self.recv()?;
        expect_id(&resp, id)?;
        match resp.body {
            Ok(RespBody::Pong) => Ok(()),
            Ok(other) => Err(NetClientError::Protocol(format!("expected pong, got {other:?}"))),
            Err(e) => Err(NetClientError::Wire(e)),
        }
    }

    /// Split into an independent send half and receive half (two handles
    /// on the same socket). The open-loop load generator sends on a paced
    /// thread while another thread receives — arrival cadence must not
    /// couple to response latency, or the measurement degenerates to
    /// closed-loop.
    pub fn split(self) -> io::Result<(NetSender, NetReceiver)> {
        let rstream = self.stream.try_clone()?;
        Ok((
            NetSender {
                stream: self.stream,
                next_id: self.next_id,
                deadline_ms: self.deadline_ms,
            },
            NetReceiver { stream: rstream, buf: self.buf },
        ))
    }
}

/// The send half of a split [`NetClient`]. Inherits the client's
/// `deadline_ms` at split time; retries do not apply to the open-loop
/// half (the load generator wants the raw error stream).
pub struct NetSender {
    stream: TcpStream,
    next_id: u64,
    deadline_ms: Option<u64>,
}

impl NetSender {
    /// Send an infer request; returns its id. Responses arrive on the
    /// paired [`NetReceiver`] in send order.
    pub fn send_infer(&mut self, model: &str, image: &[f32]) -> Result<u64, NetClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let req = NetRequest::Infer {
            id,
            model: model.to_string(),
            image: image.to_vec(),
            deadline_ms: self.deadline_ms,
        };
        let payload = req.to_json().to_string();
        frame::write_frame(&mut self.stream, payload.as_bytes())?;
        Ok(id)
    }

    /// Send a `tiered` request; returns its id. The paired receiver sees
    /// the response (or a `shed` error) in send order.
    pub fn send_tiered(&mut self, image: &[f32]) -> Result<u64, NetClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let req = NetRequest::Tiered { id, image: image.to_vec(), deadline_ms: self.deadline_ms };
        let payload = req.to_json().to_string();
        frame::write_frame(&mut self.stream, payload.as_bytes())?;
        Ok(id)
    }

    /// Half-close the write side, telling the server no more requests are
    /// coming; the receiver still drains every response.
    pub fn finish(self) {
        let _ = self.stream.shutdown(Shutdown::Write);
    }
}

/// The receive half of a split [`NetClient`].
pub struct NetReceiver {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl NetReceiver {
    /// Block for the next response frame.
    pub fn recv(&mut self) -> Result<NetResponse, NetClientError> {
        recv_on(&mut self.stream, &mut self.buf)
    }
}

fn recv_on(stream: &mut impl Read, buf: &mut Vec<u8>) -> Result<NetResponse, NetClientError> {
    match frame::read_frame(stream, buf, MAX_FRAME_LEN)? {
        FrameRead::Frame => {}
        FrameRead::Eof => {
            return Err(NetClientError::Protocol("server closed the connection".to_string()))
        }
        FrameRead::Idle => {
            // Client sockets have no read timeout, so Idle means someone
            // set one; treat it like a stall.
            return Err(NetClientError::Protocol("timed out waiting for a response".to_string()));
        }
        FrameRead::TooLarge { len } => {
            return Err(NetClientError::Protocol(format!("server sent an oversized frame ({len} B)")))
        }
        FrameRead::Truncated => {
            return Err(NetClientError::Protocol("server closed mid-frame".to_string()))
        }
    }
    let text = std::str::from_utf8(buf)
        .map_err(|_| NetClientError::Protocol("response frame is not UTF-8".to_string()))?;
    let v = Json::parse(text)
        .map_err(|e| NetClientError::Protocol(format!("response is not JSON: {e}")))?;
    NetResponse::from_json(&v).map_err(NetClientError::Protocol)
}

fn expect_id(resp: &NetResponse, want: u64) -> Result<(), NetClientError> {
    if resp.id.as_u64() == Some(want) {
        Ok(())
    } else {
        Err(NetClientError::Protocol(format!(
            "response id {:?} does not match request id {want}",
            resp.id
        )))
    }
}
