//! Length-delimited framing for the wire protocol: a 4-byte big-endian
//! payload length followed by that many bytes of UTF-8 JSON.
//!
//! The reader is written for a hostile network: an announced length over
//! [`MAX_FRAME_LEN`] is rejected *before* any body byte is read (so an
//! adversarial header cannot make the server allocate or block), EOF and
//! stalls in the middle of a frame are distinguished from a clean close at
//! a frame boundary, and frames split across arbitrarily many TCP segments
//! (down to one byte per write) still assemble. Read-timeout errors on the
//! stream surface as [`FrameRead::Idle`] only while waiting for a frame's
//! first byte — that is the hook the server's connection loop uses to poll
//! its stop flag without ever aborting a frame mid-assembly.

use std::io::{self, Read, Write};
use std::time::{Duration, Instant};

/// Ceiling on one frame's payload bytes. A 32×32×3 image request is ~30 KB
/// of JSON text; 4 MiB leaves two orders of magnitude of headroom while
/// bounding what a hostile header can demand.
pub const MAX_FRAME_LEN: usize = 4 << 20;

/// Total assembly budget for one *started* frame, armed at its first byte
/// and never reset. Split writes are fine; a frame that has not completed
/// within this budget is declared wedged. The bound is on the whole frame
/// rather than per-byte progress because a slow-loris client dribbling
/// one byte per interval makes "progress" forever — a per-byte stall
/// deadline would never trip and the connection handler would be pinned
/// indefinitely.
pub const MID_FRAME_DEADLINE: Duration = Duration::from_secs(5);

/// Outcome of one [`read_frame`] call.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete payload was read into the caller's buffer.
    Frame,
    /// Clean EOF at a frame boundary: the peer closed the connection.
    Eof,
    /// The stream's read timeout fired before the frame's first byte
    /// arrived. The connection is idle — poll the stop flag and call
    /// again.
    Idle,
    /// The 4-byte prefix announced `len` payload bytes, over the caller's
    /// maximum. The body was not read; the connection cannot be re-synced
    /// and must be closed after reporting the error.
    TooLarge {
        /// The announced payload length.
        len: usize,
    },
    /// EOF or a [`MID_FRAME_DEADLINE`] stall in the middle of a frame.
    Truncated,
}

/// Write one frame: 4-byte big-endian length prefix, then the payload,
/// then flush.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Fault-injection helper (`serve/fault.rs`, chaos tests): write a header
/// announcing the full payload length but deliver only the first half of
/// the body, then flush. The peer's reader must classify the stream as
/// [`FrameRead::Truncated`] once the connection dies — never block
/// forever, never surface a half frame as data. Production code never
/// calls this.
pub fn write_frame_truncated(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(&payload[..payload.len() / 2])?;
    w.flush()
}

/// Fault-injection helper: write a correctly *framed* payload whose bytes
/// have been garbled (a XOR stripe over the middle quarter, sparing tiny
/// payloads), so framing stays in sync but the JSON inside no longer
/// parses. Exercises the peer's payload-level error handling separately
/// from its framing robustness. Production code never calls this.
pub fn write_frame_corrupted(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    let mut garbled = payload.to_vec();
    let (a, b) = (garbled.len() / 4, garbled.len() / 2);
    for byte in &mut garbled[a..b] {
        *byte ^= 0x5a;
    }
    write_frame(w, &garbled)
}

/// Read one frame into `buf` (cleared and reused across calls, so a
/// long-lived connection allocates only when frames grow). See
/// [`FrameRead`] for the outcome contract; `Err` is reserved for hard I/O
/// failures (reset, broken pipe). Frame assembly is bounded by
/// [`MID_FRAME_DEADLINE`] total.
pub fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>, max: usize) -> io::Result<FrameRead> {
    read_frame_deadline(r, buf, max, MID_FRAME_DEADLINE)
}

/// [`read_frame`] with an explicit total-assembly deadline. One budget
/// covers header *and* body: it is armed when the frame's first byte
/// arrives and deliberately never reset on progress, so a peer trickling
/// bytes cannot hold the handler past `deadline` no matter how steadily
/// it dribbles.
pub fn read_frame_deadline(
    r: &mut impl Read,
    buf: &mut Vec<u8>,
    max: usize,
    deadline: Duration,
) -> io::Result<FrameRead> {
    let mut due: Option<Instant> = None;
    let mut header = [0u8; 4];
    match read_full(r, &mut header, true, deadline, &mut due)? {
        Progress::Done => {}
        Progress::CleanEof => return Ok(FrameRead::Eof),
        Progress::Idle => return Ok(FrameRead::Idle),
        Progress::Truncated => return Ok(FrameRead::Truncated),
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > max {
        return Ok(FrameRead::TooLarge { len });
    }
    buf.clear();
    buf.resize(len, 0);
    // `due` carries over: the body shares the header's assembly budget.
    match read_full(r, buf, false, deadline, &mut due)? {
        Progress::Done => Ok(FrameRead::Frame),
        _ => Ok(FrameRead::Truncated),
    }
}

enum Progress {
    Done,
    CleanEof,
    Idle,
    Truncated,
}

/// Fill `out` completely. `fresh` marks a frame boundary: EOF or a read
/// timeout before the first byte then mean a clean close / idle poll
/// rather than a truncated frame. `due` is the whole frame's assembly
/// deadline — armed at the first byte, shared across the header and body
/// calls, checked on *both* the timeout path and the progress path (a
/// continuously-dribbling peer may never hit a read timeout at all).
fn read_full(
    r: &mut impl Read,
    out: &mut [u8],
    fresh: bool,
    deadline: Duration,
    due: &mut Option<Instant>,
) -> io::Result<Progress> {
    let mut got = 0usize;
    while got < out.len() {
        match r.read(&mut out[got..]) {
            Ok(0) => {
                return Ok(if fresh && got == 0 {
                    Progress::CleanEof
                } else {
                    Progress::Truncated
                });
            }
            Ok(n) => {
                got += n;
                let d = *due.get_or_insert_with(|| Instant::now() + deadline);
                if got < out.len() && Instant::now() >= d {
                    return Ok(Progress::Truncated);
                }
            }
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if fresh && got == 0 && due.is_none() {
                    return Ok(Progress::Idle);
                }
                let d = *due.get_or_insert_with(|| Instant::now() + deadline);
                if Instant::now() >= d {
                    return Ok(Progress::Truncated);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Progress::Done)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_a_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"{\"id\":1}").unwrap();
        write_frame(&mut wire, b"").unwrap();
        write_frame(&mut wire, b"second").unwrap();
        let mut r: &[u8] = &wire;
        let mut buf = Vec::new();
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"{\"id\":1}");
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"");
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"second");
        // End of stream at a frame boundary is a clean close.
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Eof));
    }

    #[test]
    fn truncated_write_helper_truncates_and_reader_classifies_it() {
        let mut wire = Vec::new();
        write_frame_truncated(&mut wire, b"0123456789abcdef").unwrap();
        // Full-length header, half the body.
        assert_eq!(wire.len(), 4 + 8);
        let mut r: &[u8] = &wire;
        let mut buf = Vec::new();
        assert!(matches!(
            read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(),
            FrameRead::Truncated
        ));
        // Degenerate payloads must not panic the helper.
        let mut w2 = Vec::new();
        write_frame_truncated(&mut w2, b"").unwrap();
        write_frame_truncated(&mut w2, b"x").unwrap();
    }

    #[test]
    fn corrupted_write_helper_keeps_framing_but_garbles_the_payload() {
        let payload = b"{\"id\":1,\"op\":\"infer\",\"padding\":\"padding\"}";
        let mut wire = Vec::new();
        write_frame_corrupted(&mut wire, payload).unwrap();
        let mut r: &[u8] = &wire;
        let mut buf = Vec::new();
        // Framing survives: the frame reads whole…
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf.len(), payload.len());
        // …but the bytes differ (the XOR stripe hit the middle quarter).
        assert_ne!(buf, payload);
        // Tiny payloads pass through unharmed rather than panicking.
        let mut w2 = Vec::new();
        write_frame_corrupted(&mut w2, b"ab").unwrap();
    }

    #[test]
    fn oversized_header_is_rejected_without_reading_the_body() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_be_bytes());
        // No body at all: the header alone must trigger TooLarge.
        let mut r: &[u8] = &wire;
        let mut buf = Vec::new();
        match read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap() {
            FrameRead::TooLarge { len } => assert_eq!(len, u32::MAX as usize),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn truncation_inside_header_and_body_is_not_a_clean_eof() {
        // Two header bytes, then EOF.
        let mut r: &[u8] = &[0, 0];
        let mut buf = Vec::new();
        assert!(matches!(
            read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(),
            FrameRead::Truncated
        ));
        // Full header announcing 8 bytes, only 3 delivered.
        let mut wire = Vec::new();
        wire.extend_from_slice(&8u32.to_be_bytes());
        wire.extend_from_slice(b"abc");
        let mut r: &[u8] = &wire;
        assert!(matches!(
            read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(),
            FrameRead::Truncated
        ));
    }

    /// A reader that hands out one byte per call: frames split across
    /// arbitrarily small reads must still assemble.
    struct OneByte<'a>(&'a [u8]);
    impl Read for OneByte<'_> {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.0.is_empty() || out.is_empty() {
                return Ok(0);
            }
            out[0] = self.0[0];
            self.0 = &self.0[1..];
            Ok(1)
        }
    }

    #[test]
    fn frames_assemble_from_single_byte_reads() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"split across segments").unwrap();
        let mut r = OneByte(&wire);
        let mut buf = Vec::new();
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"split across segments");
    }

    /// A reader that sleeps, then hands out one byte: a continuous
    /// slow-loris dribble that never hits a read timeout, so only the
    /// progress-path deadline check can stop it.
    struct SleepyDribble<'a> {
        data: &'a [u8],
        gap: Duration,
    }
    impl Read for SleepyDribble<'_> {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.data.is_empty() || out.is_empty() {
                return Ok(0);
            }
            std::thread::sleep(self.gap);
            out[0] = self.data[0];
            self.data = &self.data[1..];
            Ok(1)
        }
    }

    /// A reader alternating a slept-through timeout error with one byte of
    /// progress — the exact pattern that defeated the old per-byte stall
    /// deadline (every byte reset it).
    struct TimeoutDribble<'a> {
        data: &'a [u8],
        gap: Duration,
        timeout_next: bool,
    }
    impl Read for TimeoutDribble<'_> {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.data.is_empty() || out.is_empty() {
                return Ok(0);
            }
            if self.timeout_next {
                self.timeout_next = false;
                std::thread::sleep(self.gap);
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "poll timeout"));
            }
            self.timeout_next = true;
            out[0] = self.data[0];
            self.data = &self.data[1..];
            Ok(1)
        }
    }

    #[test]
    fn continuous_dribble_trips_the_total_assembly_deadline() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[7u8; 64]).unwrap();
        // 68 framed bytes at 10 ms each ≈ 680 ms of dribble; an 80 ms
        // assembly budget must cut the frame off instead of waiting the
        // dribble out byte by byte.
        let mut r = SleepyDribble { data: &wire, gap: Duration::from_millis(10) };
        let mut buf = Vec::new();
        let t0 = Instant::now();
        assert!(matches!(
            read_frame_deadline(&mut r, &mut buf, MAX_FRAME_LEN, Duration::from_millis(80))
                .unwrap(),
            FrameRead::Truncated
        ));
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "deadline did not bound total assembly time"
        );
    }

    #[test]
    fn single_byte_progress_does_not_reset_the_deadline() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[9u8; 64]).unwrap();
        // Each byte costs a ~10 ms timeout round first: per-byte progress
        // used to reset the stall deadline, letting this run forever.
        let mut r =
            TimeoutDribble { data: &wire, gap: Duration::from_millis(10), timeout_next: false };
        let mut buf = Vec::new();
        let t0 = Instant::now();
        assert!(matches!(
            read_frame_deadline(&mut r, &mut buf, MAX_FRAME_LEN, Duration::from_millis(80))
                .unwrap(),
            FrameRead::Truncated
        ));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn idle_then_complete_frame_still_assembles() {
        // A timeout before the first byte is Idle (stop-flag poll hook),
        // and a frame that then arrives whole is read normally — the
        // deadline only arms once bytes flow.
        struct IdleOnce<'a> {
            data: &'a [u8],
            idled: bool,
        }
        impl Read for IdleOnce<'_> {
            fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
                if !self.idled {
                    self.idled = true;
                    return Err(io::Error::new(io::ErrorKind::WouldBlock, "idle"));
                }
                let n = self.data.len().min(out.len());
                out[..n].copy_from_slice(&self.data[..n]);
                self.data = &self.data[n..];
                Ok(n)
            }
        }
        let mut wire = Vec::new();
        write_frame(&mut wire, b"after idle").unwrap();
        let mut r = IdleOnce { data: &wire, idled: false };
        let mut buf = Vec::new();
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Idle));
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"after idle");
    }
}
