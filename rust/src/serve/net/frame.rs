//! Length-delimited framing for the wire protocol: a 4-byte big-endian
//! payload length followed by that many bytes of UTF-8 JSON.
//!
//! The reader is written for a hostile network: an announced length over
//! [`MAX_FRAME_LEN`] is rejected *before* any body byte is read (so an
//! adversarial header cannot make the server allocate or block), EOF and
//! stalls in the middle of a frame are distinguished from a clean close at
//! a frame boundary, and frames split across arbitrarily many TCP segments
//! (down to one byte per write) still assemble. Read-timeout errors on the
//! stream surface as [`FrameRead::Idle`] only while waiting for a frame's
//! first byte — that is the hook the server's connection loop uses to poll
//! its stop flag without ever aborting a frame mid-assembly.

use std::io::{self, Read, Write};
use std::time::{Duration, Instant};

/// Ceiling on one frame's payload bytes. A 32×32×3 image request is ~30 KB
/// of JSON text; 4 MiB leaves two orders of magnitude of headroom while
/// bounding what a hostile header can demand.
pub const MAX_FRAME_LEN: usize = 4 << 20;

/// How long a *started* frame may dribble in before the connection is
/// declared wedged. Split writes are fine; indefinite mid-frame stalls are
/// how a slow-loris client would otherwise pin a connection handler.
pub const MID_FRAME_DEADLINE: Duration = Duration::from_secs(5);

/// Outcome of one [`read_frame`] call.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete payload was read into the caller's buffer.
    Frame,
    /// Clean EOF at a frame boundary: the peer closed the connection.
    Eof,
    /// The stream's read timeout fired before the frame's first byte
    /// arrived. The connection is idle — poll the stop flag and call
    /// again.
    Idle,
    /// The 4-byte prefix announced `len` payload bytes, over the caller's
    /// maximum. The body was not read; the connection cannot be re-synced
    /// and must be closed after reporting the error.
    TooLarge {
        /// The announced payload length.
        len: usize,
    },
    /// EOF or a [`MID_FRAME_DEADLINE`] stall in the middle of a frame.
    Truncated,
}

/// Write one frame: 4-byte big-endian length prefix, then the payload,
/// then flush.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame into `buf` (cleared and reused across calls, so a
/// long-lived connection allocates only when frames grow). See
/// [`FrameRead`] for the outcome contract; `Err` is reserved for hard I/O
/// failures (reset, broken pipe).
pub fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>, max: usize) -> io::Result<FrameRead> {
    let mut header = [0u8; 4];
    match read_full(r, &mut header, true)? {
        Progress::Done => {}
        Progress::CleanEof => return Ok(FrameRead::Eof),
        Progress::Idle => return Ok(FrameRead::Idle),
        Progress::Truncated => return Ok(FrameRead::Truncated),
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > max {
        return Ok(FrameRead::TooLarge { len });
    }
    buf.clear();
    buf.resize(len, 0);
    match read_full(r, buf, false)? {
        Progress::Done => Ok(FrameRead::Frame),
        _ => Ok(FrameRead::Truncated),
    }
}

enum Progress {
    Done,
    CleanEof,
    Idle,
    Truncated,
}

/// Fill `out` completely. `fresh` marks a frame boundary: EOF or a read
/// timeout before the first byte then mean a clean close / idle poll
/// rather than a truncated frame. Once bytes are flowing, short timeouts
/// retry until [`MID_FRAME_DEADLINE`] of no progress.
fn read_full(r: &mut impl Read, out: &mut [u8], fresh: bool) -> io::Result<Progress> {
    let mut got = 0usize;
    let mut deadline: Option<Instant> = None;
    while got < out.len() {
        match r.read(&mut out[got..]) {
            Ok(0) => {
                return Ok(if fresh && got == 0 {
                    Progress::CleanEof
                } else {
                    Progress::Truncated
                });
            }
            Ok(n) => {
                got += n;
                deadline = None; // the peer is making progress
            }
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if fresh && got == 0 {
                    return Ok(Progress::Idle);
                }
                let d = *deadline.get_or_insert_with(|| Instant::now() + MID_FRAME_DEADLINE);
                if Instant::now() >= d {
                    return Ok(Progress::Truncated);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Progress::Done)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_a_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"{\"id\":1}").unwrap();
        write_frame(&mut wire, b"").unwrap();
        write_frame(&mut wire, b"second").unwrap();
        let mut r: &[u8] = &wire;
        let mut buf = Vec::new();
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"{\"id\":1}");
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"");
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"second");
        // End of stream at a frame boundary is a clean close.
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Eof));
    }

    #[test]
    fn oversized_header_is_rejected_without_reading_the_body() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_be_bytes());
        // No body at all: the header alone must trigger TooLarge.
        let mut r: &[u8] = &wire;
        let mut buf = Vec::new();
        match read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap() {
            FrameRead::TooLarge { len } => assert_eq!(len, u32::MAX as usize),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn truncation_inside_header_and_body_is_not_a_clean_eof() {
        // Two header bytes, then EOF.
        let mut r: &[u8] = &[0, 0];
        let mut buf = Vec::new();
        assert!(matches!(
            read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(),
            FrameRead::Truncated
        ));
        // Full header announcing 8 bytes, only 3 delivered.
        let mut wire = Vec::new();
        wire.extend_from_slice(&8u32.to_be_bytes());
        wire.extend_from_slice(b"abc");
        let mut r: &[u8] = &wire;
        assert!(matches!(
            read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(),
            FrameRead::Truncated
        ));
    }

    /// A reader that hands out one byte per call: frames split across
    /// arbitrarily small reads must still assemble.
    struct OneByte<'a>(&'a [u8]);
    impl Read for OneByte<'_> {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.0.is_empty() || out.is_empty() {
                return Ok(0);
            }
            out[0] = self.0[0];
            self.0 = &self.0[1..];
            Ok(1)
        }
    }

    #[test]
    fn frames_assemble_from_single_byte_reads() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"split across segments").unwrap();
        let mut r = OneByte(&wire);
        let mut buf = Vec::new();
        assert!(matches!(read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap(), FrameRead::Frame));
        assert_eq!(buf, b"split across segments");
    }
}
