//! Network-facing serving: a TCP wire protocol over the
//! [`ModelRegistry`](crate::serve::registry::ModelRegistry).
//!
//! PR 5's gateway — many precision variants of one architecture, per-
//! variant queues and replicas, typed backpressure — was in-process only.
//! This module puts a socket in front of it with zero new dependencies
//! (`std::net` + the repo's own [`crate::util::json`]):
//!
//!  * [`frame`] — length-delimited framing (4-byte big-endian length +
//!    UTF-8 JSON payload), hardened against truncation, split writes and
//!    hostile lengths;
//!  * [`wire`] — the request/response JSON vocabulary and the total
//!    mapping from [`crate::serve::ServeError`] onto structured wire
//!    errors, so a remote client sees `queue_full{depth}` backpressure
//!    and `closed` drains instead of dropped connections;
//!  * [`server`] — [`NetServer`]: accept loop, per-connection
//!    reader/writer pair, graceful drain composed with
//!    `drain_and_unload` (an accepted request is answered exactly once,
//!    socket included);
//!  * [`client`] — [`NetClient`]: the blocking client used by tests,
//!    benches and the CLI, splittable into send/receive halves for
//!    open-loop load generation.
//!
//! PR 7 adds the `tiered` op: the client names no model; the server's
//! [`TierController`](crate::serve::tier::TierController) routes the
//! request onto whichever precision tier its SLO loop currently favors,
//! spilling to cheaper tiers under queue-full and answering a structured
//! `shed` error once the whole ladder is saturated. Servers started
//! without a controller ([`NetServer::start`]) reject the op as
//! `bad_request`; [`NetServer::start_with`] enables it.
//!
//! PR 9 makes the edge self-healing: connects are timeout-bounded,
//! requests can carry a `deadline_ms` queue budget (shed at dequeue with
//! a `deadline_exceeded` error once it expires), the client grows an
//! opt-in [`RetryPolicy`] for transient failures with transparent
//! reconnects, and [`NetServer::start_faulted`] threads a deterministic
//! [`FaultPlan`](crate::serve::fault::FaultPlan) through the reader and
//! writer so the chaos tests can corrupt, truncate, stall and drop real
//! connections on a reproducible schedule.
//!
//! The protocol and its guarantees are specified in DESIGN.md
//! §Wire-protocol and §Fault-model; `lsqnet serve --listen <addr>` is
//! the entry point.

pub mod client;
pub mod frame;
pub mod server;
pub mod wire;

pub use client::{NetClient, NetClientError, NetReceiver, NetSender, RetryPolicy};
pub use server::NetServer;
pub use wire::{NetRequest, NetResponse, RespBody, WireError};
