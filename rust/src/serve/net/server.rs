//! The TCP listener: accepts connections, multiplexes each one onto the
//! [`ModelRegistry`]'s variant queues, and guarantees the drain contract
//! over the network.
//!
//! Threading model, per [`NetServer`]:
//!
//!  * one **accept** thread (`lsq-net-accept`) blocks in
//!    `TcpListener::incoming`. Stopping is a flag + a self-connect that
//!    wakes the blocked accept; the accept thread then joins every live
//!    connection before exiting, so [`NetServer::stop`] returns only after
//!    the last in-flight request has been answered;
//!  * per connection, a **reader** thread (`lsq-net-conn-{n}`) assembles
//!    frames (25 ms read timeout so it can poll the stop flag between
//!    frames without ever aborting one mid-assembly), parses and submits
//!    requests, and forwards one [`WriteItem`] per request to
//!  * a **writer** thread (`lsq-net-wr-{n}`) that resolves items in FIFO
//!    order — responses come back in request order per connection, which
//!    is what lets a pipelining client pair them without ids (ids are
//!    still echoed for clients that interleave ops).
//!
//! Why a reader/writer split instead of one request-response loop: a
//! submit hands back a reply *channel*; parking the connection on that
//! channel would serialize the connection's requests through one replica
//! batch at a time. The split keeps the reader pulling new frames while
//! earlier requests are still queued or executing — a single connection
//! can fill a variant's whole queue (that is what the saturation test
//! does to provoke `queue_full` over the wire).
//!
//! Drain composition: the registry promises every *accepted* request is
//! answered exactly once, drained variants included. The writer extends
//! that promise to the wire — it drains every pending reply channel
//! before exiting, and the reader always outlives its submits. A
//! `drain_and_unload` under live network load therefore never strands an
//! accepted request; new submits on that variant get the structured
//! `closed`/`unknown_model` error instead. Per-connection sessions are
//! cached and refreshed on next use when their intake closes, so a hot
//! re-load of the same variant keeps existing connections working.

use std::collections::BTreeMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{Context, Result};

use super::frame::{self, FrameRead};
use super::wire::{NetRequest, NetResponse, RespBody, WireError};
use crate::serve::fault::{FaultPlan, NetFault};
use crate::serve::registry::{ModelRegistry, Session};
use crate::serve::tier::TierController;
use crate::serve::{Reply, ServeError};
use crate::util::json::Json;

/// Read timeout on connection sockets: the cadence at which an idle
/// reader polls the stop flag. Short enough that shutdown feels instant,
/// long enough to stay off the profile.
pub const IDLE_POLL: Duration = Duration::from_millis(25);

/// Write timeout on connection sockets. A client that stops reading while
/// responses pile up gets its connection declared dead after this long
/// instead of pinning the writer thread forever.
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// A running TCP serving endpoint over a shared [`ModelRegistry`].
///
/// Dropping the server stops it gracefully (idempotent with an explicit
/// [`NetServer::stop`]): no new connections, every accepted request
/// answered, all threads joined.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (port 0 for ephemeral) and start accepting. The
    /// registry stays owned by the caller — load/drain variants under the
    /// server's feet freely; that composition is the point.
    ///
    /// A server started this way has no tier controller: `tiered`
    /// requests are rejected as `bad_request`. Use
    /// [`NetServer::start_with`] to serve the SLO-routed op.
    pub fn start(registry: Arc<ModelRegistry>, addr: impl ToSocketAddrs) -> Result<NetServer> {
        Self::start_with(registry, None, addr)
    }

    /// Like [`NetServer::start`], but with an optional [`TierController`]
    /// over the same registry. When present, `tiered` requests route
    /// through it — the controller picks the precision tier, spills to
    /// cheaper tiers on queue-full, and sheds once the ladder saturates.
    pub fn start_with(
        registry: Arc<ModelRegistry>,
        tiers: Option<Arc<TierController>>,
        addr: impl ToSocketAddrs,
    ) -> Result<NetServer> {
        Self::start_faulted(registry, tiers, addr, None)
    }

    /// Like [`NetServer::start_with`], plus a [`FaultPlan`] whose
    /// connection-level sites fire inside this server's reader/writer
    /// threads: stalled reads, dropped connections, corrupted and
    /// truncated response frames. `None` hooks cost one branch per frame;
    /// production callers pass `None` and never see a fault.
    pub fn start_faulted(
        registry: Arc<ModelRegistry>,
        tiers: Option<Arc<TierController>>,
        addr: impl ToSocketAddrs,
        fault: Option<Arc<FaultPlan>>,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).context("binding serve listener")?;
        let local_addr = listener.local_addr().context("listener local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            thread::Builder::new()
                .name("lsq-net-accept".into())
                .spawn(move || accept_loop(listener, registry, tiers, stop, fault))
                .context("spawning accept thread")?
        };
        Ok(NetServer { local_addr, stop, accept: Some(accept) })
    }

    /// The bound address — tests bind port 0 and read the real port here.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Graceful stop: refuse new connections, answer everything already
    /// accepted, join every thread. Returns when the last connection is
    /// done.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the blocked accept; the new connection observes the stop
        // flag and is dropped immediately.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    tiers: Option<Arc<TierController>>,
    stop: Arc<AtomicBool>,
    fault: Option<Arc<FaultPlan>>,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    let mut next_cid = 0u64;
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue, // transient accept error; keep listening
        };
        conns.retain(|h| !h.is_finished());
        let cid = next_cid;
        next_cid += 1;
        let registry = Arc::clone(&registry);
        let tiers = tiers.clone();
        let stop = Arc::clone(&stop);
        let fault = fault.clone();
        let spawned = thread::Builder::new()
            .name(format!("lsq-net-conn-{cid}"))
            .spawn(move || handle_conn(stream, &registry, tiers.as_deref(), &stop, cid, fault));
        if let Ok(h) = spawned {
            conns.push(h);
        } // else: thread spawn failed — the dropped stream closes the peer
    }
    // Joining here is what makes NetServer::stop a *drain*: it returns
    // only after every connection's writer has flushed its last reply.
    for h in conns {
        let _ = h.join();
    }
}

/// What the reader hands the writer, one per request, in arrival order.
enum WriteItem {
    /// Already-resolved response (errors, ping, models).
    Ready(NetResponse),
    /// An accepted infer: the writer blocks on the reply channel. The
    /// registry guarantees the channel is answered exactly once — with a
    /// reply or a typed error (deadline shed, exec failure, drain) — so
    /// FIFO resolution cannot wedge.
    Pending {
        id: u64,
        rx: Receiver<Result<Reply, ServeError>>,
    },
}

fn handle_conn(
    mut stream: TcpStream,
    registry: &ModelRegistry,
    tiers: Option<&TierController>,
    stop: &AtomicBool,
    cid: u64,
    fault: Option<Arc<FaultPlan>>,
) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(IDLE_POLL)).is_err() {
        return;
    }
    let wstream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = wstream.set_write_timeout(Some(WRITE_TIMEOUT));
    let (tx, witems) = mpsc::channel::<WriteItem>();
    let writer = {
        let fault = fault.clone();
        match thread::Builder::new()
            .name(format!("lsq-net-wr-{cid}"))
            .spawn(move || writer_loop(wstream, witems, fault))
        {
            Ok(h) => h,
            Err(_) => return,
        }
    };

    let mut buf = Vec::new();
    let mut sessions: BTreeMap<String, Session> = BTreeMap::new();
    loop {
        // Checked every frame, not just on idle: a continuously-streaming
        // client must not be able to starve shutdown.
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match frame::read_frame(&mut stream, &mut buf, frame::MAX_FRAME_LEN) {
            Ok(FrameRead::Idle) => continue,
            Ok(FrameRead::Frame) => {
                // Fault hook: the site counter advances once per assembled
                // frame, so the k-th frame across all connections gets a
                // deterministic verdict regardless of accept interleaving.
                match fault.as_deref().map_or(NetFault::None, FaultPlan::net_read) {
                    NetFault::None => {}
                    NetFault::Stall(d) => thread::sleep(d),
                    NetFault::Drop => {
                        let _ = stream.shutdown(Shutdown::Both);
                        break;
                    }
                    // Corrupt/Truncate are write-side verdicts; net_read
                    // never returns them.
                    NetFault::Corrupt | NetFault::Truncate => {}
                }
            }
            Ok(FrameRead::TooLarge { len }) => {
                // The unread oversized body cannot be re-synced past:
                // report, then close.
                let _ = tx.send(WriteItem::Ready(NetResponse {
                    id: Json::Null,
                    body: Err(WireError::FrameTooLarge { len, max: frame::MAX_FRAME_LEN }),
                }));
                break;
            }
            // Clean close, mid-frame truncation/stall, or hard I/O error:
            // nothing sensible to answer — drain what was accepted and go.
            Ok(FrameRead::Eof) | Ok(FrameRead::Truncated) | Err(_) => break,
        }
        let item = handle_frame(&buf, registry, tiers, &mut sessions);
        if tx.send(item).is_err() {
            break;
        }
    }
    // Dropping the sender lets the writer finish its queue and exit;
    // joining it keeps the accepted-implies-answered promise.
    drop(tx);
    let _ = writer.join();
}

/// Parse one frame payload and either resolve it on the spot or submit it
/// and return the pending reply. Never panics: every malformed input path
/// resolves to a `bad_request` wire error.
fn handle_frame(
    payload: &[u8],
    registry: &ModelRegistry,
    tiers: Option<&TierController>,
    sessions: &mut BTreeMap<String, Session>,
) -> WriteItem {
    let bad = |id: Json, msg: String| {
        WriteItem::Ready(NetResponse { id, body: Err(WireError::BadRequest { msg }) })
    };
    let text = match std::str::from_utf8(payload) {
        Ok(t) => t,
        Err(_) => return bad(Json::Null, "frame payload is not UTF-8".to_string()),
    };
    let v = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => return bad(Json::Null, e.to_string()),
    };
    let (id_echo, parsed) = NetRequest::from_json(&v);
    let req = match parsed {
        Ok(r) => r,
        Err(msg) => return bad(id_echo, msg),
    };
    match req {
        NetRequest::Ping { id } => WriteItem::Ready(NetResponse::ok(id, RespBody::Pong)),
        NetRequest::Models { id } => {
            WriteItem::Ready(NetResponse::ok(id, RespBody::Models { models: registry.variants() }))
        }
        NetRequest::Infer { id, model, image, deadline_ms } => {
            let budget = deadline_ms.map(Duration::from_millis);
            match submit(registry, sessions, &model, image, budget) {
                Ok(rx) => WriteItem::Pending { id, rx },
                Err(e) => WriteItem::Ready(NetResponse::fail(id, WireError::from(e))),
            }
        }
        NetRequest::Tiered { id, image, deadline_ms } => match tiers {
            None => bad(
                Json::Num(id as f64),
                "no tier controller on this server (start with --tiers)".to_string(),
            ),
            Some(tc) => match tc.route_deadline(image, deadline_ms.map(Duration::from_millis)) {
                Ok(rx) => WriteItem::Pending { id, rx },
                Err(e) => WriteItem::Ready(NetResponse::fail(id, WireError::from(e))),
            },
        },
    }
}

/// Submit through the connection's session cache. A cached session whose
/// intake has closed (the variant was drained) is refreshed from the
/// registry before submitting, so a drain + hot re-load of the same
/// variant is invisible to long-lived connections — no image clone on the
/// hot path, the staleness check is one `RwLock` read.
fn submit(
    registry: &ModelRegistry,
    sessions: &mut BTreeMap<String, Session>,
    model: &str,
    image: Vec<f32>,
    budget: Option<Duration>,
) -> Result<Receiver<Result<Reply, ServeError>>, ServeError> {
    let stale = sessions.get(model).map_or(true, |s| !s.is_open());
    if stale {
        sessions.remove(model);
        let fresh = registry.session(model)?; // UnknownModel if not loaded
        sessions.insert(model.to_string(), fresh);
    }
    sessions.get(model).expect("session was just inserted").submit_deadline(image, budget)
}

fn writer_loop(
    mut stream: TcpStream,
    items: Receiver<WriteItem>,
    fault: Option<Arc<FaultPlan>>,
) {
    // Once a write fails (peer gone, or WRITE_TIMEOUT against a client
    // that stopped reading) the connection is dead — but the loop keeps
    // *consuming* items so every pending reply channel is still drained
    // and no replica-side accounting is left dangling.
    let mut dead = false;
    for item in items {
        let resp = match item {
            WriteItem::Ready(r) => r,
            WriteItem::Pending { id, rx } => match rx.recv() {
                Ok(Ok(reply)) => NetResponse::ok(
                    id,
                    RespBody::Infer {
                        logits: reply.logits,
                        argmax: reply.argmax,
                        queue_ms: reply.queue_ms,
                        total_ms: reply.total_ms,
                    },
                ),
                // Typed refusal after acceptance: deadline shed at
                // dequeue, exec failure, or drain answered it.
                Ok(Err(e)) => NetResponse::fail(id, WireError::from(e)),
                // The registry answers accepted requests; a dropped reply
                // channel means the replica set died out from under us.
                Err(_) => NetResponse::fail(id, WireError::ShutDown),
            },
        };
        if dead {
            continue;
        }
        let payload = resp.to_json().to_string();
        // Fault hook: one verdict per response actually written, so the
        // k-th response across all connections is the one garbled.
        let wrote = match fault.as_deref().map_or(NetFault::None, FaultPlan::net_write) {
            NetFault::Corrupt => frame::write_frame_corrupted(&mut stream, payload.as_bytes()),
            NetFault::Truncate => {
                // A half-written frame cannot be re-synced past: garble,
                // then kill the connection like a mid-write crash would.
                let r = frame::write_frame_truncated(&mut stream, payload.as_bytes());
                let _ = stream.shutdown(Shutdown::Both);
                dead = true;
                r
            }
            _ => frame::write_frame(&mut stream, payload.as_bytes()),
        };
        if wrote.is_err() {
            dead = true;
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
}
