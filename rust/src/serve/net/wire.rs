//! Wire-level request/response vocabulary: the JSON payloads inside
//! [`super::frame`] frames, and the mapping from the in-process
//! [`ServeError`] surface onto structured wire errors.
//!
//! Requests (`op` defaults to `"infer"` when absent):
//!
//! ```text
//! {"id": 7, "op": "infer", "model": "cnn_small_q2", "image": [0.1, …]}
//! {"id": 8, "op": "models"}
//! {"id": 9, "op": "ping"}
//! {"id": 10, "op": "tiered", "image": [0.1, …]}
//! ```
//!
//! `infer` and `tiered` accept an optional `"deadline_ms"` (non-negative
//! integer): the server stamps the request on arrival and sheds it with a
//! `deadline_exceeded` error if it is still queued when the budget runs
//! out, instead of burning replica time on an answer the client has
//! stopped waiting for.
//!
//! `tiered` carries no model name: the server's
//! [`crate::serve::TierController`] picks the precision tier (and may
//! answer `shed` when its whole ladder is saturated). Servers started
//! without a controller reject it as `bad_request`.
//!
//! Responses echo the request `id` (JSON `null` when the request was too
//! malformed to carry one) and are either `"ok": true` with an op-specific
//! body, or `"ok": false` with a structured error object:
//!
//! ```text
//! {"id": 7, "ok": true, "logits": [...], "argmax": 2,
//!  "queue_ms": 0.12, "total_ms": 0.80}
//! {"id": 7, "ok": false,
//!  "error": {"kind": "queue_full", "depth": 256, "msg": "…"}}
//! ```
//!
//! Every [`ServeError`] variant has a wire `kind` (see [`WireError`] and
//! the table in DESIGN.md §Wire-protocol), so a remote open-loop client
//! sees `queue_full` backpressure and `closed` drains instead of dropped
//! connections — the paper's several-precisions-one-architecture serving
//! story (PAPER.md Figure 3) holds up across a socket.

use std::fmt;

use crate::serve::ServeError;
use crate::util::json::Json;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum NetRequest {
    /// Run one image through `model` and return its logits.
    Infer {
        /// Client-chosen id echoed on the response.
        id: u64,
        /// Registry variant name, e.g. `"cnn_small_q2"`.
        model: String,
        /// Flattened NHWC image (`image × image × channels` floats).
        image: Vec<f32>,
        /// Queue-time budget: the server sheds the request with
        /// `deadline_exceeded` if it has not started executing within
        /// this many milliseconds of arrival. `None` = wait forever.
        deadline_ms: Option<u64>,
    },
    /// List the registry's loaded variant names.
    Models {
        /// Client-chosen id echoed on the response.
        id: u64,
    },
    /// Liveness probe.
    Ping {
        /// Client-chosen id echoed on the response.
        id: u64,
    },
    /// Run one image through whatever precision tier the server's
    /// controller currently routes to (no model name). Answered with the
    /// same body as [`NetRequest::Infer`]; only servers started with a
    /// [`crate::serve::TierController`] accept it.
    Tiered {
        /// Client-chosen id echoed on the response.
        id: u64,
        /// Flattened NHWC image (`image × image × channels` floats).
        image: Vec<f32>,
        /// Queue-time budget, as on [`NetRequest::Infer`].
        deadline_ms: Option<u64>,
    },
}

/// The `"ok": true` body of a [`NetResponse`].
#[derive(Clone, Debug, PartialEq)]
pub enum RespBody {
    /// Answer to [`NetRequest::Infer`].
    Infer {
        /// Raw logits, one per class. f32 → JSON → f32 is exact (f64
        /// shortest-representation round-trip), so remote logits stay
        /// bitwise-identical to the engine's.
        logits: Vec<f32>,
        /// Index of the winning class.
        argmax: usize,
        /// Server-side queue+batching time (submit → execution start).
        queue_ms: f64,
        /// Server-side latency (accept → reply), excluding the network.
        total_ms: f64,
    },
    /// Answer to [`NetRequest::Models`].
    Models {
        /// Loaded variant names.
        models: Vec<String>,
    },
    /// Answer to [`NetRequest::Ping`].
    Pong,
}

/// Structured wire errors: the remote image of [`ServeError`] plus the
/// protocol-level failures only a socket can produce.
#[derive(Clone, Debug, PartialEq)]
pub enum WireError {
    /// The variant's queue is at its configured bound — backpressure;
    /// retry, shed, or route to another precision tier.
    QueueFull {
        /// The queue bound that was hit.
        depth: usize,
    },
    /// No variant with this name is loaded.
    UnknownModel {
        /// The name the request asked for.
        model: String,
    },
    /// The variant's intake closed mid-request (it is being drained).
    Closed,
    /// The serving side went away (replicas exited).
    ShutDown,
    /// Image float count does not match the variant's geometry.
    BadImage {
        /// Floats submitted.
        got: usize,
        /// Floats the variant needs.
        want: usize,
    },
    /// The frame was not a well-formed request (bad UTF-8, malformed
    /// JSON, missing/mistyped fields, unknown op). The connection stays
    /// usable — framing is intact.
    BadRequest {
        /// What was wrong, for the client's logs.
        msg: String,
    },
    /// The frame header announced a payload over the server's limit. Sent
    /// as the final response before the server closes the connection
    /// (an unread oversized body cannot be re-synced past).
    FrameTooLarge {
        /// Announced payload length.
        len: usize,
        /// The server's configured ceiling.
        max: usize,
    },
    /// Every tier of the controller's precision ladder was saturated: the
    /// request was not accepted anywhere and has been shed. Unlike
    /// `queue_full` (one variant's backpressure — retry or re-tier),
    /// shedding means the whole ladder is out of capacity: back off
    /// before retrying.
    Shed,
    /// The request's `deadline_ms` budget expired while it was still
    /// queued; the server shed it at dequeue without executing it. Not
    /// worth retrying with the same budget — the queue was slower than
    /// the client was willing to wait.
    DeadlineExceeded,
}

impl From<ServeError> for WireError {
    fn from(e: ServeError) -> WireError {
        match e {
            ServeError::QueueFull { depth } => WireError::QueueFull { depth },
            ServeError::UnknownModel(model) => WireError::UnknownModel { model },
            ServeError::Closed => WireError::Closed,
            ServeError::ShutDown => WireError::ShutDown,
            ServeError::BadImage { got, want } => WireError::BadImage { got, want },
            ServeError::Shed => WireError::Shed,
            ServeError::DeadlineExceeded => WireError::DeadlineExceeded,
        }
    }
}

impl WireError {
    /// The stable `kind` string used on the wire.
    pub fn kind(&self) -> &'static str {
        match self {
            WireError::QueueFull { .. } => "queue_full",
            WireError::UnknownModel { .. } => "unknown_model",
            WireError::Closed => "closed",
            WireError::ShutDown => "shut_down",
            WireError::BadImage { .. } => "bad_image",
            WireError::BadRequest { .. } => "bad_request",
            WireError::FrameTooLarge { .. } => "frame_too_large",
            WireError::Shed => "shed",
            WireError::DeadlineExceeded => "deadline_exceeded",
        }
    }

    /// The `"error"` object of an error response.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("kind", Json::str(self.kind()))];
        match self {
            WireError::QueueFull { depth } => {
                fields.push(("depth", Json::num(*depth as f64)));
            }
            WireError::UnknownModel { model } => {
                fields.push(("model", Json::str(model.clone())));
            }
            WireError::BadImage { got, want } => {
                fields.push(("got", Json::num(*got as f64)));
                fields.push(("want", Json::num(*want as f64)));
            }
            WireError::FrameTooLarge { len, max } => {
                fields.push(("len", Json::num(*len as f64)));
                fields.push(("max", Json::num(*max as f64)));
            }
            WireError::BadRequest { msg } => {
                // The raw reason gets its own field: "msg" below is the
                // human Display text ("bad request: …"), and parsing it
                // back would not be an identity.
                fields.push(("reason", Json::str(msg.clone())));
            }
            WireError::Closed
            | WireError::ShutDown
            | WireError::Shed
            | WireError::DeadlineExceeded => {}
        }
        fields.push(("msg", Json::str(self.to_string())));
        Json::obj(fields)
    }

    /// Parse an `"error"` object back into the typed error.
    pub fn from_json(v: &Json) -> Result<WireError, String> {
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| "error object missing \"kind\"".to_string())?;
        let us = |key: &str| {
            v.get(key)
                .and_then(Json::as_u64)
                .map(|n| n as usize)
                .ok_or_else(|| format!("error object missing numeric {key:?}"))
        };
        match kind {
            "queue_full" => Ok(WireError::QueueFull { depth: us("depth")? }),
            "unknown_model" => Ok(WireError::UnknownModel {
                model: v
                    .get("model")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
            }),
            "closed" => Ok(WireError::Closed),
            "shut_down" => Ok(WireError::ShutDown),
            "bad_image" => Ok(WireError::BadImage { got: us("got")?, want: us("want")? }),
            "bad_request" => Ok(WireError::BadRequest {
                msg: v.get("reason").and_then(Json::as_str).unwrap_or_default().to_string(),
            }),
            "frame_too_large" => Ok(WireError::FrameTooLarge { len: us("len")?, max: us("max")? }),
            "shed" => Ok(WireError::Shed),
            "deadline_exceeded" => Ok(WireError::DeadlineExceeded),
            other => Err(format!("unknown error kind {other:?}")),
        }
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::QueueFull { depth } => {
                write!(f, "request queue full (depth {depth}): backpressure, retry later")
            }
            WireError::UnknownModel { model } => write!(f, "unknown model variant {model:?}"),
            WireError::Closed => write!(f, "variant intake closed (draining)"),
            WireError::ShutDown => write!(f, "server shut down"),
            WireError::BadImage { got, want } => {
                write!(f, "image must have {want} floats, got {got}")
            }
            WireError::BadRequest { msg } => write!(f, "bad request: {msg}"),
            WireError::FrameTooLarge { len, max } => {
                write!(f, "frame payload {len} B exceeds the {max} B limit")
            }
            WireError::Shed => {
                write!(f, "all precision tiers saturated: request shed, back off before retrying")
            }
            WireError::DeadlineExceeded => {
                write!(f, "request deadline expired before execution; shed at dequeue")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl NetRequest {
    /// The request's client-chosen id.
    pub fn id(&self) -> u64 {
        match self {
            NetRequest::Infer { id, .. }
            | NetRequest::Models { id }
            | NetRequest::Ping { id }
            | NetRequest::Tiered { id, .. } => *id,
        }
    }

    /// Serialize to the frame payload JSON.
    pub fn to_json(&self) -> Json {
        match self {
            NetRequest::Infer { id, model, image, deadline_ms } => {
                let mut fields = vec![
                    ("id", Json::num(*id as f64)),
                    ("op", Json::str("infer")),
                    ("model", Json::str(model.clone())),
                    ("image", Json::arr_f32(image)),
                ];
                if let Some(ms) = deadline_ms {
                    fields.push(("deadline_ms", Json::num(*ms as f64)));
                }
                Json::obj(fields)
            }
            NetRequest::Models { id } => {
                Json::obj(vec![("id", Json::num(*id as f64)), ("op", Json::str("models"))])
            }
            NetRequest::Ping { id } => {
                Json::obj(vec![("id", Json::num(*id as f64)), ("op", Json::str("ping"))])
            }
            NetRequest::Tiered { id, image, deadline_ms } => {
                let mut fields = vec![
                    ("id", Json::num(*id as f64)),
                    ("op", Json::str("tiered")),
                    ("image", Json::arr_f32(image)),
                ];
                if let Some(ms) = deadline_ms {
                    fields.push(("deadline_ms", Json::num(*ms as f64)));
                }
                Json::obj(fields)
            }
        }
    }

    /// Parse a frame payload. Returns the echoable id (JSON `null` when
    /// absent/mistyped) alongside the strict parse result, so the server
    /// can address its `bad_request` response even for broken requests.
    pub fn from_json(v: &Json) -> (Json, Result<NetRequest, String>) {
        let id_echo = v.get("id").cloned().unwrap_or(Json::Null);
        let parsed = (|| {
            let id = v
                .get("id")
                .and_then(Json::as_u64)
                .ok_or_else(|| "missing or non-integer \"id\"".to_string())?;
            let op = match v.get("op") {
                None => "infer",
                Some(o) => o.as_str().ok_or_else(|| "\"op\" must be a string".to_string())?,
            };
            let image_field = || -> Result<Vec<f32>, String> {
                let arr = v
                    .get("image")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "missing array \"image\"".to_string())?;
                let mut image = Vec::with_capacity(arr.len());
                for (i, e) in arr.iter().enumerate() {
                    let x =
                        e.as_f64().ok_or_else(|| format!("\"image\"[{i}] is not a number"))?;
                    image.push(x as f32);
                }
                Ok(image)
            };
            let deadline_field = || -> Result<Option<u64>, String> {
                match v.get("deadline_ms") {
                    None => Ok(None),
                    Some(d) => d
                        .as_u64()
                        .map(Some)
                        .ok_or_else(|| "\"deadline_ms\" must be a non-negative integer".to_string()),
                }
            };
            match op {
                "infer" => {
                    let model = v
                        .get("model")
                        .and_then(Json::as_str)
                        .ok_or_else(|| "missing string \"model\"".to_string())?
                        .to_string();
                    Ok(NetRequest::Infer {
                        id,
                        model,
                        image: image_field()?,
                        deadline_ms: deadline_field()?,
                    })
                }
                "models" => Ok(NetRequest::Models { id }),
                "ping" => Ok(NetRequest::Ping { id }),
                "tiered" => {
                    Ok(NetRequest::Tiered { id, image: image_field()?, deadline_ms: deadline_field()? })
                }
                other => Err(format!("unknown op {other:?}")),
            }
        })();
        (id_echo, parsed)
    }
}

/// One response frame: the echoed request id plus either an op body or a
/// structured error.
#[derive(Clone, Debug, PartialEq)]
pub struct NetResponse {
    /// The request's `id`, echoed verbatim (JSON `null` when the request
    /// was too malformed to carry one).
    pub id: Json,
    /// Success body or structured wire error.
    pub body: Result<RespBody, WireError>,
}

impl NetResponse {
    /// A success response addressed to request `id`.
    pub fn ok(id: u64, body: RespBody) -> NetResponse {
        NetResponse { id: Json::num(id as f64), body: Ok(body) }
    }

    /// An error response addressed to request `id`.
    pub fn fail(id: u64, err: WireError) -> NetResponse {
        NetResponse { id: Json::num(id as f64), body: Err(err) }
    }

    /// Serialize to the frame payload JSON.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("id", self.id.clone())];
        match &self.body {
            Ok(RespBody::Infer { logits, argmax, queue_ms, total_ms }) => {
                fields.push(("ok", Json::Bool(true)));
                fields.push(("logits", Json::arr_f32(logits)));
                fields.push(("argmax", Json::num(*argmax as f64)));
                fields.push(("queue_ms", Json::num(*queue_ms)));
                fields.push(("total_ms", Json::num(*total_ms)));
            }
            Ok(RespBody::Models { models }) => {
                fields.push(("ok", Json::Bool(true)));
                fields.push((
                    "models",
                    Json::Arr(models.iter().map(|m| Json::str(m.clone())).collect()),
                ));
            }
            Ok(RespBody::Pong) => {
                fields.push(("ok", Json::Bool(true)));
                fields.push(("pong", Json::Bool(true)));
            }
            Err(e) => {
                fields.push(("ok", Json::Bool(false)));
                fields.push(("error", e.to_json()));
            }
        }
        Json::obj(fields)
    }

    /// Parse a frame payload the server sent.
    pub fn from_json(v: &Json) -> Result<NetResponse, String> {
        let id = v.get("id").cloned().unwrap_or(Json::Null);
        let ok = v
            .get("ok")
            .and_then(Json::as_bool)
            .ok_or_else(|| "response missing boolean \"ok\"".to_string())?;
        if !ok {
            let e = v.get("error").ok_or_else(|| "error response missing \"error\"".to_string())?;
            return Ok(NetResponse { id, body: Err(WireError::from_json(e)?) });
        }
        if let Some(arr) = v.get("logits").and_then(Json::as_arr) {
            let mut logits = Vec::with_capacity(arr.len());
            for (i, e) in arr.iter().enumerate() {
                let x = e.as_f64().ok_or_else(|| format!("\"logits\"[{i}] is not a number"))?;
                logits.push(x as f32);
            }
            let argmax = v
                .get("argmax")
                .and_then(Json::as_u64)
                .ok_or_else(|| "infer response missing \"argmax\"".to_string())?
                as usize;
            let queue_ms = v.f64_at("queue_ms").map_err(|e| e.to_string())?;
            let total_ms = v.f64_at("total_ms").map_err(|e| e.to_string())?;
            return Ok(NetResponse { id, body: Ok(RespBody::Infer { logits, argmax, queue_ms, total_ms }) });
        }
        if let Some(arr) = v.get("models").and_then(Json::as_arr) {
            let mut models = Vec::with_capacity(arr.len());
            for (i, e) in arr.iter().enumerate() {
                models.push(
                    e.as_str()
                        .ok_or_else(|| format!("\"models\"[{i}] is not a string"))?
                        .to_string(),
                );
            }
            return Ok(NetResponse { id, body: Ok(RespBody::Models { models }) });
        }
        if v.get("pong").is_some() {
            return Ok(NetResponse { id, body: Ok(RespBody::Pong) });
        }
        Err("ok response has no recognizable body".to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: NetRequest) {
        let text = r.to_json().to_string();
        let v = Json::parse(&text).unwrap();
        let (id_echo, back) = NetRequest::from_json(&v);
        assert_eq!(id_echo.as_u64(), Some(r.id()));
        assert_eq!(back.unwrap(), r, "text: {text}");
    }

    fn roundtrip_resp(r: NetResponse) {
        let text = r.to_json().to_string();
        let back = NetResponse::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r, "text: {text}");
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(NetRequest::Infer {
            id: 7,
            model: "cnn_small_q2".into(),
            image: vec![0.0, -1.5, 0.33333334, f32::MIN_POSITIVE],
            deadline_ms: None,
        });
        roundtrip_req(NetRequest::Infer {
            id: 8,
            model: "cnn_small_q2".into(),
            image: vec![0.5],
            deadline_ms: Some(250),
        });
        roundtrip_req(NetRequest::Models { id: 0 });
        roundtrip_req(NetRequest::Ping { id: u32::MAX as u64 });
        roundtrip_req(NetRequest::Tiered { id: 11, image: vec![0.25, -2.0, 1e-7], deadline_ms: None });
        roundtrip_req(NetRequest::Tiered { id: 12, image: vec![0.25], deadline_ms: Some(0) });
    }

    #[test]
    fn response_and_every_error_kind_roundtrip() {
        roundtrip_resp(NetResponse::ok(
            1,
            RespBody::Infer {
                logits: vec![1.25, -0.5, 3.0],
                argmax: 2,
                queue_ms: 0.125,
                total_ms: 1.5,
            },
        ));
        roundtrip_resp(NetResponse::ok(
            2,
            RespBody::Models { models: vec!["a_q2".into(), "a_q4".into()] },
        ));
        roundtrip_resp(NetResponse::ok(3, RespBody::Pong));
        for e in [
            WireError::QueueFull { depth: 256 },
            WireError::UnknownModel { model: "nope_q9".into() },
            WireError::Closed,
            WireError::ShutDown,
            WireError::BadImage { got: 7, want: 192 },
            WireError::BadRequest { msg: "missing string \"model\"".into() },
            WireError::FrameTooLarge { len: 1 << 30, max: 4 << 20 },
            WireError::Shed,
            WireError::DeadlineExceeded,
        ] {
            roundtrip_resp(NetResponse::fail(9, e));
        }
    }

    #[test]
    fn serve_error_mapping_covers_every_variant() {
        use crate::serve::ServeError;
        assert_eq!(
            WireError::from(ServeError::QueueFull { depth: 3 }),
            WireError::QueueFull { depth: 3 }
        );
        assert_eq!(
            WireError::from(ServeError::UnknownModel("m_q2".into())),
            WireError::UnknownModel { model: "m_q2".into() }
        );
        assert_eq!(WireError::from(ServeError::Closed), WireError::Closed);
        assert_eq!(WireError::from(ServeError::ShutDown), WireError::ShutDown);
        assert_eq!(
            WireError::from(ServeError::BadImage { got: 1, want: 2 }),
            WireError::BadImage { got: 1, want: 2 }
        );
        assert_eq!(WireError::from(ServeError::Shed), WireError::Shed);
        assert_eq!(WireError::from(ServeError::DeadlineExceeded), WireError::DeadlineExceeded);
    }

    #[test]
    fn malformed_requests_are_typed_not_panics() {
        for text in [
            "{}",
            "{\"id\": -1, \"model\": \"m\", \"image\": []}",
            "{\"id\": 1.5, \"model\": \"m\", \"image\": []}",
            "{\"id\": 1, \"op\": \"reboot\"}",
            "{\"id\": 1, \"model\": 3, \"image\": []}",
            "{\"id\": 1, \"model\": \"m\", \"image\": [\"x\"]}",
            "{\"id\": 1, \"model\": \"m\"}",
            "{\"id\": 1, \"op\": \"tiered\"}",
            "{\"id\": 1, \"op\": \"tiered\", \"image\": [\"x\"]}",
            "{\"id\": 1, \"model\": \"m\", \"image\": [], \"deadline_ms\": \"fast\"}",
            "{\"id\": 1, \"model\": \"m\", \"image\": [], \"deadline_ms\": -5}",
            "{\"id\": 1, \"model\": \"m\", \"image\": [], \"deadline_ms\": 1.5}",
            "[1, 2, 3]",
            "null",
        ] {
            let v = Json::parse(text).unwrap();
            let (_, parsed) = NetRequest::from_json(&v);
            assert!(parsed.is_err(), "should reject: {text}");
        }
        // id echo survives even when the request is rejected.
        let v = Json::parse("{\"id\": 42, \"op\": \"reboot\"}").unwrap();
        let (id, parsed) = NetRequest::from_json(&v);
        assert_eq!(id.as_u64(), Some(42));
        assert!(parsed.is_err());
    }

    #[test]
    fn op_defaults_to_infer() {
        let v = Json::parse("{\"id\": 4, \"model\": \"m_q2\", \"image\": [0.5]}").unwrap();
        let (_, parsed) = NetRequest::from_json(&v);
        assert_eq!(
            parsed.unwrap(),
            NetRequest::Infer { id: 4, model: "m_q2".into(), image: vec![0.5], deadline_ms: None }
        );
    }
}
