//! Multi-model serving gateway: one process, many bound model variants.
//!
//! LSQ's deployment story (PAPER.md §1, Figure 3) is one architecture at
//! *several* precisions — 2/3/4/8-bit variants trading accuracy against
//! size and latency. [`ModelRegistry`] makes that a first-class serving
//! surface: each loaded **variant** (a manifest family, e.g.
//! `cnn_small_q2`) owns its own request queue, replica set and
//! [`ServeStats`], all inside one process sharing one core budget.
//! Callers address a variant by name through a [`Session`] handle:
//!
//! ```text
//!  ModelRegistry ──────────────────────────────────────────────┐
//!  │ core budget (default: hardware threads)                   │
//!  │                                                           │
//!  │  "cnn_small_q2" ─ VariantShared ──────────────┐           │
//!  │  │ intake: RwLock<Option<SyncSender>>         │◄── Session("cnn_small_q2")
//!  │  │ stats:  Mutex<ServeStats>                  │◄── Session (any thread)
//!  │  │ queue ─► replica 0 ─► NativeEngine + ws    │           │
//!  │  │       └► replica 1 ─► NativeEngine + ws    │           │
//!  │  │ supervisor ── respawns dead replicas ──────┘           │
//!  │  └────────────────────────────────────────────┘           │
//!  │  "cnn_small_q4" ─ VariantShared ─► replica …  ◄── Session("cnn_small_q4")
//!  └───────────────────────────────────────────────────────────┘
//! ```
//!
//! Hot load/unload: [`ModelRegistry::load`] binds a new variant under
//! live traffic to the others, and [`ModelRegistry::drain_and_unload`]
//! retires one — the intake sender is the *only* sender for the variant's
//! queue (sessions borrow it under a read lock, never clone it), so
//! dropping it disconnects the queue deterministically: replicas dispatch
//! every request already accepted, answer it, and exit. No in-flight
//! request is dropped, and subsequent submits fail with
//! [`ServeError::Closed`].
//!
//! **Self-healing** (DESIGN.md §Fault-model): each variant runs a
//! supervisor thread that reaps dead replica workers and respawns them
//! with jittered exponential backoff under a [`RestartPolicy`] — a
//! rolling restart *budget* so a crash loop cannot spin forever. Budget
//! exhaustion (or total replica death with nothing left to respawn)
//! marks the variant unhealthy ([`ModelRegistry::healthy`]), which is
//! the signal the tier controller fails over on, instead of silently
//! serving at reduced capacity. Teardown composes with an in-flight
//! respawn: drain *joins the supervisor*, which stops scheduling
//! respawns the moment the intake closes and spawns a short-lived
//! drainer replica if workers died with requests still queued — every
//! accepted request is answered exactly once, even mid-crash.
//!
//! [`super::Server`] remains as a one-variant compatibility shim over
//! this registry. See DESIGN.md §Serving-API.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::runtime::artifact::{ArtifactError, LoadedArtifact};
use crate::runtime::native::{NativeEngine, NativeModel, UnpackMode};
use crate::runtime::{Backend, BackendKind, BackendSpec, Manifest, PrepareOptions};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

use super::fault::{FaultPlan, ReplicaFault};
use super::{Reply, Request, ServeError, ServeStats};

/// Supervisor restart discipline for one variant's replica set.
///
/// A dead replica is respawned after a jittered exponential backoff
/// (`backoff · 2^(n−1)` capped at `backoff_cap`, ×[1, 1.25) jitter so
/// sibling crash loops desynchronize), but only while fewer than
/// `budget` restarts have happened within the rolling `window`. Hitting
/// the budget marks the variant **unhealthy** — the tier controller's
/// failover signal — and stops respawning for the life of this load
/// (re-`load` the variant to reset). `budget: 0` disables supervision
/// entirely (the pre-supervisor behavior: survivors keep serving, total
/// death closes the variant).
#[derive(Clone, Debug)]
pub struct RestartPolicy {
    /// Restarts allowed per rolling `window` before the variant is
    /// declared unhealthy. 0 = never respawn.
    pub budget: u32,
    /// Rolling window the budget is counted over.
    pub window: Duration,
    /// Base backoff before the first respawn; doubles per restart in the
    /// window.
    pub backoff: Duration,
    /// Upper bound on the exponential backoff.
    pub backoff_cap: Duration,
    /// Seed for the backoff jitter (mixed with the variant name, so two
    /// variants under one policy still jitter independently).
    pub jitter_seed: u64,
}

impl Default for RestartPolicy {
    fn default() -> RestartPolicy {
        RestartPolicy {
            budget: 3,
            window: Duration::from_secs(10),
            backoff: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(500),
            jitter_seed: 0,
        }
    }
}

impl RestartPolicy {
    /// Never respawn: replica deaths only decrement live capacity, and
    /// total death closes the variant. For tests and embedded callers
    /// that manage recovery themselves.
    pub fn disabled() -> RestartPolicy {
        RestartPolicy { budget: 0, ..RestartPolicy::default() }
    }
}

/// Per-variant deployment options for [`ModelRegistry::load`].
#[derive(Clone, Debug)]
pub struct VariantOptions {
    /// Checkpoint with trained params (empty = the family's initial params).
    pub checkpoint: String,
    /// Engine replicas (worker threads) for this variant. Clamped to ≥ 1.
    pub replicas: usize,
    /// Dynamic-batching window: maximum time a dispatching worker waits
    /// for stragglers after the first request of a batch arrives.
    pub max_wait: Duration,
    /// Bound on queued requests. A full queue surfaces as
    /// [`ServeError::QueueFull`] on submit — real backpressure for
    /// open-loop clients, never an indefinite block.
    pub queue_depth: usize,
    /// Intra-op kernel threads *per replica*
    /// ([`PrepareOptions::intra_op_threads`]). 0 = auto: this variant's
    /// share of the registry core budget, `budget / total replicas`
    /// counted across every loaded variant at load time.
    pub intra_threads: usize,
    /// Weight-storage choice, forwarded to
    /// [`PrepareOptions::low_memory`]: `Some(true)` = fused low-memory
    /// unpack, `Some(false)` = pin the panelized fast path, `None` = the
    /// process `LSQNET_FUSED_UNPACK` default.
    pub low_memory: Option<bool>,
    /// Supervisor restart discipline for this variant's replicas.
    pub restarts: RestartPolicy,
    /// Deterministic fault schedule threaded into the replica exec loop
    /// (chaos tests). `None` — the default and the production value —
    /// injects nothing.
    pub fault: Option<Arc<FaultPlan>>,
    /// Bind this variant from a packed `.lsqa` artifact instead of the
    /// manifest + params path: the artifact is loaded and fully verified
    /// once on the caller thread, and every replica borrows panel blocks
    /// from its shared arena (zero per-replica rebuild — the fleet
    /// cold-start and hot-reload fast path). The artifact's family must
    /// equal the variant name; mutually exclusive with `checkpoint`
    /// (the artifact froze its checkpoint at pack time); native backend
    /// only. A corrupted or mismatched artifact fails the load loudly
    /// with a typed [`ArtifactError`] — never a silent manifest rebuild.
    pub artifact: Option<PathBuf>,
}

impl Default for VariantOptions {
    fn default() -> Self {
        VariantOptions {
            checkpoint: String::new(),
            replicas: 1,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
            intra_threads: 0,
            low_memory: None,
            restarts: RestartPolicy::default(),
            fault: None,
            artifact: None,
        }
    }
}

/// State shared between a variant's replicas and its [`Session`] handles.
///
/// The intake sender is deliberately **not** cloneable from the outside:
/// sessions borrow it under the read lock for the duration of one
/// `try_send`, so `drain_and_unload` taking the write lock and dropping it
/// is a linearization point — every submit strictly before it is accepted
/// (and will be answered), every submit after it observes
/// [`ServeError::Closed`].
struct VariantShared {
    variant: String,
    intake: RwLock<Option<SyncSender<Request>>>,
    stats: Mutex<ServeStats>,
    /// Requests ever accepted by `try_send` (the linearization point of
    /// admission). `accepted − stats.answered()` is the live queue-depth
    /// gauge: requests queued, batching, or executing but not yet
    /// answered — one of the three signals the tier controller samples.
    accepted: AtomicU64,
    /// `false` once the supervisor gives up on the variant: restart
    /// budget exhausted, or every replica dead with nothing scheduled.
    /// The tier controller's failover signal ([`ModelRegistry::healthy`]).
    health: AtomicBool,
    /// Replica worker threads currently running their exec loop.
    live: AtomicUsize,
    image_len: usize,
    queue_depth: usize,
}

struct VariantEntry {
    shared: Arc<VariantShared>,
    /// The variant's supervisor thread; it owns the replica handles.
    /// Joining it (after closing the intake) joins the whole worker set.
    supervisor: Vec<std::thread::JoinHandle<()>>,
    replicas: usize,
}

/// A cloneable, thread-safe handle for submitting requests to one variant
/// of a [`ModelRegistry`].
#[derive(Clone)]
pub struct Session {
    shared: Arc<VariantShared>,
}

impl Session {
    /// The variant name this session addresses.
    pub fn variant(&self) -> &str {
        &self.shared.variant
    }

    /// Blocking single-request inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply, ServeError> {
        let rx = self.submit(image)?;
        rx.recv().unwrap_or(Err(ServeError::ShutDown))
    }

    /// Non-blocking submit; returns the reply channel (answered exactly
    /// once: `Ok(Reply)`, or a terminal `Err` such as
    /// [`ServeError::DeadlineExceeded`] / [`ServeError::ShutDown`]). A
    /// full queue is [`ServeError::QueueFull`] (backpressure), a
    /// drained/unloaded variant [`ServeError::Closed`], and a variant
    /// whose replicas all died [`ServeError::ShutDown`].
    pub fn submit(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, ServeError> {
        self.submit_reclaim(image).map_err(|(e, _)| e)
    }

    /// [`Session::submit`] with a latency budget: once `budget` elapses
    /// the request may be shed *at dequeue* — a replica answers
    /// [`ServeError::DeadlineExceeded`] instead of executing a forward
    /// pass nobody is waiting for. `None` = no deadline.
    pub fn submit_deadline(
        &self,
        image: Vec<f32>,
        budget: Option<Duration>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, ServeError> {
        self.submit_reclaim_deadline(image, budget).map_err(|(e, _)| e)
    }

    /// [`Session::submit`], but every error path hands the image buffer
    /// back alongside the typed error, so a router retrying another tier
    /// (the tier controller spilling down its ladder) threads one
    /// allocation through the attempts instead of cloning per tier.
    pub fn submit_reclaim(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, (ServeError, Vec<f32>)> {
        self.submit_reclaim_deadline(image, None)
    }

    /// [`Session::submit_reclaim`] with a [`Session::submit_deadline`]
    /// latency budget.
    pub fn submit_reclaim_deadline(
        &self,
        image: Vec<f32>,
        budget: Option<Duration>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, (ServeError, Vec<f32>)> {
        if image.len() != self.shared.image_len {
            let err = ServeError::BadImage { got: image.len(), want: self.shared.image_len };
            return Err((err, image));
        }
        let now = Instant::now();
        let expires = budget.map(|b| now + b);
        let guard = self.shared.intake.read().unwrap();
        let tx = match guard.as_ref() {
            Some(tx) => tx,
            None => return Err((ServeError::Closed, image)),
        };
        let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(1);
        match tx.try_send(Request { image, submitted: now, expires, reply: reply_tx }) {
            Ok(()) => {
                self.shared.accepted.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(TrySendError::Full(req)) => {
                Err((ServeError::QueueFull { depth: self.shared.queue_depth }, req.image))
            }
            Err(TrySendError::Disconnected(req)) => Err((ServeError::ShutDown, req.image)),
        }
    }

    /// Requests accepted but not yet answered (queued + batching +
    /// executing): the live queue-depth gauge. "Answered" includes
    /// deadline sheds and terminal errors ([`ServeStats::answered`]).
    /// Racy by nature — it moves under traffic; use it as a load signal,
    /// not an invariant.
    pub fn in_flight(&self) -> usize {
        let accepted = self.shared.accepted.load(Ordering::Relaxed);
        let answered = self.shared.stats.lock().unwrap().answered();
        accepted.saturating_sub(answered) as usize
    }

    /// Snapshot of this variant's aggregate metrics.
    pub fn stats(&self) -> ServeStats {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Whether the variant's intake is still accepting requests (`false`
    /// after `close_intake`/`drain_and_unload`). Racy by nature — a
    /// concurrent drain can close the intake right after this returns
    /// `true`; [`Session::submit`]'s [`ServeError::Closed`] is the
    /// authoritative answer.
    pub fn is_open(&self) -> bool {
        self.shared.intake.read().unwrap().is_some()
    }
}

/// One server process hosting many bound model variants, each with its own
/// replica set and stats, sharing one core budget. See the module docs for
/// the ownership diagram and DESIGN.md §Serving-API for the rationale.
pub struct ModelRegistry {
    spec: BackendSpec,
    core_budget: usize,
    variants: Mutex<BTreeMap<String, VariantEntry>>,
}

impl ModelRegistry {
    /// A registry opening engines from `spec`, with the core budget set to
    /// the host's hardware thread count.
    pub fn open(spec: BackendSpec) -> ModelRegistry {
        ModelRegistry::with_core_budget(spec, 0)
    }

    /// [`ModelRegistry::open`] with an explicit core budget shared by all
    /// variants (0 = hardware threads). The budget is partitioned across
    /// replicas at [`ModelRegistry::load`] time: a variant loaded with
    /// `intra_threads: 0` gets `budget / total replicas` kernel threads
    /// per replica, counting every replica loaded so far plus its own.
    /// Already-running variants keep their width (re-load one to
    /// rebalance).
    pub fn with_core_budget(spec: BackendSpec, core_budget: usize) -> ModelRegistry {
        let budget = if core_budget == 0 {
            crate::runtime::kernels::hardware_threads()
        } else {
            core_budget
        };
        ModelRegistry { spec, core_budget: budget, variants: Mutex::new(BTreeMap::new()) }
    }

    /// The core budget replicas partition (see
    /// [`ModelRegistry::with_core_budget`]).
    pub fn core_budget(&self) -> usize {
        self.core_budget
    }

    /// Names of the variants currently loaded.
    pub fn variants(&self) -> Vec<String> {
        self.variants.lock().unwrap().keys().cloned().collect()
    }

    /// Total replicas across all loaded variants.
    pub fn total_replicas(&self) -> usize {
        self.variants.lock().unwrap().values().map(|e| e.replicas).sum()
    }

    /// Load `variant` (a manifest family name, e.g. `"cnn_small_q3"`) and
    /// start its replica set. Hot: other variants keep serving throughout.
    /// Manifest/params/architecture problems surface here, synchronously;
    /// loading a name twice is an error (drain it first).
    pub fn load(&self, variant: &str, opts: &VariantOptions) -> Result<()> {
        if self.variants.lock().unwrap().contains_key(variant) {
            bail!("variant {variant:?} is already loaded (drain_and_unload it first)");
        }
        // Resolve geometry and parameters on the caller thread so load
        // errors surface synchronously, not on replica stderr.
        let (image_len, classes, params, art) = match &opts.artifact {
            Some(path) => {
                // Artifact path: one verified load on the caller thread;
                // the Arc'd arena becomes the panel working set every
                // replica shares. Refusals here are typed and loud —
                // there is deliberately no manifest fallback.
                ensure!(
                    self.spec.kind == BackendKind::Native,
                    "artifact serving requires the native backend"
                );
                ensure!(
                    opts.checkpoint.is_empty(),
                    "VariantOptions::artifact and ::checkpoint are mutually exclusive \
                     (the artifact froze its checkpoint at pack time)"
                );
                let art = Arc::new(LoadedArtifact::load(path)?);
                if art.family() != variant {
                    return Err(ArtifactError::FamilyMismatch {
                        want: variant.to_string(),
                        got: art.family().to_string(),
                    }
                    .into());
                }
                // Dry-run bind: catches unsupported architectures and
                // inconsistent artifact records synchronously. Fused —
                // validation without materializing a second panel set.
                NativeModel::build_from_artifact(&art, UnpackMode::Fused)?;
                (art.image_len(), art.num_classes(), Vec::new(), Some(art))
            }
            None => {
                let manifest = Manifest::load(&self.spec.artifacts_dir)?;
                let image_len = manifest.image * manifest.image * manifest.channels;
                let classes = manifest.family(variant)?.num_classes;
                let params: Vec<Tensor> = if opts.checkpoint.is_empty() {
                    manifest.load_initial_params(variant)?
                } else {
                    crate::train::TrainState::load(&manifest, Path::new(&opts.checkpoint))?.params
                };
                match self.spec.kind {
                    BackendKind::Native => {
                        // Dry-run bind: catches unsupported architectures and
                        // missing/mis-shaped parameters synchronously. Always
                        // fused here — panelizing twice would double peak startup
                        // memory for no extra validation.
                        NativeModel::build_with_mode(
                            &manifest,
                            variant,
                            &params,
                            UnpackMode::Fused,
                        )?;
                    }
                    BackendKind::Xla => {
                        self.spec.check_available()?;
                        manifest.find("infer", variant, None, None)?;
                    }
                }
                (image_len, classes, params, None)
            }
        };

        let replicas = opts.replicas.max(1);
        let queue_depth = opts.queue_depth.max(1);
        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(queue_depth);
        let shared = Arc::new(VariantShared {
            variant: variant.to_string(),
            intake: RwLock::new(Some(tx)),
            stats: Mutex::new(ServeStats::default()),
            accepted: AtomicU64::new(0),
            health: AtomicBool::new(true),
            live: AtomicUsize::new(0),
            image_len,
            queue_depth,
        });

        // Phase 1 — reserve the name under the map lock, briefly. The
        // duplicate check re-runs under the same lock as the insert, so
        // two concurrent loads of one name cannot both win (the early
        // check above is just a fast fail before the expensive bind). The
        // entry goes in *before* any replica is spawned so the lock is
        // never held across thread creation: `session()` / `stats()` /
        // `all_stats()` on other variants — the controller's mid-shift
        // scrapes — keep working throughout a hot load. Sessions taken
        // against the placeholder are fully functional: they queue into
        // the live intake and are served once the replicas come up.
        let intra_threads = {
            let mut map = self.variants.lock().unwrap();
            if map.contains_key(variant) {
                bail!("variant {variant:?} is already loaded (drain_and_unload it first)");
            }
            let total_replicas: usize =
                map.values().map(|e| e.replicas).sum::<usize>() + replicas;
            map.insert(
                variant.to_string(),
                VariantEntry {
                    shared: Arc::clone(&shared),
                    supervisor: Vec::new(),
                    replicas,
                },
            );
            // Partition the core budget across every replica in the
            // process: the ones already serving plus the ones this load
            // adds.
            if opts.intra_threads == 0 {
                (self.core_budget / total_replicas).max(1)
            } else {
                opts.intra_threads
            }
        };

        // Everything a replica (initial, respawned, or teardown drainer)
        // needs, behind one Arc — replicas share one immutable parameter
        // set (the old per-replica `params.clone()` duplicated every
        // tensor), and the supervisor keeps the queue receiver alive
        // across replica deaths so buffered requests survive a crash.
        let ctx = Arc::new(ReplicaCtx {
            spec: self.spec.clone(),
            params: Arc::new(params),
            prep: PrepareOptions {
                intra_op_threads: intra_threads,
                low_memory: opts.low_memory,
                artifact: art,
            },
            rx: Arc::new(Mutex::new(rx)),
            shared: Arc::clone(&shared),
            max_wait: opts.max_wait,
            classes,
            fault: opts.fault.clone(),
        });

        // Phase 2 — spawn the replica set and its supervisor with no
        // lock held.
        let mut handles = Vec::with_capacity(replicas);
        let mut spawn_err: Option<std::io::Error> = None;
        for rid in 0..replicas {
            match spawn_replica(&ctx, rid) {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    spawn_err = Some(e);
                    break;
                }
            }
        }
        let supervisor = if spawn_err.is_none() {
            match spawn_supervisor(Arc::clone(&ctx), opts.restarts.clone(), handles) {
                Ok(h) => {
                    handles = Vec::new();
                    Some(h)
                }
                Err(e) => {
                    // `handles` was moved into the failed spawn's closure
                    // and dropped with it: the replicas are detached but
                    // exit on their own once the intake below closes.
                    handles = Vec::new();
                    spawn_err = Some(e);
                    None
                }
            }
        } else {
            None
        };

        // Phase 3 — re-take the lock to attach the supervisor (or roll
        // back). `Arc::ptr_eq` distinguishes *our* placeholder from a
        // same-named entry re-loaded after a concurrent drain removed
        // ours mid-spawn.
        if let Some(e) = spawn_err {
            // A mid-load spawn failure must not leak the replicas already
            // running: remove the placeholder (if still ours), disconnect
            // the intake and join what was spawned before surfacing.
            {
                let mut map = self.variants.lock().unwrap();
                let ours =
                    map.get(variant).map_or(false, |en| Arc::ptr_eq(&en.shared, &shared));
                if ours {
                    map.remove(variant);
                }
            }
            *shared.intake.write().unwrap() = None;
            for h in handles {
                let _ = h.join();
            }
            return Err(e.into());
        }
        let supervisor = supervisor.expect("supervisor spawned on the success path");
        {
            let mut map = self.variants.lock().unwrap();
            if let Some(entry) = map.get_mut(variant) {
                if Arc::ptr_eq(&entry.shared, &shared) {
                    entry.supervisor = vec![supervisor];
                    return Ok(());
                }
            }
        }
        // A concurrent drain_and_unload raced this load and removed the
        // placeholder (joining its then-empty supervisor list). Finish the
        // retirement it started: close the intake, join the supervisor —
        // its replicas still drain and answer anything accepted in the
        // window — and report the load as failed.
        *shared.intake.write().unwrap() = None;
        let _ = supervisor.join();
        bail!("variant {variant:?} was unloaded while its replicas were starting");
    }

    /// A submit handle for `variant`. Cheap; sessions are cloneable and
    /// usable from any thread, and stay valid (returning
    /// [`ServeError::Closed`]) after the variant is drained.
    pub fn session(&self, variant: &str) -> Result<Session, ServeError> {
        self.variants
            .lock()
            .unwrap()
            .get(variant)
            .map(|e| Session { shared: e.shared.clone() })
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))
    }

    /// Snapshot of one variant's metrics.
    pub fn stats(&self, variant: &str) -> Result<ServeStats, ServeError> {
        self.variants
            .lock()
            .unwrap()
            .get(variant)
            .map(|e| e.shared.stats.lock().unwrap().clone())
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))
    }

    /// Configured replica count for one variant (the supervisor's
    /// respawn target; [`ModelRegistry::live_replicas`] is how many are
    /// running right now).
    pub fn replicas(&self, variant: &str) -> Result<usize, ServeError> {
        self.variants
            .lock()
            .unwrap()
            .get(variant)
            .map(|e| e.replicas)
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))
    }

    /// Replica worker threads currently running their exec loop. Under
    /// supervision this dips on a crash and recovers after the backoff;
    /// the chaos tests assert it converges back to
    /// [`ModelRegistry::replicas`].
    pub fn live_replicas(&self, variant: &str) -> Result<usize, ServeError> {
        self.variants
            .lock()
            .unwrap()
            .get(variant)
            .map(|e| e.shared.live.load(Ordering::SeqCst))
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))
    }

    /// Supervisor health verdict for one variant: `false` once the
    /// restart budget is exhausted or every replica is dead with nothing
    /// left to respawn. This is the liveness signal the tier controller
    /// fails over on (a drained/unknown variant is reported via `Err`,
    /// which callers should treat as unhealthy too).
    pub fn healthy(&self, variant: &str) -> Result<bool, ServeError> {
        self.variants
            .lock()
            .unwrap()
            .get(variant)
            .map(|e| e.shared.health.load(Ordering::SeqCst))
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))
    }

    /// One variant's live queue-depth gauge: requests accepted but not
    /// yet answered (see [`Session::in_flight`]).
    pub fn in_flight(&self, variant: &str) -> Result<usize, ServeError> {
        self.variants
            .lock()
            .unwrap()
            .get(variant)
            .map(|e| {
                let accepted = e.shared.accepted.load(Ordering::Relaxed);
                let answered = e.shared.stats.lock().unwrap().answered();
                accepted.saturating_sub(answered) as usize
            })
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))
    }

    /// Snapshot of every loaded variant's metrics.
    pub fn all_stats(&self) -> BTreeMap<String, ServeStats> {
        self.variants
            .lock()
            .unwrap()
            .iter()
            .map(|(k, e)| (k.clone(), e.shared.stats.lock().unwrap().clone()))
            .collect()
    }

    /// Close `variant`'s intake without waiting for its replicas: further
    /// submits observe [`ServeError::Closed`]; already-accepted requests
    /// are still dispatched and answered, after which the replicas (and
    /// their supervisor) exit. The variant stays registered (for stats)
    /// until [`ModelRegistry::drain_and_unload`].
    pub fn close_intake(&self, variant: &str) -> Result<(), ServeError> {
        let map = self.variants.lock().unwrap();
        let entry = map
            .get(variant)
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))?;
        *entry.shared.intake.write().unwrap() = None;
        Ok(())
    }

    /// Hot-unload `variant`: close its intake, wait for its replicas to
    /// answer every request accepted before the close, join them, and
    /// return the variant's final stats. Other variants keep serving
    /// throughout — this is how a precision tier is swapped under live
    /// traffic (load the replacement first, then drain the old tier).
    ///
    /// Teardown composes with the supervisor: closing the intake stops
    /// any scheduled respawn (a drain never races one), and joining the
    /// supervisor joins the whole replica set — including a teardown
    /// drainer it spawns if workers died with requests still queued, so
    /// "accepted ⇒ answered exactly once" holds even mid-crash.
    ///
    /// One narrow race softens the "replicas joined on return" part:
    /// draining a variant whose [`ModelRegistry::load`] is still
    /// mid-spawn joins only the supervisor attached so far; the loader
    /// detects the removal, finishes the retirement (its replicas still
    /// answer everything accepted, exactly once) and fails the load.
    pub fn drain_and_unload(&self, variant: &str) -> Result<ServeStats, ServeError> {
        let entry = self
            .variants
            .lock()
            .unwrap()
            .remove(variant)
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))?;
        // Dropping the only sender disconnects the queue: replicas drain
        // the buffered requests (std mpsc delivers them before reporting
        // Disconnected), answer each exactly once, and exit. The map lock
        // is released before joining so sessions/loads on other variants
        // never block on a drain.
        *entry.shared.intake.write().unwrap() = None;
        for h in entry.supervisor {
            let _ = h.join();
        }
        let stats = entry.shared.stats.lock().unwrap().clone();
        Ok(stats)
    }

    /// Drain and unload every variant, returning the final per-variant
    /// stats.
    pub fn shutdown(self) -> BTreeMap<String, ServeStats> {
        let names = self.variants();
        let mut all = BTreeMap::new();
        for name in names {
            if let Ok(stats) = self.drain_and_unload(&name) {
                all.insert(name, stats);
            }
        }
        all
    }
}

impl Drop for ModelRegistry {
    /// Dropping the registry without [`ModelRegistry::shutdown`] (early
    /// error paths, panics) must not leak replica threads: each replica
    /// holds its own `Arc` context, so only closing every intake
    /// disconnects the queues and lets the replicas drain and exit. The
    /// supervisors are joined too — they terminate promptly after the
    /// disconnect (bounded by the batch in flight, never by `max_wait`).
    fn drop(&mut self) {
        // Poison-tolerant: this also runs while unwinding from a panic,
        // and a second panic here would abort the process.
        let entries: Vec<VariantEntry> = {
            let mut map = match self.variants.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            std::mem::take(&mut *map).into_values().collect()
        };
        for entry in &entries {
            let mut intake = match entry.shared.intake.write() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            *intake = None;
        }
        for entry in entries {
            for h in entry.supervisor {
                let _ = h.join();
            }
        }
    }
}

/// Everything a replica worker needs, shared with its supervisor so a
/// respawn is just "spawn another thread over the same context". Keeping
/// the queue `Receiver` here (not in a replica closure) is what lets
/// buffered requests survive every worker dying at once.
struct ReplicaCtx {
    spec: BackendSpec,
    params: Arc<Vec<Tensor>>,
    prep: PrepareOptions,
    rx: Arc<Mutex<Receiver<Request>>>,
    shared: Arc<VariantShared>,
    max_wait: Duration,
    classes: usize,
    fault: Option<Arc<FaultPlan>>,
}

/// How a replica worker thread ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ReplicaExit {
    /// Queue disconnected after a drain: normal retirement.
    Clean,
    /// Engine error or panic: supervisor may respawn.
    Failed,
}

/// FNV-1a, for mixing the variant name into the jitter seed.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

fn lock_stats<'a>(shared: &'a VariantShared) -> std::sync::MutexGuard<'a, ServeStats> {
    // Poison-tolerant: stats must survive a replica panicking elsewhere
    // (the counters are plain integers — always consistent).
    shared.stats.lock().unwrap_or_else(|p| p.into_inner())
}

/// Spawn one replica worker thread over `ctx`. The worker maintains
/// `VariantShared::live`, converts engine errors *and panics* into a
/// [`ReplicaExit::Failed`] verdict (landing in
/// [`ServeStats::replica_failures`]) and never unwinds past the closure,
/// so the supervisor can always reap it.
fn spawn_replica(
    ctx: &Arc<ReplicaCtx>,
    rid: usize,
) -> std::io::Result<std::thread::JoinHandle<ReplicaExit>> {
    let ctx = Arc::clone(ctx);
    std::thread::Builder::new().name(format!("lsq-serve-{}-{rid}", ctx.shared.variant)).spawn(
        move || {
            ctx.shared.live.fetch_add(1, Ordering::SeqCst);
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| replica_loop(&ctx)));
            ctx.shared.live.fetch_sub(1, Ordering::SeqCst);
            match result {
                Ok(Ok(())) => ReplicaExit::Clean,
                Ok(Err(e)) => {
                    eprintln!("serve replica {}/{rid}: {e:#}", ctx.shared.variant);
                    lock_stats(&ctx.shared).replica_failures += 1;
                    ReplicaExit::Failed
                }
                Err(_) => {
                    eprintln!("serve replica {}/{rid}: worker panicked", ctx.shared.variant);
                    lock_stats(&ctx.shared).replica_failures += 1;
                    ReplicaExit::Failed
                }
            }
        },
    )
}

/// Start the variant's supervisor thread, handing it the initial replica
/// handles to own.
fn spawn_supervisor(
    ctx: Arc<ReplicaCtx>,
    policy: RestartPolicy,
    handles: Vec<std::thread::JoinHandle<ReplicaExit>>,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("lsq-serve-sup-{}", ctx.shared.variant))
        .spawn(move || supervise(&ctx, &policy, handles))
}

/// The supervision loop: reap dead workers, schedule respawns under the
/// [`RestartPolicy`], flip health on give-up, and honor the drain
/// contract (never respawn into a teardown; answer every accepted
/// request exactly once before returning).
fn supervise(
    ctx: &Arc<ReplicaCtx>,
    policy: &RestartPolicy,
    mut handles: Vec<std::thread::JoinHandle<ReplicaExit>>,
) {
    const POLL: Duration = Duration::from_millis(5);
    // Jitter stream: policy seed × variant name, so sibling variants
    // under one policy desynchronize their crash-loop backoffs.
    let mut rng =
        Pcg32::new(policy.jitter_seed ^ fnv1a(ctx.shared.variant.as_bytes()), 0x7375_7065_7276);
    // Restart timestamps inside the rolling budget window.
    let mut window: Vec<Instant> = Vec::new();
    // Scheduled respawn times (one entry per pending respawn).
    let mut due: Vec<Instant> = Vec::new();
    let mut exhausted = false;
    let mut next_rid = handles.len();
    // Teardown drainers spawned (bounded — see the draining arm).
    let mut drainers = 0usize;
    loop {
        let draining = {
            let guard = match ctx.shared.intake.read() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            guard.is_none()
        };

        // Reap finished workers. A `Failed` exit earns a scheduled
        // respawn while budget remains; hitting `budget` restarts within
        // the rolling window flips the variant unhealthy instead.
        let mut k = 0;
        while k < handles.len() {
            if !handles[k].is_finished() {
                k += 1;
                continue;
            }
            let exit = handles.swap_remove(k).join().unwrap_or(ReplicaExit::Failed);
            if exit == ReplicaExit::Failed && !draining && policy.budget > 0 && !exhausted {
                let now = Instant::now();
                window.retain(|t| now.duration_since(*t) < policy.window);
                if window.len() as u32 >= policy.budget {
                    exhausted = true;
                    due.clear();
                    ctx.shared.health.store(false, Ordering::SeqCst);
                } else {
                    window.push(now);
                    let n = window.len().min(16) as u32;
                    let backoff =
                        (policy.backoff * (1u32 << (n - 1))).min(policy.backoff_cap);
                    due.push(now + backoff.mul_f64(1.0 + 0.25 * rng.uniform() as f64));
                }
            }
        }

        if draining {
            // Teardown: never race a respawn against a drain.
            due.clear();
            if handles.is_empty() {
                let accepted = ctx.shared.accepted.load(Ordering::SeqCst);
                let answered = lock_stats(&ctx.shared).answered();
                if accepted <= answered {
                    return;
                }
                // Workers died with accepted requests still queued. Spawn
                // a short-lived drainer replica to answer them for real
                // (not counted as a restart — it is teardown, not
                // recovery); if drainers themselves keep failing (engine
                // can't open at all), answer what's buffered with
                // `ShutDown` so no client waits forever.
                if drainers < 2 {
                    match spawn_replica(ctx, next_rid) {
                        Ok(h) => {
                            next_rid += 1;
                            drainers += 1;
                            handles.push(h);
                        }
                        Err(_) => {
                            flush_queue(ctx);
                            return;
                        }
                    }
                } else {
                    flush_queue(ctx);
                    return;
                }
            }
        } else {
            // Respawn everything that has come due; a thread-spawn
            // failure (fd/thread exhaustion) retries next tick.
            let now = Instant::now();
            let mut j = 0;
            while j < due.len() {
                if due[j] > now {
                    j += 1;
                    continue;
                }
                match spawn_replica(ctx, next_rid) {
                    Ok(h) => {
                        due.swap_remove(j);
                        next_rid += 1;
                        handles.push(h);
                        lock_stats(&ctx.shared).replica_restarts += 1;
                    }
                    Err(_) => {
                        due[j] = now + POLL;
                        j += 1;
                    }
                }
            }
            if handles.is_empty() && due.is_empty() {
                // Every worker is dead and nothing is scheduled (budget
                // disabled or exhausted): the variant cannot serve. Flip
                // health, stop accepting, and answer what's already
                // queued so nothing black-holes; the next iteration takes
                // the draining arm and retires the supervisor.
                ctx.shared.health.store(false, Ordering::SeqCst);
                let mut intake = match ctx.shared.intake.write() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                *intake = None;
                drop(intake);
                flush_queue(ctx);
            }
        }
        std::thread::sleep(POLL);
    }
}

/// Answer every request still buffered in the variant's queue with
/// [`ServeError::ShutDown`] (terminal teardown path: no replica can run).
fn flush_queue(ctx: &ReplicaCtx) {
    let rx = ctx.rx.lock().unwrap_or_else(|p| p.into_inner());
    let mut n = 0u64;
    while let Ok(req) = rx.try_recv() {
        let _ = req.reply.send(Err(ServeError::ShutDown));
        n += 1;
    }
    if n > 0 {
        lock_stats(&ctx.shared).failed_requests += n;
    }
}

/// NaN-safe argmax over one row of logits. `f32::total_cmp` is a total
/// order, so a NaN logit (corrupt checkpoint, overflowing fp32 head) can
/// never panic the replica thread the way `partial_cmp(..).unwrap()`
/// did; NaNs and ties resolve deterministically (positive NaN sorts
/// above +inf, last maximum wins).
fn argmax_logits(lg: &[f32]) -> usize {
    lg.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(i, _)| i).unwrap_or(0)
}

/// Answer an assembled-but-unexecuted batch with a terminal error (engine
/// failure or an injected panic): part of the "accepted ⇒ answered
/// exactly once" ledger, counted in [`ServeStats::failed_requests`].
fn fail_pending(pending: &mut Vec<Request>, shared: &VariantShared) {
    let n = pending.len() as u64;
    for req in pending.drain(..) {
        let _ = req.reply.send(Err(ServeError::ShutDown));
    }
    if n > 0 {
        lock_stats(shared).failed_requests += n;
    }
}

/// One replica: open an engine, bind the variant with the deployment's
/// [`PrepareOptions`], then batch-and-execute until the variant's queue
/// disconnects (drain/unload/shutdown). Expired-deadline requests are
/// shed at dequeue ([`ServeError::DeadlineExceeded`]) before any compute
/// is spent on them; the optional [`FaultPlan`] hooks fire here (engine
/// open, per-batch panic/slow-exec).
fn replica_loop(ctx: &ReplicaCtx) -> Result<()> {
    let shared = &*ctx.shared;
    if let Some(f) = &ctx.fault {
        if f.replica_open_fail() {
            bail!("fault injection: forced engine-open failure");
        }
    }
    // Artifact replicas skip `spec.open()` entirely: a pure-artifact
    // deployment has no `manifest.json` on disk, and the engine borrows
    // the variant-wide shared arena instead of re-reading anything.
    let mut backend: Box<dyn Backend> = match &ctx.prep.artifact {
        Some(art) => Box::new(NativeEngine::from_artifact(Arc::clone(art))),
        None => ctx.spec.open()?,
    };
    backend.prepare_infer(&shared.variant, &ctx.params, &ctx.prep)?;
    let batch = backend.batch();
    let mut pending: Vec<Request> = Vec::with_capacity(batch);

    loop {
        // Collect a batch while holding the queue; execution happens after
        // the lock is released so replicas overlap on the forward pass.
        {
            // Poison-tolerant: a sibling panicking mid-`recv` leaves the
            // receiver itself fine, and giving up here would turn one
            // crash into whole-variant death.
            let rx = ctx.rx.lock().unwrap_or_else(|p| p.into_inner());
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => continue,
                // Intake dropped and queue fully drained: we're done.
                Err(RecvTimeoutError::Disconnected) => return Ok(()),
            }
            let deadline = Instant::now() + ctx.max_wait;
            while pending.len() < batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                // Wait in short slices so an intake close mid-collection
                // dispatches what we have instead of sitting out max_wait.
                match rx.recv_timeout(left.min(Duration::from_millis(20))) {
                    Ok(r) => pending.push(r),
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }

        // Deadline shed at dequeue: a request whose budget already
        // expired is answered `DeadlineExceeded` without burning a
        // forward pass on it — under overload this is what keeps replicas
        // working on answers someone is still waiting for.
        let now = Instant::now();
        let mut expired = 0u64;
        pending.retain(|req| {
            let dead = req.expires.map_or(false, |t| now >= t);
            if dead {
                let _ = req.reply.send(Err(ServeError::DeadlineExceeded));
                expired += 1;
            }
            !dead
        });
        if expired > 0 {
            lock_stats(shared).deadline_expired += expired;
        }
        if pending.is_empty() {
            continue;
        }

        // Fault hooks fire per dispatched batch (a stable occurrence
        // index — idle poll loops don't advance it, so the schedule is a
        // pure function of the batch sequence). An injected panic answers
        // its batch *first*: the thread dies, the requests do not.
        if let Some(f) = &ctx.fault {
            match f.replica_exec() {
                ReplicaFault::None => {}
                ReplicaFault::Slow(d) => std::thread::sleep(d),
                ReplicaFault::Panic => {
                    fail_pending(&mut pending, shared);
                    panic!("fault injection: replica panic");
                }
            }
        }

        // Assemble the batch; pad the tail only for fixed-shape backends
        // (the native backend runs exactly `real` rows).
        let real = pending.len();
        let rows = if backend.fixed_batch() { batch } else { real };
        let mut x = vec![0.0f32; rows * shared.image_len];
        for (row, req) in pending.iter().enumerate() {
            x[row * shared.image_len..(row + 1) * shared.image_len]
                .copy_from_slice(&req.image);
        }

        let t_exec = Instant::now();
        // Queue time is measured to the moment execution starts, so
        // `mean_queue_ms` isolates batching/queueing from compute.
        let queue_ms: f64 = pending
            .iter()
            .map(|r| t_exec.duration_since(r.submitted).as_secs_f64() * 1e3)
            .sum();
        let logits = match backend.infer(&x) {
            Ok(lg) => lg,
            Err(e) => {
                // The engine failed mid-batch: the thread is about to
                // exit, but its batch must still be answered (exactly
                // once), not silently dropped with the reply channels.
                fail_pending(&mut pending, shared);
                return Err(e);
            }
        };
        let exec_ms = t_exec.elapsed().as_secs_f64() * 1e3;

        {
            let mut s = lock_stats(shared);
            s.batches += 1;
            s.requests += real as u64;
            s.rows_dispatched += rows as u64;
            s.padding_rows += (rows - real) as u64;
            s.exec_ms_total += exec_ms;
            s.queue_ms_total += queue_ms;
            // Occupancy stays relative to the target batch size: it
            // measures how full the batcher runs, not the dispatch shape.
            s.occupancy_sum += real as f64 / batch as f64;
        }

        for (row, req) in pending.drain(..).enumerate() {
            let lg = logits[row * ctx.classes..(row + 1) * ctx.classes].to_vec();
            let argmax = argmax_logits(&lg);
            let queue_ms = t_exec.duration_since(req.submitted).as_secs_f64() * 1e3;
            let total_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
            let _ = req.reply.send(Ok(Reply { logits: lg, argmax, queue_ms, total_ms }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bare_shared(queue_depth: usize) -> (Arc<VariantShared>, Receiver<Request>) {
        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(queue_depth);
        let shared = Arc::new(VariantShared {
            variant: "test_q2".to_string(),
            intake: RwLock::new(Some(tx)),
            stats: Mutex::new(ServeStats::default()),
            accepted: AtomicU64::new(0),
            health: AtomicBool::new(true),
            live: AtomicUsize::new(0),
            image_len: 4,
            queue_depth,
        });
        (shared, rx)
    }

    /// The backpressure contract, deterministically: with no consumer
    /// draining the queue, the `queue_depth+1`-th submit surfaces
    /// `QueueFull { depth }` immediately instead of blocking forever (the
    /// old `SyncSender::send` behavior).
    #[test]
    fn submit_surfaces_queue_full_at_depth_instead_of_blocking() {
        let (shared, _rx) = bare_shared(2);
        let session = Session { shared };
        let r1 = session.submit(vec![0.0; 4]);
        let r2 = session.submit(vec![0.0; 4]);
        assert!(r1.is_ok() && r2.is_ok());
        assert_eq!(
            session.submit(vec![0.0; 4]).err(),
            Some(ServeError::QueueFull { depth: 2 })
        );
        // The in-flight gauge counts accepted-but-unanswered only: the
        // rejected third submit must not have moved it.
        assert_eq!(session.in_flight(), 2);
        // Draining one slot re-admits exactly one request (the gauge
        // still counts it — dequeued ≠ answered).
        drop(_rx.recv().unwrap());
        assert!(session.submit(vec![0.0; 4]).is_ok());
        assert_eq!(session.in_flight(), 3);
        assert_eq!(
            session.submit(vec![0.0; 4]).err(),
            Some(ServeError::QueueFull { depth: 2 })
        );
    }

    /// `submit_reclaim` hands the image buffer back on every error path,
    /// so a ladder router retries without cloning.
    #[test]
    fn submit_reclaim_returns_the_image_on_every_error() {
        let (shared, rx) = bare_shared(1);
        let session = Session { shared: shared.clone() };
        // Wrong geometry: reclaimed before the queue is touched.
        let (err, img) = session.submit_reclaim(vec![1.0; 3]).unwrap_err();
        assert_eq!(err, ServeError::BadImage { got: 3, want: 4 });
        assert_eq!(img, vec![1.0; 3]);
        // Full queue: the rejected request's buffer comes back intact.
        assert!(session.submit_reclaim(vec![2.0; 4]).is_ok());
        let (err, img) = session.submit_reclaim(vec![3.0; 4]).unwrap_err();
        assert_eq!(err, ServeError::QueueFull { depth: 1 });
        assert_eq!(img, vec![3.0; 4]);
        // Dead consumer: ShutDown, buffer reclaimed.
        drop(rx);
        let (err, img) = session.submit_reclaim(vec![4.0; 4]).unwrap_err();
        assert_eq!(err, ServeError::ShutDown);
        assert_eq!(img, vec![4.0; 4]);
        // Closed intake: reclaimed before the send.
        *shared.intake.write().unwrap() = None;
        let (err, img) = session.submit_reclaim(vec![5.0; 4]).unwrap_err();
        assert_eq!(err, ServeError::Closed);
        assert_eq!(img, vec![5.0; 4]);
    }

    /// A submitted deadline lands on the queued request as an absolute
    /// expiry; no budget means no expiry.
    #[test]
    fn submit_deadline_stamps_the_request() {
        let (shared, rx) = bare_shared(2);
        let session = Session { shared };
        session.submit_deadline(vec![0.0; 4], Some(Duration::from_millis(40))).unwrap();
        session.submit_deadline(vec![0.0; 4], None).unwrap();
        let with_budget = rx.recv().unwrap();
        let without = rx.recv().unwrap();
        let expires = with_budget.expires.expect("budgeted request carries an expiry");
        let left = expires.saturating_duration_since(Instant::now());
        assert!(left <= Duration::from_millis(40), "expiry ≈ now + budget, got {left:?}");
        assert!(without.expires.is_none());
    }

    /// Regression for the replica-thread panic on NaN logits: argmax must
    /// be a total order, never `partial_cmp(..).unwrap()`.
    #[test]
    fn argmax_is_nan_safe_and_deterministic() {
        assert_eq!(argmax_logits(&[0.0, 3.0, 1.0]), 1);
        // A NaN must not panic; `total_cmp` sorts positive NaN above
        // +inf, so it wins deterministically.
        assert_eq!(argmax_logits(&[0.0, f32::NAN, 1.0]), 1);
        assert_eq!(argmax_logits(&[f32::NEG_INFINITY, f32::INFINITY, -1.0]), 1);
        // All-NaN row: ties resolve to the last index, deterministically.
        assert_eq!(argmax_logits(&[f32::NAN; 3]), 2);
        // Empty row degrades to 0 (the pre-existing contract).
        assert_eq!(argmax_logits(&[]), 0);
    }

    /// Replica death is a surfaced signal, not just an stderr line:
    /// workers whose engine fails to open land in `replica_failures`, the
    /// supervisor (here with respawn disabled) flips the variant
    /// unhealthy and closes its intake, and the drain still completes
    /// cleanly through the registry.
    #[test]
    fn dead_replica_variant_surfaces_failures_and_drains_cleanly() {
        let spec = BackendSpec::native(Path::new("/nonexistent/lsq_dead_replica_fixture"));
        let (shared, rx) = bare_shared(4);
        let ctx = Arc::new(ReplicaCtx {
            spec: spec.clone(),
            params: Arc::new(Vec::new()),
            prep: PrepareOptions::default(),
            rx: Arc::new(Mutex::new(rx)),
            shared: Arc::clone(&shared),
            max_wait: Duration::from_millis(1),
            classes: 4,
            fault: None,
        });
        let handles =
            (0..2).map(|rid| spawn_replica(&ctx, rid).expect("spawn")).collect::<Vec<_>>();
        let sup = spawn_supervisor(Arc::clone(&ctx), RestartPolicy::disabled(), handles)
            .expect("spawn supervisor");
        let registry = ModelRegistry::with_core_budget(spec, 2);
        registry.variants.lock().unwrap().insert(
            "test_q2".to_string(),
            VariantEntry { shared: Arc::clone(&shared), supervisor: vec![sup], replicas: 2 },
        );
        // Both replicas exit on the open error; with respawn disabled the
        // supervisor declares the variant dead: unhealthy, intake closed.
        let t0 = Instant::now();
        while registry.healthy("test_q2").unwrap() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(registry.healthy("test_q2"), Ok(false));
        // The drain must join supervisor + replicas, report the deaths,
        // and leave the registry consistent.
        let stats = registry.drain_and_unload("test_q2").expect("drain");
        assert_eq!(stats.replica_failures, 2);
        assert_eq!(stats.replica_restarts, 0);
        assert_eq!(stats.requests, 0);
        assert_eq!(
            registry.replicas("test_q2").err(),
            Some(ServeError::UnknownModel("test_q2".to_string()))
        );
        // The drained intake turns away new submits with the typed error.
        let session = Session { shared };
        assert_eq!(session.submit(vec![0.0; 4]).err(), Some(ServeError::Closed));
    }

    /// Closed intake and dead consumer produce their own typed errors.
    #[test]
    fn submit_surfaces_closed_and_shutdown() {
        let (shared, rx) = bare_shared(2);
        let session = Session { shared: shared.clone() };
        assert_eq!(
            session.submit(vec![0.0; 3]).err(),
            Some(ServeError::BadImage { got: 3, want: 4 })
        );
        // Receiver gone (all replicas exited): ShutDown.
        drop(rx);
        assert_eq!(session.submit(vec![0.0; 4]).err(), Some(ServeError::ShutDown));
        // Intake taken (close_intake / drain): Closed, checked before send.
        *shared.intake.write().unwrap() = None;
        assert!(!session.is_open());
        assert_eq!(session.submit(vec![0.0; 4]).err(), Some(ServeError::Closed));
    }
}
