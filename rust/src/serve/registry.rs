//! Multi-model serving gateway: one process, many bound model variants.
//!
//! LSQ's deployment story (PAPER.md §1, Figure 3) is one architecture at
//! *several* precisions — 2/3/4/8-bit variants trading accuracy against
//! size and latency. [`ModelRegistry`] makes that a first-class serving
//! surface: each loaded **variant** (a manifest family, e.g.
//! `cnn_small_q2`) owns its own request queue, replica set and
//! [`ServeStats`], all inside one process sharing one core budget.
//! Callers address a variant by name through a [`Session`] handle:
//!
//! ```text
//!  ModelRegistry ──────────────────────────────────────────────┐
//!  │ core budget (default: hardware threads)                   │
//!  │                                                           │
//!  │  "cnn_small_q2" ─ VariantShared ──────────────┐           │
//!  │  │ intake: RwLock<Option<SyncSender>>         │◄── Session("cnn_small_q2")
//!  │  │ stats:  Mutex<ServeStats>                  │◄── Session (any thread)
//!  │  │ queue ─► replica 0 ─► NativeEngine + ws    │           │
//!  │  │       └► replica 1 ─► NativeEngine + ws    │           │
//!  │  └────────────────────────────────────────────┘           │
//!  │  "cnn_small_q4" ─ VariantShared ─► replica …  ◄── Session("cnn_small_q4")
//!  └───────────────────────────────────────────────────────────┘
//! ```
//!
//! Hot load/unload: [`ModelRegistry::load`] binds a new variant under
//! live traffic to the others, and [`ModelRegistry::drain_and_unload`]
//! retires one — the intake sender is the *only* sender for the variant's
//! queue (sessions borrow it under a read lock, never clone it), so
//! dropping it disconnects the queue deterministically: replicas dispatch
//! every request already accepted, answer it, and exit. No in-flight
//! request is dropped, and subsequent submits fail with
//! [`ServeError::Closed`].
//!
//! [`super::Server`] remains as a one-variant compatibility shim over
//! this registry. See DESIGN.md §Serving-API.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::{Backend as _, BackendKind, BackendSpec, Manifest, PrepareOptions};
use crate::tensor::Tensor;

use super::{Reply, Request, ServeError, ServeStats};

/// Per-variant deployment options for [`ModelRegistry::load`].
#[derive(Clone, Debug)]
pub struct VariantOptions {
    /// Checkpoint with trained params (empty = the family's initial params).
    pub checkpoint: String,
    /// Engine replicas (worker threads) for this variant. Clamped to ≥ 1.
    pub replicas: usize,
    /// Dynamic-batching window: maximum time a dispatching worker waits
    /// for stragglers after the first request of a batch arrives.
    pub max_wait: Duration,
    /// Bound on queued requests. A full queue surfaces as
    /// [`ServeError::QueueFull`] on submit — real backpressure for
    /// open-loop clients, never an indefinite block.
    pub queue_depth: usize,
    /// Intra-op kernel threads *per replica*
    /// ([`PrepareOptions::intra_op_threads`]). 0 = auto: this variant's
    /// share of the registry core budget, `budget / total replicas`
    /// counted across every loaded variant at load time.
    pub intra_threads: usize,
    /// Weight-storage choice, forwarded to
    /// [`PrepareOptions::low_memory`]: `Some(true)` = fused low-memory
    /// unpack, `Some(false)` = pin the panelized fast path, `None` = the
    /// process `LSQNET_FUSED_UNPACK` default.
    pub low_memory: Option<bool>,
}

impl Default for VariantOptions {
    fn default() -> Self {
        VariantOptions {
            checkpoint: String::new(),
            replicas: 1,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
            intra_threads: 0,
            low_memory: None,
        }
    }
}

/// State shared between a variant's replicas and its [`Session`] handles.
///
/// The intake sender is deliberately **not** cloneable from the outside:
/// sessions borrow it under the read lock for the duration of one
/// `try_send`, so `drain_and_unload` taking the write lock and dropping it
/// is a linearization point — every submit strictly before it is accepted
/// (and will be answered), every submit after it observes
/// [`ServeError::Closed`].
struct VariantShared {
    variant: String,
    intake: RwLock<Option<SyncSender<Request>>>,
    stats: Mutex<ServeStats>,
    image_len: usize,
    queue_depth: usize,
}

struct VariantEntry {
    shared: Arc<VariantShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    replicas: usize,
}

/// A cloneable, thread-safe handle for submitting requests to one variant
/// of a [`ModelRegistry`].
#[derive(Clone)]
pub struct Session {
    shared: Arc<VariantShared>,
}

impl Session {
    /// The variant name this session addresses.
    pub fn variant(&self) -> &str {
        &self.shared.variant
    }

    /// Blocking single-request inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply, ServeError> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| ServeError::ShutDown)
    }

    /// Non-blocking submit; returns the reply channel. A full queue is
    /// [`ServeError::QueueFull`] (backpressure), a drained/unloaded
    /// variant [`ServeError::Closed`], and a variant whose replicas all
    /// died [`ServeError::ShutDown`].
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Reply>, ServeError> {
        if image.len() != self.shared.image_len {
            return Err(ServeError::BadImage { got: image.len(), want: self.shared.image_len });
        }
        let guard = self.shared.intake.read().unwrap();
        let tx = guard.as_ref().ok_or(ServeError::Closed)?;
        let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(1);
        match tx.try_send(Request { image, submitted: Instant::now(), reply: reply_tx }) {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(_)) => {
                Err(ServeError::QueueFull { depth: self.shared.queue_depth })
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::ShutDown),
        }
    }

    /// Snapshot of this variant's aggregate metrics.
    pub fn stats(&self) -> ServeStats {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Whether the variant's intake is still accepting requests (`false`
    /// after `close_intake`/`drain_and_unload`). Racy by nature — a
    /// concurrent drain can close the intake right after this returns
    /// `true`; [`Session::submit`]'s [`ServeError::Closed`] is the
    /// authoritative answer.
    pub fn is_open(&self) -> bool {
        self.shared.intake.read().unwrap().is_some()
    }
}

/// One server process hosting many bound model variants, each with its own
/// replica set and stats, sharing one core budget. See the module docs for
/// the ownership diagram and DESIGN.md §Serving-API for the rationale.
pub struct ModelRegistry {
    spec: BackendSpec,
    core_budget: usize,
    variants: Mutex<BTreeMap<String, VariantEntry>>,
}

impl ModelRegistry {
    /// A registry opening engines from `spec`, with the core budget set to
    /// the host's hardware thread count.
    pub fn open(spec: BackendSpec) -> ModelRegistry {
        ModelRegistry::with_core_budget(spec, 0)
    }

    /// [`ModelRegistry::open`] with an explicit core budget shared by all
    /// variants (0 = hardware threads). The budget is partitioned across
    /// replicas at [`ModelRegistry::load`] time: a variant loaded with
    /// `intra_threads: 0` gets `budget / total replicas` kernel threads
    /// per replica, counting every replica loaded so far plus its own.
    /// Already-running variants keep their width (re-load one to
    /// rebalance).
    pub fn with_core_budget(spec: BackendSpec, core_budget: usize) -> ModelRegistry {
        let budget = if core_budget == 0 {
            crate::runtime::kernels::hardware_threads()
        } else {
            core_budget
        };
        ModelRegistry { spec, core_budget: budget, variants: Mutex::new(BTreeMap::new()) }
    }

    /// The core budget replicas partition (see
    /// [`ModelRegistry::with_core_budget`]).
    pub fn core_budget(&self) -> usize {
        self.core_budget
    }

    /// Names of the variants currently loaded.
    pub fn variants(&self) -> Vec<String> {
        self.variants.lock().unwrap().keys().cloned().collect()
    }

    /// Total replicas across all loaded variants.
    pub fn total_replicas(&self) -> usize {
        self.variants.lock().unwrap().values().map(|e| e.replicas).sum()
    }

    /// Load `variant` (a manifest family name, e.g. `"cnn_small_q3"`) and
    /// start its replica set. Hot: other variants keep serving throughout.
    /// Manifest/params/architecture problems surface here, synchronously;
    /// loading a name twice is an error (drain it first).
    pub fn load(&self, variant: &str, opts: &VariantOptions) -> Result<()> {
        if self.variants.lock().unwrap().contains_key(variant) {
            bail!("variant {variant:?} is already loaded (drain_and_unload it first)");
        }
        // Resolve geometry and parameters on the caller thread so load
        // errors surface synchronously, not on replica stderr.
        let manifest = Manifest::load(&self.spec.artifacts_dir)?;
        let image_len = manifest.image * manifest.image * manifest.channels;
        let classes = manifest.family(variant)?.num_classes;
        let params: Vec<Tensor> = if opts.checkpoint.is_empty() {
            manifest.load_initial_params(variant)?
        } else {
            crate::train::TrainState::load(&manifest, Path::new(&opts.checkpoint))?.params
        };
        match self.spec.kind {
            BackendKind::Native => {
                // Dry-run bind: catches unsupported architectures and
                // missing/mis-shaped parameters synchronously. Always
                // fused here — panelizing twice would double peak startup
                // memory for no extra validation.
                crate::runtime::native::NativeModel::build_with_mode(
                    &manifest,
                    variant,
                    &params,
                    crate::runtime::native::UnpackMode::Fused,
                )?;
            }
            BackendKind::Xla => {
                self.spec.check_available()?;
                manifest.find("infer", variant, None, None)?;
            }
        }
        drop(manifest);

        let replicas = opts.replicas.max(1);
        let queue_depth = opts.queue_depth.max(1);
        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(queue_depth);
        let shared_rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(VariantShared {
            variant: variant.to_string(),
            intake: RwLock::new(Some(tx)),
            stats: Mutex::new(ServeStats::default()),
            image_len,
            queue_depth,
        });

        // Partition the core budget across every replica in the process:
        // the ones already serving plus the ones this load adds. The
        // duplicate check re-runs under the same lock as the insert, so
        // two concurrent loads of one name cannot both win (the early
        // check above is just a fast fail before the expensive bind).
        let mut map = self.variants.lock().unwrap();
        if map.contains_key(variant) {
            bail!("variant {variant:?} is already loaded (drain_and_unload it first)");
        }
        let total_replicas: usize =
            map.values().map(|e| e.replicas).sum::<usize>() + replicas;
        let intra_threads = if opts.intra_threads == 0 {
            (self.core_budget / total_replicas).max(1)
        } else {
            opts.intra_threads
        };
        let prep = PrepareOptions {
            intra_op_threads: intra_threads,
            low_memory: opts.low_memory,
        };

        let mut handles = Vec::with_capacity(replicas);
        for rid in 0..replicas {
            let spec = self.spec.clone();
            let params = params.clone();
            let prep = prep.clone();
            let shared_rx = shared_rx.clone();
            let shared_worker = shared.clone();
            let max_wait = opts.max_wait;
            let spawned = std::thread::Builder::new()
                .name(format!("lsq-serve-{variant}-{rid}"))
                .spawn(move || {
                    if let Err(e) = replica_loop(
                        &spec,
                        &params,
                        &prep,
                        &shared_rx,
                        &shared_worker,
                        max_wait,
                        classes,
                    ) {
                        eprintln!("serve replica {}/{rid}: {e:#}", shared_worker.variant);
                    }
                });
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // A mid-load spawn failure must not leak the replicas
                    // already running: the entry was never inserted, so no
                    // drain could ever reach this intake. Disconnect it and
                    // join what was spawned before surfacing the error.
                    *shared.intake.write().unwrap() = None;
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e.into());
                }
            }
        }
        map.insert(variant.to_string(), VariantEntry { shared, handles, replicas });
        Ok(())
    }

    /// A submit handle for `variant`. Cheap; sessions are cloneable and
    /// usable from any thread, and stay valid (returning
    /// [`ServeError::Closed`]) after the variant is drained.
    pub fn session(&self, variant: &str) -> Result<Session, ServeError> {
        self.variants
            .lock()
            .unwrap()
            .get(variant)
            .map(|e| Session { shared: e.shared.clone() })
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))
    }

    /// Snapshot of one variant's metrics.
    pub fn stats(&self, variant: &str) -> Result<ServeStats, ServeError> {
        self.variants
            .lock()
            .unwrap()
            .get(variant)
            .map(|e| e.shared.stats.lock().unwrap().clone())
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))
    }

    /// Snapshot of every loaded variant's metrics.
    pub fn all_stats(&self) -> BTreeMap<String, ServeStats> {
        self.variants
            .lock()
            .unwrap()
            .iter()
            .map(|(k, e)| (k.clone(), e.shared.stats.lock().unwrap().clone()))
            .collect()
    }

    /// Close `variant`'s intake without waiting for its replicas: further
    /// submits observe [`ServeError::Closed`]; already-accepted requests
    /// are still dispatched and answered, after which the replicas exit.
    /// The variant stays registered (for stats) until
    /// [`ModelRegistry::drain_and_unload`].
    pub fn close_intake(&self, variant: &str) -> Result<(), ServeError> {
        let map = self.variants.lock().unwrap();
        let entry = map
            .get(variant)
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))?;
        *entry.shared.intake.write().unwrap() = None;
        Ok(())
    }

    /// Hot-unload `variant`: close its intake, wait for its replicas to
    /// answer every request accepted before the close, join them, and
    /// return the variant's final stats. Other variants keep serving
    /// throughout — this is how a precision tier is swapped under live
    /// traffic (load the replacement first, then drain the old tier).
    pub fn drain_and_unload(&self, variant: &str) -> Result<ServeStats, ServeError> {
        let entry = self
            .variants
            .lock()
            .unwrap()
            .remove(variant)
            .ok_or_else(|| ServeError::UnknownModel(variant.to_string()))?;
        // Dropping the only sender disconnects the queue: replicas drain
        // the buffered requests (std mpsc delivers them before reporting
        // Disconnected), answer each exactly once, and exit. The map lock
        // is released before joining so sessions/loads on other variants
        // never block on a drain.
        *entry.shared.intake.write().unwrap() = None;
        for h in entry.handles {
            let _ = h.join();
        }
        let stats = entry.shared.stats.lock().unwrap().clone();
        Ok(stats)
    }

    /// Drain and unload every variant, returning the final per-variant
    /// stats.
    pub fn shutdown(self) -> BTreeMap<String, ServeStats> {
        let names = self.variants();
        let mut all = BTreeMap::new();
        for name in names {
            if let Ok(stats) = self.drain_and_unload(&name) {
                all.insert(name, stats);
            }
        }
        all
    }
}

impl Drop for ModelRegistry {
    /// Dropping the registry without [`ModelRegistry::shutdown`] (early
    /// error paths, panics) must not leak replica threads: each replica
    /// holds its own `Arc<VariantShared>`, so only closing every intake
    /// disconnects the queues and lets the replicas drain and exit. The
    /// threads are joined too — they terminate promptly after the
    /// disconnect (bounded by the batch in flight, never by `max_wait`).
    fn drop(&mut self) {
        // Poison-tolerant: this also runs while unwinding from a panic,
        // and a second panic here would abort the process.
        let entries: Vec<VariantEntry> = {
            let mut map = match self.variants.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            std::mem::take(&mut *map).into_values().collect()
        };
        for entry in &entries {
            let mut intake = match entry.shared.intake.write() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            *intake = None;
        }
        for entry in entries {
            for h in entry.handles {
                let _ = h.join();
            }
        }
    }
}

/// One replica: open an engine, bind the variant with the deployment's
/// [`PrepareOptions`], then batch-and-execute until the variant's queue
/// disconnects (drain/unload/shutdown).
#[allow(clippy::too_many_arguments)]
fn replica_loop(
    spec: &BackendSpec,
    params: &[Tensor],
    prep: &PrepareOptions,
    shared_rx: &Mutex<Receiver<Request>>,
    shared: &VariantShared,
    max_wait: Duration,
    classes: usize,
) -> Result<()> {
    let mut backend = spec.open()?;
    backend.prepare_infer(&shared.variant, params, prep)?;
    let batch = backend.batch();
    let mut pending: Vec<Request> = Vec::with_capacity(batch);

    loop {
        // Collect a batch while holding the queue; execution happens after
        // the lock is released so replicas overlap on the forward pass.
        {
            let rx = match shared_rx.lock() {
                Ok(g) => g,
                Err(_) => return Ok(()), // another replica panicked
            };
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => continue,
                // Intake dropped and queue fully drained: we're done.
                Err(RecvTimeoutError::Disconnected) => return Ok(()),
            }
            let deadline = Instant::now() + max_wait;
            while pending.len() < batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                // Wait in short slices so an intake close mid-collection
                // dispatches what we have instead of sitting out max_wait.
                match rx.recv_timeout(left.min(Duration::from_millis(20))) {
                    Ok(r) => pending.push(r),
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }

        // Assemble the batch; pad the tail only for fixed-shape backends
        // (the native backend runs exactly `real` rows).
        let real = pending.len();
        let rows = if backend.fixed_batch() { batch } else { real };
        let mut x = vec![0.0f32; rows * shared.image_len];
        for (row, req) in pending.iter().enumerate() {
            x[row * shared.image_len..(row + 1) * shared.image_len]
                .copy_from_slice(&req.image);
        }

        let t_exec = Instant::now();
        // Queue time is measured to the moment execution starts, so
        // `mean_queue_ms` isolates batching/queueing from compute.
        let queue_ms: f64 = pending
            .iter()
            .map(|r| t_exec.duration_since(r.submitted).as_secs_f64() * 1e3)
            .sum();
        let logits = backend.infer(&x)?;
        let exec_ms = t_exec.elapsed().as_secs_f64() * 1e3;

        {
            let mut s = shared.stats.lock().unwrap();
            s.batches += 1;
            s.requests += real as u64;
            s.rows_dispatched += rows as u64;
            s.padding_rows += (rows - real) as u64;
            s.exec_ms_total += exec_ms;
            s.queue_ms_total += queue_ms;
            // Occupancy stays relative to the target batch size: it
            // measures how full the batcher runs, not the dispatch shape.
            s.occupancy_sum += real as f64 / batch as f64;
        }

        for (row, req) in pending.drain(..).enumerate() {
            let lg = logits[row * classes..(row + 1) * classes].to_vec();
            let argmax = lg
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            let queue_ms = t_exec.duration_since(req.submitted).as_secs_f64() * 1e3;
            let total_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
            let _ = req.reply.send(Reply { logits: lg, argmax, queue_ms, total_ms });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bare_shared(queue_depth: usize) -> (Arc<VariantShared>, Receiver<Request>) {
        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(queue_depth);
        let shared = Arc::new(VariantShared {
            variant: "test_q2".to_string(),
            intake: RwLock::new(Some(tx)),
            stats: Mutex::new(ServeStats::default()),
            image_len: 4,
            queue_depth,
        });
        (shared, rx)
    }

    /// The backpressure contract, deterministically: with no consumer
    /// draining the queue, the `queue_depth+1`-th submit surfaces
    /// `QueueFull { depth }` immediately instead of blocking forever (the
    /// old `SyncSender::send` behavior).
    #[test]
    fn submit_surfaces_queue_full_at_depth_instead_of_blocking() {
        let (shared, _rx) = bare_shared(2);
        let session = Session { shared };
        let r1 = session.submit(vec![0.0; 4]);
        let r2 = session.submit(vec![0.0; 4]);
        assert!(r1.is_ok() && r2.is_ok());
        assert_eq!(
            session.submit(vec![0.0; 4]).err(),
            Some(ServeError::QueueFull { depth: 2 })
        );
        // Draining one slot re-admits exactly one request.
        drop(_rx.recv().unwrap());
        assert!(session.submit(vec![0.0; 4]).is_ok());
        assert_eq!(
            session.submit(vec![0.0; 4]).err(),
            Some(ServeError::QueueFull { depth: 2 })
        );
    }

    /// Closed intake and dead consumer produce their own typed errors.
    #[test]
    fn submit_surfaces_closed_and_shutdown() {
        let (shared, rx) = bare_shared(2);
        let session = Session { shared: shared.clone() };
        assert_eq!(
            session.submit(vec![0.0; 3]).err(),
            Some(ServeError::BadImage { got: 3, want: 4 })
        );
        // Receiver gone (all replicas exited): ShutDown.
        drop(rx);
        assert_eq!(session.submit(vec![0.0; 4]).err(), Some(ServeError::ShutDown));
        // Intake taken (close_intake / drain): Closed, checked before send.
        *shared.intake.write().unwrap() = None;
        assert!(!session.is_open());
        assert_eq!(session.submit(vec![0.0; 4]).err(), Some(ServeError::Closed));
    }
}
