//! SLO-driven adaptive precision tiering: the closed loop over the
//! registry (DESIGN.md §Serving-API).
//!
//! LSQ's premise is one architecture at several accuracy/latency/size
//! operating points (PAPER.md §1, Figure 3); [`super::ModelRegistry`]
//! hosts those variants and [`super::net`] serves them — but with a fixed
//! model name per request, traffic stays pinned to whatever tier the
//! operator picked. [`TierController`] closes the loop:
//!
//! * **sense** — every epoch it snapshots per-variant [`ServeStats`] and
//!   pushes them through a rolling [`StatsWindow`], so the
//!   `mean_queue_ms` / queue depth / occupancy it reasons about describe
//!   *recent* load, not lifetime averages that a long quiet morning
//!   would dilute;
//! * **decide** — the active tier's windowed queue time is compared
//!   against the latency SLO with **hysteresis**: a breach must persist
//!   for `breach_epochs` before the controller shifts down the ladder
//!   (cheaper precision, more headroom), and recovery must hold below
//!   `recover_frac · slo_ms` for `recover_epochs` before it shifts back
//!   up. The dead band between the two thresholds resets both dwell
//!   counters, so a signal hovering near the SLO can never flap the
//!   ladder. Replica health — the supervisor's verdict,
//!   [`super::ModelRegistry::healthy`]: restart budget exhausted or every
//!   replica dead — preempts hysteresis: a dead tier is failed over
//!   immediately;
//! * **act** — [`TierController::route`] submits to the active tier and
//!   spills down the ladder on per-queue backpressure. Once every tier at
//!   or below the active one is saturated, the request is **shed**
//!   ([`ServeError::Shed`]) instead of queued into a latency death
//!   spiral: callers get an explicit back-off signal, and every request
//!   that *was* accepted is still answered exactly once (the registry's
//!   drain guarantee is untouched).
//!
//! Decisions are pure: [`TierController::step_with`] consumes explicit
//! [`TierSignal`]s, so tests drive deterministic synthetic schedules and
//! assert exact transition sequences; [`TierController::step`] is the
//! production path (`step_with(sample())`), and [`TierDriver`] runs it on
//! the configured epoch. Every transition lands in an auditable
//! [`TierEvent`] trace that [`trace_to_bench`] turns into
//! `BENCH_serve.json` rows (EXPERIMENTS.md §Perf L3).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{bail, ensure, Result};

use crate::util::bench::Bench;

use super::{ModelRegistry, Reply, ServeError, Session, StatsWindow};

/// Configuration for a [`TierController`].
#[derive(Clone, Debug)]
pub struct TierConfig {
    /// The precision ladder, **most expensive first** (e.g.
    /// `["cnn_small_q8", "cnn_small_q4", "cnn_small_q2"]`). Index 0 is
    /// where traffic starts and returns when there is headroom; higher
    /// indices are the cheaper tiers load shifts down to.
    pub tiers: Vec<String>,
    /// The latency SLO: sustained windowed `mean_queue_ms` above this on
    /// the active tier is a breach.
    pub slo_ms: f64,
    /// Recovery threshold as a fraction of `slo_ms` (strictly below 1 so
    /// the dead band between recovery and breach exists — that band *is*
    /// the hysteresis).
    pub recover_frac: f64,
    /// Consecutive breached epochs required before shifting down.
    pub breach_epochs: u32,
    /// Consecutive recovered epochs required before shifting back up.
    /// Typically > `breach_epochs`: shedding accuracy under pressure
    /// should be faster than re-spending latency headroom.
    pub recover_epochs: u32,
    /// [`StatsWindow`] span, in epochs, for the sensed signals.
    pub window: usize,
    /// Sampling period for [`TierDriver`] (how often `step()` runs).
    pub epoch: Duration,
}

impl TierConfig {
    /// A config with the default hysteresis profile: recover at half the
    /// SLO, shift down after 2 breached epochs, back up after 3 clear
    /// ones, sensing over a 4-epoch window at a 50 ms epoch.
    pub fn new(tiers: Vec<String>, slo_ms: f64) -> TierConfig {
        TierConfig {
            tiers,
            slo_ms,
            recover_frac: 0.5,
            breach_epochs: 2,
            recover_epochs: 3,
            window: 4,
            epoch: Duration::from_millis(50),
        }
    }
}

/// One tier's sensed state for one decision epoch. [`TierController::sample`]
/// builds these from windowed registry stats; tests inject synthetic ones
/// through [`TierController::step_with`].
#[derive(Clone, Debug, PartialEq)]
pub struct TierSignal {
    /// Windowed mean queue+batching time (submit → execution start).
    pub queue_ms: f64,
    /// Requests accepted but not yet answered (queued + executing).
    pub depth: usize,
    /// Windowed mean batch occupancy.
    pub occupancy: f64,
    /// Whether the tier can serve at all: loaded, and its supervisor
    /// still vouches for it ([`super::ModelRegistry::healthy`] — `false`
    /// once the restart budget is exhausted or every replica is dead).
    pub healthy: bool,
}

/// What one decision epoch concluded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TierDecision {
    /// Stay on the current tier.
    Hold,
    /// Shift toward a cheaper tier (higher ladder index).
    Down {
        /// Ladder index routed before this epoch.
        from: usize,
        /// Ladder index routed from now on.
        to: usize,
    },
    /// Shift toward a more expensive tier (lower ladder index).
    Up {
        /// Ladder index routed before this epoch.
        from: usize,
        /// Ladder index routed from now on.
        to: usize,
    },
}

/// One recorded tier transition — the controller's auditable decision
/// trace ([`TierController::trace`], exported by [`trace_to_bench`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TierEvent {
    /// Decision epoch (1-based count of `step`/`step_with` calls).
    pub epoch: u64,
    /// Ladder index shifted away from.
    pub from: usize,
    /// Ladder index shifted to.
    pub to: usize,
    /// The active tier's windowed queue time that triggered the shift.
    pub queue_ms: f64,
    /// `"slo_breach"` (down), `"headroom"` (up) or `"unhealthy"`
    /// (failover, either direction).
    pub reason: &'static str,
}

/// Mutable decision state, all behind one lock: dwell counters, the
/// per-tier stats windows, the last sensed signals and the event trace.
struct TierState {
    /// Consecutive epochs the active tier breached the SLO.
    breached: u32,
    /// Consecutive epochs the active tier sat below the recovery
    /// threshold.
    clear: u32,
    /// Decision epochs elapsed.
    epoch: u64,
    windows: Vec<StatsWindow>,
    last_signals: Vec<TierSignal>,
    trace: Vec<TierEvent>,
}

/// The closed-loop controller: an ordered precision ladder over a shared
/// [`ModelRegistry`], sampled against a latency SLO. See the module docs
/// for the sense → decide → act loop and DESIGN.md §Serving-API for the
/// hysteresis rationale.
pub struct TierController {
    registry: Arc<ModelRegistry>,
    cfg: TierConfig,
    /// Ladder index requests are routed to first. Atomic so `route()` on
    /// request threads never contends with a decision in flight.
    active: AtomicUsize,
    /// Requests shed because the whole ladder at/below the active tier
    /// was saturated.
    shed: AtomicU64,
    /// Cached per-tier sessions, refreshed from the registry when a tier
    /// is drained and re-loaded (same pattern as the net server's
    /// session cache).
    sessions: RwLock<Vec<Option<Session>>>,
    state: Mutex<TierState>,
}

impl TierController {
    /// Build a controller over `registry`. Every ladder tier must be
    /// loaded and unique; `cfg` thresholds are validated here so a
    /// misconfigured SLO fails at construction, not mid-traffic.
    pub fn new(registry: Arc<ModelRegistry>, cfg: TierConfig) -> Result<TierController> {
        ensure!(!cfg.tiers.is_empty(), "tier ladder is empty");
        ensure!(
            cfg.slo_ms.is_finite() && cfg.slo_ms > 0.0,
            "slo_ms must be a positive finite number, got {}",
            cfg.slo_ms
        );
        ensure!(
            cfg.recover_frac >= 0.0 && cfg.recover_frac < 1.0,
            "recover_frac must be in [0, 1) so the hysteresis dead band exists, got {}",
            cfg.recover_frac
        );
        ensure!(
            cfg.breach_epochs >= 1 && cfg.recover_epochs >= 1,
            "breach_epochs and recover_epochs must be at least 1"
        );
        for (i, name) in cfg.tiers.iter().enumerate() {
            ensure!(!cfg.tiers[..i].contains(name), "duplicate tier {name:?} in ladder");
        }
        let mut sessions = Vec::with_capacity(cfg.tiers.len());
        for name in &cfg.tiers {
            match registry.session(name) {
                Ok(s) => sessions.push(Some(s)),
                Err(e) => bail!("tier {name:?} is not servable: {e}"),
            }
        }
        let windows = cfg.tiers.iter().map(|_| StatsWindow::new(cfg.window)).collect();
        Ok(TierController {
            registry,
            active: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            sessions: RwLock::new(sessions),
            state: Mutex::new(TierState {
                breached: 0,
                clear: 0,
                epoch: 0,
                windows,
                last_signals: Vec::new(),
                trace: Vec::new(),
            }),
            cfg,
        })
    }

    /// The ladder, most expensive first.
    pub fn tiers(&self) -> &[String] {
        &self.cfg.tiers
    }

    /// The controller configuration.
    pub fn config(&self) -> &TierConfig {
        &self.cfg
    }

    /// Ladder index currently routed to first.
    pub fn active_tier(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Variant name of the active tier.
    pub fn active_tier_name(&self) -> &str {
        &self.cfg.tiers[self.active_tier()]
    }

    /// Total requests shed so far ([`ServeError::Shed`]).
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Decision epochs elapsed.
    pub fn epochs(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    /// The transition trace so far, in decision order.
    pub fn trace(&self) -> Vec<TierEvent> {
        self.state.lock().unwrap().trace.clone()
    }

    /// The signals the most recent `sample`/`step_with` saw (one per
    /// tier; empty before the first epoch). Benches use this to annotate
    /// per-epoch rows without re-sampling (a second sample would push the
    /// stats windows twice per epoch).
    pub fn last_signals(&self) -> Vec<TierSignal> {
        self.state.lock().unwrap().last_signals.clone()
    }

    /// **Sense**: snapshot every tier's registry stats, push them through
    /// the rolling windows, and return one [`TierSignal`] per tier. A
    /// tier that is unloaded (or whose replicas all failed) senses as
    /// unhealthy rather than erroring — the ladder must keep deciding
    /// while an operator swaps a tier out underneath it.
    pub fn sample(&self) -> Vec<TierSignal> {
        let mut st = self.state.lock().unwrap();
        let mut signals = Vec::with_capacity(self.cfg.tiers.len());
        for (i, name) in self.cfg.tiers.iter().enumerate() {
            let signal = match self.registry.stats(name) {
                Ok(snapshot) => {
                    // Health is the supervisor's verdict: it stays true
                    // across transient deaths that respawn within budget,
                    // and flips (permanently for this load) on budget
                    // exhaustion or total replica death. Load signals
                    // read the windowed delta.
                    let healthy = self.registry.healthy(name).unwrap_or(false);
                    let depth = self.registry.in_flight(name).unwrap_or(0);
                    let windowed = st.windows[i].push(snapshot);
                    TierSignal {
                        queue_ms: windowed.mean_queue_ms(),
                        depth,
                        occupancy: windowed.mean_occupancy(),
                        healthy,
                    }
                }
                Err(_) => TierSignal { queue_ms: 0.0, depth: 0, occupancy: 0.0, healthy: false },
            };
            signals.push(signal);
        }
        st.last_signals = signals.clone();
        signals
    }

    /// **Decide**: one pure hysteresis step over explicit signals (one
    /// per ladder tier, same order). This is the whole decision policy —
    /// `step_with` never touches the registry, so tests feed synthetic
    /// schedules and assert exact transition sequences.
    pub fn step_with(&self, signals: &[TierSignal]) -> TierDecision {
        assert_eq!(
            signals.len(),
            self.cfg.tiers.len(),
            "one TierSignal per ladder tier, in ladder order"
        );
        let mut st = self.state.lock().unwrap();
        st.epoch += 1;
        st.last_signals = signals.to_vec();
        let act = self.active.load(Ordering::SeqCst);
        let sig = &signals[act];

        // Health preempts hysteresis: a tier whose replicas are dead
        // cannot drain its queue at all, so dwell counters would only
        // delay the inevitable while accepted requests rot. Fail over
        // downward first (cheaper tiers have the headroom to absorb the
        // displaced load); climb upward only if nothing cheaper is alive.
        if !sig.healthy {
            let target = (act + 1..signals.len())
                .find(|&i| signals[i].healthy)
                .or_else(|| (0..act).rev().find(|&i| signals[i].healthy));
            if let Some(to) = target {
                st.breached = 0;
                st.clear = 0;
                let epoch = st.epoch;
                st.trace.push(TierEvent {
                    epoch,
                    from: act,
                    to,
                    queue_ms: sig.queue_ms,
                    reason: "unhealthy",
                });
                self.active.store(to, Ordering::SeqCst);
                return if to > act {
                    TierDecision::Down { from: act, to }
                } else {
                    TierDecision::Up { from: act, to }
                };
            }
            // The whole ladder is dead: nowhere to shift. Hold and let
            // route() surface the failure per request.
            return TierDecision::Hold;
        }

        if sig.queue_ms > self.cfg.slo_ms {
            st.clear = 0;
            st.breached += 1;
            if st.breached >= self.cfg.breach_epochs {
                if let Some(to) = (act + 1..signals.len()).find(|&i| signals[i].healthy) {
                    st.breached = 0;
                    let epoch = st.epoch;
                    st.trace.push(TierEvent {
                        epoch,
                        from: act,
                        to,
                        queue_ms: sig.queue_ms,
                        reason: "slo_breach",
                    });
                    self.active.store(to, Ordering::SeqCst);
                    return TierDecision::Down { from: act, to };
                }
                // Already the cheapest healthy tier: keep the counter
                // saturated so a cheaper tier hot-loaded later is taken
                // immediately; route() sheds in the meantime.
                st.breached = self.cfg.breach_epochs;
            }
        } else if sig.queue_ms < self.cfg.recover_frac * self.cfg.slo_ms {
            st.breached = 0;
            st.clear += 1;
            if st.clear >= self.cfg.recover_epochs {
                if let Some(to) = (0..act).rev().find(|&i| signals[i].healthy) {
                    st.clear = 0;
                    let epoch = st.epoch;
                    st.trace.push(TierEvent {
                        epoch,
                        from: act,
                        to,
                        queue_ms: sig.queue_ms,
                        reason: "headroom",
                    });
                    self.active.store(to, Ordering::SeqCst);
                    return TierDecision::Up { from: act, to };
                }
                // Already the most expensive (or nothing pricier is
                // healthy): saturate so headroom is spent the moment a
                // pricier tier becomes available.
                st.clear = self.cfg.recover_epochs;
            }
        } else {
            // Dead band between the recovery and breach thresholds: the
            // hysteresis itself. Both dwell counters reset, so a signal
            // hovering near the SLO can never flap the ladder.
            st.breached = 0;
            st.clear = 0;
        }
        TierDecision::Hold
    }

    /// One production epoch: sense then decide (`step_with(sample())`).
    pub fn step(&self) -> TierDecision {
        let signals = self.sample();
        self.step_with(&signals)
    }

    /// **Act**: submit `image` to the active tier, spilling down the
    /// ladder on per-queue backpressure or a drained tier. Returns the
    /// reply channel of whichever tier accepted. If every tier at or
    /// below the active one refused with a full queue, the request is
    /// shed: [`ServeError::Shed`], counted in
    /// [`TierController::shed_count`] — an explicit back-off signal
    /// instead of unbounded queueing. The image is threaded through the
    /// attempts by reclaim (no per-tier clone). The reply channel is
    /// answered exactly once — `Ok(Reply)` or a terminal `Err` such as
    /// [`ServeError::DeadlineExceeded`].
    pub fn route(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, ServeError> {
        self.route_deadline(image, None)
    }

    /// [`TierController::route`] with a per-request latency budget
    /// ([`Session::submit_deadline`]): whichever tier accepts may shed
    /// the request at dequeue once `budget` elapses.
    pub fn route_deadline(
        &self,
        image: Vec<f32>,
        budget: Option<std::time::Duration>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, ServeError> {
        let start = self.active.load(Ordering::SeqCst);
        let mut image = image;
        let mut saw_full = false;
        let mut last = ServeError::UnknownModel(self.cfg.tiers[start].clone());
        for idx in start..self.cfg.tiers.len() {
            let session = match self.session_for(idx) {
                Some(s) => s,
                None => {
                    last = ServeError::UnknownModel(self.cfg.tiers[idx].clone());
                    continue;
                }
            };
            match session.submit_reclaim_deadline(image, budget) {
                Ok(rx) => return Ok(rx),
                // Geometry is ladder-wide (one architecture at several
                // precisions): no cheaper tier would take it either.
                Err((e @ ServeError::BadImage { .. }, _)) => return Err(e),
                Err((ServeError::QueueFull { .. }, img)) => {
                    saw_full = true;
                    image = img;
                }
                Err((e, img)) => {
                    last = e;
                    image = img;
                }
            }
        }
        if saw_full {
            self.shed.fetch_add(1, Ordering::Relaxed);
            Err(ServeError::Shed)
        } else {
            Err(last)
        }
    }

    /// Blocking single-request inference through the ladder:
    /// [`TierController::route`] + receive.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply, ServeError> {
        let rx = self.route(image)?;
        rx.recv().unwrap_or(Err(ServeError::ShutDown))
    }

    /// Start a background thread running [`TierController::step`] every
    /// `cfg.epoch`. The driver stops (and joins) on [`TierDriver::stop`]
    /// or drop.
    pub fn start_driver(self: &Arc<Self>) -> std::io::Result<TierDriver> {
        let stop = Arc::new(AtomicBool::new(false));
        let ctl = Arc::clone(self);
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new().name("lsq-tier-ctl".to_string()).spawn(
            move || {
                while !flag.load(Ordering::SeqCst) {
                    // park_timeout instead of sleep so stop() can unpark
                    // for a prompt shutdown even with a long epoch.
                    std::thread::park_timeout(ctl.cfg.epoch);
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    ctl.step();
                }
            },
        )?;
        Ok(TierDriver { stop, handle: Some(handle) })
    }

    /// The cached session for ladder index `idx`, refreshed from the
    /// registry if the cached one was drained (a re-loaded tier gets a
    /// fresh intake, hence a fresh session). `None` = the tier is not
    /// currently servable.
    fn session_for(&self, idx: usize) -> Option<Session> {
        {
            let cached = self.sessions.read().unwrap();
            if let Some(Some(s)) = cached.get(idx) {
                if s.is_open() {
                    return Some(s.clone());
                }
            }
        }
        let mut cached = self.sessions.write().unwrap();
        match self.registry.session(&self.cfg.tiers[idx]) {
            Ok(s) if s.is_open() => {
                cached[idx] = Some(s.clone());
                Some(s)
            }
            _ => {
                cached[idx] = None;
                None
            }
        }
    }
}

/// A running background decision loop ([`TierController::start_driver`]).
pub struct TierDriver {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TierDriver {
    /// Stop the decision loop and join its thread.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            h.thread().unpark();
            let _ = h.join();
        }
    }
}

impl Drop for TierDriver {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Export a controller decision trace as bench rows (one per transition)
/// so `BENCH_serve.json` carries the full audit trail of a scheduled run:
/// the row name encodes epoch, reason and the tiers involved; the numeric
/// columns carry the triggering queue time and the ladder indices
/// (EXPERIMENTS.md §Perf L3).
pub fn trace_to_bench(b: &mut Bench, tiers: &[String], trace: &[TierEvent]) {
    for ev in trace {
        let name = format!(
            "tier_shift_e{}_{}_{}_to_{}",
            ev.epoch, ev.reason, tiers[ev.from], tiers[ev.to]
        );
        // One "sample" per transition: the triggering windowed queue
        // time, in ns so the row aggregates like the latency rows.
        b.record_ns(&name, &[ev.queue_ms * 1e6], 0.0);
        b.annotate(&name, "epoch", ev.epoch as f64);
        b.annotate(&name, "from_tier", ev.from as f64);
        b.annotate(&name, "to_tier", ev.to as f64);
        b.annotate(&name, "queue_ms", ev.queue_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BackendSpec;

    /// A controller whose ladder names are registered nowhere — only
    /// usable for `step_with` (pure decision logic), which is exactly
    /// what these tests drive. Built by bypassing `new()`'s
    /// loaded-variant check.
    fn bare_controller(tiers: &[&str], cfg_of: impl FnOnce(Vec<String>) -> TierConfig) -> TierController {
        let names: Vec<String> = tiers.iter().map(|s| s.to_string()).collect();
        let cfg = cfg_of(names.clone());
        let registry =
            Arc::new(ModelRegistry::with_core_budget(BackendSpec::native(Path::new(".")), 1));
        let windows = names.iter().map(|_| StatsWindow::new(cfg.window)).collect();
        TierController {
            registry,
            active: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            sessions: RwLock::new(names.iter().map(|_| None).collect()),
            state: Mutex::new(TierState {
                breached: 0,
                clear: 0,
                epoch: 0,
                windows,
                last_signals: Vec::new(),
                trace: Vec::new(),
            }),
            cfg,
        }
    }

    use std::path::Path;

    fn sig(queue_ms: f64) -> TierSignal {
        TierSignal { queue_ms, depth: 0, occupancy: 1.0, healthy: true }
    }

    /// Breach must persist for `breach_epochs` before a downshift, and a
    /// single clear epoch resets the dwell — the core anti-flap property.
    #[test]
    fn breach_dwell_filters_transient_spikes() {
        let c = bare_controller(&["q8", "q4"], |t| TierConfig::new(t, 10.0));
        // One spike, then clear: no transition.
        assert_eq!(c.step_with(&[sig(50.0), sig(1.0)]), TierDecision::Hold);
        assert_eq!(c.step_with(&[sig(1.0), sig(1.0)]), TierDecision::Hold);
        assert_eq!(c.active_tier(), 0);
        // Two consecutive breaches: down.
        assert_eq!(c.step_with(&[sig(50.0), sig(1.0)]), TierDecision::Hold);
        assert_eq!(
            c.step_with(&[sig(50.0), sig(1.0)]),
            TierDecision::Down { from: 0, to: 1 }
        );
        assert_eq!(c.active_tier(), 1);
        assert_eq!(c.trace().len(), 1);
        assert_eq!(c.trace()[0].reason, "slo_breach");
    }

    /// The dead band (between recover_frac·slo and slo) resets both dwell
    /// counters: a signal hovering near the SLO never flaps the ladder.
    #[test]
    fn dead_band_resets_both_dwell_counters() {
        let c = bare_controller(&["q8", "q4"], |t| TierConfig::new(t, 10.0));
        // Walk down first.
        c.step_with(&[sig(50.0), sig(1.0)]);
        c.step_with(&[sig(50.0), sig(1.0)]);
        assert_eq!(c.active_tier(), 1);
        // Two clear epochs, then a dead-band epoch (7.0 ∈ [5, 10]), then
        // two more clear: recovery needs 3 *consecutive* clears, so no up
        // yet.
        c.step_with(&[sig(1.0), sig(1.0)]);
        c.step_with(&[sig(1.0), sig(1.0)]);
        assert_eq!(c.step_with(&[sig(1.0), sig(7.0)]), TierDecision::Hold);
        c.step_with(&[sig(1.0), sig(1.0)]);
        assert_eq!(c.step_with(&[sig(1.0), sig(1.0)]), TierDecision::Hold);
        // Third consecutive clear: up.
        assert_eq!(c.step_with(&[sig(1.0), sig(1.0)]), TierDecision::Up { from: 1, to: 0 });
        assert_eq!(c.active_tier(), 0);
    }

    /// An unhealthy active tier fails over immediately — no dwell —
    /// preferring cheaper tiers, climbing only when nothing cheaper is
    /// alive; a fully dead ladder holds.
    #[test]
    fn unhealthy_tier_fails_over_immediately() {
        let c = bare_controller(&["q8", "q4", "q2"], |t| TierConfig::new(t, 10.0));
        let dead = TierSignal { queue_ms: 0.0, depth: 0, occupancy: 0.0, healthy: false };
        // Active q8 dies with q4 also dead: skip straight to q2.
        assert_eq!(
            c.step_with(&[dead.clone(), dead.clone(), sig(1.0)]),
            TierDecision::Down { from: 0, to: 2 }
        );
        // q2 dies too, but q8 has recovered: climb back up.
        assert_eq!(
            c.step_with(&[sig(1.0), dead.clone(), dead.clone()]),
            TierDecision::Up { from: 2, to: 0 }
        );
        // Everything dead: hold (route() surfaces per-request failures).
        assert_eq!(
            c.step_with(&[dead.clone(), dead.clone(), dead.clone()]),
            TierDecision::Hold
        );
        let reasons: Vec<&str> = c.trace().iter().map(|e| e.reason).collect();
        assert_eq!(reasons, ["unhealthy", "unhealthy"]);
    }

    /// At the cheapest healthy tier a sustained breach holds (shedding is
    /// route()'s job), and the saturated dwell takes a newly-healthy
    /// cheaper tier on the very next breached epoch.
    #[test]
    fn saturated_breach_takes_new_cheaper_tier_immediately() {
        let c = bare_controller(&["q8", "q4"], |t| TierConfig::new(t, 10.0));
        let dead = TierSignal { queue_ms: 0.0, depth: 0, occupancy: 0.0, healthy: false };
        // q4 dead: breaches on q8 have nowhere to go.
        c.step_with(&[sig(50.0), dead.clone()]);
        assert_eq!(c.step_with(&[sig(50.0), dead.clone()]), TierDecision::Hold);
        assert_eq!(c.step_with(&[sig(50.0), dead]), TierDecision::Hold);
        // q4 comes back: the saturated counter shifts immediately.
        assert_eq!(
            c.step_with(&[sig(50.0), sig(1.0)]),
            TierDecision::Down { from: 0, to: 1 }
        );
    }

    #[test]
    fn config_validation_rejects_bad_ladders() {
        let registry =
            Arc::new(ModelRegistry::with_core_budget(BackendSpec::native(Path::new(".")), 1));
        // Empty ladder.
        assert!(TierController::new(Arc::clone(&registry), TierConfig::new(vec![], 5.0)).is_err());
        // Duplicate tier.
        let dup = TierConfig::new(vec!["a".into(), "a".into()], 5.0);
        assert!(TierController::new(Arc::clone(&registry), dup).is_err());
        // Non-positive SLO.
        let bad_slo = TierConfig::new(vec!["a".into()], 0.0);
        assert!(TierController::new(Arc::clone(&registry), bad_slo).is_err());
        // recover_frac must leave a dead band.
        let mut bad_frac = TierConfig::new(vec!["a".into()], 5.0);
        bad_frac.recover_frac = 1.0;
        assert!(TierController::new(Arc::clone(&registry), bad_frac).is_err());
        // Unloaded tier: not servable.
        let unloaded = TierConfig::new(vec!["a".into()], 5.0);
        assert!(TierController::new(registry, unloaded).is_err());
    }

    /// The trace exporter writes one row per transition with the reason
    /// and tier names encoded in the row name.
    #[test]
    fn trace_rows_carry_reason_and_tiers() {
        let tiers = vec!["q8".to_string(), "q4".to_string()];
        let trace = vec![
            TierEvent { epoch: 4, from: 0, to: 1, queue_ms: 12.5, reason: "slo_breach" },
            TierEvent { epoch: 9, from: 1, to: 0, queue_ms: 0.5, reason: "headroom" },
        ];
        let mut b = Bench::with_opts(
            "serve",
            crate::util::bench::BenchOpts {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(1),
                min_iters: 1,
            },
        );
        trace_to_bench(&mut b, &tiers, &trace);
        let json = b.to_json().to_string();
        assert!(json.contains("tier_shift_e4_slo_breach_q8_to_q4"));
        assert!(json.contains("tier_shift_e9_headroom_q4_to_q8"));
        assert!(json.contains("\"queue_ms\""));
    }
}
