//! Host tensor type + checkpoint serialization.
//!
//! `Tensor` is the coordinator-side value type: a shape plus flat f32 or i32
//! data. It deliberately implements only what the coordinator needs
//! (creation, stats, indexing, (de)serialization) — all heavy math runs
//! inside the AOT XLA artifacts.
//!
//! Checkpoints are a self-describing binary container (`LSQCKPT1`): a JSON
//! header (names, shapes, dtypes, offsets, user metadata) followed by raw
//! little-endian payloads. Writing is atomic (tmp + rename).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of a [`Tensor`] (both 4 bytes, little-endian on disk).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        4
    }

    /// Canonical manifest/checkpoint name (`"float32"` / `"int32"`).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
        }
    }

    /// Parse a manifest/checkpoint dtype name.
    pub fn from_name(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }
}

/// Flat tensor payload, one variant per [`DType`].
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    /// fp32 payload.
    F32(Vec<f32>),
    /// i32 payload.
    I32(Vec<i32>),
}

/// Host tensor: a shape plus flat row-major data.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimension sizes (empty = scalar).
    pub shape: Vec<usize>,
    /// Flat row-major payload.
    pub data: Data,
}

impl Tensor {
    /// All-zero fp32 tensor of `shape`.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![0.0; numel(shape)]) }
    }

    /// fp32 tensor from flat data (panics on shape/len mismatch).
    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    /// i32 tensor from flat data (panics on shape/len mismatch).
    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    /// Rank-0 fp32 scalar.
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    /// Element count (1 for scalars).
    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    /// Element type of the payload.
    pub fn dtype(&self) -> DType {
        match &self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    /// Borrow the fp32 payload (error if i32).
    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Mutably borrow the fp32 payload (error if i32).
    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Borrow the i32 payload (error if fp32).
    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Scalar extraction (shape [] or [1]).
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.f32s()?;
        if v.len() != 1 {
            bail!("item() on tensor with {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Little-endian view of the payload (for literals and checkpoints).
    pub fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            Data::F32(v) => bytes_of_f32(v),
            Data::I32(v) => bytes_of_i32(v),
        }
    }
}

/// Element count of `shape` (1 for the scalar shape `[]`).
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(1)
}

fn bytes_of_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytes_of_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Decode little-endian bytes to fp32 values.
pub fn f32s_from_bytes(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Decode little-endian bytes to i32 values.
pub fn i32s_from_bytes(b: &[u8]) -> Vec<i32> {
    b.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

// ---------------------------------------------------------------------------
// Checkpoint container
// ---------------------------------------------------------------------------

const MAGIC: &[u8; 8] = b"LSQCKPT1";

/// Named tensor collection with free-form JSON metadata.
#[derive(Default, Debug)]
pub struct Checkpoint {
    /// Named tensors, sorted by name (serialization order).
    pub tensors: BTreeMap<String, Tensor>,
    /// Free-form JSON metadata (family, step, ...).
    pub meta: BTreeMap<String, Json>,
}

impl Checkpoint {
    /// Empty checkpoint.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add or replace tensor `name`.
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    /// Look up tensor `name` (error when missing).
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("checkpoint missing tensor {name:?}"))
    }

    /// String metadata value for `key`, if present.
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(Json::as_str)
    }

    /// Write the `LSQCKPT1` container atomically (tmp + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for (name, t) in &self.tensors {
            let nbytes = t.numel() * t.dtype().size();
            entries.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("dtype", Json::str(t.dtype().name())),
                (
                    "shape",
                    Json::Arr(t.shape.iter().map(|d| Json::num(*d as f64)).collect()),
                ),
                ("offset", Json::num(offset as f64)),
                ("nbytes", Json::num(nbytes as f64)),
            ]));
            offset += nbytes;
        }
        let header = Json::obj(vec![
            ("tensors", Json::Arr(entries)),
            ("meta", Json::Obj(self.meta.clone())),
        ])
        .to_string();

        let tmp = path.with_extension("tmp");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("create {tmp:?}"))?;
            f.write_all(MAGIC)?;
            f.write_all(&(header.len() as u64).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            for t in self.tensors.values() {
                f.write_all(t.raw_bytes())?;
            }
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read an `LSQCKPT1` container written by [`Checkpoint::save`].
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not an LSQCKPT1 checkpoint");
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow!("{path:?}: bad header: {e}"))?;
        let mut body = Vec::new();
        f.read_to_end(&mut body)?;

        let mut ck = Checkpoint::new();
        if let Some(Json::Obj(meta)) = header.get("meta") {
            ck.meta = meta.clone();
        }
        for e in header.arr_at("tensors")? {
            let name = e.str_at("name")?;
            let dtype = DType::from_name(e.str_at("dtype")?)?;
            let shape: Vec<usize> = e
                .arr_at("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let offset = e.usize_at("offset")?;
            let nbytes = e.usize_at("nbytes")?;
            if offset + nbytes > body.len() {
                bail!("{path:?}: tensor {name} out of bounds");
            }
            let bytes = &body[offset..offset + nbytes];
            let t = match dtype {
                DType::F32 => Tensor::from_f32(&shape, f32s_from_bytes(bytes)),
                DType::I32 => Tensor::from_i32(&shape, i32s_from_bytes(bytes)),
            };
            ck.insert(name, t);
        }
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_and_access() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.f32s().unwrap()[4], 5.0);
        assert!(t.i32s().is_err());
    }

    #[test]
    fn scalar() {
        let t = Tensor::scalar_f32(3.5);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.item_f32().unwrap(), 3.5);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn bytes_roundtrip() {
        let t = Tensor::from_f32(&[3], vec![1.5, -2.0, 0.25]);
        let back = f32s_from_bytes(t.raw_bytes());
        assert_eq!(back, vec![1.5, -2.0, 0.25]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lsq_ck_{}", std::process::id()));
        let path = dir.join("a.ckpt");
        let mut ck = Checkpoint::new();
        ck.insert("w", Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        ck.insert("y", Tensor::from_i32(&[3], vec![7, -8, 9]));
        ck.meta.insert("family".into(), Json::str("cnn_small_q2"));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.get("w").unwrap(), ck.get("w").unwrap());
        assert_eq!(back.get("y").unwrap().i32s().unwrap(), &[7, -8, 9]);
        assert_eq!(back.meta_str("family"), Some("cnn_small_q2"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("lsq_ckg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
