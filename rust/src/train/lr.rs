//! Learning-rate schedules. The schedule is computed on the coordinator and
//! fed to the AOT train step as a runtime scalar, so one artifact serves
//! every schedule (Section 3.5 compares cosine vs step decay).

use crate::config::{Schedule, TrainConfig};

/// LR at optimizer step `step` of `total_steps`.
pub fn lr_at(cfg: &TrainConfig, steps_per_epoch: usize, step: usize) -> f64 {
    let total = (cfg.epochs * steps_per_epoch).max(1);
    match cfg.schedule {
        Schedule::Cosine => {
            // Cosine decay to zero without restarts (Loshchilov & Hutter).
            let t = (step.min(total) as f64) / total as f64;
            0.5 * cfg.lr * (1.0 + (std::f64::consts::PI * t).cos())
        }
        Schedule::Step => {
            let epoch = step / steps_per_epoch.max(1);
            let drops = epoch / cfg.step_every.max(1);
            cfg.lr * 0.1f64.powi(drops as i32)
        }
        Schedule::Const => cfg.lr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn cfg(schedule: Schedule) -> TrainConfig {
        TrainConfig { epochs: 10, lr: 0.1, schedule, step_every: 4, ..Default::default() }
    }

    #[test]
    fn cosine_endpoints() {
        let c = cfg(Schedule::Cosine);
        assert!((lr_at(&c, 10, 0) - 0.1).abs() < 1e-12);
        let mid = lr_at(&c, 10, 50);
        assert!((mid - 0.05).abs() < 1e-9, "mid={mid}");
        assert!(lr_at(&c, 10, 100) < 1e-9);
    }

    #[test]
    fn cosine_monotone_decreasing() {
        let c = cfg(Schedule::Cosine);
        let mut prev = f64::INFINITY;
        for s in 0..=100 {
            let v = lr_at(&c, 10, s);
            assert!(v <= prev + 1e-12);
            prev = v;
        }
    }

    #[test]
    fn step_decays_by_ten() {
        let c = cfg(Schedule::Step);
        assert_eq!(lr_at(&c, 10, 0), 0.1);
        assert_eq!(lr_at(&c, 10, 39), 0.1); // epoch 3
        assert!((lr_at(&c, 10, 40) - 0.01).abs() < 1e-12); // epoch 4
        assert!((lr_at(&c, 10, 80) - 0.001).abs() < 1e-12); // epoch 8
    }

    #[test]
    fn const_is_const() {
        let c = cfg(Schedule::Const);
        assert_eq!(lr_at(&c, 10, 0), lr_at(&c, 10, 99));
    }

    #[test]
    fn clamps_past_end() {
        let c = cfg(Schedule::Cosine);
        assert!(lr_at(&c, 10, 500) >= 0.0);
    }
}
